//! Strongly-ordered replication path (§4.3–§4.4): Mu SMR instances per
//! *catalog-global* synchronization group — the data plane flattens each
//! object's local groups into one global index space (`Catalog::
//! global_group`), so a multi-object catalog gets one round pipeline and
//! one replication log per (object, group) pair — the replication logs,
//! leader-forwarding and requester bookkeeping, plus the Raft pipeline
//! (whose single total log tags entries with their `ObjectId` for
//! per-object apply), serving both the
//! Waverunner baseline (§5.2, which replicates *every* update through this
//! path with leader-only clients) and the stand-alone `backend = raft`
//! configuration (category-routed like Mu, leader-authoritative
//! permissibility, batched AppendEntries). The APUS-style Paxos backend
//! lives in its own plane, `engine::paxos`.
//!
//! The path owns its completion tokens ([`StrongToken`]): Mu round
//! responses and forwarded-op replies route back here via the coordinator's
//! token table. The former `TokenCtx::Raft` variant is gone — Raft
//! AppendEntries completions are logical (`Payload::RaftAck` verbs), so the
//! fan-out rides fire-and-forget `Ignore` tokens like all other
//! unacknowledged writes.

use crate::config::{ConsensusBackend, PropagationMode, SimConfig, SystemKind};
use crate::engine::path::{
    Membership, MembershipEvent, PendingClient, ReplicaCore, ReplicationPath, Requester,
    Submission, TokenCtx,
};
use crate::engine::store::{Catalog, KV_READ};
use crate::engine::Ctx;
use crate::mem::MemKind;
use crate::net::verbs::{Payload, ReadData, ReadTarget, Verb};
use crate::rdt::OpCall;
use crate::sim::{EventKind, NodeId, Time, TimerKind};
use crate::smr::log::ReplicationLog;
use crate::smr::election::PlacementTable;
use crate::smr::mu::{MuInstance, Resp, Round, Step};
use crate::smr::raft::{RaftFollower, RaftLeader, RaftStep};
use crate::util::hasher::FastMap;
use crate::workload::WorkItem;

/// Completion tokens owned by the strong path.
#[derive(Clone, Copy, Debug)]
pub enum StrongToken {
    /// Mu fan-out response: (group, round_id at fan-out time).
    Mu { group: u8, round_id: u64 },
    /// Forwarded conflicting op awaiting a LeaderReply.
    Forward { request_id: u64 },
}

pub struct StrongPath {
    prop_con: PropagationMode,
    /// Mu or Raft (Paxos lives in `engine::paxos`). Waverunner pins Raft.
    backend: ConsensusBackend,
    system: SystemKind,
    /// Leader-side log-entry batching bound (1 = off).
    batch: usize,
    /// Strong-plane pipeline depth: up to this many consensus rounds in
    /// flight per group/shard (1 = stop-and-wait, the seed behavior).
    window: usize,
    /// Chaos mode (schedule has link faults): forwarded ops arm a reply
    /// watchdog and the Raft leader gets a periodic re-pump tick, since
    /// lossy links can eat the logical acks the pipeline waits on.
    chaos: bool,
    /// One Mu instance + replication log per synchronization group. Under
    /// `backend = raft` the group-0 log doubles as a mirror of the Raft
    /// log (proposal = term, kept fully applied) so snapshot transfer and
    /// anti-entropy replay work exactly like Mu/Paxos.
    mu: Vec<MuInstance>,
    logs: Vec<ReplicationLog>,
    /// First fan-out time of each in-flight consensus round, keyed
    /// `(group-or-shard, start slot)`. `or_insert` keeps the first
    /// attempt's stamp across chaos re-pumps, so `smr_round` measures true
    /// first-issue-to-commit latency.
    round_start: FastMap<(usize, u64), u64>,
    requesters: FastMap<(usize, u64), Requester>,
    pending_fwd: FastMap<u64, PendingClient>,
    next_request_id: u64,
    /// Mu leadership confirmation: false from a promotion until the first
    /// WriteProposal round reaches quorum. A never-confirmed "leader" whose
    /// rounds stall while a smaller live node exists is a partition-side
    /// imposter and abdicates (it cannot have applied anything — Mu applies
    /// only at the Accept phase, which confirmation precedes). One shared
    /// flag under `placement = single` (one leadership covers every
    /// group), one per group under sharded placements — see `cidx`.
    mu_confirmed: Vec<bool>,
    /// Chaos-mode exactly-once ledger for forwarded ops: verdicts of
    /// already-ordered `(origin, seq)` pairs. A lost LeaderReply makes the
    /// origin's watchdog re-forward; without this the duplicate would
    /// execute twice in total order (converged but double-debited).
    done_fwd: FastMap<(usize, u64), bool>,
    /// Raft fast path (Waverunner baseline + stand-alone backend). Under
    /// `placement = single` there is exactly one shard — today's single
    /// total log. Sharded placements give every global sync group its own
    /// shard (leader/follower automata, lease, parked queue); appends,
    /// acks and replays carry the shard's group id so instances never
    /// interfere, and shard `s` mirrors into `logs[s]`.
    raft: Vec<RaftShard>,
    /// Per-group leadership view this path last acted on, diffed against
    /// `core.group_leaders` when a `GroupLeadersChanged` event arrives to
    /// find the groups gained (takeover) or lost. Unused under
    /// `placement = single` (the event never fires).
    led: Vec<bool>,
}

/// One Raft consensus instance (see the `raft` field docs).
struct RaftShard {
    leader: Option<RaftLeader>,
    follower: RaftFollower,
    pending: FastMap<u64, Requester>, // index -> requester
    /// Raft leadership lease: a promoted leader must collect a majority of
    /// append acks (its takeover replay / an empty probe) before serving —
    /// submissions park below until then, so a fenced partition-side
    /// imposter never applies or replicates anything and can abdicate
    /// cleanly. The boot leader holds the lease by construction.
    lease: bool,
    votes: FastMap<usize, ()>,
    parked: Vec<(OpCall, Requester)>,
}

impl RaftShard {
    fn new(leader: Option<RaftLeader>) -> Self {
        RaftShard {
            leader,
            follower: RaftFollower::new(),
            pending: FastMap::default(),
            lease: true,
            votes: FastMap::default(),
            parked: Vec::new(),
        }
    }
}

impl StrongPath {
    pub fn new(cfg: &SimConfig, id: NodeId, groups: usize) -> Self {
        let sharded = cfg.placement.is_sharded();
        let table = PlacementTable::new(cfg.placement, groups, cfg.n_replicas);
        // The Raft pipeline serves both Waverunner (whose preset pins
        // backend = Raft) and the stand-alone Raft backend; node 0 leads
        // fault-free single-placement runs either way, while sharded
        // placements boot one shard per global group with the placement
        // table's leader holding that shard's lease by construction.
        let raft_shards = if cfg.backend == ConsensusBackend::Raft && sharded {
            groups.max(1)
        } else {
            1
        };
        let raft = (0..raft_shards)
            .map(|s| {
                let leads = cfg.backend == ConsensusBackend::Raft
                    && if sharded {
                        table.leader_of(s) == id
                    } else {
                        id == crate::smr::raft::initial_leader()
                    };
                RaftShard::new(leads.then(|| {
                    RaftLeader::with_window(
                        cfg.n_replicas,
                        cfg.batch_size as usize,
                        cfg.window as usize,
                    )
                }))
            })
            .collect();
        StrongPath {
            prop_con: cfg.prop_conflicting,
            backend: cfg.backend,
            system: cfg.system,
            batch: cfg.batch_size as usize,
            window: cfg.window as usize,
            chaos: cfg.fault.has_link_faults(),
            mu: (0..groups)
                .map(|g| MuInstance::with_window(g as u8, cfg.n_replicas, cfg.window as usize))
                .collect(),
            logs: (0..groups).map(|_| ReplicationLog::new()).collect(),
            round_start: FastMap::default(),
            requesters: FastMap::default(),
            pending_fwd: FastMap::default(),
            next_request_id: 1,
            mu_confirmed: vec![true; if sharded { groups.max(1) } else { 1 }],
            done_fwd: FastMap::default(),
            raft,
            led: (0..groups).map(|g| table.leader_of(g) == id).collect(),
        }
    }

    /// Raft shard index for global group `g`: identity under sharded
    /// placements, the one shared shard otherwise.
    fn sidx(&self, g: usize) -> usize {
        if self.raft.len() > 1 {
            g
        } else {
            0
        }
    }

    /// Mu confirmation-flag index for global group `g` (same collapse).
    fn cidx(&self, g: usize) -> usize {
        if self.mu_confirmed.len() > 1 {
            g
        } else {
            0
        }
    }

    /// Mirror a run of Raft entries into shard `s`'s replication log (the
    /// group-0 log under `placement = single`) so the generic
    /// snapshot/replay machinery sees the Raft log. The mirror is
    /// kept fully applied — Raft applies through its own automaton — so the
    /// Mu-style quiescence drain never double-executes.
    fn raft_mirror_append(&mut self, s: usize, start: u64, term: u64, ops: &[OpCall]) {
        while self.logs.len() <= s {
            self.logs.push(ReplicationLog::new());
        }
        let log = &mut self.logs[s];
        for (i, op) in ops.iter().enumerate() {
            log.write_slot(start + i as u64, term, *op);
        }
        log.applied_upto = log.applied_upto.max(log.next_free_slot());
    }

    fn drain_logs_cost(&mut self, core: &mut ReplicaCore) -> u64 {
        let mut cost = 0;
        for g in 0..self.logs.len() {
            for entry in self.logs[g].drain_unapplied() {
                cost += core.exec().op_exec_ns + core.sys.mem.local_read_ns(core.landing_mem());
                core.executions += 1;
                core.plane.apply_forced(&entry.op);
            }
        }
        cost
    }

    fn submit_conflicting(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, op: OpCall, req: Requester) {
        if core.system == SystemKind::Waverunner {
            self.waverunner_submit(core, ctx, mb, op, req);
            return;
        }
        if self.backend == ConsensusBackend::Raft {
            self.raft_submit(core, ctx, mb, op, req);
            return;
        }
        self.requesters.insert((op.origin, op.seq), req);
        // Catalog flattening: (object, local sync group) -> global
        // group, one Mu round pipeline + replication log per global
        // group. Sharded placements route leadership per group.
        let g = core.plane.global_group(&op) as usize;
        if core.is_leader_of(g) {
            let slot = self.logs[g].next_free_slot();
            if let Some((rid, at, round)) = self.mu[g].submit(op, slot) {
                self.round_start.entry((g, at)).or_insert(ctx.q.now());
                ctx.metrics.note_inflight(g, self.mu[g].depth() as u64);
                self.fan_out_round(core, ctx, mb, g, rid, round);
            }
        } else {
            self.forward_conflicting(core, ctx, op, req);
        }
    }

    /// Refill group `g`'s window from its queue (pump-until-full: a commit
    /// frees one stage, but a takeover or an abort can free several).
    fn mu_pump_full(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, g: usize) {
        loop {
            let slot = self.logs[g].next_free_slot();
            let Some((rid, at, round)) = self.mu[g].pump(slot) else { break };
            self.round_start.entry((g, at)).or_insert(ctx.q.now());
            ctx.metrics.note_inflight(g, self.mu[g].depth() as u64);
            self.fan_out_round(core, ctx, mb, g, rid, round);
        }
    }

    /// Forward a conflicting op to the leader (one RPC-sized write; §4.3).
    fn forward_conflicting(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, op: OpCall, req: Requester) {
        let request_id = self.next_request_id;
        self.next_request_id += 1;
        if let Requester::Local { client, arrival } = req {
            self.pending_fwd.insert(request_id, PendingClient { client, arrival, retries: 0, op });
            if self.chaos {
                core.arm_forward_watchdog(ctx, request_id);
            }
        }
        let leader = core.leader_for_op(&op);
        let tok = core.token(TokenCtx::Strong(StrongToken::Forward { request_id }));
        let verb = Verb::write(
            core.landing_mem_for_peer(),
            Payload::LeaderForward { op, reply_to: core.id, request_id },
            tok,
        );
        ctx.metrics.verbs += 1;
        let start = ctx.q.now().max(core.busy_until);
        let out = ctx.net.issue(ctx.q, ctx.qps, &core.sys.fabric, start, core.id, leader, verb, true);
        core.busy_total += out.initiator_free_at - start;
        core.busy_until = out.initiator_free_at;
    }

    // ----- stand-alone Raft backend (non-Waverunner) ---------------------

    /// Promote this replica to Raft leader of shard `s` if it isn't one
    /// yet (election takeover, rebalance takeover, or an origin-side retry
    /// that self-elected first). The promotion opens a lease campaign: the
    /// adopted log is re-replicated at the bumped term (an empty probe
    /// when there is nothing to replay), and follower acks become the
    /// lease votes.
    fn ensure_raft_leader(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, s: usize) {
        if self.raft[s].leader.is_some() {
            return;
        }
        let term = self.raft[s].follower.term + 1;
        let next = self.raft[s].follower.log_len();
        self.raft[s].leader =
            Some(RaftLeader::promote(mb.live_set().len(), self.batch, self.window, term, next));
        self.raft[s].lease = false;
        self.raft[s].votes = FastMap::default();
        self.raft_campaign(core, ctx, mb, s);
        if !self.raft[s].lease {
            // Campaign-retry chain: probes may be fenced at followers that
            // have not run their permission switch yet.
            ctx.q.push(
                ctx.q.now() + core.heartbeat_period_ns,
                core.id,
                EventKind::Timer(TimerKind::SmrTick(s as u8)),
            );
        }
    }

    /// One lease-campaign wave: term-bumped replay of the adopted log to
    /// every live peer (followers overwrite-accept, which is idempotent),
    /// or an empty probe batch when the log is empty. Solo leaders grant
    /// themselves the lease — there is no one left to vote.
    fn raft_campaign(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, s: usize) {
        if mb.live_set().len() / 2 == 0 {
            self.raft_grant_lease(core, ctx, mb, s);
            return;
        }
        let entries: Vec<OpCall> = self.raft[s].follower.entries().to_vec();
        let term = self.raft[s].leader.as_ref().expect("campaigning leader").term;
        let peers = mb.live_peers(core.id);
        if entries.is_empty() {
            for peer in peers {
                self.raft_send_to(core, ctx, s, peer, term, 0, Vec::new());
            }
            return;
        }
        let step = self.batch.max(1);
        let mut start = 0usize;
        while start < entries.len() {
            let end = (start + step).min(entries.len());
            self.raft_fan_out(core, ctx, mb, s, term, start as u64, entries[start..end].to_vec());
            start = end;
        }
    }

    /// A follower acknowledged our current term: count it toward the
    /// lease. Majority (of the live view) grants it and drains the parked
    /// submissions through the normal leader entry.
    fn raft_lease_vote(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, s: usize, term: u64, from: NodeId) {
        if self.raft[s].lease {
            return;
        }
        let Some(rl) = self.raft[s].leader.as_ref() else { return };
        if rl.term != term {
            return;
        }
        self.raft[s].votes.insert(from, ());
        if self.raft[s].votes.len() >= mb.live_set().len() / 2 {
            self.raft_grant_lease(core, ctx, mb, s);
        }
    }

    fn raft_grant_lease(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, s: usize) {
        self.raft[s].lease = true;
        let parked = std::mem::take(&mut self.raft[s].parked);
        for (op, req) in parked {
            self.raft_submit(core, ctx, mb, op, req);
        }
    }

    /// A promoted-but-unleased "leader" learned the rightful leader is
    /// someone else (typically after a partition heals): it was a
    /// partition-side imposter. Nothing was applied or replicated while
    /// parked, so abdication is a pure re-route: adopt the rightful view,
    /// re-fence the QP row, and push the parked ops back through the
    /// forward path. Under sharded placement the handover is per group —
    /// shard `s`'s entry in `group_leaders` adopts `rightful` and the row
    /// refences against the *full* per-group leader set (a plain
    /// `switch_leader` would revoke grants for groups that never moved).
    fn raft_abdicate(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, s: usize, rightful: NodeId) {
        if core.placement.is_sharded() {
            core.group_leaders[s] = rightful;
            ctx.qps.refence(core.id, &core.group_leaders);
            if let Some(l) = self.led.get_mut(s) {
                *l = false;
            }
        } else {
            ctx.qps.switch_leader(core.id, core.leader, rightful);
            core.leader = rightful;
        }
        self.raft[s].leader = None;
        self.raft[s].lease = true;
        self.raft[s].votes = FastMap::default();
        core.request_sync(ctx, rightful);
        let parked = std::mem::take(&mut self.raft[s].parked);
        for (op, req) in parked {
            match req {
                Requester::Local { .. } => self.forward_conflicting(core, ctx, op, req),
                Requester::Remote { reply_to, request_id } => {
                    self.reply_remote(core, ctx, reply_to, request_id, false, false)
                }
            }
        }
    }

    /// Generic Raft leader entry: unlike Waverunner's (which replicates
    /// even locally-rejected applies to mirror §5.2), the stand-alone
    /// backend gives the leader Mu-equivalent authority — an op that fails
    /// permissibility in total-order position is rejected, not replicated;
    /// followers then apply the log unconditionally (`apply_forced`).
    fn raft_submit(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, op: OpCall, req: Requester) {
        let g = core.plane.global_group(&op) as usize;
        if !core.is_leader_of(g) {
            self.forward_conflicting(core, ctx, op, req);
            return;
        }
        let s = self.sidx(g);
        self.ensure_raft_leader(core, ctx, mb, s);
        if !self.raft[s].lease {
            // Leadership not confirmed by a follower majority yet: park.
            self.raft[s].parked.push((op, req));
            return;
        }
        if !core.plane.permissible(&op) {
            core.note_rejected(&op);
            if self.chaos {
                self.done_fwd.insert((op.origin, op.seq), false);
            }
            self.answer_requester(core, ctx, req, false);
            return;
        }
        let cost = core.exec().op_exec_ns + core.write_state_cost(false);
        core.occupy(ctx.q.now(), cost);
        core.executions += 1;
        core.plane.apply(&op);
        let rl = self.raft[s].leader.as_mut().expect("just ensured");
        let term = rl.term;
        let (index, fanout) = rl.submit(op);
        let depth = rl.depth() as u64;
        self.raft_mirror_append(s, index, term, &[op]);
        self.raft[s].pending.insert(index, req);
        if let Some((term, start, ops)) = fanout {
            self.round_start.entry((s, start)).or_insert(ctx.q.now());
            ctx.metrics.note_inflight(s, depth);
            self.raft_fan_out(core, ctx, mb, s, term, start, ops);
        }
    }

    /// Fan one Mu phase out to the live follower set. `rid` is the phase
    /// nonce the automaton allocated — completion tokens carry it so
    /// responses route back to the owning in-flight round (stale rids
    /// drop inside the automaton).
    fn fan_out_round(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, g: usize, rid: u64, round: Round) {
        let group = g as u8;
        let peers = mb.live_peers(core.id);
        self.mu[g].round_started(rid, peers.len() as u32);
        let use_wt = self.prop_con == PropagationMode::WriteThrough;
        // Sequential SMR: the leader is execution-busy from the previous
        // round's fan-out through this round's quorum (appendix D.1).
        let now = ctx.q.now();
        if now > core.busy_until {
            core.busy_total += now - core.busy_until;
            core.busy_until = now;
        }
        let start = ctx.q.now().max(core.busy_until);
        let mut cursor = start;
        for dst in peers {
            let tok = core.token(TokenCtx::Strong(StrongToken::Mu { group, round_id: rid }));
            // All rounds want completions: writes for quorum ACKs, reads so
            // crashed followers surface as NACKs (reads otherwise complete
            // via ReadResp).
            let verb = match round {
                Round::ReadMinProposals => Verb::read(ReadTarget::MinProposal { group }, tok),
                Round::WriteProposal { proposal } => {
                    Verb::write(core.landing_mem_for_peer(), Payload::Propose { group, proposal }, tok)
                        .on_leader_qp()
                }
                Round::ReadSlots { slot } => Verb::read(ReadTarget::LogSlot { group, slot }, tok),
                Round::WriteLog { slot, proposal, op, adopted: _ } => {
                    let payload = Payload::LogAppend { group, slot, proposal, op };
                    if use_wt {
                        Verb::rpc_write_through(payload, tok)
                    } else {
                        Verb::write(MemKind::Hbm, payload, tok).on_leader_qp()
                    }
                }
            };
            ctx.metrics.verbs += 1;
            let out = ctx.net.issue(ctx.q, ctx.qps, &core.sys.fabric, cursor, core.id, dst, verb, true);
            cursor = out.initiator_free_at;
        }
        core.busy_total += cursor - start;
        core.busy_until = cursor;
    }

    fn mu_step(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, g: usize, step: Step) {
        match step {
            Step::Wait => {}
            Step::Next(rid, round) => {
                // A WriteProposal quorum (the transition into ReadSlots)
                // means a follower majority accepted this leadership —
                // confirmation, in lease terms.
                if matches!(round, Round::ReadSlots { .. }) {
                    let c = self.cidx(g);
                    self.mu_confirmed[c] = true;
                }
                if let Round::WriteLog { slot, proposal, op, adopted } = round {
                    self.mu_enter_accept(core, ctx, mb, g, rid, slot, proposal, op, adopted);
                } else {
                    self.fan_out_round(core, ctx, mb, g, rid, round)
                }
            }
            Step::Commit { slot, proposal: _, op, adopted: _ } => {
                // Quorum of followers acked the Accept write: committed.
                // The SMR pipeline is sequential per group — the leader is
                // execution-time-busy through the whole round (appendix
                // D.1: the leader is the longest-running replica).
                let now = ctx.q.now();
                if now > core.busy_until {
                    core.busy_total += now - core.busy_until;
                    core.busy_until = now;
                }
                self.mu_commit_one(core, ctx, g, slot, op);
                // Rounds behind this one may have collected their Accept
                // quorums out of order: release every contiguous committed
                // successor, then refill the freed window stages.
                while let Some((slot, _proposal, op, _adopted)) = self.mu[g].pop_released() {
                    self.mu_commit_one(core, ctx, g, slot, op);
                }
                self.mu_pump_full(core, ctx, mb, g);
            }
            Step::Stall => {
                // A stalled round on a never-confirmed leadership means
                // this replica self-elected inside a partition minority and
                // every correct replica fences its writes: abdicate once
                // the rightful leader is back in view. Nothing was applied
                // (Mu executes only at Accept, past confirmation), so the
                // queued ops simply re-route through the forward path.
                // Single placement asks the smallest-live-ID rule; sharded
                // placements ask the per-group view (`core.group_leaders`,
                // realigned by the cluster when the partition heals) — a
                // stalled claim whose own table still names this replica
                // just resets and retries against the fence.
                if !self.mu_confirmed[self.cidx(g)] {
                    if core.placement.is_sharded() {
                        let rightful = core.leader_of(g);
                        if rightful != core.id {
                            self.mu_abdicate_group(core, ctx, g, rightful);
                            return;
                        }
                    } else {
                        let rightful = mb.elect_leader();
                        if rightful != core.id {
                            self.mu_abdicate(core, ctx, rightful);
                            return;
                        }
                    }
                }
                self.mu[g].reset_window();
                // Retry once the heartbeat scanner refreshes the live set.
                ctx.q.push(
                    ctx.q.now() + core.heartbeat_period_ns,
                    core.id,
                    EventKind::Timer(TimerKind::SmrTick(g as u8)),
                );
            }
        }
    }

    /// Accept-phase entry (§4.4): the leader *executes* the transaction
    /// before writing followers' logs — its permissibility check here is
    /// authoritative, the op sits at a fixed position in the total order.
    /// With a window, execution is serialized in slot order: once this
    /// round enters Accept, any parked successor follows (recursively, one
    /// slot at a time).
    fn mu_enter_accept(
        &mut self,
        core: &mut ReplicaCore,
        ctx: &mut Ctx,
        mb: &dyn Membership,
        g: usize,
        rid: u64,
        slot: u64,
        proposal: u64,
        op: OpCall,
        adopted: bool,
    ) {
        if !adopted && !core.plane.permissible(&op) {
            core.note_rejected(&op);
            // Aborting frees this round's slot; later in-flight rounds
            // flush back to the queue (they would leave a log hole) and
            // re-fly from the freed slot via the pump below.
            self.mu[g].abort_accept(rid);
            if self.chaos {
                self.done_fwd.insert((op.origin, op.seq), false);
            }
            if let Some(req) = self.requesters.remove(&(op.origin, op.seq)) {
                self.answer_requester(core, ctx, req, false);
            }
            self.mu_pump_full(core, ctx, mb, g);
            return;
        }
        // Execute locally unless this replica already applied the entry
        // (e.g. it drained it from its log as a follower before winning
        // the election).
        if self.logs[g].applied_upto <= slot {
            let exec_cost = core.exec().op_exec_ns + core.write_state_cost(false);
            core.occupy(ctx.q.now(), exec_cost);
            if adopted {
                core.plane.apply_forced(&op);
            } else {
                core.plane.apply(&op);
            }
            core.executions += 1;
        }
        self.logs[g].write_slot(slot, proposal, op);
        self.logs[g].applied_upto = self.logs[g].applied_upto.max(slot + 1);
        self.fan_out_round(core, ctx, mb, g, rid, Round::WriteLog { slot, proposal, op, adopted });
        // The execution cursor advanced: a successor round parked in
        // AcceptWait may enter Accept now.
        if let Some((rid, Round::WriteLog { slot, proposal, op, adopted })) =
            self.mu[g].pop_accept_ready()
        {
            self.mu_enter_accept(core, ctx, mb, g, rid, slot, proposal, op, adopted);
        }
    }

    /// Commit-point bookkeeping for one released Mu round: latency
    /// telemetry, the chaos exactly-once ledger, and the requester answer.
    fn mu_commit_one(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, g: usize, slot: u64, op: OpCall) {
        if let Some(t0) = self.round_start.remove(&(g, slot)) {
            ctx.metrics.smr_round.record(ctx.q.now().saturating_sub(t0));
        }
        ctx.metrics.smr_commits += 1;
        if self.chaos {
            self.done_fwd.insert((op.origin, op.seq), true);
        }
        if let Some(req) = self.requesters.remove(&(op.origin, op.seq)) {
            self.answer_requester(core, ctx, req, true);
        }
    }

    /// Mu abdication (see `Step::Stall`): adopt the rightful leader view,
    /// re-fence our own QP row, and hand every queued conflicting op back
    /// to the forward path (remote requesters bounce so origins retry).
    fn mu_abdicate(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, rightful: NodeId) {
        ctx.qps.switch_leader(core.id, core.leader, rightful);
        core.leader = rightful;
        // Provisional reign over; the next promotion resets.
        self.mu_confirmed.iter_mut().for_each(|c| *c = true);
        core.request_sync(ctx, rightful);
        for g in 0..self.mu.len() {
            self.mu[g].reset_window();
            for op in self.mu[g].take_queue() {
                match self.requesters.remove(&(op.origin, op.seq)) {
                    Some(req @ Requester::Local { .. }) => self.forward_conflicting(core, ctx, op, req),
                    Some(Requester::Remote { reply_to, request_id }) => {
                        self.reply_remote(core, ctx, reply_to, request_id, false, false)
                    }
                    None => {}
                }
            }
        }
    }

    /// Per-group Mu abdication (sharded placements): hand exactly group
    /// `g` to `rightful`, leaving every other group's leadership — ours
    /// included — untouched. The QP row refences against the full
    /// per-group set; queued ops for the group re-route through the
    /// forward path like the whole-cluster variant.
    fn mu_abdicate_group(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, g: usize, rightful: NodeId) {
        core.group_leaders[g] = rightful;
        ctx.qps.refence(core.id, &core.group_leaders);
        let c = self.cidx(g);
        self.mu_confirmed[c] = true; // provisional claim over; next promotion resets
        if let Some(l) = self.led.get_mut(g) {
            *l = false;
        }
        core.request_sync(ctx, rightful);
        self.mu[g].reset_window();
        for op in self.mu[g].take_queue() {
            match self.requesters.remove(&(op.origin, op.seq)) {
                Some(req @ Requester::Local { .. }) => self.forward_conflicting(core, ctx, op, req),
                Some(Requester::Remote { reply_to, request_id }) => {
                    self.reply_remote(core, ctx, reply_to, request_id, false, false)
                }
                None => {}
            }
        }
    }

    fn answer_requester(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, req: Requester, committed: bool) {
        match req {
            Requester::Local { client, arrival } => {
                let t = core.occupy(ctx.q.now(), core.exec().client_overhead_ns / 2);
                core.complete_client(ctx, client, arrival, t);
            }
            Requester::Remote { reply_to, request_id } => {
                self.reply_remote(core, ctx, reply_to, request_id, true, committed);
            }
        }
    }

    fn reply_remote(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, reply_to: NodeId, request_id: u64, handled: bool, committed: bool) {
        let tok = core.token(TokenCtx::Ignore);
        let verb = Verb::write(
            core.landing_mem_for_peer(),
            Payload::LeaderReply { request_id, handled, committed },
            tok,
        );
        ctx.metrics.verbs += 1;
        let now = ctx.q.now().max(core.busy_until);
        ctx.net.issue(ctx.q, ctx.qps, &core.sys.fabric, now, core.id, reply_to, verb, false);
    }

    fn retry_forward(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, mut p: PendingClient) {
        p.retries += 1;
        if p.retries > 8 {
            // Give up: count as rejected so the run terminates.
            core.note_rejected(&p.op);
            let done = core.occupy(ctx.q.now(), core.exec().client_overhead_ns / 2);
            core.complete_client(ctx, p.client, p.arrival, done);
            return;
        }
        // Re-forward to the current leader view after a beat. Sharded
        // placements route by the op's group (the failure plane keeps
        // `group_leaders` current); single placement refreshes the
        // smallest-live-ID view.
        let request_id = self.next_request_id;
        self.next_request_id += 1;
        self.pending_fwd.insert(request_id, p);
        let leader = if core.placement.is_sharded() {
            core.leader_for_op(&p.op)
        } else {
            let l = mb.elect_leader();
            core.leader = l;
            l
        };
        let op = p.op;
        if leader == core.id {
            let pc = self.pending_fwd.remove(&request_id).unwrap();
            self.submit_conflicting(core, ctx, mb, op, Requester::Local { client: pc.client, arrival: pc.arrival });
            return;
        }
        let tok = core.token(TokenCtx::Strong(StrongToken::Forward { request_id }));
        let verb = Verb::write(
            core.landing_mem_for_peer(),
            Payload::LeaderForward { op, reply_to: core.id, request_id },
            tok,
        );
        ctx.metrics.verbs += 1;
        if self.chaos {
            core.arm_forward_watchdog(ctx, request_id);
        }
        let at = ctx.q.now() + core.heartbeat_period_ns;
        let at = at.max(core.busy_until);
        ctx.net.issue(ctx.q, ctx.qps, &core.sys.fabric, at, core.id, leader, verb, true);
    }

    /// Recovery: re-issue committed entries to a returned follower (§3).
    /// Under sharded placements a replica is only authoritative for the
    /// groups it leads, so the replay is gated per group; single placement
    /// replays everything (callers gate on leadership).
    fn replay_log_to(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, peer: NodeId) {
        let sharded = core.placement.is_sharded();
        for g in 0..self.logs.len() {
            if sharded && !core.is_leader_of(g) {
                continue;
            }
            self.replay_group_to(core, ctx, g, peer);
        }
    }

    /// Re-issue one group's committed entries to a peer (idempotent:
    /// followers reject equal/lower proposals and skip applied slots).
    fn replay_group_to(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, g: usize, peer: NodeId) {
        let entries = self.logs[g].entries_from(0);
        for (slot, e) in entries {
            let tok = core.token(TokenCtx::Ignore);
            let payload = Payload::LogAppend { group: g as u8, slot, proposal: e.proposal, op: e.op };
            let verb = if self.prop_con == PropagationMode::WriteThrough {
                Verb::rpc_write_through(payload, tok)
            } else {
                Verb::write(MemKind::Hbm, payload, tok).on_leader_qp()
            };
            ctx.metrics.verbs += 1;
            ctx.net.issue(ctx.q, ctx.qps, &core.sys.fabric, ctx.q.now(), core.id, peer, verb, false);
        }
    }

    /// One AppendEntries (single or batched) to a single peer — the
    /// directed half of `raft_fan_out`, used by recovery replay, the
    /// RaftRejected backfill, and (with an empty batch) the lease probe.
    fn raft_send_to(
        &mut self,
        core: &mut ReplicaCore,
        ctx: &mut Ctx,
        s: usize,
        peer: NodeId,
        term: u64,
        start: u64,
        ops: Vec<OpCall>,
    ) {
        let mem = if core.system == SystemKind::Waverunner {
            MemKind::HostDram
        } else {
            core.landing_mem_for_peer()
        };
        let group = s as u8;
        let tok = core.token(TokenCtx::Ignore);
        let payload = if ops.len() == 1 {
            Payload::RaftAppend { group, term, index: start, op: ops[0] }
        } else {
            Payload::RaftAppendBatch { group, term, start_index: start, ops: ops.into() }
        };
        ctx.metrics.verbs += 1;
        let verb = Verb::write(mem, payload, tok).on_leader_qp();
        ctx.net.issue(ctx.q, ctx.qps, &core.sys.fabric, ctx.q.now(), core.id, peer, verb, false);
    }

    /// Recovery / anti-entropy: re-ship the mirrored Raft log to one peer
    /// from `from_index`, chunked like any other append. Followers
    /// overwrite-accept (idempotent) and ack each chunk's last index, so a
    /// chunk that completes the in-flight batch still counts toward its
    /// quorum.
    fn raft_replay_to(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, s: usize, peer: NodeId, from_index: u64) {
        let entries = match self.logs.get(s) {
            Some(l) => l.entries_from(from_index),
            None => return,
        };
        if entries.is_empty() {
            return;
        }
        let term = self.raft[s]
            .leader
            .as_ref()
            .map(|l| l.term)
            .unwrap_or(self.raft[s].follower.term);
        let first = entries[0].0;
        let ops: Vec<OpCall> = entries.into_iter().map(|(_, e)| e.op).collect();
        let step = self.batch.max(1);
        let mut start = 0usize;
        while start < ops.len() {
            let end = (start + step).min(ops.len());
            self.raft_send_to(core, ctx, s, peer, term, first + start as u64, ops[start..end].to_vec());
            start = end;
        }
    }

    /// Follower side of a gap: tell the leader where our log ends so it
    /// backfills (classic Raft nextIndex back-up, collapsed to one step —
    /// gaps only open when fault injection eats an append).
    fn raft_reject(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, s: usize, leader: NodeId, term: u64) {
        let tok = core.token(TokenCtx::Ignore);
        let verb = Verb::write(
            core.landing_mem_for_peer(),
            Payload::RaftRejected {
                group: s as u8,
                term,
                from: core.id,
                log_len: self.raft[s].follower.log_len(),
            },
            tok,
        );
        ctx.metrics.verbs += 1;
        ctx.net.issue(ctx.q, ctx.qps, &core.sys.fabric, ctx.q.now(), core.id, leader, verb, false);
    }

    // ----- waverunner (Raft baseline, §5.2) ------------------------------

    fn waverunner_redirect(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, client: usize, item: WorkItem, arrival: Time) {
        // Follower rejects; client re-sends to the leader (§5.2). Modeled
        // as a forward carrying the client's retry round trip.
        let request_id = self.next_request_id;
        self.next_request_id += 1;
        self.pending_fwd.insert(request_id, PendingClient { client, arrival, retries: 0, op: item.op });
        if self.chaos {
            core.arm_forward_watchdog(ctx, request_id);
        }
        let tok = core.token(TokenCtx::Strong(StrongToken::Forward { request_id }));
        let verb = Verb::write(
            core.landing_mem_for_peer(),
            Payload::LeaderForward { op: item.op, reply_to: core.id, request_id },
            tok,
        );
        ctx.metrics.verbs += 1;
        // Reject + client re-send penalty before the forward goes out.
        let penalty = core.exec().client_overhead_ns + core.sys.fabric.wire_ns * 2;
        let now = core.occupy(arrival, penalty);
        ctx.net.issue(ctx.q, ctx.qps, &core.sys.fabric, now, core.id, 0, verb, true);
    }

    /// Raft-leader client service: reads are local; every update goes
    /// through the replication pipeline.
    fn waverunner_serve(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, client: usize, item: WorkItem, arrival: Time) {
        let ingress = core.exec().client_overhead_ns / 2;
        let sw = core.exec().software_overhead_ns;
        let op = item.op;
        if op.is_query() || op.opcode == KV_READ {
            let cost = ingress + sw + core.warm_read_ns() + core.exec().client_overhead_ns / 2;
            let done = core.occupy(arrival, cost);
            core.complete_client(ctx, client, arrival, done);
            return;
        }
        core.occupy(arrival, ingress + sw);
        self.waverunner_submit(core, ctx, mb, op, Requester::Local { client, arrival });
    }

    fn waverunner_submit(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, op: OpCall, req: Requester) {
        // Waverunner pins placement = single (validated), so shard 0 is
        // the whole pipeline.
        if self.raft[0].leader.is_none() {
            // Not the Raft leader, and Waverunner models no leader election
            // (§5.2 runs fault-free; smallest-live-ID is a documented
            // shortcut that never re-homes the RaftLeader). Every stranded
            // request must still terminate — the cluster's drain flag now
            // tracks in-flight slots for real: forwarded requests bounce so
            // the origin retries (and gives up after 8 beats), local ones
            // complete as rejected.
            match req {
                Requester::Remote { reply_to, request_id } => {
                    self.reply_remote(core, ctx, reply_to, request_id, false, false);
                }
                Requester::Local { client, arrival } => {
                    core.note_rejected(&op);
                    let done = core.occupy(ctx.q.now(), core.exec().client_overhead_ns / 2);
                    core.complete_client(ctx, client, arrival, done);
                }
            }
            return;
        }
        // The leader applies every update (its own and forwarded ones) at
        // submit; followers apply from the replicated log.
        let cost = core.exec().op_exec_ns + core.write_state_cost(false);
        core.occupy(ctx.q.now(), cost);
        core.executions += 1;
        core.plane.apply(&op);
        let rl = self.raft[0].leader.as_mut().unwrap();
        let term = rl.term;
        let (index, fanout) = rl.submit(op);
        let depth = rl.depth() as u64;
        self.raft_mirror_append(0, index, term, &[op]);
        self.raft[0].pending.insert(index, req);
        if let Some((term, start, ops)) = fanout {
            self.round_start.entry((0, start)).or_insert(ctx.q.now());
            ctx.metrics.note_inflight(0, depth);
            self.raft_fan_out(core, ctx, mb, 0, term, start, ops);
        }
    }

    /// Follower-side apply after an accepted AppendEntries. Waverunner
    /// replays the leader's raw op stream (its leader replicates even
    /// locally-rejected applies, so followers re-run the same `apply`
    /// decisions); the stand-alone backend ships only leader-accepted ops,
    /// which followers execute unconditionally like Mu's log drain.
    fn raft_follower_apply(&mut self, core: &mut ReplicaCore, s: usize) {
        let forced = core.system != SystemKind::Waverunner;
        for o in self.raft[s].follower.drain_apply() {
            if forced {
                core.executions += 1;
                core.plane.apply_forced(&o);
            } else {
                core.apply_remote(&o);
            }
        }
    }

    fn raft_ack(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, s: usize, src: NodeId, term: u64, index: u64) {
        let tok = core.token(TokenCtx::Ignore);
        let ack = Verb::write(
            core.landing_mem_for_peer(),
            Payload::RaftAck { group: s as u8, term, index, from: core.id },
            tok,
        );
        ctx.metrics.verbs += 1;
        ctx.net.issue(ctx.q, ctx.qps, &core.sys.fabric, ctx.q.now(), core.id, src, ack, false);
    }

    /// Commit-point processing for one released AppendEntries batch:
    /// latency telemetry, the chaos exactly-once ledger, and each entry's
    /// requester answer.
    fn raft_commit_batch(
        &mut self,
        core: &mut ReplicaCore,
        ctx: &mut Ctx,
        s: usize,
        start_index: u64,
        ops: Vec<OpCall>,
        done: Time,
    ) {
        if let Some(t0) = self.round_start.remove(&(s, start_index)) {
            ctx.metrics.smr_round.record(ctx.q.now().saturating_sub(t0));
        }
        ctx.metrics.smr_commits += ops.len() as u64;
        if self.chaos {
            for o in &ops {
                self.done_fwd.insert((o.origin, o.seq), true);
            }
        }
        for i in 0..ops.len() as u64 {
            if let Some(req) = self.raft[s].pending.remove(&(start_index + i)) {
                match req {
                    Requester::Local { client, arrival } => {
                        let t = core.occupy(done, core.exec().client_overhead_ns / 2);
                        core.complete_client(ctx, client, arrival, t);
                    }
                    Requester::Remote { reply_to, request_id } => {
                        self.reply_remote(core, ctx, reply_to, request_id, true, true);
                    }
                }
            }
        }
    }

    fn raft_fan_out(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, s: usize, term: u64, start: u64, ops: Vec<OpCall>) {
        // The logical ack is the RaftAck verb, not a wire completion.
        let peers = mb.live_peers(core.id);
        let mem = if core.system == SystemKind::Waverunner {
            MemKind::HostDram // SmartNIC fast path still lands in host state
        } else {
            core.landing_mem_for_peer()
        };
        let group = s as u8;
        if ops.len() == 1 {
            let op = ops[0];
            core.fan_out(
                ctx,
                &peers,
                |t| {
                    Verb::write(mem, Payload::RaftAppend { group, term, index: start, op }, t)
                        .on_leader_qp()
                },
                false,
                || TokenCtx::Ignore,
            );
        } else {
            // Leader-side log-entry batching: one AppendEntries wire verb
            // carries the whole contiguous run; the shared `Arc` batch
            // makes each per-peer clone a refcount bump (§Perf).
            ctx.metrics.coalesced += ops.len() as u64 - 1;
            let ops: crate::net::verbs::OpBatch = ops.into();
            core.fan_out(
                ctx,
                &peers,
                |t| {
                    Verb::write(
                        mem,
                        Payload::RaftAppendBatch { group, term, start_index: start, ops: ops.clone() },
                        t,
                    )
                    .on_leader_qp()
                },
                false,
                || TokenCtx::Ignore,
            );
        }
    }
}

impl ReplicationPath for StrongPath {
    fn boot(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, base: u64) {
        // Log pollers are a Mu follower concern; Raft followers apply at
        // delivery (the SmartNIC interrupt path), so they arm nothing.
        if self.backend == ConsensusBackend::Mu
            && self.prop_con != PropagationMode::WriteThrough
            && !self.logs.is_empty()
        {
            for g in 0..self.logs.len() {
                ctx.q.push(
                    base + core.poll_interval_ns + g as u64,
                    core.id,
                    EventKind::Timer(TimerKind::PollLog(g as u8)),
                );
            }
        }
        // Chaos mode: the Raft pipeline's logical acks can be eaten by
        // lossy links, so every replica arms the re-pump tick (it only
        // acts while this replica leads) — one per shard.
        if self.chaos && self.backend == ConsensusBackend::Raft {
            for s in 0..self.raft.len() {
                ctx.q.push(
                    base + core.heartbeat_period_ns + s as u64,
                    core.id,
                    EventKind::Timer(TimerKind::SmrTick(s as u8)),
                );
            }
        }
    }

    fn refresh_cost(&mut self, core: &mut ReplicaCore) -> u64 {
        let mut cost = 0;
        // Conflicting log check (§4.3 config 1: "polling the log when the
        // state is accessed to ensure the most up to date data") — a Mu
        // structure; Raft followers are already current at delivery.
        if self.backend == ConsensusBackend::Mu && self.prop_con != PropagationMode::WriteThrough {
            let per_group = core.sys.mem.local_read_ns(core.landing_mem());
            cost += per_group * self.logs.len() as u64;
            cost += self.drain_logs_cost(core);
        }
        cost
    }

    fn handle_client(
        &mut self,
        core: &mut ReplicaCore,
        ctx: &mut Ctx,
        mb: &dyn Membership,
        client: usize,
        item: WorkItem,
        arrival: Time,
    ) -> bool {
        // Waverunner: only the leader serves clients (§5.2); every update
        // replicates through Raft regardless of RDT category (no hybrid
        // consistency — that is the point of the Fig 12 comparison).
        if core.system != SystemKind::Waverunner {
            return false;
        }
        if self.raft[0].leader.is_none() {
            self.waverunner_redirect(core, ctx, client, item, arrival);
        } else {
            self.waverunner_serve(core, ctx, mb, client, item, arrival);
        }
        true
    }

    fn submit(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, sub: Submission) {
        let _t = core.occupy(sub.arrival, sub.cost);
        self.submit_conflicting(core, ctx, mb, sub.op, Requester::Local { client: sub.client, arrival: sub.arrival });
    }

    fn deliver(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, src: NodeId, verb: Verb) {
        let is_rpc = matches!(verb.kind, crate::net::verbs::VerbKind::Rpc | crate::net::verbs::VerbKind::RpcWriteThrough);
        match verb.payload {
            Payload::Propose { group, proposal } => {
                self.logs[group as usize].bump_min_proposal(proposal);
            }
            Payload::LogAppend { group, slot, proposal, op } => {
                let g = group as usize;
                // A slot beyond our append point means an earlier Accept
                // write never landed here (fenced pre-switch, or eaten by
                // fault injection): ask the sender for a replay. Never
                // fires on a clean in-order fabric.
                if slot > self.logs[g].next_free_slot() {
                    core.request_sync(ctx, src);
                }
                self.logs[g].write_slot(slot, proposal, op);
                if is_rpc {
                    // Write-through: follower state updated directly from
                    // the network (§4.4 "at L"); log is already appended.
                    let cost = core.exec().op_exec_ns + core.sys.mem.local_write_ns(MemKind::Bram);
                    core.occupy(ctx.q.now(), cost);
                    for e in self.logs[g].drain_unapplied() {
                        core.executions += 1;
                        core.plane.apply_forced(&e.op);
                    }
                }
            }
            Payload::LeaderForward { op, reply_to, request_id } => {
                if core.system == SystemKind::Waverunner {
                    // Redirected client request reaching the Raft leader.
                    let sw = core.exec().software_overhead_ns;
                    core.occupy(ctx.q.now(), sw);
                    if op.is_query() || op.opcode == KV_READ {
                        let cost = core.warm_read_ns() + core.exec().client_overhead_ns / 2;
                        core.occupy(ctx.q.now(), cost);
                        self.reply_remote(core, ctx, reply_to, request_id, true, true);
                    } else {
                        self.waverunner_submit(core, ctx, mb, op, Requester::Remote { reply_to, request_id });
                    }
                } else if core.leads_op(&op) {
                    let sw = core.exec().software_overhead_ns;
                    core.occupy(ctx.q.now(), sw);
                    // Chaos-mode exactly-once: a duplicate of an op we
                    // already ordered (its reply was eaten by a faulty
                    // link) answers with the recorded verdict instead of
                    // executing twice.
                    if self.chaos {
                        if let Some(&committed) = self.done_fwd.get(&(op.origin, op.seq)) {
                            self.reply_remote(core, ctx, reply_to, request_id, true, committed);
                            return;
                        }
                    }
                    // Leader re-checks permissibility in total order context.
                    self.submit_conflicting(core, ctx, mb, op, Requester::Remote { reply_to, request_id });
                } else {
                    // Not the leader (stale forward): bounce.
                    self.reply_remote(core, ctx, reply_to, request_id, false, false);
                }
            }
            Payload::LeaderReply { request_id, handled, committed } => {
                if let Some(p) = self.pending_fwd.remove(&request_id) {
                    if handled {
                        if !committed {
                            core.note_rejected(&p.op);
                        }
                        let done = core.occupy(ctx.q.now(), core.exec().client_overhead_ns / 2);
                        core.complete_client(ctx, p.client, p.arrival, done);
                    } else {
                        self.retry_forward(core, ctx, mb, p);
                    }
                }
            }
            Payload::RaftAppend { group, term, index, op } => {
                let s = self.sidx(group as usize);
                if self.raft[s].follower.on_append(term, index, op) {
                    self.raft_mirror_append(s, index, term, &[op]);
                    self.raft_follower_apply(core, s);
                    self.raft_ack(core, ctx, s, src, term, index);
                } else if term >= self.raft[s].follower.term
                    && index > self.raft[s].follower.log_len()
                {
                    self.raft_reject(core, ctx, s, src, term);
                }
            }
            Payload::RaftAppendBatch { group, term, start_index, ops } => {
                let s = self.sidx(group as usize);
                if self.raft[s].follower.on_append_batch(term, start_index, &ops) {
                    self.raft_mirror_append(s, start_index, term, &ops);
                    self.raft_follower_apply(core, s);
                    // One ack for the whole batch, on its last index (an
                    // empty batch is a lease probe — ack its start).
                    let last = start_index + (ops.len() as u64).max(1) - 1;
                    self.raft_ack(core, ctx, s, src, term, last);
                } else if term >= self.raft[s].follower.term
                    && start_index > self.raft[s].follower.log_len()
                {
                    self.raft_reject(core, ctx, s, src, term);
                }
            }
            Payload::RaftRejected { group, term, from, log_len } => {
                // A follower told us where its log ends (fault injection
                // ate an append): backfill from the mirrored log. The gap
                // report also proves it accepted our term — a lease vote.
                let s = self.sidx(group as usize);
                self.raft_lease_vote(core, ctx, mb, s, term, from);
                let current = self.raft[s].leader.as_ref().is_some_and(|rl| rl.term == term);
                if current {
                    self.raft_replay_to(core, ctx, s, from, log_len);
                }
            }
            Payload::SyncRequest { from } => {
                // A follower completed its permission switch toward us and
                // wants the committed log (our takeover broadcast may have
                // been fenced at it). Idempotent on both backends; sharded
                // placements replay only the groups this replica leads.
                if core.leads_any() {
                    if self.backend == ConsensusBackend::Raft {
                        for s in 0..self.raft.len() {
                            if core.is_leader_of(s) {
                                self.raft_replay_to(core, ctx, s, from, 0);
                            }
                        }
                    } else {
                        self.replay_log_to(core, ctx, from);
                    }
                }
            }
            Payload::RaftAck { group, term, index, from } => {
                // A current-term ack is also a lease vote for a freshly
                // promoted leader (the follower accepted our authority).
                let s = self.sidx(group as usize);
                self.raft_lease_vote(core, ctx, mb, s, term, from);
                if let Some(rl) = self.raft[s].leader.as_mut() {
                    if let RaftStep::Commit { start_index, ops } = rl.on_ack(term, index, from) {
                        // Leader state was updated at submit; commit point
                        // is the quorum ack.
                        let done = core.occupy(ctx.q.now(), core.exec().op_exec_ns);
                        self.raft_commit_batch(core, ctx, s, start_index, ops, done);
                        // Batches behind this one may have collected their
                        // majorities out of order: release every contiguous
                        // committed successor in index order.
                        while let Some((start, ops)) =
                            self.raft[s].leader.as_mut().unwrap().pop_released()
                        {
                            let done = core.occupy(ctx.q.now(), core.exec().op_exec_ns);
                            self.raft_commit_batch(core, ctx, s, start, ops, done);
                        }
                        // Refill the freed window stages from the queue.
                        loop {
                            let rl = self.raft[s].leader.as_mut().unwrap();
                            let Some((term, start, ops)) = rl.pump() else { break };
                            let depth = rl.depth() as u64;
                            self.round_start.entry((s, start)).or_insert(ctx.q.now());
                            ctx.metrics.note_inflight(s, depth);
                            self.raft_fan_out(core, ctx, mb, s, term, start, ops);
                        }
                    }
                }
            }
            _ => {}
        }
    }

    fn on_completion(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, token: TokenCtx, ok: bool) {
        let TokenCtx::Strong(token) = token else { return };
        match token {
            StrongToken::Mu { group, round_id } => {
                // The automaton routes by rid nonce (stale rids drop).
                let g = group as usize;
                let step = self.mu[g].on_response(round_id, if ok { Resp::Ack } else { Resp::Failure });
                self.mu_step(core, ctx, mb, g, step);
            }
            StrongToken::Forward { request_id } => {
                if !ok {
                    if let Some(p) = self.pending_fwd.remove(&request_id) {
                        self.retry_forward(core, ctx, mb, p);
                    }
                }
            }
        }
    }

    fn on_read_resp(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, token: TokenCtx, data: ReadData) {
        // Only Mu rounds read remote state; Forward tokens ride writes.
        let TokenCtx::Strong(StrongToken::Mu { group, round_id }) = token else { return };
        let g = group as usize;
        let resp = match data {
            ReadData::MinProposal(p) => Resp::MinProposal(p),
            ReadData::LogSlot(s) => Resp::Slot(s),
            _ => Resp::Ack,
        };
        let step = self.mu[g].on_response(round_id, resp);
        self.mu_step(core, ctx, mb, g, step);
    }

    fn on_timer(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, t: TimerKind) {
        match t {
            TimerKind::PollLog(_g) => {
                let cost = core.exec().poll_tick_ns + self.drain_logs_cost(core);
                core.occupy(ctx.q.now(), cost);
                if !ctx.draining {
                    ctx.q.push(ctx.q.now() + core.poll_interval_ns, core.id, EventKind::Timer(t));
                }
            }
            TimerKind::SmrTick(g) => {
                if self.backend == ConsensusBackend::Raft {
                    // Chaos-mode re-pump: a dropped append or eaten logical
                    // ack can wedge the one-in-flight pipeline, so the
                    // leader periodically re-ships the in-flight batch.
                    // Followers overwrite-accept duplicates and re-ack.
                    // An unleased leader instead re-runs its campaign — or
                    // abdicates once the rightful leader is back in view
                    // (the partition healed and it was a minority imposter).
                    // Single placement asks the smallest-live-ID rule;
                    // sharded placements ask the per-group view, which the
                    // cluster realigns at heal time — until then the
                    // campaign retries against the per-group fence.
                    let s = self.sidx(g as usize);
                    if !self.raft[s].lease && self.raft[s].leader.is_some() {
                        let rightful = if core.placement.is_sharded() {
                            core.leader_of(s)
                        } else {
                            mb.elect_leader()
                        };
                        if rightful != core.id {
                            self.raft_abdicate(core, ctx, s, rightful);
                        } else {
                            self.raft_campaign(core, ctx, mb, s);
                        }
                    } else if core.is_leader_of(s) {
                        let flights = match self.raft[s].leader.as_mut() {
                            Some(rl) => {
                                rl.set_cluster_size(mb.live_set().len());
                                rl.refanout()
                            }
                            None => Vec::new(),
                        };
                        // Re-ship *every* in-flight batch: with a window a
                        // lost append can wedge any stage, not just one.
                        for (term, start, ops) in flights {
                            self.raft_fan_out(core, ctx, mb, s, term, start, ops);
                        }
                    }
                    // Re-arm: permanently in chaos mode, and as a one-shot
                    // chain while a lease campaign is still out (probes can
                    // be fenced at followers that have not switched yet).
                    let campaigning = !self.raft[s].lease && self.raft[s].leader.is_some();
                    if (self.chaos || campaigning) && !ctx.draining {
                        ctx.q.push(
                            ctx.q.now() + core.heartbeat_period_ns,
                            core.id,
                            EventKind::Timer(t),
                        );
                    }
                    return;
                }
                let g = g as usize;
                if core.is_leader_of(g) {
                    self.mu[g].set_cluster_size(mb.live_set().len());
                    self.mu_pump_full(core, ctx, mb, g);
                }
            }
            TimerKind::ForwardCheck { request_id } => {
                // Chaos-mode watchdog: the leader's reply never arrived
                // (lost on a faulty link) — re-forward. At-least-once is
                // safe: the leader re-checks permissibility in total-order
                // position, and retry_forward gives up after its cap.
                if let Some(p) = self.pending_fwd.remove(&request_id) {
                    self.retry_forward(core, ctx, mb, p);
                }
            }
            _ => {}
        }
    }

    fn serve_read(&self, target: ReadTarget) -> Option<ReadData> {
        match target {
            ReadTarget::MinProposal { group } => {
                Some(ReadData::MinProposal(self.logs[group as usize].min_proposal))
            }
            ReadTarget::LogSlot { group, slot } => Some(ReadData::LogSlot(
                self.logs[group as usize].read_slot(slot).map(|e| (e.proposal, e.op)),
            )),
            _ => None,
        }
    }

    fn on_membership(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, ev: MembershipEvent) {
        match ev {
            MembershipEvent::PeerFailed { peer: _ } => {
                // Leader trims its follower list (background on SafarDB,
                // foreground cost charged by the failure plane for Hamband).
                for g in 0..self.mu.len() {
                    self.mu[g].set_cluster_size(mb.live_set().len());
                }
                for s in 0..self.raft.len() {
                    if let Some(rl) = self.raft[s].leader.as_mut() {
                        rl.set_cluster_size(mb.live_set().len());
                    }
                }
            }
            MembershipEvent::PeerRecovered { peer } => {
                if self.backend == ConsensusBackend::Raft {
                    // Term-bumped replay of the mirrored Raft log: the
                    // returned follower overwrite-accepts and applies the
                    // tail its snapshot predates. Sharded placements replay
                    // only the shards this replica leads.
                    for s in 0..self.raft.len() {
                        if core.is_leader_of(s) {
                            self.raft_replay_to(core, ctx, s, peer, 0);
                        }
                    }
                } else {
                    self.replay_log_to(core, ctx, peer);
                }
                for g in 0..self.mu.len() {
                    self.mu[g].set_cluster_size(mb.live_set().len());
                }
                for s in 0..self.raft.len() {
                    if let Some(rl) = self.raft[s].leader.as_mut() {
                        rl.set_cluster_size(mb.live_set().len());
                    }
                }
            }
            MembershipEvent::LeaderSwitched => {
                if core.is_leader() {
                    ctx.metrics.elections += 1;
                    ctx.metrics.election_times.push(ctx.q.now());
                    if self.backend == ConsensusBackend::Raft {
                        // Stand-alone Raft takeover: adopt the accepted log
                        // at a higher term and re-replicate it as the lease
                        // campaign (followers overwrite-accept higher
                        // terms; their acks double as lease votes). This
                        // event only fires under placement = single, where
                        // shard 0 is the whole pipeline.
                        if core.system != SystemKind::Waverunner && self.raft[0].leader.is_none() {
                            self.ensure_raft_leader(core, ctx, mb, 0);
                        }
                    } else {
                        // Take over: re-replicate our log suffix first — the
                        // crashed leader may have written an Accept to only a
                        // subset of followers (including us), and Mu's
                        // slot-adoption only repairs slots we later propose
                        // into. Idempotent: followers reject equal/lower
                        // proposals and skip already-applied slots. The
                        // Prepare phase is Mu's leadership confirmation:
                        // until a WriteProposal round reaches quorum this
                        // leadership is provisional (see mu_confirmed).
                        self.mu_confirmed.iter_mut().for_each(|c| *c = false);
                        let peers = mb.live_peers(core.id);
                        for peer in peers {
                            self.replay_log_to(core, ctx, peer);
                        }
                        for g in 0..self.mu.len() {
                            self.mu[g].set_cluster_size(mb.live_set().len());
                            self.mu_pump_full(core, ctx, mb, g);
                        }
                    }
                }
                // Any of our forwards pending at the dead leader: retry now.
                let pending: Vec<(u64, PendingClient)> = self.pending_fwd.drain().collect();
                for (_, p) in pending {
                    self.retry_forward(core, ctx, mb, p);
                }
            }
            MembershipEvent::GroupLeadersChanged => {
                // Sharded placements only: the failure plane re-placed the
                // dead node's groups and updated `core.group_leaders`. Diff
                // against our last-acted view to find the groups this
                // replica just gained, and take each one over exactly like
                // a LeaderSwitched would — Mu re-replicates the group's log
                // suffix and pumps (confirmation pending), Raft promotes
                // the shard and runs its lease campaign.
                let live = mb.live_set().len();
                for g in 0..self.mu.len() {
                    self.mu[g].set_cluster_size(live);
                }
                for s in 0..self.raft.len() {
                    if let Some(rl) = self.raft[s].leader.as_mut() {
                        rl.set_cluster_size(live);
                    }
                }
                let mut gained = false;
                for g in 0..self.led.len() {
                    let mine = core.is_leader_of(g);
                    let was = self.led[g];
                    self.led[g] = mine;
                    if !mine || was {
                        continue;
                    }
                    gained = true;
                    if self.backend == ConsensusBackend::Raft {
                        let s = self.sidx(g);
                        if self.raft[s].leader.is_none() {
                            self.ensure_raft_leader(core, ctx, mb, s);
                        }
                    } else {
                        let c = self.cidx(g);
                        self.mu_confirmed[c] = false;
                        for peer in mb.live_peers(core.id) {
                            self.replay_group_to(core, ctx, g, peer);
                        }
                        self.mu_pump_full(core, ctx, mb, g);
                    }
                }
                if gained {
                    // One election per replica gaining ≥1 group: the
                    // takeover campaigns for all gained groups run
                    // concurrently from the same detection.
                    ctx.metrics.elections += 1;
                    ctx.metrics.election_times.push(ctx.q.now());
                }
                // Forwards pending at the dead (or re-placed) leader: the
                // per-op group routing re-resolves against the new table.
                let pending: Vec<(u64, PendingClient)> = self.pending_fwd.drain().collect();
                for (_, p) in pending {
                    self.retry_forward(core, ctx, mb, p);
                }
            }
        }
    }

    fn flush_pending(&mut self, plane: &mut Catalog) {
        for g in 0..self.logs.len() {
            for e in self.logs[g].drain_unapplied() {
                plane.apply_forced(&e.op);
            }
        }
    }

    fn snapshot_logs(&self) -> Vec<ReplicationLog> {
        self.logs.clone()
    }

    fn install_logs(&mut self, logs: Vec<ReplicationLog>) {
        self.logs = logs;
        // Stale round stamps belong to the pre-crash incarnation.
        self.round_start = FastMap::default();
        // A freshly recovered replica leads nothing until the placement
        // table reassigns groups to it (sticky rebalance), so its
        // last-acted leadership view resets — any group it later regains
        // runs a full takeover.
        self.led.iter_mut().for_each(|l| *l = false);
        if self.backend != ConsensusBackend::Raft {
            return;
        }
        // Raft recovery parity with Mu/Paxos: rebuild each shard's
        // follower automaton from the donor's mirrored log. The installed
        // plane already contains every mirrored entry's effect, so the
        // rebuilt log starts fully applied; the leaders' replays cover
        // anything committed after the snapshot point.
        for s in 0..self.raft.len() {
            let entries = self.logs.get(s).map(|l| l.entries_from(0)).unwrap_or_default();
            let term = entries.iter().map(|(_, e)| e.proposal).max().unwrap_or(1);
            let ops: Vec<OpCall> = entries.into_iter().map(|(_, e)| e.op).collect();
            self.raft[s].follower = RaftFollower::restore(term, ops);
            if self.system != SystemKind::Waverunner {
                // A recovered ex-leader rejoins as a follower (the donor's
                // leader view installs with the snapshot); stale pipeline
                // state must not answer ghosts of pre-crash requests.
                self.raft[s].leader = None;
            }
            self.raft[s].pending = FastMap::default();
            self.raft[s].lease = true;
            self.raft[s].votes = FastMap::default();
            self.raft[s].parked.clear();
        }
    }

    fn replay_to(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, _mb: &dyn Membership, peer: NodeId) {
        // Heal-time anti-entropy: a short partition can open a silent gap
        // at `peer` (a round committed by the other majority members), so
        // the leader re-ships its committed log. Idempotent on every
        // backend: proposal-guarded slots (Mu) / overwrite-accept (Raft).
        if self.backend == ConsensusBackend::Raft {
            let single = self.raft.len() == 1;
            for s in 0..self.raft.len() {
                if single || core.is_leader_of(s) {
                    self.raft_replay_to(core, ctx, s, peer, 0);
                }
            }
        } else {
            self.replay_log_to(core, ctx, peer);
        }
    }

    fn abdicate_if_unconfirmed(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, _mb: &dyn Membership, rightful: NodeId) {
        if core.placement.is_sharded() {
            // Per-group nudge: the cluster realigned `core.group_leaders`
            // to the rightful placement-table view before calling us, so
            // any never-confirmed claim on a group whose rightful leader is
            // someone else is a partition-side imposter — hand each such
            // group over (the `rightful` anchor argument is the
            // single-placement shape; groups carry their own answer).
            if self.backend == ConsensusBackend::Raft {
                for s in 0..self.raft.len() {
                    if !self.raft[s].lease && self.raft[s].leader.is_some() {
                        let r = core.leader_of(s);
                        if r != core.id {
                            self.raft_abdicate(core, ctx, s, r);
                        }
                    }
                }
            } else {
                for g in 0..self.mu.len() {
                    if !self.mu_confirmed[self.cidx(g)] {
                        let r = core.leader_of(g);
                        if r != core.id {
                            self.mu_abdicate_group(core, ctx, g, r);
                        }
                    }
                }
            }
            return;
        }
        if !core.is_leader() {
            return;
        }
        if self.backend == ConsensusBackend::Raft {
            if !self.raft[0].lease && self.raft[0].leader.is_some() {
                self.raft_abdicate(core, ctx, 0, rightful);
            }
        } else if !self.mu_confirmed[0] {
            self.mu_abdicate(core, ctx, rightful);
        }
    }

    fn debug_status(&self) -> String {
        let mu_q: usize = self.mu.iter().map(|m| m.queue_len()).sum();
        let mu_idle: Vec<bool> = self.mu.iter().map(|m| m.is_idle()).collect();
        let raft_pending: usize = self.raft.iter().map(|s| s.pending.len()).sum();
        let raft_parked: usize = self.raft.iter().map(|s| s.parked.len()).sum();
        let raft_unleased: usize = self.raft.iter().filter(|s| !s.lease).count();
        format!(
            "pending_fwd={} requesters={} raft_pending={} raft_unleased={} raft_parked={} mu_q={} mu_idle={:?}",
            self.pending_fwd.len(),
            self.requesters.len(),
            raft_pending,
            raft_unleased,
            raft_parked,
            mu_q,
            mu_idle
        )
    }
}
