//! Integration: the open-loop traffic plane (`arrival = poisson | bursty |
//! diurnal`) — cross-thread determinism, fixed-seed reproducibility with a
//! digest pin table, closed-loop bit-identity guards, the saturation knee,
//! and the admission-queue shed accounting identity
//! `offered = completed + shed + crash_killed`.

use std::fmt::Write as _;

use safardb::config::{
    ArrivalProcess, CatalogSpec, ConsensusBackend, LeaderPlacement, SimConfig, WorkloadKind,
};
use safardb::engine::cluster;
use safardb::expt::common::run_cells;
use safardb::rdt::RdtKind;

const BURSTY: ArrivalProcess =
    ArrivalProcess::Bursty { rate: 400_000, period_ns: 200_000, amp: 4 };
const DIURNAL: ArrivalProcess = ArrivalProcess::Diurnal { rate: 400_000, period_ns: 1_000_000 };

fn open_cfg(arrival: ArrivalProcess, seed: u64) -> SimConfig {
    let mut cfg = SimConfig::safardb(WorkloadKind::Micro(RdtKind::Account));
    cfg.objects = CatalogSpec::parse("account:16").unwrap();
    cfg.objects.zipf_theta = 0.6;
    cfg.n_replicas = 4;
    cfg.update_pct = 25;
    cfg.total_ops = 6_000;
    cfg.arrival = arrival;
    cfg.seed = seed;
    cfg
}

#[test]
fn open_loop_runs_are_identical_across_worker_thread_counts() {
    // The experiment harness farms cells across worker threads; open-loop
    // cells must be a pure function of (cfg, ops) — same digests, same
    // event count, same shed/offered books — regardless of which worker
    // runs them or how many run concurrently.
    let arrivals =
        [ArrivalProcess::Poisson { rate: 400_000 }, BURSTY, DIURNAL];
    let jobs: Vec<_> = arrivals
        .iter()
        .enumerate()
        .map(|(i, &a)| (open_cfg(a, 0x10AD_DE7 + i as u64), 6_000u64))
        .collect();
    let one = run_cells(jobs.clone(), 1);
    let two = run_cells(jobs, 2);
    assert_eq!(one.len(), two.len());
    for (i, ((c1, r1), (c2, r2))) in one.iter().zip(&two).enumerate() {
        assert_eq!(r1.digests, r2.digests, "cell {i}: digests differ across thread counts");
        assert_eq!(r1.metrics.events, r2.metrics.events, "cell {i}: event count differs");
        assert_eq!(r1.metrics.offered, r2.metrics.offered, "cell {i}: offered differs");
        assert_eq!(r1.metrics.shed, r2.metrics.shed, "cell {i}: shed differs");
        assert_eq!(
            r1.metrics.queue_depth_max, r2.metrics.queue_depth_max,
            "cell {i}: queue high-water differs"
        );
        assert_eq!(c1.rt_us.to_bits(), c2.rt_us.to_bits(), "cell {i}: rt_us differs");
        assert_eq!(c1.tput.to_bits(), c2.tput.to_bits(), "cell {i}: tput differs");
    }
}

fn pin_cells() -> Vec<(&'static str, SimConfig)> {
    let mut poisson_raft = open_cfg(ArrivalProcess::Poisson { rate: 800_000 }, 0x10AD_0001);
    poisson_raft.backend = ConsensusBackend::Raft;
    let mut diurnal_hash = open_cfg(DIURNAL, 0x10AD_0003);
    diurnal_hash.placement = LeaderPlacement::Hash;
    vec![
        ("poisson_mu", open_cfg(ArrivalProcess::Poisson { rate: 800_000 }, 0x10AD_0000)),
        ("poisson_raft", poisson_raft),
        ("bursty_mu", open_cfg(BURSTY, 0x10AD_0002)),
        ("diurnal_mu_hash", diurnal_hash),
    ]
}

/// Fixed-seed open-loop runs must be reproducible run-to-run (hard
/// assertion), and must match `tests/data/loadcurve_pins.txt` when that
/// file exists. Unlike the failure-plane digest pins, a missing file here
/// is never fatal — not even in CI: the poisson inter-arrival draw goes
/// through `f64::ln`, whose last-bit behavior is a property of the local
/// libm, so the table is only comparable within one toolchain. The
/// in-process run-twice check is the portable guard.
#[test]
fn fixed_seed_open_loop_runs_are_reproducible_and_pinned() {
    let mut table = String::new();
    for (name, cfg) in pin_cells() {
        let a = cluster::run(cfg.clone());
        let b = cluster::run(cfg);
        assert_eq!(a.digests, b.digests, "{name}: nondeterministic digests");
        assert_eq!(a.metrics.events, b.metrics.events, "{name}: nondeterministic event count");
        assert_eq!(a.metrics.offered, b.metrics.offered, "{name}: nondeterministic offered");
        assert_eq!(a.metrics.shed, b.metrics.shed, "{name}: nondeterministic shed");
        assert!(a.converged(), "{name}: diverged: {:?}", a.digests);
        assert!(a.invariants_ok, "{name}: integrity broke");
        assert_eq!(a.metrics.offered, 6_000, "{name}: arrival stream not exhausted");
        writeln!(
            table,
            "{name} digests={:?} events={} offered={} completed={} shed={}",
            a.digests,
            a.metrics.events,
            a.metrics.offered,
            a.metrics.total_completed(),
            a.metrics.shed,
        )
        .expect("string write");
    }

    let pin_path =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/data/loadcurve_pins.txt");
    match std::fs::read_to_string(&pin_path) {
        Ok(expected) => assert_eq!(
            table, expected,
            "fixed-seed open-loop digests drifted from the local pin table. A pure \
             refactor must keep them bit-identical on one machine; if this change is \
             an intentional behavioral fix (or a toolchain/libm change), delete \
             tests/data/loadcurve_pins.txt and re-run this test to regenerate it."
        ),
        Err(_) => {
            if let Some(parent) = pin_path.parent() {
                let _ = std::fs::create_dir_all(parent);
            }
            std::fs::write(&pin_path, &table).expect("write loadcurve pin file");
            eprintln!(
                "loadcurve_pins: no pin table found; wrote a fresh one to {} — it \
                 guards refactors on this toolchain from now on",
                pin_path.display()
            );
        }
    }
}

#[test]
fn closed_loop_ignores_open_loop_plumbing() {
    // arrival=closed must be byte-identical to the pre-open-loop engine:
    // no arrival events, no queueing, no shedding, and complete
    // indifference to queue_cap. (The cross-release guarantee itself is
    // held by the bench digest + failure-plane pins; this pins the
    // in-tree invariants that imply it.)
    let base = {
        let mut cfg = open_cfg(ArrivalProcess::Closed, 0x10AD_C105);
        cfg.total_ops = 8_000;
        cfg
    };
    let a = cluster::run(base.clone());
    assert!(a.converged() && a.invariants_ok);
    assert_eq!(a.metrics.shed, 0, "closed loop never sheds");
    assert_eq!(a.metrics.queue_depth_max, 0, "closed loop never queues");
    assert_eq!(a.metrics.offered, 8_000, "closed loop offers exactly the op target");
    assert_eq!(a.metrics.offered, a.metrics.total_completed() + a.metrics.crash_killed);

    // queue_cap is an open-loop-only knob: sweeping it must not perturb a
    // closed run in any observable way.
    for cap in [1usize, 7, 4_096] {
        let mut cfg = base.clone();
        cfg.queue_cap = cap;
        let b = cluster::run(cfg);
        assert_eq!(a.digests, b.digests, "queue_cap={cap} changed closed-loop digests");
        assert_eq!(a.metrics.events, b.metrics.events, "queue_cap={cap} changed event stream");
    }
}

#[test]
fn saturation_knee_p99_blows_up_past_service_capacity() {
    // Well under the knee (~1-2M ops/s/node) vs. well past it: p99 must
    // jump by at least the acceptance factor of 5 and backpressure must
    // become visible as shed arrivals. Conservation holds at both ends.
    let run_at = |rate: u64| {
        let mut cfg = open_cfg(ArrivalProcess::Poisson { rate }, 0x10AD_2EE5);
        cfg.total_ops = 8_000;
        cluster::run(cfg)
    };
    let lo = run_at(200_000);
    let hi = run_at(6_400_000);
    for (label, rep) in [("low", &lo), ("high", &hi)] {
        assert!(rep.converged() && rep.invariants_ok, "{label}: bad run");
        assert_eq!(rep.metrics.offered, 8_000, "{label}: stream not exhausted");
        assert_eq!(
            rep.metrics.offered,
            rep.metrics.total_completed() + rep.metrics.shed,
            "{label}: accounting identity broke"
        );
    }
    let (p99_lo, p99_hi) = (lo.metrics.response.p99(), hi.metrics.response.p99());
    assert!(
        p99_hi >= 5 * p99_lo,
        "no knee: p99 {p99_lo}ns at 200k -> {p99_hi}ns at 6.4M ops/s/node"
    );
    assert_eq!(lo.metrics.shed, 0, "an unloaded node must not shed");
    assert!(hi.metrics.shed > 0, "overload never hit the queue bound");
    assert!(hi.metrics.queue_depth_max > lo.metrics.queue_depth_max);
}

#[test]
fn window_8_doubles_committed_strong_throughput_past_the_knee() {
    // The pipelining acceptance cell (ISSUE 10): account:16 at n=5 under
    // poisson arrivals well past the window=1 knee, for both quorum-ack
    // backends. Committed strong-op throughput (smr_commits over the
    // virtual makespan) must at least double at window=8, at
    // equal-or-better response p99 — the sliding window overlaps the
    // round trips that stop-and-wait serializes, so a saturated strong
    // path drains proportionally faster instead of queueing.
    for backend in [ConsensusBackend::Raft, ConsensusBackend::Paxos] {
        let run_at = |window: u32| {
            let mut cfg = open_cfg(ArrivalProcess::Poisson { rate: 6_400_000 }, 0x10AD_ACC3);
            cfg.backend = backend;
            cfg.n_replicas = 5;
            cfg.window = window;
            let rep = cluster::run(cfg);
            assert!(rep.converged(), "{} w={window}: diverged", backend.name());
            assert!(rep.invariants_ok, "{} w={window}: integrity broke", backend.name());
            assert_eq!(rep.metrics.offered, 6_000, "{} w={window}: stream", backend.name());
            rep
        };
        let one = run_at(1);
        let eight = run_at(8);
        let b = backend.name();
        assert!(one.metrics.smr_commits > 0, "{b}: strong path unexercised");
        let tput = |rep: &cluster::RunReport| {
            rep.metrics.smr_commits as f64 / rep.metrics.makespan_ns.max(1) as f64
        };
        let ratio = tput(&eight) / tput(&one);
        assert!(
            ratio >= 2.0,
            "{b}: window=8 sustains only {ratio:.2}x the window=1 committed strong-op \
             throughput ({} commits / {} ns vs {} / {})",
            eight.metrics.smr_commits,
            eight.metrics.makespan_ns,
            one.metrics.smr_commits,
            one.metrics.makespan_ns
        );
        let (p99_1, p99_8) = (one.metrics.response.p99(), eight.metrics.response.p99());
        assert!(
            p99_8 <= p99_1,
            "{b}: pipelining worsened saturated p99: {p99_1}ns -> {p99_8}ns"
        );
        // The pipeline actually opened: telemetry shows depth past 1.
        assert!(eight.metrics.inflight_max_overall() > 1, "{b}: window never opened");
        assert!(eight.metrics.inflight_max_overall() <= 8, "{b}: window bound violated");
    }
}

#[test]
fn tiny_queue_cap_sheds_aggressively_but_books_balance() {
    let mut cfg = open_cfg(ArrivalProcess::Poisson { rate: 6_400_000 }, 0x10AD_CA9);
    cfg.queue_cap = 2;
    let rep = cluster::run(cfg);
    assert!(rep.converged() && rep.invariants_ok);
    assert_eq!(rep.metrics.offered, 6_000);
    assert_eq!(rep.metrics.offered, rep.metrics.total_completed() + rep.metrics.shed);
    assert!(rep.metrics.shed > 0, "a 2-deep queue under 6.4M ops/s/node must shed");
    assert!(rep.metrics.queue_depth_max <= 2, "queue bound violated");
    assert!(rep.metrics.total_completed() > 0, "service continues under shedding");
}
