//! Relaxed replication path (§4.1–§4.2, §5.4): landing zones for reducible
//! and irreducible ops, the summarization buffer, and the flush/propagation
//! machinery.
//!
//! Reducible ops land in per-origin contribution slots and fold on access
//! or on a poll (propagation mode §4.1); irreducible ops ride per-origin
//! FIFO queues (§4.2); summarization (§5.4) batches local applies and
//! ships type-correct aggregates, optionally diverting *conflicting* ops
//! off the SMR path (the integrity/staleness trade-off).

use crate::config::{PropagationMode, SimConfig};
use crate::engine::path::{Membership, ReplicaCore, ReplicationPath, Submission, TokenCtx};
use crate::engine::store::{Catalog, ObjectPlane};
use crate::engine::Ctx;
use crate::mem::MemKind;
use crate::net::verbs::{OpBatch, Payload, Verb, VerbKind};
use crate::rdt::{Category, ObjectId, OpCall};
use crate::sim::{EventKind, NodeId, Time, TimerKind};
use crate::util::hasher::FastMap;

/// Chaos-mode retransmit budget per tracked propagation verb. A peer that
/// NACKs this many paced retries is treated as unreachable for now; the
/// entry parks (see `given_up`) and is re-armed by the second-order
/// anti-entropy pass when the peer resurfaces (snapshot install / heal),
/// so bounding the retry chain never loses the update.
const RETRY_CAP: u32 = 64;

/// Chaos-mode receiver-side re-gossip ledger bound, *per origin*: the
/// newest remote relaxed ops this replica accepted from each origin, kept
/// so a *surviving receiver* can re-ship a crashed origin's partially-
/// propagated update — the origin's own retry/parked ledger dies with its
/// snapshot install, so receivers are the only place the update still
/// exists outside folded state. The bound is per origin because a crashed
/// origin stops producing: its entries must not be aged out by the live
/// peers' ongoing traffic before it recovers.
const RESHIP_CAP: usize = 256;

/// One tracked propagation awaiting its ACK (chaos mode only).
struct RetryEntry {
    dst: NodeId,
    verb: Verb,
    attempts: u32,
}

pub struct RelaxedPath {
    prop_red: PropagationMode,
    prop_irr: PropagationMode,
    /// Fan-out coalescer bound: up to this many queued submissions merge
    /// into one wire verb (1 = off, bit-identical to the unbatched engine).
    batch: usize,
    /// Chaos mode: the schedule contains link faults (partition / drop /
    /// delay), so propagation verbs track completions and retry on NACK
    /// until acknowledged, and applies dedup on `(object, origin, seq)`.
    /// Off for empty and crash-only schedules — the classic fire-and-forget
    /// path, bit-identical to the pre-chaos engine.
    reliable: bool,
    /// Per-object landing zones (HBM): written by remote one-sided verbs,
    /// drained by pollers or on access. Each object's summaries land in its
    /// own contribution slots; each object keeps its own per-origin FIFO
    /// queues (§4.1–§4.2, generalized to the catalog).
    pending_reducible: Vec<Vec<OpCall>>,
    pending_irreducible: Vec<Vec<OpCall>>,
    /// Total landed-but-unapplied ops across all objects — the drains'
    /// early-exit so a poll tick over a large, all-empty catalog stays
    /// O(1) instead of scanning every object's zone.
    landed_red: usize,
    landed_irr: usize,
    /// Locally applied ops awaiting one aggregated propagation (§5.4);
    /// flushes aggregate per (object, opcode, key).
    sum_buffer: Vec<(OpCall, Time)>,
    /// Coalescer outboxes (batch > 1): summaries / queue appends waiting to
    /// share a verb. Flushed when a full batch accumulates and by the
    /// `BatchFlush` timer, so a partial batch never stalls propagation.
    out_sum: Vec<OpCall>,
    out_irr: Vec<OpCall>,
    /// Reusable scratch pools (§Perf): the summarizer's flattened-op
    /// buffer and the drains' fresh-op staging vector. Capacity persists
    /// across flushes/polls, so the steady-state hot path allocates
    /// nothing per flush.
    flat_scratch: Vec<OpCall>,
    apply_scratch: Vec<OpCall>,
    /// Chaos mode: in-flight tracked propagations, keyed by retry id.
    retry: FastMap<u64, RetryEntry>,
    /// Chaos mode: tracked propagations that exhausted their retry budget
    /// against an unreachable peer. Parked, not dropped — `reconcile_to`
    /// re-arms them when the peer resurfaces (the ROADMAP's "second-order
    /// anti-entropy": a recover incident combined with link faults must not
    /// lose an update whose origin-retry was outstanding at every donor).
    given_up: Vec<RetryEntry>,
    next_retry_id: u64,
    /// Chaos mode: at-most-once ledger of `(object, origin, seq)` ops this
    /// replica already folded in — retried deliveries and post-snapshot
    /// stragglers must not double-apply. Transferred from the donor on
    /// snapshot install (the donor knows exactly which ops its state
    /// contains).
    seen: FastMap<(ObjectId, usize, u64), ()>,
    /// Chaos mode: per-origin FIFO re-gossip ledgers of the last
    /// [`RESHIP_CAP`] remote relaxed ops this replica accepted from each
    /// origin (see `regossip_origin`).
    reship: FastMap<usize, std::collections::VecDeque<OpCall>>,
}

impl RelaxedPath {
    pub fn new(cfg: &SimConfig) -> Self {
        let n_objects = cfg.n_objects();
        RelaxedPath {
            prop_red: cfg.prop_reducible,
            prop_irr: cfg.prop_irreducible,
            batch: cfg.batch_size as usize,
            reliable: cfg.fault.has_link_faults(),
            pending_reducible: (0..n_objects).map(|_| Vec::new()).collect(),
            pending_irreducible: (0..n_objects).map(|_| Vec::new()).collect(),
            landed_red: 0,
            landed_irr: 0,
            sum_buffer: Vec::new(),
            out_sum: Vec::new(),
            out_irr: Vec::new(),
            flat_scratch: Vec::new(),
            apply_scratch: Vec::new(),
            retry: FastMap::default(),
            given_up: Vec::new(),
            next_retry_id: 1,
            seen: FastMap::default(),
            reship: FastMap::default(),
        }
    }

    /// Chaos mode: remember an accepted *remote* op for receiver-side
    /// re-gossip (every caller sits on a delivery/landing-zone drain path,
    /// which only ever carries remote ops). Bounded FIFO per origin: old
    /// entries age out — by then the origin's own tracked retries have
    /// either landed them everywhere or parked them in a surviving
    /// `given_up` ledger.
    fn note_reship(&mut self, op: OpCall) {
        if !self.reliable {
            return;
        }
        let q = self.reship.entry(op.origin).or_default();
        if q.len() >= RESHIP_CAP {
            q.pop_front();
        }
        q.push_back(op);
    }

    /// Chaos-mode at-most-once gate: true when `op` has not been applied
    /// through the relaxed path yet. Always true outside chaos mode (the
    /// reliable in-order fabric never duplicates).
    fn mark_fresh(&mut self, op: &OpCall) -> bool {
        if !self.reliable {
            return true;
        }
        let key = (op.obj, op.origin, op.seq);
        if self.seen.contains_key(&key) {
            return false;
        }
        self.seen.insert(key, ());
        true
    }

    /// Propagation fan-out, switching between the classic fire-and-forget
    /// path and the chaos-mode tracked path. Chaos mode targets *every*
    /// peer, not just the live view: a partitioned peer may be mis-declared
    /// dead, and the NACK-retry loop is what reaches it after the heal
    /// (crashed peers burn their retry budget and resync via snapshot).
    fn fan_out_relaxed(
        &mut self,
        core: &mut ReplicaCore,
        ctx: &mut Ctx,
        mb: &dyn Membership,
        make: impl Fn(u64) -> Verb,
    ) {
        if !self.reliable {
            let peers = mb.live_peers(core.id);
            core.fan_out(ctx, &peers, make, false, || TokenCtx::Ignore);
            return;
        }
        let start = ctx.q.now().max(core.busy_until);
        let mut cursor = start;
        for i in 0..core.peers.len() {
            let dst = core.peers[i];
            let id = self.next_retry_id;
            self.next_retry_id += 1;
            let tok = core.token(TokenCtx::Relaxed { id });
            let verb = make(tok);
            self.retry.insert(id, RetryEntry { dst, verb: verb.clone(), attempts: 0 });
            ctx.metrics.verbs += 1;
            let out = ctx.net.issue(ctx.q, ctx.qps, &core.sys.fabric, cursor, core.id, dst, verb, true);
            cursor = out.initiator_free_at;
        }
        core.busy_total += cursor - start;
        core.busy_until = cursor;
    }

    fn drain_reducible_cost(&mut self, core: &mut ReplicaCore) -> u64 {
        if self.landed_red == 0 {
            return 0;
        }
        self.landed_red = 0;
        // Each object's landed summaries are contiguous slots in its own
        // landing zone: one burst read per non-empty object, then the whole
        // run folds through the columnar batch-apply kernel (§Perf — same
        // fold order as op-at-a-time, dispatch hoisted per run). The
        // staging vector is a reusable pool; steady state allocates
        // nothing.
        let mut zones = std::mem::take(&mut self.pending_reducible);
        let mut fresh = std::mem::take(&mut self.apply_scratch);
        let mut cost = 0;
        for zone in &mut zones {
            if zone.is_empty() {
                continue;
            }
            cost += core.sys.mem.fold_read_ns(core.landing_mem(), zone.len());
            fresh.clear();
            for op in zone.drain(..) {
                if self.mark_fresh(&op) {
                    self.note_reship(op);
                    fresh.push(op);
                }
            }
            cost += core.exec().op_exec_ns * fresh.len() as u64;
            core.apply_remote_batch(&fresh);
        }
        self.apply_scratch = fresh;
        self.pending_reducible = zones;
        cost
    }

    fn drain_irreducible_cost(&mut self, core: &mut ReplicaCore) -> u64 {
        if self.landed_irr == 0 {
            return 0;
        }
        self.landed_irr = 0;
        // Per-(object, origin) FIFO queues: burst-read each object's queue
        // head run, then batch-apply the fresh run (FIFO order preserved —
        // the kernel never reorders).
        let mut queues = std::mem::take(&mut self.pending_irreducible);
        let mut fresh = std::mem::take(&mut self.apply_scratch);
        let mut cost = 0;
        for queue in &mut queues {
            if queue.is_empty() {
                continue;
            }
            cost += core.sys.mem.fold_read_ns(core.landing_mem(), queue.len());
            fresh.clear();
            for op in queue.drain(..) {
                if self.mark_fresh(&op) {
                    self.note_reship(op);
                    fresh.push(op);
                }
            }
            cost += core.exec().op_exec_ns * fresh.len() as u64;
            core.apply_remote_batch(&fresh);
        }
        self.apply_scratch = fresh;
        self.pending_irreducible = queues;
        cost
    }

    fn flush_summaries(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, host_side: bool) {
        if self.sum_buffer.is_empty() {
            return;
        }
        let now = ctx.q.now();
        // The summary buffer and the flattened-op scratch are reusable
        // pools (§Perf): taken, drained, and handed back with their
        // capacity intact, so a steady-state flush allocates only the
        // aggregate vector it ships.
        let mut items = std::mem::take(&mut self.sum_buffer);
        for (_, applied_at) in &items {
            ctx.metrics.staleness.add((now.saturating_sub(*applied_at)) as f64);
        }
        let mut ops = std::mem::take(&mut self.flat_scratch);
        ops.clear();
        ops.extend(items.iter().map(|(o, _)| *o));
        items.clear();
        self.sum_buffer = items;
        // Summarize per object under each object's type-correct rule. A
        // stable sort groups by ascending object id while preserving
        // buffer order within an object — the identical grouping the old
        // per-object filter pass produced, in one pass over the buffer.
        ops.sort_by_key(|o| o.obj);
        let mut agg: Vec<OpCall> = Vec::with_capacity(ops.len());
        let mut i = 0;
        while i < ops.len() {
            let obj = ops[i].obj;
            let mut j = i + 1;
            while j < ops.len() && ops[j].obj == obj {
                j += 1;
            }
            agg.extend(summarize(core.plane.summarize_rule(obj), &ops[i..j]));
            i = j;
        }
        self.flat_scratch = ops;
        if host_side {
            core.charge_pcie_hop(now);
        }
        if self.batch > 1 {
            // Fan-out coalescer: queue, ship full batches immediately; the
            // BatchFlush timer sweeps partial ones.
            self.out_sum.extend(agg);
            while self.out_sum.len() >= self.batch {
                let chunk: Vec<OpCall> = self.out_sum.drain(..self.batch).collect();
                self.ship_summary_chunk(core, ctx, mb, chunk);
            }
            // Draining: no sweeper may fire after us (the post-drain
            // SummarizeFlush can outlive the last BatchFlush — its period
            // is 4x), so a partial remainder must ship now or never.
            if ctx.draining && !self.out_sum.is_empty() {
                let rest: Vec<OpCall> = self.out_sum.drain(..).collect();
                self.ship_summary_chunk(core, ctx, mb, rest);
            }
            return;
        }
        let origin = core.id;
        let mode = self.prop_red;
        let mem = core.landing_mem_for_peer();
        for op in agg {
            self.fan_out_relaxed(core, ctx, mb, |t| {
                let payload = Payload::Summary { origin, ops: 1, value: op };
                match mode {
                    PropagationMode::Rpc => Verb::rpc(payload, t),
                    _ => Verb::write(mem, payload, t),
                }
            });
        }
    }

    /// Ship one coalesced summary chunk (`<= batch` entries, one verb per
    /// live peer). A landing-zone read per entry occupies the replica and
    /// the verb-issue setup is paid once; k-1 verb issues are saved
    /// relative to unbatched.
    fn ship_summary_chunk(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, chunk: Vec<OpCall>) {
        if chunk.is_empty() {
            return;
        }
        let origin = core.id;
        let per = core.sys.mem.local_read_ns(core.landing_mem());
        core.occupy_batch(ctx.q.now(), per, chunk.len());
        ctx.metrics.coalesced += chunk.len() as u64 - 1;
        let mem = core.landing_mem_for_peer();
        let mode = self.prop_red;
        // One shared batch; each per-peer clone is a refcount bump (§Perf).
        let chunk: OpBatch = chunk.into();
        self.fan_out_relaxed(core, ctx, mb, |t| {
            let payload = Payload::SummaryBatch { origin, values: chunk.clone() };
            match mode {
                PropagationMode::Rpc => Verb::rpc(payload, t),
                _ => Verb::write(mem, payload, t),
            }
        });
    }

    /// Ship one coalesced irreducible chunk (FIFO order preserved inside
    /// the batch and by the in-order channel across batches).
    fn ship_queue_chunk(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, chunk: Vec<OpCall>) {
        if chunk.is_empty() {
            return;
        }
        let per = core.sys.mem.local_read_ns(core.landing_mem());
        core.occupy_batch(ctx.q.now(), per, chunk.len());
        ctx.metrics.coalesced += chunk.len() as u64 - 1;
        let mem = core.landing_mem_for_peer();
        let mode = self.prop_irr;
        let chunk: OpBatch = chunk.into();
        self.fan_out_relaxed(core, ctx, mb, |t| {
            let payload = Payload::QueueBatch { ops: chunk.clone() };
            match mode {
                PropagationMode::Rpc => Verb::rpc(payload, t),
                _ => Verb::write(mem, payload, t),
            }
        });
    }

    fn propagate_irreducible(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, op: OpCall, host_side: bool) {
        if host_side {
            core.charge_pcie_hop(ctx.q.now());
        }
        if self.batch > 1 {
            self.out_irr.push(op);
            while self.out_irr.len() >= self.batch {
                let chunk: Vec<OpCall> = self.out_irr.drain(..self.batch).collect();
                self.ship_queue_chunk(core, ctx, mb, chunk);
            }
            return;
        }
        let mem = core.landing_mem_for_peer();
        let mode = self.prop_irr;
        self.fan_out_relaxed(core, ctx, mb, |t| {
            let payload = Payload::QueueAppend { op };
            match mode {
                PropagationMode::Rpc => Verb::rpc(payload, t),
                _ => Verb::write(mem, payload, t),
            }
        });
    }
}

impl ReplicationPath for RelaxedPath {
    fn boot(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, base: u64) {
        if self.prop_red == PropagationMode::WriteBuffered {
            ctx.q.push(base + core.poll_interval_ns, core.id, EventKind::Timer(TimerKind::PollReducible));
        }
        if self.prop_irr == PropagationMode::WriteNoBuffer || self.prop_irr == PropagationMode::WriteBuffered {
            ctx.q.push(base + core.poll_interval_ns, core.id, EventKind::Timer(TimerKind::PollIrreducible));
        }
    }

    fn boot_late(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, base: u64) {
        // The coalescer sweeper arms after the heartbeat scanner; while the
        // replica is live and not draining one is always pending, so a
        // partial batch is shipped at most one poll interval late (and the
        // post-drain firing empties the outboxes before quiescence). A
        // crash kills the chain, which is safe: the crashed replica's
        // quota is drained and never re-granted, so after recovery no
        // submission can ever reach the outboxes again (and the pre-crash
        // residue is cleared with the snapshot install).
        if self.batch > 1 {
            ctx.q.push(base + 2 * core.poll_interval_ns, core.id, EventKind::Timer(TimerKind::BatchFlush));
        }
        // The summarize flusher arms after the heartbeat scanner.
        if core.summarize_threshold > 1 {
            ctx.q.push(base + 4 * core.poll_interval_ns, core.id, EventKind::Timer(TimerKind::SummarizeFlush));
        }
    }

    fn refresh_cost(&mut self, core: &mut ReplicaCore) -> u64 {
        let mut cost = 0;
        // Reducible contribution fold (§4.1): no-buffer pays a fold from
        // the landing memory; buffered/RPC read warm on-fabric state
        // (the Design Principle #2 story).
        if self.prop_red == PropagationMode::WriteNoBuffer {
            cost += core.sys.mem.fold_read_ns(core.landing_mem(), core.n);
            cost += self.drain_reducible_cost(core);
        }
        // Irreducible queue drain (§4.2 config 1 polls; no-buffer also
        // drains on access).
        if self.prop_irr == PropagationMode::WriteNoBuffer {
            cost += self.drain_irreducible_cost(core);
        }
        cost
    }

    fn submit(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, sub: Submission) {
        let Submission { mut op, category, host_side, mut cost, arrival, client } = sub;
        if category == Category::Conflicting {
            // §5.4 Summarization: "instead of updating the remote replicas
            // via RDMA *or coordination* ... we only update the local
            // state" — batching trades integrity staleness for performance.
            // The op was locally permissible; it applies locally and ships
            // as a normalized delta in the next summary flush.
            op = normalize_for_summary(core.plane.object(op.obj), op);
        }
        cost += core.exec().op_exec_ns + core.write_state_cost(host_side);
        core.executions += 1;
        core.plane.apply(&op);
        // Chaos mode: our own ops enter the ledger too — a snapshot donor's
        // state contains its *local* applies as well, and the recovering
        // node must not re-apply their still-in-flight retried copies.
        // (Summarized aggregates inherit the max member seq, so the raw
        // entries recorded here cover them.)
        let _ = self.mark_fresh(&op);
        // Op-based relaxed semantics: respond after the local commit;
        // propagation proceeds off the response path but still occupies
        // the replica (throughput, not latency).
        let t_apply = core.occupy(arrival, cost);
        let done = core.occupy(t_apply, core.exec().client_overhead_ns / 2);
        core.complete_client(ctx, client, arrival, done);
        match category {
            Category::Irreducible => self.propagate_irreducible(core, ctx, mb, op, host_side),
            _ => {
                self.sum_buffer.push((op, t_apply));
                if self.sum_buffer.len() as u32 >= core.summarize_threshold {
                    self.flush_summaries(core, ctx, mb, host_side);
                }
            }
        }
    }

    fn deliver(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, _mb: &dyn Membership, _src: NodeId, verb: Verb) {
        let is_rpc = matches!(verb.kind, VerbKind::Rpc | VerbKind::RpcWriteThrough);
        match verb.payload {
            Payload::Summary { value, .. } => {
                if is_rpc {
                    // Dispatcher invokes the accelerator directly (Fig 1).
                    let cost = core.exec().op_exec_ns + core.sys.mem.local_write_ns(MemKind::Bram);
                    core.occupy(ctx.q.now(), cost);
                    if self.mark_fresh(&value) {
                        self.note_reship(value);
                        core.apply_remote(&value);
                    }
                } else {
                    self.pending_reducible[value.obj as usize].push(value);
                    self.landed_red += 1;
                }
            }
            Payload::QueueAppend { op } => {
                if is_rpc {
                    let cost = core.exec().op_exec_ns + core.sys.mem.local_write_ns(MemKind::Bram);
                    core.occupy(ctx.q.now(), cost);
                    if self.mark_fresh(&op) {
                        self.note_reship(op);
                        core.apply_remote(&op);
                    }
                } else {
                    self.pending_irreducible[op.obj as usize].push(op);
                    self.landed_irr += 1;
                }
            }
            Payload::SummaryBatch { values, .. } => {
                if is_rpc {
                    let per = core.exec().op_exec_ns + core.sys.mem.local_write_ns(MemKind::Bram);
                    core.occupy_batch(ctx.q.now(), per, values.len());
                    for &v in values.iter() {
                        if self.mark_fresh(&v) {
                            self.note_reship(v);
                            core.apply_remote(&v);
                        }
                    }
                } else {
                    self.landed_red += values.len();
                    for &v in values.iter() {
                        self.pending_reducible[v.obj as usize].push(v);
                    }
                }
            }
            Payload::QueueBatch { ops } => {
                if is_rpc {
                    let per = core.exec().op_exec_ns + core.sys.mem.local_write_ns(MemKind::Bram);
                    core.occupy_batch(ctx.q.now(), per, ops.len());
                    for &op in ops.iter() {
                        if self.mark_fresh(&op) {
                            self.note_reship(op);
                            core.apply_remote(&op);
                        }
                    }
                } else {
                    self.landed_irr += ops.len();
                    for &op in ops.iter() {
                        self.pending_irreducible[op.obj as usize].push(op);
                    }
                }
            }
            _ => {}
        }
    }

    fn on_timer(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, t: TimerKind) {
        match t {
            TimerKind::PollReducible => {
                let cost = core.exec().poll_tick_ns + self.drain_reducible_cost(core);
                core.occupy(ctx.q.now(), cost);
                if !ctx.draining {
                    ctx.q.push(ctx.q.now() + core.poll_interval_ns, core.id, EventKind::Timer(t));
                }
            }
            TimerKind::PollIrreducible => {
                let cost = core.exec().poll_tick_ns + self.drain_irreducible_cost(core);
                core.occupy(ctx.q.now(), cost);
                if !ctx.draining {
                    ctx.q.push(ctx.q.now() + core.poll_interval_ns, core.id, EventKind::Timer(t));
                }
            }
            TimerKind::SummarizeFlush => {
                if !self.sum_buffer.is_empty() {
                    self.flush_summaries(core, ctx, mb, false);
                }
                if !ctx.draining {
                    ctx.q.push(ctx.q.now() + 4 * core.poll_interval_ns, core.id, EventKind::Timer(t));
                }
            }
            TimerKind::BatchFlush => {
                while !self.out_sum.is_empty() {
                    let take = self.out_sum.len().min(self.batch);
                    let chunk: Vec<OpCall> = self.out_sum.drain(..take).collect();
                    self.ship_summary_chunk(core, ctx, mb, chunk);
                }
                while !self.out_irr.is_empty() {
                    let take = self.out_irr.len().min(self.batch);
                    let chunk: Vec<OpCall> = self.out_irr.drain(..take).collect();
                    self.ship_queue_chunk(core, ctx, mb, chunk);
                }
                if !ctx.draining {
                    ctx.q.push(ctx.q.now() + core.poll_interval_ns, core.id, EventKind::Timer(t));
                }
            }
            _ => {}
        }
    }

    fn on_completion(
        &mut self,
        core: &mut ReplicaCore,
        ctx: &mut Ctx,
        _mb: &dyn Membership,
        token: TokenCtx,
        ok: bool,
    ) {
        // Chaos-mode tracked propagation: ACK retires the retry entry; a
        // NACK (partition / drop / crash) re-ships the same payload after a
        // heartbeat beat, off the busy clock — the soft RNIC retransmits in
        // fabric logic. The budget bounds retries to peers that are really
        // gone; the entry then parks for the second-order anti-entropy
        // pass (`reconcile_to`) instead of being dropped, so a peer that
        // resurfaces after a long outage still receives the update.
        let TokenCtx::Relaxed { id } = token else { return };
        let Some(mut entry) = self.retry.remove(&id) else { return };
        if ok {
            return;
        }
        entry.attempts += 1;
        if entry.attempts > RETRY_CAP {
            self.given_up.push(entry);
            return;
        }
        let next_id = self.next_retry_id;
        self.next_retry_id += 1;
        let tok = core.token(TokenCtx::Relaxed { id: next_id });
        entry.verb.token = tok;
        let verb = entry.verb.clone();
        let dst = entry.dst;
        self.retry.insert(next_id, entry);
        ctx.metrics.verbs += 1;
        let at = ctx.q.now() + core.heartbeat_period_ns;
        ctx.net.issue(ctx.q, ctx.qps, &core.sys.fabric, at, core.id, dst, verb, true);
    }

    fn flush_pending(&mut self, plane: &mut Catalog) {
        self.landed_red = 0;
        self.landed_irr = 0;
        let mut zones = std::mem::take(&mut self.pending_reducible);
        for zone in &mut zones {
            for op in zone.drain(..) {
                if self.mark_fresh(&op) {
                    plane.apply(&op);
                }
            }
        }
        self.pending_reducible = zones;
        let mut queues = std::mem::take(&mut self.pending_irreducible);
        for queue in &mut queues {
            for op in queue.drain(..) {
                if self.mark_fresh(&op) {
                    plane.apply(&op);
                }
            }
        }
        self.pending_irreducible = queues;
    }

    fn clear_landed(&mut self) {
        // Pre-crash local residue (unsent summaries, coalescer outboxes)
        // and in-flight/parked retries die with the snapshot install in
        // any mode. The re-gossip ledger dies too: the installed state is
        // the donor's, and the survivors' ledgers cover the recovering
        // node's own originations.
        self.sum_buffer.clear();
        self.out_sum.clear();
        self.out_irr.clear();
        self.retry = FastMap::default();
        self.given_up.clear();
        self.reship = FastMap::default();
        if self.reliable {
            // Chaos mode keeps the landed-but-unapplied buffers: retried
            // deliveries may have landed just before the install, and the
            // donor's `seen` ledger (installed right after this call)
            // filters exactly the ones its snapshot already contains.
            return;
        }
        for v in &mut self.pending_reducible {
            v.clear();
        }
        for v in &mut self.pending_irreducible {
            v.clear();
        }
        self.landed_red = 0;
        self.landed_irr = 0;
    }

    fn snapshot_relaxed_seen(&self) -> Vec<(ObjectId, usize, u64)> {
        let mut v: Vec<(ObjectId, usize, u64)> = self.seen.keys().copied().collect();
        v.sort_unstable();
        v
    }

    fn install_relaxed_seen(&mut self, seen: Vec<(ObjectId, usize, u64)>) {
        self.seen = seen.into_iter().map(|k| (k, ())).collect();
    }

    fn reconcile_to(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, peer: NodeId, full: bool) {
        // Second-order anti-entropy: re-arm every parked propagation whose
        // destination is the resurfaced peer. The peer's dedup ledger (its
        // own, or the donor's it just installed) makes duplicates safe.
        if !self.reliable {
            return;
        }
        let (ship, keep): (Vec<RetryEntry>, Vec<RetryEntry>) =
            self.given_up.drain(..).partition(|e| e.dst == peer);
        self.given_up = keep;
        let mut verbs: Vec<Verb> = ship.into_iter().map(|e| e.verb).collect();
        if full {
            // Snapshot install: the peer's state is one donor's copy, and
            // any propagation still outstanding against *some* replica may
            // be missing from that donor — including ops the peer itself
            // ACKed before it crashed. Re-ship a copy of every outstanding
            // entry (parked or in-flight) to the peer; its installed dedup
            // ledger drops exactly the ones the donor had folded in.
            verbs.extend(self.given_up.iter().map(|e| e.verb.clone()));
            let mut ids: Vec<u64> = self.retry.keys().copied().collect();
            ids.sort_unstable(); // canonical re-ship order
            for id in ids {
                let e = &self.retry[&id];
                if e.dst != peer {
                    verbs.push(e.verb.clone());
                }
            }
        }
        for mut verb in verbs {
            let id = self.next_retry_id;
            self.next_retry_id += 1;
            let tok = core.token(TokenCtx::Relaxed { id });
            verb.token = tok;
            self.retry.insert(id, RetryEntry { dst: peer, verb: verb.clone(), attempts: 0 });
            ctx.metrics.verbs += 1;
            ctx.net.issue(ctx.q, ctx.qps, &core.sys.fabric, ctx.q.now(), core.id, peer, verb, true);
        }
    }

    fn regossip_origin(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, origin: NodeId) {
        // Receiver-side re-gossip: `origin` just installed a donor
        // snapshot, which wiped its retry/parked ledgers — an update it
        // had only partially propagated before crashing now exists solely
        // at the receivers that accepted it (the donor may not be one of
        // them). Re-ship every ledger entry that `origin` originated to
        // *every* peer: the `(object, origin, seq)` dedup ledgers absorb
        // the duplicates, and the tracked fan-out retries through any
        // still-faulty links.
        if !self.reliable {
            return;
        }
        let ops: Vec<OpCall> = self
            .reship
            .get(&origin)
            .map(|q| q.iter().copied().collect())
            .unwrap_or_default();
        let mem = core.landing_mem_for_peer();
        for op in ops {
            let irr = core.plane.category(op.obj, op.opcode) == Category::Irreducible;
            self.fan_out_relaxed(core, ctx, mb, |t| {
                let payload = if irr {
                    Payload::QueueAppend { op }
                } else {
                    Payload::Summary { origin: op.origin, ops: 1, value: op }
                };
                Verb::write(mem, payload, t)
            });
        }
    }

    fn debug_status(&self) -> String {
        format!(
            "pend_red={} pend_irr={} sum_buf={} out_sum={} out_irr={} retry={} parked={} reship={}",
            self.pending_reducible.iter().map(Vec::len).sum::<usize>(),
            self.pending_irreducible.iter().map(Vec::len).sum::<usize>(),
            self.sum_buffer.len(),
            self.out_sum.len(),
            self.out_irr.len(),
            self.retry.len(),
            self.given_up.len(),
            self.reship.values().map(std::collections::VecDeque::len).sum::<usize>()
        )
    }
}

/// Rewrite a locally-validated conflicting op into its commutative delta
/// form for summarized propagation (§5.4): debits become negative
/// deposits. Only meaningful for scalar-balance types; other conflicting
/// ops pass through unchanged (their apply is set-idempotent). `plane` is
/// the catalog object the op addresses.
pub fn normalize_for_summary(plane: &ObjectPlane, mut op: OpCall) -> OpCall {
    use crate::engine::store::{KvKind, KV_WITHDRAW, KV_WRITE};
    match plane {
        ObjectPlane::Kv(kv) if kv.kind == KvKind::SmallBank && op.opcode == KV_WITHDRAW => {
            op.opcode = KV_WRITE;
            op.x = -op.x;
            op
        }
        ObjectPlane::Micro(r) if r.kind() == crate::rdt::RdtKind::Account => {
            use crate::rdt::wrdt::account::{OP_DEPOSIT, OP_WITHDRAW};
            if op.opcode == OP_WITHDRAW {
                op.opcode = OP_DEPOSIT;
                op.x = -op.x;
            }
            op
        }
        _ => op,
    }
}

/// How a reducible op stream aggregates (§2.1 "summarizable").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SummarizeRule {
    /// Sum deltas per (opcode, key): counters, deposits.
    SumDelta,
    /// Keep only the highest-timestamp write per key: LWW registers, YCSB.
    LastWrite,
    /// Not scalar-summable (set inserts): ship the batch as-is — still one
    /// verb per op on the wire, but flushed together.
    ShipAll,
}

/// Aggregate a run of reducible ops under a type-correct rule. Keys
/// include the catalog object id, so a multi-object buffer can never fold
/// two objects' deltas together (callers group per object anyway; the key
/// keeps the invariant local).
pub fn summarize(rule: SummarizeRule, ops: &[OpCall]) -> Vec<OpCall> {
    use std::collections::BTreeMap;
    match rule {
        SummarizeRule::ShipAll => ops.to_vec(),
        SummarizeRule::SumDelta => {
            let mut agg: BTreeMap<(ObjectId, u8, u64), OpCall> = BTreeMap::new();
            for op in ops {
                let e = agg.entry((op.obj, op.opcode, op.b)).or_insert_with(|| {
                    let mut z = *op;
                    z.a = 0;
                    z.x = 0.0;
                    z
                });
                e.a += op.a;
                e.x += op.x;
                e.seq = e.seq.max(op.seq);
            }
            agg.into_values().collect()
        }
        SummarizeRule::LastWrite => {
            let mut best: BTreeMap<(ObjectId, u64), OpCall> = BTreeMap::new();
            for op in ops {
                let e = best.entry((op.obj, op.b)).or_insert(*op);
                // op.a is the LWW timestamp for both the micro register and
                // the YCSB KV path.
                if op.a > e.a {
                    *e = *op;
                }
            }
            best.into_values().collect()
        }
    }
}
