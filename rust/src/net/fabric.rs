//! Fabric cost models: the per-verb latency decomposition for a
//! traditional CPU+RNIC RDMA node (appendix C.2/C.3, Figs 19–20) and for a
//! network-attached FPGA with a soft RNIC (appendix C.4/C.5, Figs 21–22).
//!
//! Calibration targets (tests below assert them):
//! * Table 2.1 — traditional Read 1.8 µs, Write 2.0 µs; FPGA on-chip verb
//!   path ≈ 9 ns.
//! * Table C.1 — FPGA end-to-end one-way: Write(HBM) 413 ns,
//!   BRAM_Write(_Through) 309 ns, Register_Write(_Through) 285 ns.
//! * Fig 13 — permission switch: FPGA bimodal {17, 24} ns; traditional
//!   lognormal around hundreds of µs.

use crate::mem::{MemKind, MemParams};
use crate::util::rng::Rng;

/// Permission-switch (QP access-flag change) latency model (§4.4 Leader
/// Switch Plane, Design Principle #3).
#[derive(Clone, Copy, Debug)]
pub enum PermSwitchModel {
    /// FPGA: the SMR kernel pokes QP state registers directly; the observed
    /// distribution is bimodal (17 ns or 24 ns depending on arbitration).
    Bimodal { fast_ns: u64, slow_ns: u64, p_fast: f64 },
    /// Traditional RNIC: driver call + PCIe round trips + RNIC cache
    /// invalidation; lognormal with heavy tail.
    Lognormal { median_ns: f64, sigma: f64 },
}

impl PermSwitchModel {
    pub fn sample(&self, rng: &mut Rng) -> u64 {
        match *self {
            PermSwitchModel::Bimodal { fast_ns, slow_ns, p_fast } => {
                if rng.gen_bool(p_fast) {
                    fast_ns
                } else {
                    slow_ns
                }
            }
            PermSwitchModel::Lognormal { median_ns, sigma } => {
                rng.gen_lognormal(median_ns, sigma).max(1.0) as u64
            }
        }
    }
}

/// Per-fabric latency decomposition.
#[derive(Clone, Copy, Debug)]
pub struct FabricParams {
    /// Initiator overhead to hand a verb to the NIC. CPU: payload store +
    /// SQE post + doorbell + RNIC SQE fetch over PCIe (Fig 20 steps 1–4).
    /// FPGA: one AXI-stream push (Fig 22 step 1).
    pub verb_issue_ns: u64,
    /// NIC processing before the wire (QPC check, packetize).
    pub tx_stack_ns: u64,
    /// Propagation + one switch hop.
    pub wire_ns: u64,
    /// Link bandwidth for serialization delay (bytes per ns; 100 GbE = 12.5).
    pub bytes_per_ns: f64,
    /// Receive-side NIC processing (permission check, unpack).
    pub rx_stack_ns: u64,
    /// Extra hop for the payload to land past the NIC. CPU node: PCIe DMA.
    /// FPGA: zero (the network kernel writes memory directly).
    pub remote_landing_ns: u64,
    /// ACK generation at the remote plus CQE post at the initiator
    /// (traditional: PCIe write into the CQ; FPGA: ACK-queue pop).
    pub ack_overhead_ns: u64,
    /// Whether the initiating application must wait for the CQE before
    /// proceeding (Hamband does, per the RDMA spec discussion in §5.2;
    /// SafarDB/StRoM interleaves verbs with application logic).
    pub wait_ack: bool,
    /// How long a verb to a crashed node stalls before erroring out:
    /// RC retransmission timeout on a traditional RNIC (100s of µs —
    /// Fig 14's follower-crash RT spike for Hamband); the FPGA stack
    /// detects the dead link fast.
    pub crash_timeout_ns: u64,
    /// FPGA-specific RPC verbs available (§C.6)?
    pub supports_rpc: bool,
    pub perm_switch: PermSwitchModel,
}

impl FabricParams {
    /// Network-attached FPGA with StRoM-style soft RNIC.
    pub fn fpga() -> Self {
        FabricParams {
            verb_issue_ns: 4,
            tx_stack_ns: 55,
            wire_ns: 190,
            bytes_per_ns: 12.5,
            rx_stack_ns: 36,
            remote_landing_ns: 0,
            ack_overhead_ns: 90,
            wait_ack: false,
            crash_timeout_ns: 2_000,
            supports_rpc: true,
            perm_switch: PermSwitchModel::Bimodal { fast_ns: 17, slow_ns: 24, p_fast: 0.72 },
        }
    }

    /// Traditional CPU + RNIC over PCIe (the Hamband deployment).
    ///
    /// Calibration: Table 2.1 reports *initiator-observed* latencies —
    /// Read = full RTT with the payload landed (1.8 µs), Write = CQE
    /// completion (2.0 µs). Note `remote_landing_ns` is NIC-internal DMA
    /// setup only; the PCIe+DRAM hop itself is in `MemParams::net_write_ns`.
    pub fn traditional() -> Self {
        FabricParams {
            verb_issue_ns: 200, // SQE store + doorbell (posted, CPU-visible cost)
            tx_stack_ns: 100,
            wire_ns: 190,
            bytes_per_ns: 25.0, // NDR200 InfiniBand
            rx_stack_ns: 150,
            remote_landing_ns: 190, // RNIC DMA engine setup
            ack_overhead_ns: 626,   // ACK gen + wire + CQE PCIe post + SQE drain
            wait_ack: true,
            crash_timeout_ns: 120_000, // RC retransmit backoff
            
            supports_rpc: false,
            perm_switch: PermSwitchModel::Lognormal { median_ns: 250_000.0, sigma: 0.55 },
        }
    }

    fn serialize_ns(&self, bytes: u64) -> u64 {
        (bytes as f64 / self.bytes_per_ns) as u64
    }

    /// One-way latency: verb leaves the initiating application until the
    /// payload is visible at `dst_mem` on the remote node.
    pub fn one_way_ns(&self, bytes: u64, dst_mem: MemKind, mem: &MemParams) -> u64 {
        self.verb_issue_ns
            + self.tx_stack_ns
            + self.serialize_ns(bytes)
            + self.wire_ns
            + self.rx_stack_ns
            + self.remote_landing_ns
            + mem.net_write_ns(dst_mem)
    }

    /// When the initiator regains control after issuing a verb: immediately
    /// after the issue overhead if pipelined, or after the full ACK round
    /// trip if `wait_ack`.
    pub fn initiator_busy_ns(&self, bytes: u64, dst_mem: MemKind, mem: &MemParams) -> u64 {
        if self.wait_ack {
            self.one_way_ns(bytes, dst_mem, mem) + self.ack_overhead_ns
        } else {
            self.verb_issue_ns
        }
    }

    /// ACK arrival at the initiator, relative to issue.
    pub fn ack_at_ns(&self, bytes: u64, dst_mem: MemKind, mem: &MemParams) -> u64 {
        self.one_way_ns(bytes, dst_mem, mem) + self.ack_overhead_ns
    }

    /// Full Read round trip: request out, NIC-side memory fetch (no remote
    /// CPU involvement), data back, payload landed at the initiator.
    pub fn read_rtt_ns(&self, resp_bytes: u64, src_mem: MemKind, mem: &MemParams) -> u64 {
        let req = self.verb_issue_ns + self.tx_stack_ns + self.serialize_ns(16) + self.wire_ns
            + self.rx_stack_ns;
        let remote = mem.net_write_ns(src_mem); // symmetric fetch cost
        let resp = self.tx_stack_ns + self.serialize_ns(resp_bytes) + self.wire_ns
            + self.rx_stack_ns + self.remote_landing_ns;
        req + remote + resp
    }

    /// The Table 2.1 "network-attached FPGA" number: verb issue over the
    /// on-chip AXI path (user kernel -> network kernel handshake), i.e. the
    /// cost that replaces the CPU's PCIe doorbell dance.
    pub fn local_verb_ns(&self, mem: &MemParams) -> u64 {
        self.verb_issue_ns + mem.axi_hop_ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mem() -> MemParams {
        MemParams::default_params()
    }

    #[test]
    fn table_c1_fpga_one_way_latencies() {
        let f = FabricParams::fpga();
        let m = mem();
        assert_eq!(f.one_way_ns(0, MemKind::Reg, &m), 285);
        assert_eq!(f.one_way_ns(0, MemKind::Bram, &m), 309);
        assert_eq!(f.one_way_ns(0, MemKind::Hbm, &m), 413);
    }

    #[test]
    fn table_2_1_traditional_latencies() {
        let f = FabricParams::traditional();
        let m = mem();
        // Write latency as the initiator observes it: CQE completion.
        let write = f.ack_at_ns(0, MemKind::HostDram, &m);
        assert!((1_900..=2_100).contains(&write), "write={write}");
        let read = f.read_rtt_ns(64, MemKind::HostDram, &m);
        assert!((1_700..=1_900).contains(&read), "read={read}");
    }

    #[test]
    fn table_2_1_fpga_local_verb() {
        let f = FabricParams::fpga();
        assert_eq!(f.local_verb_ns(&mem()), 9);
    }

    #[test]
    fn hamband_waits_for_ack_safardb_does_not() {
        let m = mem();
        let fpga = FabricParams::fpga();
        let cpu = FabricParams::traditional();
        assert_eq!(fpga.initiator_busy_ns(64, MemKind::Hbm, &m), 4);
        let busy = cpu.initiator_busy_ns(64, MemKind::HostDram, &m);
        assert!(busy > 1_900, "Hamband serializes on the CQE: {busy}");
    }

    #[test]
    fn perm_switch_distributions_match_fig13() {
        let mut rng = Rng::new(13);
        let fpga = FabricParams::fpga().perm_switch;
        for _ in 0..1000 {
            let v = fpga.sample(&mut rng);
            assert!(v == 17 || v == 24, "FPGA switch bimodal: {v}");
        }
        let trad = FabricParams::traditional().perm_switch;
        let mut vals: Vec<u64> = (0..1001).map(|_| trad.sample(&mut rng)).collect();
        vals.sort();
        let med = vals[500];
        assert!((150_000..400_000).contains(&med), "median={med}");
        assert!(vals[990] > 2 * med, "heavy tail expected");
    }

    #[test]
    fn serialization_delay_scales_with_bytes() {
        let f = FabricParams::fpga();
        let m = mem();
        let small = f.one_way_ns(64, MemKind::Hbm, &m);
        let big = f.one_way_ns(4096, MemKind::Hbm, &m);
        assert!(big > small + 300);
    }
}
