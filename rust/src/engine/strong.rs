//! Strongly-ordered replication path (§4.3–§4.4): Mu SMR instances per
//! synchronization group, the replication logs, leader-forwarding and
//! requester bookkeeping — plus the Raft pipeline, serving both the
//! Waverunner baseline (§5.2, which replicates *every* update through this
//! path with leader-only clients) and the stand-alone `backend = raft`
//! configuration (category-routed like Mu, leader-authoritative
//! permissibility, batched AppendEntries). The APUS-style Paxos backend
//! lives in its own plane, `engine::paxos`.
//!
//! The path owns its completion tokens ([`StrongToken`]): Mu round
//! responses and forwarded-op replies route back here via the coordinator's
//! token table. The former `TokenCtx::Raft` variant is gone — Raft
//! AppendEntries completions are logical (`Payload::RaftAck` verbs), so the
//! fan-out rides fire-and-forget `Ignore` tokens like all other
//! unacknowledged writes.

use crate::config::{ConsensusBackend, PropagationMode, SimConfig, SystemKind};
use crate::engine::path::{
    Membership, MembershipEvent, PendingClient, ReplicaCore, ReplicationPath, Requester,
    Submission, TokenCtx,
};
use crate::engine::store::{DataPlane, KV_READ};
use crate::engine::Ctx;
use crate::mem::MemKind;
use crate::net::verbs::{Payload, ReadData, ReadTarget, Verb};
use crate::rdt::OpCall;
use crate::sim::{EventKind, NodeId, Time, TimerKind};
use crate::smr::log::ReplicationLog;
use crate::smr::mu::{MuInstance, Resp, Round, Step};
use crate::smr::raft::{RaftFollower, RaftLeader, RaftStep};
use crate::util::hasher::FastMap;
use crate::workload::WorkItem;

/// Completion tokens owned by the strong path.
#[derive(Clone, Copy, Debug)]
pub enum StrongToken {
    /// Mu fan-out response: (group, round_id at fan-out time).
    Mu { group: u8, round_id: u64 },
    /// Forwarded conflicting op awaiting a LeaderReply.
    Forward { request_id: u64 },
}

pub struct StrongPath {
    prop_con: PropagationMode,
    /// Mu or Raft (Paxos lives in `engine::paxos`). Waverunner pins Raft.
    backend: ConsensusBackend,
    /// Leader-side log-entry batching bound (1 = off).
    batch: usize,
    /// One Mu instance + replication log per synchronization group.
    mu: Vec<MuInstance>,
    logs: Vec<ReplicationLog>,
    round_id: Vec<u64>,
    requesters: FastMap<(usize, u64), Requester>,
    pending_fwd: FastMap<u64, PendingClient>,
    next_request_id: u64,
    // Waverunner baseline (Raft fast path, leader-only clients).
    raft_leader: Option<RaftLeader>,
    raft_follower: RaftFollower,
    raft_pending: FastMap<u64, Requester>, // index -> requester
}

impl StrongPath {
    pub fn new(cfg: &SimConfig, id: NodeId, groups: usize) -> Self {
        // The Raft pipeline serves both Waverunner (whose preset pins
        // backend = Raft) and the stand-alone Raft backend; node 0 leads
        // fault-free runs either way.
        let raft_leader = if cfg.backend == ConsensusBackend::Raft
            && id == crate::smr::raft::initial_leader()
        {
            Some(RaftLeader::with_batch(cfg.n_replicas, cfg.batch_size as usize))
        } else {
            None
        };
        StrongPath {
            prop_con: cfg.prop_conflicting,
            backend: cfg.backend,
            batch: cfg.batch_size as usize,
            mu: (0..groups).map(|g| MuInstance::new(g as u8, cfg.n_replicas)).collect(),
            logs: (0..groups).map(|_| ReplicationLog::new()).collect(),
            round_id: vec![0; groups],
            requesters: FastMap::default(),
            pending_fwd: FastMap::default(),
            next_request_id: 1,
            raft_leader,
            raft_follower: RaftFollower::new(),
            raft_pending: FastMap::default(),
        }
    }

    fn drain_logs_cost(&mut self, core: &mut ReplicaCore) -> u64 {
        let mut cost = 0;
        for g in 0..self.logs.len() {
            for entry in self.logs[g].drain_unapplied() {
                cost += core.exec().op_exec_ns + core.sys.mem.local_read_ns(core.landing_mem());
                core.executions += 1;
                core.plane.apply_forced(&entry.op);
            }
        }
        cost
    }

    fn submit_conflicting(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, op: OpCall, req: Requester) {
        if core.system == SystemKind::Waverunner {
            self.waverunner_submit(core, ctx, mb, op, req);
            return;
        }
        if self.backend == ConsensusBackend::Raft {
            self.raft_submit(core, ctx, mb, op, req);
            return;
        }
        self.requesters.insert((op.origin, op.seq), req);
        if core.is_leader() {
            let g = core.plane.sync_group(op.opcode) as usize;
            let slot = self.logs[g].next_free_slot();
            if let Some(round) = self.mu[g].submit(op, slot) {
                self.fan_out_round(core, ctx, mb, g, round);
            }
        } else {
            self.forward_conflicting(core, ctx, op, req);
        }
    }

    /// Forward a conflicting op to the leader (one RPC-sized write; §4.3).
    fn forward_conflicting(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, op: OpCall, req: Requester) {
        let request_id = self.next_request_id;
        self.next_request_id += 1;
        if let Requester::Local { client, arrival } = req {
            self.pending_fwd.insert(request_id, PendingClient { client, arrival, retries: 0, op });
        }
        let leader = core.leader;
        let tok = core.token(TokenCtx::Strong(StrongToken::Forward { request_id }));
        let verb = Verb::write(
            core.landing_mem_for_peer(),
            Payload::LeaderForward { op, reply_to: core.id, request_id },
            tok,
        );
        ctx.metrics.verbs += 1;
        let start = ctx.q.now().max(core.busy_until);
        let out = ctx.net.issue(ctx.q, ctx.qps, &core.sys.fabric, start, core.id, leader, verb, true);
        core.busy_total += out.initiator_free_at - start;
        core.busy_until = out.initiator_free_at;
    }

    // ----- stand-alone Raft backend (non-Waverunner) ---------------------

    /// Promote this replica to Raft leader if it isn't one yet (election
    /// takeover, or an origin-side retry that self-elected first).
    fn ensure_raft_leader(&mut self, mb: &dyn Membership) {
        if self.raft_leader.is_none() {
            let term = self.raft_follower.term + 1;
            let next = self.raft_follower.log_len();
            self.raft_leader = Some(RaftLeader::promote(mb.live_set().len(), self.batch, term, next));
        }
    }

    /// Generic Raft leader entry: unlike Waverunner's (which replicates
    /// even locally-rejected applies to mirror §5.2), the stand-alone
    /// backend gives the leader Mu-equivalent authority — an op that fails
    /// permissibility in total-order position is rejected, not replicated;
    /// followers then apply the log unconditionally (`apply_forced`).
    fn raft_submit(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, op: OpCall, req: Requester) {
        if !core.is_leader() {
            self.forward_conflicting(core, ctx, op, req);
            return;
        }
        self.ensure_raft_leader(mb);
        if !core.plane.permissible(&op) {
            core.rejected += 1;
            self.answer_requester(core, ctx, req, false);
            return;
        }
        let cost = core.exec().op_exec_ns + core.write_state_cost(false);
        core.occupy(ctx.q.now(), cost);
        core.executions += 1;
        core.plane.apply(&op);
        let rl = self.raft_leader.as_mut().expect("just ensured");
        let (index, fanout) = rl.submit(op);
        self.raft_pending.insert(index, req);
        if let Some((term, start, ops)) = fanout {
            self.raft_fan_out(core, ctx, mb, term, start, ops);
        }
    }

    fn fan_out_round(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, g: usize, round: Round) {
        self.round_id[g] += 1;
        let rid = self.round_id[g];
        let group = g as u8;
        let peers = mb.live_peers(core.id);
        self.mu[g].round_started(peers.len() as u32);
        let use_wt = self.prop_con == PropagationMode::WriteThrough;
        // Sequential SMR: the leader is execution-busy from the previous
        // round's fan-out through this round's quorum (appendix D.1).
        let now = ctx.q.now();
        if now > core.busy_until {
            core.busy_total += now - core.busy_until;
            core.busy_until = now;
        }
        let start = ctx.q.now().max(core.busy_until);
        let mut cursor = start;
        for dst in peers {
            let tok = core.token(TokenCtx::Strong(StrongToken::Mu { group, round_id: rid }));
            // All rounds want completions: writes for quorum ACKs, reads so
            // crashed followers surface as NACKs (reads otherwise complete
            // via ReadResp).
            let verb = match round {
                Round::ReadMinProposals => Verb::read(ReadTarget::MinProposal { group }, tok),
                Round::WriteProposal { proposal } => {
                    Verb::write(core.landing_mem_for_peer(), Payload::Propose { group, proposal }, tok)
                        .on_leader_qp()
                }
                Round::ReadSlots { slot } => Verb::read(ReadTarget::LogSlot { group, slot }, tok),
                Round::WriteLog { slot, proposal, op, adopted: _ } => {
                    let payload = Payload::LogAppend { group, slot, proposal, op };
                    if use_wt {
                        Verb::rpc_write_through(payload, tok)
                    } else {
                        Verb::write(MemKind::Hbm, payload, tok).on_leader_qp()
                    }
                }
            };
            ctx.metrics.verbs += 1;
            let out = ctx.net.issue(ctx.q, ctx.qps, &core.sys.fabric, cursor, core.id, dst, verb, true);
            cursor = out.initiator_free_at;
        }
        core.busy_total += cursor - start;
        core.busy_until = cursor;
    }

    fn mu_step(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, g: usize, step: Step) {
        match step {
            Step::Wait => {}
            Step::Next(round) => {
                if let Round::WriteLog { slot, proposal, op, adopted } = round {
                    // Accept phase entry: the leader *executes* the
                    // transaction before writing followers' logs (§4.4).
                    // Its permissibility check here is authoritative — the
                    // op sits at a fixed position in the total order.
                    if !adopted && !core.plane.permissible(&op) {
                        core.rejected += 1;
                        self.mu[g].abort_current();
                        if let Some(req) = self.requesters.remove(&(op.origin, op.seq)) {
                            self.answer_requester(core, ctx, req, false);
                        }
                        let next = self.logs[g].next_free_slot();
                        if let Some(round) = self.mu[g].pump(next) {
                            self.fan_out_round(core, ctx, mb, g, round);
                        }
                        return;
                    }
                    // Execute locally unless this replica already applied
                    // the entry (e.g. it drained it from its log as a
                    // follower before winning the election).
                    if self.logs[g].applied_upto <= slot {
                        let exec_cost = core.exec().op_exec_ns + core.write_state_cost(false);
                        core.occupy(ctx.q.now(), exec_cost);
                        if adopted {
                            core.plane.apply_forced(&op);
                        } else {
                            core.plane.apply(&op);
                        }
                        core.executions += 1;
                    }
                    self.logs[g].write_slot(slot, proposal, op);
                    self.logs[g].applied_upto = self.logs[g].applied_upto.max(slot + 1);
                }
                self.fan_out_round(core, ctx, mb, g, round)
            }
            Step::Commit { slot: _, proposal: _, op, adopted: _ } => {
                // Quorum of followers acked the Accept write: committed.
                // The SMR pipeline is sequential per group — the leader is
                // execution-time-busy through the whole round (appendix
                // D.1: the leader is the longest-running replica).
                let now = ctx.q.now();
                if now > core.busy_until {
                    core.busy_total += now - core.busy_until;
                    core.busy_until = now;
                }
                ctx.metrics.smr_commits += 1;
                if let Some(req) = self.requesters.remove(&(op.origin, op.seq)) {
                    self.answer_requester(core, ctx, req, true);
                }
                // Pump the next queued conflicting op.
                let slot = self.logs[g].next_free_slot();
                if let Some(round) = self.mu[g].pump(slot) {
                    self.fan_out_round(core, ctx, mb, g, round);
                }
            }
            Step::Stall => {
                self.mu[g].reset_in_flight();
                // Retry once the heartbeat scanner refreshes the live set.
                ctx.q.push(
                    ctx.q.now() + core.heartbeat_period_ns,
                    core.id,
                    EventKind::Timer(TimerKind::SmrTick(g as u8)),
                );
            }
        }
    }

    fn answer_requester(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, req: Requester, committed: bool) {
        match req {
            Requester::Local { client, arrival } => {
                let t = core.occupy(ctx.q.now(), core.exec().client_overhead_ns / 2);
                core.complete_client(ctx, client, arrival, t);
            }
            Requester::Remote { reply_to, request_id } => {
                self.reply_remote(core, ctx, reply_to, request_id, true, committed);
            }
        }
    }

    fn reply_remote(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, reply_to: NodeId, request_id: u64, handled: bool, committed: bool) {
        let tok = core.token(TokenCtx::Ignore);
        let verb = Verb::write(
            core.landing_mem_for_peer(),
            Payload::LeaderReply { request_id, handled, committed },
            tok,
        );
        ctx.metrics.verbs += 1;
        let now = ctx.q.now().max(core.busy_until);
        ctx.net.issue(ctx.q, ctx.qps, &core.sys.fabric, now, core.id, reply_to, verb, false);
    }

    fn retry_forward(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, mut p: PendingClient) {
        p.retries += 1;
        if p.retries > 8 {
            // Give up: count as rejected so the run terminates.
            core.rejected += 1;
            let done = core.occupy(ctx.q.now(), core.exec().client_overhead_ns / 2);
            core.complete_client(ctx, p.client, p.arrival, done);
            return;
        }
        // Re-forward to the current leader view after a beat.
        let request_id = self.next_request_id;
        self.next_request_id += 1;
        self.pending_fwd.insert(request_id, p);
        let leader = mb.elect_leader();
        core.leader = leader;
        let op = p.op;
        if leader == core.id {
            let pc = self.pending_fwd.remove(&request_id).unwrap();
            self.submit_conflicting(core, ctx, mb, op, Requester::Local { client: pc.client, arrival: pc.arrival });
            return;
        }
        let tok = core.token(TokenCtx::Strong(StrongToken::Forward { request_id }));
        let verb = Verb::write(
            core.landing_mem_for_peer(),
            Payload::LeaderForward { op, reply_to: core.id, request_id },
            tok,
        );
        ctx.metrics.verbs += 1;
        let at = ctx.q.now() + core.heartbeat_period_ns;
        let at = at.max(core.busy_until);
        ctx.net.issue(ctx.q, ctx.qps, &core.sys.fabric, at, core.id, leader, verb, true);
    }

    /// Recovery: re-issue committed entries to a returned follower (§3).
    fn replay_log_to(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, peer: NodeId) {
        for g in 0..self.logs.len() {
            let entries = self.logs[g].entries_from(0);
            for (slot, e) in entries {
                let tok = core.token(TokenCtx::Ignore);
                let payload = Payload::LogAppend { group: g as u8, slot, proposal: e.proposal, op: e.op };
                let verb = if self.prop_con == PropagationMode::WriteThrough {
                    Verb::rpc_write_through(payload, tok)
                } else {
                    Verb::write(MemKind::Hbm, payload, tok).on_leader_qp()
                };
                ctx.metrics.verbs += 1;
                ctx.net.issue(ctx.q, ctx.qps, &core.sys.fabric, ctx.q.now(), core.id, peer, verb, false);
            }
        }
    }

    // ----- waverunner (Raft baseline, §5.2) ------------------------------

    fn waverunner_redirect(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, client: usize, item: WorkItem, arrival: Time) {
        // Follower rejects; client re-sends to the leader (§5.2). Modeled
        // as a forward carrying the client's retry round trip.
        let request_id = self.next_request_id;
        self.next_request_id += 1;
        self.pending_fwd.insert(request_id, PendingClient { client, arrival, retries: 0, op: item.op });
        let tok = core.token(TokenCtx::Strong(StrongToken::Forward { request_id }));
        let verb = Verb::write(
            core.landing_mem_for_peer(),
            Payload::LeaderForward { op: item.op, reply_to: core.id, request_id },
            tok,
        );
        ctx.metrics.verbs += 1;
        // Reject + client re-send penalty before the forward goes out.
        let penalty = core.exec().client_overhead_ns + core.sys.fabric.wire_ns * 2;
        let now = core.occupy(arrival, penalty);
        ctx.net.issue(ctx.q, ctx.qps, &core.sys.fabric, now, core.id, 0, verb, true);
    }

    /// Raft-leader client service: reads are local; every update goes
    /// through the replication pipeline.
    fn waverunner_serve(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, client: usize, item: WorkItem, arrival: Time) {
        let ingress = core.exec().client_overhead_ns / 2;
        let sw = core.exec().software_overhead_ns;
        let op = item.op;
        if op.is_query() || op.opcode == KV_READ {
            let cost = ingress + sw + core.warm_read_ns() + core.exec().client_overhead_ns / 2;
            let done = core.occupy(arrival, cost);
            core.complete_client(ctx, client, arrival, done);
            return;
        }
        core.occupy(arrival, ingress + sw);
        self.waverunner_submit(core, ctx, mb, op, Requester::Local { client, arrival });
    }

    fn waverunner_submit(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, op: OpCall, req: Requester) {
        if self.raft_leader.is_none() {
            // Not the Raft leader, and Waverunner models no leader election
            // (§5.2 runs fault-free; smallest-live-ID is a documented
            // shortcut that never re-homes the RaftLeader). Every stranded
            // request must still terminate — the cluster's drain flag now
            // tracks in-flight slots for real: forwarded requests bounce so
            // the origin retries (and gives up after 8 beats), local ones
            // complete as rejected.
            match req {
                Requester::Remote { reply_to, request_id } => {
                    self.reply_remote(core, ctx, reply_to, request_id, false, false);
                }
                Requester::Local { client, arrival } => {
                    core.rejected += 1;
                    let done = core.occupy(ctx.q.now(), core.exec().client_overhead_ns / 2);
                    core.complete_client(ctx, client, arrival, done);
                }
            }
            return;
        }
        // The leader applies every update (its own and forwarded ones) at
        // submit; followers apply from the replicated log.
        let cost = core.exec().op_exec_ns + core.write_state_cost(false);
        core.occupy(ctx.q.now(), cost);
        core.executions += 1;
        core.plane.apply(&op);
        let rl = self.raft_leader.as_mut().unwrap();
        let (index, fanout) = rl.submit(op);
        self.raft_pending.insert(index, req);
        if let Some((term, start, ops)) = fanout {
            self.raft_fan_out(core, ctx, mb, term, start, ops);
        }
    }

    /// Follower-side apply after an accepted AppendEntries. Waverunner
    /// replays the leader's raw op stream (its leader replicates even
    /// locally-rejected applies, so followers re-run the same `apply`
    /// decisions); the stand-alone backend ships only leader-accepted ops,
    /// which followers execute unconditionally like Mu's log drain.
    fn raft_follower_apply(&mut self, core: &mut ReplicaCore) {
        let forced = core.system != SystemKind::Waverunner;
        for o in self.raft_follower.drain_apply() {
            if forced {
                core.executions += 1;
                core.plane.apply_forced(&o);
            } else {
                core.apply_remote(&o);
            }
        }
    }

    fn raft_ack(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, src: NodeId, term: u64, index: u64) {
        let tok = core.token(TokenCtx::Ignore);
        let ack = Verb::write(
            core.landing_mem_for_peer(),
            Payload::RaftAck { term, index, from: core.id },
            tok,
        );
        ctx.metrics.verbs += 1;
        ctx.net.issue(ctx.q, ctx.qps, &core.sys.fabric, ctx.q.now(), core.id, src, ack, false);
    }

    fn raft_fan_out(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, term: u64, start: u64, ops: Vec<OpCall>) {
        // The logical ack is the RaftAck verb, not a wire completion.
        let peers = mb.live_peers(core.id);
        let mem = if core.system == SystemKind::Waverunner {
            MemKind::HostDram // SmartNIC fast path still lands in host state
        } else {
            core.landing_mem_for_peer()
        };
        if ops.len() == 1 {
            let op = ops[0];
            core.fan_out(
                ctx,
                &peers,
                |t| Verb::write(mem, Payload::RaftAppend { term, index: start, op }, t),
                false,
                || TokenCtx::Ignore,
            );
        } else {
            // Leader-side log-entry batching: one AppendEntries wire verb
            // carries the whole contiguous run.
            ctx.metrics.coalesced += ops.len() as u64 - 1;
            core.fan_out(
                ctx,
                &peers,
                |t| {
                    Verb::write(
                        mem,
                        Payload::RaftAppendBatch { term, start_index: start, ops: ops.clone() },
                        t,
                    )
                },
                false,
                || TokenCtx::Ignore,
            );
        }
    }
}

impl ReplicationPath for StrongPath {
    fn boot(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, base: u64) {
        // Log pollers are a Mu follower concern; Raft followers apply at
        // delivery (the SmartNIC interrupt path), so they arm nothing.
        if self.backend == ConsensusBackend::Mu
            && self.prop_con != PropagationMode::WriteThrough
            && !self.logs.is_empty()
        {
            for g in 0..self.logs.len() {
                ctx.q.push(
                    base + core.poll_interval_ns + g as u64,
                    core.id,
                    EventKind::Timer(TimerKind::PollLog(g as u8)),
                );
            }
        }
    }

    fn refresh_cost(&mut self, core: &mut ReplicaCore) -> u64 {
        let mut cost = 0;
        // Conflicting log check (§4.3 config 1: "polling the log when the
        // state is accessed to ensure the most up to date data") — a Mu
        // structure; Raft followers are already current at delivery.
        if self.backend == ConsensusBackend::Mu && self.prop_con != PropagationMode::WriteThrough {
            let per_group = core.sys.mem.local_read_ns(core.landing_mem());
            cost += per_group * self.logs.len() as u64;
            cost += self.drain_logs_cost(core);
        }
        cost
    }

    fn handle_client(
        &mut self,
        core: &mut ReplicaCore,
        ctx: &mut Ctx,
        mb: &dyn Membership,
        client: usize,
        item: WorkItem,
        arrival: Time,
    ) -> bool {
        // Waverunner: only the leader serves clients (§5.2); every update
        // replicates through Raft regardless of RDT category (no hybrid
        // consistency — that is the point of the Fig 12 comparison).
        if core.system != SystemKind::Waverunner {
            return false;
        }
        if self.raft_leader.is_none() {
            self.waverunner_redirect(core, ctx, client, item, arrival);
        } else {
            self.waverunner_serve(core, ctx, mb, client, item, arrival);
        }
        true
    }

    fn submit(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, sub: Submission) {
        let _t = core.occupy(sub.arrival, sub.cost);
        self.submit_conflicting(core, ctx, mb, sub.op, Requester::Local { client: sub.client, arrival: sub.arrival });
    }

    fn deliver(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, src: NodeId, verb: Verb) {
        let is_rpc = matches!(verb.kind, crate::net::verbs::VerbKind::Rpc | crate::net::verbs::VerbKind::RpcWriteThrough);
        match verb.payload {
            Payload::Propose { group, proposal } => {
                self.logs[group as usize].bump_min_proposal(proposal);
            }
            Payload::LogAppend { group, slot, proposal, op } => {
                let g = group as usize;
                self.logs[g].write_slot(slot, proposal, op);
                if is_rpc {
                    // Write-through: follower state updated directly from
                    // the network (§4.4 "at L"); log is already appended.
                    let cost = core.exec().op_exec_ns + core.sys.mem.local_write_ns(MemKind::Bram);
                    core.occupy(ctx.q.now(), cost);
                    for e in self.logs[g].drain_unapplied() {
                        core.executions += 1;
                        core.plane.apply_forced(&e.op);
                    }
                }
            }
            Payload::LeaderForward { op, reply_to, request_id } => {
                if core.system == SystemKind::Waverunner {
                    // Redirected client request reaching the Raft leader.
                    let sw = core.exec().software_overhead_ns;
                    core.occupy(ctx.q.now(), sw);
                    if op.is_query() || op.opcode == KV_READ {
                        let cost = core.warm_read_ns() + core.exec().client_overhead_ns / 2;
                        core.occupy(ctx.q.now(), cost);
                        self.reply_remote(core, ctx, reply_to, request_id, true, true);
                    } else {
                        self.waverunner_submit(core, ctx, mb, op, Requester::Remote { reply_to, request_id });
                    }
                } else if core.is_leader() {
                    let sw = core.exec().software_overhead_ns;
                    core.occupy(ctx.q.now(), sw);
                    // Leader re-checks permissibility in total order context.
                    self.submit_conflicting(core, ctx, mb, op, Requester::Remote { reply_to, request_id });
                } else {
                    // Not the leader (stale forward): bounce.
                    self.reply_remote(core, ctx, reply_to, request_id, false, false);
                }
            }
            Payload::LeaderReply { request_id, handled, committed } => {
                if let Some(p) = self.pending_fwd.remove(&request_id) {
                    if handled {
                        if !committed {
                            core.rejected += 1;
                        }
                        let done = core.occupy(ctx.q.now(), core.exec().client_overhead_ns / 2);
                        core.complete_client(ctx, p.client, p.arrival, done);
                    } else {
                        self.retry_forward(core, ctx, mb, p);
                    }
                }
            }
            Payload::RaftAppend { term, index, op } => {
                if self.raft_follower.on_append(term, index, op) {
                    self.raft_follower_apply(core);
                    self.raft_ack(core, ctx, src, term, index);
                }
            }
            Payload::RaftAppendBatch { term, start_index, ops } => {
                if self.raft_follower.on_append_batch(term, start_index, &ops) {
                    self.raft_follower_apply(core);
                    // One ack for the whole batch, on its last index.
                    self.raft_ack(core, ctx, src, term, start_index + ops.len() as u64 - 1);
                }
            }
            Payload::RaftAck { term, index, .. } => {
                if let Some(rl) = self.raft_leader.as_mut() {
                    if let RaftStep::Commit { start_index, ops } = rl.on_ack(term, index) {
                        // Leader state was updated at submit; commit point
                        // is the quorum ack.
                        let done = core.occupy(ctx.q.now(), core.exec().op_exec_ns);
                        ctx.metrics.smr_commits += ops.len() as u64;
                        for i in 0..ops.len() as u64 {
                            if let Some(req) = self.raft_pending.remove(&(start_index + i)) {
                                match req {
                                    Requester::Local { client, arrival } => {
                                        let t = core.occupy(done, core.exec().client_overhead_ns / 2);
                                        core.complete_client(ctx, client, arrival, t);
                                    }
                                    Requester::Remote { reply_to, request_id } => {
                                        self.reply_remote(core, ctx, reply_to, request_id, true, true);
                                    }
                                }
                            }
                        }
                        if let Some((term, start, ops)) = self.raft_leader.as_mut().unwrap().pump() {
                            self.raft_fan_out(core, ctx, mb, term, start, ops);
                        }
                    }
                }
            }
            _ => {}
        }
    }

    fn on_completion(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, token: TokenCtx, ok: bool) {
        let TokenCtx::Strong(token) = token else { return };
        match token {
            StrongToken::Mu { group, round_id } => {
                let g = group as usize;
                if round_id != self.round_id[g] {
                    return; // stale round
                }
                let step = self.mu[g].on_response(if ok { Resp::Ack } else { Resp::Failure });
                self.mu_step(core, ctx, mb, g, step);
            }
            StrongToken::Forward { request_id } => {
                if !ok {
                    if let Some(p) = self.pending_fwd.remove(&request_id) {
                        self.retry_forward(core, ctx, mb, p);
                    }
                }
            }
        }
    }

    fn on_read_resp(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, token: TokenCtx, data: ReadData) {
        // Only Mu rounds read remote state; Forward tokens ride writes.
        let TokenCtx::Strong(StrongToken::Mu { group, round_id }) = token else { return };
        let g = group as usize;
        if round_id != self.round_id[g] {
            return; // stale round
        }
        let resp = match data {
            ReadData::MinProposal(p) => Resp::MinProposal(p),
            ReadData::LogSlot(s) => Resp::Slot(s),
            _ => Resp::Ack,
        };
        let step = self.mu[g].on_response(resp);
        self.mu_step(core, ctx, mb, g, step);
    }

    fn on_timer(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, t: TimerKind) {
        match t {
            TimerKind::PollLog(_g) => {
                let cost = core.exec().poll_tick_ns + self.drain_logs_cost(core);
                core.occupy(ctx.q.now(), cost);
                if !ctx.draining {
                    ctx.q.push(ctx.q.now() + core.poll_interval_ns, core.id, EventKind::Timer(t));
                }
            }
            TimerKind::SmrTick(g) => {
                let g = g as usize;
                if core.is_leader() {
                    self.mu[g].set_cluster_size(mb.live_set().len());
                    let slot = self.logs[g].next_free_slot();
                    if let Some(round) = self.mu[g].pump(slot) {
                        self.fan_out_round(core, ctx, mb, g, round);
                    }
                }
            }
            _ => {}
        }
    }

    fn serve_read(&self, target: ReadTarget) -> Option<ReadData> {
        match target {
            ReadTarget::MinProposal { group } => {
                Some(ReadData::MinProposal(self.logs[group as usize].min_proposal))
            }
            ReadTarget::LogSlot { group, slot } => Some(ReadData::LogSlot(
                self.logs[group as usize].read_slot(slot).map(|e| (e.proposal, e.op)),
            )),
            _ => None,
        }
    }

    fn on_membership(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, ev: MembershipEvent) {
        match ev {
            MembershipEvent::PeerFailed { peer: _ } => {
                // Leader trims its follower list (background on SafarDB,
                // foreground cost charged by the failure plane for Hamband).
                for g in 0..self.mu.len() {
                    self.mu[g].set_cluster_size(mb.live_set().len());
                }
                if let Some(rl) = self.raft_leader.as_mut() {
                    rl.set_cluster_size(mb.live_set().len());
                }
            }
            MembershipEvent::PeerRecovered { peer } => {
                self.replay_log_to(core, ctx, peer);
                for g in 0..self.mu.len() {
                    self.mu[g].set_cluster_size(mb.live_set().len());
                }
                if let Some(rl) = self.raft_leader.as_mut() {
                    rl.set_cluster_size(mb.live_set().len());
                }
            }
            MembershipEvent::LeaderSwitched => {
                if core.is_leader() {
                    ctx.metrics.elections += 1;
                    if self.backend == ConsensusBackend::Raft {
                        // Stand-alone Raft takeover: adopt the accepted log
                        // at a higher term and re-replicate it (followers
                        // overwrite-accept higher terms; idempotent).
                        if core.system != SystemKind::Waverunner && self.raft_leader.is_none() {
                            self.ensure_raft_leader(mb);
                            let term = self.raft_leader.as_ref().expect("promoted").term;
                            let entries: Vec<OpCall> = self.raft_follower.entries().to_vec();
                            // Replay in batch_size chunks: the election-time
                            // log re-ship coalesces like any other append.
                            let step = self.batch.max(1);
                            let mut start = 0usize;
                            while start < entries.len() {
                                let end = (start + step).min(entries.len());
                                self.raft_fan_out(
                                    core,
                                    ctx,
                                    mb,
                                    term,
                                    start as u64,
                                    entries[start..end].to_vec(),
                                );
                                start = end;
                            }
                        }
                    } else {
                        // Take over: re-replicate our log suffix first — the
                        // crashed leader may have written an Accept to only a
                        // subset of followers (including us), and Mu's
                        // slot-adoption only repairs slots we later propose
                        // into. Idempotent: followers reject equal/lower
                        // proposals and skip already-applied slots.
                        let peers = mb.live_peers(core.id);
                        for peer in peers {
                            self.replay_log_to(core, ctx, peer);
                        }
                        for g in 0..self.mu.len() {
                            self.mu[g].set_cluster_size(mb.live_set().len());
                            let slot = self.logs[g].next_free_slot();
                            if let Some(round) = self.mu[g].pump(slot) {
                                self.fan_out_round(core, ctx, mb, g, round);
                            }
                        }
                    }
                }
                // Any of our forwards pending at the dead leader: retry now.
                let pending: Vec<(u64, PendingClient)> = self.pending_fwd.drain().collect();
                for (_, p) in pending {
                    self.retry_forward(core, ctx, mb, p);
                }
            }
        }
    }

    fn flush_pending(&mut self, plane: &mut DataPlane) {
        for g in 0..self.logs.len() {
            for e in self.logs[g].drain_unapplied() {
                plane.apply_forced(&e.op);
            }
        }
    }

    fn snapshot_logs(&self) -> Vec<ReplicationLog> {
        self.logs.clone()
    }

    fn install_logs(&mut self, logs: Vec<ReplicationLog>) {
        self.logs = logs;
    }

    fn debug_status(&self) -> String {
        let mu_q: usize = self.mu.iter().map(|m| m.queue_len()).sum();
        let mu_idle: Vec<bool> = self.mu.iter().map(|m| m.is_idle()).collect();
        format!(
            "pending_fwd={} requesters={} raft_pending={} mu_q={} mu_idle={:?}",
            self.pending_fwd.len(),
            self.requesters.len(),
            self.raft_pending.len(),
            mu_q,
            mu_idle
        )
    }
}
