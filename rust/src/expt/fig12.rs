//! Fig 12: SafarDB vs Waverunner on YCSB, three nodes, across PUT/GET
//! ratios.
//!
//! Expected shape: SafarDB ≈25× lower RT / ≈31× higher throughput — the
//! Waverunner app lives in host software behind the SmartNIC, only its
//! leader serves clients (follower requests bounce), and every PUT takes a
//! full Raft round.

use crate::config::{SimConfig, WorkloadKind};
use crate::expt::common::{cell_ops, f3, run_cells_tagged};
use crate::util::table::Table;

const PUT_RATIOS: &[u8] = &[5, 25, 50, 75, 95];

pub fn run(quick: bool) -> Vec<Table> {
    let mut t = Table::new(
        "Fig 12 — YCSB on 3 nodes: SafarDB vs Waverunner",
        &["system", "put%", "rt_us", "tput_ops_us"],
    );
    let mut jobs = Vec::new();
    for system in ["SafarDB", "Waverunner"] {
        for &put in PUT_RATIOS {
            let mut cfg = match system {
                "SafarDB" => {
                    let mut c = SimConfig::safardb(WorkloadKind::Ycsb);
                    c.n_replicas = 3;
                    c
                }
                _ => SimConfig::waverunner(WorkloadKind::Ycsb),
            };
            cfg.update_pct = put;
            jobs.push(((system, put), (cfg, cell_ops(quick))));
        }
    }
    for ((system, put), cell, _) in run_cells_tagged(jobs) {
        t.row(vec![system.into(), put.to_string(), f3(cell.rt_us), f3(cell.tput)]);
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expt::common::geomean_ratio;

    #[test]
    fn safardb_dominates_waverunner() {
        let t = &run(true)[0];
        let series = |sys: &str, col: usize| -> Vec<f64> {
            t.rows().iter().filter(|r| r[0] == sys).map(|r| r[col].parse().unwrap()).collect()
        };
        let rt = geomean_ratio(&series("Waverunner", 2), &series("SafarDB", 2));
        let tp = geomean_ratio(&series("SafarDB", 3), &series("Waverunner", 3));
        assert!(rt > 3.0, "rt ratio {rt} (paper 25.5x)");
        assert!(tp > 3.0, "tput ratio {tp} (paper 31.3x)");
    }
}
