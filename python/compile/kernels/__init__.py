"""Layer-1 Pallas kernels for SafarDB's batch replication engine.

Each kernel is the TPU-shaped analogue of one of the paper's FPGA
"user kernel" fixed-function accelerators (DESIGN.md §Hardware-Adaptation):

  pn_merge          — G/PN-Counter contribution fold  (Fig 4a, summarization)
  lww_merge         — LWW-Register last-writer fold    (Table A.1)
  set_or            — G-Set/2P-Set bitmap fold         (Table A.1)
  permissibility    — Account batch overdraft scan     (Table B.1 invariant)
  batch_apply       — KV scatter-add burst (YCSB/SmallBank hot path, Fig 11)

All kernels run with interpret=True: CPU PJRT cannot execute Mosaic
custom-calls, so interpret-mode lowering (plain HLO) is the correctness and
interchange path; TPU efficiency is argued structurally in DESIGN.md §Perf.
"""

from .pn_merge import pn_merge
from .lww_merge import lww_merge
from .set_or import set_or
from .permissibility import account_permissibility
from .batch_apply import batch_apply

__all__ = [
    "pn_merge",
    "lww_merge",
    "set_or",
    "account_permissibility",
    "batch_apply",
]
