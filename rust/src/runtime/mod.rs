//! Kernel runtime: executes the exported batch kernels that the paper's
//! FPGA-resident accelerators implement (Fig 1's Dispatcher targets).
//!
//! The seed drove AOT-compiled Pallas/JAX artifacts through PJRT; the
//! offline crate set has no `xla` (or `anyhow`) bindings, so the executor
//! is now a **std-only reference implementation** whose per-kernel
//! semantics mirror `python/compile/kernels` exactly (pinned by the
//! `runtime_kernels` integration tests against the scalar engine). The
//! artifact manifest written by `python -m compile.aot` is still parsed and
//! used for call-site type checking when present.
//!
//! * [`artifacts`] — manifest parsing + builtin export signatures.
//! * [`exec`] — the signature-checked executor (load once, execute many).
//! * [`accel`] — typed batch operators with padding to the fixed AOT export
//!   shapes (N=8 replicas, K=1024 keys, B=256 burst, W=512 words).
//! * [`error`] — minimal context-chaining error type (no `anyhow` offline).

pub mod accel;
pub mod artifacts;
pub mod error;
pub mod exec;

pub use accel::Accelerator;
pub use artifacts::{Manifest, Signature};
pub use error::{Context, Error, Result};
pub use exec::{Literal, Runtime};

/// Default artifact directory relative to the repo root.
pub const DEFAULT_ARTIFACTS: &str = "artifacts";
