//! Auction WRDT (Table B.1): RUBiS-style e-commerce site.
//!
//! State: users U, auctions A, items I, stock array S[].
//! * sellItem(i, u)   — reducible (lists item, bumps stock; summable).
//! * openAuction(a)   — irreducible, a ∉ A.
//! * registerUser(u)  — conflicting (group 0), u ∉ U.
//! * buyItem(i, u)    — conflicting (group 1), i ∈ I ∧ `S[i]` ≥ 1 ∧ u ∈ U.
//! * placeBid(a,b,u)  — conflicting (group 2), a ∈ A ∧ u ∈ U.
//! * closeAuction(a)  — conflicting (group 2), a ∈ A.
//!
//! Three synchronization groups (Table B.1) — the most of any benchmark,
//! which is why Auction is the Fig 8 conflicting-transaction stress case:
//! three replication logs mean three polling targets for the baseline.
//! Invariant: stock never negative; bids only on open auctions by
//! registered users.

use std::collections::{HashMap, HashSet};

use crate::rdt::{mix64, Category, OpCall, QueryValue, Rdt, RdtKind};
use crate::util::rng::Rng;

pub const OP_SELL_ITEM: u8 = 0;
pub const OP_OPEN_AUCTION: u8 = 1;
pub const OP_REGISTER_USER: u8 = 2;
pub const OP_BUY_ITEM: u8 = 3;
pub const OP_PLACE_BID: u8 = 4;
pub const OP_CLOSE_AUCTION: u8 = 5;

pub const GROUP_USER: u8 = 0;
pub const GROUP_ITEM: u8 = 1;
pub const GROUP_AUCTION: u8 = 2;

const ID_UNIVERSE: u64 = 512;

#[derive(Clone, Debug, Default)]
pub struct Auction {
    users: HashSet<u64>,
    auctions: HashSet<u64>,
    closed: HashSet<u64>,
    items: HashSet<u64>,
    stock: HashMap<u64, i64>,
    bids: HashMap<u64, (u64, u64)>, // auction -> (best bid, user)
}

impl Auction {
    pub fn stock_of(&self, item: u64) -> i64 {
        self.stock.get(&item).copied().unwrap_or(0)
    }
}

impl Rdt for Auction {
    fn clone_box(&self) -> Box<dyn Rdt> {
        Box::new(self.clone())
    }

    fn kind(&self) -> RdtKind {
        RdtKind::Auction
    }

    fn category(&self, opcode: u8) -> Category {
        match opcode {
            OP_SELL_ITEM => Category::Reducible,
            OP_OPEN_AUCTION => Category::Irreducible,
            OP_REGISTER_USER | OP_BUY_ITEM | OP_PLACE_BID | OP_CLOSE_AUCTION => {
                Category::Conflicting
            }
            _ => Category::Reducible,
        }
    }

    fn sync_group(&self, opcode: u8) -> u8 {
        match opcode {
            OP_REGISTER_USER => GROUP_USER,
            OP_BUY_ITEM => GROUP_ITEM,
            _ => GROUP_AUCTION,
        }
    }

    fn sync_groups(&self) -> u8 {
        3
    }

    fn permissible(&self, op: &OpCall) -> bool {
        match op.opcode {
            OP_SELL_ITEM => true,
            OP_OPEN_AUCTION => !self.auctions.contains(&op.a),
            OP_REGISTER_USER => !self.users.contains(&op.a),
            OP_BUY_ITEM => {
                self.items.contains(&op.a) && self.stock_of(op.a) >= 1 && self.users.contains(&op.b)
            }
            OP_PLACE_BID => {
                self.auctions.contains(&op.a)
                    && !self.closed.contains(&op.a)
                    && self.users.contains(&op.b)
            }
            OP_CLOSE_AUCTION => self.auctions.contains(&op.a) && !self.closed.contains(&op.a),
            _ => op.is_query(),
        }
    }

    fn apply(&mut self, op: &OpCall) -> bool {
        match op.opcode {
            OP_SELL_ITEM => {
                self.items.insert(op.a);
                *self.stock.entry(op.a).or_insert(0) += 1;
                true
            }
            OP_OPEN_AUCTION => self.auctions.insert(op.a),
            OP_REGISTER_USER => self.users.insert(op.a),
            OP_BUY_ITEM => {
                if self.items.contains(&op.a)
                    && self.stock_of(op.a) >= 1
                    && self.users.contains(&op.b)
                {
                    *self.stock.get_mut(&op.a).unwrap() -= 1;
                    true
                } else {
                    false
                }
            }
            OP_PLACE_BID => {
                if self.auctions.contains(&op.a)
                    && !self.closed.contains(&op.a)
                    && self.users.contains(&op.b)
                {
                    let bid = op.x as u64;
                    let best = self.bids.entry(op.a).or_insert((0, 0));
                    if bid > best.0 {
                        *best = (bid, op.b);
                    }
                    true
                } else {
                    false
                }
            }
            OP_CLOSE_AUCTION => {
                if self.auctions.contains(&op.a) {
                    self.closed.insert(op.a)
                } else {
                    false
                }
            }
            _ => unreachable!("auction opcode {}", op.opcode),
        }
    }

    fn apply_forced(&mut self, op: &OpCall) -> bool {
        match op.opcode {
            OP_BUY_ITEM => {
                // Sell (reducible) may still be in flight at this replica.
                *self.stock.entry(op.a).or_insert(0) -= 1;
                true
            }
            OP_PLACE_BID => {
                let bid = op.x as u64;
                let best = self.bids.entry(op.a).or_insert((0, 0));
                if bid > best.0 {
                    *best = (bid, op.b);
                }
                true
            }
            OP_CLOSE_AUCTION => self.closed.insert(op.a),
            _ => self.apply(op),
        }
    }

    fn query(&self) -> QueryValue {
        QueryValue::Pair(self.users.len() as i64, self.items.len() as i64)
    }

    fn state_digest(&self) -> u64 {
        let du = self.users.iter().fold(0u64, |a, &e| a ^ mix64(e));
        let da = self.auctions.iter().fold(0u64, |a, &e| a ^ mix64(e | 1 << 59));
        let dc = self.closed.iter().fold(0u64, |a, &e| a ^ mix64(e | 1 << 58));
        let di = self
            .stock
            .iter()
            .filter(|(_, &v)| v != 0)
            .fold(0u64, |a, (&i, &v)| a ^ mix64(i).wrapping_mul(mix64(v as u64) | 1));
        let db = self
            .bids
            .iter()
            .fold(0u64, |a, (&k, &(b, u))| a ^ mix64(k ^ (b << 20) ^ (u << 40)));
        du ^ da.rotate_left(5) ^ dc.rotate_left(23) ^ di.rotate_left(37) ^ db.rotate_left(49)
    }

    fn invariant_ok(&self) -> bool {
        self.stock.values().all(|&v| v >= 0)
            && self
                .bids
                .keys()
                .all(|a| self.auctions.contains(a))
    }

    fn debug_dump(&self) -> String {
        let mut u: Vec<_> = self.users.iter().collect();
        u.sort();
        let mut a: Vec<_> = self.auctions.iter().collect();
        a.sort();
        let mut c: Vec<_> = self.closed.iter().collect();
        c.sort();
        let mut st: Vec<_> = self.stock.iter().filter(|(_, &v)| v != 0).collect();
        st.sort();
        let mut b: Vec<_> = self.bids.iter().collect();
        b.sort();
        format!("users={u:?}\nauctions={a:?}\nclosed={c:?}\nstock={st:?}\nbids={b:?}")
    }

    fn gen_update(&self, rng: &mut Rng) -> OpCall {
        match rng.gen_range(6) {
            0 => OpCall::new(OP_SELL_ITEM, rng.gen_range(ID_UNIVERSE), rng.gen_range(ID_UNIVERSE), 0.0),
            1 => OpCall::new(OP_OPEN_AUCTION, rng.gen_range(ID_UNIVERSE), 0, 0.0),
            2 => OpCall::new(OP_REGISTER_USER, rng.gen_range(ID_UNIVERSE), 0, 0.0),
            3 => OpCall::new(OP_BUY_ITEM, rng.gen_range(ID_UNIVERSE), rng.gen_range(ID_UNIVERSE), 0.0),
            4 => OpCall::new(
                OP_PLACE_BID,
                rng.gen_range(ID_UNIVERSE),
                rng.gen_range(ID_UNIVERSE),
                rng.gen_f64_range(1.0, 1000.0),
            ),
            _ => OpCall::new(OP_CLOSE_AUCTION, rng.gen_range(ID_UNIVERSE), 0, 0.0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn op(opcode: u8, a: u64, b: u64, x: f64) -> OpCall {
        OpCall::new(opcode, a, b, x)
    }

    #[test]
    fn three_sync_groups() {
        let a = Auction::default();
        assert_eq!(a.sync_group(OP_REGISTER_USER), GROUP_USER);
        assert_eq!(a.sync_group(OP_BUY_ITEM), GROUP_ITEM);
        assert_eq!(a.sync_group(OP_PLACE_BID), GROUP_AUCTION);
        assert_eq!(a.sync_group(OP_CLOSE_AUCTION), GROUP_AUCTION);
        assert_eq!(a.sync_groups(), 3);
    }

    #[test]
    fn buy_needs_stock_and_user() {
        let mut a = Auction::default();
        a.apply(&op(OP_REGISTER_USER, 9, 0, 0.0));
        assert!(!a.permissible(&op(OP_BUY_ITEM, 1, 9, 0.0)), "no item listed");
        a.apply(&op(OP_SELL_ITEM, 1, 9, 0.0));
        assert!(a.apply(&op(OP_BUY_ITEM, 1, 9, 0.0)));
        assert_eq!(a.stock_of(1), 0);
        assert!(!a.permissible(&op(OP_BUY_ITEM, 1, 9, 0.0)), "stock exhausted");
        assert!(a.invariant_ok());
    }

    #[test]
    fn bids_only_on_open_auctions() {
        let mut a = Auction::default();
        a.apply(&op(OP_REGISTER_USER, 5, 0, 0.0));
        a.apply(&op(OP_OPEN_AUCTION, 1, 0, 0.0));
        assert!(a.apply(&op(OP_PLACE_BID, 1, 5, 100.0)));
        a.apply(&op(OP_CLOSE_AUCTION, 1, 0, 0.0));
        assert!(!a.permissible(&op(OP_PLACE_BID, 1, 5, 200.0)));
    }

    #[test]
    fn best_bid_is_max() {
        let mut a = Auction::default();
        a.apply(&op(OP_REGISTER_USER, 5, 0, 0.0));
        a.apply(&op(OP_REGISTER_USER, 6, 0, 0.0));
        a.apply(&op(OP_OPEN_AUCTION, 1, 0, 0.0));
        a.apply(&op(OP_PLACE_BID, 1, 5, 100.0));
        a.apply(&op(OP_PLACE_BID, 1, 6, 50.0));
        assert_eq!(a.bids[&1], (100, 5));
    }

    #[test]
    fn sell_items_commute() {
        let ops = [op(OP_SELL_ITEM, 1, 0, 0.0), op(OP_SELL_ITEM, 2, 0, 0.0), op(OP_SELL_ITEM, 1, 0, 0.0)];
        let mut a = Auction::default();
        let mut b = Auction::default();
        for o in &ops {
            a.apply(o);
        }
        for o in ops.iter().rev() {
            b.apply(o);
        }
        assert_eq!(a.state_digest(), b.state_digest());
        assert_eq!(a.stock_of(1), 2);
    }
}
