//! Discrete-event simulation core: a virtual nanosecond clock and a
//! deterministic event queue.
//!
//! Everything time-shaped in SafarDB's reproduction flows through here —
//! verb deliveries, ACKs, background pollers, heartbeat scans, crash
//! injections, and closed-loop client arrivals. Determinism: events are
//! totally ordered by `(time, seq)` where `seq` is the global push order,
//! so equal-time events fire in FIFO order and runs are bit-reproducible
//! from the config seed.
//!
//! The queue is a calendar queue (Brown 1988): a ring of time-bucketed
//! lanes whose width adapts to the event population, giving O(1) expected
//! push/pop against the binary heap's O(log n) — the event loop is the
//! whole engine, so this is the §Perf hot path. Any correct min-queue pops
//! the *same* sequence because `(time, seq)` is a total order; the
//! `matches_reference_heap` test holds the calendar to that contract.

use crate::net::verbs::Verb;

/// Virtual time in nanoseconds.
pub type Time = u64;

/// Replica index (0-based).
pub type NodeId = usize;

/// Background timers a replica can arm.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TimerKind {
    /// §4.1 config (2): poll HBM to refresh the on-fabric copy of the
    /// contribution array.
    PollReducible,
    /// §4.2 config (1): poll the per-origin FIFO queues.
    PollIrreducible,
    /// §4.3 config (1): poll the replication log of one sync group.
    PollLog(u8),
    /// Summarization flush deadline (§5.4 Summarization).
    SummarizeFlush,
    /// Per-path batching: drain the relaxed plane's fan-out coalescer so a
    /// partially filled batch never stalls propagation.
    BatchFlush,
    /// Leader-switch plane: heartbeat scanner tick (§4.4).
    HeartbeatScan,
    /// Retry driving the SMR pipeline (leader waiting for quorum timeout).
    SmrTick(u8),
    /// Chaos-mode watchdog on a forwarded conflicting op: if the leader's
    /// reply was lost on a faulty link, re-forward (at-least-once).
    ForwardCheck { request_id: u64 },
    /// Generic continuation: replica finished a locally-serialized work
    /// item and should pick up the next queued one.
    WorkDone,
}

/// Fabric-level fault actions (chaos schedules). These ride the event
/// queue like everything else — so multi-fault scenarios replay
/// deterministically from the config seed — but are consumed by the
/// *cluster's* network actor when popped; the event's `dest` is unused.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NetFault {
    /// Cut the a <-> b link in both directions (NACK-on-partition).
    Partition { a: NodeId, b: NodeId },
    /// Repair every cut link (triggers leader anti-entropy replay).
    Heal,
    /// Silently lose the next `count` verbs on the directed src -> dst link.
    DropNext { src: NodeId, dst: NodeId, count: u32 },
    /// Scale the directed src -> dst one-way latency by `factor_pct`/100.
    DelaySpike { src: NodeId, dst: NodeId, factor_pct: u32 },
    /// End of a delay spike window (armed by the spike's `until_pct`).
    DelayRestore { src: NodeId, dst: NodeId },
}

/// Event payloads.
#[derive(Clone, Debug)]
pub enum EventKind {
    /// A closed-loop client slot at this replica wants to issue its next
    /// op. The open loop reuses the same event as its "service slot freed"
    /// signal: on completion the slot pulls the oldest queued admission.
    ClientArrive { client: usize },
    /// Open-loop aggregate arrival-stream tick at this replica: one offered
    /// op arrives, and the stream re-arms itself with the next seeded
    /// inter-arrival gap while un-offered quota remains. `epoch` guards
    /// against stale ticks: a crash kills the node's stream (epoch bump),
    /// so a tick scheduled before the crash can never double the stream a
    /// post-recovery quota grant re-arms.
    Arrival { epoch: u32 },
    /// A verb arrives at this node's NIC (payload lands per its dst_mem).
    VerbDeliver { src: NodeId, verb: Verb },
    /// Completion (CQE/ACK) for a verb this node issued earlier.
    AckDeliver { token: u64 },
    /// Negative completion: QP closed at target, target crashed, link
    /// partitioned, or the verb was dropped by fault injection.
    NackDeliver { token: u64 },
    /// A background timer fired.
    Timer(TimerKind),
    /// Fault injection: node crash / recovery (delivered to the node).
    Crash,
    Recover,
    /// Fault injection: link-level action (handled by the cluster).
    Fault(NetFault),
}

#[derive(Clone, Debug)]
pub struct Event {
    pub time: Time,
    pub seq: u64,
    pub dest: NodeId,
    pub kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.time, self.seq).cmp(&(other.time, other.seq))
    }
}

/// Deterministic min-queue of events: a calendar queue.
///
/// Buckets form a ring over virtual time — bucket `i` of a "year" covers
/// `[i·width, (i+1)·width)` modulo the year length `nbuckets·width`. Each
/// bucket keeps its events sorted descending by `(time, seq)` so the
/// minimum is a `Vec::pop` off the tail; `pop` walks the ring from the
/// cursor, taking any event that falls inside the cursor bucket's current
/// year window, and falls back to a direct min-scan after one fruitless
/// lap (the population is sparse relative to the year). The ring doubles /
/// halves and re-derives its width from the live event span whenever the
/// population outgrows or abandons it, keeping expected bucket occupancy
/// O(1).
#[derive(Debug)]
pub struct EventQueue {
    /// Ring of lanes, each sorted descending by `(time, seq)` (min at the
    /// tail).
    buckets: Vec<Vec<Event>>,
    /// Ring size; always a power of two so the index mask is a single AND.
    nbuckets: u64,
    /// Nanoseconds of virtual time each bucket covers.
    width: u64,
    /// Ring cursor: the bucket the pop scan resumes from.
    cursor: u64,
    /// Exclusive upper time bound of the cursor bucket's current window.
    bucket_top: u64,
    count: usize,
    seq: u64,
    now: Time,
    pushed: u64,
    popped: u64,
}

impl Default for EventQueue {
    fn default() -> Self {
        Self::new()
    }
}

const MIN_BUCKETS: u64 = 8;
const INITIAL_WIDTH: u64 = 1_024;

impl EventQueue {
    pub fn new() -> Self {
        EventQueue {
            buckets: (0..MIN_BUCKETS).map(|_| Vec::new()).collect(),
            nbuckets: MIN_BUCKETS,
            width: INITIAL_WIDTH,
            cursor: 0,
            bucket_top: INITIAL_WIDTH,
            count: 0,
            seq: 0,
            now: 0,
            pushed: 0,
            popped: 0,
        }
    }

    pub fn now(&self) -> Time {
        self.now
    }

    #[inline]
    fn bucket_of(&self, time: Time) -> usize {
        ((time / self.width) & (self.nbuckets - 1)) as usize
    }

    /// Insert keeping the lane sorted descending by `(time, seq)` — the
    /// lane minimum stays at the tail. Keys are unique (`seq` is global),
    /// so the partition point is unambiguous.
    #[inline]
    fn insert_sorted(bucket: &mut Vec<Event>, ev: Event) {
        let key = (ev.time, ev.seq);
        let pos = bucket.partition_point(|e| (e.time, e.seq) > key);
        bucket.insert(pos, ev);
    }

    pub fn push(&mut self, time: Time, dest: NodeId, kind: EventKind) {
        debug_assert!(time >= self.now, "scheduling into the past: {} < {}", time, self.now);
        let seq = self.seq;
        self.seq += 1;
        self.pushed += 1;
        let b = self.bucket_of(time);
        Self::insert_sorted(&mut self.buckets[b], Event { time, seq, dest, kind });
        self.count += 1;
        if self.count as u64 > self.nbuckets * 2 {
            self.resize(self.nbuckets * 2);
        }
    }

    pub fn pop(&mut self) -> Option<Event> {
        if self.count == 0 {
            return None;
        }
        // Ring scan from the cursor: one lap covers one calendar year.
        for _ in 0..self.nbuckets {
            let c = self.cursor as usize;
            if let Some(tail) = self.buckets[c].last() {
                if tail.time < self.bucket_top {
                    let ev = self.buckets[c].pop().expect("tail just observed");
                    return Some(self.take(ev));
                }
            }
            self.cursor = (self.cursor + 1) & (self.nbuckets - 1);
            self.bucket_top += self.width;
        }
        // Sparse population: nothing due this year. Jump the cursor
        // straight to the globally minimal event's window and take it.
        let (min_b, _) = self
            .buckets
            .iter()
            .enumerate()
            .filter_map(|(i, b)| b.last().map(|e| (i, (e.time, e.seq))))
            .min_by_key(|&(_, key)| key)
            .expect("count > 0");
        let ev = self.buckets[min_b].pop().expect("minimum just observed");
        self.cursor = min_b as u64;
        self.bucket_top = (ev.time / self.width + 1) * self.width;
        Some(self.take(ev))
    }

    #[inline]
    fn take(&mut self, ev: Event) -> Event {
        debug_assert!(ev.time >= self.now);
        self.now = ev.time;
        self.count -= 1;
        self.popped += 1;
        if self.nbuckets > MIN_BUCKETS && (self.count as u64) < self.nbuckets / 8 {
            self.resize(self.nbuckets / 2);
        }
        ev
    }

    /// Rebuild the ring at `nbuckets` lanes, re-deriving the bucket width
    /// from the live events' time span (target: ~one event per bucket, so
    /// pop's in-window check almost always hits on the first lane). Purely
    /// a function of queue contents — determinism is untouched because the
    /// pop *order* never depends on the layout.
    fn resize(&mut self, nbuckets: u64) {
        let mut events: Vec<Event> = Vec::with_capacity(self.count);
        for b in &mut self.buckets {
            events.append(b);
        }
        let (mut lo, mut hi) = (u64::MAX, 0u64);
        for e in &events {
            lo = lo.min(e.time);
            hi = hi.max(e.time);
        }
        if events.len() > 1 {
            self.width = ((hi - lo) / events.len() as u64).max(1);
        }
        self.nbuckets = nbuckets.max(MIN_BUCKETS);
        self.buckets = (0..self.nbuckets).map(|_| Vec::new()).collect();
        for ev in events {
            let b = self.bucket_of(ev.time);
            Self::insert_sorted(&mut self.buckets[b], ev);
        }
        // Re-anchor the cursor at the clock: the next due event is at or
        // after `now`, so scanning forward from now's window finds it.
        self.cursor = (self.now / self.width) & (self.nbuckets - 1);
        self.bucket_top = (self.now / self.width + 1) * self.width;
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn len(&self) -> usize {
        self.count
    }

    /// (pushed, popped) — engine throughput accounting for §Perf.
    pub fn counters(&self) -> (u64, u64) {
        (self.pushed, self.popped)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(q: &mut EventQueue, t: Time) {
        q.push(t, 0, EventKind::Timer(TimerKind::WorkDone));
    }

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        ev(&mut q, 30);
        ev(&mut q, 10);
        ev(&mut q, 20);
        let times: Vec<Time> = std::iter::from_fn(|| q.pop()).map(|e| e.time).collect();
        assert_eq!(times, vec![10, 20, 30]);
    }

    #[test]
    fn equal_times_fifo_by_push_order() {
        let mut q = EventQueue::new();
        q.push(5, 1, EventKind::Timer(TimerKind::WorkDone));
        q.push(5, 2, EventKind::Timer(TimerKind::WorkDone));
        q.push(5, 3, EventKind::Timer(TimerKind::WorkDone));
        let dests: Vec<NodeId> = std::iter::from_fn(|| q.pop()).map(|e| e.dest).collect();
        assert_eq!(dests, vec![1, 2, 3]);
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        ev(&mut q, 10);
        ev(&mut q, 10);
        ev(&mut q, 40);
        let mut last = 0;
        while let Some(e) = q.pop() {
            assert!(e.time >= last);
            last = e.time;
            assert_eq!(q.now(), e.time);
        }
    }

    #[test]
    #[should_panic(expected = "scheduling into the past")]
    #[cfg(debug_assertions)]
    fn rejects_past_scheduling() {
        let mut q = EventQueue::new();
        ev(&mut q, 10);
        q.pop();
        ev(&mut q, 5);
    }

    /// The calendar queue must pop the exact `(time, seq)` sequence a
    /// plain binary heap would — interleaved pushes and pops, clustered
    /// and sparse times, enough volume to cross several grow/shrink
    /// resizes. Deterministic LCG, no wall-clock anywhere.
    #[test]
    fn matches_reference_heap() {
        use std::cmp::Reverse;
        use std::collections::BinaryHeap;

        let mut q = EventQueue::new();
        let mut reference: BinaryHeap<Reverse<(Time, u64)>> = BinaryHeap::new();
        let mut rng: u64 = 0x5AFA_2DB0_0BAD_F00D;
        let mut step = move || {
            rng = rng.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            rng >> 33
        };
        let mut seq = 0u64;
        let mut now = 0u64;
        for round in 0..2_000u64 {
            // Push a burst: mostly near-future, sometimes equal-time
            // clusters, occasionally a far-future spike (forces the
            // fruitless-lap fallback and wide resize widths).
            let burst = 1 + step() % 8;
            for _ in 0..burst {
                let t = match step() % 10 {
                    0..=5 => now + step() % 4_000,
                    6..=7 => now, // equal-time FIFO cluster
                    8 => now + step() % 50,
                    _ => now + 1_000_000 + step() % 10_000_000,
                };
                q.push(t, (round % 4) as NodeId, EventKind::Timer(TimerKind::WorkDone));
                reference.push(Reverse((t, seq)));
                seq += 1;
            }
            // Pop a few; both queues must agree exactly.
            for _ in 0..(step() % 10) {
                match (q.pop(), reference.pop()) {
                    (Some(got), Some(Reverse((t, s)))) => {
                        assert_eq!((got.time, got.seq), (t, s), "diverged at round {round}");
                        now = t;
                    }
                    (None, None) => break,
                    (got, want) => panic!("length diverged: {got:?} vs {want:?}"),
                }
            }
            assert_eq!(q.len(), reference.len());
        }
        // Drain both to empty.
        while let Some(Reverse((t, s))) = reference.pop() {
            let got = q.pop().expect("calendar drained early");
            assert_eq!((got.time, got.seq), (t, s));
        }
        assert!(q.pop().is_none());
        assert!(q.is_empty());
        let (pushed, popped) = q.counters();
        assert_eq!(pushed, popped);
        assert_eq!(pushed, seq);
    }
}
