//! One replica: FPGA card + host (SafarDB), CPU node (Hamband), or
//! SmartNIC node (Waverunner) — selected purely by `SystemParams` and
//! propagation modes. Holds the real data plane, the per-group replication
//! logs and Mu instances, the heartbeat tracker, the summarization buffer,
//! and the pending-request maps. All latency flows through the fabric and
//! memory models; all state mutation is real and checked by the
//! convergence/integrity tests.

use crate::util::hasher::FastMap;

use crate::config::{PropagationMode, SimConfig, SystemKind, SystemParams};
use crate::engine::store::{DataPlane, KV_READ};
use crate::engine::Ctx;
use crate::mem::{LruCache, MemKind};
use crate::net::verbs::{Payload, ReadData, ReadTarget, Verb};
use crate::rdt::{Category, OpCall};
use crate::sim::{EventKind, NodeId, Time, TimerKind};
use crate::smr::election::{HbVerdict, HeartbeatTracker};
use crate::smr::log::ReplicationLog;
use crate::smr::mu::{MuInstance, Resp, Round, Step};
use crate::smr::raft::{RaftFollower, RaftLeader, RaftStep};
use crate::util::rng::Rng;
use crate::workload::{Generator, Placement, WorkItem};

/// Completion-token bookkeeping.
#[derive(Clone, Copy, Debug)]
enum TokenCtx {
    /// Mu fan-out response: (group, round_id at fan-out time).
    Mu { group: u8, round_id: u64 },
    /// Heartbeat read of a peer.
    Heartbeat { peer: NodeId },
    /// Forwarded conflicting op awaiting a LeaderReply.
    Forward { request_id: u64 },
    /// Raft AppendEntries awaiting follower acks.
    #[allow(dead_code)]
    Raft { term: u64, index: u64 },
    /// Fire-and-forget (relaxed propagation) — completion ignored.
    Ignore,
}

/// A client request in flight (origin side).
#[derive(Clone, Copy, Debug)]
struct PendingClient {
    client: usize,
    arrival: Time,
    retries: u8,
    op: OpCall,
}

/// Leader side: who to answer once a conflicting op commits.
#[derive(Clone, Copy, Debug)]
enum Requester {
    Local { client: usize, arrival: Time },
    Remote { reply_to: NodeId, request_id: u64 },
}

pub struct Replica {
    pub id: NodeId,
    n: usize,
    sys: SystemParams,
    system: SystemKind,
    prop_red: PropagationMode,
    prop_irr: PropagationMode,
    prop_con: PropagationMode,
    summarize_threshold: u32,
    poll_interval_ns: u64,
    heartbeat_period_ns: u64,

    pub plane: DataPlane,
    pub crashed: bool,
    busy_until: Time,
    pub busy_total: u64,

    // client loop
    gen: Generator,
    rng: Rng,
    pub quota: u64,
    op_seq: u64,

    // relaxed-path landing zones (HBM) and summarizer
    pending_reducible: Vec<OpCall>,
    pending_irreducible: Vec<OpCall>,
    sum_buffer: Vec<(OpCall, Time)>,

    // conflicting path
    pub leader: NodeId,
    mu: Vec<MuInstance>,
    pub logs: Vec<ReplicationLog>,
    round_id: Vec<u64>,
    requesters: FastMap<(usize, u64), Requester>,
    pending_fwd: FastMap<u64, PendingClient>,
    next_request_id: u64,

    // leader-switch plane
    pub hb_counter: u64,
    tracker: HeartbeatTracker,

    // tokens
    next_token: u64,
    tokens: FastMap<u64, TokenCtx>,

    // waverunner
    raft_leader: Option<RaftLeader>,
    raft_follower: RaftFollower,
    raft_pending: FastMap<u64, Requester>, // index -> requester

    // hybrid
    host_cache: Option<LruCache>,
    #[allow(dead_code)]
    fpga_keys: u64,

    // counters
    pub executions: u64,
    pub rejected: u64,
}

impl Replica {
    pub fn new(id: NodeId, cfg: &SimConfig, root_rng: &mut Rng) -> Self {
        let sys = cfg.system.params_for(cfg);
        let gen = Generator::new(cfg);
        let plane = DataPlane::for_workload(cfg.workload, gen.keyspace());
        let groups = plane.sync_groups() as usize;
        let host_cache = cfg.hybrid.map(|h| LruCache::new(h.host_cache_keys));
        let fpga_keys = cfg.hybrid.map(|h| h.fpga_keys).unwrap_or(u64::MAX);
        let raft_leader = if cfg.system == SystemKind::Waverunner && id == 0 {
            Some(RaftLeader::new(cfg.n_replicas))
        } else {
            None
        };
        Replica {
            id,
            n: cfg.n_replicas,
            sys,
            system: cfg.system,
            prop_red: cfg.prop_reducible,
            prop_irr: cfg.prop_irreducible,
            prop_con: cfg.prop_conflicting,
            summarize_threshold: cfg.summarize_threshold,
            poll_interval_ns: cfg.poll_interval_ns,
            heartbeat_period_ns: cfg.heartbeat_period_ns,
            plane,
            crashed: false,
            busy_until: 0,
            busy_total: 0,
            gen,
            rng: root_rng.fork(id as u64 + 1),
            quota: 0,
            op_seq: 0,
            pending_reducible: Vec::new(),
            pending_irreducible: Vec::new(),
            sum_buffer: Vec::new(),
            leader: 0,
            mu: (0..groups).map(|g| MuInstance::new(g as u8, cfg.n_replicas)).collect(),
            logs: (0..groups).map(|_| ReplicationLog::new()).collect(),
            round_id: vec![0; groups],
            requesters: FastMap::default(),
            pending_fwd: FastMap::default(),
            next_request_id: 1,
            hb_counter: 0,
            tracker: HeartbeatTracker::new(id, cfg.n_replicas, cfg.hb_fail_threshold),
            next_token: (id as u64) << 48,
            tokens: FastMap::default(),
            raft_leader,
            raft_follower: RaftFollower::new(),
            raft_pending: FastMap::default(),
            host_cache,
            fpga_keys,
            executions: 0,
            rejected: 0,
        }
    }

    // ----- small helpers -------------------------------------------------

    fn token(&mut self, ctx: TokenCtx) -> u64 {
        let t = self.next_token;
        self.next_token += 1;
        self.tokens.insert(t, ctx);
        t
    }

    fn peers(&self) -> Vec<NodeId> {
        (0..self.n).filter(|&i| i != self.id).collect()
    }

    fn live_peers(&self) -> Vec<NodeId> {
        self.tracker.live_set().into_iter().filter(|&i| i != self.id).collect()
    }

    pub fn is_leader(&self) -> bool {
        self.id == self.leader
    }

    /// Advance the local busy clock by `cost` starting no earlier than `at`.
    /// Returns the completion time.
    fn occupy(&mut self, at: Time, cost: u64) -> Time {
        let start = at.max(self.busy_until);
        self.busy_until = start + cost;
        self.busy_total += cost;
        self.busy_until
    }

    fn exec(&self) -> &crate::config::ExecParams {
        &self.sys.exec
    }

    /// State read cost of the local object (own state is warm).
    fn warm_read_ns(&self) -> u64 {
        match self.exec().state_mem {
            MemKind::HostDram => self.sys.mem.cache_hit_ns,
            k => self.sys.mem.local_read_ns(k),
        }
    }

    /// Landing-zone memory kind for write-propagated items.
    fn landing_mem(&self) -> MemKind {
        match self.exec().state_mem {
            MemKind::HostDram => MemKind::HostDram,
            _ => MemKind::Hbm,
        }
    }

    /// Cost of refreshing visible state before a query/permissibility check,
    /// given the propagation mode in effect (the Design Principle #2 story:
    /// no-buffer pays a fold from the landing memory; buffered/RPC read
    /// warm on-fabric state).
    fn refresh_cost(&mut self) -> u64 {
        let mut cost = 0;
        // Reducible contribution fold (§4.1).
        if self.prop_red == PropagationMode::WriteNoBuffer {
            cost += self.sys.mem.fold_read_ns(self.landing_mem(), self.n);
            cost += self.drain_reducible_cost();
        }
        // Irreducible queue drain (§4.2 config 1 polls; no-buffer also
        // drains on access).
        if self.prop_irr == PropagationMode::WriteNoBuffer {
            cost += self.drain_irreducible_cost();
        }
        // Conflicting log check (§4.3 config 1: "polling the log when the
        // state is accessed to ensure the most up to date data").
        if self.prop_con != PropagationMode::WriteThrough {
            let per_group = self.sys.mem.local_read_ns(self.landing_mem());
            cost += per_group * self.logs.len() as u64;
            cost += self.drain_logs_cost();
        }
        cost
    }

    fn drain_reducible_cost(&mut self) -> u64 {
        let items: Vec<OpCall> = self.pending_reducible.drain(..).collect();
        if items.is_empty() {
            return 0;
        }
        // Landed summaries are contiguous slots: one burst read + execute.
        let mut cost = self.sys.mem.fold_read_ns(self.landing_mem(), items.len());
        for op in items {
            cost += self.exec().op_exec_ns;
            self.apply_remote(&op);
        }
        cost
    }

    fn drain_irreducible_cost(&mut self) -> u64 {
        let items: Vec<OpCall> = self.pending_irreducible.drain(..).collect();
        if items.is_empty() {
            return 0;
        }
        // Per-origin FIFO queues: burst-read each queue head run.
        let mut cost = self.sys.mem.fold_read_ns(self.landing_mem(), items.len());
        for op in items {
            cost += self.exec().op_exec_ns;
            self.apply_remote(&op);
        }
        cost
    }

    fn drain_logs_cost(&mut self) -> u64 {
        let mut cost = 0;
        for g in 0..self.logs.len() {
            for entry in self.logs[g].drain_unapplied() {
                cost += self.exec().op_exec_ns + self.sys.mem.local_read_ns(self.landing_mem());
                self.executions += 1;
                self.plane.apply_forced(&entry.op);
            }
        }
        cost
    }

    fn apply_remote(&mut self, op: &OpCall) {
        self.executions += 1;
        self.plane.apply(op);
    }

    /// Apply every pending remote item with zero cost — used only at
    /// quiescence so convergence checks see fully-propagated state.
    pub fn flush_all_pending(&mut self) {
        let red: Vec<OpCall> = self.pending_reducible.drain(..).collect();
        for op in red {
            self.plane.apply(&op);
        }
        let irr: Vec<OpCall> = self.pending_irreducible.drain(..).collect();
        for op in irr {
            self.plane.apply(&op);
        }
        for g in 0..self.logs.len() {
            for e in self.logs[g].drain_unapplied() {
                self.plane.apply_forced(&e.op);
            }
        }
    }

    /// Remaining summarization buffer flushed into the wire at quiescence.
    pub fn has_unflushed_summaries(&self) -> bool {
        !self.sum_buffer.is_empty()
    }

    // ----- boot ----------------------------------------------------------

    pub fn boot(&mut self, ctx: &mut Ctx, clients: usize, quota: u64) {
        self.quota = quota;
        for c in 0..clients {
            ctx.q.push(ctx.q.now(), self.id, EventKind::ClientArrive { client: c });
        }
        // Background machinery.
        let base = self.id as u64 * 7; // desynchronize replicas
        if self.prop_red == PropagationMode::WriteBuffered {
            ctx.q.push(base + self.poll_interval_ns, self.id, EventKind::Timer(TimerKind::PollReducible));
        }
        if self.prop_irr == PropagationMode::WriteNoBuffer
            || self.prop_irr == PropagationMode::WriteBuffered
        {
            ctx.q.push(base + self.poll_interval_ns, self.id, EventKind::Timer(TimerKind::PollIrreducible));
        }
        if self.prop_con != PropagationMode::WriteThrough && !self.logs.is_empty() {
            for g in 0..self.logs.len() {
                ctx.q.push(
                    base + self.poll_interval_ns + g as u64,
                    self.id,
                    EventKind::Timer(TimerKind::PollLog(g as u8)),
                );
            }
        }
        // Heartbeat scanning runs for every object class: WRDTs need it for
        // leader election; CRDTs need it for membership (a crashed peer
        // must leave the relaxed-path fan-out set — Fig 14 e/f).
        ctx.q.push(base + self.heartbeat_period_ns, self.id, EventKind::Timer(TimerKind::HeartbeatScan));
        if self.summarize_threshold > 1 {
            ctx.q.push(base + 4 * self.poll_interval_ns, self.id, EventKind::Timer(TimerKind::SummarizeFlush));
        }
    }

    // ----- event dispatch --------------------------------------------------

    pub fn handle(&mut self, ctx: &mut Ctx, kind: EventKind) {
        if self.crashed && !matches!(kind, EventKind::Recover) {
            return;
        }
        match kind {
            EventKind::ClientArrive { client } => self.on_client(ctx, client),
            EventKind::VerbDeliver { src, verb } => self.on_verb(ctx, src, verb),
            EventKind::AckDeliver { token } => self.on_completion(ctx, token, true),
            EventKind::NackDeliver { token } => self.on_completion(ctx, token, false),
            EventKind::Timer(t) => self.on_timer(ctx, t),
            EventKind::Crash => {
                self.crashed = true;
                ctx.net.set_crashed(self.id, true);
            }
            EventKind::Recover => {
                self.crashed = false;
                ctx.net.set_crashed(self.id, false);
                self.busy_until = ctx.q.now();
                // Heartbeat resumes; peers will observe Recovered.
                ctx.q.push(ctx.q.now() + self.heartbeat_period_ns, self.id, EventKind::Timer(TimerKind::HeartbeatScan));
            }
        }
    }

    // ----- client path -----------------------------------------------------

    fn on_client(&mut self, ctx: &mut Ctx, client: usize) {
        if self.quota == 0 {
            return; // slot retires
        }
        self.quota -= 1;
        let now = ctx.q.now();
        self.op_seq += 1;
        // LWW timestamps compose (time, origin) so they are globally unique
        // and merge deterministically (Table A.1 "unique timestamps").
        let ts = ((now.max(1)) << 8) | self.id as u64;
        let mut item = self.gen.next(&mut self.rng, &self.plane, ts);
        item.op.origin = self.id;
        item.op.seq = self.op_seq;
        self.process_client_op(ctx, client, item, now);
    }

    fn process_client_op(&mut self, ctx: &mut Ctx, client: usize, item: WorkItem, arrival: Time) {
        // Waverunner: only the leader serves clients (§5.2); every update
        // replicates through Raft regardless of RDT category (no hybrid
        // consistency — that is the point of the Fig 12 comparison).
        if self.system == SystemKind::Waverunner {
            if self.raft_leader.is_none() {
                self.waverunner_redirect(ctx, client, item, arrival);
            } else {
                self.waverunner_serve(ctx, client, item, arrival);
            }
            return;
        }

        let ingress = self.exec().client_overhead_ns / 2;
        let sw = self.exec().software_overhead_ns;
        let mut cost = ingress + sw;

        // Hybrid: host-resident keys pay the PCIe hop + host-side costs.
        let host_side = item.placement == Placement::Host;
        if host_side {
            cost += self.sys.mem.pcie_ns; // FPGA ingress -> host handoff
            cost += 120; // host software dispatch
        }

        let op = item.op;
        if op.is_query() || op.opcode == KV_READ {
            if op.is_query() && !self.plane.has_query() {
                // Movie has no query() (§5.2): the slot is a pure local
                // no-op that never touches replicated state.
                let done = self.occupy(arrival, cost + self.exec().client_overhead_ns / 2);
                self.complete_client(ctx, client, arrival, done);
                return;
            }
            cost += self.query_cost(&op, host_side);
            let done = self.occupy(arrival, cost + self.exec().client_overhead_ns / 2);
            self.complete_client(ctx, client, arrival, done);
            return;
        }

        // Update: permissibility precheck at the issuing replica (§2.1).
        cost += self.refresh_cost();
        cost += self.read_for_check_cost(&op, host_side);
        if !self.plane.permissible(&op) {
            self.rejected += 1;
            let done = self.occupy(arrival, cost + self.exec().client_overhead_ns / 2);
            self.complete_client(ctx, client, arrival, done);
            return;
        }

        match self.plane.category(op.opcode) {
            Category::Reducible => {
                cost += self.exec().op_exec_ns + self.write_state_cost(host_side);
                self.executions += 1;
                self.plane.apply(&op);
                // Op-based relaxed semantics: respond after the local
                // commit; propagation proceeds off the response path but
                // still occupies the replica (throughput, not latency).
                let t_apply = self.occupy(arrival, cost);
                let done = self.occupy(t_apply, self.exec().client_overhead_ns / 2);
                self.complete_client(ctx, client, arrival, done);
                self.sum_buffer.push((op, t_apply));
                if self.sum_buffer.len() as u32 >= self.summarize_threshold {
                    self.flush_summaries(ctx, host_side);
                }
            }
            Category::Irreducible => {
                cost += self.exec().op_exec_ns + self.write_state_cost(host_side);
                self.executions += 1;
                self.plane.apply(&op);
                let t_apply = self.occupy(arrival, cost);
                let done = self.occupy(t_apply, self.exec().client_overhead_ns / 2);
                self.complete_client(ctx, client, arrival, done);
                self.propagate_irreducible(ctx, op, host_side);
            }
            Category::Conflicting => {
                if self.summarize_threshold > 1 {
                    // §5.4 Summarization: "instead of updating the remote
                    // replicas via RDMA *or coordination* ... we only
                    // update the local state" — batching trades integrity
                    // staleness for performance. The op was locally
                    // permissible; it applies locally and ships as a
                    // normalized delta in the next summary flush.
                    let op = normalize_for_summary(&self.plane, op);
                    cost += self.exec().op_exec_ns + self.write_state_cost(host_side);
                    self.executions += 1;
                    self.plane.apply(&op);
                    let t_apply = self.occupy(arrival, cost);
                    let done = self.occupy(t_apply, self.exec().client_overhead_ns / 2);
                    self.complete_client(ctx, client, arrival, done);
                    self.sum_buffer.push((op, t_apply));
                    if self.sum_buffer.len() as u32 >= self.summarize_threshold {
                        self.flush_summaries(ctx, host_side);
                    }
                    return;
                }
                let _t = self.occupy(arrival, cost);
                self.submit_conflicting(ctx, op, Requester::Local { client, arrival });
            }
        }
    }

    fn complete_client(&mut self, ctx: &mut Ctx, client: usize, arrival: Time, done: Time) {
        ctx.metrics.response.record(done - arrival);
        ctx.metrics.completed[self.id] += 1;
        ctx.metrics.completed_sum += 1;
        ctx.metrics.last_completion_ns = ctx.metrics.last_completion_ns.max(done);
        ctx.q.push(done, self.id, EventKind::ClientArrive { client });
    }

    fn query_cost(&mut self, op: &OpCall, host_side: bool) -> u64 {
        let mut cost = self.refresh_cost();
        if host_side {
            let hit = self
                .host_cache
                .as_mut()
                .map(|c| c.access(op.b))
                .unwrap_or(false);
            cost += self.sys.mem.host_keyed_read_ns(hit);
            cost += self.sys.mem.pcie_ns; // response back over PCIe
        } else if self.prop_red == PropagationMode::WriteNoBuffer
            && matches!(self.plane, DataPlane::Micro(_))
        {
            // fold already charged in refresh_cost
            cost += self.warm_read_ns();
        } else {
            cost += self.warm_read_ns();
        }
        cost
    }

    fn read_for_check_cost(&mut self, op: &OpCall, host_side: bool) -> u64 {
        if host_side {
            let hit = self
                .host_cache
                .as_mut()
                .map(|c| c.access(op.b))
                .unwrap_or(false);
            self.sys.mem.host_keyed_read_ns(hit)
        } else {
            self.warm_read_ns()
        }
    }

    fn write_state_cost(&self, host_side: bool) -> u64 {
        if host_side {
            self.sys.mem.dram_ns + self.sys.mem.pcie_ns
        } else {
            self.sys.mem.local_write_ns(self.exec().state_mem)
        }
    }

    // ----- relaxed propagation ----------------------------------------------

    /// Send one verb to every live peer, serializing initiator-side costs
    /// (Hamband's CQE wait makes this expensive; SafarDB pipelines).
    fn fan_out(&mut self, ctx: &mut Ctx, make: impl Fn(u64) -> Verb, want_completion: bool, ctx_of: impl Fn() -> TokenCtx) {
        let peers = self.live_peers();
        let start = ctx.q.now().max(self.busy_until);
        let mut cursor = start;
        for dst in peers {
            let tok = self.token(ctx_of());
            let verb = make(tok);
            ctx.metrics.verbs += 1;
            let out = ctx.net.issue(ctx.q, ctx.qps, &self.sys.fabric, cursor, self.id, dst, verb, want_completion);
            cursor = out.initiator_free_at;
        }
        // Initiator-side verb-issue time is real busy time on the replica
        // (the Hamband CQE serialization shows up exactly here).
        self.busy_total += cursor - start;
        self.busy_until = cursor;
    }

    fn flush_summaries(&mut self, ctx: &mut Ctx, host_side: bool) {
        if self.sum_buffer.is_empty() {
            return;
        }
        let now = ctx.q.now();
        let items: Vec<(OpCall, Time)> = self.sum_buffer.drain(..).collect();
        for (_, applied_at) in &items {
            ctx.metrics.staleness.add((now.saturating_sub(*applied_at)) as f64);
        }
        // Summarize under the data plane's type-correct rule.
        let ops: Vec<OpCall> = items.iter().map(|(o, _)| *o).collect();
        let agg = summarize(self.summarize_rule(), &ops);
        let origin = self.id;
        let mode = self.prop_red;
        let mem = self.landing_mem_for_peer();
        // Host-issued verbs pay an extra PCIe hop before the NIC.
        if host_side {
            let pcie = self.sys.mem.pcie_ns;
            self.busy_total += pcie;
            self.busy_until = self.busy_until.max(ctx.q.now()) + pcie;
        }
        for op in agg {
            match mode {
                PropagationMode::Rpc => {
                    self.fan_out(ctx, |t| Verb::rpc(Payload::Summary { origin, ops: 1, value: op }, t), false, || TokenCtx::Ignore);
                }
                _ => {
                    self.fan_out(
                        ctx,
                        |t| Verb::write(mem, Payload::Summary { origin, ops: 1, value: op }, t),
                        false,
                        || TokenCtx::Ignore,
                    );
                }
            }
        }
    }

    fn summarize_rule(&self) -> SummarizeRule {
        self.plane.summarize_rule()
    }

    fn landing_mem_for_peer(&self) -> MemKind {
        // Peers run the same system; their landing zone mirrors ours.
        self.landing_mem()
    }

    fn propagate_irreducible(&mut self, ctx: &mut Ctx, op: OpCall, host_side: bool) {
        if host_side {
            let pcie = self.sys.mem.pcie_ns;
            self.busy_total += pcie;
            self.busy_until = self.busy_until.max(ctx.q.now()) + pcie;
        }
        let mem = self.landing_mem_for_peer();
        match self.prop_irr {
            PropagationMode::Rpc => {
                self.fan_out(ctx, |t| Verb::rpc(Payload::QueueAppend { op }, t), false, || TokenCtx::Ignore);
            }
            _ => {
                self.fan_out(ctx, |t| Verb::write(mem, Payload::QueueAppend { op }, t), false, || TokenCtx::Ignore);
            }
        }
    }

    // ----- conflicting path (Mu) ---------------------------------------------

    fn submit_conflicting(&mut self, ctx: &mut Ctx, op: OpCall, req: Requester) {
        if self.system == SystemKind::Waverunner {
            self.waverunner_submit(ctx, op, req);
            return;
        }
        self.requesters.insert((op.origin, op.seq), req);
        if self.is_leader() {
            let g = self.plane.sync_group(op.opcode) as usize;
            let slot = self.logs[g].next_free_slot();
            if let Some(round) = self.mu[g].submit(op, slot) {
                self.fan_out_round(ctx, g, round);
            }
        } else {
            // Forward to the leader (one RPC-sized write; §4.3).
            let request_id = self.next_request_id;
            self.next_request_id += 1;
            if let Requester::Local { client, arrival } = req {
                self.pending_fwd.insert(request_id, PendingClient { client, arrival, retries: 0, op });
            }
            let leader = self.leader;
            let tok = self.token(TokenCtx::Forward { request_id });
            let verb = Verb::write(
                self.landing_mem_for_peer(),
                Payload::LeaderForward { op, reply_to: self.id, request_id },
                tok,
            );
            ctx.metrics.verbs += 1;
            let start = ctx.q.now().max(self.busy_until);
            let out = ctx.net.issue(ctx.q, ctx.qps, &self.sys.fabric, start, self.id, leader, verb, true);
            self.busy_total += out.initiator_free_at - start;
            self.busy_until = out.initiator_free_at;
        }
    }

    fn fan_out_round(&mut self, ctx: &mut Ctx, g: usize, round: Round) {
        self.round_id[g] += 1;
        let rid = self.round_id[g];
        let group = g as u8;
        let peers = self.live_peers();
        self.mu[g].round_started(peers.len() as u32);
        let use_wt = self.prop_con == PropagationMode::WriteThrough;
        // Sequential SMR: the leader is execution-busy from the previous
        // round's fan-out through this round's quorum (appendix D.1).
        let now = ctx.q.now();
        if now > self.busy_until {
            self.busy_total += now - self.busy_until;
            self.busy_until = now;
        }
        let start = ctx.q.now().max(self.busy_until);
        let mut cursor = start;
        for dst in peers {
            let tok = self.token(TokenCtx::Mu { group, round_id: rid });
            // All rounds want completions: writes for quorum ACKs, reads so
            // crashed followers surface as NACKs (reads otherwise complete
            // via ReadResp).
            let verb = match round {
                Round::ReadMinProposals => Verb::read(ReadTarget::MinProposal { group }, tok),
                Round::WriteProposal { proposal } => {
                    Verb::write(self.landing_mem_for_peer(), Payload::Propose { group, proposal }, tok)
                        .on_leader_qp()
                }
                Round::ReadSlots { slot } => Verb::read(ReadTarget::LogSlot { group, slot }, tok),
                Round::WriteLog { slot, proposal, op, adopted: _ } => {
                    let payload = Payload::LogAppend { group, slot, proposal, op };
                    if use_wt {
                        Verb::rpc_write_through(payload, tok)
                    } else {
                        Verb::write(MemKind::Hbm, payload, tok).on_leader_qp()
                    }
                }
            };
            ctx.metrics.verbs += 1;
            let out = ctx.net.issue(ctx.q, ctx.qps, &self.sys.fabric, cursor, self.id, dst, verb, true);
            cursor = out.initiator_free_at;
        }
        self.busy_total += cursor - start;
        self.busy_until = cursor;
    }

    fn mu_step(&mut self, ctx: &mut Ctx, g: usize, step: Step) {
        match step {
            Step::Wait => {}
            Step::Next(round) => {
                if let Round::WriteLog { slot, proposal, op, adopted } = round {
                    // Accept phase entry: the leader *executes* the
                    // transaction before writing followers' logs (§4.4).
                    // Its permissibility check here is authoritative — the
                    // op sits at a fixed position in the total order.
                    if !adopted && !self.plane.permissible(&op) {
                        self.rejected += 1;
                        self.mu[g].abort_current();
                        if let Some(req) = self.requesters.remove(&(op.origin, op.seq)) {
                            self.answer_requester(ctx, req, false);
                        }
                        let next = self.logs[g].next_free_slot();
                        if let Some(round) = self.mu[g].pump(next) {
                            self.fan_out_round(ctx, g, round);
                        }
                        return;
                    }
                    // Execute locally unless this replica already applied
                    // the entry (e.g. it drained it from its log as a
                    // follower before winning the election).
                    if self.logs[g].applied_upto <= slot {
                        let exec_cost = self.exec().op_exec_ns + self.write_state_cost(false);
                        self.occupy(ctx.q.now(), exec_cost);
                        if adopted {
                            self.plane.apply_forced(&op);
                        } else {
                            self.plane.apply(&op);
                        }
                        self.executions += 1;
                    }
                    self.logs[g].write_slot(slot, proposal, op);
                    self.logs[g].applied_upto = self.logs[g].applied_upto.max(slot + 1);
                }
                self.fan_out_round(ctx, g, round)
            }
            Step::Commit { slot: _, proposal: _, op, adopted: _ } => {
                // Quorum of followers acked the Accept write: committed.
                // The SMR pipeline is sequential per group — the leader is
                // execution-time-busy through the whole round (appendix
                // D.1: the leader is the longest-running replica).
                let now = ctx.q.now();
                if now > self.busy_until {
                    self.busy_total += now - self.busy_until;
                    self.busy_until = now;
                }
                ctx.metrics.smr_commits += 1;
                if let Some(req) = self.requesters.remove(&(op.origin, op.seq)) {
                    self.answer_requester(ctx, req, true);
                }
                // Pump the next queued conflicting op.
                let slot = self.logs[g].next_free_slot();
                if let Some(round) = self.mu[g].pump(slot) {
                    self.fan_out_round(ctx, g, round);
                }
            }
            Step::Stall => {
                self.mu[g].reset_in_flight();
                // Retry once the heartbeat scanner refreshes the live set.
                ctx.q.push(
                    ctx.q.now() + self.heartbeat_period_ns,
                    self.id,
                    EventKind::Timer(TimerKind::SmrTick(g as u8)),
                );
            }
        }
    }

    // ----- verb arrivals -----------------------------------------------------

    fn on_verb(&mut self, ctx: &mut Ctx, src: NodeId, verb: Verb) {
        let is_rpc = matches!(verb.kind, crate::net::verbs::VerbKind::Rpc | crate::net::verbs::VerbKind::RpcWriteThrough);
        match verb.payload {
            Payload::Raw { .. } => {}
            Payload::Summary { value, .. } => {
                if is_rpc {
                    // Dispatcher invokes the accelerator directly (Fig 1).
                    let cost = self.exec().op_exec_ns + self.sys.mem.local_write_ns(MemKind::Bram);
                    self.occupy(ctx.q.now(), cost);
                    self.apply_remote(&value);
                } else {
                    self.pending_reducible.push(value);
                }
            }
            Payload::QueueAppend { op } => {
                if is_rpc {
                    let cost = self.exec().op_exec_ns + self.sys.mem.local_write_ns(MemKind::Bram);
                    self.occupy(ctx.q.now(), cost);
                    self.apply_remote(&op);
                } else {
                    self.pending_irreducible.push(op);
                }
            }
            Payload::Propose { group, proposal } => {
                self.logs[group as usize].bump_min_proposal(proposal);
            }
            Payload::LogAppend { group, slot, proposal, op } => {
                let g = group as usize;
                self.logs[g].write_slot(slot, proposal, op);
                if is_rpc {
                    // Write-through: follower state updated directly from
                    // the network (§4.4 "at L"); log is already appended.
                    let cost = self.exec().op_exec_ns + self.sys.mem.local_write_ns(MemKind::Bram);
                    self.occupy(ctx.q.now(), cost);
                    for e in self.logs[g].drain_unapplied() {
                        self.executions += 1;
                        self.plane.apply_forced(&e.op);
                    }
                }
            }
            Payload::LeaderForward { op, reply_to, request_id } => {
                if self.system == SystemKind::Waverunner {
                    // Redirected client request reaching the Raft leader.
                    let sw = self.exec().software_overhead_ns;
                    self.occupy(ctx.q.now(), sw);
                    if op.is_query() || op.opcode == KV_READ {
                        let cost = self.warm_read_ns() + self.exec().client_overhead_ns / 2;
                        self.occupy(ctx.q.now(), cost);
                        self.reply_remote(ctx, reply_to, request_id, true, true);
                    } else {
                        self.waverunner_submit(ctx, op, Requester::Remote { reply_to, request_id });
                    }
                } else if self.is_leader() {
                    let sw = self.exec().software_overhead_ns;
                    self.occupy(ctx.q.now(), sw);
                    // Leader re-checks permissibility in total order context.
                    self.submit_conflicting(ctx, op, Requester::Remote { reply_to, request_id });
                } else {
                    // Not the leader (stale forward): bounce.
                    self.reply_remote(ctx, reply_to, request_id, false, false);
                }
            }
            Payload::LeaderReply { request_id, handled, committed } => {
                if let Some(p) = self.pending_fwd.remove(&request_id) {
                    if handled {
                        if !committed {
                            self.rejected += 1;
                        }
                        let done = self.occupy(ctx.q.now(), self.exec().client_overhead_ns / 2);
                        self.complete_client(ctx, p.client, p.arrival, done);
                    } else {
                        self.retry_forward(ctx, p);
                    }
                }
            }
            Payload::ReadReq { target } => {
                // One-sided: the NIC answers from memory without the app.
                let data = match target {
                    ReadTarget::Heartbeat => ReadData::Heartbeat(self.hb_counter),
                    ReadTarget::MinProposal { group } => {
                        ReadData::MinProposal(self.logs[group as usize].min_proposal)
                    }
                    ReadTarget::LogSlot { group, slot } => ReadData::LogSlot(
                        self.logs[group as usize].read_slot(slot).map(|e| (e.proposal, e.op)),
                    ),
                    ReadTarget::Raw { .. } => ReadData::Raw,
                };
                let resp = Verb {
                    kind: crate::net::verbs::VerbKind::Read,
                    dst_mem: MemKind::Hbm,
                    payload: Payload::ReadResp { target, data },
                    token: verb.token,
                    leader_qp: false,
                };
                ctx.metrics.verbs += 1;
                ctx.net.issue(ctx.q, ctx.qps, &self.sys.fabric, ctx.q.now(), self.id, src, resp, false);
            }
            Payload::ReadResp { data, .. } => self.on_read_resp(ctx, verb.token, data),
            Payload::RaftAppend { term, index, op } => {
                if self.raft_follower.on_append(term, index, op) {
                    for o in self.raft_follower.drain_apply() {
                        self.apply_remote(&o);
                    }
                    let tok = self.token(TokenCtx::Ignore);
                    let ack = Verb::write(
                        self.landing_mem_for_peer(),
                        Payload::RaftAck { term, index, from: self.id },
                        tok,
                    );
                    ctx.metrics.verbs += 1;
                    ctx.net.issue(ctx.q, ctx.qps, &self.sys.fabric, ctx.q.now(), self.id, src, ack, false);
                }
            }
            Payload::RaftAck { term, index, .. } => {
                if let Some(rl) = self.raft_leader.as_mut() {
                    if let RaftStep::Commit { index, op: _op } = rl.on_ack(term, index) {
                        // Leader state was updated at submit; commit point
                        // is the quorum ack.
                        let done = self.occupy(ctx.q.now(), self.exec().op_exec_ns);
                        ctx.metrics.smr_commits += 1;
                        if let Some(req) = self.raft_pending.remove(&index) {
                            match req {
                                Requester::Local { client, arrival } => {
                                    let t = self.occupy(done, self.exec().client_overhead_ns / 2);
                                    self.complete_client(ctx, client, arrival, t);
                                }
                                Requester::Remote { reply_to, request_id } => {
                                    self.reply_remote(ctx, reply_to, request_id, true, true);
                                }
                            }
                        }
                        if let Some((term, index, op)) = self.raft_leader.as_mut().unwrap().pump() {
                            self.raft_fan_out(ctx, term, index, op);
                        }
                    }
                }
            }
            Payload::ClientRedirect { .. } => {}
        }
    }

    fn answer_requester(&mut self, ctx: &mut Ctx, req: Requester, committed: bool) {
        if !committed {
            // rejected ops were already counted by the caller
        }
        match req {
            Requester::Local { client, arrival } => {
                let t = self.occupy(ctx.q.now(), self.exec().client_overhead_ns / 2);
                self.complete_client(ctx, client, arrival, t);
            }
            Requester::Remote { reply_to, request_id } => {
                self.reply_remote(ctx, reply_to, request_id, true, committed);
            }
        }
    }

    fn reply_remote(&mut self, ctx: &mut Ctx, reply_to: NodeId, request_id: u64, handled: bool, committed: bool) {
        let tok = self.token(TokenCtx::Ignore);
        let verb = Verb::write(
            self.landing_mem_for_peer(),
            Payload::LeaderReply { request_id, handled, committed },
            tok,
        );
        ctx.metrics.verbs += 1;
        let now = ctx.q.now().max(self.busy_until);
        ctx.net.issue(ctx.q, ctx.qps, &self.sys.fabric, now, self.id, reply_to, verb, false);
    }

    fn retry_forward(&mut self, ctx: &mut Ctx, mut p: PendingClient) {
        p.retries += 1;
        if p.retries > 8 {
            // Give up: count as rejected so the run terminates.
            self.rejected += 1;
            let done = self.occupy(ctx.q.now(), self.exec().client_overhead_ns / 2);
            self.complete_client(ctx, p.client, p.arrival, done);
            return;
        }
        // Re-forward to the current leader view after a beat.
        let request_id = self.next_request_id;
        self.next_request_id += 1;
        self.pending_fwd.insert(request_id, p);
        let leader = self.tracker.elect_leader();
        self.leader = leader;
        let op = p.op;
        if leader == self.id {
            let pc = self.pending_fwd.remove(&request_id).unwrap();
            self.submit_conflicting(ctx, op, Requester::Local { client: pc.client, arrival: pc.arrival });
            return;
        }
        let tok = self.token(TokenCtx::Forward { request_id });
        let verb = Verb::write(
            self.landing_mem_for_peer(),
            Payload::LeaderForward { op, reply_to: self.id, request_id },
            tok,
        );
        ctx.metrics.verbs += 1;
        let at = ctx.q.now() + self.heartbeat_period_ns;
        let at = at.max(self.busy_until);
        ctx.net.issue(ctx.q, ctx.qps, &self.sys.fabric, at, self.id, leader, verb, true);
    }

    fn on_read_resp(&mut self, ctx: &mut Ctx, token: u64, data: ReadData) {
        let Some(tctx) = self.tokens.remove(&token) else { return };
        match (tctx, data) {
            (TokenCtx::Heartbeat { peer }, ReadData::Heartbeat(v)) => {
                self.on_heartbeat(ctx, peer, Some(v));
            }
            (TokenCtx::Mu { group, round_id }, d) => {
                let g = group as usize;
                if round_id != self.round_id[g] {
                    return; // stale round
                }
                let resp = match d {
                    ReadData::MinProposal(p) => Resp::MinProposal(p),
                    ReadData::LogSlot(s) => Resp::Slot(s),
                    _ => Resp::Ack,
                };
                let step = self.mu[g].on_response(resp);
                self.mu_step(ctx, g, step);
            }
            _ => {}
        }
    }

    fn on_completion(&mut self, ctx: &mut Ctx, token: u64, ok: bool) {
        let Some(tctx) = self.tokens.remove(&token) else { return };
        match tctx {
            TokenCtx::Mu { group, round_id } => {
                let g = group as usize;
                if round_id != self.round_id[g] {
                    return;
                }
                let step = self.mu[g].on_response(if ok { Resp::Ack } else { Resp::Failure });
                self.mu_step(ctx, g, step);
            }
            TokenCtx::Heartbeat { peer } => {
                if !ok {
                    self.on_heartbeat(ctx, peer, None);
                }
            }
            TokenCtx::Forward { request_id } => {
                if !ok {
                    if let Some(p) = self.pending_fwd.remove(&request_id) {
                        self.retry_forward(ctx, p);
                    }
                }
            }
            TokenCtx::Raft { .. } | TokenCtx::Ignore => {}
        }
    }

    // ----- leader switch plane -------------------------------------------------

    fn on_timer(&mut self, ctx: &mut Ctx, t: TimerKind) {
        match t {
            TimerKind::PollReducible => {
                let cost = self.exec().poll_tick_ns + self.drain_reducible_cost();
                self.occupy(ctx.q.now(), cost);
                if !ctx.draining {
                    ctx.q.push(ctx.q.now() + self.poll_interval_ns, self.id, EventKind::Timer(t));
                }
            }
            TimerKind::PollIrreducible => {
                let cost = self.exec().poll_tick_ns + self.drain_irreducible_cost();
                self.occupy(ctx.q.now(), cost);
                if !ctx.draining {
                    ctx.q.push(ctx.q.now() + self.poll_interval_ns, self.id, EventKind::Timer(t));
                }
            }
            TimerKind::PollLog(_g) => {
                let cost = self.exec().poll_tick_ns + self.drain_logs_cost();
                self.occupy(ctx.q.now(), cost);
                if !ctx.draining {
                    ctx.q.push(ctx.q.now() + self.poll_interval_ns, self.id, EventKind::Timer(t));
                }
            }
            TimerKind::SummarizeFlush => {
                if !self.sum_buffer.is_empty() {
                    self.flush_summaries(ctx, false);
                }
                if !ctx.draining {
                    ctx.q.push(ctx.q.now() + 4 * self.poll_interval_ns, self.id, EventKind::Timer(t));
                }
            }
            TimerKind::HeartbeatScan => {
                self.hb_counter += 1;
                // Hamband's scanner is a software thread competing with the
                // app (§5.3 "In Hamband, this update occurs in the
                // foreground"); SafarDB's is fabric logic.
                if self.system == SystemKind::Hamband {
                    self.occupy(ctx.q.now(), self.exec().software_overhead_ns);
                }
                let peers = self.peers();
                for peer in peers {
                    let tok = self.token(TokenCtx::Heartbeat { peer });
                    let verb = Verb::read(ReadTarget::Heartbeat, tok);
                    ctx.metrics.verbs += 1;
                    ctx.net.issue(ctx.q, ctx.qps, &self.sys.fabric, ctx.q.now(), self.id, peer, verb, true);
                }
                if !ctx.draining {
                    ctx.q.push(ctx.q.now() + self.heartbeat_period_ns, self.id, EventKind::Timer(t));
                }
            }
            TimerKind::SmrTick(g) => {
                let g = g as usize;
                if self.is_leader() {
                    self.mu[g].set_cluster_size(self.tracker.live_set().len());
                    let slot = self.logs[g].next_free_slot();
                    if let Some(round) = self.mu[g].pump(slot) {
                        self.fan_out_round(ctx, g, round);
                    }
                }
            }
            TimerKind::WorkDone => {}
        }
    }

    fn on_heartbeat(&mut self, ctx: &mut Ctx, peer: NodeId, value: Option<u64>) {
        let verdict = match value {
            Some(v) => self.tracker.observe(peer, v),
            None => self.tracker.observe_timeout(peer),
        };
        match verdict {
            HbVerdict::JustFailed => {
                if std::env::var_os("SAFARDB_DEBUG").is_some() {
                    eprintln!("[{}ns] r{}: declared r{} FAILED", ctx.q.now(), self.id, peer);
                }
                if peer == self.leader {
                    self.start_leader_switch(ctx);
                } else if self.is_leader() {
                    // Leader trims its follower list (background on SafarDB,
                    // foreground cost charged above for Hamband).
                    for g in 0..self.mu.len() {
                        self.mu[g].set_cluster_size(self.tracker.live_set().len());
                    }
                }
            }
            HbVerdict::Recovered => {
                if self.is_leader() {
                    self.replay_log_to(ctx, peer);
                    for g in 0..self.mu.len() {
                        self.mu[g].set_cluster_size(self.tracker.live_set().len());
                    }
                }
            }
            _ => {}
        }
    }

    fn start_leader_switch(&mut self, ctx: &mut Ctx) {
        let old = self.leader;
        let new = self.tracker.elect_leader();
        if new == old {
            return;
        }
        if std::env::var_os("SAFARDB_DEBUG").is_some() {
            eprintln!(
                "[{}ns] r{}: leader switch {} -> {} (live {:?})",
                ctx.q.now(), self.id, old, new, self.tracker.live_set()
            );
        }
        // Permission switch: close the old leader's QP, open the new one.
        // FPGA: direct QP-register pokes, ns-scale; RNIC: driver + PCIe.
        let lat = self.sys.fabric.perm_switch.sample(&mut self.rng);
        ctx.metrics.perm_switch.record(lat);
        ctx.qps.switch_leader(self.id, old, new);
        self.occupy(ctx.q.now(), lat);
        self.leader = new;
        if new == self.id {
            ctx.metrics.elections += 1;
            // Take over: re-replicate our log suffix first — the crashed
            // leader may have written an Accept to only a subset of
            // followers (including us), and Mu's slot-adoption only repairs
            // slots we later propose into. Idempotent: followers reject
            // equal/lower proposals and skip already-applied slots.
            let peers = self.live_peers();
            for peer in peers {
                self.replay_log_to(ctx, peer);
            }
            for g in 0..self.mu.len() {
                self.mu[g].set_cluster_size(self.tracker.live_set().len());
                let slot = self.logs[g].next_free_slot();
                if let Some(round) = self.mu[g].pump(slot) {
                    self.fan_out_round(ctx, g, round);
                }
            }
        }
        // Any of our forwards pending at the dead leader: retry now.
        let pending: Vec<(u64, PendingClient)> = self.pending_fwd.drain().collect();
        for (_, p) in pending {
            self.retry_forward(ctx, p);
        }
    }

    /// Recovery: re-issue committed entries to a returned follower (§3).
    fn replay_log_to(&mut self, ctx: &mut Ctx, peer: NodeId) {
        for g in 0..self.logs.len() {
            let entries = self.logs[g].entries_from(0);
            for (slot, e) in entries {
                let tok = self.token(TokenCtx::Ignore);
                let payload = Payload::LogAppend { group: g as u8, slot, proposal: e.proposal, op: e.op };
                let verb = if self.prop_con == PropagationMode::WriteThrough {
                    Verb::rpc_write_through(payload, tok)
                } else {
                    Verb::write(MemKind::Hbm, payload, tok).on_leader_qp()
                };
                ctx.metrics.verbs += 1;
                ctx.net.issue(ctx.q, ctx.qps, &self.sys.fabric, ctx.q.now(), self.id, peer, verb, false);
            }
        }
    }

    // ----- waverunner ------------------------------------------------------------

    fn waverunner_redirect(&mut self, ctx: &mut Ctx, client: usize, item: WorkItem, arrival: Time) {
        // Follower rejects; client re-sends to the leader (§5.2). Modeled
        // as a forward carrying the client's retry round trip.
        let request_id = self.next_request_id;
        self.next_request_id += 1;
        self.pending_fwd.insert(request_id, PendingClient { client, arrival, retries: 0, op: item.op });
        let tok = self.token(TokenCtx::Forward { request_id });
        let verb = Verb::write(
            self.landing_mem_for_peer(),
            Payload::LeaderForward { op: item.op, reply_to: self.id, request_id },
            tok,
        );
        ctx.metrics.verbs += 1;
        // Reject + client re-send penalty before the forward goes out.
        let penalty = self.exec().client_overhead_ns + self.sys.fabric.wire_ns * 2;
        let now = self.occupy(arrival, penalty);
        ctx.net.issue(ctx.q, ctx.qps, &self.sys.fabric, now, self.id, 0, verb, true);
    }

    /// Raft-leader client service: reads are local; every update goes
    /// through the replication pipeline.
    fn waverunner_serve(&mut self, ctx: &mut Ctx, client: usize, item: WorkItem, arrival: Time) {
        let ingress = self.exec().client_overhead_ns / 2;
        let sw = self.exec().software_overhead_ns;
        let op = item.op;
        if op.is_query() || op.opcode == KV_READ {
            let cost = ingress + sw + self.warm_read_ns() + self.exec().client_overhead_ns / 2;
            let done = self.occupy(arrival, cost);
            self.complete_client(ctx, client, arrival, done);
            return;
        }
        self.occupy(arrival, ingress + sw);
        self.waverunner_submit(ctx, op, Requester::Local { client, arrival });
    }

    fn waverunner_submit(&mut self, ctx: &mut Ctx, op: OpCall, req: Requester) {
        if self.raft_leader.is_none() {
            return; // not the leader: redirects handle it
        }
        // The leader applies every update (its own and forwarded ones) at
        // submit; followers apply from the replicated log.
        let cost = self.exec().op_exec_ns + self.write_state_cost(false);
        self.occupy(ctx.q.now(), cost);
        self.executions += 1;
        self.plane.apply(&op);
        let rl = self.raft_leader.as_mut().unwrap();
        let (index, fanout) = rl.submit(op);
        self.raft_pending.insert(index, req);
        if let Some((term, index, op)) = fanout {
            self.raft_fan_out(ctx, term, index, op);
        }
    }

    fn raft_fan_out(&mut self, ctx: &mut Ctx, term: u64, index: u64, op: OpCall) {
        self.fan_out(
            ctx,
            |t| Verb::write(MemKind::HostDram, Payload::RaftAppend { term, index, op }, t),
            false,
            || TokenCtx::Raft { term, index },
        );
    }

    // ----- inspection -----------------------------------------------------------

    pub fn digest(&self) -> u64 {
        self.plane.state_digest()
    }

    pub fn invariant_ok(&self) -> bool {
        self.plane.invariant_ok()
    }

    pub fn tracker_live(&self) -> Vec<NodeId> {
        self.tracker.live_set()
    }

    /// Install a recovery snapshot from a live donor (§3): state + logs
    /// replace the stale copies, landed-but-unapplied buffers clear, and
    /// the transfer occupies the replica for a modeled copy time.
    pub fn install_snapshot(&mut self, plane: DataPlane, logs: Vec<crate::smr::log::ReplicationLog>, now: Time) {
        self.plane = plane;
        self.logs = logs;
        self.pending_reducible.clear();
        self.pending_irreducible.clear();
        self.sum_buffer.clear();
        self.busy_until = self.busy_until.max(now) + 50_000; // 50 µs transfer
        self.busy_total += 50_000;
    }

    /// Donor side of the snapshot.
    pub fn snapshot_state(&self) -> (DataPlane, Vec<crate::smr::log::ReplicationLog>) {
        (self.plane.snapshot(), self.logs.clone())
    }

    /// Diagnostic snapshot for runaway-loop debugging.
    pub fn debug_status(&self) -> String {
        let mu_q: usize = self.mu.iter().map(|m| m.queue_len()).sum();
        let mu_idle: Vec<bool> = self.mu.iter().map(|m| m.is_idle()).collect();
        format!(
            "id={} crashed={} quota={} leader={} pending_fwd={} requesters={} mu_q={} mu_idle={:?} busy_until={}",
            self.id, self.crashed, self.quota, self.leader,
            self.pending_fwd.len(), self.requesters.len(), mu_q, mu_idle, self.busy_until
        )
    }
}

/// Rewrite a locally-validated conflicting op into its commutative delta
/// form for summarized propagation (§5.4): debits become negative
/// deposits. Only meaningful for scalar-balance types; other conflicting
/// ops pass through unchanged (their apply is set-idempotent).
pub fn normalize_for_summary(plane: &DataPlane, mut op: OpCall) -> OpCall {
    use crate::engine::store::{KvKind, KV_WITHDRAW, KV_WRITE};
    match plane {
        DataPlane::Kv(kv) if kv.kind == KvKind::SmallBank && op.opcode == KV_WITHDRAW => {
            op.opcode = KV_WRITE;
            op.x = -op.x;
            op
        }
        DataPlane::Micro(r) if r.kind() == crate::rdt::RdtKind::Account => {
            use crate::rdt::wrdt::account::{OP_DEPOSIT, OP_WITHDRAW};
            if op.opcode == OP_WITHDRAW {
                op.opcode = OP_DEPOSIT;
                op.x = -op.x;
            }
            op
        }
        _ => op,
    }
}

/// How a reducible op stream aggregates (§2.1 "summarizable").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SummarizeRule {
    /// Sum deltas per (opcode, key): counters, deposits.
    SumDelta,
    /// Keep only the highest-timestamp write per key: LWW registers, YCSB.
    LastWrite,
    /// Not scalar-summable (set inserts): ship the batch as-is — still one
    /// verb per op on the wire, but flushed together.
    ShipAll,
}

/// Aggregate a run of reducible ops under a type-correct rule.
pub fn summarize(rule: SummarizeRule, ops: &[OpCall]) -> Vec<OpCall> {
    use std::collections::BTreeMap;
    match rule {
        SummarizeRule::ShipAll => ops.to_vec(),
        SummarizeRule::SumDelta => {
            let mut agg: BTreeMap<(u8, u64), OpCall> = BTreeMap::new();
            for op in ops {
                let e = agg.entry((op.opcode, op.b)).or_insert_with(|| {
                    let mut z = *op;
                    z.a = 0;
                    z.x = 0.0;
                    z
                });
                e.a += op.a;
                e.x += op.x;
                e.seq = e.seq.max(op.seq);
            }
            agg.into_values().collect()
        }
        SummarizeRule::LastWrite => {
            let mut best: BTreeMap<u64, OpCall> = BTreeMap::new();
            for op in ops {
                let e = best.entry(op.b).or_insert(*op);
                // op.a is the LWW timestamp for both the micro register and
                // the YCSB KV path.
                if op.a > e.a {
                    *e = *op;
                }
            }
            best.into_values().collect()
        }
    }
}
