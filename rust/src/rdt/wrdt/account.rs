//! Bank Account WRDT (Table B.1): scalar balance B.
//!
//! * deposit(d)  — reducible (sums locally, propagates a summary).
//! * withdraw(w) — conflicting, permissible iff B - w >= 0; one sync group.
//!
//! Invariant: B >= 0 always. This is the paper's running example (§2.1) and
//! the WRDT used in Figs 6, 14, 24. The batched form of the withdraw guard
//! is the `account_guard` Pallas artifact.

use crate::rdt::{mix_f64, Category, OpCall, QueryValue, Rdt, RdtKind};
use crate::util::rng::Rng;

pub const OP_DEPOSIT: u8 = 0;
pub const OP_WITHDRAW: u8 = 1;

const EPS: f64 = 1e-9;

#[derive(Clone, Debug)]
pub struct Account {
    balance: f64,
}

impl Default for Account {
    fn default() -> Self {
        // Seed balance so early withdrawals in workloads are not all
        // rejected; the invariant holds from the start.
        Account { balance: 1_000.0 }
    }
}

impl Account {
    pub fn balance(&self) -> f64 {
        self.balance
    }
}

impl Rdt for Account {
    fn clone_box(&self) -> Box<dyn Rdt> {
        Box::new(self.clone())
    }

    fn kind(&self) -> RdtKind {
        RdtKind::Account
    }

    fn category(&self, opcode: u8) -> Category {
        match opcode {
            OP_DEPOSIT => Category::Reducible,
            OP_WITHDRAW => Category::Conflicting,
            _ => Category::Reducible, // query never routed
        }
    }

    fn sync_group(&self, _opcode: u8) -> u8 {
        0
    }

    fn sync_groups(&self) -> u8 {
        1
    }

    fn permissible(&self, op: &OpCall) -> bool {
        match op.opcode {
            // Negative deposits arrive only as summarized, origin-validated
            // debit deltas (§5.4); fresh client deposits are non-negative.
            OP_DEPOSIT => true,
            OP_WITHDRAW => op.x >= 0.0 && self.balance - op.x >= -EPS,
            _ => op.is_query(),
        }
    }

    fn apply(&mut self, op: &OpCall) -> bool {
        match op.opcode {
            OP_DEPOSIT => {
                self.balance += op.x;
                true
            }
            OP_WITHDRAW => {
                if self.balance - op.x >= -EPS {
                    self.balance -= op.x;
                    true
                } else {
                    false // impermissible at execution: rejected, state unchanged
                }
            }
            _ => unreachable!("account opcode {}", op.opcode),
        }
    }

    fn apply_forced(&mut self, op: &OpCall) -> bool {
        match op.opcode {
            OP_WITHDRAW => {
                // Leader-accepted withdrawal: unconditional (the leader's
                // view was conservative; see trait docs).
                self.balance -= op.x;
                true
            }
            _ => self.apply(op),
        }
    }

    fn query(&self) -> QueryValue {
        QueryValue::Float(self.balance)
    }

    fn state_digest(&self) -> u64 {
        // Round to cents before hashing: deposit summaries may fold f64
        // additions in different orders across replicas.
        mix_f64((self.balance * 100.0).round() / 100.0)
    }

    fn invariant_ok(&self) -> bool {
        self.balance >= -1e-6
    }

    fn debug_dump(&self) -> String {
        format!("balance={:.6}", self.balance)
    }

    fn gen_update(&self, rng: &mut Rng) -> OpCall {
        if rng.gen_bool(0.5) {
            OpCall::new(OP_DEPOSIT, 0, 0, rng.gen_f64_range(1.0, 50.0))
        } else {
            OpCall::new(OP_WITHDRAW, 0, 0, rng.gen_f64_range(1.0, 80.0))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn deposit(x: f64) -> OpCall {
        OpCall::new(OP_DEPOSIT, 0, 0, x)
    }

    fn withdraw(x: f64) -> OpCall {
        OpCall::new(OP_WITHDRAW, 0, 0, x)
    }

    #[test]
    fn categories_match_table_b1() {
        let a = Account::default();
        assert_eq!(a.category(OP_DEPOSIT), Category::Reducible);
        assert_eq!(a.category(OP_WITHDRAW), Category::Conflicting);
        assert_eq!(a.sync_groups(), 1);
    }

    #[test]
    fn overdraft_rejected() {
        let mut a = Account::default();
        let w = withdraw(5_000.0);
        assert!(!a.permissible(&w));
        assert!(!a.apply(&w), "execution re-check also rejects");
        assert!(a.invariant_ok());
        assert_eq!(a.balance(), 1_000.0);
    }

    #[test]
    fn exact_drain_permissible() {
        let mut a = Account::default();
        assert!(a.apply(&withdraw(1_000.0)));
        assert!(a.balance().abs() < 1e-9);
        assert!(a.invariant_ok());
    }

    #[test]
    fn deposits_commute() {
        let mut a = Account::default();
        let mut b = Account::default();
        a.apply(&deposit(10.0));
        a.apply(&deposit(7.0));
        b.apply(&deposit(7.0));
        b.apply(&deposit(10.0));
        assert_eq!(a.state_digest(), b.state_digest());
    }

    #[test]
    fn concurrent_withdraw_hazard_needs_ordering() {
        // The §2.1 motivating example: two locally-permissible withdrawals
        // can jointly overdraft — exactly why withdraw is conflicting.
        let a = Account::default(); // 1000
        let w = withdraw(600.0);
        assert!(a.permissible(&w));
        let mut serial = Account::default();
        assert!(serial.apply(&w));
        assert!(!serial.apply(&w), "second 600 must be rejected in total order");
        assert!(serial.invariant_ok());
    }
}
