//! Fig 27 / §5.5: peak power — SafarDB (whole Alveo U280 card) vs Hamband
//! (CPU + RNIC + memory), averaged over CRDT and WRDT use cases.
//!
//! Expected: ≈35 W vs ≈160 W (≈4.5× less), with ≈2/3 of Hamband's power in
//! the CPU.

use crate::config::{SimConfig, WorkloadKind};
use crate::expt::common::{cell_ops, run_cells_tagged};
use crate::rdt::RdtKind;
use crate::util::stats::Summary;
use crate::util::table::Table;

pub fn run(quick: bool) -> Vec<Table> {
    let mut t = Table::new(
        "Fig 27 — power consumption (W)",
        &["system", "workload-class", "total_w", "compute_w", "io_w"],
    );
    let classes: &[(&str, &[RdtKind])] = &[
        ("CRDTs", RdtKind::crdt_benchmarks()),
        ("WRDTs", RdtKind::wrdt_benchmarks()),
    ];
    // Flat job list over (system, class, rdt); rows aggregate per group.
    let mut jobs = Vec::new();
    for system in ["SafarDB", "Hamband"] {
        for (class, kinds) in classes {
            for &rdt in kinds.iter() {
                if quick && rdt != kinds[0] && rdt != kinds[kinds.len() - 1] {
                    continue;
                }
                let mut cfg = match system {
                    "SafarDB" => SimConfig::safardb(WorkloadKind::Micro(rdt)),
                    _ => SimConfig::hamband(WorkloadKind::Micro(rdt)),
                };
                cfg.update_pct = 20;
                jobs.push(((system, *class), (cfg, cell_ops(quick))));
            }
        }
    }
    let results = run_cells_tagged(jobs);
    for system in ["SafarDB", "Hamband"] {
        for (class, _) in classes {
            let mut total = Summary::new();
            let mut compute = Summary::new();
            let mut io = Summary::new();
            for ((msys, mclass), _, rep) in &results {
                if *msys != system || mclass != class {
                    continue;
                }
                total.add(rep.power.total_w());
                compute.add(rep.power.static_w + rep.power.dynamic_w);
                io.add(rep.power.io_w);
            }
            t.row(vec![
                system.into(),
                class.to_string(),
                format!("{:.1}", total.mean()),
                format!("{:.1}", compute.mean()),
                format!("{:.1}", io.mean()),
            ]);
        }
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn power_ratio_matches_paper() {
        let t = &run(true)[0];
        let mean = |sys: &str| -> f64 {
            let v: Vec<f64> = t
                .rows()
                .iter()
                .filter(|r| r[0] == sys)
                .map(|r| r[2].parse().unwrap())
                .collect();
            v.iter().sum::<f64>() / v.len() as f64
        };
        let s = mean("SafarDB");
        let h = mean("Hamband");
        assert!((30.0..42.0).contains(&s), "SafarDB {s} W (paper ~35)");
        assert!((130.0..180.0).contains(&h), "Hamband {h} W (paper ~160)");
        let ratio = h / s;
        assert!((3.5..5.5).contains(&ratio), "ratio {ratio} (paper ~4.5x)");
    }
}
