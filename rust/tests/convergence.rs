//! Integration: convergence + integrity across every RDT, both systems,
//! and every propagation mode — seeded-random property runs (util::prop).
//!
//! Convergence (all live replicas reach bit-identical state at quiescence)
//! and integrity (Table B.1 invariants hold) are the paper's correctness
//! claims; every experiment asserts them too, but these tests sweep the
//! configuration space much wider.

use safardb::config::{CatalogSpec, PropagationMode, SimConfig, SystemKind, WorkloadKind};
use safardb::engine::cluster;
use safardb::prop_assert;
use safardb::rdt::RdtKind;
use safardb::util::prop;

fn all_kinds() -> Vec<RdtKind> {
    let mut v = RdtKind::crdt_benchmarks().to_vec();
    v.extend_from_slice(RdtKind::wrdt_benchmarks());
    v
}

#[test]
fn every_rdt_converges_on_safardb() {
    for rdt in all_kinds() {
        let mut cfg = SimConfig::safardb(WorkloadKind::Micro(rdt));
        cfg.total_ops = 12_000;
        cfg.update_pct = 30;
        let rep = cluster::run(cfg);
        assert!(rep.converged(), "{} diverged: {:?}", rdt.name(), rep.digests);
        assert!(rep.invariants_ok, "{} violated integrity", rdt.name());
    }
}

#[test]
fn every_rdt_converges_on_hamband() {
    for rdt in all_kinds() {
        let mut cfg = SimConfig::hamband(WorkloadKind::Micro(rdt));
        cfg.total_ops = 8_000;
        cfg.update_pct = 30;
        let rep = cluster::run(cfg);
        assert!(rep.converged(), "{} diverged: {:?}", rdt.name(), rep.digests);
        assert!(rep.invariants_ok, "{} violated integrity", rdt.name());
    }
}

#[test]
fn all_propagation_modes_converge() {
    let modes = [
        PropagationMode::WriteNoBuffer,
        PropagationMode::WriteBuffered,
        PropagationMode::Rpc,
    ];
    for red in modes {
        for con in [PropagationMode::WriteNoBuffer, PropagationMode::WriteThrough] {
            for rdt in [RdtKind::PnCounter, RdtKind::Account, RdtKind::Auction] {
                let mut cfg = SimConfig::safardb(WorkloadKind::Micro(rdt));
                cfg.prop_reducible = red;
                cfg.prop_irreducible = if red == PropagationMode::Rpc {
                    PropagationMode::Rpc
                } else {
                    PropagationMode::WriteNoBuffer
                };
                cfg.prop_conflicting = con;
                cfg.total_ops = 10_000;
                cfg.update_pct = 25;
                let rep = cluster::run(cfg);
                assert!(
                    rep.converged() && rep.invariants_ok,
                    "{} {red:?}/{con:?} failed",
                    rdt.name()
                );
            }
        }
    }
}

#[test]
fn prop_random_configs_converge() {
    // Seeded random sweep: rdt x system x nodes x update% x clients.
    prop::check("random-cluster-convergence", 0xfeed, 24, |rng| {
        let kinds = all_kinds();
        let rdt = *rng.choose(&kinds);
        let system = if rng.gen_bool(0.5) { SystemKind::SafarDb } else { SystemKind::Hamband };
        let mut cfg = match system {
            SystemKind::SafarDb => SimConfig::safardb(WorkloadKind::Micro(rdt)),
            _ => SimConfig::hamband(WorkloadKind::Micro(rdt)),
        };
        cfg.n_replicas = 3 + rng.gen_range(6) as usize;
        cfg.update_pct = 5 + rng.gen_range(45) as u8;
        cfg.clients_per_replica = 1 + rng.gen_range(6) as usize;
        cfg.total_ops = 4_000 + rng.gen_range(6_000);
        cfg.seed = rng.next_u64();
        let label = format!("{} {} n={} u={}", system.name(), rdt.name(), cfg.n_replicas, cfg.update_pct);
        let rep = cluster::run(cfg);
        prop_assert!(rep.converged(), "{label}: diverged {:?}", rep.digests);
        prop_assert!(rep.invariants_ok, "{label}: integrity violated");
        Ok(())
    });
}

#[test]
fn prop_summarization_preserves_state() {
    // Batching must change timing only, never the converged state value.
    prop::check("summarize-conservation", 0xbeef, 12, |rng| {
        let rdt = *rng.choose(&[RdtKind::PnCounter, RdtKind::Account, RdtKind::GSet]);
        let seed = rng.next_u64();
        let digest_at = |threshold: u32| {
            let mut cfg = SimConfig::safardb(WorkloadKind::Micro(rdt));
            cfg.summarize_threshold = threshold;
            cfg.total_ops = 6_000;
            cfg.update_pct = 40;
            cfg.seed = seed;
            let rep = cluster::run(cfg);
            assert!(rep.converged(), "{} t={threshold} diverged", rdt.name());
            // §5.4: batching defers coordination, so the balance invariant
            // can be transiently violated by stale-window debits — the
            // integrity/staleness trade-off the paper calls out. Conflict-
            // free types must always keep their (trivial) invariants.
            if !(rdt == RdtKind::Account && threshold > 1) {
                assert!(rep.invariants_ok, "{} t={threshold} invariant", rdt.name());
            }
            rep.digests[0]
        };
        let base = digest_at(1);
        let batched = digest_at(5);
        // Same seed => same issued ops => same converged state (counters
        // and deposits aggregate associatively; Account withdraw outcomes
        // can differ in *rejections* under different interleavings, so we
        // only require exact equality for conflict-free types).
        if rdt != RdtKind::Account {
            prop_assert!(base == batched, "{}: summarization changed state", rdt.name());
        }
        Ok(())
    });
}

#[test]
fn ycsb_and_smallbank_converge_across_systems() {
    for workload in [WorkloadKind::Ycsb, WorkloadKind::SmallBank] {
        for system in [SystemKind::SafarDb, SystemKind::Hamband] {
            let mut cfg = match system {
                SystemKind::SafarDb => SimConfig::safardb(workload),
                _ => SimConfig::hamband(workload),
            };
            cfg.total_ops = 10_000;
            cfg.update_pct = 30;
            let rep = cluster::run(cfg);
            assert!(rep.converged() && rep.invariants_ok, "{} {:?}", system.name(), workload);
        }
    }
}

#[test]
fn waverunner_converges_and_only_leader_commits() {
    let mut cfg = SimConfig::waverunner(WorkloadKind::Ycsb);
    cfg.total_ops = 9_000;
    cfg.update_pct = 40;
    let rep = cluster::run(cfg);
    assert!(rep.converged());
    assert!(rep.metrics.smr_commits > 0, "PUTs go through Raft");
}

#[test]
fn prop_mixed_catalog_converges_per_object() {
    // Multi-object catalogs: random mixes of CRDTs, WRDTs, and KV tenants
    // under random skew — every live replica must end byte-equal on every
    // object, not just on the combined digest.
    prop::check("catalog-convergence", 0x0B1EC7, 10, |rng| {
        let pool = [
            "counter", "lww", "gset", "2pset", "account", "courseware", "movie", "auction",
            "ycsb", "smallbank",
        ];
        let picks = 2 + rng.gen_range(3) as usize; // 2..=4 entry kinds
        let mut entries = Vec::new();
        for _ in 0..picks {
            let kind = *rng.choose(&pool);
            let count = 1 + rng.gen_range(3);
            entries.push(format!("{kind}:{count}"));
        }
        let spec = entries.join(",");
        let mut cfg = SimConfig::safardb(WorkloadKind::Micro(RdtKind::PnCounter));
        cfg.objects = CatalogSpec::parse(&spec).expect("generated spec parses");
        cfg.objects.zipf_theta = if rng.gen_bool(0.5) { 0.0 } else { 0.8 };
        cfg.n_replicas = 3 + rng.gen_range(4) as usize;
        cfg.update_pct = 30;
        cfg.total_ops = 6_000;
        cfg.seed = rng.next_u64();
        let n_objects = cfg.n_objects();
        let label = format!("catalog[{spec}] n={} theta={}", cfg.n_replicas, cfg.objects.zipf_theta);
        let rep = cluster::run(cfg);
        prop_assert!(rep.converged(), "{label}: combined digest diverged: {:?}", rep.digests);
        prop_assert!(
            rep.converged_per_object(),
            "{label}: per-object divergence: {:?}",
            rep.object_digests
        );
        prop_assert!(rep.invariants_ok, "{label}: integrity violated");
        prop_assert!(
            rep.object_digests.iter().all(|d| d.len() == n_objects),
            "{label}: object digest arity"
        );
        Ok(())
    });
}

#[test]
fn explicit_catalog_of_one_matches_default_config() {
    // Acceptance: a catalog-of-one must be bit-identical to the same
    // workload expressed the pre-catalog way — same digests, same event
    // stream (the generator takes the same draws, the engine the same
    // paths).
    for (spec, rdt) in [("account:1", RdtKind::Account), ("counter:1", RdtKind::PnCounter)] {
        let mut base = SimConfig::safardb(WorkloadKind::Micro(rdt));
        base.total_ops = 6_000;
        base.update_pct = 25;
        base.seed = 0xCA7A_0106;
        let mut cat = base.clone();
        cat.objects = CatalogSpec::parse(spec).unwrap();
        let a = cluster::run(base);
        let b = cluster::run(cat);
        assert_eq!(a.digests, b.digests, "{spec}: digests differ from default config");
        assert_eq!(a.metrics.events, b.metrics.events, "{spec}: event stream perturbed");
        assert_eq!(a.metrics.total_completed(), b.metrics.total_completed());
        assert_eq!(b.object_digests[0], vec![a.digests[0]], "{spec}: per-object digest");
    }
}

#[test]
fn determinism_same_seed_same_everything() {
    let make = || {
        let mut cfg = SimConfig::safardb(WorkloadKind::Micro(RdtKind::Auction));
        cfg.total_ops = 8_000;
        cfg.update_pct = 25;
        cfg.seed = 1234;
        cluster::run(cfg)
    };
    let a = make();
    let b = make();
    assert_eq!(a.digests, b.digests);
    assert_eq!(a.metrics.events, b.metrics.events);
    assert_eq!(a.metrics.makespan_ns, b.metrics.makespan_ns);
    assert_eq!(a.metrics.total_completed(), b.metrics.total_completed());
}
