//! Chaos integration (§3 fault model, generalized): randomized multi-fault
//! `FaultSchedule`s — crashes, recoveries, link partitions, packet loss,
//! delay spikes — across every consensus backend and both RDT classes,
//! with three oracles:
//!
//! * convergence — live replicas end bit-identical after quiescence;
//! * integrity  — `invariants_ok` (no overdraft etc.) despite duplicates
//!   from at-least-once retry paths (the leader re-checks permissibility
//!   in total-order position);
//! * detection  — every detected incident's heartbeat detection latency is
//!   bounded by the scan interval × miss threshold (plus one period of
//!   phase slack and the read round trip).

use safardb::config::{
    ArrivalProcess, CatalogSpec, ConsensusBackend, FaultAction, FaultSchedule, LeaderPlacement,
    SimConfig, WorkloadKind,
};
use safardb::engine::cluster;
use safardb::prop_assert;
use safardb::rdt::RdtKind;
use safardb::util::prop;

fn chaos_cfg(backend: ConsensusBackend, rdt: RdtKind, n: usize) -> SimConfig {
    let mut cfg = SimConfig::safardb(WorkloadKind::Micro(rdt));
    cfg.backend = backend;
    cfg.n_replicas = n;
    cfg.update_pct = 25;
    cfg.total_ops = 6_000;
    cfg
}

/// Detection-latency bound: `threshold` consecutive missed scans, plus one
/// scan period of phase offset, plus slack for the read round trip /
/// retransmission timeout (≪ one period).
fn detection_bound(cfg: &SimConfig) -> u64 {
    cfg.heartbeat_period_ns * (cfg.hb_fail_threshold as u64 + 3)
}

#[test]
fn prop_randomized_multi_fault_schedules_converge() {
    prop::check("chaos-schedules", 0xC4A05, 10, |rng| {
        let backend = *rng.choose(&ConsensusBackend::ALL);
        let kinds = [RdtKind::PnCounter, RdtKind::GSet, RdtKind::Account, RdtKind::Auction];
        let rdt = *rng.choose(&kinds);
        let n = 4 + rng.gen_range(3) as usize; // 4..=6
        // Three ascending watermarks with comfortable spacing.
        let p1 = 20 + rng.gen_range(20) as u8;
        let p2 = p1 + 15 + rng.gen_range(15) as u8;
        let p3 = p2 + 10 + rng.gen_range(10) as u8;
        let follower = 1 + rng.gen_range(n as u64 - 1) as usize;
        let mut sched = FaultSchedule::none();
        match rng.gen_range(5) {
            0 => {
                sched.push(p1, FaultAction::Crash { node: Some(follower) });
            }
            1 => {
                sched.push(p1, FaultAction::Crash { node: Some(follower) });
                sched.push(p2, FaultAction::Recover { node: follower });
            }
            2 => {
                // Single-link partition between two followers, healed.
                let a = 1 + rng.gen_range(n as u64 - 1) as usize;
                let b = if follower == a { 1 + (a % (n - 1)) } else { follower };
                sched.push(p1, FaultAction::PartitionLinks { a, b });
                sched.push(p2, FaultAction::HealLinks);
            }
            3 => {
                // The acceptance shape: a leader crash *during* a partition
                // (endpoints chosen so the successor keeps a majority).
                let a = 2 + rng.gen_range(n as u64 - 2) as usize;
                let b = if a == n - 1 { 2 } else { a + 1 };
                sched.push(p1, FaultAction::PartitionLinks { a, b });
                sched.push(p2, FaultAction::Crash { node: None });
                sched.push(p3, FaultAction::HealLinks);
            }
            _ => {
                let count = 1 + rng.gen_range(4) as u32;
                let factor = 150 + rng.gen_range(250) as u32;
                sched.push(p1, FaultAction::DropNext { src: 0, dst: follower, count });
                sched.push(p2, FaultAction::DelaySpike {
                    src: follower,
                    dst: 0,
                    factor_pct: factor,
                    until_pct: p3,
                });
            }
        }
        let label = format!("{} {} n={n} [{}]", backend.name(), rdt.name(), sched.label());
        let mut cfg = chaos_cfg(backend, rdt, n);
        cfg.fault = sched;
        cfg.seed = rng.next_u64();
        let bound = detection_bound(&cfg);
        let rep = cluster::run(cfg);
        prop_assert!(rep.converged(), "{label}: diverged: {:?}", rep.digests);
        prop_assert!(rep.invariants_ok, "{label}: integrity broke");
        for inc in &rep.fault_timeline {
            if let Some(d) = inc.detect_ns {
                let lat = d - inc.injected_ns;
                prop_assert!(
                    lat <= bound,
                    "{label}: {} detection latency {lat}ns exceeds bound {bound}ns",
                    inc.label
                );
            }
        }
        Ok(())
    });
}

#[test]
fn leader_crash_during_partition_converges_on_all_backends() {
    // The acceptance scenario, pinned: the leader crashes while a link
    // between its eventual successor and another follower is down; the
    // cluster re-elects, commits around the cut, and reconciles at heal.
    for backend in ConsensusBackend::ALL {
        let mut cfg = chaos_cfg(backend, RdtKind::Account, 5);
        cfg.total_ops = 10_000;
        cfg.seed = 0x5AFA_C4A0;
        cfg.fault = FaultSchedule::parse("partition@40:1-2,crash@50:leader,heal@70").unwrap();
        let bound = detection_bound(&cfg);
        let rep = cluster::run(cfg);
        let b = backend.name();
        assert!(rep.crashed[0], "{b}: initial leader stays down");
        assert_ne!(rep.leader, 0, "{b}: a successor leads");
        assert!(rep.metrics.elections >= 1, "{b}: re-election happened");
        assert!(
            rep.converged(),
            "{b}: diverged: {:?}\n{}",
            rep.digests,
            rep.dumps.join("\n---\n")
        );
        assert!(rep.invariants_ok, "{b}: integrity broke");
        assert!(rep.metrics.smr_commits > 0, "{b}: strong path unexercised");

        // Per-incident timeline: partition, crash (resolved to node 0),
        // heal — with the crash detected inside the heartbeat bound and a
        // non-zero unavailability window ending at the election.
        assert_eq!(rep.fault_timeline.len(), 3, "{b}: all incidents fired");
        assert_eq!(rep.fault_timeline[0].label, "partition:1-2");
        assert_eq!(rep.fault_timeline[1].label, "crash:0");
        assert_eq!(rep.fault_timeline[2].label, "heal");
        let crash = &rep.fault_timeline[1];
        let d = crash.detect_ns.expect("leader crash must be detected");
        assert!(d - crash.injected_ns <= bound, "{b}: detection within heartbeat bound");
        assert!(crash.unavailable_ns > 0, "{b}: unavailability window recorded");
        assert!(crash.elections >= 1, "{b}: election attributed to the crash incident");
    }
}

#[test]
fn lossy_and_slow_links_converge_on_all_backends() {
    // Packet loss on the leader's outbound link plus a delay spike on the
    // return path: retries (relaxed), NACK-driven stalls (Mu/Paxos), and
    // the gap-backfill protocol (Raft) must all absorb it.
    for backend in ConsensusBackend::ALL {
        let mut cfg = chaos_cfg(backend, RdtKind::Account, 4);
        cfg.total_ops = 8_000;
        cfg.seed = 0x5AFA_D407;
        cfg.fault = FaultSchedule::parse("drop@25:0-1x3,delay@35:2-0x300u65").unwrap();
        let rep = cluster::run(cfg);
        let b = backend.name();
        assert!(rep.converged(), "{b}: diverged: {:?}", rep.digests);
        assert!(rep.invariants_ok, "{b}: integrity broke");
        assert!(rep.crashed.iter().all(|&c| !c), "{b}: nobody crashed");
        assert!(rep.metrics.verbs > 0, "{b}: traffic flowed");
    }
}

#[test]
fn kv_workload_survives_partition_with_flaky_links() {
    // YCSB (LWW keyspace) under a healed partition + drops: exercises the
    // summarized relaxed path's retry/dedup machinery end to end.
    let mut cfg = SimConfig::safardb(WorkloadKind::Ycsb);
    cfg.n_replicas = 4;
    cfg.update_pct = 25;
    cfg.total_ops = 8_000;
    cfg.seed = 0x5AFA_9C5B;
    cfg.fault = FaultSchedule::parse("partition@30:1-3,drop@40:0-2x2,heal@60").unwrap();
    let rep = cluster::run(cfg);
    assert!(rep.converged(), "diverged: {:?}", rep.digests);
    assert!(rep.invariants_ok);
    assert_eq!(rep.fault_timeline.len(), 3);
}

#[test]
fn recovering_node_reconciles_updates_outstanding_at_every_donor() {
    // Pinned regression for the ROADMAP "chaos second-order anti-entropy"
    // item: node 3 crashes while node 1 is partitioned from nodes 0 and 2.
    // Node 1's relaxed propagations to node 3 exhaust their retry budget
    // during the long crash window (the short heartbeat period makes the
    // 64-retry cap burn in ~320 µs of virtual time), and no snapshot donor
    // has node 1's updates either (its retries to them are NACKing on the
    // cut links). Pre-fix, node 3 recovered from a donor that never saw
    // those updates and nothing ever re-shipped them — a silent loss. The
    // post-install reconciliation pull across *all* live peers (donor-set
    // union) plus the heal-time re-arm must now converge every backend.
    for backend in ConsensusBackend::ALL {
        let mut cfg = chaos_cfg(backend, RdtKind::PnCounter, 4);
        cfg.total_ops = 8_000;
        cfg.heartbeat_period_ns = 5_000;
        cfg.seed = 0x5AFA_2A17;
        cfg.fault = FaultSchedule::parse(
            "partition@15:0-1,partition@15:1-2,crash@20:3,recover@60:3,heal@80",
        )
        .unwrap();
        let rep = cluster::run(cfg);
        let b = backend.name();
        assert!(!rep.crashed[3], "{b}: node 3 must be back");
        assert!(
            rep.converged(),
            "{b}: recovered node lost an update outstanding at every donor: {:?}",
            rep.digests
        );
        assert!(rep.converged_per_object(), "{b}: per-object divergence");
        assert!(rep.invariants_ok, "{b}: integrity broke");
    }
}

#[test]
fn mixed_catalog_converges_under_chaos_schedule() {
    // Acceptance: the mixed-catalog convergence property holds under a
    // chaos schedule — partition + leader crash + heal over a
    // heterogeneous object catalog, on every backend.
    for backend in ConsensusBackend::ALL {
        let mut cfg = chaos_cfg(backend, RdtKind::Account, 5);
        cfg.objects = safardb::config::CatalogSpec::mixed();
        cfg.objects.zipf_theta = 0.6;
        cfg.total_ops = 8_000;
        cfg.seed = 0x5AFA_CA7A;
        cfg.fault = FaultSchedule::parse("partition@40:1-2,crash@50:leader,heal@70").unwrap();
        let rep = cluster::run(cfg);
        let b = backend.name();
        assert!(rep.metrics.elections >= 1, "{b}: re-election happened");
        assert!(
            rep.converged() && rep.converged_per_object(),
            "{b}: mixed catalog diverged under chaos: {:?}",
            rep.object_digests
        );
        assert!(rep.invariants_ok, "{b}: integrity broke");
        assert!(rep.metrics.smr_commits > 0, "{b}: strong path unexercised");
    }
}

fn sharded_cfg(backend: ConsensusBackend, placement: LeaderPlacement) -> SimConfig {
    let mut cfg = chaos_cfg(backend, RdtKind::Account, 5);
    cfg.objects = CatalogSpec::parse("account:16").unwrap();
    cfg.objects.zipf_theta = 0.6;
    cfg.placement = placement;
    cfg.total_ops = 8_000;
    cfg
}

#[test]
fn crashing_a_multi_group_leader_reelects_every_group() {
    // Under hash placement at n=5 with 16 groups, node 0 leads several
    // groups (rendezvous spread). Crashing it must rebalance *every* group
    // it led onto survivors — no orphaned groups — with the crash detected
    // inside the heartbeat bound, and the run still converging.
    for backend in ConsensusBackend::ALL {
        let mut cfg = sharded_cfg(backend, LeaderPlacement::Hash);
        cfg.seed = 0x5AFA_541D;
        cfg.fault = FaultSchedule::parse("crash@40:0").unwrap();
        let bound = detection_bound(&cfg);
        let rep = cluster::run(cfg);
        let b = backend.name();
        assert!(rep.crashed[0], "{b}: node 0 stays down");
        assert_eq!(rep.group_leaders.len(), 16, "{b}: one leader slot per group");
        assert!(
            rep.group_leaders.iter().all(|&l| l != 0),
            "{b}: orphaned groups still led by the dead node: {:?}",
            rep.group_leaders
        );
        assert_eq!(rep.groups_led[0], 0, "{b}: dead node leads nothing");
        assert_eq!(
            rep.groups_led.iter().sum::<u64>(),
            16,
            "{b}: every group has exactly one leader: {:?}",
            rep.groups_led
        );
        assert!(rep.metrics.elections >= 1, "{b}: takeover counted as an election");
        let crash = &rep.fault_timeline[0];
        let d = crash.detect_ns.expect("crash must be detected");
        assert!(
            d - crash.injected_ns <= bound,
            "{b}: detection latency {}ns exceeds bound {bound}ns",
            d - crash.injected_ns
        );
        assert!(rep.converged() && rep.converged_per_object(), "{b}: diverged: {:?}", rep.digests);
        assert!(rep.invariants_ok, "{b}: integrity broke");
    }
}

#[test]
fn recovered_leader_rejoins_as_follower_under_load_aware() {
    // Regression guard for the rejoin-reclaims-leadership bug class: under
    // placement=load_aware, a crashed multi-group leader that recovers
    // installs the *rebalanced* placement from its snapshot donor and
    // rejoins as a follower of its former groups — it must not resurrect
    // its pre-crash leadership (which would split every group's log).
    for backend in ConsensusBackend::ALL {
        let mut cfg = sharded_cfg(backend, LeaderPlacement::LoadAware);
        cfg.seed = 0x5AFA_4E10;
        cfg.fault = FaultSchedule::parse("crash@35:0,recover@65:0").unwrap();
        let rep = cluster::run(cfg);
        let b = backend.name();
        assert!(!rep.crashed[0], "{b}: node 0 must be back");
        assert_eq!(
            rep.groups_led[0], 0,
            "{b}: recovered ex-leader reclaimed leadership: {:?}",
            rep.groups_led
        );
        assert_eq!(
            rep.groups_led.iter().sum::<u64>(),
            16,
            "{b}: every group still has exactly one leader: {:?}",
            rep.groups_led
        );
        assert!(
            rep.converged() && rep.converged_per_object(),
            "{b}: diverged after rejoin: {:?}\n{}",
            rep.digests,
            rep.dumps.join("\n---\n")
        );
        assert!(rep.invariants_ok, "{b}: integrity broke");
    }
}

#[test]
fn partitioned_sharded_placements_converge_after_heal() {
    // The PR-8 tentpole, pinned across the full matrix: a follower-pair
    // partition under every sharded placement × every backend. Each cut
    // endpoint mis-declares the other dead and re-places its groups —
    // possibly onto itself, making it a per-group minority imposter. The
    // per-group QP fence NACKs every leader-write the imposter issues for
    // a group it does not rightfully lead, so its lease never confirms and
    // it mutates nothing (structurally enforced; observable here as
    // post-heal convergence + integrity). At heal, the cluster realigns
    // the endpoints to the authority placement view, nudges unconfirmed
    // campaigns into per-group abdication, and every inheriting leader
    // re-pulls its shards to the starved endpoints.
    for backend in ConsensusBackend::ALL {
        for placement in
            [LeaderPlacement::Hash, LeaderPlacement::RoundRobin, LeaderPlacement::LoadAware]
        {
            let mut cfg = sharded_cfg(backend, placement);
            cfg.seed = 0x5AFA_8A1D;
            cfg.fault = FaultSchedule::parse("partition@40:1-2,heal@70").unwrap();
            let rep = cluster::run(cfg);
            let lbl = format!("{}/{}", backend.name(), placement.name());
            assert!(rep.crashed.iter().all(|&c| !c), "{lbl}: nobody crashed");
            assert_eq!(rep.fault_timeline.len(), 2, "{lbl}: both incidents fired");
            assert_eq!(rep.fault_timeline[0].label, "partition:1-2");
            assert_eq!(rep.fault_timeline[1].label, "heal");
            assert_eq!(
                rep.groups_led.iter().sum::<u64>(),
                16,
                "{lbl}: every group has exactly one leader after the heal: {:?}",
                rep.groups_led
            );
            assert!(
                rep.converged() && rep.converged_per_object(),
                "{lbl}: diverged after heal: {:?}\n{}",
                rep.digests,
                rep.dumps.join("\n---\n")
            );
            assert!(rep.invariants_ok, "{lbl}: integrity broke (imposter mutated state)");
            assert!(rep.metrics.smr_commits > 0, "{lbl}: strong path unexercised");
        }
    }
}

#[test]
fn leader_crash_during_partition_converges_under_sharded_placements() {
    // The harder shape: the anchor leader crashes *while* a follower pair
    // is partitioned, so group re-placement runs on divergent live views —
    // the cut endpoints each compute a different placement than the
    // majority. Heal-time realign must reconcile all of them before the
    // convergence check.
    for backend in ConsensusBackend::ALL {
        for placement in
            [LeaderPlacement::Hash, LeaderPlacement::RoundRobin, LeaderPlacement::LoadAware]
        {
            let mut cfg = sharded_cfg(backend, placement);
            cfg.seed = 0x5AFA_8A2E;
            cfg.fault = FaultSchedule::parse("partition@40:1-2,crash@50:leader,heal@70").unwrap();
            let rep = cluster::run(cfg);
            let lbl = format!("{}/{}", backend.name(), placement.name());
            assert!(rep.crashed[0], "{lbl}: crashed anchor stays down");
            assert_eq!(rep.groups_led[0], 0, "{lbl}: dead node leads nothing");
            assert_eq!(
                rep.groups_led.iter().sum::<u64>(),
                16,
                "{lbl}: every group has exactly one leader: {:?}",
                rep.groups_led
            );
            assert!(rep.metrics.elections >= 1, "{lbl}: takeover counted as an election");
            assert!(
                rep.converged() && rep.converged_per_object(),
                "{lbl}: diverged: {:?}\n{}",
                rep.digests,
                rep.dumps.join("\n---\n")
            );
            assert!(rep.invariants_ok, "{lbl}: integrity broke");
        }
    }
}

#[test]
fn crashed_origins_partial_update_is_regossiped_by_receivers() {
    // Pinned regression for the ROADMAP "crashed-origin relaxed durability
    // gap": node 1 is cut from node 0 (partition@15), keeps originating
    // relaxed updates that reach nodes 2 and 3 but NACK-park toward node
    // 0, then crashes (crash@25). Its snapshot donor at recover@60 is node
    // 0 — the one replica that never saw those updates — and the install
    // wipes node 1's own retry/parked ledgers, so pre-fix nothing ever
    // re-shipped them to node 0 (or back to node 1): a silent loss,
    // diverging {0,1} from {2,3}. Post-fix, the surviving receivers'
    // per-origin re-gossip ledgers re-ship node 1's accepted updates to
    // every peer at install time; the dedup ledgers absorb duplicates.
    for backend in ConsensusBackend::ALL {
        let mut cfg = chaos_cfg(backend, RdtKind::PnCounter, 4);
        cfg.total_ops = 8_000;
        cfg.heartbeat_period_ns = 5_000;
        cfg.seed = 0x5AFA_0161;
        cfg.fault =
            FaultSchedule::parse("partition@15:0-1,crash@25:1,recover@60:1,heal@80").unwrap();
        let rep = cluster::run(cfg);
        let b = backend.name();
        assert!(!rep.crashed[1], "{b}: the origin must be back");
        assert!(
            rep.converged(),
            "{b}: crashed origin's partially-propagated update was lost: {:?}",
            rep.digests
        );
        assert!(rep.converged_per_object(), "{b}: per-object divergence");
        assert!(rep.invariants_ok, "{b}: integrity broke");
    }
}

#[test]
fn open_loop_crash_drains_and_balances_the_books_on_all_backends() {
    // Pinned regression for the open-loop drain hang: a crash sheds the
    // dead node's admission queue and kills its in-flight ops, and the
    // victims' arrival streams are gone — so `no_pending_clients()` must
    // not count a crashed node's queue (or shed entries anywhere) as
    // pending work, or the post-crash drain would wait forever on ops
    // nobody will ever serve. The run must terminate with the stream
    // budget fully offered and the books balanced:
    // offered = completed + shed + crash_killed.
    for backend in ConsensusBackend::ALL {
        let mut cfg = chaos_cfg(backend, RdtKind::Account, 4);
        cfg.arrival = ArrivalProcess::Poisson { rate: 2_000_000 };
        cfg.queue_cap = 8;
        cfg.seed = 0x10AD_C4A5;
        cfg.fault = FaultSchedule::parse("crash@30:2").unwrap();
        let rep = cluster::run(cfg);
        let b = backend.name();
        assert!(rep.crashed[2], "{b}: node 2 stays down");
        assert!(rep.converged(), "{b}: diverged: {:?}", rep.digests);
        assert!(rep.invariants_ok, "{b}: integrity broke");
        let m = &rep.metrics;
        assert_eq!(
            m.offered, 6_000,
            "{b}: redistributed arrival streams must offer the whole budget"
        );
        assert_eq!(
            m.offered,
            m.total_completed() + m.shed + m.crash_killed,
            "{b}: open-loop crash accounting leaked ops (completed={} shed={} killed={})",
            m.total_completed(),
            m.shed,
            m.crash_killed
        );
        assert!(m.crash_killed > 0, "{b}: the crash killed queued/in-flight ops");
    }
}

#[test]
fn empty_schedule_reports_empty_timeline() {
    let cfg = chaos_cfg(ConsensusBackend::Mu, RdtKind::PnCounter, 4);
    let rep = cluster::run(cfg);
    assert!(rep.fault_timeline.is_empty());
    assert!(rep.converged() && rep.invariants_ok);
    assert_eq!(rep.metrics.detections.len(), 0, "no failure declared on a clean run");
}
