//! Mu [3] leader-side state machine (§4.4 Replication Plane), one instance
//! per synchronization group.
//!
//! Per conflicting transaction the leader runs, as the paper describes:
//!   Prepare: RDMA-read followers' min-proposal registers → RDMA-write the
//!   next highest proposal number → RDMA-read the target log slot at each
//!   follower (adopting the highest-proposal non-empty entry if any) →
//!   Accept: execute and RDMA-write the entry to followers' logs (standard
//!   Write, or RPC Write-Through which also updates follower state
//!   directly, skipping their log poll).
//!
//! The automaton is *pure*: it emits [`Round`]s; the engine fans each round
//! out to the current live follower set over the simulated fabric and feeds
//! responses back. Each round completes on a majority quorum (leader
//! included). NACKed/crashed followers are counted as failures; if failures
//! make quorum impossible the instance stalls and the engine retries after
//! the follower list is refreshed by the Leader Switch Plane.

use std::collections::VecDeque;

use crate::rdt::OpCall;

/// One fan-out round to the follower set.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Round {
    /// RDMA read each follower's min-proposal register.
    ReadMinProposals,
    /// RDMA write the chosen proposal number.
    WriteProposal { proposal: u64 },
    /// RDMA read the log slot the leader intends to use.
    ReadSlots { slot: u64 },
    /// Accept: RDMA write (or RPC write-through) the entry. `adopted` is
    /// true when the entry was recovered from a follower's slot rather
    /// than proposed by this leader.
    WriteLog { slot: u64, proposal: u64, op: OpCall, adopted: bool },
}

/// What the engine should do after feeding a response.
#[derive(Clone, Debug, PartialEq)]
pub enum Step {
    /// Nothing yet — keep feeding responses.
    Wait,
    /// Start the next round (previous one reached quorum).
    Next(Round),
    /// The entry in `slot` is committed; `op` must be applied at the leader
    /// and (if `adopted`) the originally proposed op must be re-submitted.
    Commit { slot: u64, proposal: u64, op: OpCall, adopted: Option<OpCall> },
    /// Quorum unreachable with the current follower set.
    Stall,
}

/// Response payloads the engine feeds back.
#[derive(Clone, Copy, Debug)]
pub enum Resp {
    MinProposal(u64),
    Ack,
    Slot(Option<(u64, OpCall)>),
    Failure,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Phase {
    Idle,
    ReadProposals,
    WriteProposal,
    ReadSlots,
    Accept,
}

#[derive(Debug)]
pub struct MuInstance {
    pub group: u8,
    phase: Phase,
    /// Followers targeted in the in-flight round.
    targeted: u32,
    responded: u32,
    failed: u32,
    /// Cluster size (quorum = majority of n, leader counts as one vote).
    n: usize,
    proposal: u64,
    max_seen_proposal: u64,
    slot: u64,
    current_op: Option<OpCall>,
    /// Originally submitted op when a foreign entry got adopted.
    original_op: Option<OpCall>,
    /// Highest-proposal non-empty slot seen during ReadSlots.
    adopted: Option<(u64, OpCall)>,
    queue: VecDeque<OpCall>,
    pub committed: u64,
    pub restarts: u64,
}

impl MuInstance {
    pub fn new(group: u8, n: usize) -> Self {
        MuInstance {
            group,
            phase: Phase::Idle,
            targeted: 0,
            responded: 0,
            failed: 0,
            n,
            proposal: 0,
            max_seen_proposal: 0,
            slot: 0,
            current_op: None,
            original_op: None,
            adopted: None,
            queue: VecDeque::new(),
            committed: 0,
            restarts: 0,
        }
    }

    pub fn set_cluster_size(&mut self, n: usize) {
        self.n = n;
    }

    /// Followers (excluding the leader) whose responses complete a quorum.
    fn quorum_followers(&self) -> u32 {
        (self.n / 2) as u32 // majority of n including the leader's own vote
    }

    pub fn is_idle(&self) -> bool {
        self.phase == Phase::Idle && self.queue.is_empty()
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Submit a conflicting op. Returns the first round to fan out if the
    /// instance was idle.
    pub fn submit(&mut self, op: OpCall, next_free_slot: u64) -> Option<Round> {
        if self.phase != Phase::Idle {
            self.queue.push_back(op);
            return None;
        }
        self.begin(op, next_free_slot)
    }

    fn begin(&mut self, op: OpCall, next_free_slot: u64) -> Option<Round> {
        self.current_op = Some(op);
        self.slot = next_free_slot;
        self.adopted = None;
        self.phase = Phase::ReadProposals;
        self.arm();
        Some(Round::ReadMinProposals)
    }

    /// The engine tells the instance how many followers it targeted.
    pub fn round_started(&mut self, targeted: u32) {
        self.targeted = targeted;
    }

    fn arm(&mut self) {
        self.responded = 0;
        self.failed = 0;
        self.max_seen_proposal = self.max_seen_proposal.max(self.proposal);
    }

    /// Pop the next queued op once a commit completes. Returns the opening
    /// round if something was queued.
    pub fn pump(&mut self, next_free_slot: u64) -> Option<Round> {
        debug_assert_eq!(self.phase, Phase::Idle);
        let op = self.queue.pop_front()?;
        self.begin(op, next_free_slot)
    }

    /// Feed one follower response for the in-flight round.
    pub fn on_response(&mut self, resp: Resp) -> Step {
        if self.phase == Phase::Idle {
            return Step::Wait; // stale response after stall/commit
        }
        match resp {
            Resp::Failure => self.failed += 1,
            Resp::MinProposal(p) => {
                self.max_seen_proposal = self.max_seen_proposal.max(p);
                self.responded += 1;
            }
            Resp::Ack => self.responded += 1,
            Resp::Slot(entry) => {
                if let Some((p, op)) = entry {
                    match self.adopted {
                        Some((bp, _)) if bp >= p => {}
                        _ => self.adopted = Some((p, op)),
                    }
                }
                self.responded += 1;
            }
        }

        let need = self.quorum_followers();
        if self.responded < need {
            // Quorum impossible once too many targets have failed.
            let healthy_remaining = self.targeted - self.responded - self.failed;
            if self.responded + healthy_remaining < need {
                return Step::Stall;
            }
            return Step::Wait;
        }

        // Quorum reached: advance the phase.
        match self.phase {
            Phase::ReadProposals => {
                self.proposal = self.max_seen_proposal + 1;
                self.phase = Phase::WriteProposal;
                self.arm();
                Step::Next(Round::WriteProposal { proposal: self.proposal })
            }
            Phase::WriteProposal => {
                self.phase = Phase::ReadSlots;
                self.arm();
                Step::Next(Round::ReadSlots { slot: self.slot })
            }
            Phase::ReadSlots => {
                // Adopt a previously accepted entry if any slot was non-empty.
                let mut was_adopted = false;
                let op = if let Some((_, foreign)) = self.adopted {
                    if Some(foreign) != self.current_op {
                        self.original_op = self.current_op.take();
                        self.restarts += 1;
                        was_adopted = true;
                    }
                    foreign
                } else {
                    self.current_op.expect("op in flight")
                };
                self.current_op = Some(op);
                self.phase = Phase::Accept;
                self.arm();
                Step::Next(Round::WriteLog {
                    slot: self.slot,
                    proposal: self.proposal,
                    op,
                    adopted: was_adopted,
                })
            }
            Phase::Accept => {
                let op = self.current_op.take().expect("op in flight");
                let slot = self.slot;
                let proposal = self.proposal;
                self.committed += 1;
                self.phase = Phase::Idle;
                // If we adopted a foreign entry, the original op restarts
                // from Prepare (paper: "the leader repeats the Prepare
                // phase for the originally proposed transaction").
                let adopted = self.original_op.take();
                if let Some(orig) = adopted {
                    self.queue.push_front(orig);
                }
                Step::Commit { slot, proposal, op, adopted }
            }
            Phase::Idle => Step::Wait,
        }
    }

    /// Abort the in-flight op without requeueing it (the leader found it
    /// impermissible in total-order position; §2.1 permissibility).
    pub fn abort_current(&mut self) {
        self.current_op = None;
        if let Some(orig) = self.original_op.take() {
            self.queue.push_front(orig);
        }
        self.phase = Phase::Idle;
        self.adopted = None;
    }

    /// Abandon the in-flight round (leader change / stall reset).
    pub fn reset_in_flight(&mut self) {
        if let Some(op) = self.current_op.take() {
            self.queue.push_front(op);
        }
        if let Some(op) = self.original_op.take() {
            self.queue.push_front(op);
        }
        self.phase = Phase::Idle;
    }

    /// Abdication: hand every queued op back to the engine (which re-routes
    /// them through the forward path to the rightful leader). Call
    /// [`Self::reset_in_flight`] first so the in-flight op is included.
    pub fn take_queue(&mut self) -> Vec<OpCall> {
        self.queue.drain(..).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn op(n: u64) -> OpCall {
        OpCall::new(1, n, 0, 0.0)
    }

    /// Drive one full consensus round with `f` followers all healthy.
    fn drive_commit(mu: &mut MuInstance, f: u32, o: OpCall, slot: u64) -> Step {
        let mut round = mu.submit(o, slot).expect("idle -> first round");
        loop {
            mu.round_started(f);
            assert_eq!(round, Round::ReadMinProposals);
            let mut step = Step::Wait;
            for _ in 0..f {
                step = mu.on_response(Resp::MinProposal(0));
                if !matches!(step, Step::Wait) {
                    break;
                }
            }
            let Step::Next(r2) = step else { panic!("expected WriteProposal, got {step:?}") };
            assert!(matches!(r2, Round::WriteProposal { .. }));
            mu.round_started(f);
            let mut step = Step::Wait;
            for _ in 0..f {
                step = mu.on_response(Resp::Ack);
                if !matches!(step, Step::Wait) {
                    break;
                }
            }
            let Step::Next(r3) = step else { panic!("expected ReadSlots") };
            assert!(matches!(r3, Round::ReadSlots { .. }));
            mu.round_started(f);
            let mut step = Step::Wait;
            for _ in 0..f {
                step = mu.on_response(Resp::Slot(None));
                if !matches!(step, Step::Wait) {
                    break;
                }
            }
            let Step::Next(r4) = step else { panic!("expected WriteLog") };
            assert!(matches!(r4, Round::WriteLog { .. }));
            mu.round_started(f);
            let mut step = Step::Wait;
            for _ in 0..f {
                step = mu.on_response(Resp::Ack);
                if !matches!(step, Step::Wait) {
                    break;
                }
            }
            match step {
                Step::Commit { .. } => return step,
                Step::Next(r) => {
                    round = r;
                }
                other => panic!("unexpected {other:?}"),
            }
        }
    }

    #[test]
    fn happy_path_commits_own_op() {
        let mut mu = MuInstance::new(0, 4); // quorum = 2 followers
        let step = drive_commit(&mut mu, 3, op(42), 0);
        match step {
            Step::Commit { slot, op: o, adopted, .. } => {
                assert_eq!(slot, 0);
                assert_eq!(o.a, 42);
                assert!(adopted.is_none());
            }
            _ => unreachable!(),
        }
        assert_eq!(mu.committed, 1);
        assert!(mu.is_idle());
    }

    #[test]
    fn quorum_before_all_responses() {
        let mut mu = MuInstance::new(0, 8); // n=8: quorum followers = 4
        mu.submit(op(1), 0);
        mu.round_started(7);
        for _ in 0..3 {
            assert_eq!(mu.on_response(Resp::MinProposal(5)), Step::Wait);
        }
        let s = mu.on_response(Resp::MinProposal(2));
        assert!(matches!(s, Step::Next(Round::WriteProposal { proposal: 6 })), "{s:?}");
    }

    #[test]
    fn adopts_highest_proposal_foreign_entry_then_requeues_original() {
        let mut mu = MuInstance::new(0, 4);
        mu.submit(op(7), 3);
        mu.round_started(3);
        // Prepare reads
        mu.on_response(Resp::MinProposal(0));
        let Step::Next(_) = mu.on_response(Resp::MinProposal(0)) else { panic!() };
        mu.round_started(3);
        mu.on_response(Resp::Ack);
        let Step::Next(_) = mu.on_response(Resp::Ack) else { panic!() };
        // Slot reads find a foreign entry with proposal 9 and one with 4:
        mu.round_started(3);
        mu.on_response(Resp::Slot(Some((4, op(100)))));
        let step = mu.on_response(Resp::Slot(Some((9, op(200)))));
        let Step::Next(Round::WriteLog { op: chosen, .. }) = step else { panic!("{step:?}") };
        assert_eq!(chosen.a, 200, "highest proposal adopted");
        // Accept acks
        mu.round_started(3);
        mu.on_response(Resp::Ack);
        let step = mu.on_response(Resp::Ack);
        let Step::Commit { op: committed, adopted, .. } = step else { panic!("{step:?}") };
        assert_eq!(committed.a, 200);
        assert_eq!(adopted.unwrap().a, 7, "original requeued");
        assert_eq!(mu.queue_len(), 1);
        assert_eq!(mu.restarts, 1);
    }

    #[test]
    fn queues_while_busy_and_pumps() {
        let mut mu = MuInstance::new(0, 4);
        assert!(mu.submit(op(1), 0).is_some());
        assert!(mu.submit(op(2), 0).is_none(), "busy -> queued");
        assert_eq!(mu.queue_len(), 1);
        // finish op 1
        for round in 0..4 {
            mu.round_started(3);
            let resp = match round {
                0 => Resp::MinProposal(0),
                2 => Resp::Slot(None),
                _ => Resp::Ack,
            };
            mu.on_response(resp);
            let _ = mu.on_response(resp);
        }
        assert!(mu.phase == Phase::Idle);
        let r = mu.pump(1);
        assert_eq!(r, Some(Round::ReadMinProposals));
    }

    #[test]
    fn stalls_when_quorum_impossible() {
        let mut mu = MuInstance::new(0, 4); // needs 2 follower responses
        mu.submit(op(1), 0);
        mu.round_started(3);
        assert_eq!(mu.on_response(Resp::Failure), Step::Wait); // 2 healthy left, need 2
        // Second failure leaves only 1 healthy target < quorum 2: stall now.
        let s = mu.on_response(Resp::Failure);
        assert_eq!(s, Step::Stall);
        mu.reset_in_flight();
        assert_eq!(mu.queue_len(), 1, "op requeued for retry");
    }

    #[test]
    fn proposal_numbers_increase_past_observed() {
        let mut mu = MuInstance::new(0, 4);
        mu.submit(op(1), 0);
        mu.round_started(3);
        mu.on_response(Resp::MinProposal(41));
        let s = mu.on_response(Resp::MinProposal(3));
        assert!(matches!(s, Step::Next(Round::WriteProposal { proposal: 42 })), "{s:?}");
    }
}
