//! Fig 15: hybrid mode — response time and throughput as the fraction of
//! operations served by FPGA-resident keys sweeps 10→90 % (YCSB and
//! SmallBank).
//!
//! Expected shape: ~linear improvement with FPGA share (paper: 5.7× RT /
//! 4.7× tput from 10 %→90 % at 50 % writes on YCSB).

use crate::config::{HybridConfig, SimConfig, WorkloadKind};
use crate::expt::common::{cell_ops, f3, run_cells_tagged};
use crate::util::table::Table;

const FPGA_PCTS: &[u8] = &[10, 30, 50, 70, 90];
const WRITES: &[u8] = &[5, 25, 50];

pub fn run(quick: bool) -> Vec<Table> {
    let mut tables = Vec::new();
    for workload in [WorkloadKind::Ycsb, WorkloadKind::SmallBank] {
        let mut t = Table::new(
            &format!("Fig 15 — hybrid ops assignment on {}", workload.name()),
            &["fpga_ops%", "upd%", "rt_us", "tput_ops_us"],
        );
        let mut jobs = Vec::new();
        for &pct in FPGA_PCTS {
            for &u in WRITES {
                if quick && u == 25 {
                    continue;
                }
                let mut cfg = SimConfig::safardb(workload);
                cfg.n_replicas = 4;
                cfg.update_pct = u;
                let mut h = match workload {
                    WorkloadKind::Ycsb => HybridConfig::ycsb_default(),
                    _ => HybridConfig::smallbank_default(),
                };
                h.fpga_ops_pct = pct;
                cfg.hybrid = Some(h);
                jobs.push(((pct, u), (cfg, cell_ops(quick))));
            }
        }
        for ((pct, u), cell, _) in run_cells_tagged(jobs) {
            t.row(vec![pct.to_string(), u.to_string(), f3(cell.rt_us), f3(cell.tput)]);
        }
        tables.push(t);
    }
    tables
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn more_fpga_share_is_monotonically_better() {
        for t in run(true) {
            let series: Vec<(u8, f64, f64)> = t
                .rows()
                .iter()
                .filter(|r| r[1] == "50")
                .map(|r| (r[0].parse().unwrap(), r[2].parse().unwrap(), r[3].parse().unwrap()))
                .collect();
            let p10 = series.iter().find(|s| s.0 == 10).unwrap();
            let p90 = series.iter().find(|s| s.0 == 90).unwrap();
            assert!(p10.1 > p90.1 * 1.5, "RT improves with FPGA share: {} vs {}", p10.1, p90.1);
            assert!(p90.2 > p10.2 * 1.5, "tput improves with FPGA share");
        }
    }
}
