//! Integration: SMR safety — total order of conflicting transactions, log
//! consistency across replicas, and leader-authority of permissibility.

use safardb::config::{SimConfig, WorkloadKind};
use safardb::engine::cluster;
use safardb::rdt::RdtKind;

#[test]
fn auction_three_groups_all_converge() {
    // Auction is the stress case: three sync groups = three independent
    // SMR instances sharing one leader (Fig 8).
    let mut cfg = SimConfig::safardb(WorkloadKind::Micro(RdtKind::Auction));
    cfg.n_replicas = 8;
    cfg.update_pct = 40;
    cfg.total_ops = 20_000;
    let rep = cluster::run(cfg);
    assert!(rep.converged() && rep.invariants_ok);
    assert!(rep.metrics.smr_commits > 500, "conflicting traffic flowed");
}

#[test]
fn movie_all_conflicting_two_groups() {
    let mut cfg = SimConfig::safardb(WorkloadKind::Micro(RdtKind::Movie));
    cfg.n_replicas = 6;
    cfg.update_pct = 25;
    cfg.total_ops = 15_000;
    let rep = cluster::run(cfg);
    assert!(rep.converged() && rep.invariants_ok);
    // Every Movie update is conflicting: commits ≈ update count.
    let updates = rep.metrics.smr_commits + rep.metrics.rejected;
    assert!(updates > 2_500, "updates routed through SMR: {updates}");
}

#[test]
fn impermissible_conflicting_ops_rejected_consistently() {
    // Courseware generates plenty of duplicate addCourse / missing-ref
    // enrolls; leaders must reject them and every replica must agree.
    let mut cfg = SimConfig::safardb(WorkloadKind::Micro(RdtKind::Courseware));
    cfg.n_replicas = 5;
    cfg.update_pct = 50;
    cfg.total_ops = 15_000;
    let rep = cluster::run(cfg);
    assert!(rep.converged() && rep.invariants_ok);
    assert!(rep.metrics.rejected > 0, "duplicate adds must be rejected");
}

#[test]
fn overdraft_impossible_under_concurrent_withdrawals() {
    // The §2.1 motivating hazard at scale: all replicas fire withdrawals
    // concurrently; serialization through the leader must keep B >= 0.
    let mut cfg = SimConfig::safardb(WorkloadKind::Micro(RdtKind::Account));
    cfg.n_replicas = 8;
    cfg.update_pct = 80;
    cfg.total_ops = 20_000;
    let rep = cluster::run(cfg);
    assert!(rep.invariants_ok, "overdraft detected");
    assert!(rep.converged());
    assert!(rep.metrics.rejected > 0, "some withdrawals must bounce at the leader");
}

#[test]
fn smallbank_debits_engage_smr_but_ycsb_does_not() {
    let mut sb = SimConfig::safardb(WorkloadKind::SmallBank);
    sb.total_ops = 8_000;
    sb.update_pct = 30;
    let sb_rep = cluster::run(sb);
    assert!(sb_rep.metrics.smr_commits > 0, "SmallBank debits are conflicting");

    let mut y = SimConfig::safardb(WorkloadKind::Ycsb);
    y.total_ops = 8_000;
    y.update_pct = 30;
    let y_rep = cluster::run(y);
    assert_eq!(y_rep.metrics.smr_commits, 0, "YCSB updates are reducible");
}

#[test]
fn throughput_is_leader_bound_for_wrdts() {
    let mut cfg = SimConfig::safardb(WorkloadKind::Micro(RdtKind::Account));
    cfg.n_replicas = 8;
    cfg.update_pct = 25;
    cfg.total_ops = 16_000;
    let rep = cluster::run(cfg);
    let leader_busy = rep.metrics.busy_ns[rep.leader];
    let max_busy = *rep.metrics.busy_ns.iter().max().unwrap();
    assert_eq!(leader_busy, max_busy, "leader is the longest-running replica (D.1)");
}
