//! Table 2.1: average Read/Write verb latencies, traditional RDMA vs
//! network-attached FPGA (1M random requests). Expected: 1.8/2.0 µs vs
//! ~9 ns (the FPGA number is the on-chip AXI verb path the paper measured).

use crate::mem::{MemKind, MemParams};
use crate::net::fabric::FabricParams;
use crate::util::rng::Rng;
use crate::util::stats::Summary;
use crate::util::table::Table;

pub fn run(quick: bool) -> Vec<Table> {
    let iters = if quick { 100_000 } else { 1_000_000 };
    let mem = MemParams::default_params();
    let trad = FabricParams::traditional();
    let fpga = FabricParams::fpga();
    let mut rng = Rng::new(21);

    let mut t_read = Summary::new();
    let mut t_write = Summary::new();
    let mut f_read = Summary::new();
    let mut f_write = Summary::new();
    for _ in 0..iters {
        let bytes = 8 + rng.gen_range(56);
        t_read.add(trad.read_rtt_ns(bytes, MemKind::HostDram, &mem) as f64);
        t_write.add(trad.ack_at_ns(bytes, MemKind::HostDram, &mem) as f64);
        // FPGA: the measured on-chip path (user kernel -> AXI -> HBM).
        f_read.add(fpga.local_verb_ns(&mem) as f64);
        f_write.add(fpga.local_verb_ns(&mem) as f64);
    }

    let mut t = Table::new(
        "Table 2.1 — average RDMA verb latencies (1M random requests)",
        &["fabric", "read_us", "write_us"],
    );
    t.row(vec![
        "Traditional RDMA".into(),
        format!("{:.4}", t_read.mean() / 1000.0),
        format!("{:.4}", t_write.mean() / 1000.0),
    ]);
    t.row(vec![
        "Network-attached FPGA".into(),
        format!("{:.4}", f_read.mean() / 1000.0),
        format!("{:.4}", f_write.mean() / 1000.0),
    ]);
    vec![t]
}

#[cfg(test)]
mod tests {
    #[test]
    fn reproduces_two_orders_of_magnitude_gap() {
        let t = &super::run(true)[0];
        let trad_read: f64 = t.rows()[0][1].parse().unwrap();
        let fpga_read: f64 = t.rows()[1][1].parse().unwrap();
        assert!((1.7..1.9).contains(&trad_read), "trad={trad_read}");
        assert!(fpga_read < 0.02, "fpga={fpga_read}");
        assert!(trad_read / fpga_read > 100.0);
    }
}
