"""Account batch permissibility kernel.

The Bank Account WRDT's integrity invariant is a non-negative balance
(Table B.1): withdraw(w) is permissible only if B - w >= 0 *given every
previously accepted operation in the batch*. The FPGA runs this as a
sequential check-and-commit loop; on a vector unit we keep the running
balance in a scalar carried through a fori_loop over the batch, emitting an
accept mask. Deposits (delta >= 0) are always permissible.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(b0_ref, deltas_ref, accept_ref, bal_ref):
    b = deltas_ref.shape[0]

    def body(i, bal):
        d = deltas_ref[i]
        ok = (d >= 0.0) | (bal + d >= 0.0)
        accept_ref[i] = ok.astype(jnp.int32)
        return jnp.where(ok, bal + d, bal)

    final = jax.lax.fori_loop(0, b, body, b0_ref[0])
    bal_ref[0] = final


def account_permissibility(b0, deltas):
    """Scan a batch of signed balance deltas against the overdraft invariant.

    Args:
      b0:     f32[1] starting balance (>= 0 by invariant).
      deltas: f32[B] signed deltas (deposit > 0, withdraw < 0).
    Returns:
      (i32[B] accept mask, f32[1] final balance after accepted ops).
    """
    if deltas.ndim != 1 or b0.shape != (1,):
        raise ValueError(f"account_permissibility expects ([1],[B]), got {b0.shape} {deltas.shape}")
    b = deltas.shape[0]
    return pl.pallas_call(
        _kernel,
        out_shape=(
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((1,), b0.dtype),
        ),
        interpret=True,
    )(b0, deltas)
