//! Memory-hierarchy models (appendix C, Figs 18–23).
//!
//! Which memory a path touches is the entire story of the paper's Q1/Q6
//! results, so the model is explicit: FPGA registers / BRAM / HBM, host
//! DRAM behind a real LRU cache (drives the Fig 16 Zipfian-skew result),
//! and the PCIe hop that separates host from device.

pub mod cache;

pub use cache::LruCache;

/// Where a payload lives / lands.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MemKind {
    /// FPGA fabric registers (Table C.1 Register_Write).
    Reg,
    /// FPGA on-chip BRAM (Table C.1 BRAM_Write) — the user kernel's state.
    Bram,
    /// FPGA off-chip HBM (8 GB buffer, §3) — contribution arrays, queues,
    /// replication logs.
    Hbm,
    /// Host DRAM behind the CPU cache hierarchy.
    HostDram,
}

/// Access latencies (ns). Values calibrated so that the end-to-end verb
/// latencies reproduce Tables 2.1 and C.1 — see `net::fabric` tests.
#[derive(Clone, Copy, Debug)]
pub struct MemParams {
    pub reg_ns: u64,
    pub bram_ns: u64,
    /// HBM random access from the user kernel over MM-AXI. Real HBM2
    /// random-read latency on the U280 is in the hundreds of ns — this is
    /// exactly why §4.1's buffering/RPC configurations win (Fig 6).
    pub hbm_axi_ns: u64,
    /// Per-element cost of subsequent beats in an HBM burst read (folding
    /// an N-slot contribution array pipelines after the first access).
    pub hbm_burst_ns: u64,
    /// On-chip AXI hop (user kernel <-> network kernel handshake); with
    /// `verb_issue` this is Table 2.1's 9 ns FPGA verb path.
    pub axi_hop_ns: u64,
    /// HBM accessed from the network kernel on the receive path (the
    /// +128 ns that separates Write from Register_Write in Table C.1).
    pub hbm_net_ns: u64,
    /// Host DRAM access (row hit average).
    pub dram_ns: u64,
    /// CPU last-level-cache hit.
    pub cache_hit_ns: u64,
    /// One PCIe transaction (posted write / read completion), host <-> device.
    pub pcie_ns: u64,
    /// Number of dependent memory touches a host-side keyed lookup costs on
    /// a miss (index walk + data), multiplying `dram_ns`.
    pub host_lookup_depth: u64,
}

impl MemParams {
    pub fn default_params() -> Self {
        MemParams {
            reg_ns: 1,
            bram_ns: 3,
            hbm_axi_ns: 220,
            hbm_burst_ns: 25,
            axi_hop_ns: 5,
            hbm_net_ns: 128,
            dram_ns: 90,
            cache_hit_ns: 14,
            pcie_ns: 450,
            host_lookup_depth: 10,
        }
    }

    /// Write latency as seen by the *network kernel / RNIC* landing a
    /// payload (the receive-side component of a verb).
    pub fn net_write_ns(&self, kind: MemKind) -> u64 {
        match kind {
            MemKind::Reg => self.reg_ns.saturating_sub(1), // wired directly
            MemKind::Bram => self.bram_ns + 21,            // BRAM port arb
            MemKind::Hbm => self.hbm_net_ns,
            // Host DRAM behind PCIe: DMA write + posted PCIe transaction.
            MemKind::HostDram => self.pcie_ns + self.dram_ns,
        }
    }

    /// Read latency from the local compute element (user kernel or CPU).
    pub fn local_read_ns(&self, kind: MemKind) -> u64 {
        match kind {
            MemKind::Reg => self.reg_ns,
            MemKind::Bram => self.bram_ns,
            MemKind::Hbm => self.hbm_axi_ns,
            MemKind::HostDram => self.dram_ns,
        }
    }

    /// Local write symmetric with read for on-chip kinds.
    pub fn local_write_ns(&self, kind: MemKind) -> u64 {
        self.local_read_ns(kind)
    }

    /// Host keyed read through the cache model: `hit` decides LLC vs a
    /// dependent DRAM walk (Fig 16's mechanism).
    pub fn host_keyed_read_ns(&self, hit: bool) -> u64 {
        if hit {
            self.cache_hit_ns * 2 // index + data, both resident
        } else {
            self.dram_ns * self.host_lookup_depth
        }
    }

    /// Burst fold of an `n`-slot array in a memory kind (the §4.1 "read the
    /// contribution array on access" path). First access pays full random
    /// latency; subsequent slots pipeline.
    pub fn fold_read_ns(&self, kind: MemKind, n: usize) -> u64 {
        if n == 0 {
            return 0;
        }
        let tail = (n as u64 - 1)
            * match kind {
                MemKind::Hbm => self.hbm_burst_ns,
                MemKind::HostDram => self.dram_ns, // DMA-invalidated lines: no locality
                _ => 1,
            };
        self.local_read_ns(kind) + tail
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn net_write_ordering_matches_table_c1() {
        // Register < BRAM < HBM < host (Table C.1 ordering).
        let m = MemParams::default_params();
        assert!(m.net_write_ns(MemKind::Reg) < m.net_write_ns(MemKind::Bram));
        assert!(m.net_write_ns(MemKind::Bram) < m.net_write_ns(MemKind::Hbm));
        assert!(m.net_write_ns(MemKind::Hbm) < m.net_write_ns(MemKind::HostDram));
    }

    #[test]
    fn table_c1_deltas() {
        let m = MemParams::default_params();
        // BRAM_Write - Register_Write = 24 ns; Write(HBM) - Register = 128 ns.
        assert_eq!(m.net_write_ns(MemKind::Bram) - m.net_write_ns(MemKind::Reg), 24);
        assert_eq!(m.net_write_ns(MemKind::Hbm) - m.net_write_ns(MemKind::Reg), 128);
    }

    #[test]
    fn cache_hit_much_cheaper_than_miss() {
        let m = MemParams::default_params();
        assert!(m.host_keyed_read_ns(true) * 5 < m.host_keyed_read_ns(false));
    }

    #[test]
    fn on_chip_reads_are_fast_but_hbm_random_is_not() {
        let m = MemParams::default_params();
        assert!(m.local_read_ns(MemKind::Bram) < 10);
        // HBM *random* latency exceeds DRAM — the reason buffering into
        // BRAM (Fig 6) matters at all.
        assert!(m.local_read_ns(MemKind::Hbm) > m.local_read_ns(MemKind::HostDram));
    }

    #[test]
    fn fold_read_pipelines_after_first_beat() {
        let m = MemParams::default_params();
        let one = m.fold_read_ns(MemKind::Hbm, 1);
        let eight = m.fold_read_ns(MemKind::Hbm, 8);
        assert_eq!(one, m.hbm_axi_ns);
        assert_eq!(eight, m.hbm_axi_ns + 7 * m.hbm_burst_ns);
        assert!(eight < 8 * one, "burst must beat 8 random reads");
        assert_eq!(m.fold_read_ns(MemKind::Hbm, 0), 0);
    }
}
