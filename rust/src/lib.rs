//! # SafarDB (simulated reproduction)
//!
//! A three-layer Rust + JAX + Pallas reproduction of *"SafarDB:
//! FPGA-Accelerated Distributed Transactions via Replicated Data Types"*.
//!
//! Layer 3 (this crate) is the coordinator: a deterministic discrete-event
//! cluster simulation in which real CRDT/WRDT state is replicated over a
//! calibrated RDMA model, with Mu SMR for conflicting transactions, plus
//! the Hamband and Waverunner baselines, the paper's complete experiment
//! harness (parallel sweep executor, `expt::common::run_cells`), and a
//! std-only kernel runtime mirroring the AOT-compiled Pallas batch kernels
//! on the data plane. See DESIGN.md for the system inventory.

// Style lints we deliberately deviate from: the replica's split-borrow
// patterns index sibling vectors inside `&mut self` methods (iterators
// would double-borrow self), and the network issue path threads the DES
// context as individual arguments by design.
#![allow(clippy::needless_range_loop, clippy::too_many_arguments)]

pub mod config;
pub mod engine;
pub mod expt;
pub mod mem;
pub mod metrics;
pub mod net;
pub mod power;
pub mod rdt;
pub mod runtime;
pub mod sim;
pub mod smr;
pub mod util;
pub mod workload;
