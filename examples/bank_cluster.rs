//! Bank Account WRDT under fire: an 8-replica cluster with deposits
//! (relaxed path) and withdrawals (Mu consensus), a mid-run **leader
//! crash**, election via heartbeat detection + ns-scale permission switch,
//! and a convergence + integrity audit at the end.
//!
//! Run: `cargo run --release --example bank_cluster`

use safardb::config::{FaultSchedule, SimConfig, WorkloadKind};
use safardb::engine::cluster;
use safardb::rdt::RdtKind;

fn main() {
    let mut cfg = SimConfig::safardb(WorkloadKind::Micro(RdtKind::Account));
    cfg.n_replicas = 8;
    cfg.update_pct = 25;
    cfg.total_ops = 200_000;
    cfg.fault = FaultSchedule::crash_leader_at(50);

    println!("Bank Account, 8 replicas, 25% updates, leader crash at 50%...\n");
    let rep = cluster::run(cfg);

    println!("response        : {:.3} us (p99 {:.3} us)", rep.response_us(),
        rep.metrics.response.p99() as f64 / 1000.0);
    println!("throughput      : {:.3} OPs/us", rep.throughput());
    println!("SMR commits     : {}", rep.metrics.smr_commits);
    println!("rejected (o/d)  : {}", rep.metrics.rejected);
    println!("elections       : {}", rep.metrics.elections);
    println!("new leader      : replica {}", rep.leader);
    println!(
        "perm switches   : {} samples, p50 {} ns (paper Fig 13: 17/24 ns)",
        rep.metrics.perm_switch.count(),
        rep.metrics.perm_switch.p50()
    );
    println!("crashed         : {:?}", rep.crashed);
    println!("converged       : {} (live replicas bit-identical)", rep.converged());
    println!("integrity       : {} (no overdraft anywhere)", rep.invariants_ok);

    assert!(rep.metrics.elections >= 1, "leader crash must trigger an election");
    assert!(rep.converged() && rep.invariants_ok);
    assert_ne!(rep.leader, 0, "the initial leader (replica 0) crashed");
    println!("\nOK: cluster survived the leader crash with integrity intact.");
}
