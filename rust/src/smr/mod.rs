//! State Machine Replication for conflicting transactions (§4.3/4.4).
//!
//! * [`log`] — the replication log: a circular buffer in (modeled) HBM,
//!   one per synchronization group, used for commit and recovery.
//! * [`mu`] — the leader-side Mu state machine (Propose / Prepare /
//!   Accept), expressed as a pure action-emitting automaton so the engine
//!   wires it to the simulated network and tests drive it directly.
//! * [`election`] — the Leader Switch Plane: heartbeat tracking, failure
//!   detection, smallest-live-ID election.
//! * [`raft`] — the simplified Raft used by the Waverunner baseline
//!   (leader-only client handling) and selectable as a stand-alone
//!   strong-path backend.
//! * [`paxos`] — APUS-style RDMA Multi-Paxos: one-sided log writes into
//!   follower landing regions, quorum by write-completion doorbells (the
//!   second strong-path backend behind the `ReplicationPath` seam).

pub mod election;
pub mod log;
pub mod mu;
pub mod paxos;
pub mod raft;
