//! RDMA verbs — the standard one-sided Read/Write pair plus SafarDB's
//! FPGA-specific verbs (§2.2, appendix C.6, Table C.1):
//!
//! * `Write`         — one-sided write to a memory kind (HBM / host DRAM).
//! * `Read`          — one-sided read; the NIC answers without CPU help.
//! * `Rpc`           — payload is (opcode, params); the Dispatcher invokes
//!                     an FPGA-resident accelerator directly (Fig 1),
//!                     landing in integrated storage (BRAM/registers).
//! * `RpcWriteThrough` — §4.3's verb: invokes the accelerator *and*
//!                     concurrently appends the replication log in HBM.

use std::sync::Arc;

use crate::mem::MemKind;
use crate::rdt::OpCall;
use crate::sim::NodeId;

/// Shared op-vector for batch payloads. Fan-out clones the same batch once
/// per peer; `Arc<[OpCall]>` makes each of those clones a refcount bump
/// instead of a heap copy of the whole vector (§Perf: per-message
/// bookkeeping dominates replication cost). `Arc` (not `Rc`) because
/// [`crate::engine::path::ReplicationPath`] is `Send` — cells run on sweep
/// worker threads.
pub type OpBatch = Arc<[OpCall]>;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum VerbKind {
    Write,
    Read,
    Rpc,
    RpcWriteThrough,
}

/// What a Read verb targets in the remote node's memory.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReadTarget {
    /// Heartbeat counter of the remote replica (leader-switch plane).
    Heartbeat,
    /// Highest proposal number of a sync group (Mu Prepare).
    MinProposal { group: u8 },
    /// One replication-log slot of a sync group (Mu Prepare slot check).
    LogSlot { group: u8, slot: u64 },
    /// A raw memory region (micro-benchmarks, Table 2.1).
    Raw { bytes: u64 },
}

/// Data returned by a Read.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ReadData {
    Heartbeat(u64),
    MinProposal(u64),
    /// (proposal, op) if the slot is non-empty.
    LogSlot(Option<(u64, OpCall)>),
    Raw,
}

/// Verb payloads — real protocol state travels here, not just costs.
#[derive(Clone, Debug)]
pub enum Payload {
    /// Raw bytes (micro-benchmarks / Table 2.1 traffic).
    Raw { bytes: u64 },
    /// Reducible summary: replica `origin`'s aggregated contribution
    /// written into slot `A[origin]` (§4.1). `ops` carries the summarized
    /// count for metrics; `value` rows carry the actual contribution.
    Summary { origin: NodeId, ops: u32, value: OpCall },
    /// Irreducible op appended to the per-origin FIFO queue (§4.2).
    QueueAppend { op: OpCall },
    /// Batched reducible summaries: up to `batch_size` coalesced
    /// contributions ride one wire verb (per-path batching).
    SummaryBatch { origin: NodeId, values: OpBatch },
    /// Batched irreducible queue append: one verb, FIFO order preserved.
    QueueBatch { ops: OpBatch },
    /// Mu: write the next proposal number at a follower (Prepare).
    Propose { group: u8, proposal: u64 },
    /// Mu: append a committed entry to the replication log (Accept).
    LogAppend { group: u8, slot: u64, proposal: u64, op: OpCall },
    /// Forward a conflicting op from a non-leader replica to the leader.
    LeaderForward { op: OpCall, reply_to: NodeId, request_id: u64 },
    /// Leader's response to a forwarded conflicting op. `handled` false
    /// means "not the leader, retry elsewhere"; `committed` false with
    /// `handled` true means ordered but rejected by permissibility.
    LeaderReply { request_id: u64, handled: bool, committed: bool },
    /// One-sided read request.
    ReadReq { target: ReadTarget },
    /// Read response delivered back to the initiator.
    ReadResp { target: ReadTarget, data: ReadData },
    /// Raft (Waverunner baseline): AppendEntries carrying one op. `group`
    /// selects the per-group Raft instance under sharded placement (always
    /// 0 in single-leader mode; rides the header padding, so it adds no
    /// wire bytes — same for every group tag below).
    RaftAppend { group: u8, term: u64, index: u64, op: OpCall },
    /// Raft leader-side log-entry batching: one AppendEntries carrying a
    /// contiguous run of entries starting at `start_index`.
    RaftAppendBatch { group: u8, term: u64, start_index: u64, ops: OpBatch },
    /// Raft follower ack.
    RaftAck { group: u8, term: u64, index: u64, from: NodeId },
    /// Raft follower gap report (classic nextIndex back-up, one step):
    /// fault injection ate an append, so the follower names its log end
    /// and the leader backfills from there. Never sent on a clean fabric.
    RaftRejected { group: u8, term: u64, from: NodeId, log_len: u64 },
    /// APUS-style Paxos: leader's one-sided write of a contiguous batch of
    /// log entries into a follower's landing region. The ACK is the write
    /// completion itself (doorbell) — no logical ack verb exists.
    PaxosAppend { group: u8, ballot: u64, start_slot: u64, ops: OpBatch },
    /// Paxos leadership replay: the new leader rewrites its entire log
    /// (possibly empty) at `ballot`; the follower's landing region becomes
    /// an exact mirror (entries beyond the replayed length truncate).
    PaxosReplay { group: u8, ballot: u64, ops: OpBatch },
    /// Client redirect (Waverunner: follower rejects, client re-sends).
    ClientRedirect { request_id: u64 },
    /// Follower -> new leader, sent right after the follower's permission
    /// switch: "replay your committed log to me". Covers the window where
    /// the leader's own takeover broadcast was fenced because this
    /// follower had not opened the new leader's QP yet.
    SyncRequest { from: NodeId },
}

/// Which engine plane consumes a payload on arrival — the replica
/// coordinator's routing table, kept next to the payload definitions so a
/// new payload cannot be added without declaring its owner.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PayloadPlane {
    /// Relaxed path: landing zones + summarizer (§4.1–§4.2).
    Relaxed,
    /// Strongly-ordered path: Mu/Raft, forwards, replies (§4.3–§4.4).
    Strong,
    /// One-sided read the NIC answers from plane-owned memory.
    OneSidedRead,
    /// Read response, routed by its completion token's owner.
    Completion,
    /// No consumer (raw micro-benchmark traffic, client redirects).
    None,
}

impl Payload {
    /// Routing: which plane handles this payload at the destination.
    pub fn plane(&self) -> PayloadPlane {
        match self {
            Payload::Summary { .. }
            | Payload::QueueAppend { .. }
            | Payload::SummaryBatch { .. }
            | Payload::QueueBatch { .. } => PayloadPlane::Relaxed,
            Payload::Propose { .. }
            | Payload::LogAppend { .. }
            | Payload::LeaderForward { .. }
            | Payload::LeaderReply { .. }
            | Payload::RaftAppend { .. }
            | Payload::RaftAppendBatch { .. }
            | Payload::RaftAck { .. }
            | Payload::RaftRejected { .. }
            | Payload::PaxosAppend { .. }
            | Payload::PaxosReplay { .. }
            | Payload::SyncRequest { .. } => PayloadPlane::Strong,
            Payload::ReadReq { .. } => PayloadPlane::OneSidedRead,
            Payload::ReadResp { .. } => PayloadPlane::Completion,
            Payload::Raw { .. } | Payload::ClientRedirect { .. } => PayloadPlane::None,
        }
    }

    /// Heartbeat-plane traffic rides its own QP / virtual lane (§4.4: the
    /// Heartbeat Scanner is independent fabric logic), so it is never
    /// queued behind bulk replication on the in-order data channel.
    pub fn is_heartbeat(&self) -> bool {
        matches!(
            self,
            Payload::ReadReq { target: ReadTarget::Heartbeat }
                | Payload::ReadResp { target: ReadTarget::Heartbeat, .. }
        )
    }

    /// Global sync group carried by leader-QP replication payloads — the
    /// per-group permission fence keys on it (§4.4 under sharded
    /// placement: a node may legitimately lead group A while a partition
    /// minority wrongly believes it leads group B; fencing must tell the
    /// two apart). `None` for payloads outside the leader-write QPs.
    pub fn group(&self) -> Option<u8> {
        match self {
            Payload::Propose { group, .. }
            | Payload::LogAppend { group, .. }
            | Payload::RaftAppend { group, .. }
            | Payload::RaftAppendBatch { group, .. }
            | Payload::PaxosAppend { group, .. }
            | Payload::PaxosReplay { group, .. } => Some(*group),
            _ => None,
        }
    }

    /// Wire size for serialization-delay modeling.
    pub fn wire_bytes(&self) -> u64 {
        match self {
            Payload::Raw { bytes } => *bytes,
            Payload::Summary { value, .. } => value.wire_bytes() + 8,
            Payload::QueueAppend { op } => op.wire_bytes(),
            Payload::SummaryBatch { values, .. } => {
                values.iter().map(|v| v.wire_bytes()).sum::<u64>() + 8
            }
            Payload::QueueBatch { ops } => ops.iter().map(|o| o.wire_bytes()).sum::<u64>() + 8,
            Payload::Propose { .. } => 16,
            Payload::LogAppend { op, .. } => op.wire_bytes() + 24,
            Payload::LeaderForward { op, .. } => op.wire_bytes() + 16,
            Payload::LeaderReply { .. } => 16,
            Payload::ReadReq { .. } => 16,
            Payload::ReadResp { .. } => 48,
            Payload::RaftAppend { op, .. } => op.wire_bytes() + 24,
            Payload::RaftAppendBatch { ops, .. } => {
                ops.iter().map(|o| o.wire_bytes()).sum::<u64>() + 24
            }
            Payload::RaftAck { .. } => 24,
            Payload::RaftRejected { .. } => 24,
            Payload::PaxosAppend { ops, .. } => {
                ops.iter().map(|o| o.wire_bytes()).sum::<u64>() + 24
            }
            Payload::PaxosReplay { ops, .. } => {
                ops.iter().map(|o| o.wire_bytes()).sum::<u64>() + 16
            }
            Payload::ClientRedirect { .. } => 16,
            Payload::SyncRequest { .. } => 16,
        }
    }
}

/// A verb in flight.
#[derive(Clone, Debug)]
pub struct Verb {
    pub kind: VerbKind,
    /// Where the payload lands at the destination (write verbs).
    pub dst_mem: MemKind,
    pub payload: Payload,
    /// Initiator completion token: the ACK/NACK event carries it back.
    pub token: u64,
    /// True for writes that travel on the follower's *leader-write QP* —
    /// the one the Permission Switch fences (§4.4). Relaxed-path RDT
    /// traffic uses per-peer QPs that stay open.
    pub leader_qp: bool,
}

impl Verb {
    pub fn write(dst_mem: MemKind, payload: Payload, token: u64) -> Self {
        Verb { kind: VerbKind::Write, dst_mem, payload, token, leader_qp: false }
    }

    pub fn read(target: ReadTarget, token: u64) -> Self {
        Verb {
            kind: VerbKind::Read,
            dst_mem: MemKind::Hbm,
            payload: Payload::ReadReq { target },
            token,
            leader_qp: false,
        }
    }

    pub fn rpc(payload: Payload, token: u64) -> Self {
        Verb { kind: VerbKind::Rpc, dst_mem: MemKind::Bram, payload, token, leader_qp: false }
    }

    pub fn rpc_write_through(payload: Payload, token: u64) -> Self {
        Verb {
            kind: VerbKind::RpcWriteThrough,
            dst_mem: MemKind::Bram,
            payload,
            token,
            leader_qp: true, // write-through is the SMR Accept path
        }
    }

    /// Mark this verb as leader-write-QP traffic (Mu Propose/Accept).
    pub fn on_leader_qp(mut self) -> Self {
        self.leader_qp = true;
        self
    }

    pub fn wire_bytes(&self) -> u64 {
        // RoCEv2 headers (Eth+IP+UDP+IB BTH ≈ 58B) + payload.
        58 + self.payload.wire_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn verb_constructors_set_kind_and_mem() {
        let w = Verb::write(MemKind::Hbm, Payload::Raw { bytes: 64 }, 1);
        assert_eq!(w.kind, VerbKind::Write);
        assert_eq!(w.dst_mem, MemKind::Hbm);

        let r = Verb::read(ReadTarget::Heartbeat, 2);
        assert!(matches!(r.payload, Payload::ReadReq { target: ReadTarget::Heartbeat }));

        let rpc = Verb::rpc(Payload::QueueAppend { op: OpCall::new(0, 1, 0, 0.0) }, 3);
        assert_eq!(rpc.dst_mem, MemKind::Bram, "RPC lands in integrated storage");

        let wt = Verb::rpc_write_through(
            Payload::LogAppend { group: 0, slot: 0, proposal: 1, op: OpCall::new(0, 0, 0, 0.0) },
            4,
        );
        assert_eq!(wt.kind, VerbKind::RpcWriteThrough);
    }

    #[test]
    fn wire_bytes_include_headers() {
        let w = Verb::write(MemKind::Hbm, Payload::Raw { bytes: 100 }, 0);
        assert_eq!(w.wire_bytes(), 158);
    }

    #[test]
    fn batched_payloads_save_headers_on_the_wire() {
        let op = OpCall::new(0, 1, 2, 0.5);
        let one = Payload::SummaryBatch { origin: 0, values: vec![op].into() }.wire_bytes();
        let four = Payload::SummaryBatch { origin: 0, values: vec![op; 4].into() }.wire_bytes();
        assert_eq!(four - one, 3 * op.wire_bytes(), "payload grows per entry");
        let k_verbs = 4 * Verb::write(MemKind::Hbm, Payload::QueueAppend { op }, 0).wire_bytes();
        let batch = Verb::write(MemKind::Hbm, Payload::QueueBatch { ops: vec![op; 4].into() }, 0)
            .wire_bytes();
        assert!(batch < k_verbs, "one batched verb beats 4 singles: {batch} vs {k_verbs}");
    }

    #[test]
    fn group_tags_ride_header_padding() {
        // The sharded strong plane tags Raft/Paxos payloads with their
        // global sync group; the tag fits the header padding, so wire
        // sizes (and therefore all serialization delays) are unchanged
        // from the single-leader protocol.
        let op = OpCall::new(0, 1, 2, 0.5);
        assert_eq!(Payload::RaftAck { group: 9, term: 1, index: 0, from: 1 }.wire_bytes(), 24);
        assert_eq!(
            Payload::RaftAppend { group: 3, term: 1, index: 0, op }.wire_bytes(),
            op.wire_bytes() + 24
        );
        assert_eq!(
            Payload::PaxosReplay { group: 5, ballot: 1, ops: vec![op].into() }.wire_bytes(),
            op.wire_bytes() + 16
        );
    }

    #[test]
    fn payload_plane_routing_is_total() {
        let op = OpCall::new(0, 1, 2, 0.5);
        let cases: Vec<(Payload, PayloadPlane)> = vec![
            (Payload::Summary { origin: 0, ops: 1, value: op }, PayloadPlane::Relaxed),
            (Payload::QueueAppend { op }, PayloadPlane::Relaxed),
            (
                Payload::SummaryBatch { origin: 0, values: vec![op, op].into() },
                PayloadPlane::Relaxed,
            ),
            (Payload::QueueBatch { ops: vec![op].into() }, PayloadPlane::Relaxed),
            (Payload::Propose { group: 0, proposal: 1 }, PayloadPlane::Strong),
            (Payload::LogAppend { group: 0, slot: 0, proposal: 1, op }, PayloadPlane::Strong),
            (Payload::LeaderForward { op, reply_to: 1, request_id: 2 }, PayloadPlane::Strong),
            (Payload::LeaderReply { request_id: 2, handled: true, committed: true }, PayloadPlane::Strong),
            (Payload::RaftAppend { group: 0, term: 1, index: 0, op }, PayloadPlane::Strong),
            (
                Payload::RaftAppendBatch {
                    group: 0,
                    term: 1,
                    start_index: 0,
                    ops: vec![op, op].into(),
                },
                PayloadPlane::Strong,
            ),
            (Payload::RaftAck { group: 0, term: 1, index: 0, from: 1 }, PayloadPlane::Strong),
            (
                Payload::RaftRejected { group: 0, term: 1, from: 2, log_len: 3 },
                PayloadPlane::Strong,
            ),
            (
                Payload::PaxosAppend { group: 0, ballot: 1, start_slot: 0, ops: vec![op].into() },
                PayloadPlane::Strong,
            ),
            (Payload::PaxosReplay { group: 0, ballot: 2, ops: vec![].into() }, PayloadPlane::Strong),
            (Payload::ReadReq { target: ReadTarget::Heartbeat }, PayloadPlane::OneSidedRead),
            (
                Payload::ReadResp { target: ReadTarget::Heartbeat, data: ReadData::Heartbeat(1) },
                PayloadPlane::Completion,
            ),
            (Payload::Raw { bytes: 8 }, PayloadPlane::None),
            (Payload::ClientRedirect { request_id: 3 }, PayloadPlane::None),
            (Payload::SyncRequest { from: 2 }, PayloadPlane::Strong),
        ];
        for (p, want) in cases {
            assert_eq!(p.plane(), want, "{p:?}");
        }
    }
}
