"""KV burst scatter-add kernel (YCSB / SmallBank hot path).

Applies a burst of (key, delta) updates to a K-element state vector. The
FPGA streams decoded ops into a BRAM-resident table; the TPU-shaped
formulation materializes the burst as a one-hot [B, K] matrix and performs
one MXU matmul — duplicate keys in a burst accumulate correctly, which a
naive vector scatter would not guarantee.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(state_ref, keys_ref, deltas_ref, out_ref):
    k = state_ref.shape[0]
    keys = keys_ref[...]
    deltas = deltas_ref[...]
    # one-hot [B, K] on the fly; deltas @ onehot reduces over B on the MXU.
    onehot = (keys[:, None] == jax.lax.iota(jnp.int32, k)[None, :]).astype(deltas.dtype)
    out_ref[...] = state_ref[...] + deltas @ onehot


def batch_apply(state, keys, deltas):
    """Apply a burst of additive updates to a state vector.

    Args:
      state:  f32[K] current values.
      keys:   i32[B] target indices (may repeat; out-of-range keys must not
              be passed — the Rust dispatcher pads with key 0 / delta 0).
      deltas: f32[B] additive updates.
    Returns:
      f32[K] updated state.
    """
    if state.ndim != 1 or keys.ndim != 1 or keys.shape != deltas.shape:
        raise ValueError(f"batch_apply expects ([K],[B],[B]), got {state.shape} {keys.shape} {deltas.shape}")
    k = state.shape[0]
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((k,), state.dtype),
        interpret=True,
    )(state, keys, deltas)
