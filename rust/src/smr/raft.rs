//! Simplified Raft for the Waverunner baseline [5] (Fig 12).
//!
//! Waverunner accelerates the Raft replication fast path on an FPGA
//! SmartNIC while the application runs in host software; only the leader
//! serves client requests — followers reject and the client re-sends
//! (§5.2 "SafarDB vs Waverunner"). We model the stable-leader fast path:
//! AppendEntries fan-out, majority-ack commit, apply, respond. Leader
//! election on failure is the smallest-live-ID shortcut (documented
//! simplification — Fig 12 runs fault-free).

use std::collections::VecDeque;

use crate::rdt::OpCall;
use crate::sim::NodeId;

#[derive(Clone, Debug, PartialEq)]
pub enum RaftStep {
    Wait,
    /// The in-flight batch starting at `start_index` is committed: apply +
    /// respond to each entry's client.
    Commit { start_index: u64, ops: Vec<OpCall> },
}

/// One in-flight AppendEntries batch (a pipeline stage).
#[derive(Debug)]
struct Flight {
    start: u64,
    ops: Vec<OpCall>,
    /// Distinct ack sources. Voters are tracked by id: the chaos re-pump
    /// re-ships in-flight batches and followers re-ack, so a bare counter
    /// would let one reachable follower fake a majority.
    voters: Vec<NodeId>,
    /// Majority reached but an earlier batch hasn't: committed out of
    /// order, released (applied/answered) strictly in index order.
    committed: bool,
}

/// Leader-side replication pipeline: up to `window` in-flight batches
/// (Waverunner's packet-serial fast path is window 1, batch 1), queueing
/// behind the window; `pump` drains up to `batch` queued entries into one
/// AppendEntries per free stage.
#[derive(Debug)]
pub struct RaftLeader {
    pub term: u64,
    n: usize,
    batch: usize,
    window: usize,
    next_index: u64,
    flights: VecDeque<Flight>,
    queue: VecDeque<(u64, OpCall)>,
    pub committed: u64,
}

impl RaftLeader {
    pub fn new(n: usize) -> Self {
        Self::with_batch(n, 1)
    }

    pub fn with_batch(n: usize, batch: usize) -> Self {
        Self::with_window(n, batch, 1)
    }

    pub fn with_window(n: usize, batch: usize, window: usize) -> Self {
        RaftLeader {
            term: 1,
            n,
            batch: batch.max(1),
            window: window.max(1),
            next_index: 0,
            flights: VecDeque::new(),
            queue: VecDeque::new(),
            committed: 0,
        }
    }

    /// A follower taking over after an election (generic Raft backend):
    /// next entries append after the adopted log, at a higher term. The
    /// deposed leader's window dies with it — the replay that precedes
    /// promotion covers every slot its uncommitted flights held.
    pub fn promote(n: usize, batch: usize, window: usize, term: u64, next_index: u64) -> Self {
        let mut l = Self::with_window(n, batch, window);
        l.term = term;
        l.next_index = next_index;
        l
    }

    fn majority_acks(&self) -> u32 {
        (self.n / 2) as u32 // leader's own log write is the +1 vote
    }

    pub fn set_cluster_size(&mut self, n: usize) {
        self.n = n;
    }

    /// Client op arrives at the leader. The entry's log index is assigned
    /// immediately (so callers can key pending requests on it); an
    /// AppendEntries fan-out is returned only if the window has a free
    /// stage.
    pub fn submit(&mut self, op: OpCall) -> (u64, Option<(u64, u64, Vec<OpCall>)>) {
        let index = self.next_index;
        self.next_index += 1;
        self.queue.push_back((index, op));
        if self.flights.len() >= self.window {
            return (index, None);
        }
        (index, self.pump())
    }

    /// Release the committed batch at the commit cursor, if any. The
    /// engine drains this after every Commit step so batches whose
    /// majority arrived out of order apply strictly in index order.
    pub fn pop_released(&mut self) -> Option<(u64, Vec<OpCall>)> {
        if !self.flights.front()?.committed {
            return None;
        }
        let f = self.flights.pop_front()?;
        self.committed += f.ops.len() as u64;
        Some((f.start, f.ops))
    }

    /// Follower ack for the *last* index of an in-flight batch (followers
    /// ack a batch once, after appending all of it — possibly again for a
    /// chaos-mode re-ship; duplicates from the same follower count once).
    /// Majorities may land out of order across the window; `Commit` is
    /// only returned once the *front* batch commits (drain `pop_released`
    /// for any successors that committed earlier).
    pub fn on_ack(&mut self, term: u64, index: u64, from: NodeId) -> RaftStep {
        if term != self.term {
            return RaftStep::Wait;
        }
        let majority = self.majority_acks();
        let Some(f) = self
            .flights
            .iter_mut()
            .find(|f| f.start + f.ops.len() as u64 - 1 == index && !f.committed)
        else {
            return RaftStep::Wait;
        };
        if !f.voters.contains(&from) {
            f.voters.push(from);
        }
        if (f.voters.len() as u32) < majority {
            return RaftStep::Wait;
        }
        f.committed = true;
        match self.pop_released() {
            Some((start, ops)) => RaftStep::Commit { start_index: start, ops },
            None => RaftStep::Wait, // blocked behind an earlier batch
        }
    }

    /// Chaos-mode nudge: re-ship every in-flight batch. A lost
    /// AppendEntries or an eaten logical ack would otherwise wedge the
    /// pipeline forever; followers overwrite-accept the duplicates and
    /// re-ack, so the re-sends are idempotent.
    pub fn refanout(&self) -> Vec<(u64, u64, Vec<OpCall>)> {
        self.flights.iter().map(|f| (self.term, f.start, f.ops.clone())).collect()
    }

    /// Start the next queued batch (up to `batch` entries) if the window
    /// has a free stage. Call again until `None` to fill the window.
    pub fn pump(&mut self) -> Option<(u64, u64, Vec<OpCall>)> {
        if self.flights.len() >= self.window {
            return None;
        }
        let (start, _) = *self.queue.front()?;
        let take = self.queue.len().min(self.batch);
        let ops: Vec<OpCall> = self.queue.drain(..take).map(|(_, op)| op).collect();
        self.flights.push_back(Flight { start, ops: ops.clone(), voters: Vec::new(), committed: false });
        Some((self.term, start, ops))
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Current pipeline depth (for `inflight_max` telemetry).
    pub fn depth(&self) -> usize {
        self.flights.len()
    }
}

/// Follower-side log acceptance.
#[derive(Debug, Default)]
pub struct RaftFollower {
    pub term: u64,
    entries: Vec<OpCall>,
    pub applied: u64,
}

impl RaftFollower {
    pub fn new() -> Self {
        Self::default()
    }

    /// Rebuild from a recovery snapshot: `entries` is the donor's
    /// committed log, whose effects the installed state plane already
    /// contains — so the restored log starts fully applied.
    pub fn restore(term: u64, entries: Vec<OpCall>) -> Self {
        RaftFollower { term, applied: entries.len() as u64, entries }
    }

    /// AppendEntries from the leader; returns whether to ack.
    pub fn on_append(&mut self, term: u64, index: u64, op: OpCall) -> bool {
        if term < self.term {
            return false; // stale leader
        }
        self.term = term;
        let idx = index as usize;
        if idx > self.entries.len() {
            return false; // gap: reject (leader would back up; fast path has none)
        }
        if idx == self.entries.len() {
            self.entries.push(op);
        } else {
            self.entries[idx] = op;
        }
        true
    }

    /// Batched AppendEntries: contiguous run starting at `start`; accepted
    /// all-or-nothing (a gap rejects the whole batch).
    pub fn on_append_batch(&mut self, term: u64, start: u64, ops: &[OpCall]) -> bool {
        if term < self.term || start as usize > self.entries.len() {
            return false;
        }
        self.term = term;
        for (i, op) in ops.iter().enumerate() {
            let idx = start as usize + i;
            if idx == self.entries.len() {
                self.entries.push(*op);
            } else {
                self.entries[idx] = *op;
            }
        }
        true
    }

    /// Apply contiguous entries (followers apply on the leader's heels).
    pub fn drain_apply(&mut self) -> Vec<OpCall> {
        let out: Vec<OpCall> = self.entries[self.applied as usize..].to_vec();
        self.applied = self.entries.len() as u64;
        out
    }

    /// Accepted log length (a promoted leader appends after this point).
    pub fn log_len(&self) -> u64 {
        self.entries.len() as u64
    }

    /// Full accepted log (a promoted leader's takeover replay source).
    pub fn entries(&self) -> &[OpCall] {
        &self.entries
    }

    /// Waverunner followers reject client requests (redirect to leader).
    pub fn handles_clients(&self) -> bool {
        false
    }
}

/// Which replica leads (fault-free runs: node 0).
pub fn initial_leader() -> NodeId {
    0
}

#[cfg(test)]
mod tests {
    use super::*;

    fn op(n: u64) -> OpCall {
        OpCall::new(0, n, 0, 0.0)
    }

    #[test]
    fn three_node_commit_needs_one_follower_ack() {
        let mut l = RaftLeader::new(3);
        let (idx, fanout) = l.submit(op(1));
        let (term, fidx, ops) = fanout.unwrap();
        assert_eq!((term, fidx, idx), (1, 0, 0));
        assert_eq!(ops, vec![op(1)]);
        let s = l.on_ack(1, 0, 1);
        assert_eq!(s, RaftStep::Commit { start_index: 0, ops: vec![op(1)] });
    }

    #[test]
    fn pipeline_serializes_entries() {
        let mut l = RaftLeader::new(3);
        l.submit(op(1)).1.unwrap();
        let (idx2, fanout2) = l.submit(op(2));
        assert_eq!(idx2, 1, "index assigned immediately");
        assert!(fanout2.is_none(), "queued behind in-flight");
        l.on_ack(1, 0, 1);
        let (_, idx, ops) = l.pump().unwrap();
        assert_eq!(idx, 1);
        assert_eq!(ops[0].a, 2);
    }

    #[test]
    fn batched_leader_coalesces_queued_entries() {
        let mut l = RaftLeader::with_batch(3, 2);
        // Empty pipeline: the first submit fans out alone.
        let (_, f1) = l.submit(op(1));
        assert_eq!(f1.unwrap().2.len(), 1);
        l.submit(op(2));
        l.submit(op(3));
        // Batch acked on its last index only.
        assert_eq!(l.on_ack(1, 0, 1), RaftStep::Commit { start_index: 0, ops: vec![op(1)] });
        let (_, start, ops) = l.pump().unwrap();
        assert_eq!((start, ops.len()), (1, 2), "two queued entries coalesce");
        assert_eq!(l.on_ack(1, 1, 1), RaftStep::Wait, "mid-batch index ignored");
        let s = l.on_ack(1, 2, 1);
        assert_eq!(s, RaftStep::Commit { start_index: 1, ops: vec![op(2), op(3)] });
        assert_eq!(l.committed, 3);
    }

    #[test]
    fn duplicate_acks_from_one_follower_count_once() {
        // n=5: majority needs 2 distinct follower acks. The chaos re-pump
        // re-ships in-flight batches and followers re-ack, so a repeat vote
        // from the same node must not fake a quorum.
        let mut l = RaftLeader::new(5);
        l.submit(op(1)).1.unwrap();
        assert_eq!(l.on_ack(1, 0, 3), RaftStep::Wait);
        assert_eq!(l.on_ack(1, 0, 3), RaftStep::Wait, "duplicate voter ignored");
        assert_eq!(l.on_ack(1, 0, 3), RaftStep::Wait, "still one distinct voter");
        let s = l.on_ack(1, 0, 4);
        assert_eq!(s, RaftStep::Commit { start_index: 0, ops: vec![op(1)] });
    }

    #[test]
    fn follower_batch_append_all_or_nothing() {
        let mut f = RaftFollower::new();
        assert!(f.on_append_batch(1, 0, &[op(1), op(2)]));
        assert!(!f.on_append_batch(1, 5, &[op(9)]), "gap rejected");
        assert!(f.on_append_batch(1, 2, &[op(3)]));
        assert_eq!(f.log_len(), 3);
        assert_eq!(f.drain_apply().len(), 3);
    }

    #[test]
    fn stale_term_acks_ignored() {
        let mut l = RaftLeader::new(3);
        l.submit(op(1)).1.unwrap();
        assert_eq!(l.on_ack(0, 0, 1), RaftStep::Wait);
        assert_eq!(l.on_ack(1, 5, 1), RaftStep::Wait, "wrong index");
    }

    #[test]
    fn follower_appends_in_order_and_applies() {
        let mut f = RaftFollower::new();
        assert!(f.on_append(1, 0, op(1)));
        assert!(f.on_append(1, 1, op(2)));
        assert!(!f.on_append(1, 5, op(9)), "gap rejected");
        let applied = f.drain_apply();
        assert_eq!(applied.len(), 2);
        assert!(!f.handles_clients());
    }

    #[test]
    fn follower_rejects_stale_term() {
        let mut f = RaftFollower::new();
        f.on_append(3, 0, op(1));
        assert!(!f.on_append(2, 1, op(2)));
    }

    #[test]
    fn window_fans_out_submits_without_waiting() {
        let mut l = RaftLeader::with_window(3, 1, 2);
        assert!(l.submit(op(1)).1.is_some());
        assert!(l.submit(op(2)).1.is_some(), "second round rides the window");
        assert_eq!(l.depth(), 2);
        assert!(l.submit(op(3)).1.is_none(), "window full: queued");
        assert_eq!(l.queue_len(), 1);
    }

    #[test]
    fn out_of_order_majorities_release_in_index_order() {
        let mut l = RaftLeader::with_window(3, 1, 2);
        l.submit(op(1)).1.unwrap();
        l.submit(op(2)).1.unwrap();
        // Index 1's ack lands first: committed out of order, held back.
        assert_eq!(l.on_ack(1, 1, 1), RaftStep::Wait, "blocked behind index 0");
        assert!(l.pop_released().is_none(), "commit cursor at index 0");
        // Index 0 commits: it releases, then the parked index 1 follows.
        let s = l.on_ack(1, 0, 2);
        assert_eq!(s, RaftStep::Commit { start_index: 0, ops: vec![op(1)] });
        assert_eq!(l.pop_released(), Some((1, vec![op(2)])));
        assert_eq!(l.committed, 2);
    }

    #[test]
    fn refanout_reships_the_whole_window() {
        let mut l = RaftLeader::with_window(3, 1, 3);
        l.submit(op(1));
        l.submit(op(2));
        let ships = l.refanout();
        assert_eq!(ships.len(), 2);
        assert_eq!((ships[0].1, ships[1].1), (0, 1));
        // Re-acks after the re-ship still count once per follower.
        l.on_ack(1, 0, 1);
        assert_eq!(l.on_ack(1, 0, 1), RaftStep::Wait, "released flight: ack dropped");
    }
}
