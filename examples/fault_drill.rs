//! Fault drill: sweep crash scenarios (replica / follower / leader) across
//! both systems and print the recovery picture — the Fig 14 story as a
//! runnable demo, including the permission-switch histogram (Fig 13).
//!
//! Run: `cargo run --release --example fault_drill`

use safardb::config::{FaultSchedule, SimConfig, SystemKind, WorkloadKind};
use safardb::engine::cluster;
use safardb::rdt::RdtKind;

fn main() {
    println!("{:<26} {:>10} {:>10} {:>9} {:>10} {:>6}", "scenario", "rt_us", "tput", "elections", "p50switch", "conv");
    for system in [SystemKind::SafarDb, SystemKind::Hamband] {
        for (label, rdt, fault) in [
            ("baseline", RdtKind::Account, FaultSchedule::none()),
            ("follower-crash", RdtKind::Account, FaultSchedule::crash_at(3, 50)),
            ("leader-crash", RdtKind::Account, FaultSchedule::crash_leader_at(50)),
            ("crdt-replica-crash", RdtKind::TwoPSet, FaultSchedule::crash_at(2, 50)),
        ] {
            let mut cfg = match system {
                SystemKind::SafarDb => SimConfig::safardb(WorkloadKind::Micro(rdt)),
                _ => SimConfig::hamband(WorkloadKind::Micro(rdt)),
            };
            cfg.n_replicas = 4;
            cfg.update_pct = 20;
            cfg.total_ops = 60_000;
            cfg.fault = fault;
            let rep = cluster::run(cfg);
            assert!(rep.converged() && rep.invariants_ok, "{label} diverged");
            let switch = if rep.metrics.perm_switch.count() > 0 {
                format!("{}ns", rep.metrics.perm_switch.p50())
            } else {
                "-".into()
            };
            println!(
                "{:<26} {:>10.3} {:>10.3} {:>9} {:>10} {:>6}",
                format!("{}/{label}", system.name()),
                rep.response_us(),
                rep.throughput(),
                rep.metrics.elections,
                switch,
                rep.converged(),
            );
        }
    }
    println!("\nNote the permission-switch gap: ns on the FPGA vs 100s of us on the RNIC (Fig 13).");
}
