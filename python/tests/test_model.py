"""Layer-2 checks: export table shapes, composition semantics, and the
no-redundant-recompute perf property on the lowered HLO."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import ref


def test_export_table_shapes_lower():
    for name, (fn, specs) in model.EXPORTS.items():
        outs = jax.eval_shape(fn, *specs)
        assert isinstance(outs, tuple) and len(outs) >= 1, name


def test_two_p_set_merge_semantics():
    adds = jnp.array([[0b0111], [0b1000]], jnp.int32)
    removes = jnp.array([[0b0001], [0b0000]], jnp.int32)
    (present,) = model.two_p_set_merge(adds, removes)
    assert int(present[0]) == 0b1110  # removed bit 0 stays removed (2P rule)


def test_smallbank_burst_masks_rejected_guard_ops():
    k, b = 16, 8
    state = jnp.zeros(k, jnp.float32)
    keys = jnp.arange(b, dtype=jnp.int32)
    deltas = jnp.ones(b, jnp.float32) * 10
    b0 = jnp.array([5.0], jnp.float32)
    guard = jnp.array([-3.0, -3.0, -3.0, 1.0, -2.0, -9.0, 0.0, -1.0], jnp.float32)
    new_state, accept, bal = model.smallbank_burst(state, keys, deltas, b0, guard)
    wa, wb = ref.account_permissibility_ref(b0, guard)
    np.testing.assert_array_equal(accept, wa)
    np.testing.assert_allclose(bal, wb)
    np.testing.assert_allclose(new_state[:b], 10.0 * wa.astype(jnp.float32))


def _hlo_text(name):
    fn, specs = model.EXPORTS[name]
    from compile.aot import to_hlo_text

    return to_hlo_text(jax.jit(fn).lower(*specs))


def test_hlo_exports_parse_and_are_single_module():
    for name in model.EXPORTS:
        text = _hlo_text(name)
        assert text.count("HloModule") == 1, name
        assert "ENTRY" in text, name


def test_pn_merge_hlo_has_no_redundant_reduce():
    """Perf guard (DESIGN.md §Perf L2): the PN fold must lower to exactly two
    reduces (one per G-Counter) and one subtract — no recompute."""
    text = _hlo_text("pn_counter_merge")
    n_reduce = sum(1 for line in text.splitlines() if " reduce(" in line)
    assert n_reduce == 2, f"expected 2 reduces, got {n_reduce}"
