//! Experiment harness: one module per table/figure in the paper's
//! evaluation (§5 + appendix D). Each `run(quick)` returns the tables the
//! paper reports; `safardb expt <id>` prints them and writes CSV under
//! `results/`.
//!
//! See DESIGN.md §4 for the experiment index and EXPERIMENTS.md for
//! paper-vs-measured.

pub mod ablation;
pub mod backends;
pub mod bench;
pub mod chaos;
pub mod common;
pub mod fig06;
pub mod fig07;
pub mod fig08;
pub mod fig09;
pub mod fig10;
pub mod fig11;
pub mod fig12;
pub mod fig13;
pub mod fig14;
pub mod fig15;
pub mod fig16;
pub mod fig17;
pub mod fig24;
pub mod fig25_26;
pub mod fig27;
pub mod loadcurve;
pub mod scaleout;
pub mod table2_1;
pub mod tablec_1;

use crate::util::table::Table;

/// Every experiment id, in paper order.
pub const ALL: &[&str] = &[
    "table2_1", "tableC_1", "fig6", "fig7", "fig8", "fig9", "fig10", "fig11", "fig12", "fig13",
    "fig14", "fig15", "fig16", "fig17", "fig24", "fig25_26", "fig27", "ablation", "backends",
    "bench", "chaos", "loadcurve", "scaleout",
];

/// Canonical experiment id for `id`, accepting zero-padded aliases
/// (`fig06` -> `fig6`), or `None` when unknown. CSV filenames under
/// `results/` always use the canonical form regardless of how the
/// experiment was invoked.
pub fn canonical(id: &str) -> Option<&'static str> {
    let id = match id {
        "fig06" => "fig6",
        "fig07" => "fig7",
        "fig08" => "fig8",
        "fig09" => "fig9",
        "tablec_1" => "tableC_1",
        other => other,
    };
    ALL.iter().copied().find(|&c| c == id)
}

/// Dispatch by id (zero-padded aliases like `fig06` accepted; see
/// [`canonical`]). `quick` shrinks op counts / sweep density for CI-speed
/// runs; the shapes are preserved.
pub fn run(id: &str, quick: bool) -> Option<Vec<Table>> {
    let tables = match canonical(id)? {
        "table2_1" => table2_1::run(quick),
        "tableC_1" => tablec_1::run(quick),
        "fig6" => fig06::run(quick),
        "fig7" => fig07::run(quick),
        "fig8" => fig08::run(quick),
        "fig9" => fig09::run(quick),
        "fig10" => fig10::run(quick),
        "fig11" => fig11::run(quick),
        "fig12" => fig12::run(quick),
        "fig13" => fig13::run(quick),
        "fig14" => fig14::run(quick),
        "fig15" => fig15::run(quick),
        "fig16" => fig16::run(quick),
        "fig17" => fig17::run(quick),
        "fig24" => fig24::run(quick),
        "fig25_26" => fig25_26::run(quick),
        "fig27" => fig27::run(quick),
        "ablation" => ablation::run(quick),
        "backends" => backends::run(quick),
        "bench" => bench::run(quick),
        "chaos" => chaos::run(quick),
        "loadcurve" => loadcurve::run(quick),
        "scaleout" => scaleout::run(quick),
        _ => return None,
    };
    Some(tables)
}
