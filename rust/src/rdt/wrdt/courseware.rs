//! Courseware WRDT (Table B.1): university registrar.
//!
//! State: students S, courses C, enrollments E.
//! * addStudent(s) where s ∉ S — irreducible conflict-free.
//! * addCourse(c) where c ∉ C, deleteCourse(c) where c ∈ C,
//!   enroll(s, c) where s ∈ S ∧ c ∈ C ∧ (s,c) ∉ E — conflicting, one group.
//!
//! Invariant: referential integrity — every (s,c) ∈ E has s ∈ S and c ∈ C.
//! deleteCourse cascades its enrollments to preserve it.

use std::collections::HashSet;

use crate::rdt::{mix64, Category, OpCall, QueryValue, Rdt, RdtKind};
use crate::util::rng::Rng;

pub const OP_ADD_STUDENT: u8 = 0;
pub const OP_ADD_COURSE: u8 = 1;
pub const OP_DELETE_COURSE: u8 = 2;
pub const OP_ENROLL: u8 = 3;

const ID_UNIVERSE: u64 = 512;

#[derive(Clone, Debug, Default)]
pub struct Courseware {
    students: HashSet<u64>,
    courses: HashSet<u64>,
    enrollments: HashSet<(u64, u64)>,
}

impl Courseware {
    pub fn counts(&self) -> (usize, usize, usize) {
        (self.students.len(), self.courses.len(), self.enrollments.len())
    }
}

impl Rdt for Courseware {
    fn clone_box(&self) -> Box<dyn Rdt> {
        Box::new(self.clone())
    }

    fn kind(&self) -> RdtKind {
        RdtKind::Courseware
    }

    fn category(&self, opcode: u8) -> Category {
        match opcode {
            OP_ADD_STUDENT => Category::Irreducible,
            OP_ADD_COURSE | OP_DELETE_COURSE | OP_ENROLL => Category::Conflicting,
            _ => Category::Reducible,
        }
    }

    fn sync_group(&self, _opcode: u8) -> u8 {
        0
    }

    fn sync_groups(&self) -> u8 {
        1
    }

    fn permissible(&self, op: &OpCall) -> bool {
        match op.opcode {
            OP_ADD_STUDENT => !self.students.contains(&op.a),
            OP_ADD_COURSE => !self.courses.contains(&op.a),
            OP_DELETE_COURSE => self.courses.contains(&op.a),
            OP_ENROLL => {
                self.students.contains(&op.a)
                    && self.courses.contains(&op.b)
                    && !self.enrollments.contains(&(op.a, op.b))
            }
            _ => op.is_query(),
        }
    }

    fn apply(&mut self, op: &OpCall) -> bool {
        match op.opcode {
            OP_ADD_STUDENT => self.students.insert(op.a),
            OP_ADD_COURSE => self.courses.insert(op.a),
            OP_DELETE_COURSE => {
                if self.courses.remove(&op.a) {
                    self.enrollments.retain(|&(_, c)| c != op.a); // cascade
                    true
                } else {
                    false
                }
            }
            OP_ENROLL => {
                if self.students.contains(&op.a) && self.courses.contains(&op.b) {
                    self.enrollments.insert((op.a, op.b))
                } else {
                    false // impermissible at execution time
                }
            }
            _ => unreachable!("courseware opcode {}", op.opcode),
        }
    }

    fn apply_forced(&mut self, op: &OpCall) -> bool {
        match op.opcode {
            OP_ENROLL => self.enrollments.insert((op.a, op.b)), // student may still be in flight
            OP_DELETE_COURSE => {
                self.courses.remove(&op.a);
                self.enrollments.retain(|&(_, c)| c != op.a);
                true
            }
            _ => self.apply(op),
        }
    }

    fn query(&self) -> QueryValue {
        QueryValue::Pair(self.students.len() as i64, self.enrollments.len() as i64)
    }

    fn state_digest(&self) -> u64 {
        let ds = self.students.iter().fold(0u64, |a, &e| a ^ mix64(e));
        let dc = self.courses.iter().fold(0u64, |a, &e| a ^ mix64(e | 1 << 62));
        let de = self
            .enrollments
            .iter()
            .fold(0u64, |a, &(s, c)| a ^ mix64(s.wrapping_mul(0x1F3) ^ (c << 32)));
        ds ^ dc.rotate_left(17) ^ de.rotate_left(31)
    }

    fn invariant_ok(&self) -> bool {
        self.enrollments
            .iter()
            .all(|&(s, c)| self.students.contains(&s) && self.courses.contains(&c))
    }

    fn gen_update(&self, rng: &mut Rng) -> OpCall {
        match rng.gen_range(4) {
            0 => OpCall::new(OP_ADD_STUDENT, rng.gen_range(ID_UNIVERSE), 0, 0.0),
            1 => OpCall::new(OP_ADD_COURSE, rng.gen_range(ID_UNIVERSE), 0, 0.0),
            2 => OpCall::new(OP_DELETE_COURSE, rng.gen_range(ID_UNIVERSE), 0, 0.0),
            _ => OpCall::new(
                OP_ENROLL,
                rng.gen_range(ID_UNIVERSE),
                rng.gen_range(ID_UNIVERSE),
                0.0,
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn op2(opcode: u8, a: u64, b: u64) -> OpCall {
        OpCall::new(opcode, a, b, 0.0)
    }

    #[test]
    fn enroll_requires_both_sides() {
        let mut cw = Courseware::default();
        assert!(!cw.permissible(&op2(OP_ENROLL, 1, 2)));
        cw.apply(&op2(OP_ADD_STUDENT, 1, 0));
        cw.apply(&op2(OP_ADD_COURSE, 2, 0));
        assert!(cw.permissible(&op2(OP_ENROLL, 1, 2)));
        assert!(cw.apply(&op2(OP_ENROLL, 1, 2)));
        assert!(cw.invariant_ok());
    }

    #[test]
    fn delete_course_cascades_enrollments() {
        let mut cw = Courseware::default();
        cw.apply(&op2(OP_ADD_STUDENT, 1, 0));
        cw.apply(&op2(OP_ADD_COURSE, 2, 0));
        cw.apply(&op2(OP_ENROLL, 1, 2));
        assert!(cw.apply(&op2(OP_DELETE_COURSE, 2, 0)));
        assert!(cw.invariant_ok(), "cascade preserves referential integrity");
        assert_eq!(cw.counts().2, 0);
    }

    #[test]
    fn duplicate_add_course_impermissible() {
        let mut cw = Courseware::default();
        cw.apply(&op2(OP_ADD_COURSE, 9, 0));
        assert!(!cw.permissible(&op2(OP_ADD_COURSE, 9, 0)));
    }

    #[test]
    fn conflicting_ops_share_one_group() {
        let cw = Courseware::default();
        for opc in [OP_ADD_COURSE, OP_DELETE_COURSE, OP_ENROLL] {
            assert_eq!(cw.sync_group(opc), 0);
            assert_eq!(cw.category(opc), Category::Conflicting);
        }
        assert_eq!(cw.category(OP_ADD_STUDENT), Category::Irreducible);
    }

    #[test]
    fn same_total_order_converges() {
        let ops = [
            op2(OP_ADD_STUDENT, 1, 0),
            op2(OP_ADD_COURSE, 2, 0),
            op2(OP_ENROLL, 1, 2),
            op2(OP_DELETE_COURSE, 2, 0),
            op2(OP_ADD_COURSE, 2, 0),
        ];
        let mut a = Courseware::default();
        let mut b = Courseware::default();
        for o in &ops {
            a.apply(o);
            b.apply(o);
        }
        assert_eq!(a.state_digest(), b.state_digest());
    }
}
