//! Engine micro-benchmarks (harness=false; criterion unavailable offline).
//!
//! Times the coordinator hot paths the §Perf pass optimizes: DES event
//! throughput, verb issue, replica op processing (end-to-end events/s),
//! RNG/Zipf sampling, histogram recording, LRU access, and one batch
//! kernel invocation. Results feed EXPERIMENTS.md §Perf.

use std::time::Instant;

use safardb::config::{SimConfig, WorkloadKind};
use safardb::engine::cluster;
use safardb::mem::{LruCache, MemParams};
use safardb::net::fabric::FabricParams;
use safardb::rdt::RdtKind;
use safardb::sim::{EventKind, EventQueue, TimerKind};
use safardb::util::rng::{Rng, Zipf};
use safardb::util::stats::Histogram;

fn bench<F: FnMut()>(name: &str, iters: u64, mut f: F) {
    for _ in 0..iters / 10 {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let dt = t0.elapsed();
    let per = dt.as_nanos() as f64 / iters as f64;
    let rate = 1e9 / per / 1e6;
    println!("{name:<36} {per:>10.1} ns/op {rate:>9.2} Mops/s");
}

fn main() {
    println!("SafarDB engine micro-benchmarks\n");

    let mut rng = Rng::new(1);
    bench("rng_next_u64", 10_000_000, || {
        std::hint::black_box(rng.next_u64());
    });

    let zipf = Zipf::new(1_000_000, 0.99);
    bench("zipf_sample_theta_0.99", 2_000_000, || {
        std::hint::black_box(zipf.sample(&mut rng));
    });

    let mut h = Histogram::new();
    bench("histogram_record", 10_000_000, || {
        h.record(rng.next_u64() % 1_000_000);
    });

    let mut lru = LruCache::new(100_000);
    bench("lru_access_1M_keyspace", 2_000_000, || {
        std::hint::black_box(lru.access(rng.next_u64() % 1_000_000));
    });

    let mut q = EventQueue::new();
    let mut t = 0u64;
    bench("event_queue_push_pop", 2_000_000, || {
        t += 1;
        q.push(t, 0, EventKind::Timer(TimerKind::WorkDone));
        std::hint::black_box(q.pop());
    });

    let fab = FabricParams::fpga();
    let mem = MemParams::default_params();
    bench("fabric_one_way_cost", 10_000_000, || {
        std::hint::black_box(fab.one_way_ns(122, safardb::mem::MemKind::Hbm, &mem));
    });

    for (name, cfg) in [
        ("cluster_crdt_events", SimConfig::safardb(WorkloadKind::Micro(RdtKind::PnCounter))),
        ("cluster_wrdt_events", SimConfig::safardb(WorkloadKind::Micro(RdtKind::Account))),
        ("cluster_hamband_events", SimConfig::hamband(WorkloadKind::Micro(RdtKind::Account))),
    ] {
        let mut cfg = cfg;
        cfg.total_ops = 60_000;
        let t0 = Instant::now();
        let rep = cluster::run(cfg);
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "{name:<36} {:>10.2} M events/s ({} events, {:.2}s wall)",
            rep.metrics.events as f64 / dt / 1e6,
            rep.metrics.events,
            dt
        );
    }

    match safardb::runtime::Runtime::load("artifacts") {
        Ok(rt) => {
            let mut acc = safardb::runtime::Accelerator::new(rt);
            let state = vec![0f32; 1024];
            let keys: Vec<i32> = (0..256).map(|i| i % 1024).collect();
            let deltas = vec![1f32; 256];
            let t0 = Instant::now();
            let iters = 200;
            for _ in 0..iters {
                std::hint::black_box(acc.kv_burst_apply(&state, &keys, &deltas).unwrap());
            }
            let per_us = t0.elapsed().as_micros() as f64 / iters as f64;
            println!(
                "{:<36} {per_us:>10.1} us/call ({:.2} Mops/s through the runtime)",
                "kernel_kv_burst_apply_256",
                256.0 / per_us
            );
        }
        Err(e) => println!("kernel_kv_burst_apply_256            skipped (runtime load failed: {e:#})"),
    }
}
