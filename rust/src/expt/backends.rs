//! Backends — the consensus-backend × batch-size comparison the paper's
//! Mu-vs-Raft evaluation gestures at, extended with the APUS-style Paxos
//! strong path: Account (the §2.1 WRDT running example, one sync group)
//! and SmallBank (debit-heavy KV) under `backend ∈ {mu, raft, paxos}` and
//! `batch_size ∈ {1, 4, 16}`, 3–8 nodes at 25% updates.
//!
//! Expected shape: Paxos's single one-sided write round beats Mu's
//! four-round Prepare/Accept on commit latency at equal batch size; Raft
//! pays the logical-ack round trip; batching trades per-op wire cost for
//! small queueing delay on every backend. The CI backend matrix runs one
//! leg per backend via `--backend` (`common::set_backend_filter`).

use crate::config::{ConsensusBackend, SimConfig, WorkloadKind};
use crate::expt::common::{backend_filter, cell_ops, f3, nodes, run_cells_tagged};
use crate::rdt::RdtKind;
use crate::util::table::Table;

pub const BATCH_SWEEP: &[u32] = &[1, 4, 16];

pub fn run(quick: bool) -> Vec<Table> {
    let backends: Vec<ConsensusBackend> = match backend_filter() {
        Some(b) => vec![b],
        None => ConsensusBackend::ALL.to_vec(),
    };
    let mut tables = Vec::new();
    for workload in [WorkloadKind::Micro(RdtKind::Account), WorkloadKind::SmallBank] {
        let mut t = Table::new(
            &format!("Backends — consensus × batch on {}", workload.name()),
            &["backend", "batch", "nodes", "rt_us", "tput_ops_us", "smr_commits", "coalesced"],
        );
        let mut jobs = Vec::new();
        for &backend in &backends {
            for &batch in BATCH_SWEEP {
                for &n in nodes(quick) {
                    let mut cfg = SimConfig::safardb(workload);
                    cfg.backend = backend;
                    cfg.batch_size = batch;
                    cfg.n_replicas = n;
                    cfg.update_pct = 25;
                    jobs.push(((backend, batch, n), (cfg, cell_ops(quick))));
                }
            }
        }
        for ((backend, batch, n), cell, rep) in run_cells_tagged(jobs) {
            t.row(vec![
                backend.name().into(),
                batch.to_string(),
                n.to_string(),
                f3(cell.rt_us),
                f3(cell.tput),
                rep.metrics.smr_commits.to_string(),
                rep.metrics.coalesced.to_string(),
            ]);
        }
        tables.push(t);
    }
    tables
}
