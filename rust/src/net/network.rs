//! The network actor: turns issued verbs into delivery/ACK events with
//! fabric-calibrated latencies, enforcing reliable in-order delivery per
//! (src, dst) pair (the paper's network model, §3).

use crate::mem::MemParams;
use crate::net::fabric::FabricParams;
use crate::net::qp::QpTable;
use crate::net::verbs::{Verb, VerbKind};
use crate::sim::{EventKind, EventQueue, NodeId, Time};

/// Outcome of issuing a verb, as seen by the initiator.
#[derive(Clone, Copy, Debug)]
pub struct IssueOutcome {
    /// When the initiating compute element regains control.
    pub initiator_free_at: Time,
    /// When the payload is visible at the destination (None if nacked).
    pub delivered_at: Option<Time>,
}

#[derive(Debug)]
pub struct Network {
    mem: MemParams,
    /// In-order channel state: earliest next delivery time per (src, dst).
    channel_clear_at: Vec<Vec<Time>>,
    /// Separate lane for heartbeat-plane traffic (never queued behind bulk
    /// replication).
    hb_clear_at: Vec<Vec<Time>>,
    /// Crash state mirror (verbs to a crashed node vanish; no ACK).
    crashed: Vec<bool>,
    /// Partition state per directed link: verbs NACK after the
    /// retransmission timeout, like a crashed destination — but the sender
    /// still pays channel occupancy (no free lane on a cut link).
    partitioned: Vec<Vec<bool>>,
    /// Fault injection: remaining silent drops per directed link.
    drop_next: Vec<Vec<u32>>,
    /// Fault injection: one-way latency scale per directed link (percent;
    /// 100 = nominal — the empty-schedule fast path never multiplies).
    delay_pct: Vec<Vec<u32>>,
    pub verbs_issued: u64,
    pub verbs_nacked: u64,
    /// Verbs silently lost by `DropNext` injection.
    pub verbs_dropped: u64,
}

impl Network {
    pub fn new(n: usize, mem: MemParams) -> Self {
        Network {
            mem,
            channel_clear_at: vec![vec![0; n]; n],
            hb_clear_at: vec![vec![0; n]; n],
            crashed: vec![false; n],
            partitioned: vec![vec![false; n]; n],
            drop_next: vec![vec![0; n]; n],
            delay_pct: vec![vec![100; n]; n],
            verbs_issued: 0,
            verbs_nacked: 0,
            verbs_dropped: 0,
        }
    }

    pub fn set_crashed(&mut self, node: NodeId, crashed: bool) {
        self.crashed[node] = crashed;
    }

    pub fn is_crashed(&self, node: NodeId) -> bool {
        self.crashed[node]
    }

    /// Cut (or repair) the `a <-> b` link in both directions.
    pub fn set_partitioned(&mut self, a: NodeId, b: NodeId, cut: bool) {
        self.partitioned[a][b] = cut;
        self.partitioned[b][a] = cut;
    }

    pub fn is_partitioned(&self, src: NodeId, dst: NodeId) -> bool {
        self.partitioned[src][dst]
    }

    /// Repair every cut link.
    pub fn heal_all(&mut self) {
        for row in &mut self.partitioned {
            row.fill(false);
        }
    }

    /// Arm `count` silent drops on the directed src -> dst link.
    pub fn arm_drop(&mut self, src: NodeId, dst: NodeId, count: u32) {
        self.drop_next[src][dst] += count;
    }

    /// Scale the directed src -> dst one-way latency (100 = nominal).
    pub fn set_delay_pct(&mut self, src: NodeId, dst: NodeId, pct: u32) {
        self.delay_pct[src][dst] = pct.max(1);
    }

    pub fn mem(&self) -> &MemParams {
        &self.mem
    }

    /// Issue `verb` from `src` to `dst` at time `now` over `fabric`.
    ///
    /// Schedules `VerbDeliver` at the destination and, when the verb kind
    /// carries a completion, `AckDeliver`/`NackDeliver` back at the source.
    /// Returns initiator-side timing so the caller can advance its busy
    /// clock (Hamband blocks on the CQE; SafarDB only pays the issue cost).
    pub fn issue(
        &mut self,
        q: &mut EventQueue,
        qps: &QpTable,
        fabric: &FabricParams,
        now: Time,
        src: NodeId,
        dst: NodeId,
        verb: Verb,
        want_completion: bool,
    ) -> IssueOutcome {
        self.verbs_issued += 1;
        let bytes = verb.wire_bytes();
        let token = verb.token;

        // Permission check at the destination QPC. Only the follower's
        // leader-write QP is fenced by the Permission Switch (§4.4);
        // relaxed-path traffic rides per-peer QPs that stay open, and
        // one-sided reads are answered from memory regardless. Under
        // sharded placement the fence is per group: a node leading group A
        // is still NACKed when it leader-writes for group B.
        let fenced = verb.leader_qp && !qps.is_open_for(src, dst, verb.payload.group());
        let partitioned = self.partitioned[src][dst];

        if fenced || self.crashed[dst] || partitioned {
            self.verbs_nacked += 1;
            // Fenced QPs NACK after a round trip; a crashed destination or
            // a cut link stalls the verb until the retransmission timeout
            // expires — the sender observes a partition exactly like a
            // crash (§3 fault model, NACK-on-partition).
            let nack_at = if self.crashed[dst] || partitioned {
                now + fabric.crash_timeout_ns
            } else {
                now + fabric.ack_at_ns(bytes, verb.dst_mem, &self.mem)
            };
            if partitioned && !self.crashed[dst] {
                // A cut link is not a free lane: the NIC keeps the in-order
                // channel busy with retransmission attempts, so verbs
                // issued behind the loss still queue behind it.
                let one_way = fabric.one_way_ns(bytes, verb.dst_mem, &self.mem);
                let clear = if verb.payload.is_heartbeat() {
                    &mut self.hb_clear_at[src][dst]
                } else {
                    &mut self.channel_clear_at[src][dst]
                };
                *clear = (now + one_way).max(*clear + 1);
            }
            if want_completion {
                q.push(nack_at, src, EventKind::NackDeliver { token });
            }
            let free_at = if fabric.wait_ack { nack_at } else { now + fabric.verb_issue_ns };
            return IssueOutcome { initiator_free_at: free_at, delivered_at: None };
        }

        let mut one_way = fabric.one_way_ns(bytes, verb.dst_mem, &self.mem);
        let scale = self.delay_pct[src][dst];
        if scale != 100 {
            one_way = (one_way.saturating_mul(scale as u64) / 100).max(1);
        }
        // Reliable in-order per channel: delivery can't overtake the
        // previous verb on the same (src, dst) pair. Heartbeat-plane verbs
        // ride their own lane.
        let clear = if verb.payload.is_heartbeat() {
            &mut self.hb_clear_at[src][dst]
        } else {
            &mut self.channel_clear_at[src][dst]
        };
        let deliver_at = (now + one_way).max(*clear + 1);
        *clear = deliver_at;

        if self.drop_next[src][dst] > 0 {
            // The verb went on the wire (its channel slot is consumed) but
            // the payload is lost. Completion-carrying verbs surface as a
            // NACK at the retransmission timeout; fire-and-forget verbs
            // vanish — which is why the chaos-mode relaxed path tracks
            // completions and retries.
            self.drop_next[src][dst] -= 1;
            self.verbs_dropped += 1;
            if want_completion {
                q.push(now + fabric.crash_timeout_ns, src, EventKind::NackDeliver { token });
            }
            let free_at = if fabric.wait_ack {
                now + fabric.crash_timeout_ns
            } else {
                now + fabric.verb_issue_ns
            };
            return IssueOutcome { initiator_free_at: free_at, delivered_at: None };
        }

        let is_read = verb.kind == VerbKind::Read;
        q.push(deliver_at, dst, EventKind::VerbDeliver { src, verb });

        let ack_at = deliver_at + fabric.ack_overhead_ns;
        // Read verbs complete via the remote's ReadResp, not an ACK; they
        // still NACK above when fenced/crashed so initiators see failures.
        if want_completion && !is_read {
            q.push(ack_at, src, EventKind::AckDeliver { token });
        }
        let free_at = if fabric.wait_ack { ack_at } else { now + fabric.verb_issue_ns };
        IssueOutcome { initiator_free_at: free_at, delivered_at: Some(deliver_at) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::MemKind;
    use crate::net::verbs::Payload;

    fn setup(n: usize) -> (EventQueue, Network, QpTable, FabricParams) {
        (
            EventQueue::new(),
            Network::new(n, MemParams::default_params()),
            QpTable::full_mesh(n),
            FabricParams::fpga(),
        )
    }

    fn raw_write(token: u64) -> Verb {
        Verb::write(MemKind::Hbm, Payload::Raw { bytes: 64 }, token)
    }

    #[test]
    fn delivery_and_ack_scheduled() {
        let (mut q, mut net, qps, fab) = setup(2);
        let out = net.issue(&mut q, &qps, &fab, 0, 0, 1, raw_write(7), true);
        assert!(out.delivered_at.is_some());
        let ev1 = q.pop().unwrap();
        assert!(matches!(ev1.kind, EventKind::VerbDeliver { src: 0, .. }));
        assert_eq!(ev1.dest, 1);
        let ev2 = q.pop().unwrap();
        assert!(matches!(ev2.kind, EventKind::AckDeliver { token: 7 }));
        assert_eq!(ev2.dest, 0);
        assert!(ev2.time > ev1.time);
    }

    #[test]
    fn in_order_delivery_per_channel() {
        let (mut q, mut net, qps, fab) = setup(2);
        // Issue a large verb then a tiny one: the tiny one must not overtake.
        let big = Verb::write(MemKind::Hbm, Payload::Raw { bytes: 8192 }, 1);
        let tiny = Verb::write(MemKind::Reg, Payload::Raw { bytes: 1 }, 2);
        let d1 = net.issue(&mut q, &qps, &fab, 0, 0, 1, big, false).delivered_at.unwrap();
        let d2 = net.issue(&mut q, &qps, &fab, 5, 0, 1, tiny, false).delivered_at.unwrap();
        assert!(d2 > d1, "FIFO per (src,dst): {d2} <= {d1}");
    }

    #[test]
    fn closed_qp_nacks_writes() {
        let (mut q, mut net, mut qps, fab) = setup(2);
        qps.close(1, 0);
        let out = net.issue(&mut q, &qps, &fab, 0, 0, 1, raw_write(9).on_leader_qp(), true);
        assert!(out.delivered_at.is_none());
        let ev = q.pop().unwrap();
        assert!(matches!(ev.kind, EventKind::NackDeliver { token: 9 }));
        assert_eq!(net.verbs_nacked, 1);
    }

    #[test]
    fn reads_bypass_write_fencing() {
        let (mut q, mut net, mut qps, fab) = setup(2);
        qps.close(1, 0);
        let r = Verb::read(crate::net::verbs::ReadTarget::Heartbeat, 3);
        let out = net.issue(&mut q, &qps, &fab, 0, 0, 1, r, false);
        assert!(out.delivered_at.is_some(), "one-sided reads still answered");
    }

    #[test]
    fn relaxed_path_writes_unfenced() {
        // Only the leader-write QP is fenced (§4.4); relaxed RDT traffic
        // keeps flowing through a permission switch.
        let (mut q, mut net, mut qps, fab) = setup(2);
        qps.close(1, 0);
        let out = net.issue(&mut q, &qps, &fab, 0, 0, 1, raw_write(5), false);
        assert!(out.delivered_at.is_some());
    }

    #[test]
    fn crashed_destination_swallows_verbs() {
        let (mut q, mut net, qps, fab) = setup(2);
        net.set_crashed(1, true);
        let out = net.issue(&mut q, &qps, &fab, 0, 0, 1, raw_write(4), true);
        assert!(out.delivered_at.is_none());
        assert!(matches!(q.pop().unwrap().kind, EventKind::NackDeliver { token: 4 }));
    }

    #[test]
    fn partitioned_destination_nacks_like_a_crash() {
        let (mut q, mut net, qps, fab) = setup(2);
        net.set_partitioned(0, 1, true);
        let out = net.issue(&mut q, &qps, &fab, 0, 0, 1, raw_write(4), true);
        assert!(out.delivered_at.is_none());
        let ev = q.pop().unwrap();
        assert!(matches!(ev.kind, EventKind::NackDeliver { token: 4 }));
        assert_eq!(ev.time, fab.crash_timeout_ns, "partition NACKs at the retransmit timeout");
        assert_eq!(net.verbs_nacked, 1);
        // Symmetric cut; heal_all repairs both directions.
        assert!(net.is_partitioned(1, 0));
        net.heal_all();
        let out2 = net.issue(&mut q, &qps, &fab, 10_000, 0, 1, raw_write(5), false);
        assert!(out2.delivered_at.is_some(), "healed link delivers again");
    }

    #[test]
    fn partitioned_link_still_consumes_channel_occupancy() {
        // A sender must not get a free lane because the link is down: the
        // NACKed verb's retransmission attempts occupy the in-order channel,
        // so the next verb after a heal queues behind it.
        let (mut q, mut net, qps, fab) = setup(2);
        let big = Verb::write(MemKind::Hbm, Payload::Raw { bytes: 8192 }, 1);
        let big_one_way = fab.one_way_ns(big.wire_bytes(), MemKind::Hbm, net.mem());
        net.set_partitioned(0, 1, true);
        let out = net.issue(&mut q, &qps, &fab, 0, 0, 1, big, true);
        assert!(out.delivered_at.is_none());
        net.heal_all();
        let tiny = Verb::write(MemKind::Reg, Payload::Raw { bytes: 1 }, 2);
        let d = net.issue(&mut q, &qps, &fab, 5, 0, 1, tiny, false).delivered_at.unwrap();
        assert!(
            d > big_one_way,
            "tiny verb must queue behind the lost big verb's channel slot: {d} <= {big_one_way}"
        );
    }

    #[test]
    fn drop_next_loses_verbs_and_nacks_completions() {
        let (mut q, mut net, qps, fab) = setup(2);
        net.arm_drop(0, 1, 2);
        // Fire-and-forget drop: silent loss, channel slot still consumed.
        let out1 = net.issue(&mut q, &qps, &fab, 0, 0, 1, raw_write(1), false);
        assert!(out1.delivered_at.is_none());
        assert!(q.is_empty(), "no delivery, no completion");
        // Completion-carrying drop: NACK at the retransmit timeout.
        let out2 = net.issue(&mut q, &qps, &fab, 0, 0, 1, raw_write(2), true);
        assert!(out2.delivered_at.is_none());
        assert!(matches!(q.pop().unwrap().kind, EventKind::NackDeliver { token: 2 }));
        assert_eq!(net.verbs_dropped, 2);
        // Budget exhausted: traffic flows again, in order behind the drops.
        let out3 = net.issue(&mut q, &qps, &fab, 0, 0, 1, raw_write(3), false);
        assert!(out3.delivered_at.is_some());
    }

    #[test]
    fn delay_spike_scales_one_way_latency() {
        let (mut q, mut net, qps, fab) = setup(2);
        let base = net.issue(&mut q, &qps, &fab, 0, 0, 1, raw_write(1), false).delivered_at.unwrap();
        net.set_delay_pct(0, 1, 300);
        let slow =
            net.issue(&mut q, &qps, &fab, base + 1, 0, 1, raw_write(2), false).delivered_at.unwrap()
                - (base + 1);
        assert_eq!(slow, base * 3, "3x spike triples the one-way latency");
        net.set_delay_pct(0, 1, 100);
        let t0 = base * 10;
        let nominal =
            net.issue(&mut q, &qps, &fab, t0, 0, 1, raw_write(3), false).delivered_at.unwrap() - t0;
        assert_eq!(nominal, base, "restore returns to the calibrated latency");
    }

    #[test]
    fn wait_ack_fabric_blocks_initiator() {
        let mut q = EventQueue::new();
        let mut net = Network::new(2, MemParams::default_params());
        let qps = QpTable::full_mesh(2);
        let fab = FabricParams::traditional();
        let out = net.issue(
            &mut q,
            &qps,
            &fab,
            0,
            0,
            1,
            Verb::write(MemKind::HostDram, Payload::Raw { bytes: 64 }, 1),
            true,
        );
        assert!(out.initiator_free_at > 1_900, "CQE wait: {}", out.initiator_free_at);
    }
}
