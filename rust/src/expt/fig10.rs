//! Fig 10: the five WRDT micro-benchmarks — SafarDB (baseline verbs),
//! SafarDB (RPC), and Hamband.
//!
//! Headline: ≈12× lower RT / ≈6.8× higher throughput vs Hamband. SafarDB
//! (RPC) ≥ SafarDB everywhere; its edge is clearest on Auction (3 sync
//! groups) and absent on Movie (no query, no non-conflicting ops).

use crate::config::{SimConfig, WorkloadKind};
use crate::expt::common::{cell_ops, f3, nodes, run_cells_tagged, UPDATE_SWEEP};
use crate::rdt::RdtKind;
use crate::util::table::Table;

pub fn run(quick: bool) -> Vec<Table> {
    let mut tables = Vec::new();
    for &rdt in RdtKind::wrdt_benchmarks() {
        let mut t = Table::new(
            &format!("Fig 10 — {} (WRDT): SafarDB / SafarDB(RPC) / Hamband", rdt.name()),
            &["system", "nodes", "upd%", "rt_us", "tput_ops_us"],
        );
        let mut jobs = Vec::new();
        for system in ["SafarDB", "SafarDB(RPC)", "Hamband"] {
            for &n in nodes(quick) {
                for &u in UPDATE_SWEEP {
                    let mut cfg = match system {
                        "SafarDB" => SimConfig::safardb_baseline(WorkloadKind::Micro(rdt)),
                        "SafarDB(RPC)" => SimConfig::safardb(WorkloadKind::Micro(rdt)),
                        _ => SimConfig::hamband(WorkloadKind::Micro(rdt)),
                    };
                    cfg.n_replicas = n;
                    cfg.update_pct = u;
                    jobs.push(((system, n, u), (cfg, cell_ops(quick))));
                }
            }
        }
        for ((system, n, u), cell, _) in run_cells_tagged(jobs) {
            t.row(vec![
                system.into(),
                n.to_string(),
                u.to_string(),
                f3(cell.rt_us),
                f3(cell.tput),
            ]);
        }
        tables.push(t);
    }
    tables
}

pub fn headline(tables: &[Table]) -> (f64, f64) {
    let mut h_rt = Vec::new();
    let mut s_rt = Vec::new();
    let mut h_tp = Vec::new();
    let mut s_tp = Vec::new();
    for t in tables {
        for r in t.rows() {
            let (rt, tp): (f64, f64) = (r[3].parse().unwrap(), r[4].parse().unwrap());
            match r[0].as_str() {
                "SafarDB(RPC)" => {
                    s_rt.push(rt);
                    s_tp.push(tp);
                }
                "Hamband" => {
                    h_rt.push(rt);
                    h_tp.push(tp);
                }
                _ => {}
            }
        }
    }
    (
        crate::expt::common::geomean_ratio(&h_rt, &s_rt),
        crate::expt::common::geomean_ratio(&s_tp, &h_tp),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expt::common::geomean_ratio;

    #[test]
    fn wrdt_headline_and_rpc_never_loses() {
        let tables = run(true);
        assert_eq!(tables.len(), 5);
        let (rt_ratio, tput_ratio) = headline(&tables);
        assert!(rt_ratio > 4.0, "rt ratio {rt_ratio} (paper 12x)");
        assert!(tput_ratio > 4.0, "tput ratio {tput_ratio} (paper 6.8x)");
        // "we see no instances in which SafarDB clearly outperforms
        // SafarDB (RPC)" — geomean per benchmark must not favor baseline.
        for t in &tables {
            let series = |sys: &str| -> Vec<f64> {
                t.rows().iter().filter(|r| r[0] == sys).map(|r| r[3].parse().unwrap()).collect()
            };
            let ratio = geomean_ratio(&series("SafarDB"), &series("SafarDB(RPC)"));
            assert!(ratio > 0.9, "rpc must not clearly lose: {ratio}");
        }
    }
}
