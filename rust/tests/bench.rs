//! Integration: the `expt bench` perf-ratchet harness must emit a
//! well-formed `BENCH_engine.json` and be deterministic — event counts
//! and state digests bit-identical across repeated runs and across
//! worker-thread counts. A speedup that changes either is a correctness
//! bug, not a speedup (ISSUE: bench harness smoke test).

use safardb::expt::bench::{bench_cells, grid_ids, to_json, SCHEMA};
use safardb::util::json::Json;

#[test]
fn bench_json_document_is_well_formed() {
    let cells = bench_cells(true, 2);
    assert_eq!(cells.len(), 14, "3 backends x 2 batches x 2 catalogs + 2 pipelined");
    let doc = to_json(&cells, true, false);
    let parsed = Json::parse(&doc.render()).expect("writer output must parse");
    assert_eq!(parsed.get("schema").and_then(|s| s.as_str()), Some(SCHEMA));
    assert_eq!(parsed.get("provisional").and_then(|p| p.as_bool()), Some(false));
    let arr = parsed.get("cells").and_then(|c| c.as_arr()).expect("cells array");
    assert_eq!(arr.len(), 14);
    for c in arr {
        for key in [
            "id",
            "backend",
            "batch",
            "window",
            "objects",
            "placement",
            "ops",
            "events",
            "wall_s",
            "events_per_sec",
            "peak_rss_kb",
            "digest",
            "smr_round_p99_us",
            "inflight_max",
        ] {
            assert!(c.get(key).is_some(), "cell missing field '{key}'");
        }
        // The pipeline depth telemetry never exceeds the configured window.
        let w = c.get("window").unwrap().as_f64().unwrap();
        let inflight = c.get("inflight_max").unwrap().as_f64().unwrap();
        assert!(inflight <= w, "inflight_max {inflight} > window {w}");
        // Digests are 16-hex-digit strings (u64 doesn't fit f64).
        let d = c.get("digest").unwrap().as_str().expect("digest is a string");
        assert_eq!(d.len(), 16);
        assert!(d.chars().all(|ch| ch.is_ascii_hexdigit()));
        assert!(c.get("events").unwrap().as_f64().unwrap() > 0.0, "cells simulate real work");
    }
}

#[test]
fn bench_cells_deterministic_across_runs_and_threads() {
    let a = bench_cells(true, 1);
    let b = bench_cells(true, 1);
    let c = bench_cells(true, 2);
    for (x, y) in a.iter().zip(&b).chain(a.iter().zip(&c)) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.events, y.events, "{}: event count must be seed-deterministic", x.id);
        assert_eq!(x.digest, y.digest, "{}: state digest must be seed-deterministic", x.id);
        assert_eq!(x.ops, y.ops);
    }
}

#[test]
fn committed_baseline_parses_and_matches_grid() {
    let body = include_str!("data/BENCH_engine.json");
    let doc = Json::parse(body).expect("committed baseline must be valid JSON");
    assert_eq!(doc.get("schema").and_then(|s| s.as_str()), Some(SCHEMA));
    assert_eq!(
        doc.get("provisional").and_then(|p| p.as_bool()),
        Some(false),
        "baseline is blessed; bench-compare gates hard"
    );
    let baseline_ids: Vec<&str> = doc
        .get("cells")
        .and_then(|c| c.as_arr())
        .expect("cells array")
        .iter()
        .map(|c| c.get("id").unwrap().as_str().unwrap())
        .collect();
    // The committed ratchet baseline must cover exactly the canonical grid;
    // a grid change requires re-blessing the baseline in the same PR.
    let grid = grid_ids();
    assert_eq!(baseline_ids.len(), grid.len());
    for id in &grid {
        assert!(
            baseline_ids.contains(&id.as_str()),
            "baseline missing grid cell '{id}' — re-bless rust/tests/data/BENCH_engine.json"
        );
    }
}
