//! The replication engine (Layer 3 proper): replica actors over the DES,
//! the cluster builder/run loop, the opcode dispatcher, hybrid storage,
//! and the summarization batcher.

pub mod cluster;
pub mod replica;
pub mod store;

pub use cluster::{Cluster, RunReport};

use crate::metrics::RunMetrics;
use crate::net::{Network, QpTable};
use crate::sim::EventQueue;

/// Mutable cluster context handed to replica handlers (split-borrowed from
/// the cluster so replicas and shared infrastructure coexist).
pub struct Ctx<'a> {
    pub q: &'a mut EventQueue,
    pub net: &'a mut Network,
    pub qps: &'a mut QpTable,
    pub metrics: &'a mut RunMetrics,
    /// True once the op target is met: background timers stop re-arming so
    /// the event queue drains to quiescence.
    pub draining: bool,
}
