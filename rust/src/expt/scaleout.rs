//! Scale-out — the multi-object catalog sweep: object count × consensus
//! backend × cluster size, the ROADMAP's sharding step ("millions of
//! users" = many objects, not one hot counter). Homogeneous Account
//! catalogs (`account:N`, one sync group per object, so Mu runs N round
//! pipelines while Raft/Paxos tag one total log) scale N ∈ {1, 4, 16, 64};
//! a `mixed` multi-tenant cell per backend exercises heterogeneous
//! routing. Zipfian object selection (θ = 0.6) keeps some objects hotter
//! than others, like real tenants.
//!
//! Per-object telemetry rides along: applied-op min/max/total across
//! objects shows the skew, rejected totals show invariant pressure. The
//! CI smoke leg (`expt scaleout --quick --threads 2 --backend ...`) runs
//! one backend per matrix job.

use crate::config::{CatalogSpec, ConsensusBackend, SimConfig, WorkloadKind};
use crate::expt::common::{backend_filter, f3, run_cells_tagged};
use crate::rdt::RdtKind;
use crate::util::table::Table;

/// Object-count axis (the acceptance sweep).
pub const OBJECT_SWEEP: &[u32] = &[1, 4, 16, 64];
pub const OBJECT_SWEEP_QUICK: &[u32] = &[1, 16];

pub fn run(quick: bool) -> Vec<Table> {
    let backends: Vec<ConsensusBackend> = match backend_filter() {
        Some(b) => vec![b],
        None => ConsensusBackend::ALL.to_vec(),
    };
    let objects: &[u32] = if quick { OBJECT_SWEEP_QUICK } else { OBJECT_SWEEP };
    let nodes: &[usize] = if quick { &[3] } else { &[3, 5] };
    let ops: u64 = if quick { 8_000 } else { 24_000 };

    let mut t = Table::new(
        "Scale-out — objects × backend × nodes (Account catalog + mixed, 25% updates)",
        &[
            "catalog",
            "objects",
            "backend",
            "nodes",
            "rt_us",
            "tput_ops_us",
            "smr_commits",
            "obj_applied_min",
            "obj_applied_max",
            "obj_applied_total",
            "obj_rejected_total",
        ],
    );
    let mut jobs = Vec::new();
    for (bi, &backend) in backends.iter().enumerate() {
        for (oi, &n_obj) in objects.iter().enumerate() {
            for &n in nodes {
                let mut cfg = SimConfig::safardb(WorkloadKind::Micro(RdtKind::Account));
                cfg.objects = CatalogSpec::parse(&format!("account:{n_obj}"))
                    .expect("homogeneous spec parses");
                cfg.objects.zipf_theta = 0.6;
                cfg.backend = backend;
                cfg.n_replicas = n;
                cfg.update_pct = 25;
                cfg.seed = 0x5CA1_E000 + (bi as u64) * 0x1000 + (oi as u64) * 0x10 + n as u64;
                jobs.push(((format!("account:{n_obj}"), backend, n), (cfg, ops)));
            }
        }
        // One heterogeneous multi-tenant cell per backend.
        for &n in nodes {
            let mut cfg = SimConfig::safardb(WorkloadKind::Micro(RdtKind::Account));
            cfg.objects = CatalogSpec::mixed();
            cfg.objects.zipf_theta = 0.6;
            cfg.backend = backend;
            cfg.n_replicas = n;
            cfg.update_pct = 25;
            cfg.seed = 0x5CA1_F000 + (bi as u64) * 0x1000 + n as u64;
            jobs.push((("mixed".to_string(), backend, n), (cfg, ops)));
        }
    }
    for ((catalog, backend, n), cell, rep) in run_cells_tagged(jobs) {
        let applied = &rep.metrics.obj_applied;
        let rejected = &rep.metrics.obj_rejected;
        t.row(vec![
            catalog,
            applied.len().to_string(),
            backend.name().into(),
            n.to_string(),
            f3(cell.rt_us),
            f3(cell.tput),
            rep.metrics.smr_commits.to_string(),
            applied.iter().min().copied().unwrap_or(0).to_string(),
            applied.iter().max().copied().unwrap_or(0).to_string(),
            applied.iter().sum::<u64>().to_string(),
            rejected.iter().sum::<u64>().to_string(),
        ]);
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_sweep_scales_objects_with_telemetry() {
        crate::expt::common::set_threads(2);
        let t = &run(true)[0];
        let backends = match backend_filter() {
            Some(_) => 1,
            None => ConsensusBackend::ALL.len(),
        };
        // (|OBJECT_SWEEP_QUICK| homogeneous + 1 mixed) × 1 node count.
        assert_eq!(t.rows().len(), backends * (OBJECT_SWEEP_QUICK.len() + 1));
        for row in t.rows() {
            let objects: usize = row[1].parse().unwrap();
            let applied_total: u64 = row[9].parse().unwrap();
            assert!(objects >= 1);
            assert!(applied_total > 0, "catalog saw traffic: {row:?}");
            if row[0] == "mixed" {
                assert_eq!(objects, CatalogSpec::mixed().n_objects());
            }
            let min: u64 = row[7].parse().unwrap();
            let max: u64 = row[8].parse().unwrap();
            assert!(min <= max);
            if objects > 1 {
                // Zipf-skewed selection: the hottest object leads.
                assert!(max > min, "skewed traffic across objects: {row:?}");
            }
        }
    }
}
