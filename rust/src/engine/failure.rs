//! Leader-switch / failure plane (§3, §4.4): the heartbeat tracker and
//! scanner, crash/recover handling, smallest-live-ID election, and the
//! permission switch. Owns the membership view every replication path
//! consults (via the [`Membership`] trait) and reports failures,
//! recoveries, and leadership changes into the paths as
//! [`MembershipEvent`]s.

use crate::config::{SimConfig, SystemKind};
use crate::engine::path::{Membership, MembershipEvent, ReplicaCore, ReplicationPath, TokenCtx};
use crate::engine::Ctx;
use crate::net::verbs::{ReadTarget, Verb};
use crate::sim::{EventKind, NodeId, TimerKind};
use crate::smr::election::{HbVerdict, HeartbeatTracker, PlacementTable};

pub struct FailurePlane {
    tracker: HeartbeatTracker,
    /// Per-group leadership view (sharded strong plane). Under
    /// `placement=single` every group maps to the classic leader and the
    /// table is never consulted on the crash path.
    table: PlacementTable,
    /// RDMA-exposed heartbeat counter peers read one-sidedly.
    pub hb_counter: u64,
}

impl FailurePlane {
    pub fn new(cfg: &SimConfig, id: NodeId, groups: usize) -> Self {
        FailurePlane {
            tracker: HeartbeatTracker::new(id, cfg.n_replicas, cfg.hb_fail_threshold),
            table: PlacementTable::new(cfg.placement, groups, cfg.n_replicas),
            hb_counter: 0,
        }
    }

    /// Adopt a placement snapshot (state-transfer install on a recovering
    /// replica): the rebalanced view must survive the snapshot, otherwise
    /// the ex-leader would resurrect its pre-crash placement.
    pub fn install_placement(&mut self, leaders: &[NodeId]) {
        self.table.install(leaders);
    }

    pub fn boot(&mut self, core: &ReplicaCore, ctx: &mut Ctx, base: u64) {
        // Heartbeat scanning runs for every object class: WRDTs need it for
        // leader election; CRDTs need it for membership (a crashed peer
        // must leave the relaxed-path fan-out set — Fig 14 e/f).
        ctx.q.push(base + core.heartbeat_period_ns, core.id, EventKind::Timer(TimerKind::HeartbeatScan));
    }

    pub fn on_crash(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx) {
        core.crashed = true;
        ctx.net.set_crashed(core.id, true);
        // In-flight client slots die with the replica; their quota was
        // consumed and is redistributed by the cluster.
        core.clients_in_flight = 0;
    }

    pub fn on_recover(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx) {
        core.crashed = false;
        ctx.net.set_crashed(core.id, false);
        core.busy_until = ctx.q.now();
        // Heartbeat resumes; peers will observe Recovered.
        ctx.q.push(ctx.q.now() + core.heartbeat_period_ns, core.id, EventKind::Timer(TimerKind::HeartbeatScan));
    }

    /// Heartbeat scanner tick: bump our own counter, read every peer's.
    pub fn on_scan(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx) {
        self.hb_counter += 1;
        // Hamband's scanner is a software thread competing with the
        // app (§5.3 "In Hamband, this update occurs in the
        // foreground"); SafarDB's is fabric logic.
        if core.system == SystemKind::Hamband {
            core.occupy(ctx.q.now(), core.exec().software_overhead_ns);
        }
        for i in 0..core.peers.len() {
            let peer = core.peers[i];
            let tok = core.token(TokenCtx::Heartbeat { peer });
            let verb = Verb::read(ReadTarget::Heartbeat, tok);
            ctx.metrics.verbs += 1;
            ctx.net.issue(ctx.q, ctx.qps, &core.sys.fabric, ctx.q.now(), core.id, peer, verb, true);
        }
        if !ctx.draining {
            ctx.q.push(ctx.q.now() + core.heartbeat_period_ns, core.id, EventKind::Timer(TimerKind::HeartbeatScan));
        }
    }

    /// One heartbeat observation of `peer` (`None` = read never completed).
    pub fn on_heartbeat(
        &mut self,
        core: &mut ReplicaCore,
        strong: &mut dyn ReplicationPath,
        ctx: &mut Ctx,
        peer: NodeId,
        value: Option<u64>,
    ) {
        let verdict = match value {
            Some(v) => self.tracker.observe(peer, v),
            None => self.tracker.observe_timeout(peer),
        };
        match verdict {
            HbVerdict::JustFailed => {
                if std::env::var_os("SAFARDB_DEBUG").is_some() {
                    eprintln!("[{}ns] r{}: declared r{} FAILED", ctx.q.now(), core.id, peer);
                }
                // Fault-timeline telemetry: the chaos harness derives each
                // incident's detection latency from these observations.
                ctx.metrics.detections.push((ctx.q.now(), peer, core.id));
                if core.placement.is_sharded() {
                    self.sharded_crash(core, strong, ctx, peer);
                } else if peer == core.leader {
                    self.leader_switch(core, strong, ctx);
                } else if core.is_leader() {
                    strong.on_membership(core, ctx, &*self, MembershipEvent::PeerFailed { peer });
                }
            }
            HbVerdict::Recovered => {
                ctx.metrics.recoveries.push((ctx.q.now(), peer, core.id));
                // `leads_any()` collapses to `is_leader()` under
                // placement=single; under sharding every group leader must
                // learn the peer is back (anti-entropy replay, fan-out set).
                if core.leads_any() {
                    strong.on_membership(core, ctx, &*self, MembershipEvent::PeerRecovered { peer });
                }
            }
            _ => {}
        }
    }

    /// The leader failed: elect, fence the old leader's QP, open the new
    /// one (Permission Switch, Fig 13), and hand the paths the new view.
    fn leader_switch(&mut self, core: &mut ReplicaCore, strong: &mut dyn ReplicationPath, ctx: &mut Ctx) {
        let old = core.leader;
        let new = self.tracker.elect_leader();
        if new == old {
            return;
        }
        if std::env::var_os("SAFARDB_DEBUG").is_some() {
            eprintln!(
                "[{}ns] r{}: leader switch {} -> {} (live {:?})",
                ctx.q.now(),
                core.id,
                old,
                new,
                self.tracker.live_set()
            );
        }
        // Permission switch: close the old leader's QP, open the new one.
        // FPGA: direct QP-register pokes, ns-scale; RNIC: driver + PCIe.
        let lat = core.sys.fabric.perm_switch.sample(&mut core.rng);
        ctx.metrics.perm_switch.record(lat);
        ctx.qps.switch_leader(core.id, old, new);
        core.occupy(ctx.q.now(), lat);
        core.leader = new;
        strong.on_membership(core, ctx, &*self, MembershipEvent::LeaderSwitched);
        if new != core.id {
            // Ask the new leader for a log replay: its own takeover
            // broadcast may have been fenced here if our permission switch
            // ran after it (the broadcast covers the reverse ordering).
            core.request_sync(ctx, new);
        }
    }

    /// Sharded-placement crash handling: reassign only the groups the dead
    /// node led, refence QPs against the full per-group leader set, and
    /// hand the paths the new placement in one event. Groups led by live
    /// nodes are untouched (sticky rebalance).
    fn sharded_crash(&mut self, core: &mut ReplicaCore, strong: &mut dyn ReplicationPath, ctx: &mut Ctx, dead: NodeId) {
        let live = self.tracker.live_set();
        let changed = self.table.on_crash(dead, &live);
        if dead == core.leader {
            // Keep the anchor leader view (boot fan-out, debug) pointing at
            // a live node; per-group routing never reads it when sharded.
            core.leader = self.tracker.elect_leader();
        }
        if changed.is_empty() {
            // Dead node led nothing: surviving leaders still shrink their
            // commit quorums, same as the single-leader PeerFailed path.
            if core.leads_any() {
                strong.on_membership(core, ctx, &*self, MembershipEvent::PeerFailed { peer: dead });
            }
            return;
        }
        if std::env::var_os("SAFARDB_DEBUG").is_some() {
            eprintln!(
                "[{}ns] r{}: rebalanced {} group(s) off dead r{}: {:?} (live {:?})",
                ctx.q.now(),
                core.id,
                changed.len(),
                dead,
                changed,
                live
            );
        }
        // One permission switch covers the whole refence: the QP table row
        // is rebuilt in a single pass however many groups moved (FPGA:
        // batched QP-register pokes).
        let lat = core.sys.fabric.perm_switch.sample(&mut core.rng);
        ctx.metrics.perm_switch.record(lat);
        core.occupy(ctx.q.now(), lat);
        core.group_leaders.clear();
        core.group_leaders.extend_from_slice(self.table.leaders());
        ctx.qps.refence(core.id, self.table.leaders());
        strong.on_membership(core, ctx, &*self, MembershipEvent::GroupLeadersChanged);
        // Ask each distinct new leader (other than us) for a log replay of
        // the groups it inherited — its takeover broadcast may have been
        // fenced here if our permission switch ran after it.
        let mut asked: Vec<NodeId> = Vec::new();
        for &(_, new) in &changed {
            if new != core.id && !asked.contains(&new) {
                asked.push(new);
                core.request_sync(ctx, new);
            }
        }
    }
}

impl Membership for FailurePlane {
    fn live_set(&self) -> Vec<NodeId> {
        self.tracker.live_set()
    }

    fn live_peers(&self, me: NodeId) -> Vec<NodeId> {
        self.tracker.live_set().into_iter().filter(|&i| i != me).collect()
    }

    fn elect_leader(&self) -> NodeId {
        self.tracker.elect_leader()
    }
}
