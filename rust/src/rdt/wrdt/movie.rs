//! Movie WRDT (Table B.1): theater ticketing database.
//!
//! State: customers C, movies M. All four transactions are conflicting and
//! form **two** synchronization groups (§2.1's example): {addCustomer,
//! deleteCustomer} and {addMovie, deleteMovie}. Movie has *no* query()
//! transaction and no non-conflicting transactions (§5.2), which is why the
//! RPC variant shows no advantage on it — the experiment reproduces that.

use std::collections::HashSet;

use crate::rdt::{mix64, Category, OpCall, QueryValue, Rdt, RdtKind};
use crate::util::rng::Rng;

pub const OP_ADD_CUSTOMER: u8 = 0;
pub const OP_DELETE_CUSTOMER: u8 = 1;
pub const OP_ADD_MOVIE: u8 = 2;
pub const OP_DELETE_MOVIE: u8 = 3;

pub const GROUP_CUSTOMER: u8 = 0;
pub const GROUP_MOVIE: u8 = 1;

const ID_UNIVERSE: u64 = 512;

#[derive(Clone, Debug, Default)]
pub struct Movie {
    customers: HashSet<u64>,
    movies: HashSet<u64>,
}

impl Rdt for Movie {
    fn clone_box(&self) -> Box<dyn Rdt> {
        Box::new(self.clone())
    }

    fn kind(&self) -> RdtKind {
        RdtKind::Movie
    }

    fn category(&self, _opcode: u8) -> Category {
        Category::Conflicting
    }

    fn sync_group(&self, opcode: u8) -> u8 {
        match opcode {
            OP_ADD_CUSTOMER | OP_DELETE_CUSTOMER => GROUP_CUSTOMER,
            _ => GROUP_MOVIE,
        }
    }

    fn sync_groups(&self) -> u8 {
        2
    }

    fn permissible(&self, op: &OpCall) -> bool {
        match op.opcode {
            OP_ADD_CUSTOMER => !self.customers.contains(&op.a),
            OP_DELETE_CUSTOMER => self.customers.contains(&op.a),
            OP_ADD_MOVIE => !self.movies.contains(&op.a),
            OP_DELETE_MOVIE => self.movies.contains(&op.a),
            _ => op.is_query(),
        }
    }

    fn apply(&mut self, op: &OpCall) -> bool {
        match op.opcode {
            OP_ADD_CUSTOMER => self.customers.insert(op.a),
            OP_DELETE_CUSTOMER => self.customers.remove(&op.a),
            OP_ADD_MOVIE => self.movies.insert(op.a),
            OP_DELETE_MOVIE => self.movies.remove(&op.a),
            _ => unreachable!("movie opcode {}", op.opcode),
        }
    }

    fn query(&self) -> QueryValue {
        QueryValue::Pair(self.customers.len() as i64, self.movies.len() as i64)
    }

    fn has_query(&self) -> bool {
        false // §5.2: Movie has no query transaction
    }

    fn state_digest(&self) -> u64 {
        let dc = self.customers.iter().fold(0u64, |a, &e| a ^ mix64(e));
        let dm = self.movies.iter().fold(0u64, |a, &e| a ^ mix64(e | 1 << 60));
        dc ^ dm.rotate_left(19)
    }

    fn gen_update(&self, rng: &mut Rng) -> OpCall {
        let opcode = match rng.gen_range(4) {
            0 => OP_ADD_CUSTOMER,
            1 => OP_DELETE_CUSTOMER,
            2 => OP_ADD_MOVIE,
            _ => OP_DELETE_MOVIE,
        };
        OpCall::new(opcode, rng.gen_range(ID_UNIVERSE), 0, 0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn op1(opcode: u8, a: u64) -> OpCall {
        OpCall::new(opcode, a, 0, 0.0)
    }

    #[test]
    fn two_sync_groups_partition_ops() {
        let m = Movie::default();
        assert_eq!(m.sync_group(OP_ADD_CUSTOMER), GROUP_CUSTOMER);
        assert_eq!(m.sync_group(OP_DELETE_CUSTOMER), GROUP_CUSTOMER);
        assert_eq!(m.sync_group(OP_ADD_MOVIE), GROUP_MOVIE);
        assert_eq!(m.sync_group(OP_DELETE_MOVIE), GROUP_MOVIE);
        assert_eq!(m.sync_groups(), 2);
    }

    #[test]
    fn all_ops_conflicting() {
        let m = Movie::default();
        for opc in [OP_ADD_CUSTOMER, OP_DELETE_CUSTOMER, OP_ADD_MOVIE, OP_DELETE_MOVIE] {
            assert_eq!(m.category(opc), Category::Conflicting);
        }
    }

    #[test]
    fn delete_requires_presence() {
        let mut m = Movie::default();
        assert!(!m.permissible(&op1(OP_DELETE_MOVIE, 3)));
        m.apply(&op1(OP_ADD_MOVIE, 3));
        assert!(m.permissible(&op1(OP_DELETE_MOVIE, 3)));
        assert!(m.apply(&op1(OP_DELETE_MOVIE, 3)));
    }

    #[test]
    fn same_order_converges() {
        let ops = [
            op1(OP_ADD_MOVIE, 1),
            op1(OP_ADD_CUSTOMER, 2),
            op1(OP_DELETE_MOVIE, 1),
            op1(OP_ADD_MOVIE, 1),
        ];
        let mut a = Movie::default();
        let mut b = Movie::default();
        for o in &ops {
            a.apply(o);
            b.apply(o);
        }
        assert_eq!(a.state_digest(), b.state_digest());
    }
}
