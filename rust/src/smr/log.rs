//! Replication log (§4.3): per-synchronization-group ordered slots of
//! `(proposal, operation)`. Allocated in HBM in the paper because it can
//! outgrow on-fabric storage; here it is a real Vec the recovery path
//! replays from.

use crate::rdt::OpCall;

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LogEntry {
    pub proposal: u64,
    pub op: OpCall,
}

#[derive(Clone, Debug, Default)]
pub struct ReplicationLog {
    slots: Vec<Option<LogEntry>>,
    /// Highest proposal number this replica has promised/seen (Mu's
    /// min-proposal register, RDMA-readable).
    pub min_proposal: u64,
    /// Slots `< applied_upto` have been executed against local state.
    pub applied_upto: u64,
}

impl ReplicationLog {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> u64 {
        self.slots.len() as u64
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// First never-written slot index (leader's append point).
    pub fn next_free_slot(&self) -> u64 {
        self.slots.iter().rposition(|s| s.is_some()).map(|i| i as u64 + 1).unwrap_or(0)
    }

    pub fn read_slot(&self, slot: u64) -> Option<LogEntry> {
        self.slots.get(slot as usize).copied().flatten()
    }

    /// Write a slot (leader's Accept write, or recovery replay). Higher
    /// proposals overwrite lower ones; equal/lower are ignored (stale
    /// leader fencing at the data level).
    pub fn write_slot(&mut self, slot: u64, proposal: u64, op: OpCall) -> bool {
        let idx = slot as usize;
        if idx >= self.slots.len() {
            self.slots.resize(idx + 1, None);
        }
        match self.slots[idx] {
            Some(e) if e.proposal >= proposal => false,
            _ => {
                self.slots[idx] = Some(LogEntry { proposal, op });
                true
            }
        }
    }

    pub fn bump_min_proposal(&mut self, proposal: u64) -> bool {
        if proposal > self.min_proposal {
            self.min_proposal = proposal;
            true
        } else {
            false
        }
    }

    /// Contiguously committed entries not yet applied; advances
    /// `applied_upto`. This is what the follower's poller (§4.3 config 1)
    /// or the write-through path drains.
    pub fn drain_unapplied(&mut self) -> Vec<LogEntry> {
        let mut out = Vec::new();
        while let Some(e) = self.read_slot(self.applied_upto) {
            out.push(e);
            self.applied_upto += 1;
        }
        out
    }

    /// Entries from `from` upward — the leader's recovery replay for a
    /// returned follower (§3 Fault Model).
    pub fn entries_from(&self, from: u64) -> Vec<(u64, LogEntry)> {
        (from..self.next_free_slot())
            .filter_map(|s| self.read_slot(s).map(|e| (s, e)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn op(n: u64) -> OpCall {
        OpCall::new(0, n, 0, 0.0)
    }

    #[test]
    fn append_and_read() {
        let mut l = ReplicationLog::new();
        assert_eq!(l.next_free_slot(), 0);
        assert!(l.write_slot(0, 1, op(10)));
        assert_eq!(l.next_free_slot(), 1);
        assert_eq!(l.read_slot(0).unwrap().op.a, 10);
        assert!(l.read_slot(1).is_none());
    }

    #[test]
    fn higher_proposal_overwrites() {
        let mut l = ReplicationLog::new();
        l.write_slot(0, 2, op(1));
        assert!(!l.write_slot(0, 1, op(2)), "stale proposal rejected");
        assert!(!l.write_slot(0, 2, op(3)), "equal proposal rejected");
        assert!(l.write_slot(0, 3, op(4)));
        assert_eq!(l.read_slot(0).unwrap().op.a, 4);
    }

    #[test]
    fn drain_applies_contiguous_prefix_only() {
        let mut l = ReplicationLog::new();
        l.write_slot(0, 1, op(0));
        l.write_slot(2, 1, op(2)); // gap at slot 1
        let d = l.drain_unapplied();
        assert_eq!(d.len(), 1);
        assert_eq!(l.applied_upto, 1);
        l.write_slot(1, 1, op(1));
        let d2 = l.drain_unapplied();
        assert_eq!(d2.len(), 2, "gap filled, both drain");
        assert_eq!(l.applied_upto, 3);
    }

    #[test]
    fn min_proposal_monotone() {
        let mut l = ReplicationLog::new();
        assert!(l.bump_min_proposal(5));
        assert!(!l.bump_min_proposal(5));
        assert!(!l.bump_min_proposal(3));
        assert_eq!(l.min_proposal, 5);
    }

    #[test]
    fn recovery_replay_range() {
        let mut l = ReplicationLog::new();
        for s in 0..5 {
            l.write_slot(s, 1, op(s));
        }
        let replay = l.entries_from(2);
        assert_eq!(replay.len(), 3);
        assert_eq!(replay[0].0, 2);
        assert_eq!(replay[2].1.op.a, 4);
    }
}
