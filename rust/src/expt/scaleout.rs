//! Scale-out — the multi-object catalog sweep: object count × consensus
//! backend × cluster size × leadership placement, the ROADMAP's sharding
//! step ("millions of users" = many objects, not one hot counter).
//! Homogeneous Account catalogs (`account:N`, one sync group per object,
//! so Mu runs N round pipelines while Raft/Paxos tag one total log) scale
//! N ∈ {1, 4, 16, 64}; a `mixed` multi-tenant cell per backend exercises
//! heterogeneous routing. Zipfian object selection (θ = 0.6) keeps some
//! objects hotter than others, like real tenants.
//!
//! The placement axis (`--placement`, default `single` + `hash` on full
//! sweeps) is the multi-leader acceptance sweep: with `hash`, each sync
//! group's leader is rendezvous-placed across the cluster, so strong-path
//! throughput scales with nodes instead of serializing on one leader. The
//! pinned acceptance cell is `account:16` at `nodes=5` (Raft and Paxos):
//! `hash` ≥ 1.5× `single` throughput, recorded in the CSV artifact.
//!
//! Per-object telemetry rides along: applied-op min/max/total across
//! objects shows the skew, rejected totals show invariant pressure, and
//! `groups_led` ("a/b/c" per node) shows the placement spread. The CI
//! smoke legs (`expt scaleout --quick --threads 2 --backend ...`, plus a
//! `--placement hash` leg per backend) run one backend per matrix job.

use crate::config::{CatalogSpec, ConsensusBackend, LeaderPlacement, SimConfig, WorkloadKind};
use crate::expt::common::{backend_filter, f3, placement_filter, run_cells_tagged};
use crate::rdt::RdtKind;
use crate::util::table::Table;

/// Object-count axis (the acceptance sweep).
pub const OBJECT_SWEEP: &[u32] = &[1, 4, 16, 64];
pub const OBJECT_SWEEP_QUICK: &[u32] = &[1, 16];

pub fn run(quick: bool) -> Vec<Table> {
    let backends: Vec<ConsensusBackend> = match backend_filter() {
        Some(b) => vec![b],
        None => ConsensusBackend::ALL.to_vec(),
    };
    let placements: Vec<LeaderPlacement> = match placement_filter() {
        Some(p) => vec![p],
        // Quick sweeps stay single-placement (the CI hash legs opt in via
        // --placement); full sweeps carry the acceptance comparison.
        None if quick => vec![LeaderPlacement::Single],
        None => vec![LeaderPlacement::Single, LeaderPlacement::Hash],
    };
    let objects: &[u32] = if quick { OBJECT_SWEEP_QUICK } else { OBJECT_SWEEP };
    let nodes: &[usize] = if quick { &[3] } else { &[3, 5] };
    let ops: u64 = if quick { 8_000 } else { 24_000 };

    let mut t = Table::new(
        "Scale-out — objects × backend × nodes × placement (Account catalog + mixed, 25% updates)",
        &[
            "catalog",
            "objects",
            "backend",
            "placement",
            "nodes",
            "rt_us",
            "tput_ops_us",
            "smr_commits",
            "obj_applied_min",
            "obj_applied_max",
            "obj_applied_total",
            "obj_rejected_total",
            "groups_led",
        ],
    );
    let mut jobs = Vec::new();
    for &placement in &placements {
        for (bi, &backend) in backends.iter().enumerate() {
            for (oi, &n_obj) in objects.iter().enumerate() {
                for &n in nodes {
                    let mut cfg = SimConfig::safardb(WorkloadKind::Micro(RdtKind::Account));
                    cfg.objects = CatalogSpec::parse(&format!("account:{n_obj}"))
                        .expect("homogeneous spec parses");
                    cfg.objects.zipf_theta = 0.6;
                    cfg.backend = backend;
                    cfg.placement = placement;
                    cfg.n_replicas = n;
                    cfg.update_pct = 25;
                    // Seed depends only on the workload axes, so the
                    // single/hash pair of a cell runs the same op stream.
                    cfg.seed = 0x5CA1_E000 + (bi as u64) * 0x1000 + (oi as u64) * 0x10 + n as u64;
                    jobs.push(((format!("account:{n_obj}"), backend, placement, n), (cfg, ops)));
                }
            }
            // One heterogeneous multi-tenant cell per backend.
            for &n in nodes {
                let mut cfg = SimConfig::safardb(WorkloadKind::Micro(RdtKind::Account));
                cfg.objects = CatalogSpec::mixed();
                cfg.objects.zipf_theta = 0.6;
                cfg.backend = backend;
                cfg.placement = placement;
                cfg.n_replicas = n;
                cfg.update_pct = 25;
                cfg.seed = 0x5CA1_F000 + (bi as u64) * 0x1000 + n as u64;
                jobs.push((("mixed".to_string(), backend, placement, n), (cfg, ops)));
            }
        }
    }
    for ((catalog, backend, placement, n), cell, rep) in run_cells_tagged(jobs) {
        let applied = &rep.metrics.obj_applied;
        let rejected = &rep.metrics.obj_rejected;
        let groups_led: Vec<String> = rep.groups_led.iter().map(|g| g.to_string()).collect();
        t.row(vec![
            catalog,
            applied.len().to_string(),
            backend.name().into(),
            placement.name().into(),
            n.to_string(),
            f3(cell.rt_us),
            f3(cell.tput),
            rep.metrics.smr_commits.to_string(),
            applied.iter().min().copied().unwrap_or(0).to_string(),
            applied.iter().max().copied().unwrap_or(0).to_string(),
            applied.iter().sum::<u64>().to_string(),
            rejected.iter().sum::<u64>().to_string(),
            groups_led.join("/"),
        ]);
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expt::common::run_cell;

    #[test]
    fn quick_sweep_scales_objects_with_telemetry() {
        crate::expt::common::set_threads(2);
        let t = &run(true)[0];
        let backends = match backend_filter() {
            Some(_) => 1,
            None => ConsensusBackend::ALL.len(),
        };
        let placements = match placement_filter() {
            Some(_) => 1,
            None => 1, // quick default: single only
        };
        // (|OBJECT_SWEEP_QUICK| homogeneous + 1 mixed) × 1 node count.
        assert_eq!(t.rows().len(), backends * placements * (OBJECT_SWEEP_QUICK.len() + 1));
        for row in t.rows() {
            let objects: usize = row[1].parse().unwrap();
            let applied_total: u64 = row[10].parse().unwrap();
            assert!(objects >= 1);
            assert!(applied_total > 0, "catalog saw traffic: {row:?}");
            if row[0] == "mixed" {
                assert_eq!(objects, CatalogSpec::mixed().n_objects());
            }
            let min: u64 = row[8].parse().unwrap();
            let max: u64 = row[9].parse().unwrap();
            assert!(min <= max);
            if objects > 1 {
                // Zipf-skewed selection: the hottest object leads.
                assert!(max > min, "skewed traffic across objects: {row:?}");
            }
            // groups_led is one slash-joined count per node and sums to
            // the catalog's group total under any placement.
            let led: Vec<u64> = row[12].split('/').map(|s| s.parse().unwrap()).collect();
            let nodes: usize = row[4].parse().unwrap();
            assert_eq!(led.len(), nodes, "one groups_led entry per node: {row:?}");
            assert!(led.iter().sum::<u64>() >= 1, "every group has a leader: {row:?}");
        }
    }

    /// Soft perf guard for the acceptance cell (`account:16`, n=5): hash
    /// placement must at least be in the same league as single. The
    /// ≥ 1.5× acceptance figure is recorded by the full sweep's CSV
    /// artifact, not asserted here (test-sized runs are noisier).
    #[test]
    fn hash_placement_holds_throughput_on_acceptance_cell() {
        for backend in [ConsensusBackend::Raft, ConsensusBackend::Paxos] {
            let mut cfg = SimConfig::safardb(WorkloadKind::Micro(RdtKind::Account));
            cfg.objects = CatalogSpec::parse("account:16").unwrap();
            cfg.objects.zipf_theta = 0.6;
            cfg.backend = backend;
            cfg.n_replicas = 5;
            cfg.update_pct = 25;
            cfg.seed = 0x5CA1_ACCE;
            let mut hash_cfg = cfg.clone();
            hash_cfg.placement = LeaderPlacement::Hash;
            let (single, _) = run_cell(cfg, 8_000);
            let (hash, _) = run_cell(hash_cfg, 8_000);
            assert!(
                hash.tput >= 0.8 * single.tput,
                "{}: hash placement lost throughput: hash={} single={}",
                backend.name(),
                hash.tput,
                single.tput
            );
        }
    }
}
