//! Fig 24 (appendix D.1): per-replica execution time for Bank Account,
//! 8 nodes, 15 % writes — the leader runs >2× longer than any follower,
//! which is why throughput is leader-bound.

use crate::config::{SimConfig, WorkloadKind};
use crate::expt::common::{cell_ops, run_cell};
use crate::rdt::RdtKind;
use crate::util::table::{fmt_ns, Table};

pub fn run(quick: bool) -> Vec<Table> {
    // Single cell: nothing to fan out, the sequential runner is the
    // simplest correct thing.
    let mut cfg = SimConfig::safardb(WorkloadKind::Micro(RdtKind::Account));
    cfg.n_replicas = 8;
    cfg.update_pct = 15;
    let (_, rep) = run_cell(cfg, cell_ops(quick));
    let leader = rep.leader;
    let mut t = Table::new(
        "Fig 24 — per-replica execution time, Account, 8 nodes, 15% writes",
        &["replica", "role", "exec_time"],
    );
    for (i, &busy) in rep.metrics.busy_ns.iter().enumerate() {
        let role = if i == leader { "LEADER" } else { "follower" };
        t.row(vec![i.to_string(), role.into(), fmt_ns(busy as f64)]);
    }
    let (l, f) = rep.metrics.leader_vs_followers(leader);
    t.row(vec!["-".into(), "leader/follower-mean".into(), format!("{:.2}x", l as f64 / f)]);
    vec![t]
}

#[cfg(test)]
mod tests {
    use crate::config::{SimConfig, WorkloadKind};
    use crate::expt::common::run_cell;
    use crate::rdt::RdtKind;

    #[test]
    fn leader_execution_dominates() {
        let mut cfg = SimConfig::safardb(WorkloadKind::Micro(RdtKind::Account));
        cfg.n_replicas = 8;
        cfg.update_pct = 15;
        let (_, rep) = run_cell(cfg, 24_000);
        let (l, f) = rep.metrics.leader_vs_followers(rep.leader);
        assert!(
            l as f64 > 2.0 * f,
            "leader {l} should be >2x follower mean {f} (paper Fig 24)"
        );
    }
}
