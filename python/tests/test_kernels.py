"""Layer-1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes and values; integer kernels must agree exactly,
float folds to tight tolerance. This is the core correctness signal for the
AOT artifacts the Rust coordinator executes.
"""

import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline runner: deterministic fallback sweeps
    from _hypothesis_stub import given, settings, st

from compile.kernels import (
    account_permissibility,
    batch_apply,
    lww_merge,
    pn_merge,
    set_or,
)
from compile.kernels import ref

SHAPE_NK = st.tuples(st.integers(1, 8), st.integers(1, 64))
FINITE = st.floats(-1e4, 1e4, allow_nan=False, width=32)


def _arr(rng, shape, lo, hi, dtype):
    if np.issubdtype(dtype, np.integer):
        return jnp.asarray(rng.integers(lo, hi, size=shape, dtype=np.int64).astype(dtype))
    return jnp.asarray(rng.uniform(lo, hi, size=shape).astype(dtype))


@settings(max_examples=40, deadline=None)
@given(nk=SHAPE_NK, seed=st.integers(0, 2**32 - 1))
def test_pn_merge_matches_ref(nk, seed):
    rng = np.random.default_rng(seed)
    p = _arr(rng, nk, 0, 1e4, np.float32)
    m = _arr(rng, nk, 0, 1e4, np.float32)
    got = pn_merge(p, m)
    want = ref.pn_merge_ref(p, m)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-3)


@settings(max_examples=40, deadline=None)
@given(nk=SHAPE_NK, seed=st.integers(0, 2**32 - 1))
def test_lww_merge_matches_ref(nk, seed):
    rng = np.random.default_rng(seed)
    vals = _arr(rng, nk, -1e4, 1e4, np.float32)
    ts = _arr(rng, nk, 0, 1 << 30, np.int32)
    gv, gt = lww_merge(vals, ts)
    wv, wt = ref.lww_merge_ref(vals, ts)
    np.testing.assert_array_equal(gv, wv)
    np.testing.assert_array_equal(gt, wt)


def test_lww_merge_tie_keeps_lowest_replica():
    vals = jnp.array([[1.0], [2.0], [3.0]], jnp.float32)
    ts = jnp.array([[7], [7], [3]], jnp.int32)
    gv, gt = lww_merge(vals, ts)
    assert gv[0] == 1.0 and gt[0] == 7


@settings(max_examples=40, deadline=None)
@given(nw=st.tuples(st.integers(2, 8), st.integers(1, 64)), seed=st.integers(0, 2**32 - 1))
def test_set_or_matches_ref(nw, seed):
    rng = np.random.default_rng(seed)
    bm = _arr(rng, nw, 0, 1 << 31, np.int32)
    np.testing.assert_array_equal(set_or(bm), ref.set_or_ref(bm))


@settings(max_examples=40, deadline=None)
@given(b=st.integers(1, 128), b0=st.floats(0, 1e4, width=32), seed=st.integers(0, 2**32 - 1))
def test_account_permissibility_matches_ref(b, b0, seed):
    rng = np.random.default_rng(seed)
    b0 = jnp.array([b0], jnp.float32)
    deltas = _arr(rng, (b,), -200, 200, np.float32)
    ga, gb = account_permissibility(b0, deltas)
    wa, wb = ref.account_permissibility_ref(b0, deltas)
    np.testing.assert_array_equal(ga, wa)
    np.testing.assert_allclose(gb, wb, rtol=1e-6, atol=1e-4)


@settings(max_examples=40, deadline=None)
@given(b=st.integers(1, 128), b0=st.floats(0, 1e3, width=32), seed=st.integers(0, 2**32 - 1))
def test_account_balance_never_negative(b, b0, seed):
    """The integrity invariant itself (Table B.1): accepted prefix never
    overdrafts, regardless of input batch."""
    rng = np.random.default_rng(seed)
    deltas = _arr(rng, (b,), -500, 100, np.float32)
    accept, _ = account_permissibility(jnp.array([b0], jnp.float32), deltas)
    bal = float(b0)
    for i in range(b):
        if int(accept[i]):
            bal += float(deltas[i])
        assert bal >= -1e-3, f"overdraft at op {i}: {bal}"


@settings(max_examples=40, deadline=None)
@given(
    k=st.integers(1, 256),
    b=st.integers(1, 128),
    seed=st.integers(0, 2**32 - 1),
)
def test_batch_apply_matches_ref(k, b, seed):
    rng = np.random.default_rng(seed)
    state = _arr(rng, (k,), -1e3, 1e3, np.float32)
    keys = _arr(rng, (b,), 0, k, np.int32)
    deltas = _arr(rng, (b,), -100, 100, np.float32)
    got = batch_apply(state, keys, deltas)
    want = ref.batch_apply_ref(state, keys, deltas)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)


def test_batch_apply_duplicate_keys_accumulate():
    state = jnp.zeros(4, jnp.float32)
    keys = jnp.array([2, 2, 2], jnp.int32)
    deltas = jnp.array([1.0, 2.0, 3.0], jnp.float32)
    out = batch_apply(state, keys, deltas)
    np.testing.assert_allclose(out, jnp.array([0, 0, 6.0, 0]))


def test_pn_merge_empty_contributions():
    p = jnp.zeros((8, 16), jnp.float32)
    out = pn_merge(p, p)
    np.testing.assert_array_equal(out, jnp.zeros(16))


def test_kernel_shape_validation():
    import pytest

    with pytest.raises(ValueError):
        pn_merge(jnp.zeros((2, 3)), jnp.zeros((3, 2)))
    with pytest.raises(ValueError):
        batch_apply(jnp.zeros(4), jnp.zeros(2, jnp.int32), jnp.zeros(3))
    with pytest.raises(ValueError):
        account_permissibility(jnp.zeros(2), jnp.zeros(4))
    with pytest.raises(ValueError):
        set_or(jnp.zeros((2, 2, 2), jnp.int32))
    with pytest.raises(ValueError):
        lww_merge(jnp.zeros((2, 3)), jnp.zeros((2, 4), jnp.int32))
