//! RDMA verbs — the standard one-sided Read/Write pair plus SafarDB's
//! FPGA-specific verbs (§2.2, appendix C.6, Table C.1):
//!
//! * `Write`         — one-sided write to a memory kind (HBM / host DRAM).
//! * `Read`          — one-sided read; the NIC answers without CPU help.
//! * `Rpc`           — payload is (opcode, params); the Dispatcher invokes
//!                     an FPGA-resident accelerator directly (Fig 1),
//!                     landing in integrated storage (BRAM/registers).
//! * `RpcWriteThrough` — §4.3's verb: invokes the accelerator *and*
//!                     concurrently appends the replication log in HBM.

use crate::mem::MemKind;
use crate::rdt::OpCall;
use crate::sim::NodeId;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum VerbKind {
    Write,
    Read,
    Rpc,
    RpcWriteThrough,
}

/// What a Read verb targets in the remote node's memory.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReadTarget {
    /// Heartbeat counter of the remote replica (leader-switch plane).
    Heartbeat,
    /// Highest proposal number of a sync group (Mu Prepare).
    MinProposal { group: u8 },
    /// One replication-log slot of a sync group (Mu Prepare slot check).
    LogSlot { group: u8, slot: u64 },
    /// A raw memory region (micro-benchmarks, Table 2.1).
    Raw { bytes: u64 },
}

/// Data returned by a Read.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ReadData {
    Heartbeat(u64),
    MinProposal(u64),
    /// (proposal, op) if the slot is non-empty.
    LogSlot(Option<(u64, OpCall)>),
    Raw,
}

/// Verb payloads — real protocol state travels here, not just costs.
#[derive(Clone, Debug)]
pub enum Payload {
    /// Raw bytes (micro-benchmarks / Table 2.1 traffic).
    Raw { bytes: u64 },
    /// Reducible summary: replica `origin`'s aggregated contribution
    /// written into slot A[origin] (§4.1). `ops` carries the summarized
    /// count for metrics; `value` rows carry the actual contribution.
    Summary { origin: NodeId, ops: u32, value: OpCall },
    /// Irreducible op appended to the per-origin FIFO queue (§4.2).
    QueueAppend { op: OpCall },
    /// Mu: write the next proposal number at a follower (Prepare).
    Propose { group: u8, proposal: u64 },
    /// Mu: append a committed entry to the replication log (Accept).
    LogAppend { group: u8, slot: u64, proposal: u64, op: OpCall },
    /// Forward a conflicting op from a non-leader replica to the leader.
    LeaderForward { op: OpCall, reply_to: NodeId, request_id: u64 },
    /// Leader's response to a forwarded conflicting op. `handled` false
    /// means "not the leader, retry elsewhere"; `committed` false with
    /// `handled` true means ordered but rejected by permissibility.
    LeaderReply { request_id: u64, handled: bool, committed: bool },
    /// One-sided read request.
    ReadReq { target: ReadTarget },
    /// Read response delivered back to the initiator.
    ReadResp { target: ReadTarget, data: ReadData },
    /// Raft (Waverunner baseline): AppendEntries carrying one op.
    RaftAppend { term: u64, index: u64, op: OpCall },
    /// Raft follower ack.
    RaftAck { term: u64, index: u64, from: NodeId },
    /// Client redirect (Waverunner: follower rejects, client re-sends).
    ClientRedirect { request_id: u64 },
}

/// Which engine plane consumes a payload on arrival — the replica
/// coordinator's routing table, kept next to the payload definitions so a
/// new payload cannot be added without declaring its owner.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PayloadPlane {
    /// Relaxed path: landing zones + summarizer (§4.1–§4.2).
    Relaxed,
    /// Strongly-ordered path: Mu/Raft, forwards, replies (§4.3–§4.4).
    Strong,
    /// One-sided read the NIC answers from plane-owned memory.
    OneSidedRead,
    /// Read response, routed by its completion token's owner.
    Completion,
    /// No consumer (raw micro-benchmark traffic, client redirects).
    None,
}

impl Payload {
    /// Routing: which plane handles this payload at the destination.
    pub fn plane(&self) -> PayloadPlane {
        match self {
            Payload::Summary { .. } | Payload::QueueAppend { .. } => PayloadPlane::Relaxed,
            Payload::Propose { .. }
            | Payload::LogAppend { .. }
            | Payload::LeaderForward { .. }
            | Payload::LeaderReply { .. }
            | Payload::RaftAppend { .. }
            | Payload::RaftAck { .. } => PayloadPlane::Strong,
            Payload::ReadReq { .. } => PayloadPlane::OneSidedRead,
            Payload::ReadResp { .. } => PayloadPlane::Completion,
            Payload::Raw { .. } | Payload::ClientRedirect { .. } => PayloadPlane::None,
        }
    }

    /// Heartbeat-plane traffic rides its own QP / virtual lane (§4.4: the
    /// Heartbeat Scanner is independent fabric logic), so it is never
    /// queued behind bulk replication on the in-order data channel.
    pub fn is_heartbeat(&self) -> bool {
        matches!(
            self,
            Payload::ReadReq { target: ReadTarget::Heartbeat }
                | Payload::ReadResp { target: ReadTarget::Heartbeat, .. }
        )
    }

    /// Wire size for serialization-delay modeling.
    pub fn wire_bytes(&self) -> u64 {
        match self {
            Payload::Raw { bytes } => *bytes,
            Payload::Summary { value, .. } => value.wire_bytes() + 8,
            Payload::QueueAppend { op } => op.wire_bytes(),
            Payload::Propose { .. } => 16,
            Payload::LogAppend { op, .. } => op.wire_bytes() + 24,
            Payload::LeaderForward { op, .. } => op.wire_bytes() + 16,
            Payload::LeaderReply { .. } => 16,
            Payload::ReadReq { .. } => 16,
            Payload::ReadResp { .. } => 48,
            Payload::RaftAppend { op, .. } => op.wire_bytes() + 24,
            Payload::RaftAck { .. } => 24,
            Payload::ClientRedirect { .. } => 16,
        }
    }
}

/// A verb in flight.
#[derive(Clone, Debug)]
pub struct Verb {
    pub kind: VerbKind,
    /// Where the payload lands at the destination (write verbs).
    pub dst_mem: MemKind,
    pub payload: Payload,
    /// Initiator completion token: the ACK/NACK event carries it back.
    pub token: u64,
    /// True for writes that travel on the follower's *leader-write QP* —
    /// the one the Permission Switch fences (§4.4). Relaxed-path RDT
    /// traffic uses per-peer QPs that stay open.
    pub leader_qp: bool,
}

impl Verb {
    pub fn write(dst_mem: MemKind, payload: Payload, token: u64) -> Self {
        Verb { kind: VerbKind::Write, dst_mem, payload, token, leader_qp: false }
    }

    pub fn read(target: ReadTarget, token: u64) -> Self {
        Verb {
            kind: VerbKind::Read,
            dst_mem: MemKind::Hbm,
            payload: Payload::ReadReq { target },
            token,
            leader_qp: false,
        }
    }

    pub fn rpc(payload: Payload, token: u64) -> Self {
        Verb { kind: VerbKind::Rpc, dst_mem: MemKind::Bram, payload, token, leader_qp: false }
    }

    pub fn rpc_write_through(payload: Payload, token: u64) -> Self {
        Verb {
            kind: VerbKind::RpcWriteThrough,
            dst_mem: MemKind::Bram,
            payload,
            token,
            leader_qp: true, // write-through is the SMR Accept path
        }
    }

    /// Mark this verb as leader-write-QP traffic (Mu Propose/Accept).
    pub fn on_leader_qp(mut self) -> Self {
        self.leader_qp = true;
        self
    }

    pub fn wire_bytes(&self) -> u64 {
        // RoCEv2 headers (Eth+IP+UDP+IB BTH ≈ 58B) + payload.
        58 + self.payload.wire_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn verb_constructors_set_kind_and_mem() {
        let w = Verb::write(MemKind::Hbm, Payload::Raw { bytes: 64 }, 1);
        assert_eq!(w.kind, VerbKind::Write);
        assert_eq!(w.dst_mem, MemKind::Hbm);

        let r = Verb::read(ReadTarget::Heartbeat, 2);
        assert!(matches!(r.payload, Payload::ReadReq { target: ReadTarget::Heartbeat }));

        let rpc = Verb::rpc(Payload::QueueAppend { op: OpCall::new(0, 1, 0, 0.0) }, 3);
        assert_eq!(rpc.dst_mem, MemKind::Bram, "RPC lands in integrated storage");

        let wt = Verb::rpc_write_through(
            Payload::LogAppend { group: 0, slot: 0, proposal: 1, op: OpCall::new(0, 0, 0, 0.0) },
            4,
        );
        assert_eq!(wt.kind, VerbKind::RpcWriteThrough);
    }

    #[test]
    fn wire_bytes_include_headers() {
        let w = Verb::write(MemKind::Hbm, Payload::Raw { bytes: 100 }, 0);
        assert_eq!(w.wire_bytes(), 158);
    }

    #[test]
    fn payload_plane_routing_is_total() {
        let op = OpCall::new(0, 1, 2, 0.5);
        let cases: Vec<(Payload, PayloadPlane)> = vec![
            (Payload::Summary { origin: 0, ops: 1, value: op }, PayloadPlane::Relaxed),
            (Payload::QueueAppend { op }, PayloadPlane::Relaxed),
            (Payload::Propose { group: 0, proposal: 1 }, PayloadPlane::Strong),
            (Payload::LogAppend { group: 0, slot: 0, proposal: 1, op }, PayloadPlane::Strong),
            (Payload::LeaderForward { op, reply_to: 1, request_id: 2 }, PayloadPlane::Strong),
            (Payload::LeaderReply { request_id: 2, handled: true, committed: true }, PayloadPlane::Strong),
            (Payload::RaftAppend { term: 1, index: 0, op }, PayloadPlane::Strong),
            (Payload::RaftAck { term: 1, index: 0, from: 1 }, PayloadPlane::Strong),
            (Payload::ReadReq { target: ReadTarget::Heartbeat }, PayloadPlane::OneSidedRead),
            (
                Payload::ReadResp { target: ReadTarget::Heartbeat, data: ReadData::Heartbeat(1) },
                PayloadPlane::Completion,
            ),
            (Payload::Raw { bytes: 8 }, PayloadPlane::None),
            (Payload::ClientRedirect { request_id: 3 }, PayloadPlane::None),
        ];
        for (p, want) in cases {
            assert_eq!(p.plane(), want, "{p:?}");
        }
    }
}
