"""LWW-Register merge kernel.

Last-writer-wins fold over per-replica (value, timestamp) pairs. The paper
assumes unique timestamps (Table A.1), which makes the fold order-free; on
ties we deterministically keep the lowest replica index (argmax-first), and
ref.py / the Rust scalar path implement the identical rule.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(vals_ref, ts_ref, out_val_ref, out_ts_ref):
    vals = vals_ref[...]
    ts = ts_ref[...]
    best = jnp.argmax(ts, axis=0)  # first max => lowest replica id on ties
    out_val_ref[...] = jnp.take_along_axis(vals, best[None, :], axis=0)[0]
    out_ts_ref[...] = jnp.take_along_axis(ts, best[None, :], axis=0)[0]


def lww_merge(vals, ts):
    """Fold per-replica LWW-Register states.

    Args:
      vals: f32[N, K] last-written values per replica.
      ts:   i32[N, K] timestamps per replica.
    Returns:
      (f32[K] merged values, i32[K] merged timestamps).
    """
    if vals.shape != ts.shape or vals.ndim != 2:
        raise ValueError(f"lww_merge expects matching [N,K] arrays, got {vals.shape} {ts.shape}")
    n, k = vals.shape
    return pl.pallas_call(
        _kernel,
        out_shape=(
            jax.ShapeDtypeStruct((k,), vals.dtype),
            jax.ShapeDtypeStruct((k,), ts.dtype),
        ),
        interpret=True,
    )(vals, ts)
