//! Plain-text table rendering for the experiment harness — each paper
//! table/figure prints as an aligned grid the way the paper reports it.

#[derive(Clone, Debug)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn rows(&self) -> &[Vec<String>] {
        &self.rows
    }

    pub fn headers(&self) -> &[String] {
        &self.headers
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let line = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{:>width$}", c, width = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&line(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &widths));
            out.push('\n');
        }
        out
    }

    /// CSV form for EXPERIMENTS.md / plotting.
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.headers.join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }
}

/// Format nanoseconds with an adaptive unit, the way the paper mixes ns/µs.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0}ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2}us", ns / 1_000.0)
    } else {
        format!("{:.3}ms", ns / 1_000_000.0)
    }
}

/// Format ops/µs (the paper's throughput unit).
pub fn fmt_tput(ops_per_us: f64) -> String {
    format!("{ops_per_us:.3}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns_columns() {
        let mut t = Table::new("demo", &["a", "long_header"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["100".into(), "xyz".into()]);
        let r = t.render();
        assert!(r.contains("demo"));
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 5);
        assert_eq!(lines[1].len(), lines[3].len());
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn row_arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        assert_eq!(t.to_csv(), "a,b\n1,2\n");
    }

    #[test]
    fn fmt_units() {
        assert_eq!(fmt_ns(17.0), "17ns");
        assert_eq!(fmt_ns(2_000.0), "2.00us");
        assert_eq!(fmt_ns(3_500_000.0), "3.500ms");
    }
}
