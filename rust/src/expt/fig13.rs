//! Fig 13: permission-switch round-trip histograms — SafarDB's FPGA QP
//! pokes (bimodal 17/24 ns) vs Hamband's traditional RNIC permission
//! change (lognormal, hundreds of µs, heavy tail). Design Principle #3.

use crate::net::fabric::FabricParams;
use crate::util::rng::Rng;
use crate::util::stats::Histogram;
use crate::util::table::Table;

pub fn sample(model: &crate::net::fabric::PermSwitchModel, iters: u64, seed: u64) -> Histogram {
    let mut rng = Rng::new(seed);
    let mut h = Histogram::new();
    for _ in 0..iters {
        h.record(model.sample(&mut rng));
    }
    h
}

pub fn run(quick: bool) -> Vec<Table> {
    let iters = if quick { 10_000 } else { 100_000 };
    let fpga = sample(&FabricParams::fpga().perm_switch, iters, 13);
    let trad = sample(&FabricParams::traditional().perm_switch, iters, 14);

    let mut summary = Table::new(
        "Fig 13 — permission switch latency",
        &["fabric", "p50_ns", "p99_ns", "min_ns", "max_ns"],
    );
    for (name, h) in [("SafarDB (FPGA QP regs)", &fpga), ("Hamband (RNIC verbs)", &trad)] {
        summary.row(vec![
            name.into(),
            h.p50().to_string(),
            h.p99().to_string(),
            h.min().to_string(),
            h.max().to_string(),
        ]);
    }

    let mut hist = Table::new(
        "Fig 13 — histogram series (bucket_ns, count)",
        &["fabric", "bucket_ns", "count"],
    );
    for (name, h) in [("SafarDB", &fpga), ("Hamband", &trad)] {
        for (b, c) in h.nonzero_buckets() {
            hist.row(vec![name.into(), b.to_string(), c.to_string()]);
        }
    }
    vec![summary, hist]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fpga_bimodal_traditional_heavy_tailed() {
        let tabs = run(true);
        let s = &tabs[0];
        let fpga_p50: u64 = s.rows()[0][1].parse().unwrap();
        let fpga_max: u64 = s.rows()[0][4].parse().unwrap();
        let trad_p50: u64 = s.rows()[1][1].parse().unwrap();
        let trad_p99: u64 = s.rows()[1][2].parse().unwrap();
        assert!(fpga_p50 == 17 || fpga_p50 == 24);
        assert!(fpga_max <= 24);
        assert!(trad_p50 > 100_000, "hundreds of us: {trad_p50}");
        assert!(trad_p99 > trad_p50, "variability");
        // Orders of magnitude apart.
        assert!(trad_p50 / fpga_p50 > 1_000);
        // The FPGA histogram has exactly two buckets (17 and 24).
        let h = &tabs[1];
        let fpga_buckets: Vec<&Vec<String>> =
            h.rows().iter().filter(|r| r[0] == "SafarDB").collect();
        assert_eq!(fpga_buckets.len(), 2);
    }
}
