//! Leader-switch / failure plane (§3, §4.4): the heartbeat tracker and
//! scanner, crash/recover handling, smallest-live-ID election, and the
//! permission switch. Owns the membership view every replication path
//! consults (via the [`Membership`] trait) and reports failures,
//! recoveries, and leadership changes into the paths as
//! [`MembershipEvent`]s.

use crate::config::SystemKind;
use crate::engine::path::{Membership, MembershipEvent, ReplicaCore, ReplicationPath, TokenCtx};
use crate::engine::Ctx;
use crate::net::verbs::{ReadTarget, Verb};
use crate::sim::{EventKind, NodeId, TimerKind};
use crate::smr::election::{HbVerdict, HeartbeatTracker};

pub struct FailurePlane {
    tracker: HeartbeatTracker,
    /// RDMA-exposed heartbeat counter peers read one-sidedly.
    pub hb_counter: u64,
}

impl FailurePlane {
    pub fn new(id: NodeId, n: usize, hb_fail_threshold: u32) -> Self {
        FailurePlane { tracker: HeartbeatTracker::new(id, n, hb_fail_threshold), hb_counter: 0 }
    }

    pub fn boot(&mut self, core: &ReplicaCore, ctx: &mut Ctx, base: u64) {
        // Heartbeat scanning runs for every object class: WRDTs need it for
        // leader election; CRDTs need it for membership (a crashed peer
        // must leave the relaxed-path fan-out set — Fig 14 e/f).
        ctx.q.push(base + core.heartbeat_period_ns, core.id, EventKind::Timer(TimerKind::HeartbeatScan));
    }

    pub fn on_crash(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx) {
        core.crashed = true;
        ctx.net.set_crashed(core.id, true);
        // In-flight client slots die with the replica; their quota was
        // consumed and is redistributed by the cluster.
        core.clients_in_flight = 0;
    }

    pub fn on_recover(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx) {
        core.crashed = false;
        ctx.net.set_crashed(core.id, false);
        core.busy_until = ctx.q.now();
        // Heartbeat resumes; peers will observe Recovered.
        ctx.q.push(ctx.q.now() + core.heartbeat_period_ns, core.id, EventKind::Timer(TimerKind::HeartbeatScan));
    }

    /// Heartbeat scanner tick: bump our own counter, read every peer's.
    pub fn on_scan(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx) {
        self.hb_counter += 1;
        // Hamband's scanner is a software thread competing with the
        // app (§5.3 "In Hamband, this update occurs in the
        // foreground"); SafarDB's is fabric logic.
        if core.system == SystemKind::Hamband {
            core.occupy(ctx.q.now(), core.exec().software_overhead_ns);
        }
        for i in 0..core.peers.len() {
            let peer = core.peers[i];
            let tok = core.token(TokenCtx::Heartbeat { peer });
            let verb = Verb::read(ReadTarget::Heartbeat, tok);
            ctx.metrics.verbs += 1;
            ctx.net.issue(ctx.q, ctx.qps, &core.sys.fabric, ctx.q.now(), core.id, peer, verb, true);
        }
        if !ctx.draining {
            ctx.q.push(ctx.q.now() + core.heartbeat_period_ns, core.id, EventKind::Timer(TimerKind::HeartbeatScan));
        }
    }

    /// One heartbeat observation of `peer` (`None` = read never completed).
    pub fn on_heartbeat(
        &mut self,
        core: &mut ReplicaCore,
        strong: &mut dyn ReplicationPath,
        ctx: &mut Ctx,
        peer: NodeId,
        value: Option<u64>,
    ) {
        let verdict = match value {
            Some(v) => self.tracker.observe(peer, v),
            None => self.tracker.observe_timeout(peer),
        };
        match verdict {
            HbVerdict::JustFailed => {
                if std::env::var_os("SAFARDB_DEBUG").is_some() {
                    eprintln!("[{}ns] r{}: declared r{} FAILED", ctx.q.now(), core.id, peer);
                }
                // Fault-timeline telemetry: the chaos harness derives each
                // incident's detection latency from these observations.
                ctx.metrics.detections.push((ctx.q.now(), peer, core.id));
                if peer == core.leader {
                    self.leader_switch(core, strong, ctx);
                } else if core.is_leader() {
                    strong.on_membership(core, ctx, &*self, MembershipEvent::PeerFailed { peer });
                }
            }
            HbVerdict::Recovered => {
                ctx.metrics.recoveries.push((ctx.q.now(), peer, core.id));
                if core.is_leader() {
                    strong.on_membership(core, ctx, &*self, MembershipEvent::PeerRecovered { peer });
                }
            }
            _ => {}
        }
    }

    /// The leader failed: elect, fence the old leader's QP, open the new
    /// one (Permission Switch, Fig 13), and hand the paths the new view.
    fn leader_switch(&mut self, core: &mut ReplicaCore, strong: &mut dyn ReplicationPath, ctx: &mut Ctx) {
        let old = core.leader;
        let new = self.tracker.elect_leader();
        if new == old {
            return;
        }
        if std::env::var_os("SAFARDB_DEBUG").is_some() {
            eprintln!(
                "[{}ns] r{}: leader switch {} -> {} (live {:?})",
                ctx.q.now(),
                core.id,
                old,
                new,
                self.tracker.live_set()
            );
        }
        // Permission switch: close the old leader's QP, open the new one.
        // FPGA: direct QP-register pokes, ns-scale; RNIC: driver + PCIe.
        let lat = core.sys.fabric.perm_switch.sample(&mut core.rng);
        ctx.metrics.perm_switch.record(lat);
        ctx.qps.switch_leader(core.id, old, new);
        core.occupy(ctx.q.now(), lat);
        core.leader = new;
        strong.on_membership(core, ctx, &*self, MembershipEvent::LeaderSwitched);
        if new != core.id {
            // Ask the new leader for a log replay: its own takeover
            // broadcast may have been fenced here if our permission switch
            // ran after it (the broadcast covers the reverse ordering).
            core.request_sync(ctx, new);
        }
    }

}

impl Membership for FailurePlane {
    fn live_set(&self) -> Vec<NodeId> {
        self.tracker.live_set()
    }

    fn live_peers(&self, me: NodeId) -> Vec<NodeId> {
        self.tracker.live_set().into_iter().filter(|&i| i != me).collect()
    }

    fn elect_leader(&self) -> NodeId {
        self.tracker.elect_leader()
    }
}
