"""Grow-only set bitmap merge kernel.

G-Sets (and each phase of a 2P-Set) merge by union; with a fixed element
universe the union is a bitwise OR over per-replica bitmaps. N is small and
static (cluster size), so the fold is a fully unrolled OR tree — the direct
analogue of the FPGA's OR reduction fabric.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _make_kernel(n):
    def kernel(bm_ref, out_ref):
        bm = bm_ref[...]
        acc = bm[0]
        for i in range(1, n):  # static unroll: N is the cluster size
            acc = acc | bm[i]
        out_ref[...] = acc

    return kernel


def set_or(bitmaps):
    """OR-fold per-replica set bitmaps.

    Args:
      bitmaps: i32[N, W] bitmap words per replica.
    Returns:
      i32[W] merged bitmap.
    """
    if bitmaps.ndim != 2:
        raise ValueError(f"set_or expects [N,W], got {bitmaps.shape}")
    n, w = bitmaps.shape
    return pl.pallas_call(
        _make_kernel(n),
        out_shape=jax.ShapeDtypeStruct((w,), bitmaps.dtype),
        interpret=True,
    )(bitmaps)
