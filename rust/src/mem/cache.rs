//! An exact LRU cache over keys, used to model the host CPU's last-level
//! cache for the hybrid-mode experiments (Fig 16: "with higher skew, the
//! hot host keys are reused more and stay in the CPU caches").
//!
//! O(1) access via HashMap + intrusive doubly-linked list over a slab.

use std::collections::HashMap;

const NIL: usize = usize::MAX;

#[derive(Debug)]
struct Node {
    key: u64,
    prev: usize,
    next: usize,
}

#[derive(Debug)]
pub struct LruCache {
    capacity: usize,
    map: HashMap<u64, usize>,
    slab: Vec<Node>,
    head: usize, // most recent
    tail: usize, // least recent
    hits: u64,
    misses: u64,
}

impl LruCache {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "LruCache capacity must be positive");
        LruCache {
            capacity,
            map: HashMap::with_capacity(capacity + 1),
            slab: Vec::with_capacity(capacity),
            head: NIL,
            tail: NIL,
            hits: 0,
            misses: 0,
        }
    }

    /// Touch `key`: returns true on hit. On miss the key is inserted,
    /// evicting the least-recently-used entry if full.
    pub fn access(&mut self, key: u64) -> bool {
        if let Some(&idx) = self.map.get(&key) {
            self.unlink(idx);
            self.push_front(idx);
            self.hits += 1;
            return true;
        }
        self.misses += 1;
        if self.slab.len() < self.capacity {
            let idx = self.slab.len();
            self.slab.push(Node { key, prev: NIL, next: NIL });
            self.map.insert(key, idx);
            self.push_front(idx);
        } else {
            // Evict LRU in place.
            let idx = self.tail;
            debug_assert_ne!(idx, NIL);
            self.unlink(idx);
            let old_key = self.slab[idx].key;
            self.map.remove(&old_key);
            self.slab[idx].key = key;
            self.map.insert(key, idx);
            self.push_front(idx);
        }
        false
    }

    fn unlink(&mut self, idx: usize) {
        let (prev, next) = (self.slab[idx].prev, self.slab[idx].next);
        if prev != NIL {
            self.slab[prev].next = next;
        } else {
            self.head = next;
        }
        if next != NIL {
            self.slab[next].prev = prev;
        } else {
            self.tail = prev;
        }
        self.slab[idx].prev = NIL;
        self.slab[idx].next = NIL;
    }

    fn push_front(&mut self, idx: usize) {
        self.slab[idx].prev = NIL;
        self.slab[idx].next = self.head;
        if self.head != NIL {
            self.slab[self.head].prev = idx;
        }
        self.head = idx;
        if self.tail == NIL {
            self.tail = idx;
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    pub fn counters(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hits_after_insert() {
        let mut c = LruCache::new(2);
        assert!(!c.access(1));
        assert!(c.access(1));
        assert_eq!(c.counters(), (1, 1));
    }

    #[test]
    fn evicts_least_recently_used() {
        let mut c = LruCache::new(2);
        c.access(1);
        c.access(2);
        c.access(1); // 1 now MRU
        c.access(3); // evicts 2 -> cache {1, 3}
        assert!(c.access(3), "3 still resident");
        assert!(c.access(1), "1 still resident");
        assert!(!c.access(2), "2 was evicted");
    }

    #[test]
    fn capacity_bounded() {
        let mut c = LruCache::new(10);
        for k in 0..1000 {
            c.access(k);
        }
        assert_eq!(c.len(), 10);
    }

    #[test]
    fn skewed_stream_has_high_hit_rate() {
        // The Fig 16 mechanism in miniature: zipf-ish reuse of a hot head.
        let mut c = LruCache::new(100);
        let mut rng = crate::util::rng::Rng::new(9);
        let z = crate::util::rng::Zipf::new(10_000, 1.2);
        for _ in 0..50_000 {
            c.access(z.sample(&mut rng));
        }
        assert!(c.hit_rate() > 0.5, "hit_rate={}", c.hit_rate());

        let mut u = LruCache::new(100);
        for _ in 0..50_000 {
            u.access(rng.gen_range(10_000));
        }
        assert!(u.hit_rate() < 0.05, "uniform hit_rate={}", u.hit_rate());
    }

    #[test]
    fn single_entry_cache() {
        let mut c = LruCache::new(1);
        assert!(!c.access(5));
        assert!(c.access(5));
        assert!(!c.access(6));
        assert!(!c.access(5));
    }
}
