//! Configuration system: which system (SafarDB / Hamband / Waverunner),
//! cluster shape, workload, propagation modes, faults, hybrid-mode layout —
//! plus per-system parameter presets bundling fabric, memory, execution,
//! and power models.
//!
//! Configs are built programmatically (`SimConfig::safardb(...)`) or parsed
//! from simple `key = value` files (`parse`), since no TOML crate exists in
//! the offline set.

pub mod params;

pub use params::{ConsensusBackend, ExecParams, PowerParams, SystemParams};

use crate::rdt::{Category, RdtKind};

/// Which system a run models.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SystemKind {
    /// The paper's system: network-attached FPGA, soft RNIC, FPGA-resident
    /// RDT engine, Mu SMR.
    SafarDb,
    /// Baseline (1): CPU-hosted RDTs over traditional RDMA [41].
    Hamband,
    /// Baseline (2): FPGA SmartNIC Raft, leader-only client handling [5].
    Waverunner,
}

impl SystemKind {
    pub fn name(&self) -> &'static str {
        match self {
            SystemKind::SafarDb => "SafarDB",
            SystemKind::Hamband => "Hamband",
            SystemKind::Waverunner => "Waverunner",
        }
    }

    pub fn params(&self) -> SystemParams {
        match self {
            SystemKind::SafarDb => SystemParams::safardb(),
            SystemKind::Hamband => SystemParams::hamband(),
            SystemKind::Waverunner => SystemParams::waverunner(),
        }
    }

    /// Parameters for a run, honoring an ablation override.
    pub fn params_for(&self, cfg: &SimConfig) -> SystemParams {
        cfg.params_override.unwrap_or_else(|| self.params())
    }
}

/// Which replication path (paper plane, §4) serves a transaction
/// category. The engine holds one trait object per kind
/// (`engine::path::ReplicationPath`) and routes by [`SimConfig::path_for`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReplicationPathKind {
    /// Relaxed plane: landing zones + summarizer (§4.1–§4.2).
    Relaxed,
    /// Strongly-ordered plane: Mu SMR, or Raft for Waverunner (§4.3–§4.4).
    Strong,
}

/// How a transaction category is propagated to remote replicas
/// (the Figs 6–8 sweeps; §4.1–4.3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PropagationMode {
    /// RDMA Write into HBM, reader folds on access (§4.1/4.2/4.3 config 1,
    /// "no buffer").
    WriteNoBuffer,
    /// RDMA Write into HBM + background poller refreshing an on-fabric
    /// copy (§4.1 config 2).
    WriteBuffered,
    /// FPGA-specific RDMA RPC verb: remote accelerator state updated
    /// directly from the network (§4.1/4.2 config RPC).
    Rpc,
    /// RDMA RPC Write-Through: accelerator update + simultaneous
    /// replication-log append (§4.3 config 2, conflicting only).
    WriteThrough,
}

/// Strong-plane leadership placement: how the `Catalog::total_groups()`
/// global sync groups are assigned leaders across the cluster.
///
/// `Single` (default) keeps today's behavior — one node leads every group
/// — and is bit-identical to the pre-sharding engine on fixed seeds. The
/// other policies shard leadership so N nodes each lead ~1/N of the
/// groups (the production multi-Raft pattern), which is what lets
/// strong-path throughput scale with nodes instead of saturating one
/// leader. All policies are deterministic functions of the group index,
/// the cluster size, and the observed crash sequence, so every replica
/// evolves the same placement table without coordination.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum LeaderPlacement {
    /// One cluster-wide leader for every group (the classic layout).
    #[default]
    Single,
    /// Rendezvous (highest-random-weight) hash of (group, node): stable
    /// under membership change — a crash only moves the dead node's
    /// groups.
    Hash,
    /// `group % n`: perfectly even, but a membership change re-ranks the
    /// live set.
    RoundRobin,
    /// Greedy least-loaded assignment (ties to the smallest node id);
    /// crash-time reassignment picks the currently least-loaded live
    /// node per orphaned group. Sticky: a recovering ex-leader rejoins
    /// as a follower of its former groups until a later reassignment
    /// places load on it again.
    LoadAware,
}

impl LeaderPlacement {
    pub const ALL: [LeaderPlacement; 4] = [
        LeaderPlacement::Single,
        LeaderPlacement::Hash,
        LeaderPlacement::RoundRobin,
        LeaderPlacement::LoadAware,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            LeaderPlacement::Single => "single",
            LeaderPlacement::Hash => "hash",
            LeaderPlacement::RoundRobin => "round_robin",
            LeaderPlacement::LoadAware => "load_aware",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "single" => Some(LeaderPlacement::Single),
            "hash" => Some(LeaderPlacement::Hash),
            "round_robin" | "round-robin" | "rr" => Some(LeaderPlacement::RoundRobin),
            "load_aware" | "load-aware" => Some(LeaderPlacement::LoadAware),
            _ => None,
        }
    }

    /// True for every policy that shards leadership across nodes.
    pub fn is_sharded(&self) -> bool {
        *self != LeaderPlacement::Single
    }
}

/// One fault action in a [`FaultSchedule`] (§3 fault model, generalized:
/// crash-stop, crash-recover, link partitions, packet loss, delay spikes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultAction {
    /// Crash a node (`None` = whoever leads at the trigger point).
    Crash { node: Option<usize> },
    /// Bring a crashed node back ("return to functionality", §3): the
    /// cluster snapshots a live donor into it and the leader's
    /// heartbeat-driven log replay covers the rest.
    Recover { node: usize },
    /// Cut the `a <-> b` link in both directions. Senders observe the cut
    /// like they observe a crash: verbs NACK after the retransmission
    /// timeout (and still occupy the in-order channel — no free lane).
    PartitionLinks { a: usize, b: usize },
    /// Repair every cut link; the current leader replays its strong log to
    /// the formerly unreachable side (anti-entropy on heal).
    HealLinks,
    /// Silently lose the next `count` verbs on the directed `src -> dst`
    /// link (completion-carrying verbs still NACK at the retransmission
    /// timeout, so initiators observe the loss).
    DropNext { src: usize, dst: usize, count: u32 },
    /// Multiply the one-way latency of the directed `src -> dst` link by
    /// `factor_pct`/100 until `until_pct` % of ops have completed.
    DelaySpike { src: usize, dst: usize, factor_pct: u32, until_pct: u8 },
}

impl FaultAction {
    /// Round-trips through [`FaultSchedule::parse`] when prefixed with
    /// `@pct`; also the per-incident label in chaos telemetry/CSV.
    pub fn label(&self) -> String {
        match *self {
            FaultAction::Crash { node: Some(n) } => format!("crash:{n}"),
            FaultAction::Crash { node: None } => "crash:leader".into(),
            FaultAction::Recover { node } => format!("recover:{node}"),
            FaultAction::PartitionLinks { a, b } => format!("partition:{a}-{b}"),
            FaultAction::HealLinks => "heal".into(),
            FaultAction::DropNext { src, dst, count } => format!("drop:{src}-{dst}x{count}"),
            FaultAction::DelaySpike { src, dst, factor_pct, until_pct } => {
                format!("delay:{src}-{dst}x{factor_pct}u{until_pct}")
            }
        }
    }
}

/// A fault action armed at a completed-ops watermark (`at_pct` % of the
/// run's op target).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TimedFault {
    pub at_pct: u8,
    pub action: FaultAction,
}

/// Deterministic fault-injection plan: an ordered list of timed actions.
/// Empty = fault-free (bit-identical to the engine with no fault plumbing).
/// Parseable from kv/CLI — see [`FaultSchedule::parse`] for the grammar.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultSchedule {
    pub incidents: Vec<TimedFault>,
}

impl FaultSchedule {
    pub fn none() -> Self {
        FaultSchedule::default()
    }

    pub fn single(at_pct: u8, action: FaultAction) -> Self {
        FaultSchedule { incidents: vec![TimedFault { at_pct, action }] }
    }

    pub fn push(&mut self, at_pct: u8, action: FaultAction) -> &mut Self {
        self.incidents.push(TimedFault { at_pct, action });
        self
    }

    /// Fig 14 a/b: crash `node` once `pct` % of ops completed.
    pub fn crash_at(node: usize, pct: u8) -> Self {
        Self::single(pct, FaultAction::Crash { node: Some(node) })
    }

    /// Fig 14 c/d: crash whoever leads at the watermark.
    pub fn crash_leader_at(pct: u8) -> Self {
        Self::single(pct, FaultAction::Crash { node: None })
    }

    /// §3 "return to functionality": crash then recover the same node.
    pub fn crash_then_recover(node: usize, crash_pct: u8, recover_pct: u8) -> Self {
        let mut s = Self::crash_at(node, crash_pct);
        s.push(recover_pct, FaultAction::Recover { node });
        s
    }

    pub fn is_empty(&self) -> bool {
        self.incidents.is_empty()
    }

    /// Whether the schedule contains link-level faults (partition / drop /
    /// delay). These switch the relaxed path into tracked-completion mode
    /// (retry until ACK + at-most-once dedup); crash-only schedules keep
    /// the classic fire-and-forget fan-out so existing digests hold.
    pub fn has_link_faults(&self) -> bool {
        self.incidents.iter().any(|i| {
            matches!(
                i.action,
                FaultAction::PartitionLinks { .. }
                    | FaultAction::HealLinks
                    | FaultAction::DropNext { .. }
                    | FaultAction::DelaySpike { .. }
            )
        })
    }

    /// Human-readable round-trip form (`crash@40:0,partition@50:0-2,...`).
    pub fn label(&self) -> String {
        if self.incidents.is_empty() {
            return "none".into();
        }
        self.incidents
            .iter()
            .map(|i| {
                let a = i.action.label();
                match a.split_once(':') {
                    Some((kind, args)) => format!("{kind}@{}:{args}", i.at_pct),
                    None => format!("{a}@{}", i.at_pct),
                }
            })
            .collect::<Vec<_>>()
            .join(",")
    }

    /// Parse a comma-separated schedule. Grammar (one incident per item):
    ///
    /// ```text
    /// crash@<pct>:<node|leader>      partition@<pct>:<a>-<b>
    /// recover@<pct>:<node>           heal@<pct>
    /// drop@<pct>:<src>-<dst>x<count>
    /// delay@<pct>:<src>-<dst>x<factor_pct>u<until_pct>
    /// ```
    ///
    /// `none` (or an empty string) parses to the empty schedule.
    pub fn parse(s: &str) -> Result<Self, String> {
        let s = s.trim();
        if s.is_empty() || s == "none" {
            return Ok(FaultSchedule::none());
        }
        let mut out = FaultSchedule::none();
        for item in s.split(',') {
            let item = item.trim();
            let bad = |why: &str| format!("fault incident '{item}': {why}");
            let (head, args) = match item.split_once(':') {
                Some((h, a)) => (h, Some(a)),
                None => (item, None),
            };
            let (kind, pct) =
                head.split_once('@').ok_or_else(|| bad("expected <kind>@<pct>"))?;
            let at_pct: u8 = pct.parse().map_err(|_| bad("bad percentage"))?;
            let node = |v: &str| v.parse::<usize>().map_err(|_| bad("bad node id"));
            let pair = |v: &str| -> Result<(usize, usize), String> {
                let (a, b) = v.split_once('-').ok_or_else(|| bad("expected <a>-<b>"))?;
                Ok((node(a)?, node(b)?))
            };
            let action = match kind {
                "crash" => {
                    let v = args.ok_or_else(|| bad("crash needs :<node|leader>"))?;
                    if v == "leader" {
                        FaultAction::Crash { node: None }
                    } else {
                        FaultAction::Crash { node: Some(node(v)?) }
                    }
                }
                "recover" => {
                    FaultAction::Recover { node: node(args.ok_or_else(|| bad("recover needs :<node>"))?)? }
                }
                "partition" => {
                    let (a, b) = pair(args.ok_or_else(|| bad("partition needs :<a>-<b>"))?)?;
                    FaultAction::PartitionLinks { a, b }
                }
                "heal" => {
                    if args.is_some() {
                        return Err(bad("heal takes no arguments"));
                    }
                    FaultAction::HealLinks
                }
                "drop" => {
                    let v = args.ok_or_else(|| bad("drop needs :<src>-<dst>x<count>"))?;
                    let (links, count) = v.split_once('x').ok_or_else(|| bad("expected x<count>"))?;
                    let (src, dst) = pair(links)?;
                    let count: u32 = count.parse().map_err(|_| bad("bad drop count"))?;
                    FaultAction::DropNext { src, dst, count }
                }
                "delay" => {
                    let v = args.ok_or_else(|| bad("delay needs :<src>-<dst>x<factor_pct>u<until_pct>"))?;
                    let (links, rest) = v.split_once('x').ok_or_else(|| bad("expected x<factor>"))?;
                    let (src, dst) = pair(links)?;
                    let (factor, until) =
                        rest.split_once('u').ok_or_else(|| bad("expected u<until_pct>"))?;
                    let factor_pct: u32 = factor.parse().map_err(|_| bad("bad delay factor"))?;
                    let until_pct: u8 = until.parse().map_err(|_| bad("bad until pct"))?;
                    FaultAction::DelaySpike { src, dst, factor_pct, until_pct }
                }
                other => return Err(bad(&format!("unknown fault kind '{other}'"))),
            };
            out.push(at_pct, action);
        }
        Ok(out)
    }

    /// Structural validation against a cluster size.
    pub fn validate(&self, n: usize) -> Result<(), String> {
        let chk = |id: usize, what: &str| {
            if id >= n {
                Err(format!("fault schedule: {what} {id} out of range (n = {n})"))
            } else {
                Ok(())
            }
        };
        for inc in &self.incidents {
            if inc.at_pct > 100 {
                return Err(format!("fault schedule: at_pct {} > 100", inc.at_pct));
            }
            match inc.action {
                FaultAction::Crash { node: Some(nd) } => chk(nd, "crash node")?,
                FaultAction::Crash { node: None } => {}
                FaultAction::Recover { node } => chk(node, "recover node")?,
                FaultAction::PartitionLinks { a, b } => {
                    chk(a, "partition endpoint")?;
                    chk(b, "partition endpoint")?;
                    if a == b {
                        return Err("fault schedule: partition endpoints must differ".into());
                    }
                }
                FaultAction::HealLinks => {}
                FaultAction::DropNext { src, dst, count } => {
                    chk(src, "drop src")?;
                    chk(dst, "drop dst")?;
                    if src == dst {
                        return Err("fault schedule: drop endpoints must differ".into());
                    }
                    if count == 0 {
                        return Err("fault schedule: drop count must be >= 1".into());
                    }
                }
                FaultAction::DelaySpike { src, dst, factor_pct, until_pct } => {
                    chk(src, "delay src")?;
                    chk(dst, "delay dst")?;
                    if src == dst {
                        return Err("fault schedule: delay endpoints must differ".into());
                    }
                    if factor_pct == 0 {
                        return Err("fault schedule: delay factor must be >= 1 %".into());
                    }
                    if until_pct > 100 {
                        return Err(format!("fault schedule: delay until {until_pct} > 100"));
                    }
                    if until_pct < inc.at_pct {
                        return Err("fault schedule: delay ends before it starts".into());
                    }
                }
            }
        }
        Ok(())
    }
}

/// One object class in a [`CatalogSpec`]: a micro-benchmark RDT or a keyed
/// KV tenant (YCSB registers / SmallBank accounts). The engine's catalog
/// instantiates `count` independent instances per entry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ObjectKind {
    Rdt(RdtKind),
    Ycsb,
    SmallBank,
}

impl ObjectKind {
    /// Spec-grammar name (round-trips through [`CatalogSpec::parse`]).
    pub fn spec_name(&self) -> &'static str {
        match self {
            ObjectKind::Rdt(RdtKind::GCounter) => "gcounter",
            ObjectKind::Rdt(RdtKind::PnCounter) => "counter",
            ObjectKind::Rdt(RdtKind::LwwRegister) => "lww",
            ObjectKind::Rdt(RdtKind::GSet) => "gset",
            ObjectKind::Rdt(RdtKind::PnSet) => "pnset",
            ObjectKind::Rdt(RdtKind::TwoPSet) => "2pset",
            ObjectKind::Rdt(RdtKind::Account) => "account",
            ObjectKind::Rdt(RdtKind::Courseware) => "courseware",
            ObjectKind::Rdt(RdtKind::Project) => "project",
            ObjectKind::Rdt(RdtKind::Movie) => "movie",
            ObjectKind::Rdt(RdtKind::Auction) => "auction",
            ObjectKind::Ycsb => "ycsb",
            ObjectKind::SmallBank => "smallbank",
        }
    }

    fn parse_name(name: &str) -> Option<ObjectKind> {
        Some(match name {
            "counter" | "pn-counter" | "pncounter" => ObjectKind::Rdt(RdtKind::PnCounter),
            "gcounter" | "g-counter" => ObjectKind::Rdt(RdtKind::GCounter),
            "lww" | "lww-register" => ObjectKind::Rdt(RdtKind::LwwRegister),
            "gset" | "g-set" => ObjectKind::Rdt(RdtKind::GSet),
            "pnset" | "pn-set" => ObjectKind::Rdt(RdtKind::PnSet),
            "2pset" | "2p-set" | "twopset" => ObjectKind::Rdt(RdtKind::TwoPSet),
            "account" => ObjectKind::Rdt(RdtKind::Account),
            "courseware" => ObjectKind::Rdt(RdtKind::Courseware),
            "project" => ObjectKind::Rdt(RdtKind::Project),
            "movie" => ObjectKind::Rdt(RdtKind::Movie),
            "auction" => ObjectKind::Rdt(RdtKind::Auction),
            "ycsb" => ObjectKind::Ycsb,
            "smallbank" => ObjectKind::SmallBank,
            _ => return None,
        })
    }

    /// Synchronization groups one instance of this kind needs (Table B.1;
    /// KV: SmallBank debits need one SMR instance, YCSB none).
    pub fn sync_groups(&self) -> u32 {
        match self {
            ObjectKind::Rdt(k) => k.instantiate().sync_groups() as u32,
            ObjectKind::Ycsb => 0,
            ObjectKind::SmallBank => 1,
        }
    }
}

/// Multi-object catalog specification: which RDT instances the data plane
/// hosts (`objects = counter:8,account:4,movie:2` in kv/CLI form) and how
/// skewed the workload's object selection is. The empty spec is the
/// default and means "one object, derived from `workload`" — bit-identical
/// to the pre-catalog engine.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CatalogSpec {
    /// Ordered (kind, instance count) entries; object ids are assigned
    /// densely in entry order.
    pub entries: Vec<(ObjectKind, u32)>,
    /// Zipfian skew of object selection (0 = uniform).
    pub zipf_theta: f64,
}

impl CatalogSpec {
    /// The default catalog-of-one derived from `SimConfig::workload`.
    pub fn single() -> Self {
        CatalogSpec::default()
    }

    /// True when the catalog is the implicit single object.
    pub fn is_default(&self) -> bool {
        self.entries.is_empty()
    }

    /// Total object count (1 for the default spec).
    pub fn n_objects(&self) -> usize {
        if self.entries.is_empty() {
            1
        } else {
            self.entries.iter().map(|&(_, c)| c as usize).sum()
        }
    }

    /// The standard mixed multi-tenant scenario (`objects = mixed`):
    /// commutative counters/registers/sets next to invariant-carrying
    /// WRDTs — 9 objects, 7 global sync groups.
    pub fn mixed() -> Self {
        CatalogSpec {
            entries: vec![
                (ObjectKind::Rdt(RdtKind::PnCounter), 2),
                (ObjectKind::Rdt(RdtKind::LwwRegister), 2),
                (ObjectKind::Rdt(RdtKind::GSet), 1),
                (ObjectKind::Rdt(RdtKind::Account), 2),
                (ObjectKind::Rdt(RdtKind::Movie), 1),
                (ObjectKind::Rdt(RdtKind::Auction), 1),
            ],
            zipf_theta: 0.0,
        }
    }

    /// Round-trip form (`counter:8,account:4`; `none` for the default).
    pub fn label(&self) -> String {
        if self.entries.is_empty() {
            return "none".into();
        }
        self.entries
            .iter()
            .map(|(k, c)| format!("{}:{c}", k.spec_name()))
            .collect::<Vec<_>>()
            .join(",")
    }

    /// Parse the `objects =` grammar: comma-separated `name[:count]` items
    /// (`count` defaults to 1), plus the aliases `none`/`` (default spec)
    /// and `mixed` (the standard multi-tenant scenario).
    pub fn parse(s: &str) -> Result<Self, String> {
        let s = s.trim();
        if s.is_empty() || s == "none" {
            return Ok(CatalogSpec::default());
        }
        if s == "mixed" {
            return Ok(CatalogSpec::mixed());
        }
        let mut entries = Vec::new();
        for item in s.split(',') {
            let item = item.trim();
            let bad = |why: &str| format!("catalog entry '{item}': {why}");
            let (name, count) = match item.split_once(':') {
                Some((n, c)) => {
                    let count: u32 =
                        c.parse().map_err(|_| bad("bad instance count"))?;
                    (n, count)
                }
                None => (item, 1),
            };
            let kind = ObjectKind::parse_name(name)
                .ok_or_else(|| bad("unknown object kind"))?;
            if count == 0 {
                return Err(bad("instance count must be >= 1"));
            }
            entries.push((kind, count));
        }
        Ok(CatalogSpec { entries, zipf_theta: 0.0 })
    }

    /// Dense object-id -> kind expansion (entry order, `count` instances
    /// each). The single source of truth for object-id assignment: the
    /// engine's catalog and the workload generator both derive from this,
    /// so they can never disagree on which object an id names. Empty for
    /// the default spec.
    pub fn expanded_kinds(&self) -> Vec<ObjectKind> {
        self.entries
            .iter()
            .flat_map(|&(kind, count)| (0..count).map(move |_| kind))
            .collect()
    }

    /// Total synchronization groups across the catalog: the strong planes
    /// flatten `(object, local group)` into this global index space.
    pub fn total_groups(&self) -> u32 {
        self.entries.iter().map(|&(k, c)| k.sync_groups() * c).sum()
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.n_objects() > 4096 {
            return Err(format!("catalog: {} objects exceeds the 4096 cap", self.n_objects()));
        }
        if !self.entries.is_empty() && self.total_groups() > u8::MAX as u32 {
            return Err(format!(
                "catalog: {} global sync groups exceeds the 255 wire-format cap",
                self.total_groups()
            ));
        }
        if !(0.0..2.0).contains(&self.zipf_theta) {
            return Err(format!("catalog: obj_theta {} out of range [0, 2)", self.zipf_theta));
        }
        Ok(())
    }
}

/// Hybrid-mode layout (Figs 15–17): part of the keyspace FPGA-resident,
/// the rest in host memory behind the CPU cache.
#[derive(Clone, Copy, Debug)]
pub struct HybridConfig {
    /// Total keys (YCSB keys / SmallBank accounts).
    pub total_keys: u64,
    /// Keys resident on the FPGA (hot set).
    pub fpga_keys: u64,
    /// Fraction (0..=100) of operations targeting FPGA-resident keys.
    pub fpga_ops_pct: u8,
    /// Zipfian skew of key selection (θ=0 uniform).
    pub zipf_theta: f64,
    /// Host LLC model capacity in keys.
    pub host_cache_keys: usize,
}

impl HybridConfig {
    pub fn ycsb_default() -> Self {
        // Scaled 10:1 from the paper's 100K FPGA / 10M host keys so exact
        // LRU simulation stays cheap; ratios preserved (DESIGN.md §1).
        HybridConfig {
            total_keys: 1_010_000,
            fpga_keys: 10_000,
            fpga_ops_pct: 50,
            zipf_theta: 0.0,
            host_cache_keys: 150_000,
        }
    }

    pub fn smallbank_default() -> Self {
        // Paper: 10M FPGA / 90M host accounts, scaled 100:1.
        HybridConfig {
            total_keys: 1_000_000,
            fpga_keys: 100_000,
            fpga_ops_pct: 50,
            zipf_theta: 0.0,
            host_cache_keys: 150_000,
        }
    }
}

/// Client-plane arrival process (`arrival = ...`).
///
/// `Closed` is the historical fixed-slot loop: `clients_per_replica`
/// outstanding ops per node, each slot issuing its next op the moment the
/// previous one completes — bit-identical to the pre-open-loop engine. The
/// open-loop kinds instead model millions of logical clients as one
/// aggregate seeded arrival stream per node: inter-arrival gaps are drawn
/// from `core.rng`, arrivals queue behind a bounded admission buffer
/// (`queue_cap`), and arrivals that find the buffer full are shed. Rates
/// are offered load in ops per second of virtual time, per node.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum ArrivalProcess {
    /// Fixed-slot closed loop (default; bit-identical to prior releases).
    #[default]
    Closed,
    /// Memoryless arrivals: exponential inter-arrival gaps with mean
    /// `1e9 / rate` ns.
    Poisson { rate: u64 },
    /// Square-wave burst train with mean `rate`: the first half of every
    /// `period_ns` window runs `amp` times hotter than the second half
    /// (`amp = 1` degenerates to `Poisson`).
    Bursty { rate: u64, period_ns: u64, amp: u32 },
    /// Slow sinusoid-free daily cycle: a triangle wave swings the
    /// instantaneous rate between 0.5x and 1.5x of `rate` over `period_ns`
    /// (piecewise-linear so draws stay bit-stable across platforms).
    Diurnal { rate: u64, period_ns: u64 },
}

impl ArrivalProcess {
    /// Parse the `closed | poisson:RATE | bursty:RATE:PERIOD:AMP |
    /// diurnal:RATE:PERIOD` grammar (RATE in ops/s per node, PERIOD in ns).
    pub fn parse(s: &str) -> Result<Self, String> {
        let s = s.trim();
        if s == "closed" {
            return Ok(ArrivalProcess::Closed);
        }
        let mut parts = s.split(':');
        let kind = parts.next().unwrap_or("");
        let mut num = |what: &str| -> Result<u64, String> {
            parts
                .next()
                .ok_or_else(|| format!("arrival '{kind}' is missing its {what}"))?
                .parse::<u64>()
                .map_err(|_| format!("arrival '{kind}': bad {what} in '{s}'"))
        };
        let parsed = match kind {
            "poisson" => ArrivalProcess::Poisson { rate: num("RATE")? },
            "bursty" => ArrivalProcess::Bursty {
                rate: num("RATE")?,
                period_ns: num("PERIOD")?,
                amp: num("AMP")? as u32,
            },
            "diurnal" => ArrivalProcess::Diurnal { rate: num("RATE")?, period_ns: num("PERIOD")? },
            _ => {
                return Err(format!(
                    "unknown arrival process '{s}' (want closed | poisson:RATE | \
                     bursty:RATE:PERIOD:AMP | diurnal:RATE:PERIOD)"
                ))
            }
        };
        if parts.next().is_some() {
            return Err(format!("arrival '{s}': trailing fields"));
        }
        Ok(parsed)
    }

    /// Round-trips through `parse`.
    pub fn label(&self) -> String {
        match self {
            ArrivalProcess::Closed => "closed".to_string(),
            ArrivalProcess::Poisson { rate } => format!("poisson:{rate}"),
            ArrivalProcess::Bursty { rate, period_ns, amp } => {
                format!("bursty:{rate}:{period_ns}:{amp}")
            }
            ArrivalProcess::Diurnal { rate, period_ns } => format!("diurnal:{rate}:{period_ns}"),
        }
    }

    /// True for every kind except the closed loop.
    pub fn is_open(&self) -> bool {
        !matches!(self, ArrivalProcess::Closed)
    }

    pub fn validate(&self) -> Result<(), String> {
        let (rate, period) = match *self {
            ArrivalProcess::Closed => return Ok(()),
            ArrivalProcess::Poisson { rate } => (rate, 1),
            ArrivalProcess::Bursty { rate, period_ns, amp } => {
                if amp == 0 {
                    return Err("arrival: bursty AMP must be >= 1".into());
                }
                if amp > 1_000 {
                    return Err(format!("arrival: bursty AMP must be <= 1000, got {amp}"));
                }
                (rate, period_ns)
            }
            ArrivalProcess::Diurnal { rate, period_ns } => (rate, period_ns),
        };
        if rate == 0 {
            return Err("arrival: RATE must be >= 1 op/s".into());
        }
        if rate > 1_000_000_000 {
            return Err(format!("arrival: RATE must be <= 1e9 ops/s per node, got {rate}"));
        }
        if period == 0 {
            return Err("arrival: PERIOD must be >= 1 ns".into());
        }
        Ok(())
    }
}

/// Workload selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkloadKind {
    /// One RDT instance, update/query mix (the micro-benchmarks).
    Micro(RdtKind),
    /// YCSB over a keyspace of LWW registers (Fig 11/12/15/16).
    Ycsb,
    /// SmallBank over accounts (Fig 11/15/16/17).
    SmallBank,
}

impl WorkloadKind {
    pub fn name(&self) -> String {
        match self {
            WorkloadKind::Micro(k) => k.name().to_string(),
            WorkloadKind::Ycsb => "YCSB".to_string(),
            WorkloadKind::SmallBank => "SmallBank".to_string(),
        }
    }
}

/// Full run configuration.
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub system: SystemKind,
    pub n_replicas: usize,
    pub workload: WorkloadKind,
    /// Multi-object catalog layout. The default (empty) spec hosts one
    /// object derived from `workload`, bit-identical to the pre-catalog
    /// engine; non-empty specs make the data plane an ObjectId-addressed
    /// table of heterogeneous RDT instances.
    pub objects: CatalogSpec,
    /// Total operations across the cluster (paper: 4M; sweeps scale down).
    pub total_ops: u64,
    /// Percent of ops that are updates (the rest are query()).
    pub update_pct: u8,
    /// Service parallelism per replica: in the closed loop these are the
    /// fixed client slots (each re-issues on completion); in the open loop
    /// they bound how many admitted ops a node processes concurrently,
    /// with further arrivals waiting in the admission queue.
    pub clients_per_replica: usize,
    /// Client-plane arrival process (`Closed` default = fixed-slot loop,
    /// bit-identical to prior releases; the open-loop kinds drive seeded
    /// per-node arrival streams through `EventKind::Arrival`).
    pub arrival: ArrivalProcess,
    /// Open-loop admission-queue bound per replica: arrivals beyond the
    /// busy service slots wait here; arrivals that find it full are shed
    /// (counted, never serviced). Ignored by the closed loop.
    pub queue_cap: usize,
    pub prop_reducible: PropagationMode,
    pub prop_irreducible: PropagationMode,
    pub prop_conflicting: PropagationMode,
    /// Consensus engine on the strongly-ordered path (Mu / Raft / Paxos).
    /// Waverunner's strong path *is* its SmartNIC Raft pipeline, so that
    /// system pins Raft; everything else defaults to Mu.
    pub backend: ConsensusBackend,
    /// Bookkeeping for kv parsing: true once a `backend =` line was
    /// applied. `system = waverunner` implies Raft only while the backend
    /// is *not* an explicit user choice — across multiple `apply_kv` calls
    /// (the CLI applies one per argument) — so an explicit-but-incompatible
    /// pick surfaces through `validate()` instead of being overridden.
    pub backend_explicit: bool,
    /// Strong-plane leadership placement: `Single` (default, one node
    /// leads every global sync group — bit-identical to the pre-sharding
    /// engine) or a sharded policy (`Hash` / `RoundRobin` / `LoadAware`)
    /// that places each group's leader independently so strong-path
    /// throughput scales with nodes.
    pub placement: LeaderPlacement,
    /// Per-path batching: up to this many queued submissions coalesce into
    /// one wire verb (relaxed fan-out and leader-side log appends). 1 =
    /// batching off, bit-identical to the pre-batching engine.
    pub batch_size: u32,
    /// Strong-plane pipeline depth: up to this many consensus rounds in
    /// flight per shard (sync group). Quorums collect out of order; commit
    /// and apply stay strictly in slot order behind a commit cursor. 1 =
    /// stop-and-wait, bit-identical to the pre-pipelining engine.
    /// Orthogonal to `batch_size`: batching widens each round, the window
    /// deepens the pipeline — they multiply.
    pub window: u32,
    /// Reducible ops aggregated locally before one propagation (§5.4; 1 =
    /// propagate every op).
    pub summarize_threshold: u32,
    pub seed: u64,
    /// Deterministic fault-injection plan (empty = fault-free).
    pub fault: FaultSchedule,
    pub hybrid: Option<HybridConfig>,
    /// Background poll interval for buffered/queue/log pollers (ns).
    pub poll_interval_ns: u64,
    /// Heartbeat scanner period (ns) and #unchanged reads to declare death.
    pub heartbeat_period_ns: u64,
    pub hb_fail_threshold: u32,
    /// Ablation hook: replace the system's parameter bundle (fabric /
    /// memory / exec / power) for this run only.
    pub params_override: Option<SystemParams>,
}

impl SimConfig {
    pub fn new(system: SystemKind, workload: WorkloadKind) -> Self {
        SimConfig {
            system,
            n_replicas: 4,
            workload,
            objects: CatalogSpec::default(),
            total_ops: 100_000,
            update_pct: 15,
            clients_per_replica: 4,
            arrival: ArrivalProcess::Closed,
            queue_cap: 256,
            prop_reducible: PropagationMode::Rpc,
            prop_irreducible: PropagationMode::Rpc,
            prop_conflicting: PropagationMode::WriteThrough,
            backend: ConsensusBackend::Mu,
            backend_explicit: false,
            placement: LeaderPlacement::Single,
            batch_size: 1,
            window: 1,
            summarize_threshold: 1,
            seed: 0xC0FFEE,
            fault: FaultSchedule::none(),
            hybrid: None,
            poll_interval_ns: 400,
            heartbeat_period_ns: 20_000,
            hb_fail_threshold: 4,
            params_override: None,
        }
    }

    /// SafarDB with its best configuration (RPC verbs everywhere).
    pub fn safardb(workload: WorkloadKind) -> Self {
        SimConfig::new(SystemKind::SafarDb, workload)
    }

    /// SafarDB restricted to standard verbs + buffering ("SafarDB
    /// (Baseline)" in Figs 8/10).
    pub fn safardb_baseline(workload: WorkloadKind) -> Self {
        let mut c = SimConfig::new(SystemKind::SafarDb, workload);
        c.prop_reducible = PropagationMode::WriteBuffered;
        c.prop_irreducible = PropagationMode::WriteNoBuffer;
        c.prop_conflicting = PropagationMode::WriteNoBuffer;
        c
    }

    /// Hamband: CPU RDMA, standard verbs only.
    pub fn hamband(workload: WorkloadKind) -> Self {
        let mut c = SimConfig::new(SystemKind::Hamband, workload);
        c.prop_reducible = PropagationMode::WriteNoBuffer;
        c.prop_irreducible = PropagationMode::WriteNoBuffer;
        c.prop_conflicting = PropagationMode::WriteNoBuffer;
        // CPU pollers are threads, not fabric logic: coarser interval.
        c.poll_interval_ns = 1_200;
        c
    }

    /// Waverunner: 3-node Raft, leader-only clients.
    pub fn waverunner(workload: WorkloadKind) -> Self {
        let mut c = SimConfig::new(SystemKind::Waverunner, workload);
        c.n_replicas = 3;
        c.backend = ConsensusBackend::Raft;
        c
    }

    /// Catalog object count (1 for the default single-object spec).
    pub fn n_objects(&self) -> usize {
        self.objects.n_objects()
    }

    /// Category → replication-path routing. Waverunner replicates every
    /// update through Raft — no hybrid consistency, which is the point of
    /// the Fig 12 comparison (§5.2). Summarization (§5.4) diverts
    /// conflicting ops onto the relaxed path, trading integrity staleness
    /// for performance.
    pub fn path_for(&self, category: Category) -> ReplicationPathKind {
        if self.system == SystemKind::Waverunner {
            return ReplicationPathKind::Strong;
        }
        match category {
            Category::Reducible | Category::Irreducible => ReplicationPathKind::Relaxed,
            Category::Conflicting if self.summarize_threshold > 1 => ReplicationPathKind::Relaxed,
            Category::Conflicting => ReplicationPathKind::Strong,
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.n_replicas < 2 {
            return Err(format!("n_replicas must be >= 2, got {}", self.n_replicas));
        }
        if self.n_replicas > crate::rdt::crdt::counter::MAX_REPLICAS {
            return Err(format!("n_replicas must be <= 16, got {}", self.n_replicas));
        }
        if self.update_pct > 100 {
            return Err(format!("update_pct must be <= 100, got {}", self.update_pct));
        }
        if self.total_ops == 0 {
            return Err("total_ops must be positive".into());
        }
        if self.clients_per_replica == 0 {
            return Err("clients_per_replica must be positive".into());
        }
        self.arrival.validate()?;
        if self.arrival.is_open() && self.queue_cap == 0 {
            return Err("queue_cap must be >= 1 under an open-loop arrival process".into());
        }
        if self.queue_cap > 1 << 20 {
            return Err(format!("queue_cap must be <= 2^20, got {}", self.queue_cap));
        }
        if self.summarize_threshold == 0 {
            return Err("summarize_threshold must be >= 1".into());
        }
        if self.batch_size == 0 {
            return Err("batch_size must be >= 1 (1 = batching off)".into());
        }
        if self.batch_size > 1024 {
            return Err(format!("batch_size must be <= 1024, got {}", self.batch_size));
        }
        if self.window == 0 {
            return Err("window must be >= 1 (1 = pipelining off)".into());
        }
        if self.window > 64 {
            return Err(format!("window must be <= 64, got {}", self.window));
        }
        if self.system == SystemKind::Waverunner && self.backend != ConsensusBackend::Raft {
            return Err(format!(
                "Waverunner's strong path is its SmartNIC Raft pipeline; backend '{}' \
                 is not selectable for it",
                self.backend.name()
            ));
        }
        if self.system == SystemKind::Waverunner && self.placement.is_sharded() {
            return Err(
                "Waverunner handles clients at its single Raft leader; sharded \
                 leadership placement is not selectable for it"
                    .into(),
            );
        }
        self.fault.validate(self.n_replicas)?;
        self.objects.validate()?;
        if !self.objects.is_default() && self.hybrid.is_some() {
            return Err("hybrid mode addresses a single keyed store; it cannot \
                 combine with a multi-object catalog"
                .into());
        }
        if self.system != SystemKind::SafarDb {
            let rpc = [self.prop_reducible, self.prop_irreducible]
                .iter()
                .any(|m| matches!(m, PropagationMode::Rpc | PropagationMode::WriteThrough))
                || matches!(self.prop_conflicting, PropagationMode::Rpc | PropagationMode::WriteThrough);
            if rpc && self.system == SystemKind::Hamband {
                return Err("Hamband's RNIC has no FPGA-specific RPC verbs".into());
            }
        }
        if let Some(h) = &self.hybrid {
            if h.fpga_keys > h.total_keys {
                return Err("hybrid: fpga_keys > total_keys".into());
            }
            if h.fpga_ops_pct > 100 {
                return Err("hybrid: fpga_ops_pct > 100".into());
            }
        }
        Ok(())
    }

    /// Parse a simple `key = value` config file body over a base config.
    pub fn apply_kv(&mut self, body: &str) -> Result<(), String> {
        for (lineno, raw) in body.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let (k, v) = (k.trim(), v.trim());
            let bad = |what: &str| format!("line {}: bad {what}: {v}", lineno + 1);
            match k {
                "replicas" => self.n_replicas = v.parse().map_err(|_| bad("replicas"))?,
                "total_ops" => self.total_ops = v.parse().map_err(|_| bad("total_ops"))?,
                "update_pct" => self.update_pct = v.parse().map_err(|_| bad("update_pct"))?,
                "clients" => {
                    self.clients_per_replica = v.parse().map_err(|_| bad("clients"))?
                }
                "seed" => self.seed = v.parse().map_err(|_| bad("seed"))?,
                "arrival" => {
                    self.arrival = ArrivalProcess::parse(v)
                        .map_err(|e| format!("line {}: {e}", lineno + 1))?
                }
                "queue_cap" => self.queue_cap = v.parse().map_err(|_| bad("queue_cap"))?,
                "summarize" => {
                    self.summarize_threshold = v.parse().map_err(|_| bad("summarize"))?
                }
                "poll_interval_ns" => {
                    self.poll_interval_ns = v.parse().map_err(|_| bad("poll_interval_ns"))?
                }
                "backend" => {
                    self.backend = ConsensusBackend::parse(v).ok_or_else(|| bad("backend"))?;
                    self.backend_explicit = true;
                }
                "placement" => {
                    self.placement = LeaderPlacement::parse(v).ok_or_else(|| bad("placement"))?
                }
                "fault" => {
                    self.fault = FaultSchedule::parse(v)
                        .map_err(|e| format!("line {}: {e}", lineno + 1))?
                }
                "objects" => {
                    let theta = self.objects.zipf_theta;
                    self.objects = CatalogSpec::parse(v)
                        .map_err(|e| format!("line {}: {e}", lineno + 1))?;
                    self.objects.zipf_theta = theta;
                }
                "obj_theta" => {
                    self.objects.zipf_theta = v.parse().map_err(|_| bad("obj_theta"))?
                }
                "batch" | "batch_size" => {
                    self.batch_size = v.parse().map_err(|_| bad("batch_size"))?
                }
                "window" => self.window = v.parse().map_err(|_| bad("window"))?,
                "system" => {
                    self.system = match v {
                        "safardb" => SystemKind::SafarDb,
                        "hamband" => SystemKind::Hamband,
                        "waverunner" => {
                            // Waverunner's strong path is its Raft pipeline;
                            // an explicit backend choice (any apply_kv call)
                            // wins and is judged by validate() instead.
                            if !self.backend_explicit {
                                self.backend = ConsensusBackend::Raft;
                            }
                            SystemKind::Waverunner
                        }
                        _ => return Err(bad("system")),
                    }
                }
                _ => return Err(format!("line {}: unknown key '{k}'", lineno + 1)),
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        for c in [
            SimConfig::safardb(WorkloadKind::Micro(RdtKind::PnCounter)),
            SimConfig::safardb_baseline(WorkloadKind::Micro(RdtKind::Account)),
            SimConfig::hamband(WorkloadKind::Ycsb),
            SimConfig::waverunner(WorkloadKind::Ycsb),
        ] {
            c.validate().expect("preset must validate");
        }
    }

    #[test]
    fn hamband_cannot_use_rpc_verbs() {
        let mut c = SimConfig::hamband(WorkloadKind::Ycsb);
        c.prop_reducible = PropagationMode::Rpc;
        assert!(c.validate().is_err());
    }

    #[test]
    fn bounds_checked() {
        let mut c = SimConfig::safardb(WorkloadKind::Ycsb);
        c.n_replicas = 1;
        assert!(c.validate().is_err());
        c.n_replicas = 64;
        assert!(c.validate().is_err());
        c.n_replicas = 8;
        c.update_pct = 101;
        assert!(c.validate().is_err());
    }

    #[test]
    fn kv_parse_applies_and_rejects() {
        let mut c = SimConfig::safardb(WorkloadKind::Ycsb);
        c.apply_kv("replicas = 6\nupdate_pct = 25 # comment\n\nseed = 7\n").unwrap();
        assert_eq!(c.n_replicas, 6);
        assert_eq!(c.update_pct, 25);
        assert_eq!(c.seed, 7);
        assert!(c.apply_kv("nope = 1").is_err());
        assert!(c.apply_kv("replicas").is_err());
        assert!(c.apply_kv("replicas = x").is_err());
    }

    #[test]
    fn config_doc_covers_every_field() {
        const DOC: &str = include_str!("../../../docs/CONFIG.md");
        // Exhaustive destructure: adding a SimConfig field breaks this
        // pattern at compile time, forcing the field list below — and
        // therefore docs/CONFIG.md — to be updated in the same change.
        let SimConfig {
            system: _,
            n_replicas: _,
            workload: _,
            objects: _,
            total_ops: _,
            update_pct: _,
            clients_per_replica: _,
            arrival: _,
            queue_cap: _,
            prop_reducible: _,
            prop_irreducible: _,
            prop_conflicting: _,
            backend: _,
            backend_explicit: _,
            placement: _,
            batch_size: _,
            window: _,
            summarize_threshold: _,
            seed: _,
            fault: _,
            hybrid: _,
            poll_interval_ns: _,
            heartbeat_period_ns: _,
            hb_fail_threshold: _,
            params_override: _,
        } = SimConfig::safardb(WorkloadKind::Ycsb);
        for field in [
            "system",
            "n_replicas",
            "workload",
            "objects",
            "total_ops",
            "update_pct",
            "clients_per_replica",
            "arrival",
            "queue_cap",
            "prop_reducible",
            "prop_irreducible",
            "prop_conflicting",
            "backend",
            "backend_explicit",
            "placement",
            "batch_size",
            "window",
            "summarize_threshold",
            "seed",
            "fault",
            "hybrid",
            "poll_interval_ns",
            "heartbeat_period_ns",
            "hb_fail_threshold",
            "params_override",
        ] {
            assert!(
                DOC.contains(field),
                "docs/CONFIG.md does not mention SimConfig field '{field}'"
            );
        }
    }

    #[test]
    fn arrival_grammar_roundtrips_and_rejects() {
        for s in ["closed", "poisson:800000", "bursty:400000:200000:4", "diurnal:250000:1000000"] {
            let a = ArrivalProcess::parse(s).expect("grammar accepts");
            assert_eq!(a.label(), s, "label round-trips");
            a.validate().expect("parsed arrival validates");
        }
        assert_eq!(ArrivalProcess::parse("closed").unwrap(), ArrivalProcess::Closed);
        assert!(!ArrivalProcess::Closed.is_open());
        assert!(ArrivalProcess::Poisson { rate: 1 }.is_open());
        for s in [
            "poisson",              // missing RATE
            "poisson:fast",         // non-numeric
            "poisson:1000:7",       // trailing field
            "bursty:1000:200",      // missing AMP
            "diurnal:1000",         // missing PERIOD
            "sawtooth:1000",        // unknown kind
        ] {
            assert!(ArrivalProcess::parse(s).is_err(), "'{s}' must be rejected");
        }
        for bad in [
            ArrivalProcess::Poisson { rate: 0 },
            ArrivalProcess::Poisson { rate: 2_000_000_000 },
            ArrivalProcess::Bursty { rate: 1000, period_ns: 0, amp: 2 },
            ArrivalProcess::Bursty { rate: 1000, period_ns: 100, amp: 0 },
            ArrivalProcess::Diurnal { rate: 1000, period_ns: 0 },
        ] {
            assert!(bad.validate().is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn arrival_and_queue_cap_kv_knobs() {
        let mut c = SimConfig::safardb(WorkloadKind::Micro(RdtKind::Account));
        assert_eq!(c.arrival, ArrivalProcess::Closed, "closed loop is the default");
        assert_eq!(c.queue_cap, 256);
        c.apply_kv("arrival = poisson:800000\nqueue_cap = 64\n").unwrap();
        assert_eq!(c.arrival, ArrivalProcess::Poisson { rate: 800_000 });
        assert_eq!(c.queue_cap, 64);
        c.validate().expect("open-loop config validates");
        assert!(c.apply_kv("arrival = sawtooth:9").is_err());
        assert!(c.apply_kv("queue_cap = lots").is_err());
        c.queue_cap = 0;
        assert!(c.validate().is_err(), "open loop needs a positive queue_cap");
        c.arrival = ArrivalProcess::Closed;
        c.validate().expect("closed loop ignores queue_cap");
        c.queue_cap = (1 << 20) + 1;
        assert!(c.validate().is_err(), "queue_cap cap enforced");
    }

    #[test]
    fn path_routing_matches_planes() {
        let c = SimConfig::safardb(WorkloadKind::SmallBank);
        assert_eq!(c.path_for(Category::Reducible), ReplicationPathKind::Relaxed);
        assert_eq!(c.path_for(Category::Irreducible), ReplicationPathKind::Relaxed);
        assert_eq!(c.path_for(Category::Conflicting), ReplicationPathKind::Strong);

        // §5.4: summarization diverts conflicting ops off the SMR path.
        let mut batched = c.clone();
        batched.summarize_threshold = 8;
        assert_eq!(batched.path_for(Category::Conflicting), ReplicationPathKind::Relaxed);

        // Waverunner replicates everything through Raft (§5.2).
        let w = SimConfig::waverunner(WorkloadKind::Ycsb);
        assert_eq!(w.path_for(Category::Reducible), ReplicationPathKind::Strong);
        assert_eq!(w.path_for(Category::Conflicting), ReplicationPathKind::Strong);
    }

    #[test]
    fn backend_and_batch_knobs() {
        let mut c = SimConfig::safardb(WorkloadKind::Micro(RdtKind::Account));
        assert_eq!(c.backend, ConsensusBackend::Mu, "default backend is Mu");
        assert_eq!(c.batch_size, 1, "batching defaults off");
        c.apply_kv("backend = paxos\nbatch = 8\n").unwrap();
        assert_eq!(c.backend, ConsensusBackend::Paxos);
        assert_eq!(c.batch_size, 8);
        c.validate().expect("paxos + batching validates");
        assert!(c.apply_kv("backend = zab").is_err());

        c.batch_size = 0;
        assert!(c.validate().is_err(), "batch_size 0 rejected");
        c.batch_size = 2048;
        assert!(c.validate().is_err(), "batch_size cap enforced");
        c.batch_size = 8;

        assert_eq!(c.window, 1, "pipelining defaults off");
        c.apply_kv("window = 16\n").unwrap();
        assert_eq!(c.window, 16);
        c.validate().expect("window + batching validates");
        c.window = 0;
        assert!(c.validate().is_err(), "window 0 rejected");
        c.window = 65;
        assert!(c.validate().is_err(), "window cap enforced");
        c.window = 1;

        // Waverunner's strong path is its Raft pipeline — backend pinned.
        let mut w = SimConfig::waverunner(WorkloadKind::Ycsb);
        assert_eq!(w.backend, ConsensusBackend::Raft);
        w.backend = ConsensusBackend::Paxos;
        assert!(w.validate().is_err());

        // Every backend supports fault injection (generic Raft gained
        // snapshot-install + term-bumped replay recovery).
        let mut r = SimConfig::safardb(WorkloadKind::Micro(RdtKind::Account));
        r.backend = ConsensusBackend::Raft;
        r.validate().expect("fault-free raft is fine");
        r.fault = FaultSchedule::crash_at(1, 30);
        r.validate().expect("raft crash runs are supported now");
        r.backend = ConsensusBackend::Paxos;
        r.validate().expect("paxos supports crash runs");

        // kv: selecting waverunner implies raft, but an explicit backend
        // choice wins in either key order — even split across apply_kv
        // calls, as the CLI applies one per argument — and is then
        // rejected by validate instead of silently overridden.
        let mut k = SimConfig::safardb(WorkloadKind::Ycsb);
        k.apply_kv("system = waverunner").unwrap();
        assert_eq!(k.backend, ConsensusBackend::Raft, "waverunner implies raft");
        let mut k2 = SimConfig::safardb(WorkloadKind::Ycsb);
        k2.apply_kv("backend = mu\nsystem = waverunner").unwrap();
        assert_eq!(k2.backend, ConsensusBackend::Mu, "explicit choice preserved");
        assert!(k2.validate().is_err(), "incompatible combination surfaces");
        let mut k3 = SimConfig::safardb(WorkloadKind::Ycsb);
        k3.apply_kv("backend = mu").unwrap();
        k3.apply_kv("system = waverunner").unwrap();
        assert_eq!(k3.backend, ConsensusBackend::Mu, "explicitness survives across calls");
        assert!(k3.validate().is_err());
    }

    #[test]
    fn placement_knob_parses_and_validates() {
        let mut c = SimConfig::safardb(WorkloadKind::Micro(RdtKind::Account));
        assert_eq!(c.placement, LeaderPlacement::Single, "default is the classic layout");
        assert!(!c.placement.is_sharded());
        c.apply_kv("placement = hash").unwrap();
        assert_eq!(c.placement, LeaderPlacement::Hash);
        assert!(c.placement.is_sharded());
        c.validate().expect("sharded placement validates on SafarDB");
        c.apply_kv("placement = round-robin").unwrap();
        assert_eq!(c.placement, LeaderPlacement::RoundRobin);
        c.apply_kv("placement = load_aware").unwrap();
        assert_eq!(c.placement, LeaderPlacement::LoadAware);
        assert!(c.apply_kv("placement = sticky").is_err());

        // Every policy name round-trips through parse().
        for p in LeaderPlacement::ALL {
            assert_eq!(LeaderPlacement::parse(p.name()), Some(p));
        }

        // Waverunner's leader-only client handling pins the classic layout.
        let mut w = SimConfig::waverunner(WorkloadKind::Ycsb);
        w.placement = LeaderPlacement::Hash;
        assert!(w.validate().is_err(), "waverunner pins placement=single");

        // Partition faults resolve per group under sharding (per-group
        // minority-imposter abdication + heal-time realign): the full
        // chaos vocabulary validates for every placement policy.
        let mut p = SimConfig::safardb(WorkloadKind::Ycsb);
        p.placement = LeaderPlacement::Hash;
        p.fault = FaultSchedule::parse("partition@40:0-2,heal@60").unwrap();
        p.validate().expect("sharded + partition/heal is supported");
        p.fault = FaultSchedule::parse("crash@40:1,recover@70:1").unwrap();
        p.validate().expect("sharded + crash/recover is supported");
    }

    #[test]
    fn fault_schedule_parses_and_round_trips() {
        let s = FaultSchedule::parse(
            "crash@40:leader,partition@50:0-2,drop@55:1-3x5,delay@60:0-1x300u80,heal@70,recover@80:2",
        )
        .unwrap();
        assert_eq!(s.incidents.len(), 6);
        assert_eq!(s.incidents[0].at_pct, 40);
        assert_eq!(s.incidents[0].action, FaultAction::Crash { node: None });
        assert_eq!(s.incidents[1].action, FaultAction::PartitionLinks { a: 0, b: 2 });
        assert_eq!(s.incidents[2].action, FaultAction::DropNext { src: 1, dst: 3, count: 5 });
        assert_eq!(
            s.incidents[3].action,
            FaultAction::DelaySpike { src: 0, dst: 1, factor_pct: 300, until_pct: 80 }
        );
        assert_eq!(s.incidents[4].action, FaultAction::HealLinks);
        assert_eq!(s.incidents[5].action, FaultAction::Recover { node: 2 });
        assert!(s.has_link_faults());

        // label() round-trips through parse().
        assert_eq!(FaultSchedule::parse(&s.label()).unwrap(), s);
        assert_eq!(FaultSchedule::parse("none").unwrap(), FaultSchedule::none());
        assert_eq!(FaultSchedule::none().label(), "none");
        assert!(!FaultSchedule::crash_then_recover(1, 30, 60).has_link_faults());

        for bad in [
            "crash@40",          // crash needs a target
            "crash@x:1",         // bad pct
            "partition@50:0",    // missing endpoint
            "heal@70:1",         // heal takes no args
            "drop@30:0-1",       // missing count
            "delay@30:0-1x300",  // missing until
            "explode@10:0",      // unknown kind
        ] {
            assert!(FaultSchedule::parse(bad).is_err(), "{bad} must not parse");
        }
    }

    #[test]
    fn fault_schedule_validation_bounds() {
        let mut c = SimConfig::safardb(WorkloadKind::Micro(RdtKind::Account));
        c.fault = FaultSchedule::parse("crash@40:7").unwrap();
        assert!(c.validate().is_err(), "node out of range for n=4");
        c.fault = FaultSchedule::parse("partition@50:1-1").unwrap();
        assert!(c.validate().is_err(), "self-partition rejected");
        c.fault = FaultSchedule::parse("delay@60:0-1x300u40").unwrap();
        assert!(c.validate().is_err(), "delay window ends before it starts");
        c.fault = FaultSchedule::parse("drop@30:0-1x0").unwrap();
        assert!(c.validate().is_err(), "zero drop count rejected");
        c.fault =
            FaultSchedule::parse("partition@40:1-2,crash@50:leader,heal@70").unwrap();
        c.validate().expect("well-formed multi-fault schedule");

        // kv plumbing.
        let mut k = SimConfig::safardb(WorkloadKind::Micro(RdtKind::Account));
        k.apply_kv("fault = crash@40:0,recover@60:0").unwrap();
        assert_eq!(k.fault, FaultSchedule::crash_then_recover(0, 40, 60));
        assert!(k.apply_kv("fault = crash@40").is_err());
        k.apply_kv("fault = none").unwrap();
        assert!(k.fault.is_empty());
    }

    #[test]
    fn catalog_spec_parses_and_round_trips() {
        let s = CatalogSpec::parse("counter:8,account:4,movie:2").unwrap();
        assert_eq!(s.n_objects(), 14);
        assert_eq!(s.entries[0], (ObjectKind::Rdt(RdtKind::PnCounter), 8));
        assert_eq!(s.entries[2], (ObjectKind::Rdt(RdtKind::Movie), 2));
        // account: 4 groups, movie: 2×2 groups; counters contribute none.
        assert_eq!(s.total_groups(), 8);
        assert_eq!(CatalogSpec::parse(&s.label()).unwrap(), s);

        // Bare names default to one instance; kv tenants are objects too.
        let kv = CatalogSpec::parse("ycsb:2,smallbank,lww").unwrap();
        assert_eq!(kv.n_objects(), 4);
        assert_eq!(kv.total_groups(), 1, "one SmallBank tenant, one group");

        assert_eq!(CatalogSpec::parse("none").unwrap(), CatalogSpec::default());
        assert!(CatalogSpec::parse("").unwrap().is_default());
        assert_eq!(CatalogSpec::default().n_objects(), 1);
        assert_eq!(CatalogSpec::default().label(), "none");

        let mixed = CatalogSpec::parse("mixed").unwrap();
        assert_eq!(mixed, CatalogSpec::mixed());
        assert_eq!(mixed.n_objects(), 9);
        assert_eq!(mixed.total_groups(), 7);
        mixed.validate().expect("mixed spec validates");

        for bad in ["zork:2", "counter:0", "counter:x", "counter:"] {
            assert!(CatalogSpec::parse(bad).is_err(), "{bad} must not parse");
        }
    }

    #[test]
    fn catalog_kv_and_validation() {
        let mut c = SimConfig::safardb(WorkloadKind::Micro(RdtKind::PnCounter));
        assert!(c.objects.is_default(), "default is catalog-of-one");
        assert_eq!(c.n_objects(), 1);
        c.apply_kv("objects = counter:4,account:2\nobj_theta = 0.9").unwrap();
        assert_eq!(c.n_objects(), 6);
        assert!((c.objects.zipf_theta - 0.9).abs() < 1e-12);
        c.validate().expect("catalog config validates");

        // obj_theta survives a later objects= line and vice versa.
        let mut c2 = SimConfig::safardb(WorkloadKind::Micro(RdtKind::PnCounter));
        c2.apply_kv("obj_theta = 0.5").unwrap();
        c2.apply_kv("objects = counter:2").unwrap();
        assert!((c2.objects.zipf_theta - 0.5).abs() < 1e-12);

        // Group cap: auction has 3 groups; 86 instances exceed 255.
        let mut big = SimConfig::safardb(WorkloadKind::Micro(RdtKind::PnCounter));
        big.objects = CatalogSpec::parse("auction:86").unwrap();
        assert!(big.validate().is_err(), "group cap enforced");

        // Hybrid mode is single-store-specific.
        let mut h = SimConfig::safardb(WorkloadKind::Ycsb);
        h.hybrid = Some(HybridConfig::ycsb_default());
        h.objects = CatalogSpec::parse("counter:2").unwrap();
        assert!(h.validate().is_err(), "hybrid + catalog rejected");

        let mut t = SimConfig::safardb(WorkloadKind::Micro(RdtKind::PnCounter));
        t.objects.zipf_theta = 2.5;
        assert!(t.validate().is_err(), "theta bound enforced");
    }

    #[test]
    fn hybrid_validation() {
        let mut c = SimConfig::safardb(WorkloadKind::Ycsb);
        let mut h = HybridConfig::ycsb_default();
        h.fpga_keys = h.total_keys + 1;
        c.hybrid = Some(h);
        assert!(c.validate().is_err());
    }
}
