//! Failure-plane integration (§3 fault model): seeded crash-then-recover
//! property coverage across CRDT and WRDT workloads — the recovered node
//! must converge after snapshot install + committed-log replay — plus a
//! fixed-seed `RunReport` digest pin that guards refactors of the engine's
//! plane decomposition (the digests must stay bit-identical unless a
//! behavioral change is intentional).

use std::fmt::Write as _;

use safardb::config::{ConsensusBackend, FaultSchedule, SimConfig, SystemKind, WorkloadKind};
use safardb::engine::cluster;
use safardb::prop_assert;
use safardb::rdt::RdtKind;
use safardb::util::prop;

#[test]
fn prop_crash_then_recover_converges_across_rdt_classes() {
    prop::check("crash-recover-convergence", 0xf00d, 12, |rng| {
        // Mix of CRDTs (no leader, relaxed-only) and WRDTs (Mu + election).
        let kinds = [
            RdtKind::PnCounter,
            RdtKind::GSet,
            RdtKind::TwoPSet,
            RdtKind::Account,
            RdtKind::Courseware,
            RdtKind::Auction,
        ];
        let rdt = *rng.choose(&kinds);
        let n = 3 + rng.gen_range(4) as usize;
        // A returning *follower* is the §3 recovery story (the leader-crash
        // path is covered without recovery in tests/faults.rs).
        let node = 1 + rng.gen_range(n as u64 - 1) as usize;
        let crash_pct = 20 + rng.gen_range(30) as u8;
        let recover_pct = crash_pct + 10 + rng.gen_range(30) as u8;
        let mut cfg = SimConfig::safardb(WorkloadKind::Micro(rdt));
        cfg.n_replicas = n;
        cfg.update_pct = 25;
        cfg.total_ops = 8_000;
        cfg.fault = FaultSchedule::crash_then_recover(node, crash_pct, recover_pct);
        cfg.seed = rng.next_u64();
        let label = format!("{} n={n} node={node} {crash_pct}->{recover_pct}%", rdt.name());
        let rep = cluster::run(cfg);
        prop_assert!(!rep.crashed[node], "{label}: node must be back");
        prop_assert!(rep.converged(), "{label}: diverged after recover: {:?}", rep.digests);
        prop_assert!(rep.invariants_ok, "{label}: integrity broke after recover");
        Ok(())
    });
}

#[test]
fn kv_workloads_survive_crash_then_recover() {
    for workload in [WorkloadKind::Ycsb, WorkloadKind::SmallBank] {
        let mut cfg = SimConfig::safardb(workload);
        cfg.n_replicas = 4;
        cfg.update_pct = 25;
        cfg.total_ops = 10_000;
        cfg.fault = FaultSchedule::crash_then_recover(2, 30, 60);
        let rep = cluster::run(cfg);
        assert!(!rep.crashed[2], "{workload:?}: node 2 recovered");
        assert!(rep.converged(), "{workload:?}: diverged: {:?}", rep.digests);
        assert!(rep.invariants_ok, "{workload:?}: integrity broke");
    }
}

/// One representative configuration per experiment family (the fig06–fig27
/// config space), all with pinned seeds. Cells avoid Hamband leader
/// crashes: those sample a lognormal permission-switch latency through
/// `f64::ln`/`cos`, which is not bit-stable across platforms; everything
/// else is integer-deterministic.
fn pin_cells() -> Vec<(&'static str, SimConfig)> {
    let mut cells: Vec<(&'static str, SimConfig)> = Vec::new();
    let push = |cells: &mut Vec<(&'static str, SimConfig)>, name, mut cfg: SimConfig, seed| {
        cfg.total_ops = 6_000;
        cfg.update_pct = 20;
        cfg.seed = seed;
        cells.push((name, cfg));
    };

    push(&mut cells, "safardb/pn-counter/rpc", SimConfig::safardb(WorkloadKind::Micro(RdtKind::PnCounter)), 0x5AFA_0001);
    push(
        &mut cells,
        "safardb-baseline/pn-counter",
        SimConfig::safardb_baseline(WorkloadKind::Micro(RdtKind::PnCounter)),
        0x5AFA_0002,
    );
    push(&mut cells, "safardb/account/mu", SimConfig::safardb(WorkloadKind::Micro(RdtKind::Account)), 0x5AFA_0003);
    push(&mut cells, "safardb/auction/3-groups", SimConfig::safardb(WorkloadKind::Micro(RdtKind::Auction)), 0x5AFA_0004);
    push(&mut cells, "hamband/account", SimConfig::hamband(WorkloadKind::Micro(RdtKind::Account)), 0x5AFA_0005);
    push(&mut cells, "safardb/ycsb", SimConfig::safardb(WorkloadKind::Ycsb), 0x5AFA_0006);
    push(&mut cells, "safardb/smallbank", SimConfig::safardb(WorkloadKind::SmallBank), 0x5AFA_0007);
    push(&mut cells, "waverunner/ycsb", SimConfig::waverunner(WorkloadKind::Ycsb), 0x5AFA_0008);

    let mut batched = SimConfig::safardb(WorkloadKind::Micro(RdtKind::Account));
    batched.summarize_threshold = 8;
    push(&mut cells, "safardb/account/summarize-8", batched, 0x5AFA_0009);

    let mut hybrid = SimConfig::safardb(WorkloadKind::Ycsb);
    hybrid.hybrid = Some(safardb::config::HybridConfig::ycsb_default());
    push(&mut cells, "safardb/ycsb/hybrid", hybrid, 0x5AFA_000A);

    let mut leader_crash = SimConfig::safardb(WorkloadKind::Micro(RdtKind::Account));
    leader_crash.n_replicas = 5;
    leader_crash.fault = FaultSchedule::crash_leader_at(40);
    push(&mut cells, "safardb/account/leader-crash", leader_crash, 0x5AFA_000B);

    let mut recover = SimConfig::safardb(WorkloadKind::Micro(RdtKind::TwoPSet));
    recover.fault = FaultSchedule::crash_then_recover(2, 30, 60);
    push(&mut cells, "safardb/2p-set/crash-recover", recover, 0x5AFA_000C);

    // Generic-Raft crash recovery is at Mu/Paxos parity now: pin one
    // fixed-seed raft crash-then-recover run too.
    let mut raft_recover = SimConfig::safardb(WorkloadKind::Micro(RdtKind::Account));
    raft_recover.backend = ConsensusBackend::Raft;
    raft_recover.fault = FaultSchedule::crash_then_recover(2, 30, 60);
    push(&mut cells, "safardb/account/raft-crash-recover", raft_recover, 0x5AFA_000D);

    // Multi-object catalog: a mixed five-object cell (counters, a register,
    // accounts) with skewed object selection — pins the catalog data
    // plane's routing, group flattening, and per-object digesting.
    let mut catalog = SimConfig::safardb(WorkloadKind::Micro(RdtKind::PnCounter));
    catalog.objects =
        safardb::config::CatalogSpec::parse("counter:2,lww:1,account:2").unwrap();
    catalog.objects.zipf_theta = 0.6;
    push(&mut cells, "safardb/catalog/mixed-5", catalog, 0x5AFA_000E);

    assert!(cells.iter().all(|(_, c)| c.system != SystemKind::Hamband || c.fault.is_empty()));
    cells
}

/// Refactor guard: fixed-seed digests (plus the full event count — the
/// most sensitive summary of the event stream) must be reproducible
/// run-to-run, and must match the pinned table in
/// `tests/data/digest_pins.txt` when it exists. On first run (no pin file
/// yet) the table is written there so it can be committed.
#[test]
fn digest_pins_are_stable() {
    let mut table = String::new();
    for (name, cfg) in pin_cells() {
        let a = cluster::run(cfg.clone());
        let b = cluster::run(cfg);
        assert_eq!(a.digests, b.digests, "{name}: nondeterministic digests");
        assert_eq!(a.metrics.events, b.metrics.events, "{name}: nondeterministic event count");
        assert!(a.converged(), "{name}: diverged: {:?}", a.digests);
        writeln!(
            table,
            "{name} digests={:?} events={} completed={}",
            a.digests,
            a.metrics.events,
            a.metrics.total_completed()
        )
        .expect("string write");
    }

    let pin_path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/data/digest_pins.txt");
    match std::fs::read_to_string(&pin_path) {
        Ok(expected) => assert_eq!(
            table, expected,
            "fixed-seed RunReport digests drifted from the pinned values. A pure \
             refactor must keep them bit-identical; if this change is an intentional \
             behavioral fix, delete tests/data/digest_pins.txt, re-run this test to \
             regenerate it, and commit the new file."
        ),
        Err(_) => {
            // Any automated environment must never silently re-baseline: a
            // missing pin file there means the committed guard was deleted
            // (or never landed), and auto-writing would accept whatever the
            // current build produces. SAFARDB_REQUIRE_PINS=1 opts a local
            // run into the same strictness. Outside those, the bootstrap
            // write below exists only because the pin table has not been
            // committed yet (ROADMAP open item: generate once, commit, and
            // this branch becomes dead code).
            let bless =
                std::env::var("SAFARDB_BLESS_PINS").map(|v| v == "1").unwrap_or(false);
            let automated = ["CI", "GITHUB_ACTIONS"]
                .iter()
                .any(|k| std::env::var(k).map(|v| !v.is_empty() && v != "false").unwrap_or(false))
                || std::env::var("SAFARDB_REQUIRE_PINS").map(|v| v == "1").unwrap_or(false);
            if automated && !bless {
                panic!(
                    "tests/data/digest_pins.txt is missing. The committed pin table is \
                     the refactor guard and is never regenerated here; download the \
                     `digest-pins` CI artifact (or run this test once on a dev \
                     machine) and commit the file. Current table:\n{table}"
                );
            }
            if let Some(parent) = pin_path.parent() {
                let _ = std::fs::create_dir_all(parent);
            }
            std::fs::write(&pin_path, &table).expect("write digest pin file");
            eprintln!(
                "digest_pins: ERROR-grade warning: no committed pin table found; wrote \
                 a fresh one to {} — commit it, since an uncommitted table guards \
                 nothing and CI hard-fails without it",
                pin_path.display()
            );
        }
    }
}

// ----- Paxos backend failure coverage ----------------------------------
//
// The APUS-style strong path must survive the same §3 fault model as Mu:
// follower crash-then-recover (snapshot + leader replay), and the harder
// leader-crash cases — mid-quorum crash with re-election, and an
// ex-leader returning as a follower (the donor's leader view installs
// with the snapshot so it cannot come back believing it still leads).

fn paxos_cfg(rdt: safardb::rdt::RdtKind) -> SimConfig {
    let mut cfg = SimConfig::safardb(WorkloadKind::Micro(rdt));
    cfg.backend = ConsensusBackend::Paxos;
    cfg
}

fn raft_cfg(rdt: safardb::rdt::RdtKind) -> SimConfig {
    let mut cfg = SimConfig::safardb(WorkloadKind::Micro(rdt));
    cfg.backend = ConsensusBackend::Raft;
    cfg
}

#[test]
fn paxos_follower_crash_then_recover_converges() {
    for rdt in [RdtKind::Account, RdtKind::Auction] {
        let mut cfg = paxos_cfg(rdt);
        cfg.n_replicas = 4;
        cfg.update_pct = 25;
        cfg.total_ops = 8_000;
        cfg.fault = FaultSchedule::crash_then_recover(2, 30, 60);
        let rep = cluster::run(cfg);
        assert!(!rep.crashed[2], "{}: node 2 must be back", rdt.name());
        assert!(rep.converged(), "{}: diverged: {:?}", rdt.name(), rep.digests);
        assert!(rep.invariants_ok, "{}: integrity broke", rdt.name());
        assert!(rep.metrics.smr_commits > 0, "{}: paxos path unexercised", rdt.name());
    }
}

#[test]
fn paxos_leader_crash_mid_quorum_re_elects() {
    let mut cfg = paxos_cfg(RdtKind::Account);
    cfg.n_replicas = 5;
    cfg.update_pct = 40;
    cfg.total_ops = 12_000;
    cfg.fault = FaultSchedule::crash_leader_at(40);
    let rep = cluster::run(cfg);
    assert!(rep.crashed[0], "initial leader stays down");
    assert_ne!(rep.leader, 0, "a successor leads");
    assert!(rep.metrics.elections >= 1, "re-election happened");
    assert!(rep.converged(), "diverged: {:?}\n{}", rep.digests, rep.dumps.join("\n---\n"));
    assert!(rep.invariants_ok, "integrity broke after leader crash");
    assert!(rep.metrics.smr_commits > 0);
}

#[test]
fn paxos_leader_crash_then_recover_rejoins_as_follower() {
    let mut cfg = paxos_cfg(RdtKind::Account);
    cfg.n_replicas = 4;
    cfg.update_pct = 30;
    cfg.total_ops = 10_000;
    cfg.fault = FaultSchedule::crash_then_recover(0, 30, 60);
    let rep = cluster::run(cfg);
    assert!(!rep.crashed[0], "ex-leader recovered");
    assert_eq!(rep.leader, 1, "leadership stays with the elected successor");
    assert!(rep.metrics.elections >= 1);
    assert!(rep.converged(), "diverged: {:?}\n{}", rep.digests, rep.dumps.join("\n---\n"));
    assert!(rep.invariants_ok, "integrity broke across recovery");
}

// ----- generic-Raft backend failure coverage ---------------------------
//
// The stand-alone Raft backend (`backend = raft` outside Waverunner) is at
// Mu/Paxos parity now: snapshot install rebuilds the follower automaton
// from the mirrored log, recovery replay is term-bumped AppendEntries, and
// `validate()` no longer rejects crash runs. These legs mirror the Paxos
// legs above.

#[test]
fn raft_follower_crash_then_recover_converges() {
    for rdt in [RdtKind::Account, RdtKind::Auction] {
        let mut cfg = raft_cfg(rdt);
        cfg.n_replicas = 4;
        cfg.update_pct = 25;
        cfg.total_ops = 8_000;
        cfg.fault = FaultSchedule::crash_then_recover(2, 30, 60);
        let rep = cluster::run(cfg);
        assert!(!rep.crashed[2], "{}: node 2 must be back", rdt.name());
        assert!(rep.converged(), "{}: diverged: {:?}", rdt.name(), rep.digests);
        assert!(rep.invariants_ok, "{}: integrity broke", rdt.name());
        assert!(rep.metrics.smr_commits > 0, "{}: raft path unexercised", rdt.name());
    }
}

#[test]
fn raft_leader_crash_re_elects_with_term_bumped_replay() {
    let mut cfg = raft_cfg(RdtKind::Account);
    cfg.n_replicas = 5;
    cfg.update_pct = 40;
    cfg.total_ops = 12_000;
    cfg.fault = FaultSchedule::crash_leader_at(40);
    let rep = cluster::run(cfg);
    assert!(rep.crashed[0], "initial leader stays down");
    assert_ne!(rep.leader, 0, "a successor leads");
    assert!(rep.metrics.elections >= 1, "re-election happened");
    assert!(rep.converged(), "diverged: {:?}\n{}", rep.digests, rep.dumps.join("\n---\n"));
    assert!(rep.invariants_ok, "integrity broke after leader crash");
    assert!(rep.metrics.smr_commits > 0);
}

#[test]
fn raft_leader_crash_then_recover_rejoins_as_follower() {
    let mut cfg = raft_cfg(RdtKind::Account);
    cfg.n_replicas = 4;
    cfg.update_pct = 30;
    cfg.total_ops = 10_000;
    cfg.fault = FaultSchedule::crash_then_recover(0, 30, 60);
    let rep = cluster::run(cfg);
    assert!(!rep.crashed[0], "ex-leader recovered");
    assert_eq!(rep.leader, 1, "leadership stays with the elected successor");
    assert!(rep.metrics.elections >= 1);
    assert!(rep.converged(), "diverged: {:?}\n{}", rep.digests, rep.dumps.join("\n---\n"));
    assert!(rep.invariants_ok, "integrity broke across recovery");
}
