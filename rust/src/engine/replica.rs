//! One replica: a thin coordinator over the paper's planes. FPGA card +
//! host (SafarDB), CPU node (Hamband), or SmartNIC node (Waverunner) —
//! selected purely by `SystemParams` and the propagation modes, which pick
//! the [`ReplicationPath`] trait objects serving each RDT category.
//!
//! The coordinator owns the shared [`ReplicaCore`] (data plane, busy clock,
//! token table, leader view) and routes `EventKind`s:
//!
//! * client arrivals  → `engine::client` (slots, quota, request costs),
//!   then by category into a path (`SimConfig::path_for`);
//! * verb deliveries  → the path owning the payload (`Payload::plane`);
//! * completions      → the path owning the token (`TokenCtx`);
//! * timers           → the plane that armed them;
//! * crash/recover    → `engine::failure` (heartbeats, election, snapshot).
//!
//! All latency flows through the fabric and memory models; all state
//! mutation is real and checked by the convergence/integrity tests.

use crate::config::{ReplicationPathKind, SimConfig};
use crate::engine::client::ClientPlane;
use crate::engine::failure::FailurePlane;
use crate::engine::path::{self, ReplicaCore, ReplicationPath, Submission, TokenCtx};
use crate::engine::store::{Catalog, KV_READ};
use crate::engine::Ctx;
use crate::mem::MemKind;
use crate::net::verbs::{Payload, PayloadPlane, ReadData, ReadTarget, Verb, VerbKind};
use crate::rdt::{Category, ObjectId};
use crate::sim::{EventKind, NodeId, Time, TimerKind};
use crate::smr::log::ReplicationLog;
use crate::util::rng::Rng;
use crate::workload::{Placement, WorkItem};

/// Category → path routing, resolved from the config at construction so
/// the hot loop never re-derives it.
#[derive(Clone, Copy, Debug)]
struct PathRoutes {
    reducible: ReplicationPathKind,
    irreducible: ReplicationPathKind,
    conflicting: ReplicationPathKind,
}

impl PathRoutes {
    fn resolve(cfg: &SimConfig) -> Self {
        PathRoutes {
            reducible: cfg.path_for(Category::Reducible),
            irreducible: cfg.path_for(Category::Irreducible),
            conflicting: cfg.path_for(Category::Conflicting),
        }
    }

    fn for_category(&self, category: Category) -> ReplicationPathKind {
        match category {
            Category::Reducible => self.reducible,
            Category::Irreducible => self.irreducible,
            Category::Conflicting => self.conflicting,
        }
    }
}

pub struct Replica {
    core: ReplicaCore,
    client: ClientPlane,
    relaxed: Box<dyn ReplicationPath>,
    strong: Box<dyn ReplicationPath>,
    failure: FailurePlane,
    routes: PathRoutes,
}

impl Replica {
    pub fn new(id: NodeId, cfg: &SimConfig, root_rng: &mut Rng) -> Self {
        let client = ClientPlane::new(cfg);
        let plane = Catalog::for_config(cfg, client.keyspace());
        let groups = plane.total_groups() as usize;
        let rng = root_rng.fork(id as u64 + 1);
        let core = ReplicaCore::new(id, cfg, plane, rng);
        let (relaxed, strong) = path::build_paths(cfg, id, groups);
        Replica {
            core,
            client,
            relaxed,
            strong,
            failure: FailurePlane::new(cfg, id, groups),
            routes: PathRoutes::resolve(cfg),
        }
    }

    // ----- boot ----------------------------------------------------------

    pub fn boot(&mut self, ctx: &mut Ctx, clients: usize, quota: u64) {
        self.client.quota = quota;
        if self.client.is_open() {
            // Open loop: one aggregate arrival stream instead of slot
            // self-arrivals. The first gap is drawn here so the stream is
            // seeded per node; the closed loop must not reach this draw
            // (bit-identity with the pre-open-loop engine).
            if quota > 0 {
                let at =
                    ctx.q.now() + self.client.next_interarrival(&mut self.core.rng, ctx.q.now());
                let epoch = self.client.stream_epoch();
                ctx.q.push(at, self.core.id, EventKind::Arrival { epoch });
                self.client.set_stream_armed(true);
            }
        } else {
            for c in 0..clients {
                ctx.q.push(ctx.q.now(), self.core.id, EventKind::ClientArrive { client: c });
            }
        }
        // Background machinery; `base` desynchronizes replicas. The boot
        // push order (relaxed pollers, strong log pollers, heartbeat
        // scanner, summarize flusher) is part of the deterministic
        // event-stream contract — equal-time events fire in push order.
        let base = self.core.id as u64 * 7;
        self.relaxed.boot(&mut self.core, ctx, base);
        self.strong.boot(&mut self.core, ctx, base);
        self.failure.boot(&self.core, ctx, base);
        self.relaxed.boot_late(&mut self.core, ctx, base);
    }

    // ----- event dispatch ------------------------------------------------

    pub fn handle(&mut self, ctx: &mut Ctx, kind: EventKind) {
        if self.core.crashed && !matches!(kind, EventKind::Recover) {
            return;
        }
        match kind {
            EventKind::ClientArrive { client } => self.on_client(ctx, client),
            EventKind::Arrival { epoch } => self.on_arrival(ctx, epoch),
            EventKind::VerbDeliver { src, verb } => self.on_verb(ctx, src, verb),
            EventKind::AckDeliver { token } => self.on_completion(ctx, token, true),
            EventKind::NackDeliver { token } => self.on_completion(ctx, token, false),
            EventKind::Timer(t) => self.on_timer(ctx, t),
            EventKind::Crash => {
                // Queued-but-unissued admissions die with the node (their
                // logical clients see a connection reset); in-flight ops
                // are killed by the failure plane's reset below. Counting
                // both keeps the offered = completed + shed + killed
                // identity closed across crash schedules.
                ctx.metrics.crash_killed +=
                    self.core.clients_in_flight + self.client.crash_reset();
                self.failure.on_crash(&mut self.core, ctx)
            }
            EventKind::Recover => self.failure.on_recover(&mut self.core, ctx),
            // Link-level fault actions are consumed by the cluster's
            // network actor before dispatch; a replica never sees them.
            EventKind::Fault(_) => {}
        }
    }

    // ----- client path ---------------------------------------------------

    fn on_client(&mut self, ctx: &mut Ctx, client: usize) {
        let now = ctx.q.now();
        if self.client.is_open() {
            // Open loop: a completion freed this service slot — start the
            // oldest queued admission (latency spans its queue wait).
            let Some((item, admitted_at)) = self.client.start_queued(&mut self.core, now) else {
                return; // admission queue empty: the slot idles until the next arrival
            };
            self.process_client_op(ctx, client, item, admitted_at);
            return;
        }
        let Some(item) = self.client.next_op(&mut self.core, now) else {
            return; // quota spent: the slot retires
        };
        self.process_client_op(ctx, client, item, now);
    }

    /// Open-loop arrival-stream tick: offer one op, re-arm the stream
    /// while un-offered quota remains, and admit / queue / shed the
    /// arrival against the service slots. The re-arm draw happens before
    /// workload generation so the RNG interleaving is a fixed function of
    /// the stream, independent of slot occupancy.
    fn on_arrival(&mut self, ctx: &mut Ctx, epoch: u32) {
        if epoch != self.client.stream_epoch() {
            return; // tick from a pre-crash stream incarnation
        }
        let now = ctx.q.now();
        if self.client.quota == 0 {
            self.client.set_stream_armed(false);
            return;
        }
        if self.client.quota > 1 {
            let at = now + self.client.next_interarrival(&mut self.core.rng, now);
            ctx.q.push(at, self.core.id, EventKind::Arrival { epoch });
        } else {
            self.client.set_stream_armed(false);
        }
        if let Some(item) = self.client.admit_arrival(&mut self.core, now) {
            self.process_client_op(ctx, 0, item, now);
        }
    }

    fn process_client_op(&mut self, ctx: &mut Ctx, client: usize, item: WorkItem, arrival: Time) {
        let Replica { core, client: cl, relaxed, strong, failure, routes } = self;

        // A path may own client handling end to end (Waverunner's
        // leader-only Raft service, §5.2).
        if strong.handle_client(core, ctx, &*failure, client, item, arrival) {
            return;
        }

        let ingress = core.exec().client_overhead_ns / 2;
        let sw = core.exec().software_overhead_ns;
        let mut cost = ingress + sw;

        // Hybrid: host-resident keys pay the PCIe hop + host-side costs.
        let host_side = item.placement == Placement::Host;
        if host_side {
            cost += core.sys.mem.pcie_ns; // FPGA ingress -> host handoff
            cost += 120; // host software dispatch
        }

        let op = item.op;
        if op.is_query() || op.opcode == KV_READ {
            if op.is_query() && !core.plane.has_query(op.obj) {
                // Movie has no query() (§5.2): the slot is a pure local
                // no-op that never touches replicated state.
                let done = core.occupy(arrival, cost + core.exec().client_overhead_ns / 2);
                core.complete_client(ctx, client, arrival, done);
                return;
            }
            cost += relaxed.refresh_cost(core) + strong.refresh_cost(core);
            cost += cl.query_read_cost(core, &op, host_side);
            let done = core.occupy(arrival, cost + core.exec().client_overhead_ns / 2);
            core.complete_client(ctx, client, arrival, done);
            return;
        }

        // Update: permissibility precheck at the issuing replica (§2.1).
        cost += relaxed.refresh_cost(core) + strong.refresh_cost(core);
        cost += cl.check_read_cost(core, &op, host_side);
        if !core.plane.permissible(&op) {
            core.note_rejected(&op);
            let done = core.occupy(arrival, cost + core.exec().client_overhead_ns / 2);
            core.complete_client(ctx, client, arrival, done);
            return;
        }

        let category = core.plane.category(op.obj, op.opcode);
        let path: &mut dyn ReplicationPath = match routes.for_category(category) {
            ReplicationPathKind::Relaxed => &mut **relaxed,
            ReplicationPathKind::Strong => &mut **strong,
        };
        path.submit(core, ctx, &*failure, Submission { op, category, host_side, cost, arrival, client });
    }

    // ----- verb arrivals -------------------------------------------------

    fn on_verb(&mut self, ctx: &mut Ctx, src: NodeId, verb: Verb) {
        if let Payload::ReadResp { data, .. } = verb.payload {
            self.on_read_resp(ctx, verb.token, data);
            return;
        }
        let Replica { core, relaxed, strong, failure, .. } = self;
        match verb.payload.plane() {
            PayloadPlane::Relaxed => relaxed.deliver(core, ctx, &*failure, src, verb),
            PayloadPlane::Strong => strong.deliver(core, ctx, &*failure, src, verb),
            PayloadPlane::OneSidedRead => {
                let Payload::ReadReq { target } = verb.payload else { return };
                // One-sided: the NIC answers from the memory of whichever
                // plane owns the target, without involving the app.
                let data = match target {
                    ReadTarget::Heartbeat => ReadData::Heartbeat(failure.hb_counter),
                    _ => strong.serve_read(target).unwrap_or(ReadData::Raw),
                };
                let resp = Verb {
                    kind: VerbKind::Read,
                    dst_mem: MemKind::Hbm,
                    payload: Payload::ReadResp { target, data },
                    token: verb.token,
                    leader_qp: false,
                };
                ctx.metrics.verbs += 1;
                ctx.net.issue(ctx.q, ctx.qps, &core.sys.fabric, ctx.q.now(), core.id, src, resp, false);
            }
            PayloadPlane::Completion | PayloadPlane::None => {}
        }
    }

    // ----- completion routing (token ownership) --------------------------

    fn on_read_resp(&mut self, ctx: &mut Ctx, token: u64, data: ReadData) {
        let Replica { core, strong, failure, .. } = self;
        let Some(tctx) = core.tokens.remove(&token) else { return };
        match tctx {
            TokenCtx::Heartbeat { peer } => {
                if let ReadData::Heartbeat(v) = data {
                    failure.on_heartbeat(core, &mut **strong, ctx, peer, Some(v));
                }
            }
            TokenCtx::Strong(_) | TokenCtx::Paxos(_) => {
                strong.on_read_resp(core, ctx, &*failure, tctx, data)
            }
            TokenCtx::Relaxed { .. } | TokenCtx::Ignore => {}
        }
    }

    fn on_completion(&mut self, ctx: &mut Ctx, token: u64, ok: bool) {
        let Replica { core, relaxed, strong, failure, .. } = self;
        let Some(tctx) = core.tokens.remove(&token) else { return };
        match tctx {
            TokenCtx::Strong(_) | TokenCtx::Paxos(_) => {
                strong.on_completion(core, ctx, &*failure, tctx, ok)
            }
            TokenCtx::Relaxed { .. } => relaxed.on_completion(core, ctx, &*failure, tctx, ok),
            TokenCtx::Heartbeat { peer } => {
                if !ok {
                    failure.on_heartbeat(core, &mut **strong, ctx, peer, None);
                }
            }
            TokenCtx::Ignore => {}
        }
    }

    // ----- timers --------------------------------------------------------

    fn on_timer(&mut self, ctx: &mut Ctx, t: TimerKind) {
        let Replica { core, relaxed, strong, failure, .. } = self;
        match t {
            TimerKind::PollReducible
            | TimerKind::PollIrreducible
            | TimerKind::SummarizeFlush
            | TimerKind::BatchFlush => relaxed.on_timer(core, ctx, &*failure, t),
            TimerKind::PollLog(_) | TimerKind::SmrTick(_) | TimerKind::ForwardCheck { .. } => {
                strong.on_timer(core, ctx, &*failure, t)
            }
            TimerKind::HeartbeatScan => failure.on_scan(core, ctx),
            TimerKind::WorkDone => {}
        }
    }

    // ----- cluster-facing surface ----------------------------------------

    pub fn id(&self) -> NodeId {
        self.core.id
    }

    pub fn crashed(&self) -> bool {
        self.core.crashed
    }

    pub fn leader(&self) -> NodeId {
        self.core.leader
    }

    /// Per-group leader view (len = total sync groups; all equal to
    /// `leader()` under `placement=single`).
    pub fn group_leaders(&self) -> Vec<NodeId> {
        (0..self.core.group_leaders.len()).map(|g| self.core.leader_of(g)).collect()
    }

    pub fn busy_total(&self) -> u64 {
        self.core.busy_total
    }

    pub fn executions(&self) -> u64 {
        self.core.executions
    }

    pub fn rejected(&self) -> u64 {
        self.core.rejected
    }

    pub fn quota(&self) -> u64 {
        self.client.quota
    }

    /// Client slots that consumed quota but have not been responded to.
    pub fn in_flight(&self) -> u64 {
        self.core.clients_in_flight
    }

    /// Open-loop admissions waiting for a service slot (0 when closed).
    pub fn queued_admissions(&self) -> usize {
        self.client.queued()
    }

    /// Ops offered to this node (arrival ticks fired / quota consumed).
    pub fn offered(&self) -> u64 {
        self.client.offered
    }

    /// Open-loop arrivals shed on a full admission queue.
    pub fn shed(&self) -> u64 {
        self.client.shed
    }

    /// Open-loop admission-queue high-water mark.
    pub fn queue_depth_max(&self) -> usize {
        self.client.queue_depth_max
    }

    /// Drain this replica's remaining quota (crash redistribution).
    pub fn take_quota(&mut self) -> u64 {
        std::mem::take(&mut self.client.quota)
    }

    /// Grant extra quota (a crashed peer's redistributed share). Returns
    /// the stream epoch to arm when the grant must re-start this node's
    /// open-loop arrival stream (the stream parked at quota exhaustion, so
    /// nothing else would ever offer the new quota); the cluster owns the
    /// event queue and pushes the `Arrival` tick. `None` for the closed
    /// loop, a still-armed stream, a zero grant, or a crashed node.
    #[must_use]
    pub fn grant_quota(&mut self, extra: u64) -> Option<u32> {
        self.client.quota += extra;
        let rearm =
            extra > 0 && self.client.is_open() && !self.client.stream_armed() && !self.core.crashed;
        if rearm {
            self.client.set_stream_armed(true);
            Some(self.client.stream_epoch())
        } else {
            None
        }
    }

    pub fn digest(&self) -> u64 {
        self.core.plane.state_digest()
    }

    /// Per-object state digests (convergence holds object by object).
    pub fn object_digests(&self) -> Vec<u64> {
        self.core.plane.object_digests()
    }

    /// Per-object applied-op counters (scale-out telemetry).
    pub fn object_applied(&self) -> &[u64] {
        self.core.plane.applied_counts()
    }

    /// Per-object permissibility-rejection counters.
    pub fn object_rejected(&self) -> &[u64] {
        self.core.plane.rejected_counts()
    }

    pub fn invariant_ok(&self) -> bool {
        self.core.plane.invariant_ok()
    }

    /// Human-readable data-plane dump (divergence diagnosis).
    pub fn plane_dump(&self) -> String {
        self.core.plane.debug_dump()
    }

    /// Apply every pending remote item with zero cost — used only at
    /// quiescence so convergence checks see fully-propagated state.
    pub fn flush_all_pending(&mut self) {
        self.relaxed.flush_pending(&mut self.core.plane);
        self.strong.flush_pending(&mut self.core.plane);
    }

    /// Install a recovery snapshot from a live donor (§3): state + logs
    /// replace the stale copies, landed-but-unapplied buffers clear, and
    /// the transfer occupies the replica for a modeled copy time. The
    /// donor's *leader view* installs too — a crashed ex-leader would
    /// otherwise come back believing it still leads and stall against the
    /// cluster's permission fences; adopting the view re-fences its QPs
    /// (a no-op when the views already agree, e.g. follower recovery).
    pub fn install_snapshot(
        &mut self,
        plane: Catalog,
        logs: Vec<ReplicationLog>,
        leader: NodeId,
        group_leaders: Vec<NodeId>,
        relaxed_seen: Vec<(ObjectId, usize, u64)>,
        qps: &mut crate::net::QpTable,
        now: Time,
    ) {
        // The donor's *state* installs; per-object op counters stay this
        // replica's own (they are run telemetry, not replicated state).
        let counts = self.core.plane.op_counts();
        self.core.plane = plane;
        self.core.plane.set_op_counts(counts);
        self.strong.install_logs(logs);
        self.relaxed.clear_landed();
        // Chaos mode: the donor's at-most-once ledger says exactly which
        // relaxed ops its snapshot contains, so retried deliveries landing
        // around the install neither double-apply nor get lost.
        self.relaxed.install_relaxed_seen(relaxed_seen);
        if self.core.placement.is_sharded() {
            // Sharded: adopt the donor's per-group placement wholesale — a
            // recovered ex-leader rejoins as a follower of its former
            // groups (sticky rebalance) — and refence against the full
            // leader set in one pass.
            self.failure.install_placement(&group_leaders);
            self.core.group_leaders = group_leaders;
            self.core.leader = leader;
            qps.refence(self.core.id, &self.core.group_leaders);
        } else if self.core.leader != leader {
            qps.switch_leader(self.core.id, self.core.leader, leader);
            self.core.leader = leader;
        }
        self.core.busy_until = self.core.busy_until.max(now) + 50_000; // 50 µs transfer
        self.core.busy_total += 50_000;
    }

    /// Donor side of the snapshot (state, strong logs, leader views, dedup
    /// ledger).
    pub fn snapshot_state(
        &self,
    ) -> (Catalog, Vec<ReplicationLog>, NodeId, Vec<NodeId>, Vec<(ObjectId, usize, u64)>) {
        (
            self.core.plane.snapshot(),
            self.strong.snapshot_logs(),
            self.core.leader,
            self.group_leaders(),
            self.relaxed.snapshot_relaxed_seen(),
        )
    }

    /// Second-order anti-entropy (chaos harness): re-ship relaxed-path
    /// propagations to `peer`. The cluster calls this on every live
    /// replica when `peer` installs a recovery snapshot (`full = true`:
    /// donor-set union — the donor itself may have missed an update that
    /// is still outstanding somewhere, including ops the peer ACKed before
    /// crashing) and across healed links (`full = false`: only entries
    /// that exhausted their retry budget against the peer).
    pub fn reconcile_relaxed_to(&mut self, ctx: &mut Ctx, peer: NodeId, full: bool) {
        let Replica { core, relaxed, .. } = self;
        relaxed.reconcile_to(core, ctx, peer, full);
    }

    /// Receiver-side re-gossip (chaos harness): re-ship the remote relaxed
    /// ops this replica accepted from `origin` to every peer — called when
    /// `origin` installs a recovery snapshot, since the install wipes the
    /// origin's own retry ledger and its partially-propagated updates then
    /// survive only at their receivers.
    pub fn regossip_from_origin(&mut self, ctx: &mut Ctx, origin: NodeId) {
        let Replica { core, relaxed, failure, .. } = self;
        relaxed.regossip_origin(core, ctx, &*failure, origin);
    }

    /// Heal-time anti-entropy (chaos harness): replay this replica's
    /// strong-path log to a peer the healed partition may have starved.
    /// Called by the cluster on the current leader only.
    pub fn replay_strong_to(&mut self, ctx: &mut Ctx, peer: NodeId) {
        let Replica { core, strong, failure, .. } = self;
        strong.replay_to(core, ctx, &*failure, peer);
    }

    /// Heal-time imposter nudge (chaos harness): if this replica
    /// self-elected inside a partition minority and never confirmed its
    /// leadership, hand it to `rightful` now (a quiescent imposter has no
    /// stalled round to trigger abdication on its own). Sharded placements
    /// resolve per group against the (realigned) placement table and
    /// ignore `rightful`.
    pub fn abdicate_unconfirmed_leadership(&mut self, ctx: &mut Ctx, rightful: NodeId) {
        let Replica { core, strong, failure, .. } = self;
        strong.abdicate_if_unconfirmed(core, ctx, &*failure, rightful);
    }

    /// Heal-time placement realign (chaos harness, sharded placements): a
    /// partition leaves its two endpoints with divergent placement tables —
    /// each mis-declared the other dead and re-placed the other's groups,
    /// possibly onto itself. The cluster installs the authority view (from
    /// a replica that saw both sides stay alive, i.e. the view the
    /// majority's permission fences enforced all along) so the per-group
    /// abdication nudge below resolves every campaign against the same
    /// rightful leaders. Refences this replica's own QP row in one pass.
    pub fn realign_group_leaders(&mut self, leaders: &[NodeId], qps: &mut crate::net::QpTable) {
        self.failure.install_placement(leaders);
        self.core.group_leaders = leaders.to_vec();
        qps.refence(self.core.id, leaders);
    }

    /// Diagnostic snapshot for runaway-loop debugging.
    pub fn debug_status(&self) -> String {
        format!(
            "id={} crashed={} quota={} in_flight={} queued={} offered={} shed={} leader={} {} {} \
             busy_until={}",
            self.core.id,
            self.core.crashed,
            self.client.quota,
            self.core.clients_in_flight,
            self.client.queued(),
            self.client.offered,
            self.client.shed,
            self.core.leader,
            self.relaxed.debug_status(),
            self.strong.debug_status(),
            self.core.busy_until
        )
    }
}
