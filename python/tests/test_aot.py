"""AOT exporter round-trip: artifacts are HLO text, manifest matches the
export table, and the no-serialized-proto rule holds."""

import os

from compile import model
from compile.aot import export_all


def test_export_all_roundtrip(tmp_path):
    lines = export_all(str(tmp_path))
    assert len(lines) == len(model.EXPORTS)
    names = set()
    for line in lines:
        name, ins, outs = line.split(";")
        names.add(name)
        assert ins.startswith("in=") and outs.startswith("out=")
        path = tmp_path / f"{name}.hlo.txt"
        text = path.read_text()
        assert text.startswith("HloModule"), "artifact must be HLO *text*"
        assert "\x00" not in text
    assert names == set(model.EXPORTS)
    manifest = (tmp_path / "manifest.txt").read_text().strip().splitlines()
    assert manifest == lines


def test_manifest_signatures_have_fixed_export_shapes(tmp_path):
    export_all(str(tmp_path))
    manifest = (tmp_path / "manifest.txt").read_text()
    assert f"float32[{model.N_REPLICAS}x{model.K_KEYS}]" in manifest
    assert f"int32[{model.B_BURST}]" in manifest
