//! The replica's data plane: either one micro-benchmark RDT object or a
//! keyed store (YCSB registers / SmallBank accounts), behind a single
//! category-routing interface — the paper's "single replication/consistency
//! interface across FPGA- and host-resident data" (§1, contribution 3).

use crate::config::WorkloadKind;
use crate::rdt::{mix64, mix_f64, Category, OpCall, QueryValue, Rdt, RdtKind};

/// KV opcodes (OpCall.b carries the key).
pub const KV_READ: u8 = 0xFE; // like query() but keyed
pub const KV_WRITE: u8 = 0; // YCSB update / SmallBank deposit  (reducible)
pub const KV_WITHDRAW: u8 = 1; // SmallBank debit (conflicting, overdraft guard)

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvKind {
    /// YCSB: last-writer-wins registers; updates are reducible.
    Ycsb,
    /// SmallBank: accounts with a non-negative-balance invariant; debits
    /// are conflicting (the Fig 11 "drastic drop at 5% updates" is the SMR
    /// engagement this category triggers).
    SmallBank,
}

#[derive(Clone, Debug)]
pub struct KvState {
    pub kind: KvKind,
    values: Vec<f64>,
    versions: Vec<u64>, // LWW timestamps for YCSB convergence
}

impl KvState {
    pub fn new(kind: KvKind, keys: u64) -> Self {
        let init = match kind {
            KvKind::Ycsb => 0.0,
            KvKind::SmallBank => 100.0, // seeded account balances
        };
        KvState {
            kind,
            values: vec![init; keys as usize],
            versions: vec![0; keys as usize],
        }
    }

    pub fn keys(&self) -> u64 {
        self.values.len() as u64
    }

    pub fn value(&self, key: u64) -> f64 {
        self.values[key as usize]
    }

    fn apply(&mut self, op: &OpCall) -> bool {
        let k = op.b as usize;
        match (self.kind, op.opcode) {
            (KvKind::Ycsb, KV_WRITE) => {
                // LWW merge on (timestamp, origin): replicas converge
                // regardless of delivery order.
                let ts = op.a;
                if ts > self.versions[k] {
                    self.versions[k] = ts;
                    self.values[k] = op.x;
                    true
                } else {
                    false
                }
            }
            (KvKind::SmallBank, KV_WRITE) => {
                self.values[k] += op.x; // deposit: commutative add
                true
            }
            (KvKind::SmallBank, KV_WITHDRAW) => {
                if self.values[k] - op.x >= -1e-9 {
                    self.values[k] -= op.x;
                    true
                } else {
                    false
                }
            }
            _ => false,
        }
    }

    fn permissible(&self, op: &OpCall) -> bool {
        match (self.kind, op.opcode) {
            (KvKind::SmallBank, KV_WITHDRAW) => {
                self.values[op.b as usize] - op.x >= -1e-9
            }
            _ => true,
        }
    }

    fn apply_forced(&mut self, op: &OpCall) -> bool {
        match (self.kind, op.opcode) {
            (KvKind::SmallBank, KV_WITHDRAW) => {
                self.values[op.b as usize] -= op.x; // leader-accepted debit
                true
            }
            _ => self.apply(op),
        }
    }

    fn digest(&self) -> u64 {
        let mut acc = 0u64;
        for (k, (&v, &ver)) in self.values.iter().zip(&self.versions).enumerate() {
            // Round to cents: deposit folding order differs across replicas.
            let vq = (v * 100.0).round() / 100.0;
            if vq != 0.0 || ver != 0 {
                acc ^= mix64(k as u64 ^ (ver << 32)).wrapping_mul(mix_f64(vq) | 1);
            }
        }
        acc
    }

    fn invariant_ok(&self) -> bool {
        match self.kind {
            KvKind::Ycsb => true,
            KvKind::SmallBank => self.values.iter().all(|&v| v >= -1e-6),
        }
    }
}

/// The unified data plane.
pub enum DataPlane {
    Micro(Box<dyn Rdt>),
    Kv(KvState),
}

impl DataPlane {
    pub fn for_workload(workload: WorkloadKind, keys: u64) -> Self {
        match workload {
            WorkloadKind::Micro(kind) => DataPlane::Micro(kind.instantiate()),
            WorkloadKind::Ycsb => DataPlane::Kv(KvState::new(KvKind::Ycsb, keys)),
            WorkloadKind::SmallBank => DataPlane::Kv(KvState::new(KvKind::SmallBank, keys)),
        }
    }

    pub fn category(&self, opcode: u8) -> Category {
        match self {
            DataPlane::Micro(r) => r.category(opcode),
            DataPlane::Kv(kv) => match (kv.kind, opcode) {
                (KvKind::SmallBank, KV_WITHDRAW) => Category::Conflicting,
                _ => Category::Reducible,
            },
        }
    }

    pub fn sync_group(&self, opcode: u8) -> u8 {
        match self {
            DataPlane::Micro(r) => r.sync_group(opcode),
            DataPlane::Kv(_) => 0,
        }
    }

    pub fn sync_groups(&self) -> u8 {
        match self {
            DataPlane::Micro(r) => r.sync_groups(),
            DataPlane::Kv(kv) => match kv.kind {
                KvKind::Ycsb => 0,
                KvKind::SmallBank => 1,
            },
        }
    }

    pub fn permissible(&self, op: &OpCall) -> bool {
        match self {
            DataPlane::Micro(r) => r.permissible(op),
            DataPlane::Kv(kv) => kv.permissible(op),
        }
    }

    pub fn apply(&mut self, op: &OpCall) -> bool {
        match self {
            DataPlane::Micro(r) => r.apply(op),
            DataPlane::Kv(kv) => kv.apply(op),
        }
    }

    /// Unconditional application of a leader-committed conflicting op
    /// (see `Rdt::apply_forced`).
    pub fn apply_forced(&mut self, op: &OpCall) -> bool {
        match self {
            DataPlane::Micro(r) => r.apply_forced(op),
            DataPlane::Kv(kv) => kv.apply_forced(op),
        }
    }

    pub fn query(&self, key: u64) -> QueryValue {
        match self {
            DataPlane::Micro(r) => r.query(),
            DataPlane::Kv(kv) => QueryValue::Float(kv.value(key)),
        }
    }

    pub fn has_query(&self) -> bool {
        match self {
            DataPlane::Micro(r) => r.has_query(),
            DataPlane::Kv(_) => true,
        }
    }

    pub fn state_digest(&self) -> u64 {
        match self {
            DataPlane::Micro(r) => r.state_digest(),
            DataPlane::Kv(kv) => kv.digest(),
        }
    }

    pub fn invariant_ok(&self) -> bool {
        match self {
            DataPlane::Micro(r) => r.invariant_ok(),
            DataPlane::Kv(kv) => kv.invariant_ok(),
        }
    }

    /// Type-correct summarization rule for this plane's reducible ops
    /// (see `engine::relaxed::summarize`).
    pub fn summarize_rule(&self) -> crate::engine::relaxed::SummarizeRule {
        use crate::engine::relaxed::SummarizeRule as R;
        match self {
            DataPlane::Micro(r) => match r.kind() {
                RdtKind::GCounter | RdtKind::PnCounter | RdtKind::Account => R::SumDelta,
                RdtKind::LwwRegister => R::LastWrite,
                _ => R::ShipAll,
            },
            DataPlane::Kv(kv) => match kv.kind {
                KvKind::Ycsb => R::LastWrite,
                KvKind::SmallBank => R::SumDelta,
            },
        }
    }

    /// Deep-copy for recovery snapshot transfer.
    pub fn snapshot(&self) -> DataPlane {
        match self {
            DataPlane::Micro(r) => DataPlane::Micro(r.clone_box()),
            DataPlane::Kv(kv) => DataPlane::Kv(kv.clone()),
        }
    }

    pub fn debug_dump(&self) -> String {
        match self {
            DataPlane::Micro(r) => r.debug_dump(),
            DataPlane::Kv(_) => String::new(),
        }
    }

    pub fn micro_kind(&self) -> Option<RdtKind> {
        match self {
            DataPlane::Micro(r) => Some(r.kind()),
            DataPlane::Kv(_) => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ycsb_lww_converges_out_of_order() {
        let mut a = KvState::new(KvKind::Ycsb, 8);
        let mut b = KvState::new(KvKind::Ycsb, 8);
        let mut w1 = OpCall::new(KV_WRITE, 10, 3, 1.5);
        w1.origin = 0;
        let mut w2 = OpCall::new(KV_WRITE, 20, 3, 2.5);
        w2.origin = 1;
        a.apply(&w1);
        a.apply(&w2);
        b.apply(&w2);
        b.apply(&w1);
        assert_eq!(a.value(3), 2.5);
        assert_eq!(a.digest(), b.digest());
    }

    #[test]
    fn smallbank_withdraw_guard() {
        let mut kv = KvState::new(KvKind::SmallBank, 4);
        let w = OpCall::new(KV_WITHDRAW, 0, 2, 150.0);
        assert!(!kv.permissible(&w), "balance 100 < 150");
        assert!(!kv.apply(&w));
        assert!(kv.invariant_ok());
        let d = OpCall::new(KV_WRITE, 0, 2, 75.0);
        kv.apply(&d);
        assert!(kv.apply(&w));
        assert!((kv.value(2) - 25.0).abs() < 1e-9);
    }

    #[test]
    fn dataplane_category_routing() {
        let sb = DataPlane::for_workload(WorkloadKind::SmallBank, 16);
        assert_eq!(sb.category(KV_WITHDRAW), Category::Conflicting);
        assert_eq!(sb.category(KV_WRITE), Category::Reducible);
        assert_eq!(sb.sync_groups(), 1);
        let y = DataPlane::for_workload(WorkloadKind::Ycsb, 16);
        assert_eq!(y.category(KV_WRITE), Category::Reducible);
        assert_eq!(y.sync_groups(), 0);
    }

    #[test]
    fn micro_plane_delegates() {
        let mut p = DataPlane::for_workload(WorkloadKind::Micro(RdtKind::PnCounter), 0);
        let op = OpCall::new(0, 5, 0, 0.0);
        assert!(p.permissible(&op));
        p.apply(&op);
        assert_eq!(p.query(0), QueryValue::Int(5));
        assert!(p.invariant_ok());
    }
}
