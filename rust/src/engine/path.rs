//! The pluggable replication-path layer (§3–§4, Design Principle #3).
//!
//! The paper's replication engine is plane-structured: a *relaxed* path for
//! the reducible/irreducible RDT categories (landing zones + summarizer,
//! §4.1–§4.2), a *strongly-ordered* path for conflicting categories (Mu
//! SMR, or Raft for the Waverunner baseline, §4.3–§4.4), and a
//! leader-switch/failure plane that owns membership. [`ReplicationPath`] is
//! the seam between them: category routing comes in (as a [`Submission`]),
//! verbs and completion tokens go out. `SimConfig::path_for` decides which
//! path serves each category and [`build_paths`] turns a config into the
//! two trait objects the replica coordinator owns — adding a new consensus
//! backend means implementing this trait, not editing a god-struct.

use crate::config::{ExecParams, LeaderPlacement, SimConfig, SystemKind, SystemParams};
use crate::engine::store::Catalog;
use crate::engine::Ctx;
use crate::mem::MemKind;
use crate::net::verbs::{Payload, ReadData, ReadTarget, Verb};
use crate::rdt::{Category, ObjectId, OpCall};
use crate::sim::{EventKind, NodeId, Time, TimerKind};
use crate::smr::log::ReplicationLog;
use crate::util::hasher::FastMap;
use crate::util::rng::Rng;
use crate::workload::WorkItem;

use crate::config::ConsensusBackend;
use crate::engine::paxos::PaxosToken;
use crate::engine::strong::StrongToken;

/// Completion-token bookkeeping: which plane owns an outstanding verb.
/// The tokens themselves live next to the plane that consumes them
/// ([`StrongToken`] in `engine::strong`, [`PaxosToken`] in
/// `engine::paxos`; heartbeat tokens belong to the failure plane); this
/// enum is only the routing envelope the coordinator dispatches on.
#[derive(Clone, Copy, Debug)]
pub enum TokenCtx {
    /// Owned by the strongly-ordered path (Mu rounds, leader forwards).
    Strong(StrongToken),
    /// Owned by the Paxos strong path (doorbell-acked appends, forwards).
    Paxos(PaxosToken),
    /// Owned by the relaxed path's chaos-mode reliable fan-out: `id` keys
    /// the retry entry that re-ships a propagation NACKed by a faulty link.
    Relaxed { id: u64 },
    /// Heartbeat read of a peer (failure plane).
    Heartbeat { peer: NodeId },
    /// Fire-and-forget — no completion expected, so never stored in the
    /// token map (keeps it from growing with every relaxed fan-out).
    Ignore,
}

/// A client request in flight at its origin replica while its conflicting
/// op is forwarded to (and retried against) the strong-path leader. Shared
/// by every consensus backend.
#[derive(Clone, Copy, Debug)]
pub struct PendingClient {
    pub client: usize,
    pub arrival: Time,
    pub retries: u8,
    pub op: OpCall,
}

/// Leader side: who to answer once a conflicting op commits.
#[derive(Clone, Copy, Debug)]
pub enum Requester {
    Local { client: usize, arrival: Time },
    Remote { reply_to: NodeId, request_id: u64 },
}

/// A locally admitted update op handed to a replication path, carrying the
/// request-side cost accumulated so far (ingress, software dispatch,
/// refresh fold, permissibility read).
#[derive(Clone, Copy, Debug)]
pub struct Submission {
    pub op: OpCall,
    pub category: Category,
    /// Hybrid mode: the op's key lives in host memory behind PCIe.
    pub host_side: bool,
    /// Pre-costs to charge together with the local apply.
    pub cost: u64,
    pub arrival: Time,
    pub client: usize,
}

/// Membership changes the failure plane reports into the paths.
#[derive(Clone, Copy, Debug)]
pub enum MembershipEvent {
    /// A non-leader peer crossed the failure threshold (observer leads).
    PeerFailed { peer: NodeId },
    /// A failed peer's heartbeat resumed (observer leads).
    PeerRecovered { peer: NodeId },
    /// The permission switch completed; `core.leader` holds the new view.
    LeaderSwitched,
    /// Sharded placement only: the per-group leader table changed
    /// (`core.group_leaders` holds the new view). Paths diff the view
    /// against their own tracked assignment to find groups they gained or
    /// lost — the event carries no group list so it stays `Copy`.
    GroupLeadersChanged,
}

/// Read-only membership view the failure plane exposes to the paths.
pub trait Membership {
    /// Live replicas as this replica sees them (self always included).
    fn live_set(&self) -> Vec<NodeId>;
    /// Live peers (self excluded) — the fan-out set.
    fn live_peers(&self, me: NodeId) -> Vec<NodeId>;
    /// Election rule: the live replica with the smallest ID (§4.4).
    fn elect_leader(&self) -> NodeId;
}

/// One replication path: a plane that turns admitted ops into verbs and
/// completions back into client responses. Implemented by the relaxed
/// plane (`engine::relaxed`) and the strongly-ordered plane
/// (`engine::strong`); the failure plane is the coordinator of membership,
/// not a path.
pub trait ReplicationPath: Send {
    /// Arm background timers at boot (`base` desynchronizes replicas).
    fn boot(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, base: u64);

    /// Second boot wave — timers that arm after the heartbeat scanner
    /// (boot push order is part of the deterministic event-stream
    /// contract).
    fn boot_late(&mut self, _core: &mut ReplicaCore, _ctx: &mut Ctx, _base: u64) {}

    /// Cost of refreshing visible state before a query/permissibility
    /// check under this path's propagation mode (Design Principle #2).
    fn refresh_cost(&mut self, core: &mut ReplicaCore) -> u64;

    /// Full client-request takeover. Waverunner's Raft path serves/redirects
    /// every client op itself (§5.2); everyone else returns false and the
    /// standard category-routed flow applies.
    fn handle_client(
        &mut self,
        _core: &mut ReplicaCore,
        _ctx: &mut Ctx,
        _mb: &dyn Membership,
        _client: usize,
        _item: WorkItem,
        _arrival: Time,
    ) -> bool {
        false
    }

    /// Route a locally admitted update into this path.
    fn submit(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, sub: Submission);

    /// An arriving verb whose payload this path owns.
    fn deliver(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, src: NodeId, verb: Verb);

    /// ACK/NACK for a token this path owns.
    fn on_completion(
        &mut self,
        _core: &mut ReplicaCore,
        _ctx: &mut Ctx,
        _mb: &dyn Membership,
        _token: TokenCtx,
        _ok: bool,
    ) {
    }

    /// Read response for a token this path owns.
    fn on_read_resp(
        &mut self,
        _core: &mut ReplicaCore,
        _ctx: &mut Ctx,
        _mb: &dyn Membership,
        _token: TokenCtx,
        _data: ReadData,
    ) {
    }

    /// One of this path's timers fired.
    fn on_timer(&mut self, core: &mut ReplicaCore, ctx: &mut Ctx, mb: &dyn Membership, t: TimerKind);

    /// Answer a one-sided read of path-owned state (the NIC answers from
    /// memory without the app).
    fn serve_read(&self, _target: ReadTarget) -> Option<ReadData> {
        None
    }

    /// Membership change reported by the failure plane.
    fn on_membership(&mut self, _core: &mut ReplicaCore, _ctx: &mut Ctx, _mb: &dyn Membership, _ev: MembershipEvent) {}

    /// Zero-cost apply of landed-but-unapplied state at quiescence, so
    /// convergence checks see fully-propagated replicas.
    fn flush_pending(&mut self, plane: &mut Catalog);

    /// Drop landed-but-unapplied buffers (snapshot install replaces state).
    fn clear_landed(&mut self) {}

    /// Committed-log snapshot for recovery transfer (strong path only).
    fn snapshot_logs(&self) -> Vec<ReplicationLog> {
        Vec::new()
    }

    /// Install a committed-log snapshot (strong path only).
    fn install_logs(&mut self, _logs: Vec<ReplicationLog>) {}

    /// At-most-once dedup ledger for the chaos-mode relaxed path: which
    /// `(object, origin, seq)` ops the donor's snapshot already folded in.
    /// Empty outside link-fault runs.
    fn snapshot_relaxed_seen(&self) -> Vec<(ObjectId, usize, u64)> {
        Vec::new()
    }

    /// Install the donor's dedup ledger alongside its state snapshot.
    fn install_relaxed_seen(&mut self, _seen: Vec<(ObjectId, usize, u64)>) {}

    /// Second-order anti-entropy (chaos harness): re-arm any relaxed-path
    /// propagations to `peer` that exhausted their retry budget while the
    /// peer was unreachable. Called on every live replica when `peer`
    /// installs a recovery snapshot (with `full = true`: the peer's state
    /// is one donor's, so every propagation still outstanding against
    /// *any* replica may be missing there and is re-shipped as a copy —
    /// the donor-set union) and across healed links (`full = false`: the
    /// peer kept its state; only entries parked for it matter). Default
    /// no-op for paths without tracked fan-out.
    fn reconcile_to(&mut self, _core: &mut ReplicaCore, _ctx: &mut Ctx, _peer: NodeId, _full: bool) {}

    /// Receiver-side re-gossip (chaos harness): re-ship every remote
    /// relaxed op this replica accepted that originated at `origin` —
    /// called when `origin` installs a recovery snapshot, because the
    /// install wipes the origin's own retry/parked ledgers and a
    /// partially-propagated update then survives only at its receivers.
    /// Default no-op for paths without relaxed propagation.
    fn regossip_origin(&mut self, _core: &mut ReplicaCore, _ctx: &mut Ctx, _mb: &dyn Membership, _origin: NodeId) {}

    /// Anti-entropy: replay this path's committed log to one peer (leader
    /// side, after a heal or recovery re-included the peer). Default no-op
    /// for paths without a log.
    fn replay_to(&mut self, _core: &mut ReplicaCore, _ctx: &mut Ctx, _mb: &dyn Membership, _peer: NodeId) {}

    /// Heal-time nudge for a partition-minority imposter: if this path
    /// self-elected but never confirmed its leadership (no Prepare quorum /
    /// lease), hand leadership to `rightful` and re-route anything parked.
    /// Confirmed leaderships ignore the nudge — a majority already backs
    /// them. Sharded placements resolve per shard against the placement
    /// table (`core.leader_of`, realigned by the cluster before the nudge)
    /// and ignore `rightful`. Default no-op.
    fn abdicate_if_unconfirmed(&mut self, _core: &mut ReplicaCore, _ctx: &mut Ctx, _mb: &dyn Membership, _rightful: NodeId) {}

    /// One-line diagnostic fragment for runaway-loop debugging.
    fn debug_status(&self) -> String {
        String::new()
    }
}

/// Build the two replication paths a configuration selects: the relaxed
/// plane parameterized by the reducible/irreducible propagation modes, and
/// the strongly-ordered plane picked by the consensus backend — Mu/Raft
/// share `StrongPath`, APUS-style Paxos is its own `ReplicationPath` impl
/// (the trait boundary is the extension point, not a god-struct edit).
pub fn build_paths(
    cfg: &SimConfig,
    id: NodeId,
    groups: usize,
) -> (Box<dyn ReplicationPath>, Box<dyn ReplicationPath>) {
    let strong: Box<dyn ReplicationPath> = match cfg.backend {
        ConsensusBackend::Paxos => Box::new(crate::engine::paxos::PaxosPath::new(cfg, id, groups)),
        ConsensusBackend::Mu | ConsensusBackend::Raft => {
            Box::new(crate::engine::strong::StrongPath::new(cfg, id, groups))
        }
    };
    (Box::new(crate::engine::relaxed::RelaxedPath::new(cfg)), strong)
}

/// State shared by every plane: identity, cost models, the data plane, the
/// busy clock, the completion-token table, and the leader view. Handed by
/// the coordinator into every plane call, so planes stay borrow-disjoint.
pub struct ReplicaCore {
    pub id: NodeId,
    pub n: usize,
    pub sys: SystemParams,
    pub system: SystemKind,
    pub summarize_threshold: u32,
    pub poll_interval_ns: u64,
    pub heartbeat_period_ns: u64,

    pub plane: Catalog,
    pub crashed: bool,
    pub busy_until: Time,
    pub busy_total: u64,

    /// Every other replica, live or not (heartbeat scan targets).
    /// Precomputed once — the heartbeat scanner and the chaos-mode fan-out
    /// walk this every tick, and membership (`n`) never changes mid-run
    /// (§Perf: was a fresh `Vec` per call on the hot path).
    pub peers: Vec<NodeId>,

    /// Shared deterministic stream (workload generation + latency samples).
    pub rng: Rng,

    /// This replica's view of who leads (maintained by the failure plane).
    /// Under sharded placement this is the classic *anchor* view (the
    /// smallest-live-ID rule, kept for reporting and the heal machinery);
    /// per-group authority lives in `group_leaders`.
    pub leader: NodeId,

    /// Strong-plane leadership placement policy (`single` = classic
    /// one-leader mode, bit-identical to the pre-sharding engine).
    pub placement: LeaderPlacement,

    /// Per-global-sync-group leader view (len = `Catalog::total_groups()`),
    /// maintained by the failure plane's placement table. Never consulted
    /// under `placement = single` — `leader_of` returns `leader` there.
    pub group_leaders: Vec<NodeId>,

    /// Client slots that consumed quota but have not been responded to yet
    /// (drives the cluster's drain-flag flip).
    pub clients_in_flight: u64,

    next_token: u64,
    pub tokens: FastMap<u64, TokenCtx>,

    pub executions: u64,
    pub rejected: u64,
}

impl ReplicaCore {
    pub fn new(id: NodeId, cfg: &SimConfig, plane: Catalog, rng: Rng) -> Self {
        // Boot-time per-group leader view (deterministic, RNG-free: the
        // placement table must never consume a draw from the shared
        // stream, or `placement = single` would stop being bit-identical).
        let groups = plane.total_groups() as usize;
        let group_leaders = crate::smr::election::PlacementTable::new(
            cfg.placement,
            groups,
            cfg.n_replicas,
        )
        .leaders()
        .to_vec();
        ReplicaCore {
            id,
            n: cfg.n_replicas,
            sys: cfg.system.params_for(cfg),
            system: cfg.system,
            summarize_threshold: cfg.summarize_threshold,
            poll_interval_ns: cfg.poll_interval_ns,
            heartbeat_period_ns: cfg.heartbeat_period_ns,
            plane,
            crashed: false,
            busy_until: 0,
            busy_total: 0,
            peers: (0..cfg.n_replicas).filter(|&i| i != id).collect(),
            rng,
            leader: 0,
            placement: cfg.placement,
            group_leaders,
            clients_in_flight: 0,
            next_token: (id as u64) << 48,
            tokens: FastMap::default(),
            executions: 0,
            rejected: 0,
        }
    }

    pub fn exec(&self) -> &ExecParams {
        &self.sys.exec
    }

    pub fn is_leader(&self) -> bool {
        self.id == self.leader
    }

    /// Leader of global sync group `g`. Under `placement = single` every
    /// group resolves to the classic single leader view, so callers can
    /// use this unconditionally without changing unsharded behavior.
    pub fn leader_of(&self, g: usize) -> NodeId {
        if self.placement.is_sharded() {
            self.group_leaders[g]
        } else {
            self.leader
        }
    }

    pub fn is_leader_of(&self, g: usize) -> bool {
        self.id == self.leader_of(g)
    }

    /// Leader responsible for `op` (its object's global sync group).
    pub fn leader_for_op(&self, op: &OpCall) -> NodeId {
        if self.placement.is_sharded() {
            self.group_leaders[self.plane.global_group(op) as usize]
        } else {
            self.leader
        }
    }

    pub fn leads_op(&self, op: &OpCall) -> bool {
        self.id == self.leader_for_op(op)
    }

    /// Does this replica lead anything — the cluster (single) or at least
    /// one group (sharded)? Gates leader-only bookkeeping like membership
    /// trimming and recovery replay.
    pub fn leads_any(&self) -> bool {
        if self.placement.is_sharded() {
            self.group_leaders.contains(&self.id)
        } else {
            self.is_leader()
        }
    }

    /// Advance the local busy clock by `cost` starting no earlier than `at`.
    /// Returns the completion time.
    pub fn occupy(&mut self, at: Time, cost: u64) -> Time {
        let start = at.max(self.busy_until);
        self.busy_until = start + cost;
        self.busy_total += cost;
        self.busy_until
    }

    /// Batched work: `items` per-item increments charged as one occupancy
    /// window — the per-path coalescer's cost model. k submissions sharing
    /// one wire verb pay their verb-issue/setup cost once (charged by the
    /// single `fan_out` call that follows); only the per-item term (memory
    /// reads, entry appends) scales with the batch.
    pub fn occupy_batch(&mut self, at: Time, per_item: u64, items: usize) -> Time {
        self.occupy(at, per_item * items as u64)
    }

    /// State read cost of the local object (own state is warm).
    pub fn warm_read_ns(&self) -> u64 {
        match self.exec().state_mem {
            MemKind::HostDram => self.sys.mem.cache_hit_ns,
            k => self.sys.mem.local_read_ns(k),
        }
    }

    /// Landing-zone memory kind for write-propagated items.
    pub fn landing_mem(&self) -> MemKind {
        match self.exec().state_mem {
            MemKind::HostDram => MemKind::HostDram,
            _ => MemKind::Hbm,
        }
    }

    /// Peers run the same system; their landing zone mirrors ours.
    pub fn landing_mem_for_peer(&self) -> MemKind {
        self.landing_mem()
    }

    pub fn write_state_cost(&self, host_side: bool) -> u64 {
        if host_side {
            self.sys.mem.dram_ns + self.sys.mem.pcie_ns
        } else {
            self.sys.mem.local_write_ns(self.exec().state_mem)
        }
    }

    pub fn apply_remote(&mut self, op: &OpCall) {
        self.executions += 1;
        self.plane.apply(op);
    }

    /// Batched remote apply (§Perf): fold a whole op run through the
    /// columnar [`Catalog::apply_batch`] kernel. Counters advance exactly
    /// as `ops.len()` calls to [`ReplicaCore::apply_remote`] would.
    pub fn apply_remote_batch(&mut self, ops: &[OpCall]) {
        self.executions += ops.len() as u64;
        self.plane.apply_batch(ops);
    }

    /// Record a permissibility rejection: the run-level counter plus the
    /// op's per-object telemetry.
    pub fn note_rejected(&mut self, op: &OpCall) {
        self.rejected += 1;
        self.plane.note_rejected(op);
    }

    /// Allocate a completion token. `Ignore` tokens still consume a number
    /// (verbs carry them on the wire) but are not stored — no completion
    /// will ever look them up.
    pub fn token(&mut self, ctx: TokenCtx) -> u64 {
        let t = self.next_token;
        self.next_token += 1;
        if !matches!(ctx, TokenCtx::Ignore) {
            self.tokens.insert(t, ctx);
        }
        t
    }

    /// Fire-and-forget `SyncRequest` to `leader`: "replay your committed
    /// log to me". Sent after a permission switch, on abdication, and when
    /// a slot-addressed append reveals a gap — the one anti-entropy pull
    /// shared by every strong backend.
    pub fn request_sync(&mut self, ctx: &mut Ctx, leader: NodeId) {
        let tok = self.token(TokenCtx::Ignore);
        let verb = Verb::write(
            self.landing_mem_for_peer(),
            Payload::SyncRequest { from: self.id },
            tok,
        );
        ctx.metrics.verbs += 1;
        ctx.net.issue(ctx.q, ctx.qps, &self.sys.fabric, ctx.q.now(), self.id, leader, verb, false);
    }

    /// Arm the chaos-mode reply watchdog for a pending forward (callers
    /// gate on their chaos flag): if the leader's reply is lost on a
    /// faulty link, the `ForwardCheck` timer re-forwards.
    pub fn arm_forward_watchdog(&self, ctx: &mut Ctx, request_id: u64) {
        let at = ctx.q.now() + self.heartbeat_period_ns * 8;
        ctx.q.push(at, self.id, EventKind::Timer(TimerKind::ForwardCheck { request_id }));
    }

    /// Host-issued verbs pay an extra PCIe hop before the NIC.
    pub fn charge_pcie_hop(&mut self, now: Time) {
        let pcie = self.sys.mem.pcie_ns;
        self.busy_total += pcie;
        self.busy_until = self.busy_until.max(now) + pcie;
    }

    /// Respond to a client slot: record metrics and re-arm the closed loop.
    pub fn complete_client(&mut self, ctx: &mut Ctx, client: usize, arrival: Time, done: Time) {
        ctx.metrics.response.record(done - arrival);
        ctx.metrics.completed[self.id] += 1;
        ctx.metrics.completed_sum += 1;
        ctx.metrics.last_completion_ns = ctx.metrics.last_completion_ns.max(done);
        // Saturating: a slot that died in a crash may see a stale reply
        // after recovery (its in-flight count was reset at crash time).
        self.clients_in_flight = self.clients_in_flight.saturating_sub(1);
        ctx.q.push(done, self.id, EventKind::ClientArrive { client });
    }

    /// Send one verb to every peer in `peers`, serializing initiator-side
    /// costs (Hamband's CQE wait makes this expensive; SafarDB pipelines —
    /// and `SimConfig::window` extends that pipelining across whole
    /// consensus rounds, not just the verbs within one fan-out).
    pub fn fan_out(
        &mut self,
        ctx: &mut Ctx,
        peers: &[NodeId],
        make: impl Fn(u64) -> Verb,
        want_completion: bool,
        ctx_of: impl Fn() -> TokenCtx,
    ) {
        let start = ctx.q.now().max(self.busy_until);
        let mut cursor = start;
        for &dst in peers {
            let tok = self.token(ctx_of());
            let verb = make(tok);
            ctx.metrics.verbs += 1;
            let out = ctx.net.issue(ctx.q, ctx.qps, &self.sys.fabric, cursor, self.id, dst, verb, want_completion);
            cursor = out.initiator_free_at;
        }
        // Initiator-side verb-issue time is real busy time on the replica
        // (the Hamband CQE serialization shows up exactly here).
        self.busy_total += cursor - start;
        self.busy_until = cursor;
    }
}
