//! Shared experiment plumbing: cell runners, sweep axes, result output.

use std::path::Path;

use crate::config::SimConfig;
use crate::engine::cluster::{self, RunReport};
use crate::util::table::Table;

/// Paper sweep axes (§5.1: 3–8 nodes, 15/20/25 % updates; 4M ops scaled).
pub const NODE_SWEEP: &[usize] = &[3, 4, 5, 6, 7, 8];
pub const NODE_SWEEP_QUICK: &[usize] = &[3, 5, 8];
pub const UPDATE_SWEEP: &[u8] = &[15, 20, 25];

pub fn nodes(quick: bool) -> &'static [usize] {
    if quick {
        NODE_SWEEP_QUICK
    } else {
        NODE_SWEEP
    }
}

/// Ops per cell: the paper runs 4M per experiment; the simulator preserves
/// shape at far smaller counts (documented in EXPERIMENTS.md).
pub fn cell_ops(quick: bool) -> u64 {
    if quick {
        24_000
    } else {
        96_000
    }
}

/// One measured cell.
#[derive(Clone, Copy, Debug)]
pub struct Cell {
    pub rt_us: f64,
    pub tput: f64,
}

/// Run one configuration and sanity-check it (convergence + integrity are
/// hard requirements of every experiment, not just the tests).
pub fn run_cell(mut cfg: SimConfig, ops: u64) -> (Cell, RunReport) {
    cfg.total_ops = ops;
    let label = format!(
        "{}/{} n={} upd={}%",
        cfg.system.name(),
        cfg.workload.name(),
        cfg.n_replicas,
        cfg.update_pct
    );
    let rep = cluster::run(cfg);
    assert!(rep.converged(), "experiment cell diverged: {label} digests={:?}", rep.digests);
    assert!(rep.invariants_ok, "experiment cell violated integrity: {label}");
    (Cell { rt_us: rep.response_us(), tput: rep.throughput() }, rep)
}

pub fn f3(v: f64) -> String {
    format!("{v:.3}")
}

pub fn f2(v: f64) -> String {
    format!("{v:.2}")
}

/// Write tables as CSV under `results/` (one file per table).
pub fn save(tables: &[Table], id: &str) {
    let dir = Path::new("results");
    let _ = std::fs::create_dir_all(dir);
    for (i, t) in tables.iter().enumerate() {
        let name = if tables.len() == 1 {
            format!("{id}.csv")
        } else {
            format!("{id}_{i}.csv")
        };
        let _ = std::fs::write(dir.join(name), t.to_csv());
    }
}

/// Geometric-mean ratio of two series (the paper's "X× lower/higher").
pub fn geomean_ratio(nums: &[f64], dens: &[f64]) -> f64 {
    assert_eq!(nums.len(), dens.len());
    let log_sum: f64 = nums
        .iter()
        .zip(dens)
        .filter(|(n, d)| **n > 0.0 && **d > 0.0)
        .map(|(n, d)| (n / d).ln())
        .sum();
    (log_sum / nums.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_ratio_basics() {
        assert!((geomean_ratio(&[2.0, 8.0], &[1.0, 2.0]) - (2.0f64 * 4.0).sqrt()).abs() < 1e-9);
    }
}
