//! Latency statistics: an HDR-style log-bucketed histogram (ns resolution,
//! ~1.6% relative error) plus simple summary accumulators. Used for the
//! paper's response-time metrics and the Fig 13 permission-switch
//! histograms.

/// Log-bucketed histogram over u64 nanosecond values.
///
/// Buckets: 64 magnitude groups × `SUB` linear sub-buckets, i.e. values are
/// recorded with a relative error of at most 1/SUB.
#[derive(Clone, Debug)]
pub struct Histogram {
    counts: Vec<u64>,
    total: u64,
    sum: u128,
    min: u64,
    max: u64,
}

const SUB_BITS: u32 = 6; // 64 sub-buckets => <= 1.6% relative error
const SUB: u64 = 1 << SUB_BITS;

#[inline]
fn bucket_of(v: u64) -> usize {
    if v < SUB {
        return v as usize;
    }
    let mag = 63 - v.leading_zeros(); // >= SUB_BITS
    let shift = mag - SUB_BITS;
    let sub = (v >> shift) & (SUB - 1);
    (((mag - SUB_BITS + 1) as u64 * SUB) + sub) as usize
}

#[inline]
fn bucket_value(idx: usize) -> u64 {
    let idx = idx as u64;
    if idx < SUB {
        return idx;
    }
    let group = (idx / SUB) - 1;
    let sub = idx % SUB;
    // Midpoint of the bucket range for low reconstruction bias.
    let base = (SUB + sub) << group;
    let width = 1u64 << group;
    base + width / 2
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            counts: vec![0; (SUB as usize) * 60],
            total: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }

    pub fn record(&mut self, v: u64) {
        let idx = bucket_of(v).min(self.counts.len() - 1);
        self.counts[idx] += 1;
        self.total += 1;
        self.sum += v as u128;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn record_n(&mut self, v: u64, n: u64) {
        if n == 0 {
            return;
        }
        let idx = bucket_of(v).min(self.counts.len() - 1);
        self.counts[idx] += n;
        self.total += n;
        self.sum += v as u128 * n as u128;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        self.sum as f64 / self.total as f64
    }

    pub fn min(&self) -> u64 {
        if self.total == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    /// Value at quantile `q` in `[0, 1]`.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let target = ((q * self.total as f64).ceil() as u64).clamp(1, self.total);
        let mut seen = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return bucket_value(i).clamp(self.min, self.max.max(self.min));
            }
        }
        self.max
    }

    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> u64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }

    /// Non-empty (bucket midpoint, count) pairs — the Fig 13 histogram series.
    pub fn nonzero_buckets(&self) -> Vec<(u64, u64)> {
        self.counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (bucket_value(i), c))
            .collect()
    }
}

/// Streaming mean/variance (Welford).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_small_values_exact() {
        let mut h = Histogram::new();
        for v in [0u64, 1, 5, 17, 24, 63] {
            h.record(v);
        }
        assert_eq!(h.count(), 6);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 63);
        // values < 64 land in exact buckets
        let buckets = h.nonzero_buckets();
        let vals: Vec<u64> = buckets.iter().map(|&(v, _)| v).collect();
        assert_eq!(vals, vec![0, 1, 5, 17, 24, 63]);
    }

    #[test]
    fn histogram_relative_error_bounded() {
        let mut h = Histogram::new();
        for v in [1_000u64, 250_000, 2_000_000, 300_000_000] {
            h.record(v);
        }
        for &(mid, _) in &h.nonzero_buckets() {
            let nearest = [1_000u64, 250_000, 2_000_000, 300_000_000]
                .iter()
                .copied()
                .min_by_key(|&x| x.abs_diff(mid))
                .unwrap();
            let err = mid.abs_diff(nearest) as f64 / nearest as f64;
            assert!(err < 0.02, "mid={mid} nearest={nearest} err={err}");
        }
    }

    #[test]
    fn histogram_quantiles_monotone() {
        let mut h = Histogram::new();
        for i in 1..=10_000u64 {
            h.record(i * 10);
        }
        let p50 = h.p50();
        let p90 = h.quantile(0.9);
        let p99 = h.p99();
        assert!(p50 <= p90 && p90 <= p99);
        assert!((p50 as f64 - 50_000.0).abs() / 50_000.0 < 0.05, "p50={p50}");
        assert!((p99 as f64 - 99_000.0).abs() / 99_000.0 < 0.05, "p99={p99}");
    }

    #[test]
    fn histogram_mean_exact() {
        let mut h = Histogram::new();
        h.record(10);
        h.record(20);
        h.record(30);
        assert!((h.mean() - 20.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_merge() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        a.record(100);
        b.record(200);
        b.record(300);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert_eq!(a.max(), 300);
    }

    #[test]
    fn summary_moments() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.add(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.std() - 2.138089935299395).abs() < 1e-9);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }
}
