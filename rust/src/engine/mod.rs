//! The replication engine (Layer 3 proper), decomposed along the paper's
//! planes (§3–§4):
//!
//! * `replica`  — thin coordinator: owns the shared core + routes events;
//! * `client`   — closed-loop client slots, quota, request-side costs;
//! * `relaxed`  — landing zones, summarization buffer, flush/propagation
//!   (§4.1–§4.2, §5.4);
//! * `strong`   — Mu instances, Raft, forwarding/requester bookkeeping
//!   (§4.3–§4.4, §5.2);
//! * `paxos`    — APUS-style RDMA-Paxos strong path (backend = paxos):
//!   one-sided log writes, doorbell-completion quorums;
//! * `failure`  — heartbeat tracker, election, crash/recover/snapshot (§3);
//! * `path`     — the [`ReplicationPath`] trait + shared `ReplicaCore`;
//! * `cluster`  — builder/run loop; `store` — the ObjectId-addressed
//!   catalog data plane (heterogeneous RDT instances + KV tenants).

pub mod client;
pub mod cluster;
pub mod failure;
pub mod path;
pub mod paxos;
pub mod relaxed;
pub mod replica;
pub mod store;
pub mod strong;

pub use cluster::{Cluster, RunReport};
pub use path::{Membership, ReplicationPath};

use crate::metrics::RunMetrics;
use crate::net::{Network, QpTable};
use crate::sim::EventQueue;

/// Mutable cluster context handed to replica handlers (split-borrowed from
/// the cluster so replicas and shared infrastructure coexist).
pub struct Ctx<'a> {
    pub q: &'a mut EventQueue,
    pub net: &'a mut Network,
    pub qps: &'a mut QpTable,
    pub metrics: &'a mut RunMetrics,
    /// True once the op target is met: background timers stop re-arming so
    /// the event queue drains to quiescence.
    pub draining: bool,
}
