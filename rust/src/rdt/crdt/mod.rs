//! Operation-based CRDTs (Table A.1). All transactions are conflict-free,
//! so every type here uses only the relaxed replication paths.

pub mod counter;
pub mod lww;
pub mod sets;
