//! Queue-pair permission table (QPC, §2.2).
//!
//! Each follower keeps one open QP granting write permission to the
//! current leader; on suspected leader failure it closes that QP and opens
//! one for the new leader (§4.4 "Permission Switch"). Writes through a
//! closed QP fail with a NACK — the mechanism Mu leans on to fence a
//! deposed leader.

use crate::sim::NodeId;

#[derive(Debug)]
pub struct QpTable {
    n: usize,
    /// `open[dst][src]` — may `src` write into `dst`'s memory?
    open: Vec<Vec<bool>>,
}

impl QpTable {
    /// All-open mesh (relaxed-path traffic is always permitted; only the
    /// leader-write QPs get fenced).
    pub fn full_mesh(n: usize) -> Self {
        QpTable { n, open: vec![vec![true; n]; n] }
    }

    /// Paper-faithful boot state (§4.4): each replica grants leader-write
    /// permission to exactly one peer — the current leader. A node that
    /// wrongly elects itself (e.g. inside a partition minority) is fenced
    /// at every correct replica, which is what makes split-brain writes
    /// impossible; the table checks only `leader_qp` verbs, so relaxed
    /// traffic is unaffected.
    pub fn leader_fenced(n: usize, leader: NodeId) -> Self {
        let mut t = QpTable { n, open: vec![vec![false; n]; n] };
        for dst in 0..n {
            t.open(dst, leader);
            t.open(dst, dst); // self-writes are local, never fenced
        }
        t
    }

    pub fn is_open(&self, src: NodeId, dst: NodeId) -> bool {
        self.open[dst][src]
    }

    pub fn close(&mut self, dst: NodeId, src: NodeId) {
        self.open[dst][src] = false;
    }

    pub fn open(&mut self, dst: NodeId, src: NodeId) {
        self.open[dst][src] = true;
    }

    /// Permission switch at `dst`: fence `old_leader`, grant `new_leader`.
    pub fn switch_leader(&mut self, dst: NodeId, old_leader: NodeId, new_leader: NodeId) {
        if old_leader != dst {
            self.close(dst, old_leader);
        }
        self.open(dst, new_leader);
    }

    /// Sharded boot state: each replica grants leader-write permission to
    /// every per-group leader (`leaders[g]` = leader of global sync group
    /// `g`). Collapses to [`QpTable::leader_fenced`] when every group maps
    /// to the same node.
    pub fn leaders_fenced(n: usize, leaders: &[NodeId]) -> Self {
        let mut t = QpTable { n, open: vec![vec![false; n]; n] };
        for dst in 0..n {
            for &l in leaders {
                t.open(dst, l);
            }
            t.open(dst, dst); // self-writes are local, never fenced
        }
        t
    }

    /// Sharded permission switch at `dst`: rebuild `dst`'s grant row so
    /// exactly the current per-group leaders (plus `dst` itself) may
    /// leader-write. One table rebuild per placement change, however many
    /// groups moved.
    pub fn refence(&mut self, dst: NodeId, leaders: &[NodeId]) {
        for src in 0..self.n {
            self.open[dst][src] = src == dst || leaders.contains(&src);
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_fully_open() {
        let t = QpTable::full_mesh(4);
        for s in 0..4 {
            for d in 0..4 {
                assert!(t.is_open(s, d));
            }
        }
    }

    #[test]
    fn close_blocks_one_direction_only() {
        let mut t = QpTable::full_mesh(3);
        t.close(1, 0); // node 0 may no longer write into node 1
        assert!(!t.is_open(0, 1));
        assert!(t.is_open(1, 0), "reverse direction unaffected");
        assert!(t.is_open(0, 2));
    }

    #[test]
    fn switch_leader_fences_old_grants_new() {
        let mut t = QpTable::full_mesh(4);
        t.switch_leader(2, 0, 1);
        assert!(!t.is_open(0, 2), "old leader fenced");
        assert!(t.is_open(1, 2), "new leader granted");
    }

    #[test]
    fn leaders_fenced_grants_every_group_leader() {
        // Groups 0..4 led by nodes 0, 2, 0, 2 — only 0 and 2 (and self) open.
        let t = QpTable::leaders_fenced(4, &[0, 2, 0, 2]);
        for dst in 0..4 {
            assert!(t.is_open(0, dst));
            assert!(t.is_open(2, dst));
            assert_eq!(t.is_open(1, dst), dst == 1, "non-leader 1 fenced at {dst}");
            assert_eq!(t.is_open(3, dst), dst == 3, "non-leader 3 fenced at {dst}");
        }
    }

    #[test]
    fn leaders_fenced_single_leader_matches_leader_fenced() {
        let a = QpTable::leaders_fenced(4, &[1, 1, 1]);
        let b = QpTable::leader_fenced(4, 1);
        for src in 0..4 {
            for dst in 0..4 {
                assert_eq!(a.is_open(src, dst), b.is_open(src, dst), "{src}->{dst}");
            }
        }
    }

    #[test]
    fn refence_rebuilds_one_row_only() {
        let mut t = QpTable::leaders_fenced(4, &[0, 0]);
        t.refence(2, &[0, 3]);
        // Row 2 now admits 0, 3, and self.
        assert!(t.is_open(0, 2));
        assert!(t.is_open(3, 2));
        assert!(t.is_open(2, 2));
        assert!(!t.is_open(1, 2));
        // Other rows untouched: 3 still fenced at dst 1.
        assert!(!t.is_open(3, 1));
        assert!(t.is_open(0, 1));
    }

    #[test]
    fn leader_fenced_boot_grants_only_the_leader() {
        let t = QpTable::leader_fenced(4, 0);
        for dst in 0..4 {
            assert!(t.is_open(0, dst), "leader may write everywhere");
            for src in 1..4 {
                assert_eq!(t.is_open(src, dst), src == dst, "non-leaders fenced: {src}->{dst}");
            }
        }
    }
}
