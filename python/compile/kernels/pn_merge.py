"""PN-Counter merge kernel.

The paper's reducible path (§4.1) keeps an N-element contribution array A —
A[i] is replica i's summarized contribution — and folds it on access. On the
FPGA that fold is a pipelined adder over BRAM; here it is a VPU reduction
over a VMEM-resident [N, K] tile (N replicas × K counters).

A PN-Counter is two G-Counters (increments P, decrements M); the merged
value is sum_i P[i] - sum_i M[i].
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(p_ref, m_ref, out_ref):
    # Whole [N, K] blocks stay resident in VMEM for the entire fold, the way
    # the FPGA keeps the contribution array in BRAM across the burst.
    p = p_ref[...]
    m = m_ref[...]
    out_ref[...] = jnp.sum(p, axis=0) - jnp.sum(m, axis=0)


def pn_merge(p, m):
    """Fold per-replica PN-Counter contributions.

    Args:
      p: f32[N, K] increment contributions (replica-major).
      m: f32[N, K] decrement contributions.
    Returns:
      f32[K] merged counter values.
    """
    if p.shape != m.shape or p.ndim != 2:
        raise ValueError(f"pn_merge expects matching [N,K] arrays, got {p.shape} {m.shape}")
    n, k = p.shape
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((k,), p.dtype),
        interpret=True,
    )(p, m)
