//! Cluster builder and run loop: N replicas over the simulated fabric,
//! closed-loop clients, deterministic multi-fault injection (the chaos
//! harness), termination + quiescence drain, and report assembly
//! (response time / throughput / power — the paper's metrics, §5 — plus
//! the per-incident fault timeline).

use crate::config::{FaultAction, SimConfig};
use crate::engine::replica::Replica;
use crate::engine::Ctx;
use crate::metrics::RunMetrics;
use crate::net::{Network, QpTable};
use crate::power::{self, PowerReport};
use crate::sim::{EventKind, EventQueue, NetFault, NodeId};
use crate::util::rng::Rng;

/// Post-run telemetry for one fired fault incident (chaos harness).
#[derive(Clone, Debug)]
pub struct FaultIncidentReport {
    /// `kind:args` form of the fired action (leader crashes resolve to the
    /// concrete node).
    pub label: String,
    /// Virtual time the incident was injected.
    pub injected_ns: u64,
    /// First heartbeat-tracker failure declaration of an affected node
    /// after injection (None: nothing to detect, or never detected —
    /// e.g. a partition healed inside the detection window).
    pub detect_ns: Option<u64>,
    /// Unavailability window: crash of the leader → until the successor's
    /// election completes; other crashes → until detection excludes the
    /// node from fan-outs; partition → until the heal. 0 when the
    /// incident costs no availability (recover/heal/drop/delay).
    pub unavailable_ns: u64,
    /// Elections completed between this incident and the next (or run end).
    pub elections: u64,
}

/// Everything an experiment needs from one run.
#[derive(Debug)]
pub struct RunReport {
    pub metrics: RunMetrics,
    pub power: PowerReport,
    /// Post-quiescence state digests (crashed replicas excluded).
    pub digests: Vec<u64>,
    /// Per-replica, per-object state digests — multi-object convergence
    /// holds object by object (`object_digests[replica][object]`).
    pub object_digests: Vec<Vec<u64>>,
    pub crashed: Vec<bool>,
    pub invariants_ok: bool,
    pub leader: NodeId,
    /// Per-group leader view at quiescence (first live replica's; all
    /// equal to `leader` under `placement=single`).
    pub group_leaders: Vec<NodeId>,
    /// Groups led per node at quiescence (`groups_led[node]`; scale-out
    /// telemetry for placement policies).
    pub groups_led: Vec<u64>,
    /// Per-incident fault timeline (empty for fault-free runs).
    pub fault_timeline: Vec<FaultIncidentReport>,
    /// Per-replica human-readable state dumps (divergence diagnosis).
    pub dumps: Vec<String>,
    /// Wall-clock seconds the simulation itself took (engine §Perf).
    pub wall_s: f64,
}

impl RunReport {
    pub fn converged(&self) -> bool {
        let mut live = self
            .digests
            .iter()
            .zip(&self.crashed)
            .filter(|&(_, &c)| !c)
            .map(|(&d, _)| d);
        match live.next() {
            None => true,
            Some(first) => live.all(|d| d == first),
        }
    }

    /// Per-object convergence: every live replica byte-equal on every
    /// catalog object (strictly stronger than the combined-digest check
    /// when the catalog has more than one object).
    pub fn converged_per_object(&self) -> bool {
        let mut live = self
            .object_digests
            .iter()
            .zip(&self.crashed)
            .filter(|&(_, &c)| !c)
            .map(|(d, _)| d);
        match live.next() {
            None => true,
            Some(first) => live.all(|d| d == first),
        }
    }

    pub fn response_us(&self) -> f64 {
        self.metrics.response_us()
    }

    pub fn throughput(&self) -> f64 {
        self.metrics.throughput_ops_per_us()
    }
}

/// One fired incident, recorded while the run is live; the public
/// [`FaultIncidentReport`] is derived from these at quiescence.
struct FiredIncident {
    label: String,
    injected_ns: u64,
    /// Nodes whose failure declaration counts as "detected".
    subjects: Vec<NodeId>,
    /// The crashed node led at injection time (unavailability ends at the
    /// successor's election).
    leader_crash: bool,
    partition: bool,
    heal: bool,
}

pub struct Cluster {
    cfg: SimConfig,
    replicas: Vec<Replica>,
    q: EventQueue,
    net: Network,
    qps: QpTable,
    metrics: RunMetrics,
}

impl Cluster {
    pub fn new(cfg: SimConfig) -> Self {
        cfg.validate().expect("invalid SimConfig");
        let mut root = Rng::new(cfg.seed);
        let replicas: Vec<Replica> =
            (0..cfg.n_replicas).map(|id| Replica::new(id, &cfg, &mut root)).collect();
        let mem = cfg.system.params_for(&cfg).mem;
        let mut metrics = RunMetrics::new(cfg.n_replicas);
        metrics.obj_applied = vec![0; cfg.n_objects()];
        metrics.obj_rejected = vec![0; cfg.n_objects()];
        // Boot QP fences: single placement grants the classic initial
        // leader; sharded placements grant every per-group leader (the same
        // deterministic table every replica computes from the config).
        let qps = if cfg.placement.is_sharded() {
            let keyspace = crate::engine::client::ClientPlane::new(&cfg).keyspace();
            let groups =
                crate::engine::store::Catalog::for_config(&cfg, keyspace).total_groups() as usize;
            let table =
                crate::smr::election::PlacementTable::new(cfg.placement, groups, cfg.n_replicas);
            QpTable::leaders_fenced(cfg.n_replicas, table.leaders())
        } else {
            QpTable::leader_fenced(cfg.n_replicas, crate::smr::raft::initial_leader())
        };
        Cluster {
            net: Network::new(cfg.n_replicas, mem),
            qps,
            q: EventQueue::new(),
            metrics,
            replicas,
            cfg,
        }
    }

    /// Run to completion: all ops issued and completed, then the event
    /// queue drained to quiescence, then pending state force-flushed for
    /// the convergence check.
    pub fn run(mut self) -> RunReport {
        let wall_start = std::time::Instant::now();
        let n = self.cfg.n_replicas;
        let per_replica = self.cfg.total_ops / n as u64;
        let target: u64 = per_replica * n as u64;

        // Boot replicas.
        for i in 0..n {
            let (mut ctx, replica) = split(&mut self.q, &mut self.net, &mut self.qps, &mut self.metrics, &mut self.replicas, i, false);
            replica.boot(&mut ctx, self.cfg.clients_per_replica, per_replica);
        }

        // Compile the fault schedule into completed-op watermarks, fired
        // in (watermark, schedule-position) order — deterministic and
        // seed-reproducible like everything else in the event stream.
        let mut armed: Vec<(u64, FaultAction)> = self
            .cfg
            .fault
            .incidents
            .iter()
            .map(|inc| (target * inc.at_pct as u64 / 100, inc.action))
            .collect();
        armed.sort_by_key(|&(at, _)| at); // stable: schedule order breaks ties
        let mut next_arm = 0usize;
        // DelaySpike end watermarks, armed as spikes fire.
        let mut delay_restores: Vec<(u64, NodeId, NodeId)> = Vec::new();
        // Pending recovery snapshot transfers (node, install time).
        // Snapshot transfer runs after the cluster has re-included the
        // returned node (heartbeat detection window), so no relaxed op can
        // fall between the snapshot point and re-inclusion.
        let mut snapshots: Vec<(NodeId, u64)> = Vec::new();
        let grace_ns = self.cfg.heartbeat_period_ns * (self.cfg.hb_fail_threshold as u64 + 4);
        // Links currently cut (heal-time anti-entropy set).
        let mut cut_links: Vec<(NodeId, NodeId)> = Vec::new();
        let mut timeline: Vec<FiredIncident> = Vec::new();

        let mut draining = false;
        let mut events: u64 = 0;
        // Hard safety valve (runaway bug guard), generous: 400 events/op.
        let event_cap = 4_000_000 + target.saturating_mul(400);

        while let Some(ev) = self.q.pop() {
            events += 1;
            if events > event_cap {
                let status: Vec<String> =
                    self.replicas.iter().map(|r| r.debug_status()).collect();
                let done = self.metrics.total_completed();
                if !cut_links.is_empty() && done < target {
                    // Not a runaway bug: the schedule cut links and never
                    // healed them, so clients whose ops route to a leader
                    // behind the cut retry forever. Name the livelock
                    // instead of tripping the cap opaquely.
                    let cuts: Vec<String> =
                        cut_links.iter().map(|&(a, b)| format!("{a}-{b}")).collect();
                    panic!(
                        "no-progress livelock: {done}/{target} ops completed when the event cap tripped, \
                         with unhealed partition(s) [{}] still cutting the fabric — a leader behind the \
                         cut can never reach its quorum or its clients; the fault schedule needs a \
                         `heal@` incident after its last `partition@`\n{}",
                        cuts.join(", "),
                        status.join("\n")
                    );
                }
                panic!(
                    "event cap exceeded: {} events for {} ops (completed {})\n{}",
                    events,
                    target,
                    done,
                    status.join("\n")
                );
            }

            let completed = self.metrics.total_completed();

            // Pending recovery snapshot installs: the returned replica
            // pulls state + logs + dedup ledger from a live donor; the
            // leader's heartbeat-driven replay covers anything committed
            // during the transfer (§3).
            if !snapshots.is_empty() && snapshots.iter().any(|&(_, at)| self.q.now() >= at) {
                let due: Vec<NodeId> = snapshots
                    .iter()
                    .filter(|&&(_, at)| self.q.now() >= at)
                    .map(|&(node, _)| node)
                    .collect();
                snapshots.retain(|&(_, at)| self.q.now() < at);
                for node in due {
                    let t = self.q.now();
                    if let Some(donor) = (0..n).find(|&i| i != node && !self.replicas[i].crashed()) {
                        let (plane, logs, leader, group_leaders, seen) =
                            self.replicas[donor].snapshot_state();
                        self.replicas[node].install_snapshot(plane, logs, leader, group_leaders, seen, &mut self.qps, t);
                        // Second-order anti-entropy (chaos mode): one donor's
                        // snapshot may itself be missing an update whose
                        // origin-retry was outstanding against every donor,
                        // so the *union* of live peers re-ships anything
                        // they gave up sending to the returned node. The
                        // installed dedup ledger makes duplicates safe.
                        if self.cfg.fault.has_link_faults() {
                            for p in 0..n {
                                if p == node || self.replicas[p].crashed() {
                                    continue;
                                }
                                let (mut ctx, replica) = split(&mut self.q, &mut self.net, &mut self.qps, &mut self.metrics, &mut self.replicas, p, draining);
                                replica.reconcile_relaxed_to(&mut ctx, node, true);
                                // Receiver-side re-gossip: the node's own
                                // retry ledger died with the install, so
                                // an update it had only partially shipped
                                // before crashing now exists solely at the
                                // peers that accepted it — they re-ship it
                                // everywhere (dedup absorbs duplicates).
                                replica.regossip_from_origin(&mut ctx, node);
                            }
                        }
                    }
                }
            }

            // Fire schedule incidents whose watermark has passed.
            while next_arm < armed.len() && completed >= armed[next_arm].0 {
                let (_, action) = armed[next_arm];
                next_arm += 1;
                self.fire_incident(
                    action,
                    target,
                    grace_ns,
                    &mut timeline,
                    &mut snapshots,
                    &mut delay_restores,
                );
            }

            // End delay-spike windows whose until-watermark has passed.
            if !delay_restores.is_empty() {
                let t = self.q.now();
                let mut i = 0;
                while i < delay_restores.len() {
                    let (at, src, dst) = delay_restores[i];
                    if completed >= at {
                        self.q.push(t, 0, EventKind::Fault(NetFault::DelayRestore { src, dst }));
                        delay_restores.swap_remove(i);
                    } else {
                        i += 1;
                    }
                }
            }

            self.maybe_begin_drain(&mut draining);

            // Link-level fault actions are consumed by the cluster's
            // network actor, not a replica.
            if let EventKind::Fault(nf) = &ev.kind {
                let nf = *nf;
                self.apply_net_fault(nf, &mut cut_links, draining);
                continue;
            }

            let dest = ev.dest;
            let (mut ctx, replica) = split(&mut self.q, &mut self.net, &mut self.qps, &mut self.metrics, &mut self.replicas, dest, draining);
            replica.handle(&mut ctx, ev.kind);

            self.maybe_begin_drain(&mut draining);
        }

        // Quiescence: force-flush remaining landed-but-unapplied state so
        // convergence is checked on fully-propagated replicas.
        self.metrics.makespan_ns = self.metrics.makespan_from(&self.replicas);
        for (i, r) in self.replicas.iter_mut().enumerate() {
            if !r.crashed() {
                r.flush_all_pending();
            }
            self.metrics.busy_ns[i] = r.busy_total();
            self.metrics.executions += r.executions();
            self.metrics.rejected += r.rejected();
            self.metrics.offered += r.offered();
            self.metrics.shed += r.shed();
            self.metrics.queue_depth_max =
                self.metrics.queue_depth_max.max(r.queue_depth_max() as u64);
            for (o, &a) in r.object_applied().iter().enumerate() {
                self.metrics.obj_applied[o] += a;
            }
            for (o, &x) in r.object_rejected().iter().enumerate() {
                self.metrics.obj_rejected[o] += x;
            }
        }

        self.metrics.events = events;
        let fault_timeline = self.assemble_timeline(&timeline);
        let power = power::estimate(&self.cfg.system.params_for(&self.cfg).power, &self.metrics);
        let digests: Vec<u64> = self.replicas.iter().map(|r| r.digest()).collect();
        let object_digests: Vec<Vec<u64>> =
            self.replicas.iter().map(|r| r.object_digests()).collect();
        let dumps: Vec<String> = self.replicas.iter().map(|r| r.plane_dump()).collect();
        let crashed: Vec<bool> = self.replicas.iter().map(|r| r.crashed()).collect();
        let invariants_ok = self
            .replicas
            .iter()
            .filter(|r| !r.crashed())
            .all(|r| r.invariant_ok());
        let leader = self.current_leader();
        let group_leaders = self
            .replicas
            .iter()
            .find(|r| !r.crashed())
            .map(|r| r.group_leaders())
            .unwrap_or_default();
        let mut groups_led = vec![0u64; self.cfg.n_replicas];
        for &l in &group_leaders {
            groups_led[l] += 1;
        }

        RunReport {
            metrics: self.metrics,
            power,
            digests,
            object_digests,
            dumps,
            crashed,
            invariants_ok,
            leader,
            group_leaders,
            groups_led,
            fault_timeline,
            wall_s: wall_start.elapsed().as_secs_f64(),
        }
    }

    /// Fire one scheduled incident at the current virtual time.
    fn fire_incident(
        &mut self,
        action: FaultAction,
        target: u64,
        grace_ns: u64,
        timeline: &mut Vec<FiredIncident>,
        snapshots: &mut Vec<(NodeId, u64)>,
        delay_restores: &mut Vec<(u64, NodeId, NodeId)>,
    ) {
        let t = self.q.now();
        let n = self.cfg.n_replicas;
        match action {
            FaultAction::Crash { node } => {
                let node = node.unwrap_or_else(|| self.current_leader());
                if self.replicas[node].crashed() {
                    return; // double-crash in a hand-written schedule: no-op
                }
                let leader_crash = node == self.current_leader();
                self.q.push(t, node, EventKind::Crash);
                // Redistribute the crashed node's remaining quota over the
                // still-live replicas.
                let remaining = self.replicas[node].take_quota();
                let live: Vec<NodeId> =
                    (0..n).filter(|&i| i != node && !self.replicas[i].crashed()).collect();
                if !live.is_empty() {
                    for (j, &r) in live.iter().enumerate() {
                        let share = remaining / live.len() as u64
                            + if j < (remaining % live.len() as u64) as usize { 1 } else { 0 };
                        if let Some(epoch) = self.replicas[r].grant_quota(share) {
                            // The survivor's open-loop stream had parked at
                            // quota exhaustion; restart it or the granted
                            // share would never be offered (the run would
                            // then never drain).
                            self.q.push(t, r, EventKind::Arrival { epoch });
                        }
                    }
                }
                timeline.push(FiredIncident {
                    label: format!("crash:{node}"),
                    injected_ns: t,
                    subjects: vec![node],
                    leader_crash,
                    partition: false,
                    heal: false,
                });
            }
            FaultAction::Recover { node } => {
                if self.replicas[node].crashed() {
                    self.q.push(t, node, EventKind::Recover);
                    snapshots.push((node, t + grace_ns));
                }
                timeline.push(FiredIncident {
                    label: format!("recover:{node}"),
                    injected_ns: t,
                    subjects: Vec::new(),
                    leader_crash: false,
                    partition: false,
                    heal: false,
                });
            }
            FaultAction::PartitionLinks { a, b } => {
                self.q.push(t, 0, EventKind::Fault(NetFault::Partition { a, b }));
                timeline.push(FiredIncident {
                    label: format!("partition:{a}-{b}"),
                    injected_ns: t,
                    subjects: vec![a, b],
                    leader_crash: false,
                    partition: true,
                    heal: false,
                });
            }
            FaultAction::HealLinks => {
                self.q.push(t, 0, EventKind::Fault(NetFault::Heal));
                timeline.push(FiredIncident {
                    label: "heal".into(),
                    injected_ns: t,
                    subjects: Vec::new(),
                    leader_crash: false,
                    partition: false,
                    heal: true,
                });
            }
            FaultAction::DropNext { src, dst, count } => {
                self.q.push(t, 0, EventKind::Fault(NetFault::DropNext { src, dst, count }));
                timeline.push(FiredIncident {
                    label: format!("drop:{src}-{dst}x{count}"),
                    injected_ns: t,
                    subjects: Vec::new(),
                    leader_crash: false,
                    partition: false,
                    heal: false,
                });
            }
            FaultAction::DelaySpike { src, dst, factor_pct, until_pct } => {
                self.q.push(t, 0, EventKind::Fault(NetFault::DelaySpike { src, dst, factor_pct }));
                delay_restores.push((target * until_pct as u64 / 100, src, dst));
                timeline.push(FiredIncident {
                    label: format!("delay:{src}-{dst}x{factor_pct}u{until_pct}"),
                    injected_ns: t,
                    subjects: Vec::new(),
                    leader_crash: false,
                    partition: false,
                    heal: false,
                });
            }
        }
    }

    /// Apply a link-level fault action to the network actor. On heal, the
    /// current leader replays its strong log to every peer it was cut off
    /// from — a short partition can open a silent gap there (a round
    /// committed by the other majority members), and heartbeat recovery
    /// only covers partitions long enough to be detected.
    fn apply_net_fault(&mut self, nf: NetFault, cut_links: &mut Vec<(NodeId, NodeId)>, draining: bool) {
        match nf {
            NetFault::Partition { a, b } => {
                self.net.set_partitioned(a, b, true);
                cut_links.push((a, b));
            }
            NetFault::Heal => {
                self.net.heal_all();
                let pairs = std::mem::take(cut_links);
                // Long partitions (and drop bursts — heal_all repairs every
                // link, not just recorded cuts) can exhaust the relaxed
                // path's per-entry retry budget; re-arm every parked
                // propagation between live replicas now that the fabric is
                // whole (the relaxed-plane half of heal-time anti-entropy).
                self.reconcile_all_parked(draining);
                if self.cfg.placement.is_sharded() {
                    // Sharded placements: a partition leaves its endpoints
                    // with divergent placement tables — each mis-declared
                    // the other dead and re-placed the other's groups,
                    // possibly onto itself (the minority imposter). The
                    // rightful view is any live replica that was NOT a cut
                    // endpoint: it saw both sides stay alive, so its table
                    // is the one the majority's permission fences enforced
                    // all along.
                    let n = self.cfg.n_replicas;
                    let is_endpoint =
                        |r: NodeId| pairs.iter().any(|&(a, b)| a == r || b == r);
                    let authority = (0..n)
                        .find(|&r| !self.replicas[r].crashed() && !is_endpoint(r))
                        .or_else(|| (0..n).find(|&r| !self.replicas[r].crashed()));
                    let Some(auth) = authority else { return };
                    let rightful = self.replicas[auth].group_leaders();
                    let anchor = self.replicas[auth].leader();
                    for r in 0..n {
                        if r == auth || self.replicas[r].crashed() {
                            continue;
                        }
                        if self.replicas[r].group_leaders() != rightful {
                            self.replicas[r].realign_group_leaders(&rightful, &mut self.qps);
                        }
                    }
                    // Minority imposters next: a campaign that never
                    // confirmed (fenced at every correct follower) hands
                    // its shard to the realigned table's rightful leader
                    // and re-routes whatever it parked — a quiescent
                    // imposter would otherwise never notice the heal.
                    for r in 0..n {
                        if self.replicas[r].crashed() {
                            continue;
                        }
                        let (mut ctx, replica) = split(&mut self.q, &mut self.net, &mut self.qps, &mut self.metrics, &mut self.replicas, r, draining);
                        replica.abdicate_unconfirmed_leadership(&mut ctx, anchor);
                    }
                    // Per-inheriting-leader re-pull: every live replica
                    // replays the shards it leads to each cut endpoint
                    // (replay gates per-shard on leadership internally) —
                    // a group led by a third node may have committed
                    // rounds an endpoint never saw through the cut.
                    let mut endpoints: Vec<NodeId> =
                        pairs.iter().flat_map(|&(a, b)| [a, b]).collect();
                    endpoints.sort_unstable();
                    endpoints.dedup();
                    for &e in &endpoints {
                        if self.replicas[e].crashed() {
                            continue;
                        }
                        for from in 0..n {
                            if from == e || self.replicas[from].crashed() {
                                continue;
                            }
                            let (mut ctx, replica) = split(&mut self.q, &mut self.net, &mut self.qps, &mut self.metrics, &mut self.replicas, from, draining);
                            replica.replay_strong_to(&mut ctx, e);
                        }
                    }
                    return;
                }
                let leader = self.current_leader();
                if self.replicas[leader].crashed() {
                    return;
                }
                // Partition-minority imposters first: a node that
                // self-elected but never confirmed (fenced by everyone
                // else's permission switch) re-fences itself toward the
                // rightful leader and re-routes whatever it parked — a
                // quiescent imposter would otherwise never notice.
                for r in 0..self.cfg.n_replicas {
                    if r != leader
                        && !self.replicas[r].crashed()
                        && self.replicas[r].leader() == r
                    {
                        let (mut ctx, replica) = split(&mut self.q, &mut self.net, &mut self.qps, &mut self.metrics, &mut self.replicas, r, draining);
                        replica.abdicate_unconfirmed_leadership(&mut ctx, leader);
                    }
                }
                for (a, b) in pairs {
                    let peer = match (a == leader, b == leader) {
                        (true, _) => b,
                        (_, true) => a,
                        _ => continue, // follower-follower cut: no log owner
                    };
                    if self.replicas[peer].crashed() {
                        continue;
                    }
                    let (mut ctx, replica) = split(&mut self.q, &mut self.net, &mut self.qps, &mut self.metrics, &mut self.replicas, leader, draining);
                    replica.replay_strong_to(&mut ctx, peer);
                }
            }
            NetFault::DropNext { src, dst, count } => self.net.arm_drop(src, dst, count),
            NetFault::DelaySpike { src, dst, factor_pct } => {
                self.net.set_delay_pct(src, dst, factor_pct)
            }
            NetFault::DelayRestore { src, dst } => self.net.set_delay_pct(src, dst, 100),
        }
    }

    /// Derive the public per-incident reports from the fired timeline and
    /// the heartbeat/election telemetry the run collected.
    fn assemble_timeline(&self, timeline: &[FiredIncident]) -> Vec<FaultIncidentReport> {
        timeline
            .iter()
            .enumerate()
            .map(|(i, inc)| {
                let window_end =
                    timeline.get(i + 1).map(|nx| nx.injected_ns).unwrap_or(u64::MAX);
                let detect_ns = if inc.subjects.is_empty() {
                    None
                } else {
                    self.metrics
                        .detections
                        .iter()
                        .filter(|&&(t, subj, _)| t >= inc.injected_ns && inc.subjects.contains(&subj))
                        .map(|&(t, _, _)| t)
                        .min()
                };
                let elections = self
                    .metrics
                    .election_times
                    .iter()
                    .filter(|&&t| t >= inc.injected_ns && t < window_end)
                    .count() as u64;
                let unavailable_ns = if inc.leader_crash {
                    self.metrics
                        .election_times
                        .iter()
                        .find(|&&t| t >= inc.injected_ns)
                        .map(|&t| t - inc.injected_ns)
                        .or_else(|| detect_ns.map(|d| d - inc.injected_ns))
                        .unwrap_or(0)
                } else if inc.partition {
                    timeline[i + 1..]
                        .iter()
                        .find(|x| x.heal)
                        .map(|h| h.injected_ns - inc.injected_ns)
                        .unwrap_or_else(|| {
                            self.metrics.makespan_ns.saturating_sub(inc.injected_ns)
                        })
                } else {
                    detect_ns.map(|d| d - inc.injected_ns).unwrap_or(0)
                };
                FaultIncidentReport {
                    label: inc.label.clone(),
                    injected_ns: inc.injected_ns,
                    detect_ns,
                    unavailable_ns,
                    elections,
                }
            })
            .collect()
    }

    /// Re-arm every parked relaxed-path propagation between live replicas
    /// (second-order anti-entropy). Cheap when nothing is parked.
    fn reconcile_all_parked(&mut self, draining: bool) {
        let n = self.cfg.n_replicas;
        for from in 0..n {
            if self.replicas[from].crashed() {
                continue;
            }
            for to in 0..n {
                if to == from || self.replicas[to].crashed() {
                    continue;
                }
                let (mut ctx, replica) = split(&mut self.q, &mut self.net, &mut self.qps, &mut self.metrics, &mut self.replicas, from, draining);
                replica.reconcile_relaxed_to(&mut ctx, to, false);
            }
        }
    }

    /// Flip the drain flag once all client work is accounted for. In chaos
    /// mode (link faults in the schedule) the flip also triggers one final
    /// leader anti-entropy replay to every live peer — a drop or partition
    /// may have eaten the *last* strong append to some follower — and one
    /// relaxed-plane reconcile of parked propagations (a drop burst with no
    /// later heal can exhaust a retry budget that nothing else re-arms);
    /// with no further traffic nothing else would repair either before the
    /// convergence check.
    fn maybe_begin_drain(&mut self, draining: &mut bool) {
        if *draining || !(self.all_quota_spent() && self.no_pending_clients()) {
            return;
        }
        *draining = true;
        if !self.cfg.fault.has_link_faults() {
            return;
        }
        self.reconcile_all_parked(true);
        if self.cfg.placement.is_sharded() {
            // Every live replica replays the shards it leads to every live
            // peer (replay gates per-shard on leadership internally), so
            // each group's final appends reach every follower.
            for from in 0..self.cfg.n_replicas {
                if self.replicas[from].crashed() {
                    continue;
                }
                for peer in 0..self.cfg.n_replicas {
                    if peer == from || self.replicas[peer].crashed() {
                        continue;
                    }
                    let (mut ctx, replica) = split(&mut self.q, &mut self.net, &mut self.qps, &mut self.metrics, &mut self.replicas, from, true);
                    replica.replay_strong_to(&mut ctx, peer);
                }
            }
            return;
        }
        let leader = self.current_leader();
        if self.replicas[leader].crashed() {
            return;
        }
        for peer in 0..self.cfg.n_replicas {
            if peer == leader || self.replicas[peer].crashed() {
                continue;
            }
            let (mut ctx, replica) = split(&mut self.q, &mut self.net, &mut self.qps, &mut self.metrics, &mut self.replicas, leader, true);
            replica.replay_strong_to(&mut ctx, peer);
        }
    }

    fn all_quota_spent(&self) -> bool {
        // Closed loop: remaining slot issues; open loop: the un-offered
        // tail of each node's arrival stream. Either way, quota 0
        // everywhere means the cluster's total offered-op budget is spent,
        // so termination keys off arrival-stream exhaustion.
        self.replicas.iter().all(|r| r.quota() == 0 || r.crashed())
    }

    fn no_pending_clients(&self) -> bool {
        // A client slot is pending from the event that consumes its quota
        // until its response is recorded — forwarded/SMR ops stay pending
        // across events. The drain flag must not flip while any live
        // replica still owes a response: background timers (heartbeats,
        // pollers) may be exactly what those completions are waiting on.
        // Open loop adds queued-but-unissued admissions, which are pending
        // in the same sense (a completion will dequeue them into a slot) —
        // but only at *live* replicas: shed arrivals were dropped outright
        // and a crashed node's queue was wiped at crash time, so neither
        // may hold the drain open (a chaos run would spin to the event
        // cap waiting on clients that no longer exist). Crashed replicas'
        // in-flight slots died with them (reset at crash; their quota was
        // redistributed).
        self.replicas
            .iter()
            .all(|r| r.crashed() || (r.in_flight() == 0 && r.queued_admissions() == 0))
    }

    fn current_leader(&self) -> NodeId {
        // The smallest live replica's own view (they agree at quiescence).
        self.replicas
            .iter()
            .find(|r| !r.crashed())
            .map(|r| r.leader())
            .unwrap_or(0)
    }
}

impl RunMetrics {
    fn makespan_from(&self, replicas: &[Replica]) -> u64 {
        // System execution time: until the last client op completed (the
        // leader's busy time dominates this for WRDTs — appendix D.1 —
        // but fault recovery delays count too, which Fig 14 needs).
        let busy_bound = replicas.iter().map(|r| r.busy_total()).max().unwrap_or(0);
        self.last_completion_ns.max(busy_bound).max(1)
    }
}

/// Split-borrow helper: one replica mutable alongside the shared
/// infrastructure.
fn split<'a>(
    q: &'a mut EventQueue,
    net: &'a mut Network,
    qps: &'a mut QpTable,
    metrics: &'a mut RunMetrics,
    replicas: &'a mut [Replica],
    idx: usize,
    draining: bool,
) -> (Ctx<'a>, &'a mut Replica) {
    let replica = &mut replicas[idx];
    (Ctx { q, net, qps, metrics, draining }, replica)
}

/// Convenience: build + run.
pub fn run(cfg: SimConfig) -> RunReport {
    Cluster::new(cfg).run()
}
