//! Minimal seeded property-test harness (the offline crate set lacks
//! proptest). `check` runs a property over `iters` derived RNG streams and,
//! on failure, panics with the exact seed so the case replays with
//! `Rng::new(seed)`.
//!
//! Used by the coordinator invariants: replica convergence, batching
//! conservation, routing determinism, log ordering (see rust/tests/).

use super::rng::Rng;

/// Run `prop` for `iters` independent seeds derived from `base_seed`.
/// The property receives a fresh RNG; panic or `Err` fails the run with a
/// replayable seed in the message.
pub fn check<F>(name: &str, base_seed: u64, iters: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for i in 0..iters {
        let seed = base_seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(i.wrapping_mul(0xD1B54A32D192ED03));
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed at iter {i} (replay seed {seed}): {msg}");
        }
    }
}

/// Assert-style helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("unit-interval", 1, 50, |rng| {
            let v = rng.gen_f64();
            if (0.0..1.0).contains(&v) {
                Ok(())
            } else {
                Err(format!("v={v}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn reports_replay_seed_on_failure() {
        check("always-fails", 2, 3, |_| Err("nope".into()));
    }

    #[test]
    fn prop_assert_macro() {
        check("macro", 3, 10, |rng| {
            let v = rng.gen_range(10);
            prop_assert!(v < 10, "v={v}");
            Ok(())
        });
    }
}
