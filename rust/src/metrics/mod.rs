//! Run metrics: the paper's three reported quantities — response time,
//! throughput (OPs/µs), power (W) — plus per-replica execution time
//! (Figs 24–26), permission-switch samples (Fig 13), staleness
//! (summarization trade-off, §5.4), and engine counters for §Perf.

use crate::util::stats::{Histogram, Summary};

#[derive(Debug)]
pub struct RunMetrics {
    /// Response time of completed client ops (ns).
    pub response: Histogram,
    /// Per-replica busy time (execution time in the paper's Fig 24 sense).
    pub busy_ns: Vec<u64>,
    /// Per-replica completed client ops.
    pub completed: Vec<u64>,
    /// Running sum of `completed` (hot-loop termination check, §Perf 2).
    pub completed_sum: u64,
    /// Updates rejected by permissibility (impermissible at execution).
    pub rejected: u64,
    /// Conflicting ops that went through SMR.
    pub smr_commits: u64,
    /// Strong-plane round commit latency (ns): first fan-out of a
    /// consensus round / append batch to its in-order commit release.
    /// With `window` > 1 overlapping rounds keep their own stamps.
    pub smr_round: Histogram,
    /// Per-shard (global sync group; index 0 under `placement = single`)
    /// high-water mark of concurrent in-flight consensus rounds. Never
    /// exceeds `window`; 1 everywhere at the stop-and-wait default.
    pub inflight_max: Vec<u64>,
    /// Verbs put on the wire.
    pub verbs: u64,
    /// Per-path batching merge count: every *batch* of k coalesced
    /// submissions adds k-1, independent of how many peers its fan-out
    /// targets (total wire verbs saved = coalesced × fan-out width).
    /// Always 0 at `batch_size` 1 — the unbatched engine never emits
    /// batch verbs.
    pub coalesced: u64,
    /// Transactions executed (local + remote applies) for power accounting.
    pub executions: u64,
    /// Per-catalog-object applied-op counts, summed across replicas
    /// (multi-object telemetry; one entry for catalog-of-one runs).
    pub obj_applied: Vec<u64>,
    /// Per-catalog-object permissibility rejections, summed across
    /// replicas.
    pub obj_rejected: Vec<u64>,
    /// Permission-switch latencies sampled during leader changes (Fig 13).
    pub perm_switch: Histogram,
    /// Staleness: local-apply -> propagation-issue delay for summarized ops.
    pub staleness: Summary,
    /// Leader elections completed.
    pub elections: u64,
    /// Fault-timeline telemetry: when each election completed (virtual ns).
    pub election_times: Vec<u64>,
    /// Fault-timeline telemetry: `(t, subject, observer)` — observer's
    /// heartbeat tracker declared subject FAILED at t.
    pub detections: Vec<(u64, usize, usize)>,
    /// Fault-timeline telemetry: `(t, subject, observer)` — observer saw
    /// subject's heartbeat resume at t.
    pub recoveries: Vec<(u64, usize, usize)>,
    /// Ops offered to the cluster: open-loop arrival ticks fired plus
    /// closed-loop quota consumed (summed per node at quiescence).
    pub offered: u64,
    /// Open-loop arrivals shed on full admission queues (backpressure).
    pub shed: u64,
    /// Offered ops killed by crashes: in-flight at the crashed node plus
    /// its queued-but-unissued admissions. Closes the conservation
    /// identity `offered = completed + shed + crash_killed` for runs that
    /// lose nodes (fault-free runs have it 0).
    pub crash_killed: u64,
    /// High-water mark of any node's open-loop admission queue.
    pub queue_depth_max: u64,
    /// Virtual makespan of the run (ns): last client completion.
    pub makespan_ns: u64,
    /// Last client-op completion time (feeds makespan).
    pub last_completion_ns: u64,
    /// DES events processed (engine §Perf).
    pub events: u64,
}

impl RunMetrics {
    pub fn new(n: usize) -> Self {
        RunMetrics {
            response: Histogram::new(),
            busy_ns: vec![0; n],
            completed: vec![0; n],
            completed_sum: 0,
            rejected: 0,
            smr_commits: 0,
            smr_round: Histogram::new(),
            inflight_max: Vec::new(),
            verbs: 0,
            coalesced: 0,
            executions: 0,
            obj_applied: Vec::new(),
            obj_rejected: Vec::new(),
            perm_switch: Histogram::new(),
            staleness: Summary::new(),
            elections: 0,
            election_times: Vec::new(),
            detections: Vec::new(),
            recoveries: Vec::new(),
            offered: 0,
            shed: 0,
            crash_killed: 0,
            queue_depth_max: 0,
            makespan_ns: 0,
            last_completion_ns: 0,
            events: 0,
        }
    }

    /// Record an observed pipeline depth for `shard` (resizes on first
    /// sight — sharded placements discover their group count lazily).
    pub fn note_inflight(&mut self, shard: usize, depth: u64) {
        if self.inflight_max.len() <= shard {
            self.inflight_max.resize(shard + 1, 0);
        }
        self.inflight_max[shard] = self.inflight_max[shard].max(depth);
    }

    /// Deepest pipeline any shard reached (bench/loadcurve telemetry).
    pub fn inflight_max_overall(&self) -> u64 {
        self.inflight_max.iter().copied().max().unwrap_or(0)
    }

    pub fn total_completed(&self) -> u64 {
        debug_assert_eq!(self.completed_sum, self.completed.iter().sum::<u64>());
        self.completed_sum
    }

    /// Mean response time in µs (the paper's Figs 6–12 y-axis).
    pub fn response_us(&self) -> f64 {
        self.response.mean() / 1_000.0
    }

    /// Throughput in OPs/µs: completed ops over the system makespan, which
    /// is constrained by the longest-running replica (appendix D.1).
    pub fn throughput_ops_per_us(&self) -> f64 {
        if self.makespan_ns == 0 {
            return 0.0;
        }
        self.total_completed() as f64 / (self.makespan_ns as f64 / 1_000.0)
    }

    /// Busy time of the leader vs mean follower busy time (Fig 24).
    pub fn leader_vs_followers(&self, leader: usize) -> (u64, f64) {
        let l = self.busy_ns[leader];
        let others: Vec<u64> = self
            .busy_ns
            .iter()
            .enumerate()
            .filter(|&(i, _)| i != leader)
            .map(|(_, &b)| b)
            .collect();
        let mean = others.iter().sum::<u64>() as f64 / others.len().max(1) as f64;
        (l, mean)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_definition_uses_makespan() {
        let mut m = RunMetrics::new(2);
        m.completed = vec![500, 500];
        m.completed_sum = 1_000;
        m.makespan_ns = 1_000_000; // 1 ms
        assert!((m.throughput_ops_per_us() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn leader_vs_followers_split() {
        let mut m = RunMetrics::new(4);
        m.busy_ns = vec![100, 1000, 120, 80];
        let (l, f) = m.leader_vs_followers(1);
        assert_eq!(l, 1000);
        assert!((f - 100.0).abs() < 1e-9);
    }

    #[test]
    fn response_unit_conversion() {
        let mut m = RunMetrics::new(1);
        m.response.record(2_000);
        m.response.record(4_000);
        assert!((m.response_us() - 3.0).abs() < 1e-9);
    }
}
