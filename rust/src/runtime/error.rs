//! Minimal error type with context chaining for the runtime layer.
//!
//! The offline crate set has no `anyhow`; this covers the slice of it the
//! runtime needs: a string-backed error, `.context(...)` /
//! `.with_context(...)` on `Result` and `Option`, and an alternate Display
//! (`{:#}`) that renders the whole cause chain outermost-first.

use std::fmt;

/// A runtime error: root message plus outward-growing context frames.
pub struct Error {
    root: String,
    /// Context frames, innermost first (`contexts.last()` is outermost).
    contexts: Vec<String>,
}

impl Error {
    pub fn msg(root: impl Into<String>) -> Error {
        Error { root: root.into(), contexts: Vec::new() }
    }

    fn wrap(mut self, context: String) -> Error {
        self.contexts.push(context);
        self
    }

    /// Outermost context (or the root message if no context was attached).
    pub fn headline(&self) -> &str {
        self.contexts.last().unwrap_or(&self.root)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            for c in self.contexts.iter().rev() {
                write!(f, "{c}: ")?;
            }
            write!(f, "{}", self.root)
        } else {
            write!(f, "{}", self.headline())
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self:#}")
    }
}

impl std::error::Error for Error {}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow::Context`-style adapters for `Result` and `Option`.
pub trait Context<T> {
    fn context(self, msg: impl Into<String>) -> Result<T>;
    fn with_context(self, f: impl FnOnce() -> String) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    // `{e:#}` rather than `.to_string()`: when E is itself this Error type
    // the alternate form carries the whole existing chain into the new
    // root, so re-wrapping never drops inner frames.
    fn context(self, msg: impl Into<String>) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{e:#}")).wrap(msg.into()))
    }

    fn with_context(self, f: impl FnOnce() -> String) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{e:#}")).wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl Into<String>) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg.into()))
    }

    fn with_context(self, f: impl FnOnce() -> String) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_shows_outermost_alternate_shows_chain() {
        let e: Error = Err::<(), _>("root cause")
            .context("parsing manifest")
            .unwrap_err();
        let e = Err::<(), _>(e).context("loading artifacts").unwrap_err();
        assert_eq!(format!("{e}"), "loading artifacts");
        let full = format!("{e:#}");
        assert!(full.starts_with("loading artifacts: "), "{full}");
        assert!(full.contains("parsing manifest"), "{full}");
        // Re-wrapping an Error must not drop the innermost root.
        assert!(full.ends_with("root cause"), "{full}");
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        assert!(v.context("missing").is_err());
        assert_eq!(Some(3u8).context("missing").unwrap(), 3);
    }

    #[test]
    fn with_context_lazy() {
        let r: Result<u8> = "x".parse::<u8>().with_context(|| "bad number".to_string());
        assert_eq!(format!("{}", r.unwrap_err()), "bad number");
    }
}
