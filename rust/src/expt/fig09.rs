//! Fig 9: the five CRDT micro-benchmarks, SafarDB vs Hamband, 3–8 nodes,
//! 15/20/25 % updates.
//!
//! Headline: SafarDB ≈7.0× lower response time, ≈5.3× higher throughput;
//! Hamband degrades faster with node count (CQE-wait serialization) while
//! SafarDB's per-replica load *drops* with N.

use crate::config::{SimConfig, WorkloadKind};
use crate::expt::common::{cell_ops, f3, nodes, run_cells_tagged, UPDATE_SWEEP};
use crate::rdt::RdtKind;
use crate::util::table::Table;

pub fn run(quick: bool) -> Vec<Table> {
    let mut tables = Vec::new();
    for &rdt in RdtKind::crdt_benchmarks() {
        let mut t = Table::new(
            &format!("Fig 9 — {} (CRDT): SafarDB vs Hamband", rdt.name()),
            &["system", "nodes", "upd%", "rt_us", "tput_ops_us"],
        );
        let mut jobs = Vec::new();
        for system in ["SafarDB", "Hamband"] {
            for &n in nodes(quick) {
                for &u in UPDATE_SWEEP {
                    let mut cfg = match system {
                        "SafarDB" => SimConfig::safardb(WorkloadKind::Micro(rdt)),
                        _ => SimConfig::hamband(WorkloadKind::Micro(rdt)),
                    };
                    cfg.n_replicas = n;
                    cfg.update_pct = u;
                    jobs.push(((system, n, u), (cfg, cell_ops(quick))));
                }
            }
        }
        for ((system, n, u), cell, _) in run_cells_tagged(jobs) {
            t.row(vec![
                system.into(),
                n.to_string(),
                u.to_string(),
                f3(cell.rt_us),
                f3(cell.tput),
            ]);
        }
        tables.push(t);
    }
    tables
}

/// Aggregate ratios over all CRDT tables (for EXPERIMENTS.md).
pub fn headline(tables: &[Table]) -> (f64, f64) {
    let mut h_rt = Vec::new();
    let mut s_rt = Vec::new();
    let mut h_tp = Vec::new();
    let mut s_tp = Vec::new();
    for t in tables {
        for r in t.rows() {
            let (rt, tp): (f64, f64) = (r[3].parse().unwrap(), r[4].parse().unwrap());
            if r[0] == "SafarDB" {
                s_rt.push(rt);
                s_tp.push(tp);
            } else {
                h_rt.push(rt);
                h_tp.push(tp);
            }
        }
    }
    (
        crate::expt::common::geomean_ratio(&h_rt, &s_rt),
        crate::expt::common::geomean_ratio(&s_tp, &h_tp),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn headline_ratios_in_band() {
        let tables = run(true);
        assert_eq!(tables.len(), 5, "five CRDT benchmarks");
        let (rt_ratio, tput_ratio) = headline(&tables);
        // Paper: 7.0x RT, 5.3x throughput. Accept a generous band; the
        // direction and order must hold.
        assert!((3.0..16.0).contains(&rt_ratio), "rt ratio {rt_ratio}");
        assert!((3.0..16.0).contains(&tput_ratio), "tput ratio {tput_ratio}");
    }
}
