//! SafarDB launcher.
//!
//! ```text
//! safardb expt <id|all> [--quick] [--threads N] [--backend mu|raft|paxos]
//!                       [--placement single|hash|round_robin|load_aware]
//!                       [--window N]
//!                                                 reproduce a paper table/figure
//! safardb list                                    list experiment ids
//! safardb run [config.kv] [k=v ...]               run one cluster config, print report
//! safardb bench-compare <baseline.json> <current.json>
//!                                                 perf ratchet: fail on events/sec regression
//! safardb runtime-check [dir]                     load + execute the kernel runtime
//! ```
//! (hand-rolled arg parsing: the offline crate set has no clap.)
//!
//! Sweep cells fan out over worker threads (`--threads N`, the
//! `SAFARDB_THREADS` environment variable, or all available cores, in that
//! order); tables are bit-identical for any thread count.

use safardb::config::{ConsensusBackend, LeaderPlacement, SimConfig, WorkloadKind};
use safardb::engine::cluster;
use safardb::expt;
use safardb::rdt::RdtKind;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(String::as_str) {
        Some("expt") => cmd_expt(&args[1..]),
        Some("list") => {
            for id in expt::ALL {
                println!("{id}");
            }
            0
        }
        Some("run") => cmd_run(&args[1..]),
        Some("bench-compare") => cmd_bench_compare(&args[1..]),
        Some("runtime-check") => cmd_runtime_check(&args[1..]),
        _ => {
            eprintln!("usage: safardb <expt|list|run|bench-compare|runtime-check> [...]");
            eprintln!("  expt <id|all> [--quick] [--threads N] [--backend mu|raft|paxos]");
            eprintln!("                [--placement single|hash|round_robin|load_aware] [--window N]");
            eprintln!("                           reproduce a paper table/figure (see `safardb list`)");
            eprintln!("  run [config.kv] [k=v]    run one cluster and print the report");
            eprintln!("  bench-compare <baseline.json> <current.json>");
            eprintln!("                           fail if any bench cell regressed >10% events/sec");
            eprintln!("  runtime-check [dir]      verify the kernel runtime loads and executes");
            2
        }
    };
    std::process::exit(code);
}

fn parse_threads(v: &str) -> Option<usize> {
    match v.parse::<usize>() {
        Ok(n) if n >= 1 => Some(n),
        _ => None,
    }
}

fn parse_backend(v: &str) -> Option<ConsensusBackend> {
    ConsensusBackend::parse(v)
}

/// Same bounds as `SimConfig::validate` (1 = pipelining off, 64 = cap).
fn parse_window(v: &str) -> Option<u32> {
    match v.parse::<u32>() {
        Ok(w) if (1..=64).contains(&w) => Some(w),
        _ => None,
    }
}

fn cmd_expt(args: &[String]) -> i32 {
    let mut quick = false;
    let mut threads: Option<usize> = None;
    let mut backend: Option<ConsensusBackend> = None;
    let mut placement: Option<LeaderPlacement> = None;
    let mut window: Option<u32> = None;
    let mut ids: Vec<&str> = Vec::new();
    let mut i = 0;
    while i < args.len() {
        let a = args[i].as_str();
        if a == "--quick" {
            quick = true;
        } else if a == "--placement" {
            i += 1;
            let Some(v) = args.get(i) else {
                eprintln!("--placement requires a value (single|hash|round_robin|load_aware)");
                return 2;
            };
            let Some(p) = LeaderPlacement::parse(v) else {
                eprintln!("bad --placement value '{v}' (want single|hash|round_robin|load_aware)");
                return 2;
            };
            placement = Some(p);
        } else if let Some(v) = a.strip_prefix("--placement=") {
            let Some(p) = LeaderPlacement::parse(v) else {
                eprintln!("bad --placement value '{v}' (want single|hash|round_robin|load_aware)");
                return 2;
            };
            placement = Some(p);
        } else if a == "--backend" {
            i += 1;
            let Some(v) = args.get(i) else {
                eprintln!("--backend requires a value (mu|raft|paxos)");
                return 2;
            };
            let Some(b) = parse_backend(v) else {
                eprintln!("bad --backend value '{v}' (want mu|raft|paxos)");
                return 2;
            };
            backend = Some(b);
        } else if let Some(v) = a.strip_prefix("--backend=") {
            let Some(b) = parse_backend(v) else {
                eprintln!("bad --backend value '{v}' (want mu|raft|paxos)");
                return 2;
            };
            backend = Some(b);
        } else if a == "--window" {
            i += 1;
            let Some(v) = args.get(i) else {
                eprintln!("--window requires a value (1..=64)");
                return 2;
            };
            let Some(w) = parse_window(v) else {
                eprintln!("bad --window value '{v}' (want an integer in 1..=64)");
                return 2;
            };
            window = Some(w);
        } else if let Some(v) = a.strip_prefix("--window=") {
            let Some(w) = parse_window(v) else {
                eprintln!("bad --window value '{v}' (want an integer in 1..=64)");
                return 2;
            };
            window = Some(w);
        } else if a == "--threads" {
            i += 1;
            let Some(v) = args.get(i) else {
                eprintln!("--threads requires a value");
                return 2;
            };
            let Some(n) = parse_threads(v) else {
                eprintln!("bad --threads value '{v}' (want a positive integer)");
                return 2;
            };
            threads = Some(n);
        } else if let Some(v) = a.strip_prefix("--threads=") {
            let Some(n) = parse_threads(v) else {
                eprintln!("bad --threads value '{v}' (want a positive integer)");
                return 2;
            };
            threads = Some(n);
        } else if a.starts_with("--") {
            eprintln!("unknown flag '{a}'");
            return 2;
        } else {
            ids.push(a);
        }
        i += 1;
    }
    if let Some(n) = threads {
        expt::common::set_threads(n);
    }
    if let Some(b) = backend {
        // Only the backend-aware sweeps (`backends`, `chaos`) consult the
        // filter; accepting it elsewhere would silently emit unfiltered
        // (default-backend) CSVs under a backend-filtered invocation.
        let ids_for_check: Vec<&str> = if ids.is_empty() || ids == ["all"] {
            expt::ALL.to_vec()
        } else {
            ids.clone()
        };
        if ids_for_check.iter().any(|id| {
            !matches!(
                expt::canonical(id),
                Some("backends") | Some("chaos") | Some("scaleout") | Some("loadcurve")
            )
        }) {
            eprintln!(
                "--backend only applies to `expt backends`, `expt chaos`, `expt scaleout`, \
                 and `expt loadcurve`"
            );
            return 2;
        }
        expt::common::set_backend_filter(b);
        eprintln!("[backend filter: {}]", b.name());
    }
    if let Some(p) = placement {
        // Only the placement-aware sweep consults the filter; accepting it
        // elsewhere would silently emit unfiltered CSVs.
        let ids_for_check: Vec<&str> = if ids.is_empty() || ids == ["all"] {
            expt::ALL.to_vec()
        } else {
            ids.clone()
        };
        if ids_for_check.iter().any(|id| {
            !matches!(expt::canonical(id), Some("scaleout") | Some("chaos") | Some("loadcurve"))
        }) {
            eprintln!(
                "--placement only applies to `expt scaleout`, `expt chaos`, and `expt loadcurve`"
            );
            return 2;
        }
        expt::common::set_placement_filter(p);
        eprintln!("[placement filter: {}]", p.name());
    }
    if let Some(w) = window {
        // Only the window-aware sweep consults the filter; accepting it
        // elsewhere would silently emit unfiltered CSVs.
        let ids_for_check: Vec<&str> = if ids.is_empty() || ids == ["all"] {
            expt::ALL.to_vec()
        } else {
            ids.clone()
        };
        if ids_for_check.iter().any(|id| !matches!(expt::canonical(id), Some("loadcurve"))) {
            eprintln!("--window only applies to `expt loadcurve`");
            return 2;
        }
        expt::common::set_window_filter(w);
        eprintln!("[window filter: {w}]");
    }
    eprintln!("[sweep executor: {} worker thread(s)]", expt::common::configured_threads());
    let ids: Vec<&str> = if ids.is_empty() || ids == ["all"] {
        expt::ALL.to_vec()
    } else {
        ids
    };
    for id in ids {
        // Save under the canonical id so `expt fig06` and `expt all` write
        // the same results/ filenames.
        let Some(canon) = expt::canonical(id) else {
            eprintln!("unknown experiment '{id}'; try `safardb list`");
            return 2;
        };
        let Some(tables) = expt::run(canon, quick) else {
            // Reachable only if expt::ALL and run()'s dispatch drift apart.
            eprintln!("experiment '{canon}' is listed but has no dispatch arm");
            return 2;
        };
        for t in &tables {
            println!("{}", t.render());
        }
        // A placement- or window-filtered scaleout/chaos/loadcurve run
        // saves under a suffixed id so the CI matrix's legs upload
        // distinct CSVs (the suffixes compose: `loadcurve_hash_w8`).
        let mut save_id = match expt::common::placement_filter() {
            Some(p) if matches!(canon, "scaleout" | "chaos" | "loadcurve") => {
                format!("{canon}_{}", p.name())
            }
            _ => canon.to_string(),
        };
        if let Some(w) = expt::common::window_filter() {
            if canon == "loadcurve" {
                save_id = format!("{save_id}_w{w}");
            }
        }
        expt::common::save(&tables, &save_id);
        println!("[saved results/{save_id}*.csv]\n");
    }
    0
}

fn cmd_run(args: &[String]) -> i32 {
    let mut cfg = SimConfig::safardb(WorkloadKind::Micro(RdtKind::PnCounter));
    for a in args {
        if a.ends_with(".kv") || a.contains('/') {
            match std::fs::read_to_string(a) {
                Ok(body) => {
                    if let Err(e) = cfg.apply_kv(&body) {
                        eprintln!("{a}: {e}");
                        return 2;
                    }
                }
                Err(e) => {
                    eprintln!("{a}: {e}");
                    return 2;
                }
            }
        } else if let Some((k, v)) = a.split_once('=') {
            if let Err(e) = cfg.apply_kv(&format!("{k} = {v}")) {
                eprintln!("{e}");
                return 2;
            }
        } else if a.to_lowercase() == "mixed" {
            // Multi-tenant catalog scenario: heterogeneous objects behind
            // one data plane (equivalent to `objects=mixed`).
            cfg.objects = safardb::config::CatalogSpec::mixed();
        } else {
            // workload selector: rdt name / ycsb / smallbank
            cfg.workload = match a.to_lowercase().as_str() {
                "ycsb" => WorkloadKind::Ycsb,
                "smallbank" => WorkloadKind::SmallBank,
                "pn-counter" | "pncounter" => WorkloadKind::Micro(RdtKind::PnCounter),
                "lww" | "lww-register" => WorkloadKind::Micro(RdtKind::LwwRegister),
                "g-set" | "gset" => WorkloadKind::Micro(RdtKind::GSet),
                "pn-set" | "pnset" => WorkloadKind::Micro(RdtKind::PnSet),
                "2p-set" | "2pset" => WorkloadKind::Micro(RdtKind::TwoPSet),
                "account" => WorkloadKind::Micro(RdtKind::Account),
                "courseware" => WorkloadKind::Micro(RdtKind::Courseware),
                "project" => WorkloadKind::Micro(RdtKind::Project),
                "movie" => WorkloadKind::Micro(RdtKind::Movie),
                "auction" => WorkloadKind::Micro(RdtKind::Auction),
                other => {
                    eprintln!("unknown workload '{other}'");
                    return 2;
                }
            };
        }
    }
    if let Err(e) = cfg.validate() {
        eprintln!("invalid config: {e}");
        return 2;
    }
    let sys = cfg.system;
    let backend = cfg.backend;
    let batch = cfg.batch_size;
    let name = if cfg.objects.is_default() {
        cfg.workload.name()
    } else {
        format!("catalog[{}] ({} objects)", cfg.objects.label(), cfg.n_objects())
    };
    let rep = cluster::run(cfg);
    println!("system      : {}", sys.name());
    println!("backend     : {} (batch {})", backend.name(), batch);
    println!("workload    : {name}");
    println!(
        "response    : {:.3} us (p50 {:.3}, p99 {:.3})",
        rep.response_us(),
        rep.metrics.response.p50() as f64 / 1000.0,
        rep.metrics.response.p99() as f64 / 1000.0
    );
    println!("throughput  : {:.3} OPs/us", rep.throughput());
    println!("power       : {:.1} W", rep.power.total_w());
    println!("converged   : {}", rep.converged());
    println!("invariants  : {}", rep.invariants_ok);
    println!("smr commits : {}", rep.metrics.smr_commits);
    println!("rejected    : {}", rep.metrics.rejected);
    println!("elections   : {}", rep.metrics.elections);
    println!(
        "sim events  : {} ({:.2}M events/s wall)",
        rep.metrics.events,
        rep.metrics.events as f64 / rep.wall_s.max(1e-9) / 1e6
    );
    if rep.converged() && rep.invariants_ok {
        0
    } else {
        1
    }
}

/// Perf ratchet: compare a current `BENCH_engine.json` against a baseline,
/// cell by cell on the stable cell id. A cell that dropped below 90% of
/// its baseline events/sec fails the run. A baseline marked
/// `"provisional": true` (numbers measured on a different machine, e.g.
/// the committed first baseline) reports the same table but never fails —
/// the ratchet becomes blocking once a CI-measured baseline is blessed.
fn cmd_bench_compare(args: &[String]) -> i32 {
    const MAX_REGRESSION: f64 = 0.9;
    let (Some(base_path), Some(cur_path)) = (args.first(), args.get(1)) else {
        eprintln!("usage: safardb bench-compare <baseline.json> <current.json>");
        return 2;
    };
    let load = |path: &str| -> Result<safardb::util::json::Json, String> {
        let body = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        let doc = safardb::util::json::Json::parse(&body).map_err(|e| format!("{path}: {e}"))?;
        match doc.get("schema").and_then(|s| s.as_str()) {
            Some(safardb::expt::bench::SCHEMA) => Ok(doc),
            other => {
                Err(format!("{path}: schema {other:?}, want {:?}", safardb::expt::bench::SCHEMA))
            }
        }
    };
    let (base, cur) = match (load(base_path), load(cur_path)) {
        (Ok(b), Ok(c)) => (b, c),
        (b, c) => {
            for e in [b.err(), c.err()].into_iter().flatten() {
                eprintln!("bench-compare: {e}");
            }
            return 2;
        }
    };
    let provisional = base.get("provisional").and_then(|p| p.as_bool()).unwrap_or(false);
    let cells = |doc: &safardb::util::json::Json| -> Vec<(String, f64)> {
        doc.get("cells")
            .and_then(|c| c.as_arr())
            .unwrap_or(&[])
            .iter()
            .filter_map(|c| {
                let id = c.get("id")?.as_str()?.to_string();
                let eps = c.get("events_per_sec")?.as_f64()?;
                Some((id, eps))
            })
            .collect()
    };
    let base_cells = cells(&base);
    let cur_cells = cells(&cur);
    if cur_cells.is_empty() {
        eprintln!("bench-compare: {cur_path} has no cells");
        return 2;
    }

    let mut regressed = 0u32;
    println!("{:<18} {:>14} {:>14} {:>7}", "cell", "baseline", "current", "ratio");
    for (id, cur_eps) in &cur_cells {
        match base_cells.iter().find(|(bid, _)| bid == id) {
            Some((_, base_eps)) if *base_eps > 0.0 => {
                let ratio = cur_eps / base_eps;
                let flag = if ratio < MAX_REGRESSION { " REGRESSED" } else { "" };
                if ratio < MAX_REGRESSION {
                    regressed += 1;
                }
                println!("{id:<18} {base_eps:>14.0} {cur_eps:>14.0} {ratio:>7.3}{flag}");
            }
            _ => println!("{id:<18} {:>14} {cur_eps:>14.0}   (new)", "-"),
        }
    }
    for (id, _) in &base_cells {
        if !cur_cells.iter().any(|(cid, _)| cid == id) {
            eprintln!("bench-compare: baseline cell '{id}' missing from current run");
            regressed += 1;
        }
    }

    if regressed == 0 {
        println!(
            "bench-compare: OK ({} cells within {:.0}% of baseline)",
            cur_cells.len(),
            (1.0 - MAX_REGRESSION) * 100.0
        );
        0
    } else if provisional {
        println!("bench-compare: {regressed} cell(s) below baseline, but baseline is provisional — warn only");
        0
    } else {
        eprintln!("bench-compare: FAIL — {regressed} cell(s) regressed >10% events/sec");
        1
    }
}

fn cmd_runtime_check(args: &[String]) -> i32 {
    let dir = args.first().map(String::as_str).unwrap_or(safardb::runtime::DEFAULT_ARTIFACTS);
    match safardb::runtime::Runtime::load(dir) {
        Ok(rt) => {
            // Absent AOT artifacts are not an error: the reference executor
            // runs on builtin signatures (platform() says which happened).
            println!("platform : {}", rt.platform());
            println!("artifacts: {:?}", rt.names());
            let mut acc = safardb::runtime::Accelerator::new(rt);
            let v = acc
                .pn_counter_merge(&[vec![1.0, 2.0], vec![3.0, 4.0]], &[vec![0.5; 2], vec![0.5; 2]])
                .expect("pn_counter_merge");
            assert_eq!(v, vec![3.0, 5.0]);
            println!("pn_counter_merge OK ({} calls)", acc.calls());
            0
        }
        Err(e) => {
            eprintln!("runtime load failed: {e:#}");
            1
        }
    }
}
