"""Pure-jnp oracles for every Layer-1 kernel.

These are the correctness ground truth: python/tests compares each Pallas
kernel against its oracle (exact dtype-for-dtype agreement is required for
the integer kernels, allclose for f32 reductions), and the Rust scalar
paths implement the same semantics, closing the loop L1 == L2 == L3.
"""

import jax
import jax.numpy as jnp


def pn_merge_ref(p, m):
    return jnp.sum(p, axis=0) - jnp.sum(m, axis=0)


def lww_merge_ref(vals, ts):
    best = jnp.argmax(ts, axis=0)  # first max => lowest replica id on ties
    val = jnp.take_along_axis(vals, best[None, :], axis=0)[0]
    t = jnp.take_along_axis(ts, best[None, :], axis=0)[0]
    return val, t


def set_or_ref(bitmaps):
    out = bitmaps[0]
    for i in range(1, bitmaps.shape[0]):
        out = out | bitmaps[i]
    return out


def account_permissibility_ref(b0, deltas):
    def body(bal, d):
        ok = (d >= 0.0) | (bal + d >= 0.0)
        return jnp.where(ok, bal + d, bal), ok.astype(jnp.int32)

    final, accept = jax.lax.scan(body, b0[0], deltas)
    return accept, final[None]


def batch_apply_ref(state, keys, deltas):
    return state.at[keys].add(deltas)
