//! Cross-backend equivalence suite — the "Replication-Aware
//! Linearizability"-style oracle for the consensus backends: identical
//! fixed-seed workloads must drive Mu, Raft, and Paxos to the same
//! abstract RDT state.
//!
//! What "same" can mean is type-dependent, and the assertions are chosen
//! to be exact where exactness is *constructible*:
//!
//! * CRDT workloads (counter/sets) never route to the strong path and are
//!   commutative, so all three backends must be **bit-identical** — same
//!   digests, same event count, same completions.
//! * A rejection-proof Account workload (total worst-case withdrawal
//!   volume below the seed balance, so no interleaving can reject) makes
//!   the conflicting path itself byte-comparable: every backend, at every
//!   batch size, must land on identical final store digests and commit
//!   counts.
//! * Heavy WRDT workloads (Account/Auction at realistic mixes) keep the
//!   per-backend guarantees — convergence, integrity, full completion —
//!   but not byte-equality: permissibility outcomes are
//!   interleaving-dependent by design (the same reason
//!   `prop_summarization_preserves_state` carves out Account), and each
//!   backend schedules time differently.

use safardb::config::{CatalogSpec, ConsensusBackend, LeaderPlacement, SimConfig, WorkloadKind};
use safardb::engine::cluster::{self, RunReport};
use safardb::rdt::RdtKind;

fn run_backend(mut cfg: SimConfig, backend: ConsensusBackend) -> RunReport {
    cfg.backend = backend;
    let rep = cluster::run(cfg);
    assert!(rep.converged(), "{}: replicas diverged: {:?}", backend.name(), rep.digests);
    assert!(rep.invariants_ok, "{}: integrity violated", backend.name());
    rep
}

#[test]
fn crdt_workloads_are_bit_identical_across_backends() {
    // No conflicting ops → the strong path never runs, and no backend may
    // perturb the event stream even at boot (no stray timers, no refresh
    // cost). The strongest possible cross-backend assertion holds.
    for rdt in [RdtKind::PnCounter, RdtKind::GSet, RdtKind::TwoPSet] {
        for seed in [0xE0_0001u64, 0xE0_0002] {
            let mut cfg = SimConfig::safardb(WorkloadKind::Micro(rdt));
            cfg.total_ops = 8_000;
            cfg.update_pct = 30;
            cfg.seed = seed;
            let reps: Vec<RunReport> =
                ConsensusBackend::ALL.iter().map(|&b| run_backend(cfg.clone(), b)).collect();
            for rep in &reps[1..] {
                assert_eq!(
                    reps[0].digests,
                    rep.digests,
                    "{}: backend changed CRDT state",
                    rdt.name()
                );
                assert_eq!(
                    reps[0].metrics.events,
                    rep.metrics.events,
                    "{}: backend perturbed the event stream",
                    rdt.name()
                );
                assert_eq!(reps[0].metrics.total_completed(), rep.metrics.total_completed());
            }
        }
    }
}

/// Account workload that cannot reject in *any* interleaving: at 100%
/// updates and 12 total ops, worst case is 12 withdrawals at the
/// generator's 80-unit cap = 960, below the 1000 seed balance. With the
/// rejected-set pinned (empty), the final balance is the order-free sum of
/// the issued deltas — byte-comparable across backends and batch sizes.
fn rejection_proof_account(seed: u64) -> SimConfig {
    let mut cfg = SimConfig::safardb(WorkloadKind::Micro(RdtKind::Account));
    cfg.n_replicas = 4;
    cfg.update_pct = 100;
    cfg.total_ops = 12;
    cfg.seed = seed;
    cfg
}

#[test]
fn conflicting_path_digests_identical_across_backends() {
    for seed in [0xACC_0001u64, 0xACC_0002, 0xACC_0003] {
        let cfg = rejection_proof_account(seed);
        let reps: Vec<RunReport> =
            ConsensusBackend::ALL.iter().map(|&b| run_backend(cfg.clone(), b)).collect();
        for (i, rep) in reps.iter().enumerate() {
            assert_eq!(rep.metrics.rejected, 0, "workload is rejection-proof by construction");
            assert_eq!(
                reps[0].digests[0], rep.digests[0],
                "{}: conflicting-path state diverged from mu (seed {seed:#x})",
                ConsensusBackend::ALL[i].name()
            );
            assert_eq!(
                reps[0].metrics.smr_commits, rep.metrics.smr_commits,
                "{}: commit count diverged (seed {seed:#x})",
                ConsensusBackend::ALL[i].name()
            );
        }
    }
}

#[test]
fn batched_runs_reproduce_unbatched_digests_on_conflicting_path() {
    // Leader-side log-entry batching may re-time commits, never change
    // them: with rejections pinned off, any batch size must reproduce the
    // unbatched digest under every backend.
    for backend in ConsensusBackend::ALL {
        let base = run_backend(rejection_proof_account(0xBA_7C4), backend);
        for batch in [4u32, 16] {
            let mut cfg = rejection_proof_account(0xBA_7C4);
            cfg.batch_size = batch;
            let rep = run_backend(cfg, backend);
            assert_eq!(
                base.digests[0],
                rep.digests[0],
                "{} batch={batch}: batching changed outcomes",
                backend.name()
            );
            assert_eq!(base.metrics.rejected, rep.metrics.rejected);
        }
    }
}

/// Mixed catalog that cannot reject in *any* interleaving: the counter and
/// set objects are commutative and rejection-free, and each Account object
/// seeds a 1000 balance while the whole run issues only 12 updates of at
/// most 80 withdrawal units — so even if every op lands on one account, no
/// ordering can reject. Rejected-set pinned empty, the converged state is
/// the order-free fold of the issued ops: byte-comparable across backends
/// and batch sizes, object by object.
fn rejection_proof_mixed_catalog(seed: u64) -> SimConfig {
    let mut cfg = SimConfig::safardb(WorkloadKind::Micro(RdtKind::Account));
    cfg.objects = CatalogSpec::parse("counter:2,gset:1,account:2").unwrap();
    cfg.n_replicas = 4;
    cfg.update_pct = 100;
    cfg.total_ops = 12;
    cfg.seed = seed;
    cfg
}

#[test]
fn mixed_catalog_digests_identical_across_backends() {
    for seed in [0x0CA7_0001u64, 0x0CA7_0002] {
        let cfg = rejection_proof_mixed_catalog(seed);
        let reps: Vec<RunReport> =
            ConsensusBackend::ALL.iter().map(|&b| run_backend(cfg.clone(), b)).collect();
        for (i, rep) in reps.iter().enumerate() {
            assert!(rep.converged_per_object(), "per-object convergence");
            assert_eq!(rep.metrics.rejected, 0, "workload is rejection-proof by construction");
            assert_eq!(
                reps[0].object_digests[0], rep.object_digests[0],
                "{}: mixed-catalog state diverged from mu (seed {seed:#x})",
                ConsensusBackend::ALL[i].name()
            );
            assert_eq!(
                reps[0].metrics.smr_commits, rep.metrics.smr_commits,
                "{}: commit count diverged (seed {seed:#x})",
                ConsensusBackend::ALL[i].name()
            );
        }
    }
}

#[test]
fn mixed_catalog_batched_matches_unbatched_across_backends() {
    for backend in ConsensusBackend::ALL {
        let base = run_backend(rejection_proof_mixed_catalog(0x0CA7_BA7C), backend);
        for batch in [4u32, 16] {
            let mut cfg = rejection_proof_mixed_catalog(0x0CA7_BA7C);
            cfg.batch_size = batch;
            let rep = run_backend(cfg, backend);
            assert_eq!(
                base.object_digests[0],
                rep.object_digests[0],
                "{} batch={batch}: batching changed mixed-catalog outcomes",
                backend.name()
            );
            assert_eq!(base.metrics.rejected, rep.metrics.rejected);
        }
    }
}

#[test]
fn sharded_placement_digests_match_single_on_rejection_proof_catalogs() {
    // Sharding leadership re-times commits (per-group leaders run
    // concurrently) but must never change them: with rejections pinned off,
    // hash placement must land on exactly the single-leader digests and
    // commit counts, per backend, on both a one-group and a five-group
    // catalog.
    for backend in ConsensusBackend::ALL {
        for (label, mk) in [
            ("account", rejection_proof_account as fn(u64) -> SimConfig),
            ("mixed", rejection_proof_mixed_catalog as fn(u64) -> SimConfig),
        ] {
            for seed in [0x5AAD_0001u64, 0x5AAD_0002] {
                let single = run_backend(mk(seed), backend);
                let mut cfg = mk(seed);
                cfg.placement = LeaderPlacement::Hash;
                let sharded = run_backend(cfg, backend);
                assert!(sharded.converged_per_object(), "per-object convergence");
                assert_eq!(
                    single.object_digests[0],
                    sharded.object_digests[0],
                    "{}/{label}: hash placement changed outcomes (seed {seed:#x})",
                    backend.name()
                );
                assert_eq!(
                    single.metrics.smr_commits,
                    sharded.metrics.smr_commits,
                    "{}/{label}: hash placement changed commit count (seed {seed:#x})",
                    backend.name()
                );
                assert_eq!(sharded.metrics.rejected, 0, "workload is rejection-proof");
                // Telemetry sanity: every group has exactly one leader.
                assert_eq!(
                    sharded.groups_led.iter().sum::<u64>() as usize,
                    sharded.group_leaders.len(),
                    "{}/{label}: groups_led must partition the groups",
                    backend.name()
                );
            }
        }
    }
}

#[test]
fn placement_single_is_bit_identical_to_seed_behavior() {
    // placement=single is the default and must not perturb anything —
    // digests, event counts, completions all bit-equal to an explicit
    // Single run (the config default) on a realistic WRDT mix.
    let mut cfg = SimConfig::safardb(WorkloadKind::Micro(RdtKind::Account));
    cfg.n_replicas = 4;
    cfg.update_pct = 30;
    cfg.total_ops = 6_000;
    cfg.seed = 0x51_0617;
    for backend in ConsensusBackend::ALL {
        let a = run_backend(cfg.clone(), backend);
        let mut explicit = cfg.clone();
        explicit.placement = LeaderPlacement::Single;
        let b = run_backend(explicit, backend);
        assert_eq!(a.digests, b.digests, "{}", backend.name());
        assert_eq!(a.metrics.events, b.metrics.events, "{}", backend.name());
        assert_eq!(a.metrics.total_completed(), b.metrics.total_completed());
    }
}

#[test]
fn wrdt_workloads_converge_under_every_backend() {
    // Realistic conflicting mixes: rejections are interleaving-dependent,
    // so the oracle is per-backend convergence + integrity + full
    // completion, with the strong path demonstrably exercised.
    for rdt in [RdtKind::Account, RdtKind::Auction] {
        let mut cfg = SimConfig::safardb(WorkloadKind::Micro(rdt));
        cfg.n_replicas = 4;
        cfg.update_pct = 30;
        cfg.total_ops = 10_000;
        cfg.seed = 0xE9_0000 + rdt as u64;
        let target = cfg.total_ops / cfg.n_replicas as u64 * cfg.n_replicas as u64;
        for backend in ConsensusBackend::ALL {
            let rep = run_backend(cfg.clone(), backend);
            assert_eq!(
                rep.metrics.total_completed(),
                target,
                "{}/{}: lost client completions",
                backend.name(),
                rdt.name()
            );
            assert!(
                rep.metrics.smr_commits > 0,
                "{}/{}: strong path unexercised",
                backend.name(),
                rdt.name()
            );
        }
    }
}

#[test]
fn backend_knob_reaches_the_wire() {
    // Sanity that the knob actually swaps protocols (not just labels):
    // Paxos acks ride wire completions (no RaftAck verbs), Raft acks are
    // logical verbs, and per-op verb counts differ accordingly.
    let cfg = |b: ConsensusBackend| {
        let mut c = SimConfig::safardb(WorkloadKind::Micro(RdtKind::Account));
        c.n_replicas = 3;
        c.update_pct = 50;
        c.total_ops = 3_000;
        c.backend = b;
        c
    };
    let mu = cluster::run(cfg(ConsensusBackend::Mu));
    let raft = cluster::run(cfg(ConsensusBackend::Raft));
    let paxos = cluster::run(cfg(ConsensusBackend::Paxos));
    assert!(mu.metrics.smr_commits > 0);
    assert!(raft.metrics.smr_commits > 0);
    assert!(paxos.metrics.smr_commits > 0);
    // Mu's 4-round pipeline puts strictly more verbs on the wire per
    // commit than Paxos's single one-sided write round.
    let mu_rate = mu.metrics.verbs as f64 / mu.metrics.smr_commits as f64;
    let paxos_rate = paxos.metrics.verbs as f64 / paxos.metrics.smr_commits as f64;
    assert!(
        mu_rate > paxos_rate,
        "expected Mu to spend more verbs per commit: mu={mu_rate:.2} paxos={paxos_rate:.2}"
    );
}
