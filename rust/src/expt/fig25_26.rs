//! Figs 25/26 (appendix D.1): Courseware leader and mean-follower
//! execution times across 3–8 replicas and 15/20/25 % writes.
//!
//! Expected shape: leader time grows with both write % (more conflicting
//! ops) and replica count (more followers to coordinate); follower time
//! *shrinks* with replica count (fewer calls each) and only marginally
//! grows with write %.

use crate::config::{SimConfig, WorkloadKind};
use crate::expt::common::{cell_ops, nodes, run_cells_tagged, UPDATE_SWEEP};
use crate::rdt::RdtKind;
use crate::util::table::Table;

pub fn run(quick: bool) -> Vec<Table> {
    let mut t = Table::new(
        "Figs 25/26 — Courseware leader & follower execution time (ms)",
        &["nodes", "upd%", "leader_ms", "follower_mean_ms"],
    );
    let mut jobs = Vec::new();
    for &n in nodes(quick) {
        for &u in UPDATE_SWEEP {
            let mut cfg = SimConfig::safardb(WorkloadKind::Micro(RdtKind::Courseware));
            cfg.n_replicas = n;
            cfg.update_pct = u;
            jobs.push(((n, u), (cfg, cell_ops(quick))));
        }
    }
    for ((n, u), _, rep) in run_cells_tagged(jobs) {
        let (l, f) = rep.metrics.leader_vs_followers(rep.leader);
        t.row(vec![
            n.to_string(),
            u.to_string(),
            format!("{:.3}", l as f64 / 1e6),
            format!("{:.3}", f / 1e6),
        ]);
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn leader_grows_with_writes_follower_shrinks_with_nodes() {
        let t = &run(true)[0];
        let get = |n: &str, u: &str, col: usize| -> f64 {
            t.rows().iter().find(|r| r[0] == n && r[1] == u).unwrap()[col].parse().unwrap()
        };
        // Leader time increases with write percentage (fixed nodes).
        assert!(get("8", "25", 2) > get("8", "15", 2), "leader grows with writes");
        // Follower mean decreases with node count (fixed write %).
        assert!(get("3", "15", 3) > get("8", "15", 3), "follower shrinks with nodes");
    }
}
