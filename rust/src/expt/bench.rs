//! Engine hot-path benchmark (`expt bench`) — the §Perf ratchet grid.
//!
//! Runs a pinned canonical cell grid (consensus backend × batch size ×
//! catalog shape) and reports, per cell, the event-loop rate
//! (events/sec), simulation wall time, and peak RSS — the three numbers
//! the CI perf-ratchet job compares against the committed
//! `BENCH_engine.json` baseline (`safardb bench-compare`). Event counts
//! and state digests are part of the output on purpose: they are
//! bit-reproducible for a fixed seed, so the bench doubles as a
//! determinism probe (the `bench` integration test asserts them equal
//! across runs and thread counts), and any optimization that changes
//! them is a correctness bug, not a speedup.
//!
//! Cells deliberately engage every plane: the Account WRDT (conflicting
//! withdraws → strong path) and the `mixed` 9-object catalog, each under
//! batching off (1) and on (8), per backend, plus pipelined (window 8)
//! Account cells for the Raft and Paxos backends — 14 cells total.

use crate::config::{CatalogSpec, ConsensusBackend, SimConfig, WorkloadKind};
use crate::expt::common::{self, CellJob};
use crate::rdt::RdtKind;
use crate::util::json::Json;
use crate::util::table::Table;

/// Schema tag stamped into `BENCH_engine.json`; bump on layout changes so
/// the ratchet comparison never diffs across incompatible formats.
pub const SCHEMA: &str = "safardb-bench-v1";

/// Batch axis of the grid (off / on).
pub const BATCHES: &[u32] = &[1, 8];

/// One measured bench cell (the unit the ratchet compares).
#[derive(Clone, Debug)]
pub struct BenchCell {
    /// Stable cell id (`<backend>_b<batch>_<objects>`, with a `w<window>`
    /// suffix after the batch for pipelined cells) — the join key for
    /// baseline comparison.
    pub id: String,
    pub backend: &'static str,
    pub batch: u32,
    /// Strong-plane pipeline depth the cell ran under (1 = stop-and-wait).
    pub window: u32,
    pub objects: &'static str,
    /// Leadership placement the cell ran under (the pinned grid is all
    /// `single`; recorded so sharded cells can join the grid later without
    /// a schema bump).
    pub placement: &'static str,
    pub ops: u64,
    /// Simulator events processed — deterministic for a fixed seed.
    pub events: u64,
    pub wall_s: f64,
    pub events_per_sec: f64,
    /// Process peak RSS in kB after this cell (Linux `VmHWM`; 0 elsewhere).
    /// Monotone across cells — a memory ceiling, not a per-cell delta.
    pub peak_rss_kb: u64,
    /// Replica 0's converged state digest — deterministic for a fixed seed.
    pub digest: u64,
    /// p99 consensus-round commit latency in µs (0 when nothing conflicted).
    pub smr_round_p99_us: f64,
    /// Deepest strong-plane pipeline any shard reached (≤ `window`).
    pub inflight_max: u64,
}

/// Ops per bench cell. Smaller than the figure sweeps: the grid exists to
/// time the event loop, and 14 cells must fit a CI leg.
pub fn bench_ops(quick: bool) -> u64 {
    if quick {
        8_000
    } else {
        48_000
    }
}

/// (cell id, backend name, batch, window, catalog label) — a cell's
/// identity.
type BenchMeta = (String, &'static str, u32, u32, &'static str);

fn grid(quick: bool) -> Vec<(BenchMeta, CellJob)> {
    let mut jobs = Vec::new();
    for backend in ConsensusBackend::ALL {
        for &batch in BATCHES {
            for objects in ["account", "mixed"] {
                let mut cfg = SimConfig::safardb(WorkloadKind::Micro(RdtKind::Account));
                if objects == "mixed" {
                    cfg.objects = CatalogSpec::mixed();
                }
                cfg.backend = backend;
                cfg.batch_size = batch;
                cfg.update_pct = 25;
                cfg.seed = 0x5AFA_BE7C;
                let id = format!("{}_b{batch}_{objects}", backend.name());
                jobs.push(((id, backend.name(), batch, 1, objects), (cfg, bench_ops(quick))));
            }
        }
    }
    // Pipelined strong-plane cells: window 8, unbatched, on the
    // conflicting-heavy Account catalog for the two quorum-ack backends
    // (pipelining moves their round-trip-bound commit path the most).
    for backend in [ConsensusBackend::Raft, ConsensusBackend::Paxos] {
        let mut cfg = SimConfig::safardb(WorkloadKind::Micro(RdtKind::Account));
        cfg.backend = backend;
        cfg.batch_size = 1;
        cfg.window = 8;
        cfg.update_pct = 25;
        cfg.seed = 0x5AFA_BE7C;
        let id = format!("{}_b1w8_account", backend.name());
        jobs.push(((id, backend.name(), 1, 8, "account"), (cfg, bench_ops(quick))));
    }
    jobs
}

/// Cell ids of the canonical grid, in grid order — the join keys a
/// committed baseline must cover. Cheap (no simulation).
pub fn grid_ids() -> Vec<String> {
    grid(true).into_iter().map(|((id, ..), _)| id).collect()
}

/// Run the canonical grid on `threads` workers. Taking the thread count
/// explicitly (instead of the global `--threads` knob) lets the
/// determinism test drive the same grid at 1 and 2 workers.
pub fn bench_cells(quick: bool, threads: usize) -> Vec<BenchCell> {
    let (metas, cells): (Vec<BenchMeta>, Vec<CellJob>) = grid(quick).into_iter().unzip();
    let results = common::run_cells(cells, threads);
    metas
        .into_iter()
        .zip(results)
        .map(|((id, backend, batch, window, objects), (_, rep))| {
            let events = rep.metrics.events;
            let wall_s = rep.wall_s;
            BenchCell {
                id,
                backend,
                batch,
                window,
                objects,
                placement: "single",
                ops: bench_ops(quick),
                events,
                wall_s,
                events_per_sec: if wall_s > 0.0 { events as f64 / wall_s } else { 0.0 },
                peak_rss_kb: peak_rss_kb(),
                digest: rep.digests[0],
                smr_round_p99_us: rep.metrics.smr_round.p99() as f64 / 1_000.0,
                inflight_max: rep.metrics.inflight_max_overall(),
            }
        })
        .collect()
}

/// Process peak resident set in kB (`VmHWM` from `/proc/self/status`).
/// Returns 0 where procfs is unavailable — the ratchet only compares
/// events/sec, so RSS is telemetry, not a gate.
pub fn peak_rss_kb() -> u64 {
    if let Ok(status) = std::fs::read_to_string("/proc/self/status") {
        for line in status.lines() {
            if let Some(rest) = line.strip_prefix("VmHWM:") {
                return rest.trim().trim_end_matches("kB").trim().parse().unwrap_or(0);
            }
        }
    }
    0
}

/// Serialize cells to the `BENCH_engine.json` document. `provisional`
/// marks a baseline measured on a different machine than the comparison
/// will run on (e.g. the committed first baseline) — `bench-compare`
/// warns instead of failing against a provisional baseline.
pub fn to_json(cells: &[BenchCell], quick: bool, provisional: bool) -> Json {
    let mut doc = Json::obj();
    doc.set("schema", SCHEMA.into());
    doc.set("quick", Json::Bool(quick));
    doc.set("provisional", Json::Bool(provisional));
    let arr = cells
        .iter()
        .map(|c| {
            let mut o = Json::obj();
            o.set("id", c.id.as_str().into());
            o.set("backend", c.backend.into());
            o.set("batch", Json::Num(c.batch as f64));
            o.set("window", Json::Num(c.window as f64));
            o.set("objects", c.objects.into());
            o.set("placement", c.placement.into());
            o.set("ops", c.ops.into());
            o.set("events", c.events.into());
            o.set("wall_s", c.wall_s.into());
            o.set("events_per_sec", c.events_per_sec.into());
            o.set("peak_rss_kb", c.peak_rss_kb.into());
            // Hex string: a u64 digest does not fit f64 exactly.
            o.set("digest", format!("{:016x}", c.digest).as_str().into());
            o.set("smr_round_p99_us", c.smr_round_p99_us.into());
            o.set("inflight_max", c.inflight_max.into());
            o
        })
        .collect();
    doc.set("cells", Json::Arr(arr));
    doc
}

pub fn run(quick: bool) -> Vec<Table> {
    let cells = bench_cells(quick, common::configured_threads());
    let doc = to_json(&cells, quick, false);
    let dir = std::path::Path::new("results");
    let _ = std::fs::create_dir_all(dir);
    let _ = std::fs::write(dir.join("BENCH_engine.json"), doc.render() + "\n");
    eprintln!("[bench] wrote results/BENCH_engine.json ({} cells)", cells.len());

    let mut t = Table::new(
        "Bench — engine event-loop rate per canonical cell",
        &[
            "cell",
            "backend",
            "batch",
            "window",
            "objects",
            "events",
            "wall_s",
            "events_per_sec",
            "peak_rss_kb",
            "round_p99_us",
            "inflight_max",
        ],
    );
    for c in &cells {
        t.row(vec![
            c.id.clone(),
            c.backend.into(),
            c.batch.to_string(),
            c.window.to_string(),
            c.objects.into(),
            c.events.to_string(),
            format!("{:.3}", c.wall_s),
            format!("{:.0}", c.events_per_sec),
            c.peak_rss_kb.to_string(),
            format!("{:.3}", c.smr_round_p99_us),
            c.inflight_max.to_string(),
        ]);
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_ids_are_unique_and_stable() {
        let g = grid(true);
        assert_eq!(g.len(), 14, "3 backends x 2 batches x 2 catalogs + 2 pipelined");
        let mut ids: Vec<&str> = g.iter().map(|((id, ..), _)| id.as_str()).collect();
        assert!(ids.contains(&"mu_b1_account"));
        assert!(ids.contains(&"paxos_b8_mixed"));
        assert!(ids.contains(&"raft_b1w8_account"));
        assert!(ids.contains(&"paxos_b1w8_account"));
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 14, "cell ids must be unique join keys");
    }

    #[test]
    fn json_document_shape() {
        let cells = vec![BenchCell {
            id: "mu_b1_account".into(),
            backend: "mu",
            batch: 1,
            window: 1,
            objects: "account",
            placement: "single",
            ops: 8000,
            events: 123456,
            wall_s: 0.25,
            events_per_sec: 493824.0,
            peak_rss_kb: 4096,
            digest: 0xDEAD_BEEF,
            smr_round_p99_us: 4.5,
            inflight_max: 1,
        }];
        let s = to_json(&cells, true, true).render();
        assert!(s.contains(r#""schema":"safardb-bench-v1""#));
        assert!(s.contains(r#""provisional":true"#));
        assert!(s.contains(r#""placement":"single""#));
        assert!(s.contains(r#""id":"mu_b1_account""#));
        assert!(s.contains(r#""window":1"#));
        assert!(s.contains(r#""inflight_max":1"#));
        assert!(s.contains(r#""digest":"00000000deadbeef""#));
    }

    #[test]
    fn peak_rss_is_sane_on_linux() {
        let kb = peak_rss_kb();
        if cfg!(target_os = "linux") {
            assert!(kb > 0, "VmHWM should parse on Linux");
        }
    }
}
