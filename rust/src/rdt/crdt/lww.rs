//! LWW-Register (Table A.1): assign(value) with unique timestamps ensures
//! a total order of assignments; the register keeps the latest write.
//!
//! Timestamps are supplied by the engine as `(virtual_time << 8) | origin`,
//! which makes them globally unique and makes merge order-free. Ties (which
//! cannot occur with engine timestamps) resolve to the lowest origin — the
//! same argmax-first rule as the `lww_merge` kernel and its oracle.

use crate::rdt::{mix64, mix_f64, Category, OpCall, QueryValue, Rdt, RdtKind};
use crate::util::rng::Rng;

pub const OP_ASSIGN: u8 = 0;

#[derive(Clone, Debug, Default)]
pub struct LwwRegister {
    value: f64,
    ts: u64,
    ts_origin: usize,
}

impl LwwRegister {
    pub fn value(&self) -> f64 {
        self.value
    }

    pub fn timestamp(&self) -> u64 {
        self.ts
    }
}

impl Rdt for LwwRegister {
    fn clone_box(&self) -> Box<dyn Rdt> {
        Box::new(self.clone())
    }

    fn kind(&self) -> RdtKind {
        RdtKind::LwwRegister
    }

    fn category(&self, _opcode: u8) -> Category {
        // assign is reducible (Table A.1): a local run of assigns summarizes
        // to the one with the highest timestamp.
        Category::Reducible
    }

    fn sync_groups(&self) -> u8 {
        0
    }

    fn permissible(&self, op: &OpCall) -> bool {
        op.is_query() || op.opcode == OP_ASSIGN
    }

    fn apply(&mut self, op: &OpCall) -> bool {
        debug_assert_eq!(op.opcode, OP_ASSIGN);
        // Strictly newer timestamp wins; on a timestamp tie the lowest
        // origin wins (argmax-first, matching the lww_merge kernel). The
        // initial state (ts == 0) is older than any engine timestamp.
        let newer = op.a > self.ts || (op.a == self.ts && self.ts != 0 && op.origin < self.ts_origin);
        if newer {
            self.value = op.x;
            self.ts = op.a;
            self.ts_origin = op.origin;
            true
        } else {
            false
        }
    }

    fn query(&self) -> QueryValue {
        QueryValue::Float(self.value)
    }

    fn state_digest(&self) -> u64 {
        mix_f64(self.value) ^ mix64(self.ts).rotate_left(7)
    }

    fn gen_update(&self, rng: &mut Rng) -> OpCall {
        // Timestamp (arg a) is overwritten by the engine at issue time.
        OpCall::new(OP_ASSIGN, 0, 0, rng.gen_f64_range(-1e6, 1e6))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assign(ts: u64, origin: usize, x: f64) -> OpCall {
        let mut o = OpCall::new(OP_ASSIGN, ts, 0, x);
        o.origin = origin;
        o
    }

    #[test]
    fn latest_timestamp_wins() {
        let mut r = LwwRegister::default();
        r.apply(&assign(10, 0, 1.0));
        r.apply(&assign(5, 1, 2.0));
        assert_eq!(r.value(), 1.0);
        r.apply(&assign(20, 1, 3.0));
        assert_eq!(r.value(), 3.0);
    }

    #[test]
    fn order_free_merge() {
        let ops = [assign(10, 0, 1.0), assign(30, 2, 3.0), assign(20, 1, 2.0)];
        let mut a = LwwRegister::default();
        let mut b = LwwRegister::default();
        for o in &ops {
            a.apply(o);
        }
        for o in ops.iter().rev() {
            b.apply(o);
        }
        assert_eq!(a.state_digest(), b.state_digest());
        assert_eq!(a.value(), 3.0);
        assert_eq!(b.value(), 3.0);
    }

    #[test]
    fn tie_resolves_to_lowest_origin() {
        // Matches lww_merge kernel's argmax-first rule.
        let mut a = LwwRegister::default();
        a.apply(&assign(7, 2, 9.0));
        a.apply(&assign(7, 0, 1.0));
        let mut b = LwwRegister::default();
        b.apply(&assign(7, 0, 1.0));
        b.apply(&assign(7, 2, 9.0));
        assert_eq!(a.value(), 1.0);
        assert_eq!(b.value(), 1.0);
        assert_eq!(a.state_digest(), b.state_digest());
    }
}
