//! Chaos — deterministic multi-fault schedules × consensus backend ×
//! cluster size, reporting the paper's resilience story (§3 fault model,
//! §5.3 crash experiments) as a per-incident fault timeline: injection
//! time, heartbeat detection latency, unavailability window, and
//! re-election count, alongside the run's response time / throughput.
//!
//! Every cell must converge with invariants intact (`run_cell` hard-fails
//! otherwise), so this sweep doubles as the chaos acceptance gate: a
//! leader crash *during* a partition, lossy links, and delay spikes all
//! terminate in a consistent cluster on every backend. The CI smoke leg
//! (`expt chaos --quick --threads 2`) runs one schedule per backend, and a
//! second leg adds `--placement hash` to run the same schedules over a
//! 16-group sharded strong plane (partition minorities must abdicate per
//! group, not per node).
//!
//! With `--placement` set the workload switches to a 16-instance Account
//! catalog (zipf 0.6) so the placement table has real groups to spread;
//! without it the single-object default exercises the single-leader path.

use crate::config::{CatalogSpec, ConsensusBackend, FaultSchedule, SimConfig, WorkloadKind};
use crate::expt::common::{backend_filter, f3, placement_filter, run_cells_tagged};
use crate::rdt::RdtKind;
use crate::util::table::Table;

/// Named schedules, in increasing nastiness. `quick` keeps the acceptance
/// scenario only (leader crash mid-partition, then heal).
fn schedules(quick: bool) -> &'static [(&'static str, &'static str)] {
    const ALL: &[(&str, &str)] = &[
        ("follower-crash", "crash@40:2"),
        ("crash-recover", "crash@30:2,recover@60:2"),
        ("partition-heal", "partition@35:1-2,heal@65"),
        ("leader-crash-partitioned", "partition@40:1-2,crash@50:leader,heal@70"),
        ("flaky-link", "drop@25:0-1x3,delay@35:0-2x300u65"),
    ];
    if quick {
        &ALL[3..4]
    } else {
        ALL
    }
}

pub fn run(quick: bool) -> Vec<Table> {
    let backends: Vec<ConsensusBackend> = match backend_filter() {
        Some(b) => vec![b],
        None => ConsensusBackend::ALL.to_vec(),
    };
    let nodes: &[usize] = if quick { &[5] } else { &[4, 6] };
    let ops: u64 = if quick { 12_000 } else { 40_000 };

    let mut t = Table::new(
        "Chaos — fault schedules × backend (Account, 25% updates)",
        &[
            "schedule",
            "backend",
            "nodes",
            "incident",
            "action",
            "injected_us",
            "detect_us",
            "unavail_us",
            "elections",
            "rt_us",
            "tput_ops_us",
        ],
    );
    let mut jobs = Vec::new();
    for (si, &(name, sched)) in schedules(quick).iter().enumerate() {
        for (bi, &backend) in backends.iter().enumerate() {
            for &n in nodes {
                let mut cfg = SimConfig::safardb(WorkloadKind::Micro(RdtKind::Account));
                cfg.backend = backend;
                cfg.n_replicas = n;
                cfg.update_pct = 25;
                cfg.fault = FaultSchedule::parse(sched).expect("named schedule parses");
                if let Some(p) = placement_filter() {
                    cfg.placement = p;
                    cfg.objects = CatalogSpec::parse("account:16").expect("catalog spec parses");
                    cfg.objects.zipf_theta = 0.6;
                }
                cfg.seed = 0xC4A0_5000 + (si as u64) * 0x101 + (bi as u64) * 0x11 + n as u64;
                jobs.push(((name, backend, n), (cfg, ops)));
            }
        }
    }
    for ((name, backend, n), cell, rep) in run_cells_tagged(jobs) {
        for (i, inc) in rep.fault_timeline.iter().enumerate() {
            t.row(vec![
                name.to_string(),
                backend.name().into(),
                n.to_string(),
                i.to_string(),
                inc.label.clone(),
                f3(inc.injected_ns as f64 / 1_000.0),
                inc.detect_ns
                    .map(|d| f3((d - inc.injected_ns) as f64 / 1_000.0))
                    .unwrap_or_else(|| "-".into()),
                f3(inc.unavailable_ns as f64 / 1_000.0),
                inc.elections.to_string(),
                f3(cell.rt_us),
                f3(cell.tput),
            ]);
        }
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_sweep_reports_per_incident_telemetry() {
        crate::expt::common::set_threads(2);
        let t = &run(true)[0];
        // One schedule (3 incidents) per backend — unless a backend filter
        // narrowed the matrix.
        let backends = match backend_filter() {
            Some(_) => 1,
            None => ConsensusBackend::ALL.len(),
        };
        assert_eq!(t.rows().len(), 3 * backends, "3 incidents per cell");
        for row in t.rows() {
            assert!(
                ["partition:1-2", "heal"].contains(&row[4].as_str())
                    || row[4].starts_with("crash:"),
                "unexpected incident label {}",
                row[4]
            );
        }
        // The leader crash must have been detected and cost a bounded
        // unavailability window, with at least one re-election.
        let crash_rows: Vec<_> =
            t.rows().iter().filter(|r| r[4].starts_with("crash:")).collect();
        assert_eq!(crash_rows.len(), backends);
        for r in crash_rows {
            assert_ne!(r[6], "-", "leader crash must be detected");
            assert!(r[8].parse::<u64>().unwrap() >= 1, "re-election after leader crash");
            assert!(r[7].parse::<f64>().unwrap() > 0.0, "unavailability window recorded");
        }
    }
}
