//! Mu [3] leader-side state machine (§4.4 Replication Plane), one instance
//! per synchronization group.
//!
//! Per conflicting transaction the leader runs, as the paper describes:
//!   Prepare: RDMA-read followers' min-proposal registers → RDMA-write the
//!   next highest proposal number → RDMA-read the target log slot at each
//!   follower (adopting the highest-proposal non-empty entry if any) →
//!   Accept: execute and RDMA-write the entry to followers' logs (standard
//!   Write, or RPC Write-Through which also updates follower state
//!   directly, skipping their log poll).
//!
//! The automaton is *pure*: it emits [`Round`]s; the engine fans each round
//! out to the current live follower set over the simulated fabric and feeds
//! responses back. Each round completes on a majority quorum (leader
//! included). NACKed/crashed followers are counted as failures; if failures
//! make quorum impossible the instance stalls and the engine retries after
//! the follower list is refreshed by the Leader Switch Plane.
//!
//! With a window > 1 the instance keeps several transactions in flight at
//! contiguous slots: their Prepare phases (ReadMinProposals, WriteProposal,
//! ReadSlots) overlap freely and quorums collect out of order, but the
//! Accept entry — where the engine runs permissibility, applies, and writes
//! the log slot — is serialized in slot order behind an execution cursor,
//! and commits release in slot order behind the commit cursor (the deque
//! front). Every phase fan-out carries a fresh `rid` nonce; the engine
//! tags tokens with it and the instance routes responses back to the
//! owning round (stale rids fall on the floor).

use std::collections::VecDeque;

use crate::rdt::OpCall;

/// One fan-out round to the follower set.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Round {
    /// RDMA read each follower's min-proposal register.
    ReadMinProposals,
    /// RDMA write the chosen proposal number.
    WriteProposal { proposal: u64 },
    /// RDMA read the log slot the leader intends to use.
    ReadSlots { slot: u64 },
    /// Accept: RDMA write (or RPC write-through) the entry. `adopted` is
    /// true when the entry was recovered from a follower's slot rather
    /// than proposed by this leader.
    WriteLog { slot: u64, proposal: u64, op: OpCall, adopted: bool },
}

/// What the engine should do after feeding a response. `Next` carries the
/// rid nonce of the new phase fan-out — the engine stamps it on the
/// round's completion tokens.
#[derive(Clone, Debug, PartialEq)]
pub enum Step {
    /// Nothing yet — keep feeding responses.
    Wait,
    /// Start the next round (previous one reached quorum).
    Next(u64, Round),
    /// The entry in `slot` is committed; `op` must be applied at the leader
    /// and (if `adopted`) the originally proposed op must be re-submitted.
    Commit { slot: u64, proposal: u64, op: OpCall, adopted: Option<OpCall> },
    /// Quorum unreachable with the current follower set.
    Stall,
}

/// Response payloads the engine feeds back.
#[derive(Clone, Copy, Debug)]
pub enum Resp {
    MinProposal(u64),
    Ack,
    Slot(Option<(u64, OpCall)>),
    Failure,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Phase {
    ReadProposals,
    WriteProposal,
    ReadSlots,
    /// ReadSlots quorum reached, but an earlier round has not entered
    /// Accept yet: parked behind the execution cursor.
    AcceptWait,
    Accept,
}

/// One in-flight transaction's consensus state (a window stage).
#[derive(Debug)]
struct MuRound {
    /// Nonce of the in-flight phase fan-out (fresh per phase).
    rid: u64,
    phase: Phase,
    /// Followers targeted in the in-flight phase.
    targeted: u32,
    responded: u32,
    failed: u32,
    proposal: u64,
    slot: u64,
    current_op: Option<OpCall>,
    /// Originally submitted op when a foreign entry got adopted.
    original_op: Option<OpCall>,
    /// Highest-proposal non-empty slot seen during ReadSlots.
    adopted: Option<(u64, OpCall)>,
    /// The Accept entry is a foreign adoption (rides `Round::WriteLog`).
    was_adopted: bool,
    /// Accept quorum reached but an earlier round hasn't: committed out of
    /// order, released strictly in slot order.
    committed: bool,
}

#[derive(Debug)]
pub struct MuInstance {
    pub group: u8,
    /// Cluster size (quorum = majority of n, leader counts as one vote).
    n: usize,
    /// Pipeline depth: concurrent rounds at contiguous slots.
    window: usize,
    rounds: VecDeque<MuRound>,
    next_rid: u64,
    max_seen_proposal: u64,
    queue: VecDeque<OpCall>,
    pub committed: u64,
    pub restarts: u64,
}

impl MuInstance {
    pub fn new(group: u8, n: usize) -> Self {
        Self::with_window(group, n, 1)
    }

    pub fn with_window(group: u8, n: usize, window: usize) -> Self {
        MuInstance {
            group,
            n,
            window: window.max(1),
            rounds: VecDeque::new(),
            next_rid: 0,
            max_seen_proposal: 0,
            queue: VecDeque::new(),
            committed: 0,
            restarts: 0,
        }
    }

    pub fn set_cluster_size(&mut self, n: usize) {
        self.n = n;
    }

    /// Followers (excluding the leader) whose responses complete a quorum.
    fn quorum_followers(&self) -> u32 {
        (self.n / 2) as u32 // majority of n including the leader's own vote
    }

    pub fn is_idle(&self) -> bool {
        self.rounds.is_empty() && self.queue.is_empty()
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Current pipeline depth (for `inflight_max` telemetry).
    pub fn depth(&self) -> usize {
        self.rounds.len()
    }

    fn alloc_rid(&mut self) -> u64 {
        self.next_rid += 1;
        self.next_rid
    }

    /// Submit a conflicting op. Returns `(rid, slot, round)` to fan out if
    /// the window had a free stage, else queues.
    pub fn submit(&mut self, op: OpCall, next_free_slot: u64) -> Option<(u64, u64, Round)> {
        if self.rounds.len() >= self.window {
            self.queue.push_back(op);
            return None;
        }
        Some(self.begin(op, next_free_slot))
    }

    fn begin(&mut self, op: OpCall, next_free_slot: u64) -> (u64, u64, Round) {
        // In-flight rounds hold slots the log doesn't show yet (the slot is
        // only written at the Accept entry): place after the deepest one.
        let slot = next_free_slot.max(self.rounds.back().map_or(0, |r| r.slot + 1));
        let rid = self.alloc_rid();
        self.rounds.push_back(MuRound {
            rid,
            phase: Phase::ReadProposals,
            targeted: 0,
            responded: 0,
            failed: 0,
            proposal: 0,
            slot,
            current_op: Some(op),
            original_op: None,
            adopted: None,
            was_adopted: false,
            committed: false,
        });
        (rid, slot, Round::ReadMinProposals)
    }

    /// The engine tells the instance how many followers the `rid` fan-out
    /// targeted.
    pub fn round_started(&mut self, rid: u64, targeted: u32) {
        if let Some(r) = self.rounds.iter_mut().find(|r| r.rid == rid) {
            r.targeted = targeted;
        }
    }

    /// Start the next queued op if the window has a free stage. Call again
    /// until `None` to fill the window (pump-until-full).
    pub fn pump(&mut self, next_free_slot: u64) -> Option<(u64, u64, Round)> {
        if self.rounds.len() >= self.window {
            return None;
        }
        let op = self.queue.pop_front()?;
        Some(self.begin(op, next_free_slot))
    }

    /// Release the committed round at the commit cursor, if any. The
    /// engine drains this after every Commit step so rounds whose Accept
    /// quorum arrived out of order commit strictly in slot order.
    pub fn pop_released(&mut self) -> Option<(u64, u64, OpCall, Option<OpCall>)> {
        let front = self.rounds.front()?;
        if !(front.phase == Phase::Accept && front.committed) {
            return None;
        }
        let r = self.rounds.pop_front().expect("front exists");
        self.committed += 1;
        let op = r.current_op.expect("op in flight");
        // If we adopted a foreign entry, the original op restarts from
        // Prepare (paper: "the leader repeats the Prepare phase for the
        // originally proposed transaction").
        let adopted = r.original_op;
        if let Some(orig) = adopted {
            self.queue.push_front(orig);
        }
        Some((r.slot, r.proposal, op, adopted))
    }

    /// A parked round whose predecessor has entered Accept: transition it
    /// to Accept and return its `(rid, WriteLog)` fan-out. The engine
    /// drains this after every Accept entry so execution stays serialized
    /// in slot order.
    pub fn pop_accept_ready(&mut self) -> Option<(u64, Round)> {
        let idx = self.rounds.iter().position(|r| r.phase == Phase::AcceptWait)?;
        if idx > 0 && self.rounds[idx - 1].phase != Phase::Accept {
            return None; // execution cursor still behind
        }
        let rid = self.alloc_rid();
        let r = &mut self.rounds[idx];
        r.phase = Phase::Accept;
        r.rid = rid;
        r.responded = 0;
        r.failed = 0;
        let round = Round::WriteLog {
            slot: r.slot,
            proposal: r.proposal,
            op: r.current_op.expect("resolved at ReadSlots"),
            adopted: r.was_adopted,
        };
        Some((rid, round))
    }

    /// Feed one follower response for the phase fan-out tagged `rid`.
    pub fn on_response(&mut self, rid: u64, resp: Resp) -> Step {
        let need = self.quorum_followers();
        // Route to the owning round; responses from superseded phases,
        // committed rounds, or flushed rounds carry dead rids and drop.
        let Some(idx) = self
            .rounds
            .iter()
            .position(|r| r.rid == rid && !r.committed && r.phase != Phase::AcceptWait)
        else {
            return Step::Wait;
        };
        if let Resp::MinProposal(p) = resp {
            self.max_seen_proposal = self.max_seen_proposal.max(p);
        }
        {
            let r = &mut self.rounds[idx];
            match resp {
                Resp::Failure => r.failed += 1,
                Resp::MinProposal(_) | Resp::Ack => r.responded += 1,
                Resp::Slot(entry) => {
                    if let Some((p, op)) = entry {
                        match r.adopted {
                            Some((bp, _)) if bp >= p => {}
                            _ => r.adopted = Some((p, op)),
                        }
                    }
                    r.responded += 1;
                }
            }
        }
        let r = &self.rounds[idx];
        if r.responded < need {
            // Quorum impossible once too many targets have failed.
            let healthy_remaining = r.targeted - r.responded - r.failed;
            if r.responded + healthy_remaining < need {
                return Step::Stall;
            }
            return Step::Wait;
        }

        // Quorum reached: advance the round's phase.
        match r.phase {
            Phase::ReadProposals => {
                let proposal = self.max_seen_proposal + 1;
                self.max_seen_proposal = proposal;
                let rid = self.alloc_rid();
                let r = &mut self.rounds[idx];
                r.proposal = proposal;
                r.phase = Phase::WriteProposal;
                r.rid = rid;
                r.responded = 0;
                r.failed = 0;
                Step::Next(rid, Round::WriteProposal { proposal })
            }
            Phase::WriteProposal => {
                let rid = self.alloc_rid();
                let r = &mut self.rounds[idx];
                r.phase = Phase::ReadSlots;
                r.rid = rid;
                r.responded = 0;
                r.failed = 0;
                Step::Next(rid, Round::ReadSlots { slot: r.slot })
            }
            Phase::ReadSlots => {
                // Adopt a previously accepted entry if any slot was
                // non-empty, then enter Accept — unless an earlier round
                // hasn't executed yet (the execution cursor serializes
                // Accept entries in slot order).
                let r = &mut self.rounds[idx];
                if let Some((_, foreign)) = r.adopted {
                    if Some(foreign) != r.current_op {
                        r.original_op = r.current_op.take();
                        r.was_adopted = true;
                        self.restarts += 1;
                    }
                    r.current_op = Some(foreign);
                }
                if idx > 0 && self.rounds[idx - 1].phase != Phase::Accept {
                    self.rounds[idx].phase = Phase::AcceptWait;
                    return Step::Wait;
                }
                let rid = self.alloc_rid();
                let r = &mut self.rounds[idx];
                r.phase = Phase::Accept;
                r.rid = rid;
                r.responded = 0;
                r.failed = 0;
                Step::Next(
                    rid,
                    Round::WriteLog {
                        slot: r.slot,
                        proposal: r.proposal,
                        op: r.current_op.expect("op in flight"),
                        adopted: r.was_adopted,
                    },
                )
            }
            Phase::Accept => {
                self.rounds[idx].committed = true;
                match self.pop_released() {
                    Some((slot, proposal, op, adopted)) => {
                        Step::Commit { slot, proposal, op, adopted }
                    }
                    None => Step::Wait, // blocked behind an earlier round
                }
            }
            Phase::AcceptWait => Step::Wait, // unreachable (filtered above)
        }
    }

    /// Abort the round that just entered Accept without requeueing its op
    /// (the leader found it impermissible in total-order position; §2.1
    /// permissibility). Later in-flight rounds hold later slots — letting
    /// them write would leave a hole at the aborted slot, so they flush
    /// back to the queue head (in slot order) and re-fly from the freed
    /// slot.
    pub fn abort_accept(&mut self, rid: u64) {
        let Some(idx) = self.rounds.iter().position(|r| r.rid == rid) else {
            return;
        };
        while self.rounds.len() > idx + 1 {
            let r = self.rounds.pop_back().expect("len checked");
            if let Some(op) = r.current_op {
                self.queue.push_front(op);
            }
            if let Some(op) = r.original_op {
                self.queue.push_front(op);
            }
        }
        let r = self.rounds.pop_back().expect("aborted round exists");
        if let Some(orig) = r.original_op {
            self.queue.push_front(orig);
        }
    }

    /// Abandon the whole window (leader change / stall reset): every
    /// in-flight op — including committed-but-unreleased rounds, whose
    /// effects never applied — returns to the queue head in slot order.
    pub fn reset_window(&mut self) {
        while let Some(r) = self.rounds.pop_back() {
            if let Some(op) = r.current_op {
                self.queue.push_front(op);
            }
            if let Some(op) = r.original_op {
                self.queue.push_front(op);
            }
        }
    }

    /// Abdication: hand every queued op back to the engine (which re-routes
    /// them through the forward path to the rightful leader). Call
    /// [`Self::reset_window`] first so in-flight ops are included.
    pub fn take_queue(&mut self) -> Vec<OpCall> {
        self.queue.drain(..).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn op(n: u64) -> OpCall {
        OpCall::new(1, n, 0, 0.0)
    }

    /// Drive one full consensus round with `f` followers all healthy.
    fn drive_commit(mu: &mut MuInstance, f: u32, o: OpCall, slot: u64) -> Step {
        let (rid, _, round) = mu.submit(o, slot).expect("idle -> first round");
        assert_eq!(round, Round::ReadMinProposals);
        drive_from(mu, f, rid)
    }

    /// Feed healthy quorums phase by phase until the round commits.
    fn drive_from(mu: &mut MuInstance, f: u32, mut rid: u64) -> Step {
        let mut phase = 0usize;
        loop {
            mu.round_started(rid, f);
            let resp = match phase {
                0 => Resp::MinProposal(0),
                2 => Resp::Slot(None),
                _ => Resp::Ack,
            };
            let mut step = Step::Wait;
            for _ in 0..f {
                step = mu.on_response(rid, resp);
                if !matches!(step, Step::Wait) {
                    break;
                }
            }
            match step {
                Step::Commit { .. } => return step,
                Step::Next(next_rid, _) => {
                    rid = next_rid;
                    phase += 1;
                }
                other => panic!("unexpected {other:?} in phase {phase}"),
            }
        }
    }

    #[test]
    fn happy_path_commits_own_op() {
        let mut mu = MuInstance::new(0, 4); // quorum = 2 followers
        let step = drive_commit(&mut mu, 3, op(42), 0);
        match step {
            Step::Commit { slot, op: o, adopted, .. } => {
                assert_eq!(slot, 0);
                assert_eq!(o.a, 42);
                assert!(adopted.is_none());
            }
            _ => unreachable!(),
        }
        assert_eq!(mu.committed, 1);
        assert!(mu.is_idle());
    }

    #[test]
    fn quorum_before_all_responses() {
        let mut mu = MuInstance::new(0, 8); // n=8: quorum followers = 4
        let (rid, _, _) = mu.submit(op(1), 0).unwrap();
        mu.round_started(rid, 7);
        for _ in 0..3 {
            assert_eq!(mu.on_response(rid, Resp::MinProposal(5)), Step::Wait);
        }
        let s = mu.on_response(rid, Resp::MinProposal(2));
        assert!(matches!(s, Step::Next(_, Round::WriteProposal { proposal: 6 })), "{s:?}");
    }

    #[test]
    fn adopts_highest_proposal_foreign_entry_then_requeues_original() {
        let mut mu = MuInstance::new(0, 4);
        let (rid, _, _) = mu.submit(op(7), 3).unwrap();
        mu.round_started(rid, 3);
        // Prepare reads
        mu.on_response(rid, Resp::MinProposal(0));
        let Step::Next(rid, _) = mu.on_response(rid, Resp::MinProposal(0)) else { panic!() };
        mu.round_started(rid, 3);
        mu.on_response(rid, Resp::Ack);
        let Step::Next(rid, _) = mu.on_response(rid, Resp::Ack) else { panic!() };
        // Slot reads find a foreign entry with proposal 9 and one with 4:
        mu.round_started(rid, 3);
        mu.on_response(rid, Resp::Slot(Some((4, op(100)))));
        let step = mu.on_response(rid, Resp::Slot(Some((9, op(200)))));
        let Step::Next(rid, Round::WriteLog { op: chosen, .. }) = step else { panic!("{step:?}") };
        assert_eq!(chosen.a, 200, "highest proposal adopted");
        // Accept acks
        mu.round_started(rid, 3);
        mu.on_response(rid, Resp::Ack);
        let step = mu.on_response(rid, Resp::Ack);
        let Step::Commit { op: committed, adopted, .. } = step else { panic!("{step:?}") };
        assert_eq!(committed.a, 200);
        assert_eq!(adopted.unwrap().a, 7, "original requeued");
        assert_eq!(mu.queue_len(), 1);
        assert_eq!(mu.restarts, 1);
    }

    #[test]
    fn queues_while_busy_and_pumps() {
        let mut mu = MuInstance::new(0, 4);
        let (rid, _, _) = mu.submit(op(1), 0).expect("idle -> first round");
        assert!(mu.submit(op(2), 0).is_none(), "window full -> queued");
        assert_eq!(mu.queue_len(), 1);
        assert!(mu.pump(0).is_none(), "window full -> no pump");
        // finish op 1
        let step = drive_from(&mut mu, 3, rid);
        assert!(matches!(step, Step::Commit { .. }));
        let r = mu.pump(1);
        assert!(matches!(r, Some((_, 1, Round::ReadMinProposals))), "{r:?}");
    }

    #[test]
    fn stalls_when_quorum_impossible() {
        let mut mu = MuInstance::new(0, 4); // needs 2 follower responses
        let (rid, _, _) = mu.submit(op(1), 0).unwrap();
        mu.round_started(rid, 3);
        assert_eq!(mu.on_response(rid, Resp::Failure), Step::Wait); // 2 healthy left, need 2
        // Second failure leaves only 1 healthy target < quorum 2: stall now.
        let s = mu.on_response(rid, Resp::Failure);
        assert_eq!(s, Step::Stall);
        mu.reset_window();
        assert_eq!(mu.queue_len(), 1, "op requeued for retry");
    }

    #[test]
    fn proposal_numbers_increase_past_observed() {
        let mut mu = MuInstance::new(0, 4);
        let (rid, _, _) = mu.submit(op(1), 0).unwrap();
        mu.round_started(rid, 3);
        mu.on_response(rid, Resp::MinProposal(41));
        let s = mu.on_response(rid, Resp::MinProposal(3));
        assert!(matches!(s, Step::Next(_, Round::WriteProposal { proposal: 42 })), "{s:?}");
    }

    /// Step a round through one healthy quorum phase, returning the next
    /// emission.
    fn quorum(mu: &mut MuInstance, rid: u64, f: u32, resp: Resp) -> Step {
        mu.round_started(rid, f);
        let mut step = Step::Wait;
        for _ in 0..f {
            step = mu.on_response(rid, resp);
            if !matches!(step, Step::Wait) {
                break;
            }
        }
        step
    }

    #[test]
    fn windowed_prepares_overlap_at_contiguous_slots() {
        let mut mu = MuInstance::with_window(0, 4, 2);
        let (rid_a, slot_a, _) = mu.submit(op(1), 5).unwrap();
        // The log can't show slot 6 as free yet — the in-flight round owns
        // slot 5 and hasn't written it — so the instance places round B
        // after its own deepest in-flight slot.
        let (rid_b, slot_b, _) = mu.submit(op(2), 5).unwrap();
        assert_eq!((slot_a, slot_b), (5, 6), "contiguous in-flight slots");
        assert_ne!(rid_a, rid_b);
        assert!(mu.submit(op(3), 5).is_none(), "window full -> queued");
        // Both Prepare phases advance independently.
        let Step::Next(_, Round::WriteProposal { proposal: p_a }) =
            quorum(&mut mu, rid_a, 3, Resp::MinProposal(0))
        else {
            panic!()
        };
        let Step::Next(_, Round::WriteProposal { proposal: p_b }) =
            quorum(&mut mu, rid_b, 3, Resp::MinProposal(0))
        else {
            panic!()
        };
        assert!(p_b > p_a, "later round proposes higher");
    }

    #[test]
    fn accept_entries_serialize_behind_the_execution_cursor() {
        let mut mu = MuInstance::with_window(0, 4, 2);
        let (rid_a, _, _) = mu.submit(op(1), 0).unwrap();
        let (rid_b, _, _) = mu.submit(op(2), 0).unwrap();
        // Round B races ahead through Prepare while A sits in ReadProposals.
        let Step::Next(rid_b, _) = quorum(&mut mu, rid_b, 3, Resp::MinProposal(0)) else {
            panic!()
        };
        let Step::Next(rid_b, _) = quorum(&mut mu, rid_b, 3, Resp::Ack) else { panic!() };
        // B's ReadSlots quorum completes first: parked, not emitted.
        assert_eq!(quorum(&mut mu, rid_b, 3, Resp::Slot(None)), Step::Wait, "B parks");
        assert!(mu.pop_accept_ready().is_none(), "execution cursor still at A");
        // A advances to its Accept entry...
        let Step::Next(rid_a, _) = quorum(&mut mu, rid_a, 3, Resp::MinProposal(0)) else {
            panic!()
        };
        let Step::Next(rid_a, _) = quorum(&mut mu, rid_a, 3, Resp::Ack) else { panic!() };
        let Step::Next(rid_a, Round::WriteLog { slot: 0, .. }) =
            quorum(&mut mu, rid_a, 3, Resp::Slot(None))
        else {
            panic!()
        };
        // ...which unparks B in slot order.
        let (rid_b2, Round::WriteLog { slot: 1, .. }) = mu.pop_accept_ready().unwrap() else {
            panic!()
        };
        assert_ne!(rid_b, rid_b2, "Accept fan-out gets a fresh nonce");
        // B's Accept quorum lands before A's: committed out of order,
        // released in slot order.
        assert_eq!(quorum(&mut mu, rid_b2, 3, Resp::Ack), Step::Wait, "blocked behind A");
        assert!(mu.pop_released().is_none());
        let Step::Commit { slot: 0, .. } = quorum(&mut mu, rid_a, 3, Resp::Ack) else { panic!() };
        let (slot, _, o, adopted) = mu.pop_released().unwrap();
        assert_eq!((slot, o.a), (1, 2));
        assert!(adopted.is_none());
        assert_eq!(mu.committed, 2);
        assert!(mu.is_idle());
    }

    #[test]
    fn aborted_accept_flushes_later_rounds_to_requeue() {
        let mut mu = MuInstance::with_window(0, 4, 3);
        let (rid_a, _, _) = mu.submit(op(1), 0).unwrap();
        let (_, _, _) = mu.submit(op(2), 0).unwrap();
        let (_, _, _) = mu.submit(op(3), 0).unwrap();
        // A reaches its Accept entry; the engine finds it impermissible.
        let Step::Next(rid_a, _) = quorum(&mut mu, rid_a, 3, Resp::MinProposal(0)) else {
            panic!()
        };
        let Step::Next(rid_a, _) = quorum(&mut mu, rid_a, 3, Resp::Ack) else { panic!() };
        let Step::Next(rid_a, Round::WriteLog { .. }) = quorum(&mut mu, rid_a, 3, Resp::Slot(None))
        else {
            panic!()
        };
        mu.abort_accept(rid_a);
        // The rejected op is gone; the later rounds' ops re-fly from the
        // freed slot (no log hole), in slot order.
        assert_eq!(mu.depth(), 0);
        assert_eq!(mu.queue_len(), 2);
        let (_, slot, _) = mu.pump(0).unwrap();
        assert_eq!(slot, 0, "pipeline restarts at the freed slot");
    }

    #[test]
    fn reset_window_requeues_all_rounds_in_slot_order() {
        let mut mu = MuInstance::with_window(0, 4, 3);
        mu.submit(op(1), 0).unwrap();
        mu.submit(op(2), 0).unwrap();
        mu.submit(op(3), 0).unwrap();
        mu.reset_window();
        assert_eq!(mu.depth(), 0);
        assert_eq!(mu.queue_len(), 3);
        let ops = mu.take_queue();
        assert_eq!(ops.iter().map(|o| o.a).collect::<Vec<_>>(), vec![1, 2, 3]);
        assert_eq!(mu.committed, 0, "nothing released, nothing counted");
    }
}
