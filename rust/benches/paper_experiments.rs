//! `cargo bench` harness (criterion is unavailable offline; harness=false).
//!
//! Regenerates every paper table/figure in quick mode, timing each, and
//! prints the headline ratios next to the paper's claims — the "same
//! rows/series the paper reports" requirement of the benchmark deliverable.
//! Full-density runs: `cargo run --release -- expt all`.

use std::time::Instant;

use safardb::expt;

fn main() {
    println!("SafarDB paper-experiment bench (quick mode; full: `safardb expt all`)\n");
    println!("{:<10} {:>9} {:>7}  headline", "experiment", "wall_s", "tables");
    let t_all = Instant::now();
    for id in expt::ALL {
        let t0 = Instant::now();
        let tables = expt::run(id, true).expect("known id");
        let wall = t0.elapsed().as_secs_f64();
        let headline = match *id {
            "fig9" => {
                let (rt, tp) = expt::fig09::headline(&tables);
                format!("CRDT RT {rt:.1}x / tput {tp:.1}x vs Hamband (paper 7.0x / 5.3x)")
            }
            "fig10" => {
                let (rt, tp) = expt::fig10::headline(&tables);
                format!("WRDT RT {rt:.1}x / tput {tp:.1}x vs Hamband (paper 12x / 6.8x)")
            }
            "table2_1" => "verb latencies (paper 1.8/2.0us vs 9ns)".to_string(),
            "fig13" => "perm switch ns vs 100s-of-us (paper 17/24ns)".to_string(),
            "fig27" => "power ~35W vs ~160W (paper 4.5x)".to_string(),
            _ => String::new(),
        };
        println!("{id:<10} {wall:>9.2} {:>7}  {headline}", tables.len());
        expt::common::save(&tables, id);
    }
    println!("\ntotal: {:.1}s — all {} experiments regenerated under results/", t_all.elapsed().as_secs_f64(), expt::ALL.len());
}
