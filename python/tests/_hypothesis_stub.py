"""Deterministic stand-in for the slice of the Hypothesis API the kernel
tests use, for offline runners where `hypothesis` is not installed.

Each `@given` test runs over a fixed number of seeded draws instead of
Hypothesis's adaptive search. Coverage is narrower than real Hypothesis
(no shrinking, no edge-case bias), but the oracle comparisons still sweep
shapes and values deterministically, so the suite stays meaningful — and
runnable — without the dependency. When `hypothesis` is installed the
tests import it instead (see test_kernels.py).
"""

import random

_N_EXAMPLES = 25


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


class _Strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value, max_value, allow_nan=False, width=64):
        del allow_nan, width  # uniform draws are always finite
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def tuples(*strategies):
        return _Strategy(lambda rng: tuple(s.draw(rng) for s in strategies))


st = _Strategies()


def settings(**_kwargs):
    """No-op: example counts are fixed in this stub."""

    def decorate(fn):
        return fn

    return decorate


def given(**strategies):
    """Run the wrapped test over `_N_EXAMPLES` deterministic draws."""

    def decorate(fn):
        def wrapper(*args, **kwargs):
            for i in range(_N_EXAMPLES):
                rng = random.Random(0xC0FFEE + 9176 * i)
                drawn = {name: s.draw(rng) for name, s in strategies.items()}
                fn(*args, **drawn, **kwargs)

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper

    return decorate
