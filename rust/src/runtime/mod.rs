//! PJRT runtime: loads the AOT-compiled Pallas/JAX artifacts
//! (`artifacts/*.hlo.txt`, produced once by `make artifacts`) and executes
//! them from Rust. Python never runs on this path.
//!
//! * [`artifacts`] — manifest parsing + artifact registry.
//! * [`exec`] — the PJRT CPU client wrapper (compile once, execute many).
//! * [`accel`] — typed batch operators mirroring the paper's FPGA-resident
//!   accelerators (Fig 1's Dispatcher targets), with padding to the fixed
//!   export shapes.

pub mod accel;
pub mod artifacts;
pub mod exec;

pub use accel::Accelerator;
pub use artifacts::{Manifest, Signature};
pub use exec::Runtime;

/// Default artifact directory relative to the repo root.
pub const DEFAULT_ARTIFACTS: &str = "artifacts";
