//! Shared experiment plumbing: cell runners (sequential and parallel),
//! sweep axes, result output.
//!
//! Every sweep cell is an independent, seeded, deterministic simulation, so
//! the harness fans cells out across worker threads with [`run_cells`]:
//! results come back in submission order and are bit-identical to the
//! sequential path for any thread count (asserted by the
//! `parallel_determinism` integration test). The worker count comes from
//! `--threads N` on the CLI, the `SAFARDB_THREADS` environment variable, or
//! the machine's available parallelism, in that order.

use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::config::{ConsensusBackend, LeaderPlacement, SimConfig};
use crate::engine::cluster::{self, RunReport};
use crate::util::table::Table;

/// Paper sweep axes (§5.1: 3–8 nodes, 15/20/25 % updates; 4M ops scaled).
pub const NODE_SWEEP: &[usize] = &[3, 4, 5, 6, 7, 8];
pub const NODE_SWEEP_QUICK: &[usize] = &[3, 5, 8];
pub const UPDATE_SWEEP: &[u8] = &[15, 20, 25];

pub fn nodes(quick: bool) -> &'static [usize] {
    if quick {
        NODE_SWEEP_QUICK
    } else {
        NODE_SWEEP
    }
}

/// Ops per cell: the paper runs 4M per experiment; the simulator preserves
/// shape at far smaller counts (documented in EXPERIMENTS.md).
pub fn cell_ops(quick: bool) -> u64 {
    if quick {
        24_000
    } else {
        96_000
    }
}

/// One measured cell.
#[derive(Clone, Copy, Debug)]
pub struct Cell {
    pub rt_us: f64,
    pub tput: f64,
}

/// One sweep cell awaiting execution: a full cluster configuration plus its
/// op count.
pub type CellJob = (SimConfig, u64);

/// Run one configuration and sanity-check it (convergence + integrity are
/// hard requirements of every experiment, not just the tests).
pub fn run_cell(mut cfg: SimConfig, ops: u64) -> (Cell, RunReport) {
    cfg.total_ops = ops;
    let label = cell_label(&cfg);
    let rep = cluster::run(cfg);
    assert!(rep.converged(), "experiment cell diverged: {label} digests={:?}", rep.digests);
    assert!(
        rep.converged_per_object(),
        "experiment cell diverged per-object: {label} object_digests={:?}",
        rep.object_digests
    );
    assert!(rep.invariants_ok, "experiment cell violated integrity: {label}");
    (Cell { rt_us: rep.response_us(), tput: rep.throughput() }, rep)
}

/// Globally configured worker count for [`run_cells_auto`] (0 = unset:
/// resolve from `SAFARDB_THREADS` / available parallelism at call time).
static THREADS: AtomicUsize = AtomicUsize::new(0);

/// Consensus-backend restriction for backend-aware sweeps (the CLI's
/// `--backend mu|raft|paxos` knob; 0 = all backends).
static BACKEND: AtomicUsize = AtomicUsize::new(0);

/// Restrict backend-aware sweeps (currently `expt backends`) to one
/// consensus backend — the CI matrix runs one leg per backend.
pub fn set_backend_filter(b: ConsensusBackend) {
    let idx = ConsensusBackend::ALL.iter().position(|&x| x == b).expect("known backend");
    BACKEND.store(idx + 1, Ordering::SeqCst);
}

/// The configured backend restriction, if any.
pub fn backend_filter() -> Option<ConsensusBackend> {
    match BACKEND.load(Ordering::SeqCst) {
        0 => None,
        i => Some(ConsensusBackend::ALL[i - 1]),
    }
}

/// Leadership-placement restriction for placement-aware sweeps (the CLI's
/// `--placement single|hash|round_robin|load_aware` knob; 0 = unset, the
/// sweep's own default axis).
static PLACEMENT: AtomicUsize = AtomicUsize::new(0);

/// Restrict placement-aware sweeps (currently `expt scaleout`) to one
/// leadership placement — the CI matrix runs sharded smoke legs this way.
pub fn set_placement_filter(p: LeaderPlacement) {
    let idx = LeaderPlacement::ALL.iter().position(|&x| x == p).expect("known placement");
    PLACEMENT.store(idx + 1, Ordering::SeqCst);
}

/// The configured placement restriction, if any.
pub fn placement_filter() -> Option<LeaderPlacement> {
    match PLACEMENT.load(Ordering::SeqCst) {
        0 => None,
        i => Some(LeaderPlacement::ALL[i - 1]),
    }
}

/// Strong-plane window restriction for window-aware sweeps (the CLI's
/// `--window N` knob; 0 = unset, the sweep's own default axis).
static WINDOW: AtomicUsize = AtomicUsize::new(0);

/// Pin window-aware sweeps (currently `expt loadcurve`) to one pipeline
/// depth — the CI matrix runs its pipelined legs this way.
pub fn set_window_filter(w: u32) {
    WINDOW.store(w as usize, Ordering::SeqCst);
}

/// The configured window restriction, if any.
pub fn window_filter() -> Option<u32> {
    match WINDOW.load(Ordering::SeqCst) {
        0 => None,
        w => Some(w as u32),
    }
}

/// Pin the worker count for subsequent [`run_cells_auto`] calls (the CLI's
/// `--threads N` knob lands here).
pub fn set_threads(n: usize) {
    THREADS.store(n, Ordering::SeqCst);
}

/// Effective worker count: explicit [`set_threads`] value, else
/// [`default_threads`] — resolved once and cached, so an invalid
/// `SAFARDB_THREADS` warns a single time instead of once per table.
pub fn configured_threads() -> usize {
    let n = THREADS.load(Ordering::SeqCst);
    if n >= 1 {
        return n;
    }
    let resolved = default_threads();
    let _ = THREADS.compare_exchange(0, resolved, Ordering::SeqCst, Ordering::SeqCst);
    THREADS.load(Ordering::SeqCst)
}

/// `SAFARDB_THREADS` when set to a positive integer, else the machine's
/// available parallelism (1 if unknown). An unparseable or zero value is
/// ignored with a warning (the CLI's `--threads` rejects those outright).
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("SAFARDB_THREADS") {
        match v.trim().parse::<usize>() {
            Ok(n) if n >= 1 => return n,
            _ => eprintln!(
                "warning: ignoring SAFARDB_THREADS='{v}' (want a positive integer); \
                 using available parallelism"
            ),
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run independent sweep cells on up to `threads` workers.
///
/// Results are returned in submission order. Each cell's RNG streams derive
/// only from its own `SimConfig::seed`, so the output is bit-identical to
/// the sequential path regardless of thread count or scheduling — workers
/// pull the next job index from a shared counter, but each writes only its
/// own slot. A panic in any cell (convergence/integrity assertion) aborts
/// the remaining queue and is re-raised with the failing job's index once
/// the workers have stopped; the original panic message has already
/// reached stderr at that point.
///
/// Per-cell wall-clock telemetry: every `RunReport` carries the cell's own
/// simulation wall time (`wall_s`), and the sweep logs its slowest cell —
/// work stealing is index-based, so one long cell can straggle an entire
/// sweep tail and this names it.
pub fn run_cells(jobs: Vec<CellJob>, threads: usize) -> Vec<(Cell, RunReport)> {
    let labels: Vec<String> = jobs.iter().map(|(cfg, _)| cell_label(cfg)).collect();
    let results = run_cells_inner(jobs, threads);
    log_slowest_cell(&labels, &results);
    results
}

fn cell_label(cfg: &SimConfig) -> String {
    format!(
        "{}/{} n={} upd={}% objs={}",
        cfg.system.name(),
        cfg.workload.name(),
        cfg.n_replicas,
        cfg.update_pct,
        cfg.n_objects()
    )
}

/// Name the straggler so sweep-tail latency is diagnosable (ROADMAP item).
fn log_slowest_cell(labels: &[String], results: &[(Cell, RunReport)]) {
    if results.len() < 2 {
        return;
    }
    let total: f64 = results.iter().map(|(_, r)| r.wall_s).sum();
    let (slowest, wall) = results
        .iter()
        .enumerate()
        .map(|(i, (_, r))| (i, r.wall_s))
        .max_by(|a, b| a.1.total_cmp(&b.1))
        .expect("at least two cells");
    eprintln!(
        "[sweep] {} cells, {:.2}s total cell wall; slowest: cell {} ({}) at {:.2}s ({:.0}% of total)",
        results.len(),
        total,
        slowest,
        labels[slowest],
        wall,
        if total > 0.0 { wall / total * 100.0 } else { 0.0 }
    );
}

fn run_cells_inner(jobs: Vec<CellJob>, threads: usize) -> Vec<(Cell, RunReport)> {
    let n = jobs.len();
    let threads = threads.clamp(1, n.max(1));
    if threads <= 1 || n <= 1 {
        return jobs.into_iter().map(|(cfg, ops)| run_cell(cfg, ops)).collect();
    }
    let next = AtomicUsize::new(0);
    let abort = AtomicBool::new(false);
    let slots: Vec<Mutex<Option<(Cell, RunReport)>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let failed: Mutex<Option<(usize, Box<dyn std::any::Any + Send>)>> = Mutex::new(None);
    let jobs_ref = &jobs;
    let slots_ref = &slots;
    let next_ref = &next;
    let abort_ref = &abort;
    let failed_ref = &failed;
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(move || loop {
                if abort_ref.load(Ordering::SeqCst) {
                    break;
                }
                let i = next_ref.fetch_add(1, Ordering::SeqCst);
                if i >= jobs_ref.len() {
                    break;
                }
                let (cfg, ops) = jobs_ref[i].clone();
                match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    run_cell(cfg, ops)
                })) {
                    Ok(res) => {
                        *slots_ref[i].lock().expect("cell slot poisoned") = Some(res);
                    }
                    Err(payload) => {
                        let mut f = failed_ref.lock().expect("failure slot poisoned");
                        if f.is_none() {
                            *f = Some((i, payload));
                        }
                        abort_ref.store(true, Ordering::SeqCst);
                        break;
                    }
                }
            });
        }
    });
    if let Some((i, payload)) = failed.into_inner().expect("failure slot poisoned") {
        eprintln!("run_cells: cell {i} of {n} panicked (message above); aborted the sweep");
        std::panic::resume_unwind(payload);
    }
    slots
        .into_iter()
        .map(|m| m.into_inner().expect("cell slot poisoned").expect("cell completed"))
        .collect()
}

/// [`run_cells`] with the globally configured worker count.
pub fn run_cells_auto(jobs: Vec<CellJob>) -> Vec<(Cell, RunReport)> {
    let threads = configured_threads();
    run_cells(jobs, threads)
}

/// [`run_cells_auto`] for tagged jobs: each cell carries caller metadata
/// (its row labels) that comes back attached to its result, so the
/// label/result pairing cannot drift — the experiment modules' standard
/// entry point.
pub fn run_cells_tagged<M>(jobs: Vec<(M, CellJob)>) -> Vec<(M, Cell, RunReport)> {
    let (metas, cells): (Vec<M>, Vec<CellJob>) = jobs.into_iter().unzip();
    metas
        .into_iter()
        .zip(run_cells_auto(cells))
        .map(|(meta, (cell, rep))| (meta, cell, rep))
        .collect()
}

pub fn f3(v: f64) -> String {
    format!("{v:.3}")
}

pub fn f2(v: f64) -> String {
    format!("{v:.2}")
}

/// Write tables as CSV under `results/` (one file per table).
pub fn save(tables: &[Table], id: &str) {
    let dir = Path::new("results");
    let _ = std::fs::create_dir_all(dir);
    for (i, t) in tables.iter().enumerate() {
        let name = if tables.len() == 1 {
            format!("{id}.csv")
        } else {
            format!("{id}_{i}.csv")
        };
        let _ = std::fs::write(dir.join(name), t.to_csv());
    }
}

/// Geometric-mean ratio of two series (the paper's "X× lower/higher").
pub fn geomean_ratio(nums: &[f64], dens: &[f64]) -> f64 {
    assert_eq!(nums.len(), dens.len());
    let log_sum: f64 = nums
        .iter()
        .zip(dens)
        .filter(|(n, d)| **n > 0.0 && **d > 0.0)
        .map(|(n, d)| (n / d).ln())
        .sum();
    (log_sum / nums.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadKind;
    use crate::rdt::RdtKind;

    #[test]
    fn geomean_ratio_basics() {
        assert!((geomean_ratio(&[2.0, 8.0], &[1.0, 2.0]) - (2.0f64 * 4.0).sqrt()).abs() < 1e-9);
    }

    fn small_jobs() -> Vec<CellJob> {
        let mut jobs = Vec::new();
        for (i, rdt) in [RdtKind::PnCounter, RdtKind::GSet, RdtKind::LwwRegister]
            .into_iter()
            .enumerate()
        {
            let mut cfg = SimConfig::safardb(WorkloadKind::Micro(rdt));
            cfg.update_pct = 20;
            cfg.seed = 0xA11CE + i as u64;
            jobs.push((cfg, 3_000));
        }
        jobs
    }

    #[test]
    fn run_cells_preserves_submission_order() {
        let results = run_cells(small_jobs(), 3);
        assert_eq!(results.len(), 3);
        // Each job used a distinct RDT; the reports carry distinguishable
        // digests, so cross-checking against a per-job sequential run pins
        // the ordering.
        for (job, (_, rep)) in small_jobs().into_iter().zip(&results) {
            let (_, seq_rep) = run_cell(job.0, job.1);
            assert_eq!(seq_rep.digests, rep.digests, "slot order preserved");
        }
    }

    #[test]
    fn run_cells_parallel_matches_sequential_bits() {
        let seq = run_cells(small_jobs(), 1);
        let par = run_cells(small_jobs(), 2);
        for ((cs, rs), (cp, rp)) in seq.iter().zip(&par) {
            assert_eq!(cs.rt_us.to_bits(), cp.rt_us.to_bits());
            assert_eq!(cs.tput.to_bits(), cp.tput.to_bits());
            assert_eq!(rs.digests, rp.digests);
            assert_eq!(rs.metrics.events, rp.metrics.events);
        }
    }

    #[test]
    fn thread_knobs_resolve_sanely() {
        assert!(default_threads() >= 1);
        assert!(configured_threads() >= 1);
    }

    #[test]
    fn tagged_jobs_keep_their_labels() {
        let jobs: Vec<(usize, CellJob)> =
            small_jobs().into_iter().enumerate().collect();
        let results = run_cells_tagged(jobs);
        let labels: Vec<usize> = results.iter().map(|(m, _, _)| *m).collect();
        assert_eq!(labels, vec![0, 1, 2]);
        for ((_, cell, rep), (seq_cell, seq_rep)) in
            results.iter().zip(small_jobs().into_iter().map(|(c, o)| run_cell(c, o)))
        {
            assert_eq!(cell.rt_us.to_bits(), seq_cell.rt_us.to_bits());
            assert_eq!(rep.digests, seq_rep.digests);
        }
    }
}
