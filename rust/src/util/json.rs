//! Minimal JSON value + writer (no serde offline). Used to persist
//! experiment results under results/ for EXPERIMENTS.md.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        } else {
            panic!("set on non-object");
        }
        self
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if n.fract() == 0.0 && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{n}");
                    }
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}

impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}

impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested() {
        let mut o = Json::obj();
        o.set("name", "fig9".into());
        o.set("ratio", 7.0.into());
        o.set("series", Json::Arr(vec![1.0.into(), 2.5.into()]));
        assert_eq!(o.render(), r#"{"name":"fig9","ratio":7,"series":[1,2.5]}"#);
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(j.render(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn integers_render_clean() {
        assert_eq!(Json::Num(42.0).render(), "42");
        assert_eq!(Json::Num(4.25).render(), "4.25");
    }
}
