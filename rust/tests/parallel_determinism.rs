//! Integration: the parallel sweep executor must be a pure speedup — cell
//! outputs bit-identical to the sequential path for a fixed seed, across
//! multiple node counts, workloads, and both systems (ISSUE: the tier-1
//! credibility requirement for a concurrent, repeatable harness).

use safardb::config::{SimConfig, SystemKind, WorkloadKind};
use safardb::expt::common::{run_cells, CellJob};
use safardb::rdt::RdtKind;

/// A sweep slice shaped like the paper's §5.1 axes: >= 2 node counts,
/// multiple update mixes, CRDT + WRDT + keyed workloads, both systems.
fn sweep_jobs() -> Vec<CellJob> {
    let mut jobs = Vec::new();
    for &n in &[3usize, 5, 8] {
        for &u in &[15u8, 25] {
            for (system, workload) in [
                (SystemKind::SafarDb, WorkloadKind::Micro(RdtKind::PnCounter)),
                (SystemKind::SafarDb, WorkloadKind::Micro(RdtKind::Account)),
                (SystemKind::Hamband, WorkloadKind::Micro(RdtKind::PnCounter)),
                (SystemKind::SafarDb, WorkloadKind::Ycsb),
            ] {
                let mut cfg = match system {
                    SystemKind::SafarDb => SimConfig::safardb(workload),
                    _ => SimConfig::hamband(workload),
                };
                cfg.n_replicas = n;
                cfg.update_pct = u;
                cfg.seed = 0xD15EA5E ^ ((n as u64) << 16) ^ ((u as u64) << 8);
                jobs.push((cfg, 4_000));
            }
        }
    }
    jobs
}

#[test]
fn parallel_executor_bit_identical_to_sequential() {
    let seq = run_cells(sweep_jobs(), 1);
    let par = run_cells(sweep_jobs(), 4);
    assert_eq!(seq.len(), par.len());
    for (i, ((cell_s, rep_s), (cell_p, rep_p))) in seq.iter().zip(&par).enumerate() {
        // Bit-identical table values, not approximate equality: the tables
        // the harness renders come straight from these floats.
        assert_eq!(cell_s.rt_us.to_bits(), cell_p.rt_us.to_bits(), "cell {i}: rt_us");
        assert_eq!(cell_s.tput.to_bits(), cell_p.tput.to_bits(), "cell {i}: tput");
        // And the full simulation transcript agrees, not just the summary.
        assert_eq!(rep_s.digests, rep_p.digests, "cell {i}: state digests");
        assert_eq!(rep_s.metrics.events, rep_p.metrics.events, "cell {i}: event count");
        assert_eq!(
            rep_s.metrics.total_completed(),
            rep_p.metrics.total_completed(),
            "cell {i}: completions"
        );
        assert_eq!(
            rep_s.metrics.makespan_ns, rep_p.metrics.makespan_ns,
            "cell {i}: makespan"
        );
    }
}

#[test]
fn oversubscribed_thread_count_is_safe() {
    // More workers than jobs: the executor must clamp and stay correct.
    let jobs: Vec<CellJob> = sweep_jobs().into_iter().take(3).collect();
    let seq = run_cells(jobs.clone(), 1);
    let par = run_cells(jobs, 64);
    for ((cs, rs), (cp, rp)) in seq.iter().zip(&par) {
        assert_eq!(cs.rt_us.to_bits(), cp.rt_us.to_bits());
        assert_eq!(cs.tput.to_bits(), cp.tput.to_bits());
        assert_eq!(rs.digests, rp.digests);
    }
}
