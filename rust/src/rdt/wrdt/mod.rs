//! Well-coordinated Replicated Data Types (Table B.1).
//!
//! Each WRDT partitions its transactions into reducible / irreducible /
//! conflicting categories and declares synchronization groups; conflicting
//! transactions of one group share an SMR instance and replication log
//! (§2.1, §4.3). Integrity invariants are checked by `invariant_ok` in
//! tests and by `permissible` on the execution path.

pub mod account;
pub mod auction;
pub mod courseware;
pub mod movie;
pub mod project;
