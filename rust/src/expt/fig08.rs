//! Fig 8: conflicting-transaction implementations (§4.3) on Auction —
//! RDMA Write (log + polling) vs RDMA RPC Write-Through.
//!
//! Expected shape: Write-Through ~1.5× lower RT, ~1.1× higher throughput
//! on average, with the throughput edge strongest at low node counts
//! (coordination dominates at high N). Auction stresses this most: three
//! sync groups = three replication logs to poll.

use crate::config::{PropagationMode, SimConfig, WorkloadKind};
use crate::expt::common::{cell_ops, f3, nodes, run_cells_tagged, UPDATE_SWEEP};
use crate::rdt::RdtKind;
use crate::util::table::Table;

const CONFIGS: &[(&str, PropagationMode)] = &[
    ("write", PropagationMode::WriteNoBuffer),
    ("write-through", PropagationMode::WriteThrough),
];

pub fn run(quick: bool) -> Vec<Table> {
    let mut t = Table::new(
        "Fig 8 — conflicting configs on Auction (3 sync groups)",
        &["config", "nodes", "upd%", "rt_us", "tput_ops_us"],
    );
    let mut jobs = Vec::new();
    for &(name, mode) in CONFIGS {
        for &n in nodes(quick) {
            for &u in UPDATE_SWEEP {
                let mut cfg = SimConfig::safardb(WorkloadKind::Micro(RdtKind::Auction));
                cfg.prop_conflicting = mode;
                cfg.prop_reducible = PropagationMode::WriteBuffered;
                cfg.prop_irreducible = PropagationMode::WriteNoBuffer;
                cfg.n_replicas = n;
                cfg.update_pct = u;
                jobs.push(((name, n, u), (cfg, cell_ops(quick))));
            }
        }
    }
    for ((name, n, u), cell, _) in run_cells_tagged(jobs) {
        t.row(vec![name.into(), n.to_string(), u.to_string(), f3(cell.rt_us), f3(cell.tput)]);
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expt::common::geomean_ratio;

    #[test]
    fn write_through_lowers_response_time() {
        let t = &run(true)[0];
        let series = |cfg: &str, col: usize| -> Vec<f64> {
            t.rows().iter().filter(|r| r[0] == cfg).map(|r| r[col].parse().unwrap()).collect()
        };
        let rt_gain = geomean_ratio(&series("write", 3), &series("write-through", 3));
        assert!(rt_gain > 1.1, "rt gain {rt_gain} (paper ~1.5x)");
        let tput_gain = geomean_ratio(&series("write-through", 4), &series("write", 4));
        assert!(tput_gain > 0.95, "tput gain {tput_gain} (paper ~1.1x)");
    }
}
