//! **End-to-end driver** (DESIGN.md deliverable): serve batched YCSB +
//! SmallBank requests through the full three-layer stack —
//!
//!   clients -> Rust coordinator (simulated FPGA cluster, Mu SMR when
//!   needed) -> **batch kernels** applying the op bursts and guarding
//!   Account batches -> metrics.
//!
//! The kernel runtime type-checks against the AOT manifest when
//! `artifacts/` exists (built once by `python -m compile.aot`) and runs the
//! std-only reference executor either way; the scalar engine result is
//! cross-checked against the kernel result exactly. Recorded in
//! EXPERIMENTS.md §End-to-end.
//!
//! Run: `cargo run --release --example ycsb_serve`

use safardb::config::{SimConfig, WorkloadKind};
use safardb::engine::cluster;
use safardb::runtime::{Accelerator, Runtime};
use safardb::util::rng::{Rng, Zipf};

fn main() -> safardb::runtime::Result<()> {
    // --- Layer-1/2 signatures through the kernel runtime -----------------
    let rt = Runtime::load("artifacts")?;
    println!("kernel platform: {} | artifacts: {:?}\n", rt.platform(), rt.names());
    let mut acc = Accelerator::new(rt);

    // --- Serve request bursts through the batch kernels ------------------
    // 1024-key YCSB tile, 64 bursts of 256 ops each, Zipf-skewed keys.
    let mut rng = Rng::new(42);
    let zipf = Zipf::new(1024, 0.99);
    let mut state = vec![0f32; 1024];
    let mut shadow = state.clone(); // scalar cross-check
    let mut served = 0u64;
    let t0 = std::time::Instant::now();
    for _ in 0..64 {
        let mut keys = Vec::with_capacity(256);
        let mut deltas = Vec::with_capacity(256);
        for _ in 0..256 {
            keys.push(zipf.sample(&mut rng) as i32);
            deltas.push(rng.gen_f64_range(-5.0, 10.0) as f32);
        }
        state = acc.kv_burst_apply(&state, &keys, &deltas)?;
        for (k, d) in keys.iter().zip(&deltas) {
            shadow[*k as usize] += d;
        }
        served += 256;
    }
    let kernel_wall = t0.elapsed();
    for (i, (a, b)) in state.iter().zip(&shadow).enumerate() {
        assert!((a - b).abs() < 1e-2, "key {i}: kernel {a} vs scalar {b}");
    }
    println!(
        "kernel path : {served} ops in {:.1} ms ({:.1} kops/s through the runtime, {} kernel calls)",
        kernel_wall.as_secs_f64() * 1e3,
        served as f64 / kernel_wall.as_secs_f64() / 1e3,
        acc.calls(),
    );

    // Account guard burst: overdraft-protected debit batch (SmallBank).
    let deltas: Vec<f32> = (0..256).map(|_| rng.gen_f64_range(-30.0, 20.0) as f32).collect();
    let (mask, balance) = acc.account_guard(100.0, &deltas)?;
    let accepted = mask.iter().filter(|&&m| m).count();
    println!("guard burst : {accepted}/256 ops accepted, final balance {balance:.2} (>= 0: {})", balance >= 0.0);
    assert!(balance >= 0.0, "integrity invariant");

    // --- Full-cluster serving runs (latency/throughput report) -----------
    println!("\nfull-cluster serving (4 replicas, 100k ops each workload):");
    for (name, workload) in [("YCSB", WorkloadKind::Ycsb), ("SmallBank", WorkloadKind::SmallBank)] {
        let mut cfg = SimConfig::safardb(workload);
        cfg.update_pct = 25;
        cfg.total_ops = 100_000;
        let rep = cluster::run(cfg);
        assert!(rep.converged() && rep.invariants_ok);
        println!(
            "  {name:9}: response {:>7.3} us (p99 {:>8.3}) | throughput {:>7.3} OPs/us | {} SMR commits",
            rep.response_us(),
            rep.metrics.response.p99() as f64 / 1000.0,
            rep.throughput(),
            rep.metrics.smr_commits,
        );
    }
    println!("\nOK: all layers compose (kernel semantics -> batch runtime -> Rust coordinator).");
    Ok(())
}
