//! Simulated RDMA networking: verbs, queue pairs with permissions, fabric
//! cost models (traditional CPU RNIC vs network-attached FPGA), and the
//! delivery scheduler that turns an issued verb into `VerbDeliver` /
//! `AckDeliver` events with calibrated latencies.

pub mod fabric;
pub mod network;
pub mod qp;
pub mod verbs;

pub use fabric::{FabricParams, PermSwitchModel};
pub use network::Network;
pub use qp::QpTable;
pub use verbs::{Payload, ReadData, ReadTarget, Verb, VerbKind};
