//! Artifact manifest: `artifacts/manifest.txt`, one line per exported
//! entry — `name;in=f32[8x1024],...;out=f32[1024],...` — written by
//! `python/compile/aot.py` and parsed here so the runtime can type-check
//! inputs before handing them to PJRT.

use std::path::Path;

use anyhow::{bail, Context, Result};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            other => bail!("unsupported dtype {other}"),
        }
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct TensorSig {
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl TensorSig {
    fn parse(s: &str) -> Result<Self> {
        let (dt, rest) = s
            .split_once('[')
            .with_context(|| format!("bad tensor sig {s}"))?;
        let dims = rest.trim_end_matches(']');
        let shape = if dims.is_empty() {
            vec![]
        } else {
            dims.split('x')
                .map(|d| d.parse::<usize>().map_err(Into::into))
                .collect::<Result<Vec<_>>>()?
        };
        Ok(TensorSig { dtype: DType::parse(dt)?, shape })
    }

    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct Signature {
    pub name: String,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub entries: Vec<Signature>,
}

impl Manifest {
    pub fn parse(body: &str) -> Result<Manifest> {
        let mut entries = Vec::new();
        for (i, line) in body.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let mut parts = line.split(';');
            let name = parts.next().context("missing name")?.to_string();
            let ins = parts
                .next()
                .and_then(|p| p.strip_prefix("in="))
                .with_context(|| format!("line {}: missing in=", i + 1))?;
            let outs = parts
                .next()
                .and_then(|p| p.strip_prefix("out="))
                .with_context(|| format!("line {}: missing out=", i + 1))?;
            let parse_list = |s: &str| -> Result<Vec<TensorSig>> {
                s.split(',').map(TensorSig::parse).collect()
            };
            entries.push(Signature { name, inputs: parse_list(ins)?, outputs: parse_list(outs)? });
        }
        Ok(Manifest { entries })
    }

    pub fn load(dir: &Path) -> Result<Manifest> {
        let body = std::fs::read_to_string(dir.join("manifest.txt"))
            .with_context(|| format!("reading manifest in {dir:?} (run `make artifacts`)"))?;
        Self::parse(&body)
    }

    pub fn get(&self, name: &str) -> Option<&Signature> {
        self.entries.iter().find(|e| e.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
pn_counter_merge;in=float32[8x1024],float32[8x1024];out=float32[1024]
account_guard;in=float32[1],float32[256];out=int32[256],float32[1]
";

    #[test]
    fn parses_manifest_lines() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.entries.len(), 2);
        let pn = m.get("pn_counter_merge").unwrap();
        assert_eq!(pn.inputs.len(), 2);
        assert_eq!(pn.inputs[0].shape, vec![8, 1024]);
        assert_eq!(pn.inputs[0].dtype, DType::F32);
        assert_eq!(pn.outputs[0].elems(), 1024);
        let ag = m.get("account_guard").unwrap();
        assert_eq!(ag.outputs[0].dtype, DType::I32);
        assert_eq!(ag.outputs[1].shape, vec![1]);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("name_only").is_err());
        assert!(Manifest::parse("x;in=f99[2];out=float32[1]").is_err());
    }

    #[test]
    fn missing_entry_is_none() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.get("nope").is_none());
    }
}
