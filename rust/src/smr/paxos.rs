//! APUS-style RDMA Multi-Paxos leader automaton (the second strong-path
//! backend; cf. "Reliable Replication Protocols on SmartNICs" — the
//! offload-friendly Paxos family).
//!
//! The stable-leader fast path replicates by *memory placement*, not
//! messaging: the leader writes contiguous log entries straight into each
//! follower's landing region with one-sided RDMA writes and counts the
//! write completions ("doorbells") toward a majority quorum — followers
//! are passive memory on the critical path. Entries batch natively: one
//! in-flight write covers up to `batch` queued ops.
//!
//! Like [`super::mu`], the automaton is pure: the engine
//! (`engine::paxos`) owns slots/logs/fabric and feeds completions back.
//! Ballots encode `(round << 8) | leader_id` so two successive leaders can
//! never collide on a ballot number; the engine fences deposed leaders at
//! the QP level (the Permission Switch) and followers additionally reject
//! writes carrying a stale ballot.

use std::collections::VecDeque;

use crate::rdt::OpCall;
use crate::sim::NodeId;

/// Compose a ballot: monotone round, leader id in the low byte.
pub fn ballot(round: u64, leader: NodeId) -> u64 {
    (round << 8) | (leader as u64 & 0xFF)
}

/// The round a ballot belongs to.
pub fn ballot_round(b: u64) -> u64 {
    b >> 8
}

/// What the engine should do after feeding a write completion.
#[derive(Clone, Debug, PartialEq)]
pub enum PaxosStep {
    /// Keep feeding completions.
    Wait,
    /// Majority of landing-region writes completed: the batch is chosen.
    Commit { start_slot: u64, ops: Vec<OpCall> },
    /// Quorum unreachable with the current follower set; the engine resets
    /// and retries after the membership view refreshes.
    Stall,
}

/// One in-flight batch of contiguous log slots (a pipeline stage).
#[derive(Debug)]
struct Flight {
    start: u64,
    ops: Vec<OpCall>,
    /// Monotone per-pump nonce: a doorbell left over from an aborted
    /// (stalled) round must not count toward the retried round's quorum,
    /// even though ballot and start_slot repeat — Mu's `round_id` guard,
    /// one-sided edition. With a window > 1 it also routes each doorbell
    /// to its flight.
    round: u64,
    acks: u32,
    fails: u32,
    targeted: u32,
    /// Quorum reached but an earlier flight hasn't: committed out of
    /// order, released (applied/answered) strictly in slot order.
    committed: bool,
}

/// Leader-side pipeline: up to `window` in-flight batches of contiguous
/// log slots. Doorbell quorums collect out of order across flights; the
/// commit cursor (the deque front) releases contiguous committed batches
/// in slot order.
#[derive(Debug)]
pub struct PaxosLeader {
    pub ballot: u64,
    n: usize,
    batch: usize,
    window: usize,
    flights: VecDeque<Flight>,
    round_id: u64,
    queue: VecDeque<(u64, OpCall)>, // (slot, op) — slots are contiguous
    pub committed: u64,
}

impl PaxosLeader {
    pub fn new(id: NodeId, n: usize, batch: usize) -> Self {
        Self::with_window(id, n, batch, 1)
    }

    pub fn with_window(id: NodeId, n: usize, batch: usize, window: usize) -> Self {
        PaxosLeader {
            ballot: ballot(1, id),
            n,
            batch: batch.max(1),
            window: window.max(1),
            flights: VecDeque::new(),
            round_id: 0,
            queue: VecDeque::new(),
            committed: 0,
        }
    }

    /// Follower write-completions needed (leader's local append is its own
    /// majority vote, exactly as in Mu).
    fn quorum_followers(&self) -> u32 {
        (self.n / 2) as u32
    }

    pub fn set_cluster_size(&mut self, n: usize) {
        self.n = n;
    }

    pub fn is_idle(&self) -> bool {
        self.flights.is_empty() && self.queue.is_empty()
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    pub fn in_flight(&self) -> bool {
        !self.flights.is_empty()
    }

    /// Current pipeline depth (for `inflight_max` telemetry).
    pub fn depth(&self) -> usize {
        self.flights.len()
    }

    /// Take over leadership: adopt a ballot strictly above everything seen
    /// (`seen` is the acceptor-side promise), keyed to this leader's id.
    pub fn assume_leadership(&mut self, id: NodeId, seen: u64) {
        let round = ballot_round(self.ballot.max(seen)) + 1;
        self.ballot = ballot(round, id);
    }

    /// Queue an op at its assigned log slot (the engine appends to its own
    /// log first, so slots arrive contiguous and monotone).
    pub fn submit(&mut self, slot: u64, op: OpCall) {
        debug_assert!(
            match self.queue.back() {
                Some(&(s, _)) => s + 1 == slot,
                None => true,
            },
            "paxos slots must be contiguous"
        );
        self.queue.push_back((slot, op));
    }

    /// Start the next batch if the window has a free stage: drains up to
    /// `batch` queued entries and returns `(ballot, round, start_slot,
    /// ops)` to fan out. The round nonce must ride the completion tokens.
    /// Call again until `None` to fill the window (pump-until-full).
    pub fn pump(&mut self) -> Option<(u64, u64, u64, Vec<OpCall>)> {
        if self.flights.len() >= self.window {
            return None;
        }
        let (start, _) = *self.queue.front()?;
        let take = self.queue.len().min(self.batch);
        let ops: Vec<OpCall> = self.queue.drain(..take).map(|(_, op)| op).collect();
        self.round_id += 1;
        self.flights.push_back(Flight {
            start,
            ops: ops.clone(),
            round: self.round_id,
            acks: 0,
            fails: 0,
            targeted: 0,
            committed: false,
        });
        Some((self.ballot, self.round_id, start, ops))
    }

    /// The engine reports how many followers the fan-out targeted (applies
    /// to the flight `pump` just started).
    pub fn round_started(&mut self, targeted: u32) {
        if let Some(f) = self.flights.back_mut() {
            f.targeted = targeted;
        }
    }

    /// Release the committed flight at the commit cursor, if any. The
    /// engine drains this after every Commit step / solo commit so flights
    /// whose quorum arrived out of order apply strictly in slot order.
    pub fn pop_released(&mut self) -> Option<(u64, Vec<OpCall>)> {
        if !self.flights.front()?.committed {
            return None;
        }
        let f = self.flights.pop_front()?;
        self.committed += f.ops.len() as u64;
        Some((f.start, f.ops))
    }

    /// Feed one write completion (`ok` = ACK doorbell, else NACK) for the
    /// in-flight batch identified by `(b, round, start_slot)`. Quorums may
    /// complete out of order across the window; `Commit` is only returned
    /// once the *front* flight commits (drain `pop_released` for any
    /// successors that committed earlier).
    pub fn on_completion(&mut self, b: u64, round: u64, start_slot: u64, ok: bool) -> PaxosStep {
        if b != self.ballot {
            return PaxosStep::Wait; // pre-takeover write
        }
        let need = self.quorum_followers();
        // Doorbells from a round that stalled and was re-pumped (same
        // ballot and slots, older nonce) match no flight and are dropped.
        let Some(f) = self.flights.iter_mut().find(|f| f.round == round) else {
            return PaxosStep::Wait;
        };
        if f.start != start_slot || f.committed {
            return PaxosStep::Wait;
        }
        if ok {
            f.acks += 1;
        } else {
            f.fails += 1;
        }
        if f.acks >= need {
            f.committed = true;
            if let Some((start, ops)) = self.pop_released() {
                return PaxosStep::Commit { start_slot: start, ops };
            }
            return PaxosStep::Wait; // blocked behind an earlier flight
        }
        let healthy_remaining = f.targeted.saturating_sub(f.acks + f.fails);
        if f.acks + healthy_remaining < need {
            return PaxosStep::Stall;
        }
        PaxosStep::Wait
    }

    /// With no live followers the leader's own local append already *is*
    /// the majority (cluster of one): commit the front flight without
    /// waiting for doorbells that can never arrive.
    pub fn commit_if_solo(&mut self) -> Option<(u64, Vec<OpCall>)> {
        if self.quorum_followers() > 0 {
            return None;
        }
        if let Some(f) = self.flights.front_mut() {
            f.committed = true;
        }
        self.pop_released()
    }

    /// Abandon the whole window (stall/leader change): every in-flight
    /// entry — including committed-but-unreleased flights, whose effects
    /// never applied — returns to the queue head in slot order.
    pub fn reset_window(&mut self) {
        while let Some(f) = self.flights.pop_back() {
            for (i, op) in f.ops.into_iter().enumerate().rev() {
                self.queue.push_front((f.start + i as u64, op));
            }
        }
    }

    /// Drop all pipeline state (recovery snapshot install).
    pub fn clear(&mut self) {
        self.flights.clear();
        self.queue.clear();
    }
}

/// Acceptor-side ballot promise: one register per replica. Real APUS keeps
/// this check in NIC/driver logic next to the landing region; writes with
/// stale ballots are ignored even if they land (belt to the QP fence's
/// suspenders).
#[derive(Debug, Default, Clone, Copy)]
pub struct PaxosAcceptor {
    pub promised: u64,
}

impl PaxosAcceptor {
    pub fn new() -> Self {
        Self::default()
    }

    /// Accept a write at ballot `b`? Adopts `b` when it is >= the promise.
    pub fn accept(&mut self, b: u64) -> bool {
        if b >= self.promised {
            self.promised = b;
            true
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn op(n: u64) -> OpCall {
        OpCall::new(1, n, 0, 0.0)
    }

    #[test]
    fn ballots_are_unique_per_leader_and_monotone() {
        assert!(ballot(2, 1) > ballot(1, 7));
        assert_ne!(ballot(3, 1), ballot(3, 2));
        assert_eq!(ballot_round(ballot(9, 4)), 9);
    }

    #[test]
    fn majority_of_doorbells_commits() {
        let mut l = PaxosLeader::new(0, 4, 1); // quorum = 2 follower doorbells
        l.submit(0, op(42));
        let (b, r, start, ops) = l.pump().unwrap();
        assert_eq!((start, ops.len()), (0, 1));
        l.round_started(3);
        assert_eq!(l.on_completion(b, r, start, true), PaxosStep::Wait);
        let s = l.on_completion(b, r, start, true);
        assert_eq!(s, PaxosStep::Commit { start_slot: 0, ops: vec![op(42)] });
        assert_eq!(l.committed, 1);
        assert!(l.is_idle());
    }

    #[test]
    fn batches_drain_up_to_batch_size() {
        let mut l = PaxosLeader::new(0, 4, 2);
        for slot in 0..3 {
            l.submit(slot, op(slot));
        }
        let (b, r, start, ops) = l.pump().unwrap();
        assert_eq!((start, ops.len()), (0, 2), "two entries coalesce");
        assert!(l.pump().is_none(), "pipeline busy");
        l.round_started(3);
        l.on_completion(b, r, start, true);
        let s = l.on_completion(b, r, start, true);
        assert_eq!(s, PaxosStep::Commit { start_slot: 0, ops: vec![op(0), op(1)] });
        let (_, _, start2, ops2) = l.pump().unwrap();
        assert_eq!((start2, ops2.len()), (2, 1), "tail entry follows");
    }

    #[test]
    fn stalls_when_quorum_impossible_and_requeues() {
        let mut l = PaxosLeader::new(0, 4, 1); // need 2 follower doorbells
        l.submit(0, op(1));
        let (b, r, start, _) = l.pump().unwrap();
        l.round_started(3);
        assert_eq!(l.on_completion(b, r, start, false), PaxosStep::Wait);
        let s = l.on_completion(b, r, start, false); // 1 healthy left < 2
        assert_eq!(s, PaxosStep::Stall);
        l.reset_window();
        assert_eq!(l.queue_len(), 1, "entry requeued at its slot");
        let (_, _, start_again, _) = l.pump().unwrap();
        assert_eq!(start_again, 0);
    }

    #[test]
    fn stale_ballot_completions_ignored() {
        let mut l = PaxosLeader::new(0, 4, 1);
        l.submit(0, op(1));
        let (b, r, start, _) = l.pump().unwrap();
        l.round_started(3);
        assert_eq!(l.on_completion(b + 256, r, start, true), PaxosStep::Wait);
        assert_eq!(l.on_completion(b, r, start + 7, true), PaxosStep::Wait);
        assert_eq!(l.on_completion(b, r, start, true), PaxosStep::Wait, "only 1 real ack");
    }

    #[test]
    fn doorbell_from_aborted_round_never_counts_for_the_retry() {
        // Stall with one real ACK still in flight, retry the same slots at
        // the same ballot: the late doorbell must not reach quorum for the
        // new round (the round nonce, not ballot/slot, is the guard).
        let mut l = PaxosLeader::new(0, 5, 1); // need 2 follower doorbells
        l.submit(0, op(9));
        let (b, r1, start, _) = l.pump().unwrap();
        l.round_started(4);
        for _ in 0..3 {
            let _ = l.on_completion(b, r1, start, false);
        }
        l.reset_window();
        l.set_cluster_size(2); // crashed peers left the live set; need 1
        let (b2, r2, start2, _) = l.pump().unwrap();
        assert_eq!((b2, start2), (b, start), "same ballot and slot re-fly");
        assert_ne!(r1, r2);
        l.round_started(1);
        assert_eq!(l.on_completion(b, r1, start, true), PaxosStep::Wait, "stale doorbell");
        assert!(matches!(l.on_completion(b2, r2, start2, true), PaxosStep::Commit { .. }));
    }

    #[test]
    fn takeover_outbids_everything_seen() {
        let mut l = PaxosLeader::new(2, 4, 1);
        let old = ballot(5, 0);
        l.assume_leadership(2, old);
        assert!(l.ballot > old);
        assert_eq!(l.ballot & 0xFF, 2, "ballot carries the leader id");
    }

    #[test]
    fn acceptor_promises_monotonically() {
        let mut a = PaxosAcceptor::new();
        assert!(a.accept(ballot(1, 0)));
        assert!(a.accept(ballot(1, 0)), "equal ballot re-accepted (same leader)");
        assert!(a.accept(ballot(2, 1)));
        assert!(!a.accept(ballot(1, 0)), "stale leader rejected");
    }

    #[test]
    fn window_keeps_multiple_rounds_in_flight() {
        let mut l = PaxosLeader::with_window(0, 4, 1, 3);
        for slot in 0..4 {
            l.submit(slot, op(slot));
        }
        assert!(l.pump().is_some());
        assert!(l.pump().is_some());
        assert!(l.pump().is_some(), "three concurrent rounds fit");
        assert_eq!(l.depth(), 3);
        assert!(l.pump().is_none(), "window full");
        assert_eq!(l.queue_len(), 1);
    }

    #[test]
    fn out_of_order_quorums_release_in_slot_order() {
        let mut l = PaxosLeader::with_window(0, 4, 1, 2); // need 2 doorbells
        l.submit(0, op(10));
        l.submit(1, op(11));
        let (b, r0, s0, _) = l.pump().unwrap();
        l.round_started(3);
        let (_, r1, s1, _) = l.pump().unwrap();
        l.round_started(3);
        // Slot 1's quorum lands first: committed out of order, held back.
        assert_eq!(l.on_completion(b, r1, s1, true), PaxosStep::Wait);
        assert_eq!(l.on_completion(b, r1, s1, true), PaxosStep::Wait, "blocked behind slot 0");
        assert!(l.pop_released().is_none(), "commit cursor at slot 0");
        // Slot 0 commits: it releases, then the parked slot 1 follows.
        l.on_completion(b, r0, s0, true);
        let s = l.on_completion(b, r0, s0, true);
        assert_eq!(s, PaxosStep::Commit { start_slot: 0, ops: vec![op(10)] });
        assert_eq!(l.pop_released(), Some((1, vec![op(11)])));
        assert_eq!(l.committed, 2);
        assert!(l.is_idle());
    }

    #[test]
    fn reset_window_requeues_every_flight_in_slot_order() {
        let mut l = PaxosLeader::with_window(0, 4, 1, 3);
        for slot in 0..3 {
            l.submit(slot, op(slot));
        }
        let (b, _, _, _) = l.pump().unwrap();
        let (_, r1, s1, _) = l.pump().unwrap();
        let (_, _, _, _) = l.pump().unwrap();
        l.round_started(3);
        // A committed-but-unreleased flight resets too: its effects never
        // applied, so a deposed leader must not treat it as durable.
        l.on_completion(b, r1, s1, true);
        l.on_completion(b, r1, s1, true);
        l.reset_window();
        assert_eq!(l.depth(), 0);
        assert_eq!(l.queue_len(), 3, "all window entries requeued");
        let (_, _, start, _) = l.pump().unwrap();
        assert_eq!(start, 0, "retry restarts from the first window slot");
        assert_eq!(l.committed, 0, "nothing released, nothing counted");
    }
}
