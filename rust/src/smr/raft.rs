//! Simplified Raft for the Waverunner baseline [5] (Fig 12).
//!
//! Waverunner accelerates the Raft replication fast path on an FPGA
//! SmartNIC while the application runs in host software; only the leader
//! serves client requests — followers reject and the client re-sends
//! (§5.2 "SafarDB vs Waverunner"). We model the stable-leader fast path:
//! AppendEntries fan-out, majority-ack commit, apply, respond. Leader
//! election on failure is the smallest-live-ID shortcut (documented
//! simplification — Fig 12 runs fault-free).

use std::collections::VecDeque;

use crate::rdt::OpCall;
use crate::sim::NodeId;

#[derive(Clone, Debug, PartialEq)]
pub enum RaftStep {
    Wait,
    /// The in-flight batch starting at `start_index` is committed: apply +
    /// respond to each entry's client.
    Commit { start_index: u64, ops: Vec<OpCall> },
}

/// Leader-side replication pipeline. One in-flight *batch* at a time
/// (Waverunner's packet-serial fast path is batch size 1), queueing behind
/// it; `pump` drains up to `batch` queued entries into one AppendEntries.
#[derive(Debug)]
pub struct RaftLeader {
    pub term: u64,
    n: usize,
    batch: usize,
    next_index: u64,
    /// (start_index, ops, distinct ack sources). Voters are tracked by id:
    /// the chaos re-pump re-ships an in-flight batch and followers re-ack,
    /// so a bare counter would let one reachable follower fake a majority.
    in_flight: Option<(u64, Vec<OpCall>, Vec<NodeId>)>,
    queue: VecDeque<(u64, OpCall)>,
    pub committed: u64,
}

impl RaftLeader {
    pub fn new(n: usize) -> Self {
        Self::with_batch(n, 1)
    }

    pub fn with_batch(n: usize, batch: usize) -> Self {
        RaftLeader {
            term: 1,
            n,
            batch: batch.max(1),
            next_index: 0,
            in_flight: None,
            queue: VecDeque::new(),
            committed: 0,
        }
    }

    /// A follower taking over after an election (generic Raft backend):
    /// next entries append after the adopted log, at a higher term.
    pub fn promote(n: usize, batch: usize, term: u64, next_index: u64) -> Self {
        let mut l = Self::with_batch(n, batch);
        l.term = term;
        l.next_index = next_index;
        l
    }

    fn majority_acks(&self) -> u32 {
        (self.n / 2) as u32 // leader's own log write is the +1 vote
    }

    pub fn set_cluster_size(&mut self, n: usize) {
        self.n = n;
    }

    /// Client op arrives at the leader. The entry's log index is assigned
    /// immediately (so callers can key pending requests on it); an
    /// AppendEntries fan-out is returned only if the pipeline was empty.
    pub fn submit(&mut self, op: OpCall) -> (u64, Option<(u64, u64, Vec<OpCall>)>) {
        let index = self.next_index;
        self.next_index += 1;
        self.queue.push_back((index, op));
        if self.in_flight.is_some() {
            return (index, None);
        }
        (index, self.pump())
    }

    /// Follower ack for the *last* index of the in-flight batch (followers
    /// ack a batch once, after appending all of it — possibly again for a
    /// chaos-mode re-ship; duplicates from the same follower count once).
    pub fn on_ack(&mut self, term: u64, index: u64, from: NodeId) -> RaftStep {
        if term != self.term {
            return RaftStep::Wait;
        }
        let majority = self.majority_acks();
        match &mut self.in_flight {
            Some((start, ops, voters)) if *start + ops.len() as u64 - 1 == index => {
                if !voters.contains(&from) {
                    voters.push(from);
                }
                if voters.len() as u32 >= majority {
                    let start = *start;
                    let ops = std::mem::take(ops);
                    self.in_flight = None;
                    self.committed += ops.len() as u64;
                    RaftStep::Commit { start_index: start, ops }
                } else {
                    RaftStep::Wait
                }
            }
            _ => RaftStep::Wait,
        }
    }

    /// Chaos-mode nudge: re-ship the in-flight batch. A lost AppendEntries
    /// or an eaten logical ack would otherwise wedge the one-in-flight
    /// pipeline forever; followers overwrite-accept the duplicates and
    /// re-ack, so the re-send is idempotent.
    pub fn refanout(&self) -> Option<(u64, u64, Vec<OpCall>)> {
        self.in_flight.as_ref().map(|(start, ops, _)| (self.term, *start, ops.clone()))
    }

    /// After a commit, start the next queued batch (up to `batch` entries)
    /// if any.
    pub fn pump(&mut self) -> Option<(u64, u64, Vec<OpCall>)> {
        if self.in_flight.is_some() {
            return None;
        }
        let (start, _) = *self.queue.front()?;
        let take = self.queue.len().min(self.batch);
        let ops: Vec<OpCall> = self.queue.drain(..take).map(|(_, op)| op).collect();
        self.in_flight = Some((start, ops.clone(), Vec::new()));
        Some((self.term, start, ops))
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }
}

/// Follower-side log acceptance.
#[derive(Debug, Default)]
pub struct RaftFollower {
    pub term: u64,
    entries: Vec<OpCall>,
    pub applied: u64,
}

impl RaftFollower {
    pub fn new() -> Self {
        Self::default()
    }

    /// Rebuild from a recovery snapshot: `entries` is the donor's
    /// committed log, whose effects the installed state plane already
    /// contains — so the restored log starts fully applied.
    pub fn restore(term: u64, entries: Vec<OpCall>) -> Self {
        RaftFollower { term, applied: entries.len() as u64, entries }
    }

    /// AppendEntries from the leader; returns whether to ack.
    pub fn on_append(&mut self, term: u64, index: u64, op: OpCall) -> bool {
        if term < self.term {
            return false; // stale leader
        }
        self.term = term;
        let idx = index as usize;
        if idx > self.entries.len() {
            return false; // gap: reject (leader would back up; fast path has none)
        }
        if idx == self.entries.len() {
            self.entries.push(op);
        } else {
            self.entries[idx] = op;
        }
        true
    }

    /// Batched AppendEntries: contiguous run starting at `start`; accepted
    /// all-or-nothing (a gap rejects the whole batch).
    pub fn on_append_batch(&mut self, term: u64, start: u64, ops: &[OpCall]) -> bool {
        if term < self.term || start as usize > self.entries.len() {
            return false;
        }
        self.term = term;
        for (i, op) in ops.iter().enumerate() {
            let idx = start as usize + i;
            if idx == self.entries.len() {
                self.entries.push(*op);
            } else {
                self.entries[idx] = *op;
            }
        }
        true
    }

    /// Apply contiguous entries (followers apply on the leader's heels).
    pub fn drain_apply(&mut self) -> Vec<OpCall> {
        let out: Vec<OpCall> = self.entries[self.applied as usize..].to_vec();
        self.applied = self.entries.len() as u64;
        out
    }

    /// Accepted log length (a promoted leader appends after this point).
    pub fn log_len(&self) -> u64 {
        self.entries.len() as u64
    }

    /// Full accepted log (a promoted leader's takeover replay source).
    pub fn entries(&self) -> &[OpCall] {
        &self.entries
    }

    /// Waverunner followers reject client requests (redirect to leader).
    pub fn handles_clients(&self) -> bool {
        false
    }
}

/// Which replica leads (fault-free runs: node 0).
pub fn initial_leader() -> NodeId {
    0
}

#[cfg(test)]
mod tests {
    use super::*;

    fn op(n: u64) -> OpCall {
        OpCall::new(0, n, 0, 0.0)
    }

    #[test]
    fn three_node_commit_needs_one_follower_ack() {
        let mut l = RaftLeader::new(3);
        let (idx, fanout) = l.submit(op(1));
        let (term, fidx, ops) = fanout.unwrap();
        assert_eq!((term, fidx, idx), (1, 0, 0));
        assert_eq!(ops, vec![op(1)]);
        let s = l.on_ack(1, 0, 1);
        assert_eq!(s, RaftStep::Commit { start_index: 0, ops: vec![op(1)] });
    }

    #[test]
    fn pipeline_serializes_entries() {
        let mut l = RaftLeader::new(3);
        l.submit(op(1)).1.unwrap();
        let (idx2, fanout2) = l.submit(op(2));
        assert_eq!(idx2, 1, "index assigned immediately");
        assert!(fanout2.is_none(), "queued behind in-flight");
        l.on_ack(1, 0, 1);
        let (_, idx, ops) = l.pump().unwrap();
        assert_eq!(idx, 1);
        assert_eq!(ops[0].a, 2);
    }

    #[test]
    fn batched_leader_coalesces_queued_entries() {
        let mut l = RaftLeader::with_batch(3, 2);
        // Empty pipeline: the first submit fans out alone.
        let (_, f1) = l.submit(op(1));
        assert_eq!(f1.unwrap().2.len(), 1);
        l.submit(op(2));
        l.submit(op(3));
        // Batch acked on its last index only.
        assert_eq!(l.on_ack(1, 0, 1), RaftStep::Commit { start_index: 0, ops: vec![op(1)] });
        let (_, start, ops) = l.pump().unwrap();
        assert_eq!((start, ops.len()), (1, 2), "two queued entries coalesce");
        assert_eq!(l.on_ack(1, 1, 1), RaftStep::Wait, "mid-batch index ignored");
        let s = l.on_ack(1, 2, 1);
        assert_eq!(s, RaftStep::Commit { start_index: 1, ops: vec![op(2), op(3)] });
        assert_eq!(l.committed, 3);
    }

    #[test]
    fn duplicate_acks_from_one_follower_count_once() {
        // n=5: majority needs 2 distinct follower acks. The chaos re-pump
        // re-ships in-flight batches and followers re-ack, so a repeat vote
        // from the same node must not fake a quorum.
        let mut l = RaftLeader::new(5);
        l.submit(op(1)).1.unwrap();
        assert_eq!(l.on_ack(1, 0, 3), RaftStep::Wait);
        assert_eq!(l.on_ack(1, 0, 3), RaftStep::Wait, "duplicate voter ignored");
        assert_eq!(l.on_ack(1, 0, 3), RaftStep::Wait, "still one distinct voter");
        let s = l.on_ack(1, 0, 4);
        assert_eq!(s, RaftStep::Commit { start_index: 0, ops: vec![op(1)] });
    }

    #[test]
    fn follower_batch_append_all_or_nothing() {
        let mut f = RaftFollower::new();
        assert!(f.on_append_batch(1, 0, &[op(1), op(2)]));
        assert!(!f.on_append_batch(1, 5, &[op(9)]), "gap rejected");
        assert!(f.on_append_batch(1, 2, &[op(3)]));
        assert_eq!(f.log_len(), 3);
        assert_eq!(f.drain_apply().len(), 3);
    }

    #[test]
    fn stale_term_acks_ignored() {
        let mut l = RaftLeader::new(3);
        l.submit(op(1)).1.unwrap();
        assert_eq!(l.on_ack(0, 0, 1), RaftStep::Wait);
        assert_eq!(l.on_ack(1, 5, 1), RaftStep::Wait, "wrong index");
    }

    #[test]
    fn follower_appends_in_order_and_applies() {
        let mut f = RaftFollower::new();
        assert!(f.on_append(1, 0, op(1)));
        assert!(f.on_append(1, 1, op(2)));
        assert!(!f.on_append(1, 5, op(9)), "gap rejected");
        let applied = f.drain_apply();
        assert_eq!(applied.len(), 2);
        assert!(!f.handles_clients());
    }

    #[test]
    fn follower_rejects_stale_term() {
        let mut f = RaftFollower::new();
        f.on_append(3, 0, op(1));
        assert!(!f.on_append(2, 1, op(2)));
    }
}
