//! Client plane: closed- and open-loop traffic generation — quota
//! accounting, workload generation, per-origin sequence numbers, the
//! open-loop admission queue, and the request-side read costs (including
//! the hybrid host cache, Figs 15–17).
//!
//! Two traffic shapes share this plane:
//!
//! * **Closed loop** (`arrival = closed`, default): `clients_per_replica`
//!   fixed slots, each issuing its next op the moment the previous one
//!   completes. Bit-identical to the pre-open-loop engine.
//! * **Open loop** (`poisson` / `bursty` / `diurnal`): one aggregate seeded
//!   arrival stream per node models millions of logical clients.
//!   `EventKind::Arrival` ticks consume quota as *offered* ops; an arrival
//!   that finds a free service slot (the same `clients_per_replica` bound)
//!   starts immediately, otherwise it waits in a bounded admission queue
//!   (`queue_cap`) — and is shed, counted but never serviced, when the
//!   queue is full. Client latency is measured from admission-queue entry,
//!   so queueing delay shows up in the response histogram.
//!
//! The pending-request maps for *forwarded* ops live with the strong path
//! (`engine::strong`), which owns their retry protocol; this plane only
//! tracks how many slots are in flight via `ReplicaCore::clients_in_flight`.

use std::collections::VecDeque;

use crate::config::{ArrivalProcess, SimConfig};
use crate::engine::path::ReplicaCore;
use crate::mem::LruCache;
use crate::rdt::OpCall;
use crate::sim::Time;
use crate::util::rng::Rng;
use crate::workload::{Generator, WorkItem};

pub struct ClientPlane {
    gen: Generator,
    /// Remaining ops this replica may offer (cluster-assigned;
    /// redistributed away from crashed replicas). In the open loop this is
    /// the un-offered remainder of the node's arrival stream.
    pub quota: u64,
    op_seq: u64,
    /// Arrival process (closed loop or one of the open-loop kinds).
    arrival: ArrivalProcess,
    /// Open loop: service parallelism (the closed loop's slot count,
    /// reused as the bound on concurrently-processed admissions).
    slots: u64,
    /// Open loop: admission-queue bound; arrivals beyond it are shed.
    queue_cap: usize,
    /// Open loop: admission timestamps of arrivals waiting for a slot.
    queue: VecDeque<Time>,
    /// Open loop: a future `EventKind::Arrival` is scheduled for this node
    /// (the stream pauses at quota exhaustion and on crash, and the
    /// cluster re-arms it when crash-time redistribution grants quota).
    armed: bool,
    /// Open loop: current arrival-stream incarnation. Crashes bump it so
    /// ticks scheduled pre-crash are ignored if they fire post-recovery.
    epoch: u32,
    /// Ops offered to this node: arrival ticks fired (open loop) or quota
    /// consumed by slots (closed loop).
    pub offered: u64,
    /// Open loop: arrivals dropped because the admission queue was full.
    pub shed: u64,
    /// Open loop: high-water mark of the admission queue.
    pub queue_depth_max: usize,
    /// Hybrid mode: host LLC model for host-resident keys.
    host_cache: Option<LruCache>,
}

impl ClientPlane {
    pub fn new(cfg: &SimConfig) -> Self {
        ClientPlane {
            gen: Generator::new(cfg),
            quota: 0,
            op_seq: 0,
            arrival: cfg.arrival,
            slots: cfg.clients_per_replica as u64,
            queue_cap: cfg.queue_cap,
            queue: VecDeque::new(),
            armed: false,
            epoch: 0,
            offered: 0,
            shed: 0,
            queue_depth_max: 0,
            host_cache: cfg.hybrid.map(|h| LruCache::new(h.host_cache_keys)),
        }
    }

    /// Total keyspace the generator addresses (sizes the data plane).
    pub fn keyspace(&self) -> u64 {
        self.gen.keyspace()
    }

    /// True when this node runs an open-loop arrival stream.
    pub fn is_open(&self) -> bool {
        self.arrival.is_open()
    }

    /// Admissions waiting for a service slot (always 0 in the closed loop).
    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// A future `Arrival` event is scheduled for this node.
    pub fn stream_armed(&self) -> bool {
        self.armed
    }

    pub fn set_stream_armed(&mut self, armed: bool) {
        self.armed = armed;
    }

    /// Current arrival-stream incarnation (see `EventKind::Arrival`).
    pub fn stream_epoch(&self) -> u32 {
        self.epoch
    }

    /// LWW timestamps compose (time, per-origin seq, origin) so ops are
    /// globally unique and merge deterministically even when one origin
    /// issues several ops in the same tick (open-loop bursts and same-tick
    /// slot boots both do). Layout: now in the top 44 bits, the low 12
    /// bits of `op_seq` next, origin id in the low byte. The seq field
    /// wraps at 4096, but two same-origin ops 4096 seqs apart can never
    /// share a tick: inter-arrival gaps and service times are >= 1 ns.
    fn lww_timestamp(&self, core: &ReplicaCore, now: Time) -> u64 {
        debug_assert!(now < 1 << 44, "virtual clock overflows the LWW timestamp packing");
        ((now.max(1)) << 20) | ((self.op_seq & 0xFFF) << 8) | core.id as u64
    }

    /// Draw the next request unconditionally (quota already consumed).
    fn generate(&mut self, core: &mut ReplicaCore, now: Time) -> WorkItem {
        self.op_seq += 1;
        let ts = self.lww_timestamp(core, now);
        let mut item = self.gen.next(&mut core.rng, &core.plane, ts);
        item.op.origin = core.id;
        item.op.seq = self.op_seq;
        core.clients_in_flight += 1;
        item
    }

    /// Closed loop: consume one quota slot and draw the next request, or
    /// `None` when the quota is spent (the slot retires). In catalog mode
    /// the generator selects the target object first (Zipfian over
    /// `objects =`), then a type-appropriate op; the returned op carries
    /// its `ObjectId`.
    pub fn next_op(&mut self, core: &mut ReplicaCore, now: Time) -> Option<WorkItem> {
        if self.quota == 0 {
            return None;
        }
        self.quota -= 1;
        self.offered += 1;
        Some(self.generate(core, now))
    }

    /// Open loop: consume one arrival from the stream (quota -> offered)
    /// and classify it. The caller has already scheduled/parked the next
    /// stream tick. Returns the generated item when a service slot is
    /// free; `None` when the arrival was queued or shed.
    pub fn admit_arrival(&mut self, core: &mut ReplicaCore, now: Time) -> Option<WorkItem> {
        debug_assert!(self.quota > 0, "arrival fired with no quota");
        self.quota -= 1;
        self.offered += 1;
        if core.clients_in_flight < self.slots {
            Some(self.generate(core, now))
        } else {
            if self.queue.len() < self.queue_cap {
                self.queue.push_back(now);
                self.queue_depth_max = self.queue_depth_max.max(self.queue.len());
            } else {
                self.shed += 1;
            }
            None
        }
    }

    /// Open loop: a service slot freed up — start the oldest queued
    /// admission, if any. Returns the item plus its original admission
    /// time (latency includes the queue wait).
    pub fn start_queued(&mut self, core: &mut ReplicaCore, now: Time) -> Option<(WorkItem, Time)> {
        let admitted_at = self.queue.pop_front()?;
        Some((self.generate(core, now), admitted_at))
    }

    /// Open loop: the seeded gap to the next arrival (>= 1 ns). The
    /// instantaneous rate is modulated by the process kind; all shapes are
    /// piecewise-exponential draws off `rng`, so streams replay
    /// bit-identically from the seed.
    pub fn next_interarrival(&self, rng: &mut Rng, now: Time) -> Time {
        let per_sec = match self.arrival {
            ArrivalProcess::Closed => unreachable!("closed loop draws no inter-arrival gaps"),
            ArrivalProcess::Poisson { rate } => rate as f64,
            ArrivalProcess::Bursty { rate, period_ns, amp } => {
                // Mean-preserving square wave: the first half of each
                // period runs `amp` times hotter than the second half.
                let on = (now % period_ns) < period_ns / 2;
                let base = 2.0 * rate as f64 / (1.0 + amp as f64);
                if on {
                    base * amp as f64
                } else {
                    base
                }
            }
            ArrivalProcess::Diurnal { rate, period_ns } => {
                // Triangle wave between 0.5x and 1.5x of the mean rate
                // (piecewise-linear: no libm trig, so draws stay
                // bit-stable across platforms).
                let phase = (now % period_ns) as f64 / period_ns as f64;
                let tri = if phase < 0.5 { 4.0 * phase - 1.0 } else { 3.0 - 4.0 * phase };
                rate as f64 * (1.0 + 0.5 * tri)
            }
        };
        let mean_ns = 1.0e9 / per_sec;
        (rng.gen_exp(mean_ns) as u64).max(1)
    }

    /// Crash: wipe the admission queue (those clients observe a connection
    /// reset, not service) and park the arrival stream. Returns the number
    /// of queued admissions killed; the in-flight kill count is handled by
    /// the failure plane's `clients_in_flight` reset.
    pub fn crash_reset(&mut self) -> u64 {
        let killed = self.queue.len() as u64;
        self.queue.clear();
        self.armed = false;
        self.epoch = self.epoch.wrapping_add(1);
        killed
    }

    /// Read cost of answering a query, after the paths' refresh fold:
    /// host-resident keys go through the LLC model and pay the PCIe
    /// response hop; on-fabric state is warm.
    pub fn query_read_cost(&mut self, core: &ReplicaCore, op: &OpCall, host_side: bool) -> u64 {
        if host_side {
            let hit = self.host_cache.as_mut().map(|c| c.access(op.b)).unwrap_or(false);
            core.sys.mem.host_keyed_read_ns(hit) + core.sys.mem.pcie_ns // response back over PCIe
        } else {
            core.warm_read_ns()
        }
    }

    /// Read cost of the permissibility precheck (§2.1) — same keyed read,
    /// no response egress.
    pub fn check_read_cost(&mut self, core: &ReplicaCore, op: &OpCall, host_side: bool) -> u64 {
        if host_side {
            let hit = self.host_cache.as_mut().map(|c| c.access(op.b)).unwrap_or(false);
            core.sys.mem.host_keyed_read_ns(hit)
        } else {
            core.warm_read_ns()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadKind;
    use crate::engine::store::Catalog;
    use crate::rdt::RdtKind;

    fn lww_plane(cfg: &SimConfig) -> (ReplicaCore, ClientPlane) {
        let mut client = ClientPlane::new(cfg);
        client.quota = 16;
        let catalog = Catalog::for_config(cfg, client.keyspace());
        (ReplicaCore::new(0, cfg, catalog, Rng::new(7)), client)
    }

    /// Satellite regression: `(now << 8) | origin` gave two ops issued by
    /// one replica in the same tick identical LWW timestamps, so the merge
    /// winner depended on delivery order. The packed per-origin `op_seq`
    /// disambiguator makes same-tick writes strictly ordered by issue.
    #[test]
    fn same_tick_lww_writes_from_one_origin_get_distinct_timestamps() {
        let mut cfg = SimConfig::safardb(WorkloadKind::Micro(RdtKind::LwwRegister));
        cfg.update_pct = 100; // every op is an LWW write carrying its timestamp
        let (mut core, mut client) = lww_plane(&cfg);
        let now = 1_000;
        let a = client.next_op(&mut core, now).expect("quota");
        let b = client.next_op(&mut core, now).expect("quota");
        assert_ne!(a.op.a, b.op.a, "same-tick LWW writes must not collide");
        assert!(b.op.a > a.op.a, "issue order breaks the same-tick tie");
        // Time still dominates: an op from any later tick outranks both.
        let c = client.next_op(&mut core, now + 1).expect("quota");
        assert!(c.op.a > b.op.a, "later tick outranks same-tick seq range");
        // Origin id stays in the low byte for cross-node uniqueness.
        assert_eq!(a.op.a & 0xFF, core.id as u64);
    }
}
