//! Workload generators: the CRDT/WRDT micro-benchmark mixes, YCSB (with
//! Zipfian key selection, Fig 16), and SmallBank (§5 Workloads).
//!
//! A generator yields the next transaction for a replica's client slot;
//! keys for the hybrid experiments are pre-partitioned into FPGA-resident
//! and host-resident ranges with the paper's operation-assignment knob.

use crate::config::{HybridConfig, SimConfig, WorkloadKind};
use crate::engine::store::{DataPlane, KV_READ, KV_WITHDRAW, KV_WRITE};
use crate::rdt::OpCall;
use crate::util::rng::{Rng, Zipf};

/// Where a keyed op's data lives (hybrid mode).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Placement {
    Fpga,
    Host,
}

/// One generated client request.
#[derive(Clone, Copy, Debug)]
pub struct WorkItem {
    pub op: OpCall,
    pub placement: Placement,
}

#[derive(Debug)]
pub struct Generator {
    workload: WorkloadKind,
    update_pct: u8,
    hybrid: Option<HybridConfig>,
    zipf_fpga: Option<Zipf>,
    zipf_host: Option<Zipf>,
    /// Keyspace when not hybrid.
    keys: u64,
    zipf_flat: Option<Zipf>,
}

impl Generator {
    pub fn new(cfg: &SimConfig) -> Self {
        let keys = default_keys(cfg.workload);
        let (zipf_fpga, zipf_host, zipf_flat) = match &cfg.hybrid {
            Some(h) => (
                Some(Zipf::new(h.fpga_keys.max(1), h.zipf_theta)),
                Some(Zipf::new((h.total_keys - h.fpga_keys).max(1), h.zipf_theta)),
                None,
            ),
            None => (None, None, Some(Zipf::new(keys.max(1), 0.0))),
        };
        Generator {
            workload: cfg.workload,
            update_pct: cfg.update_pct,
            hybrid: cfg.hybrid,
            zipf_fpga,
            zipf_host,
            keys,
            zipf_flat,
        }
    }

    /// Total keyspace size this generator addresses.
    pub fn keyspace(&self) -> u64 {
        match &self.hybrid {
            Some(h) => h.total_keys,
            None => self.keys,
        }
    }

    /// Draw the next request. `plane` supplies state-aware micro-benchmark
    /// op generation; `timestamp` seeds LWW versions.
    pub fn next(&self, rng: &mut Rng, plane: &DataPlane, timestamp: u64) -> WorkItem {
        match self.workload {
            WorkloadKind::Micro(_) => self.next_micro(rng, plane, timestamp),
            WorkloadKind::Ycsb => self.next_kv(rng, timestamp, false),
            WorkloadKind::SmallBank => self.next_kv(rng, timestamp, true),
        }
    }

    fn next_micro(&self, rng: &mut Rng, plane: &DataPlane, timestamp: u64) -> WorkItem {
        let is_update = rng.gen_bool(self.update_pct as f64 / 100.0);
        let op = if is_update || !plane.has_query() {
            // Movie has no query(): reads degrade to local no-ops at the
            // engine level; the generator always produces updates for it.
            let mut op = match plane {
                DataPlane::Micro(r) => r.gen_update(rng),
                DataPlane::Kv(_) => unreachable!("micro generator on kv plane"),
            };
            if !is_update && !plane.has_query() {
                // Keep the configured mix: non-update slots become local
                // reads that bypass replication (see §5.2 on Movie).
                return WorkItem { op: OpCall::query(), placement: Placement::Fpga };
            }
            // LWW timestamps must be unique and monotone: engine time.
            if matches!(plane.micro_kind(), Some(crate::rdt::RdtKind::LwwRegister)) {
                op.a = timestamp;
            }
            op
        } else {
            OpCall::query()
        };
        WorkItem { op, placement: Placement::Fpga }
    }

    fn next_kv(&self, rng: &mut Rng, timestamp: u64, smallbank: bool) -> WorkItem {
        let (key, placement) = self.pick_key(rng);
        let is_update = rng.gen_bool(self.update_pct as f64 / 100.0);
        let op = if !is_update {
            OpCall::new(KV_READ, 0, key, 0.0)
        } else if smallbank {
            // SmallBank update mix: half deposits, half debits (the debit
            // path is the conflicting / SMR-engaging one).
            if rng.gen_bool(0.5) {
                OpCall::new(KV_WRITE, timestamp, key, rng.gen_f64_range(1.0, 20.0))
            } else {
                OpCall::new(KV_WITHDRAW, timestamp, key, rng.gen_f64_range(1.0, 30.0))
            }
        } else {
            OpCall::new(KV_WRITE, timestamp, key, rng.gen_f64_range(-1e3, 1e3))
        };
        WorkItem { op, placement }
    }

    fn pick_key(&self, rng: &mut Rng) -> (u64, Placement) {
        match (&self.hybrid, &self.zipf_flat) {
            (Some(h), _) => {
                let to_fpga = rng.gen_bool(h.fpga_ops_pct as f64 / 100.0);
                if to_fpga {
                    (self.zipf_fpga.as_ref().unwrap().sample(rng), Placement::Fpga)
                } else {
                    let k = h.fpga_keys + self.zipf_host.as_ref().unwrap().sample(rng);
                    (k, Placement::Host)
                }
            }
            (None, Some(z)) => (z.sample(rng), Placement::Fpga),
            _ => unreachable!(),
        }
    }
}

/// Non-hybrid keyspace sizes (FPGA-only mode must fit on-fabric; §5.2 uses
/// YCSB 100K keys inside the FPGA).
pub fn default_keys(workload: WorkloadKind) -> u64 {
    match workload {
        WorkloadKind::Micro(_) => 0,
        WorkloadKind::Ycsb => 100_000,
        WorkloadKind::SmallBank => 100_000,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rdt::RdtKind;

    fn cfg(workload: WorkloadKind, update_pct: u8) -> SimConfig {
        let mut c = SimConfig::safardb(workload);
        c.update_pct = update_pct;
        c
    }

    #[test]
    fn update_fraction_respected() {
        let c = cfg(WorkloadKind::Ycsb, 25);
        let g = Generator::new(&c);
        let plane = DataPlane::for_workload(c.workload, g.keyspace());
        let mut rng = Rng::new(1);
        let mut updates = 0;
        for t in 0..10_000 {
            let w = g.next(&mut rng, &plane, t);
            if w.op.opcode != KV_READ {
                updates += 1;
            }
        }
        assert!((2_000..3_000).contains(&updates), "updates={updates}");
    }

    #[test]
    fn hybrid_placement_fraction() {
        let mut c = cfg(WorkloadKind::Ycsb, 50);
        let mut h = HybridConfig::ycsb_default();
        h.fpga_ops_pct = 30;
        c.hybrid = Some(h);
        let g = Generator::new(&c);
        let plane = DataPlane::for_workload(c.workload, g.keyspace());
        let mut rng = Rng::new(2);
        let mut fpga = 0;
        for t in 0..10_000 {
            if g.next(&mut rng, &plane, t).placement == Placement::Fpga {
                fpga += 1;
            }
        }
        assert!((2_500..3_500).contains(&fpga), "fpga={fpga}");
    }

    #[test]
    fn hybrid_keys_partition_cleanly() {
        let mut c = cfg(WorkloadKind::SmallBank, 50);
        c.hybrid = Some(HybridConfig::smallbank_default());
        let g = Generator::new(&c);
        let plane = DataPlane::for_workload(c.workload, g.keyspace());
        let mut rng = Rng::new(3);
        let h = c.hybrid.unwrap();
        for t in 0..5_000 {
            let w = g.next(&mut rng, &plane, t);
            match w.placement {
                Placement::Fpga => assert!(w.op.b < h.fpga_keys),
                Placement::Host => {
                    assert!(w.op.b >= h.fpga_keys && w.op.b < h.total_keys)
                }
            }
        }
    }

    #[test]
    fn micro_movie_reads_are_local_noops() {
        let c = cfg(WorkloadKind::Micro(RdtKind::Movie), 0);
        let g = Generator::new(&c);
        let plane = DataPlane::for_workload(c.workload, 0);
        let mut rng = Rng::new(4);
        for t in 0..100 {
            let w = g.next(&mut rng, &plane, t);
            assert!(w.op.is_query());
        }
    }

    #[test]
    fn lww_updates_get_engine_timestamps() {
        let c = cfg(WorkloadKind::Micro(RdtKind::LwwRegister), 100);
        let g = Generator::new(&c);
        let plane = DataPlane::for_workload(c.workload, 0);
        let mut rng = Rng::new(5);
        let w = g.next(&mut rng, &plane, 777);
        assert_eq!(w.op.a, 777);
    }

    #[test]
    fn smallbank_generates_both_update_kinds() {
        let c = cfg(WorkloadKind::SmallBank, 100);
        let g = Generator::new(&c);
        let plane = DataPlane::for_workload(c.workload, g.keyspace());
        let mut rng = Rng::new(6);
        let (mut dep, mut wd) = (0, 0);
        for t in 0..1_000 {
            match g.next(&mut rng, &plane, t).op.opcode {
                KV_WRITE => dep += 1,
                KV_WITHDRAW => wd += 1,
                _ => {}
            }
        }
        assert!(dep > 300 && wd > 300, "dep={dep} wd={wd}");
    }
}
