//! Identity/multiply hashers for the coordinator's hot maps. Tokens and
//! request ids are sequential u64s — SipHash (std default) wastes cycles
//! on the verb hot path (§Perf optimization 1, EXPERIMENTS.md).

use std::hash::{BuildHasherDefault, Hasher};

/// Fibonacci-multiply hasher for integer keys (not DoS-resistant; keys are
/// internal counters, never attacker-controlled).
#[derive(Default)]
pub struct FxU64Hasher {
    state: u64,
}

impl Hasher for FxU64Hasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.state
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        // Fallback for composite keys: FNV-ish fold.
        for &b in bytes {
            self.state = (self.state ^ b as u64).wrapping_mul(0x100000001b3);
        }
    }

    #[inline]
    fn write_u64(&mut self, n: u64) {
        self.state = (self.state ^ n).wrapping_mul(0x9E3779B97F4A7C15);
    }

    #[inline]
    fn write_usize(&mut self, n: usize) {
        self.write_u64(n as u64);
    }
}

pub type BuildFxU64 = BuildHasherDefault<FxU64Hasher>;

/// HashMap with the fast integer hasher.
pub type FastMap<K, V> = std::collections::HashMap<K, V, BuildFxU64>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_roundtrip() {
        let mut m: FastMap<u64, u32> = FastMap::default();
        for i in 0..10_000u64 {
            m.insert(i, i as u32 * 2);
        }
        for i in 0..10_000u64 {
            assert_eq!(m[&i], i as u32 * 2);
        }
        assert_eq!(m.len(), 10_000);
    }

    #[test]
    fn composite_keys_work() {
        let mut m: FastMap<(usize, u64), u8> = FastMap::default();
        m.insert((1, 2), 3);
        m.insert((2, 1), 4);
        assert_eq!(m[&(1, 2)], 3);
        assert_eq!(m[&(2, 1)], 4);
    }
}
