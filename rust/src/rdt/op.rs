//! Transaction representation.
//!
//! Transactions are single-statement (paper §3 fn.2) and travel inside RDMA
//! verbs as `(opcode, args)` — exactly the payload the paper's Dispatcher
//! decodes (Fig 1). `OpCall` is small and `Copy` so the simulator can move
//! millions of them without allocation.

/// Coordination category of a transaction (§2.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Category {
    /// Conflict-free, dependence-free, summarizable — relaxed path with
    /// local aggregation (§4.1).
    Reducible,
    /// Conflict-free but order/dependence-carrying — relaxed path via
    /// per-origin FIFO queues (§4.2).
    Irreducible,
    /// Requires total order via SMR (§4.3/4.4).
    Conflicting,
}

/// Reserved opcode for the read-only query() transaction (never replicated).
pub const QUERY_OP: u8 = 0xFF;

/// Catalog object address: every transaction names the RDT instance it
/// targets (the paper's "direct invocation of FPGA-resident operators" —
/// the Dispatcher routes on the object id in the verb header). Single-object
/// configurations pin it to 0 everywhere.
pub type ObjectId = u32;

/// A single-statement transaction: opcode + up to two integer args and one
/// float arg, tagged with the catalog object it targets, its origin replica
/// and per-origin sequence number (used for FIFO/dependence ordering and
/// at-most-once application).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OpCall {
    pub opcode: u8,
    pub a: u64,
    pub b: u64,
    pub x: f64,
    pub origin: usize,
    pub seq: u64,
    /// Catalog object this transaction addresses (0 in catalog-of-one).
    pub obj: ObjectId,
}

impl OpCall {
    pub fn new(opcode: u8, a: u64, b: u64, x: f64) -> Self {
        OpCall { opcode, a, b, x, origin: 0, seq: 0, obj: 0 }
    }

    pub fn query() -> Self {
        OpCall::new(QUERY_OP, 0, 0, 0.0)
    }

    pub fn is_query(&self) -> bool {
        self.opcode == QUERY_OP
    }

    /// Wire size in bytes (opcode + tag + args), used for serialization
    /// delay on the simulated link. The 8-byte tag word packs origin,
    /// object id, and per-origin sequence number, so addressing a catalog
    /// object costs no extra wire bytes.
    pub fn wire_bytes(&self) -> u64 {
        1 + 8 + 8 + 8 + 8 // opcode, origin/obj/seq tag, a, b, x
    }
}

/// Result of a query() — enough structure for the workloads and tests.
#[derive(Clone, Debug, PartialEq)]
pub enum QueryValue {
    Int(i64),
    Float(f64),
    Size(usize),
    Pair(i64, i64),
    None,
}

impl QueryValue {
    pub fn as_f64(&self) -> f64 {
        match self {
            QueryValue::Int(v) => *v as f64,
            QueryValue::Float(v) => *v,
            QueryValue::Size(v) => *v as f64,
            QueryValue::Pair(a, _) => *a as f64,
            QueryValue::None => 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn query_op_recognized() {
        assert!(OpCall::query().is_query());
        assert!(!OpCall::new(0, 1, 2, 3.0).is_query());
    }

    #[test]
    fn wire_bytes_constant_small() {
        let op = OpCall::new(3, u64::MAX, 0, -1.5);
        assert_eq!(op.wire_bytes(), 33);
    }

    #[test]
    fn query_value_coercion() {
        assert_eq!(QueryValue::Int(-3).as_f64(), -3.0);
        assert_eq!(QueryValue::Size(7).as_f64(), 7.0);
        assert_eq!(QueryValue::Pair(9, 1).as_f64(), 9.0);
        assert_eq!(QueryValue::None.as_f64(), 0.0);
    }
}
