//! Table C.1: FPGA-specific verb latencies — Write(HBM) 413 ns,
//! BRAM_Write(_Through) 309 ns, Register_Write(_Through) 285 ns (one-way,
//! ACKs excluded, as the paper notes).

use crate::mem::{MemKind, MemParams};
use crate::net::fabric::FabricParams;
use crate::util::table::Table;

pub fn run(_quick: bool) -> Vec<Table> {
    let mem = MemParams::default_params();
    let f = FabricParams::fpga();
    let mut t = Table::new(
        "Table C.1 — FPGA-specific RDMA verb latencies (one-way, no ACK)",
        &["operation", "latency_ns"],
    );
    let rows: &[(&str, MemKind)] = &[
        ("Write", MemKind::Hbm),
        ("BRAM_Write", MemKind::Bram),
        ("BRAM_Write_Through", MemKind::Bram),
        ("Register_Write", MemKind::Reg),
        ("Register_Write_Through", MemKind::Reg),
    ];
    for (name, kind) in rows {
        t.row(vec![name.to_string(), f.one_way_ns(0, *kind, &mem).to_string()]);
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    #[test]
    fn matches_paper_values() {
        let t = &super::run(true)[0];
        let v: Vec<u64> = t.rows().iter().map(|r| r[1].parse().unwrap()).collect();
        assert_eq!(v, vec![413, 309, 309, 285, 285]);
    }
}
