//! Fig 11: YCSB and SmallBank, SafarDB vs Hamband across update
//! percentages (0–50 %).
//!
//! Expected shape: ≈8× lower RT / ≈5.2× higher throughput on average;
//! Hamband *wins the read-only point* (its big CPU cache holds the whole
//! store); SmallBank shows the 0→5 % cliff where SMR engages.

use crate::config::{SimConfig, WorkloadKind};
use crate::expt::common::{cell_ops, f3, run_cells_tagged};
use crate::util::table::Table;

const UPDATES: &[u8] = &[0, 5, 15, 25, 50];

pub fn run(quick: bool) -> Vec<Table> {
    let mut tables = Vec::new();
    for workload in [WorkloadKind::Ycsb, WorkloadKind::SmallBank] {
        let mut t = Table::new(
            &format!("Fig 11 — {} : SafarDB vs Hamband", workload.name()),
            &["system", "nodes", "upd%", "rt_us", "tput_ops_us"],
        );
        let node_sweep: &[usize] = if quick { &[4, 8] } else { &[4, 6, 8] };
        let mut jobs = Vec::new();
        for system in ["SafarDB", "Hamband"] {
            for &n in node_sweep {
                for &u in UPDATES {
                    let mut cfg = match system {
                        "SafarDB" => SimConfig::safardb(workload),
                        _ => SimConfig::hamband(workload),
                    };
                    cfg.n_replicas = n;
                    cfg.update_pct = u;
                    jobs.push(((system, n, u), (cfg, cell_ops(quick))));
                }
            }
        }
        for ((system, n, u), cell, _) in run_cells_tagged(jobs) {
            t.row(vec![
                system.into(),
                n.to_string(),
                u.to_string(),
                f3(cell.rt_us),
                f3(cell.tput),
            ]);
        }
        tables.push(t);
    }
    tables
}

#[cfg(test)]
mod tests {
    use super::*;

    fn col(t: &Table, sys: &str, upd: &str, col: usize) -> Vec<f64> {
        t.rows()
            .iter()
            .filter(|r| r[0] == sys && r[2] == upd)
            .map(|r| r[col].parse().unwrap())
            .collect()
    }

    #[test]
    fn smallbank_smr_cliff_at_5pct() {
        let tables = run(true);
        let sb = &tables[1];
        let t0: f64 = col(sb, "SafarDB", "0", 4).iter().sum();
        let t5: f64 = col(sb, "SafarDB", "5", 4).iter().sum();
        assert!(t0 > t5 * 1.5, "0% {t0} should be well above 5% {t5} (SMR cliff)");
    }

    #[test]
    fn safardb_wins_update_workloads() {
        let tables = run(true);
        for t in &tables {
            let s: f64 = col(t, "SafarDB", "25", 3).iter().sum();
            let h: f64 = col(t, "Hamband", "25", 3).iter().sum();
            assert!(h > 2.0 * s, "{}: h={h} s={s}", t.headers().len());
        }
    }
}
