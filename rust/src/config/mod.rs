//! Configuration system: which system (SafarDB / Hamband / Waverunner),
//! cluster shape, workload, propagation modes, faults, hybrid-mode layout —
//! plus per-system parameter presets bundling fabric, memory, execution,
//! and power models.
//!
//! Configs are built programmatically (`SimConfig::safardb(...)`) or parsed
//! from simple `key = value` files (`parse`), since no TOML crate exists in
//! the offline set.

pub mod params;

pub use params::{ConsensusBackend, ExecParams, PowerParams, SystemParams};

use crate::rdt::{Category, RdtKind};

/// Which system a run models.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SystemKind {
    /// The paper's system: network-attached FPGA, soft RNIC, FPGA-resident
    /// RDT engine, Mu SMR.
    SafarDb,
    /// Baseline (1): CPU-hosted RDTs over traditional RDMA [41].
    Hamband,
    /// Baseline (2): FPGA SmartNIC Raft, leader-only client handling [5].
    Waverunner,
}

impl SystemKind {
    pub fn name(&self) -> &'static str {
        match self {
            SystemKind::SafarDb => "SafarDB",
            SystemKind::Hamband => "Hamband",
            SystemKind::Waverunner => "Waverunner",
        }
    }

    pub fn params(&self) -> SystemParams {
        match self {
            SystemKind::SafarDb => SystemParams::safardb(),
            SystemKind::Hamband => SystemParams::hamband(),
            SystemKind::Waverunner => SystemParams::waverunner(),
        }
    }

    /// Parameters for a run, honoring an ablation override.
    pub fn params_for(&self, cfg: &SimConfig) -> SystemParams {
        cfg.params_override.unwrap_or_else(|| self.params())
    }
}

/// Which replication path (paper plane, §4) serves a transaction
/// category. The engine holds one trait object per kind
/// (`engine::path::ReplicationPath`) and routes by [`SimConfig::path_for`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReplicationPathKind {
    /// Relaxed plane: landing zones + summarizer (§4.1–§4.2).
    Relaxed,
    /// Strongly-ordered plane: Mu SMR, or Raft for Waverunner (§4.3–§4.4).
    Strong,
}

/// How a transaction category is propagated to remote replicas
/// (the Figs 6–8 sweeps; §4.1–4.3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PropagationMode {
    /// RDMA Write into HBM, reader folds on access (§4.1/4.2/4.3 config 1,
    /// "no buffer").
    WriteNoBuffer,
    /// RDMA Write into HBM + background poller refreshing an on-fabric
    /// copy (§4.1 config 2).
    WriteBuffered,
    /// FPGA-specific RDMA RPC verb: remote accelerator state updated
    /// directly from the network (§4.1/4.2 config RPC).
    Rpc,
    /// RDMA RPC Write-Through: accelerator update + simultaneous
    /// replication-log append (§4.3 config 2, conflicting only).
    WriteThrough,
}

/// Fault injection plan (Fig 14, §3 fault model).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultSpec {
    /// Crash a specific node once a fraction of ops have completed.
    CrashAtFraction { node: usize, fraction_pct: u8 },
    /// Crash whoever is leader at that point (Fig 14 c/d).
    CrashLeaderAtFraction { fraction_pct: u8 },
    /// Crash a follower, then bring it back ("return to functionality",
    /// §3): the leader detects the resumed heartbeat and replays its log.
    CrashThenRecover { node: usize, crash_pct: u8, recover_pct: u8 },
}

/// Hybrid-mode layout (Figs 15–17): part of the keyspace FPGA-resident,
/// the rest in host memory behind the CPU cache.
#[derive(Clone, Copy, Debug)]
pub struct HybridConfig {
    /// Total keys (YCSB keys / SmallBank accounts).
    pub total_keys: u64,
    /// Keys resident on the FPGA (hot set).
    pub fpga_keys: u64,
    /// Fraction (0..=100) of operations targeting FPGA-resident keys.
    pub fpga_ops_pct: u8,
    /// Zipfian skew of key selection (θ=0 uniform).
    pub zipf_theta: f64,
    /// Host LLC model capacity in keys.
    pub host_cache_keys: usize,
}

impl HybridConfig {
    pub fn ycsb_default() -> Self {
        // Scaled 10:1 from the paper's 100K FPGA / 10M host keys so exact
        // LRU simulation stays cheap; ratios preserved (DESIGN.md §1).
        HybridConfig {
            total_keys: 1_010_000,
            fpga_keys: 10_000,
            fpga_ops_pct: 50,
            zipf_theta: 0.0,
            host_cache_keys: 150_000,
        }
    }

    pub fn smallbank_default() -> Self {
        // Paper: 10M FPGA / 90M host accounts, scaled 100:1.
        HybridConfig {
            total_keys: 1_000_000,
            fpga_keys: 100_000,
            fpga_ops_pct: 50,
            zipf_theta: 0.0,
            host_cache_keys: 150_000,
        }
    }
}

/// Workload selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkloadKind {
    /// One RDT instance, update/query mix (the micro-benchmarks).
    Micro(RdtKind),
    /// YCSB over a keyspace of LWW registers (Fig 11/12/15/16).
    Ycsb,
    /// SmallBank over accounts (Fig 11/15/16/17).
    SmallBank,
}

impl WorkloadKind {
    pub fn name(&self) -> String {
        match self {
            WorkloadKind::Micro(k) => k.name().to_string(),
            WorkloadKind::Ycsb => "YCSB".to_string(),
            WorkloadKind::SmallBank => "SmallBank".to_string(),
        }
    }
}

/// Full run configuration.
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub system: SystemKind,
    pub n_replicas: usize,
    pub workload: WorkloadKind,
    /// Total operations across the cluster (paper: 4M; sweeps scale down).
    pub total_ops: u64,
    /// Percent of ops that are updates (the rest are query()).
    pub update_pct: u8,
    /// Closed-loop client slots per replica.
    pub clients_per_replica: usize,
    pub prop_reducible: PropagationMode,
    pub prop_irreducible: PropagationMode,
    pub prop_conflicting: PropagationMode,
    /// Consensus engine on the strongly-ordered path (Mu / Raft / Paxos).
    /// Waverunner's strong path *is* its SmartNIC Raft pipeline, so that
    /// system pins Raft; everything else defaults to Mu.
    pub backend: ConsensusBackend,
    /// Bookkeeping for kv parsing: true once a `backend =` line was
    /// applied. `system = waverunner` implies Raft only while the backend
    /// is *not* an explicit user choice — across multiple `apply_kv` calls
    /// (the CLI applies one per argument) — so an explicit-but-incompatible
    /// pick surfaces through `validate()` instead of being overridden.
    pub backend_explicit: bool,
    /// Per-path batching: up to this many queued submissions coalesce into
    /// one wire verb (relaxed fan-out and leader-side log appends). 1 =
    /// batching off, bit-identical to the pre-batching engine.
    pub batch_size: u32,
    /// Reducible ops aggregated locally before one propagation (§5.4; 1 =
    /// propagate every op).
    pub summarize_threshold: u32,
    pub seed: u64,
    pub fault: Option<FaultSpec>,
    pub hybrid: Option<HybridConfig>,
    /// Background poll interval for buffered/queue/log pollers (ns).
    pub poll_interval_ns: u64,
    /// Heartbeat scanner period (ns) and #unchanged reads to declare death.
    pub heartbeat_period_ns: u64,
    pub hb_fail_threshold: u32,
    /// Ablation hook: replace the system's parameter bundle (fabric /
    /// memory / exec / power) for this run only.
    pub params_override: Option<SystemParams>,
}

impl SimConfig {
    pub fn new(system: SystemKind, workload: WorkloadKind) -> Self {
        SimConfig {
            system,
            n_replicas: 4,
            workload,
            total_ops: 100_000,
            update_pct: 15,
            clients_per_replica: 4,
            prop_reducible: PropagationMode::Rpc,
            prop_irreducible: PropagationMode::Rpc,
            prop_conflicting: PropagationMode::WriteThrough,
            backend: ConsensusBackend::Mu,
            backend_explicit: false,
            batch_size: 1,
            summarize_threshold: 1,
            seed: 0xC0FFEE,
            fault: None,
            hybrid: None,
            poll_interval_ns: 400,
            heartbeat_period_ns: 20_000,
            hb_fail_threshold: 4,
            params_override: None,
        }
    }

    /// SafarDB with its best configuration (RPC verbs everywhere).
    pub fn safardb(workload: WorkloadKind) -> Self {
        SimConfig::new(SystemKind::SafarDb, workload)
    }

    /// SafarDB restricted to standard verbs + buffering ("SafarDB
    /// (Baseline)" in Figs 8/10).
    pub fn safardb_baseline(workload: WorkloadKind) -> Self {
        let mut c = SimConfig::new(SystemKind::SafarDb, workload);
        c.prop_reducible = PropagationMode::WriteBuffered;
        c.prop_irreducible = PropagationMode::WriteNoBuffer;
        c.prop_conflicting = PropagationMode::WriteNoBuffer;
        c
    }

    /// Hamband: CPU RDMA, standard verbs only.
    pub fn hamband(workload: WorkloadKind) -> Self {
        let mut c = SimConfig::new(SystemKind::Hamband, workload);
        c.prop_reducible = PropagationMode::WriteNoBuffer;
        c.prop_irreducible = PropagationMode::WriteNoBuffer;
        c.prop_conflicting = PropagationMode::WriteNoBuffer;
        // CPU pollers are threads, not fabric logic: coarser interval.
        c.poll_interval_ns = 1_200;
        c
    }

    /// Waverunner: 3-node Raft, leader-only clients.
    pub fn waverunner(workload: WorkloadKind) -> Self {
        let mut c = SimConfig::new(SystemKind::Waverunner, workload);
        c.n_replicas = 3;
        c.backend = ConsensusBackend::Raft;
        c
    }

    /// Category → replication-path routing. Waverunner replicates every
    /// update through Raft — no hybrid consistency, which is the point of
    /// the Fig 12 comparison (§5.2). Summarization (§5.4) diverts
    /// conflicting ops onto the relaxed path, trading integrity staleness
    /// for performance.
    pub fn path_for(&self, category: Category) -> ReplicationPathKind {
        if self.system == SystemKind::Waverunner {
            return ReplicationPathKind::Strong;
        }
        match category {
            Category::Reducible | Category::Irreducible => ReplicationPathKind::Relaxed,
            Category::Conflicting if self.summarize_threshold > 1 => ReplicationPathKind::Relaxed,
            Category::Conflicting => ReplicationPathKind::Strong,
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.n_replicas < 2 {
            return Err(format!("n_replicas must be >= 2, got {}", self.n_replicas));
        }
        if self.n_replicas > crate::rdt::crdt::counter::MAX_REPLICAS {
            return Err(format!("n_replicas must be <= 16, got {}", self.n_replicas));
        }
        if self.update_pct > 100 {
            return Err(format!("update_pct must be <= 100, got {}", self.update_pct));
        }
        if self.total_ops == 0 {
            return Err("total_ops must be positive".into());
        }
        if self.clients_per_replica == 0 {
            return Err("clients_per_replica must be positive".into());
        }
        if self.summarize_threshold == 0 {
            return Err("summarize_threshold must be >= 1".into());
        }
        if self.batch_size == 0 {
            return Err("batch_size must be >= 1 (1 = batching off)".into());
        }
        if self.batch_size > 1024 {
            return Err(format!("batch_size must be <= 1024, got {}", self.batch_size));
        }
        if self.system == SystemKind::Waverunner && self.backend != ConsensusBackend::Raft {
            return Err(format!(
                "Waverunner's strong path is its SmartNIC Raft pipeline; backend '{}' \
                 is not selectable for it",
                self.backend.name()
            ));
        }
        if self.backend == ConsensusBackend::Raft
            && self.system != SystemKind::Waverunner
            && self.fault.is_some()
        {
            // The stand-alone Raft backend has promotion-on-election but no
            // follower-log snapshot/truncation recovery (ROADMAP open item):
            // crash runs would *silently* diverge, so reject them outright.
            return Err(
                "the stand-alone raft backend does not support fault injection yet; \
                 use backend mu or paxos for crash runs"
                    .into(),
            );
        }
        if self.system != SystemKind::SafarDb {
            let rpc = [self.prop_reducible, self.prop_irreducible]
                .iter()
                .any(|m| matches!(m, PropagationMode::Rpc | PropagationMode::WriteThrough))
                || matches!(self.prop_conflicting, PropagationMode::Rpc | PropagationMode::WriteThrough);
            if rpc && self.system == SystemKind::Hamband {
                return Err("Hamband's RNIC has no FPGA-specific RPC verbs".into());
            }
        }
        if let Some(h) = &self.hybrid {
            if h.fpga_keys > h.total_keys {
                return Err("hybrid: fpga_keys > total_keys".into());
            }
            if h.fpga_ops_pct > 100 {
                return Err("hybrid: fpga_ops_pct > 100".into());
            }
        }
        Ok(())
    }

    /// Parse a simple `key = value` config file body over a base config.
    pub fn apply_kv(&mut self, body: &str) -> Result<(), String> {
        for (lineno, raw) in body.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let (k, v) = (k.trim(), v.trim());
            let bad = |what: &str| format!("line {}: bad {what}: {v}", lineno + 1);
            match k {
                "replicas" => self.n_replicas = v.parse().map_err(|_| bad("replicas"))?,
                "total_ops" => self.total_ops = v.parse().map_err(|_| bad("total_ops"))?,
                "update_pct" => self.update_pct = v.parse().map_err(|_| bad("update_pct"))?,
                "clients" => {
                    self.clients_per_replica = v.parse().map_err(|_| bad("clients"))?
                }
                "seed" => self.seed = v.parse().map_err(|_| bad("seed"))?,
                "summarize" => {
                    self.summarize_threshold = v.parse().map_err(|_| bad("summarize"))?
                }
                "poll_interval_ns" => {
                    self.poll_interval_ns = v.parse().map_err(|_| bad("poll_interval_ns"))?
                }
                "backend" => {
                    self.backend = ConsensusBackend::parse(v).ok_or_else(|| bad("backend"))?;
                    self.backend_explicit = true;
                }
                "batch" | "batch_size" => {
                    self.batch_size = v.parse().map_err(|_| bad("batch_size"))?
                }
                "system" => {
                    self.system = match v {
                        "safardb" => SystemKind::SafarDb,
                        "hamband" => SystemKind::Hamband,
                        "waverunner" => {
                            // Waverunner's strong path is its Raft pipeline;
                            // an explicit backend choice (any apply_kv call)
                            // wins and is judged by validate() instead.
                            if !self.backend_explicit {
                                self.backend = ConsensusBackend::Raft;
                            }
                            SystemKind::Waverunner
                        }
                        _ => return Err(bad("system")),
                    }
                }
                _ => return Err(format!("line {}: unknown key '{k}'", lineno + 1)),
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        for c in [
            SimConfig::safardb(WorkloadKind::Micro(RdtKind::PnCounter)),
            SimConfig::safardb_baseline(WorkloadKind::Micro(RdtKind::Account)),
            SimConfig::hamband(WorkloadKind::Ycsb),
            SimConfig::waverunner(WorkloadKind::Ycsb),
        ] {
            c.validate().expect("preset must validate");
        }
    }

    #[test]
    fn hamband_cannot_use_rpc_verbs() {
        let mut c = SimConfig::hamband(WorkloadKind::Ycsb);
        c.prop_reducible = PropagationMode::Rpc;
        assert!(c.validate().is_err());
    }

    #[test]
    fn bounds_checked() {
        let mut c = SimConfig::safardb(WorkloadKind::Ycsb);
        c.n_replicas = 1;
        assert!(c.validate().is_err());
        c.n_replicas = 64;
        assert!(c.validate().is_err());
        c.n_replicas = 8;
        c.update_pct = 101;
        assert!(c.validate().is_err());
    }

    #[test]
    fn kv_parse_applies_and_rejects() {
        let mut c = SimConfig::safardb(WorkloadKind::Ycsb);
        c.apply_kv("replicas = 6\nupdate_pct = 25 # comment\n\nseed = 7\n").unwrap();
        assert_eq!(c.n_replicas, 6);
        assert_eq!(c.update_pct, 25);
        assert_eq!(c.seed, 7);
        assert!(c.apply_kv("nope = 1").is_err());
        assert!(c.apply_kv("replicas").is_err());
        assert!(c.apply_kv("replicas = x").is_err());
    }

    #[test]
    fn path_routing_matches_planes() {
        let c = SimConfig::safardb(WorkloadKind::SmallBank);
        assert_eq!(c.path_for(Category::Reducible), ReplicationPathKind::Relaxed);
        assert_eq!(c.path_for(Category::Irreducible), ReplicationPathKind::Relaxed);
        assert_eq!(c.path_for(Category::Conflicting), ReplicationPathKind::Strong);

        // §5.4: summarization diverts conflicting ops off the SMR path.
        let mut batched = c.clone();
        batched.summarize_threshold = 8;
        assert_eq!(batched.path_for(Category::Conflicting), ReplicationPathKind::Relaxed);

        // Waverunner replicates everything through Raft (§5.2).
        let w = SimConfig::waverunner(WorkloadKind::Ycsb);
        assert_eq!(w.path_for(Category::Reducible), ReplicationPathKind::Strong);
        assert_eq!(w.path_for(Category::Conflicting), ReplicationPathKind::Strong);
    }

    #[test]
    fn backend_and_batch_knobs() {
        let mut c = SimConfig::safardb(WorkloadKind::Micro(RdtKind::Account));
        assert_eq!(c.backend, ConsensusBackend::Mu, "default backend is Mu");
        assert_eq!(c.batch_size, 1, "batching defaults off");
        c.apply_kv("backend = paxos\nbatch = 8\n").unwrap();
        assert_eq!(c.backend, ConsensusBackend::Paxos);
        assert_eq!(c.batch_size, 8);
        c.validate().expect("paxos + batching validates");
        assert!(c.apply_kv("backend = zab").is_err());

        c.batch_size = 0;
        assert!(c.validate().is_err(), "batch_size 0 rejected");
        c.batch_size = 2048;
        assert!(c.validate().is_err(), "batch_size cap enforced");

        // Waverunner's strong path is its Raft pipeline — backend pinned.
        let mut w = SimConfig::waverunner(WorkloadKind::Ycsb);
        assert_eq!(w.backend, ConsensusBackend::Raft);
        w.backend = ConsensusBackend::Paxos;
        assert!(w.validate().is_err());

        // Stand-alone Raft has no crash recovery: fault runs must error
        // loudly instead of silently diverging.
        let mut r = SimConfig::safardb(WorkloadKind::Micro(RdtKind::Account));
        r.backend = ConsensusBackend::Raft;
        r.validate().expect("fault-free raft is fine");
        r.fault = Some(FaultSpec::CrashAtFraction { node: 1, fraction_pct: 30 });
        assert!(r.validate().is_err(), "raft + fault injection rejected");
        r.backend = ConsensusBackend::Paxos;
        r.validate().expect("paxos supports crash runs");

        // kv: selecting waverunner implies raft, but an explicit backend
        // choice wins in either key order — even split across apply_kv
        // calls, as the CLI applies one per argument — and is then
        // rejected by validate instead of silently overridden.
        let mut k = SimConfig::safardb(WorkloadKind::Ycsb);
        k.apply_kv("system = waverunner").unwrap();
        assert_eq!(k.backend, ConsensusBackend::Raft, "waverunner implies raft");
        let mut k2 = SimConfig::safardb(WorkloadKind::Ycsb);
        k2.apply_kv("backend = mu\nsystem = waverunner").unwrap();
        assert_eq!(k2.backend, ConsensusBackend::Mu, "explicit choice preserved");
        assert!(k2.validate().is_err(), "incompatible combination surfaces");
        let mut k3 = SimConfig::safardb(WorkloadKind::Ycsb);
        k3.apply_kv("backend = mu").unwrap();
        k3.apply_kv("system = waverunner").unwrap();
        assert_eq!(k3.backend, ConsensusBackend::Mu, "explicitness survives across calls");
        assert!(k3.validate().is_err());
    }

    #[test]
    fn hybrid_validation() {
        let mut c = SimConfig::safardb(WorkloadKind::Ycsb);
        let mut h = HybridConfig::ycsb_default();
        h.fpga_keys = h.total_keys + 1;
        c.hybrid = Some(h);
        assert!(c.validate().is_err());
    }
}
