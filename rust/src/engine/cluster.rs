//! Cluster builder and run loop: N replicas over the simulated fabric,
//! closed-loop clients, fault injection, termination + quiescence drain,
//! and report assembly (response time / throughput / power — the paper's
//! metrics, §5).

use crate::config::{FaultSpec, SimConfig};
use crate::engine::replica::Replica;
use crate::engine::Ctx;
use crate::metrics::RunMetrics;
use crate::net::{Network, QpTable};
use crate::power::{self, PowerReport};
use crate::sim::{EventKind, EventQueue, NodeId};
use crate::util::rng::Rng;

/// Everything an experiment needs from one run.
#[derive(Debug)]
pub struct RunReport {
    pub metrics: RunMetrics,
    pub power: PowerReport,
    /// Post-quiescence state digests (crashed replicas excluded).
    pub digests: Vec<u64>,
    pub crashed: Vec<bool>,
    pub invariants_ok: bool,
    pub leader: NodeId,
    /// Per-replica human-readable state dumps (divergence diagnosis).
    pub dumps: Vec<String>,
    /// Wall-clock seconds the simulation itself took (engine §Perf).
    pub wall_s: f64,
}

impl RunReport {
    pub fn converged(&self) -> bool {
        let mut live = self
            .digests
            .iter()
            .zip(&self.crashed)
            .filter(|&(_, &c)| !c)
            .map(|(&d, _)| d);
        match live.next() {
            None => true,
            Some(first) => live.all(|d| d == first),
        }
    }

    pub fn response_us(&self) -> f64 {
        self.metrics.response_us()
    }

    pub fn throughput(&self) -> f64 {
        self.metrics.throughput_ops_per_us()
    }
}

pub struct Cluster {
    cfg: SimConfig,
    replicas: Vec<Replica>,
    q: EventQueue,
    net: Network,
    qps: QpTable,
    metrics: RunMetrics,
}

impl Cluster {
    pub fn new(cfg: SimConfig) -> Self {
        cfg.validate().expect("invalid SimConfig");
        let mut root = Rng::new(cfg.seed);
        let replicas: Vec<Replica> =
            (0..cfg.n_replicas).map(|id| Replica::new(id, &cfg, &mut root)).collect();
        let mem = cfg.system.params_for(&cfg).mem;
        Cluster {
            net: Network::new(cfg.n_replicas, mem),
            qps: QpTable::full_mesh(cfg.n_replicas),
            q: EventQueue::new(),
            metrics: RunMetrics::new(cfg.n_replicas),
            replicas,
            cfg,
        }
    }

    /// Run to completion: all ops issued and completed, then the event
    /// queue drained to quiescence, then pending state force-flushed for
    /// the convergence check.
    pub fn run(mut self) -> RunReport {
        let wall_start = std::time::Instant::now();
        let n = self.cfg.n_replicas;
        let per_replica = self.cfg.total_ops / n as u64;
        let target: u64 = per_replica * n as u64;

        // Boot replicas.
        for i in 0..n {
            let (mut ctx, replica) = split(&mut self.q, &mut self.net, &mut self.qps, &mut self.metrics, &mut self.replicas, i, false);
            replica.boot(&mut ctx, self.cfg.clients_per_replica, per_replica);
        }

        // Fault injection plan: translate fraction -> completed-op watermark.
        let fault_at = self.cfg.fault.map(|f| match f {
            FaultSpec::CrashAtFraction { node, fraction_pct } => {
                (node, target * fraction_pct as u64 / 100, None)
            }
            FaultSpec::CrashLeaderAtFraction { fraction_pct } => {
                (usize::MAX, target * fraction_pct as u64 / 100, None) // resolved at trigger
            }
            FaultSpec::CrashThenRecover { node, crash_pct, recover_pct } => (
                node,
                target * crash_pct as u64 / 100,
                Some(target * recover_pct as u64 / 100),
            ),
        });
        let mut fault_pending = fault_at;
        let mut recover_pending: Option<(usize, u64)> = None;
        // Snapshot transfer runs after the cluster has re-included the
        // returned node (heartbeat detection window), so no relaxed op can
        // fall between the snapshot point and re-inclusion.
        let mut snapshot_at: Option<(usize, u64)> = None;
        let grace_ns = self.cfg.heartbeat_period_ns * (self.cfg.hb_fail_threshold as u64 + 4);

        let mut draining = false;
        let mut events: u64 = 0;
        // Hard safety valve (runaway bug guard), generous: 400 events/op.
        let event_cap = 4_000_000 + target.saturating_mul(400);

        while let Some(ev) = self.q.pop() {
            events += 1;
            if events > event_cap {
                let status: Vec<String> =
                    self.replicas.iter().map(|r| r.debug_status()).collect();
                panic!(
                    "event cap exceeded: {} events for {} ops (completed {})\n{}",
                    events,
                    target,
                    self.metrics.total_completed(),
                    status.join("\n")
                );
            }

            let completed = self.metrics.total_completed();

            // Trigger the recovery once its watermark passes: the returned
            // replica pulls a snapshot from a live donor (relaxed state)
            // and the leader's heartbeat-driven log replay covers anything
            // committed during the transfer (§3).
            if let Some((node, at)) = recover_pending {
                if completed >= at {
                    let t = self.q.now();
                    self.q.push(t, node, EventKind::Recover);
                    snapshot_at = Some((node, t + grace_ns));
                    recover_pending = None;
                }
            }
            if let Some((node, at)) = snapshot_at {
                if self.q.now() >= at {
                    let t = self.q.now();
                    if let Some(donor) = (0..n).find(|&i| i != node && !self.replicas[i].crashed()) {
                        let (plane, logs, leader) = self.replicas[donor].snapshot_state();
                        self.replicas[node].install_snapshot(plane, logs, leader, &mut self.qps, t);
                    }
                    snapshot_at = None;
                }
            }

            // Trigger the crash once the watermark passes.
            if let Some((node, at, recover)) = fault_pending {
                if completed >= at {
                    let node = if node == usize::MAX { self.current_leader() } else { node };
                    if let Some(rec_at) = recover {
                        recover_pending = Some((node, rec_at));
                    }
                    let t = self.q.now();
                    self.q.push(t, node, EventKind::Crash);
                    // Redistribute the crashed node's remaining quota.
                    let remaining = self.replicas[node].take_quota();
                    let live: Vec<NodeId> = (0..n).filter(|&i| i != node).collect();
                    for (j, &r) in live.iter().enumerate() {
                        let share = remaining / live.len() as u64
                            + if j < (remaining % live.len() as u64) as usize { 1 } else { 0 };
                        self.replicas[r].grant_quota(share);
                    }
                    fault_pending = None;
                }
            }

            if !draining && self.all_quota_spent() && self.no_pending_clients() {
                draining = true;
            }

            let dest = ev.dest;
            let (mut ctx, replica) = split(&mut self.q, &mut self.net, &mut self.qps, &mut self.metrics, &mut self.replicas, dest, draining);
            replica.handle(&mut ctx, ev.kind);

            if !draining && self.all_quota_spent() && self.no_pending_clients() {
                draining = true;
            }
        }

        // Quiescence: force-flush remaining landed-but-unapplied state so
        // convergence is checked on fully-propagated replicas.
        self.metrics.makespan_ns = self.metrics.makespan_from(&self.replicas);
        for (i, r) in self.replicas.iter_mut().enumerate() {
            if !r.crashed() {
                r.flush_all_pending();
            }
            self.metrics.busy_ns[i] = r.busy_total();
            self.metrics.executions += r.executions();
            self.metrics.rejected += r.rejected();
        }

        self.metrics.events = events;
        let power = power::estimate(&self.cfg.system.params_for(&self.cfg).power, &self.metrics);
        let digests: Vec<u64> = self.replicas.iter().map(|r| r.digest()).collect();
        let dumps: Vec<String> = self.replicas.iter().map(|r| r.plane_dump()).collect();
        let crashed: Vec<bool> = self.replicas.iter().map(|r| r.crashed()).collect();
        let invariants_ok = self
            .replicas
            .iter()
            .filter(|r| !r.crashed())
            .all(|r| r.invariant_ok());
        let leader = self.current_leader();

        RunReport {
            metrics: self.metrics,
            power,
            digests,
            dumps,
            crashed,
            invariants_ok,
            leader,
            wall_s: wall_start.elapsed().as_secs_f64(),
        }
    }

    fn all_quota_spent(&self) -> bool {
        self.replicas.iter().all(|r| r.quota() == 0 || r.crashed())
    }

    fn no_pending_clients(&self) -> bool {
        // A client slot is pending from the event that consumes its quota
        // until its response is recorded — forwarded/SMR ops stay pending
        // across events. The drain flag must not flip while any live
        // replica still owes a response: background timers (heartbeats,
        // pollers) may be exactly what those completions are waiting on.
        // Crashed replicas' slots died with them (their in-flight count is
        // reset at crash time; their quota was redistributed).
        self.replicas.iter().all(|r| r.crashed() || r.in_flight() == 0)
    }

    fn current_leader(&self) -> NodeId {
        // The smallest live replica's own view (they agree at quiescence).
        self.replicas
            .iter()
            .find(|r| !r.crashed())
            .map(|r| r.leader())
            .unwrap_or(0)
    }
}

impl RunMetrics {
    fn makespan_from(&self, replicas: &[Replica]) -> u64 {
        // System execution time: until the last client op completed (the
        // leader's busy time dominates this for WRDTs — appendix D.1 —
        // but fault recovery delays count too, which Fig 14 needs).
        let busy_bound = replicas.iter().map(|r| r.busy_total()).max().unwrap_or(0);
        self.last_completion_ns.max(busy_bound).max(1)
    }
}

/// Split-borrow helper: one replica mutable alongside the shared
/// infrastructure.
fn split<'a>(
    q: &'a mut EventQueue,
    net: &'a mut Network,
    qps: &'a mut QpTable,
    metrics: &'a mut RunMetrics,
    replicas: &'a mut [Replica],
    idx: usize,
    draining: bool,
) -> (Ctx<'a>, &'a mut Replica) {
    let replica = &mut replicas[idx];
    (Ctx { q, net, qps, metrics, draining }, replica)
}

/// Convenience: build + run.
pub fn run(cfg: SimConfig) -> RunReport {
    Cluster::new(cfg).run()
}
