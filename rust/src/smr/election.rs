//! Leader Switch Plane (§4.4): heartbeat tracking, crash detection, and
//! smallest-live-ID leader election — plus the sharded-placement table
//! that generalizes "the leader" to one leader per global sync group.
//!
//! Each replica keeps an RDMA-exposed heartbeat counter it increments
//! periodically; its Heartbeat Scanner RDMA-reads every other replica's
//! counter. A counter unchanged for `threshold` consecutive reads marks the
//! replica failed; a counter that moves again marks it recovered. If the
//! failed replica was the leader, the new leader is the smallest live ID
//! and every live replica performs a Permission Switch (Fig 13).
//!
//! Under `placement != single`, [`PlacementTable`] replaces the single
//! election rule: every replica evolves an identical per-group leader
//! assignment from the initial deterministic placement plus the sequence
//! of observed crashes (reassigning only the dead node's groups), so no
//! coordination is needed to agree on who leads what.

use crate::config::LeaderPlacement;
use crate::sim::NodeId;

#[derive(Clone, Copy, Debug, Default)]
struct PeerState {
    last_value: u64,
    unchanged: u32,
    alive: bool,
}

#[derive(Clone, Debug)]
pub struct HeartbeatTracker {
    me: NodeId,
    peers: Vec<PeerState>,
    threshold: u32,
}

/// What a heartbeat observation revealed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HbVerdict {
    Alive,
    /// Crossed the failure threshold on *this* observation.
    JustFailed,
    /// Already considered failed.
    StillDead,
    /// Was failed, counter moved again (§3: replicas may return).
    Recovered,
}

impl HeartbeatTracker {
    pub fn new(me: NodeId, n: usize, threshold: u32) -> Self {
        HeartbeatTracker {
            me,
            peers: vec![PeerState { last_value: 0, unchanged: 0, alive: true }; n],
            threshold,
        }
    }

    /// Feed one heartbeat read of `peer`.
    pub fn observe(&mut self, peer: NodeId, value: u64) -> HbVerdict {
        debug_assert_ne!(peer, self.me);
        let s = &mut self.peers[peer];
        if value != s.last_value {
            s.last_value = value;
            s.unchanged = 0;
            if !s.alive {
                s.alive = true;
                return HbVerdict::Recovered;
            }
            return HbVerdict::Alive;
        }
        if !s.alive {
            return HbVerdict::StillDead;
        }
        s.unchanged += 1;
        if s.unchanged >= self.threshold {
            s.alive = false;
            HbVerdict::JustFailed
        } else {
            HbVerdict::Alive
        }
    }

    /// A read that never completed (node crashed hard): counts as an
    /// unchanged observation.
    pub fn observe_timeout(&mut self, peer: NodeId) -> HbVerdict {
        let v = self.peers[peer].last_value;
        self.observe(peer, v)
    }

    pub fn is_alive(&self, peer: NodeId) -> bool {
        if peer == self.me {
            true
        } else {
            self.peers[peer].alive
        }
    }

    /// Live replica set as this replica sees it (self always included).
    pub fn live_set(&self) -> Vec<NodeId> {
        (0..self.peers.len()).filter(|&i| self.is_alive(i)).collect()
    }

    /// Election rule: the live replica with the smallest ID (§4.4).
    pub fn elect_leader(&self) -> NodeId {
        self.live_set().into_iter().min().expect("self is always live")
    }
}

/// Deterministic per-group leadership assignment for sharded placement
/// policies.
///
/// The table is a pure function of `(policy, group count, n, observed
/// crash sequence)`: it starts from the boot-time assignment over all `n`
/// nodes and, on each observed crash, reassigns *only the groups the dead
/// node led* among the live set. Recovery is sticky — a returning node
/// rejoins as a follower of its former groups and regains load only
/// through later crash-time reassignment (`load_aware`) — which is what
/// prevents the rejoin-reclaims-leadership bug class: a recovered
/// ex-leader must never believe it still leads.
#[derive(Clone, Debug)]
pub struct PlacementTable {
    policy: LeaderPlacement,
    n: usize,
    leaders: Vec<NodeId>,
}

/// SplitMix64 finalizer — the rendezvous-hash weight for (group, node).
fn mix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

impl PlacementTable {
    /// Boot-time assignment over the full (all-live) cluster. `Single`
    /// pins every group to the classic initial leader so the table stays
    /// consistent with the unsharded code path.
    pub fn new(policy: LeaderPlacement, groups: usize, n: usize) -> Self {
        let groups = groups.max(1);
        let mut leaders = vec![crate::smr::raft::initial_leader(); groups];
        match policy {
            LeaderPlacement::Single => {}
            LeaderPlacement::Hash => {
                let all: Vec<NodeId> = (0..n).collect();
                for (g, l) in leaders.iter_mut().enumerate() {
                    *l = Self::rendezvous(g, &all);
                }
            }
            LeaderPlacement::RoundRobin => {
                for (g, l) in leaders.iter_mut().enumerate() {
                    *l = g % n;
                }
            }
            LeaderPlacement::LoadAware => {
                // Greedy least-loaded with smallest-id ties: over an
                // all-live boot set this fills nodes 0..n round-robin,
                // but diverges from `RoundRobin` as soon as crashes skew
                // the load.
                let mut load = vec![0usize; n];
                for l in leaders.iter_mut() {
                    let pick = Self::least_loaded(&load, &(0..n).collect::<Vec<_>>());
                    load[pick] += 1;
                    *l = pick;
                }
            }
        }
        PlacementTable { policy, n, leaders }
    }

    /// Highest-random-weight choice of a live node for `group`.
    fn rendezvous(group: usize, live: &[NodeId]) -> NodeId {
        *live
            .iter()
            .max_by_key(|&&node| (mix64(((group as u64) << 32) ^ node as u64), usize::MAX - node))
            .expect("live set is never empty")
    }

    /// Smallest-id node among `live` with minimal current load.
    fn least_loaded(load: &[usize], live: &[NodeId]) -> NodeId {
        *live.iter().min_by_key(|&&node| (load[node], node)).expect("live set is never empty")
    }

    pub fn policy(&self) -> LeaderPlacement {
        self.policy
    }

    /// Current per-group leader view.
    pub fn leaders(&self) -> &[NodeId] {
        &self.leaders
    }

    pub fn leader_of(&self, group: usize) -> NodeId {
        self.leaders[group]
    }

    /// Number of groups each node currently leads (len = cluster size).
    pub fn groups_led(&self) -> Vec<u64> {
        let mut led = vec![0u64; self.n];
        for &l in &self.leaders {
            led[l] += 1;
        }
        led
    }

    /// Install a donor's evolved view (snapshot install on recovery): the
    /// recovering replica missed the crash observations that drove the
    /// donor's reassignments.
    pub fn install(&mut self, leaders: &[NodeId]) {
        debug_assert_eq!(leaders.len(), self.leaders.len());
        self.leaders.clear();
        self.leaders.extend_from_slice(leaders);
    }

    /// Observed crash of `dead`: reassign only the groups it led, among
    /// `live` (which must exclude `dead`). Returns the reassigned
    /// `(group, new leader)` pairs, in group order. Recovery is sticky —
    /// there is deliberately no inverse of this.
    pub fn on_crash(&mut self, dead: NodeId, live: &[NodeId]) -> Vec<(usize, NodeId)> {
        debug_assert!(!live.contains(&dead));
        debug_assert!(!live.is_empty());
        let mut changed = Vec::new();
        // Current load over live nodes (for load_aware), before any moves.
        let mut load = vec![0usize; self.n];
        for &l in &self.leaders {
            if l != dead {
                load[l] += 1;
            }
        }
        for g in 0..self.leaders.len() {
            if self.leaders[g] != dead {
                continue;
            }
            let new = match self.policy {
                // Single keeps the classic rule: smallest live id.
                LeaderPlacement::Single => *live.iter().min().expect("nonempty"),
                LeaderPlacement::Hash => Self::rendezvous(g, live),
                LeaderPlacement::RoundRobin => live[g % live.len()],
                LeaderPlacement::LoadAware => {
                    let pick = Self::least_loaded(&load, live);
                    load[pick] += 1;
                    pick
                }
            };
            self.leaders[g] = new;
            changed.push((g, new));
        }
        changed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn failure_after_threshold_unchanged_reads() {
        let mut t = HeartbeatTracker::new(1, 4, 3);
        assert_eq!(t.observe(0, 5), HbVerdict::Alive);
        assert_eq!(t.observe(0, 5), HbVerdict::Alive);
        assert_eq!(t.observe(0, 5), HbVerdict::Alive); // unchanged #2
        assert_eq!(t.observe(0, 5), HbVerdict::JustFailed); // unchanged #3
        assert!(!t.is_alive(0));
        assert_eq!(t.observe(0, 5), HbVerdict::StillDead);
    }

    #[test]
    fn progressing_heartbeat_stays_alive() {
        let mut t = HeartbeatTracker::new(1, 2, 2);
        for v in 1..100 {
            assert_eq!(t.observe(0, v), HbVerdict::Alive);
        }
        assert!(t.is_alive(0));
    }

    #[test]
    fn recovery_detected() {
        let mut t = HeartbeatTracker::new(1, 2, 1);
        t.observe(0, 5);
        assert_eq!(t.observe(0, 5), HbVerdict::JustFailed);
        assert_eq!(t.observe(0, 6), HbVerdict::Recovered);
        assert!(t.is_alive(0));
    }

    #[test]
    fn elects_smallest_live_id() {
        let mut t = HeartbeatTracker::new(2, 4, 1);
        assert_eq!(t.elect_leader(), 0);
        t.observe(0, 0); // unchanged from initial 0 -> failed (threshold 1)
        assert_eq!(t.elect_leader(), 1);
        t.observe(1, 0);
        assert_eq!(t.elect_leader(), 2, "self is next smallest");
    }

    #[test]
    fn timeout_counts_as_unchanged() {
        let mut t = HeartbeatTracker::new(1, 2, 2);
        t.observe(0, 9);
        assert_eq!(t.observe_timeout(0), HbVerdict::Alive);
        assert_eq!(t.observe_timeout(0), HbVerdict::JustFailed);
    }

    #[test]
    fn placement_single_pins_the_initial_leader() {
        let t = PlacementTable::new(LeaderPlacement::Single, 7, 5);
        assert!(t.leaders().iter().all(|&l| l == crate::smr::raft::initial_leader()));
        assert_eq!(t.groups_led()[0], 7);
    }

    #[test]
    fn sharded_policies_spread_groups_across_nodes() {
        for policy in [LeaderPlacement::Hash, LeaderPlacement::RoundRobin, LeaderPlacement::LoadAware]
        {
            let t = PlacementTable::new(policy, 16, 5);
            let led = t.groups_led();
            assert_eq!(led.iter().sum::<u64>(), 16);
            let leading = led.iter().filter(|&&c| c > 0).count();
            assert!(
                leading >= 4,
                "{}: 16 groups over 5 nodes must engage most nodes: {led:?}",
                policy.name()
            );
            if policy != LeaderPlacement::Hash {
                // The deterministic spreaders are perfectly balanced.
                assert!(led.iter().all(|&c| (3..=4).contains(&c)), "{}: {led:?}", policy.name());
            }
        }
    }

    #[test]
    fn crash_reassigns_only_the_dead_nodes_groups() {
        for policy in [LeaderPlacement::Hash, LeaderPlacement::RoundRobin, LeaderPlacement::LoadAware]
        {
            let mut t = PlacementTable::new(policy, 16, 5);
            let before = t.leaders().to_vec();
            let dead = before[0];
            let live: Vec<NodeId> = (0..5).filter(|&x| x != dead).collect();
            let changed = t.on_crash(dead, &live);
            assert!(!changed.is_empty(), "{}: dead node led groups", policy.name());
            for (g, l) in &changed {
                assert_eq!(before[*g], dead);
                assert_ne!(*l, dead);
            }
            for (g, (&b, &a)) in before.iter().zip(t.leaders()).enumerate() {
                if b != dead {
                    assert_eq!(b, a, "{}: group {g} moved without cause", policy.name());
                }
            }
            assert!(!t.leaders().contains(&dead), "{}: no orphaned groups", policy.name());
        }
    }

    #[test]
    fn load_aware_rebalances_to_least_loaded_and_stays_sticky() {
        let mut t = PlacementTable::new(LeaderPlacement::LoadAware, 10, 5);
        // Crash node 1: its groups land on the least-loaded survivors.
        let live: Vec<NodeId> = vec![0, 2, 3, 4];
        t.on_crash(1, &live);
        let led = t.groups_led();
        assert_eq!(led[1], 0);
        assert_eq!(led.iter().sum::<u64>(), 10);
        assert!(led.iter().enumerate().filter(|&(i, _)| i != 1).all(|(_, &c)| c >= 2), "{led:?}");
        // Sticky recovery: the table has no recover hook, so node 1 leads
        // nothing until a later crash reassignment picks it (it is now the
        // least-loaded live node).
        let view = t.leaders().to_vec();
        assert!(!view.contains(&1));
        let live2: Vec<NodeId> = vec![0, 1, 3, 4];
        let changed = t.on_crash(2, &live2);
        assert!(changed.iter().all(|&(_, l)| l == 1), "recovered node is least-loaded: {changed:?}");
    }

    #[test]
    fn install_realigns_a_diverged_minority_view() {
        // A partition makes its endpoints mis-declare each other dead:
        // each reassigns the other's groups and the tables diverge — the
        // endpoint may even assign groups to itself (the minority
        // imposter). Heal-time realign installs the authority view (a
        // non-endpoint replica whose table never moved, since it saw both
        // sides stay alive) and the views agree again.
        for policy in [LeaderPlacement::Hash, LeaderPlacement::RoundRobin, LeaderPlacement::LoadAware]
        {
            let authority = PlacementTable::new(policy, 16, 5);
            let mut minority = PlacementTable::new(policy, 16, 5);
            let live: Vec<NodeId> = vec![0, 1, 3, 4]; // endpoint 1's view: 2 "died"
            let changed = minority.on_crash(2, &live);
            if !changed.is_empty() {
                assert_ne!(
                    minority.leaders(),
                    authority.leaders(),
                    "{}: views diverged while the cut stood",
                    policy.name()
                );
            }
            minority.install(authority.leaders());
            assert_eq!(minority.leaders(), authority.leaders(), "{}", policy.name());
        }
    }

    #[test]
    fn tables_evolve_identically_from_the_same_observations() {
        // Replicas never exchange placement state: identical inputs must
        // yield identical tables.
        for policy in LeaderPlacement::ALL {
            let mut a = PlacementTable::new(policy, 12, 6);
            let mut b = PlacementTable::new(policy, 12, 6);
            let live: Vec<NodeId> = (0..6).filter(|&x| x != 2).collect();
            assert_eq!(a.on_crash(2, &live), b.on_crash(2, &live));
            let live2: Vec<NodeId> = live.iter().copied().filter(|&x| x != 4).collect();
            assert_eq!(a.on_crash(4, &live2), b.on_crash(4, &live2));
            assert_eq!(a.leaders(), b.leaders());
        }
    }
}
