//! G-Counter and PN-Counter (Table A.1).
//!
//! State is kept **replica-major** — `p[i]` is replica i's summarized
//! contribution — mirroring the paper's N-element array A (§4.1, Fig 4a).
//! This is exactly the layout the `pn_merge` Pallas kernel folds.

use crate::rdt::{mix64, Category, OpCall, QueryValue, Rdt, RdtKind};
use crate::util::rng::Rng;

pub const OP_INCREMENT: u8 = 0;
pub const OP_DECREMENT: u8 = 1;

pub const MAX_REPLICAS: usize = 16;

/// Grow-only counter: increment(x), x >= 0. Reducible (summable).
#[derive(Clone, Debug, Default)]
pub struct GCounter {
    p: [u64; MAX_REPLICAS],
}

impl GCounter {
    pub fn value(&self) -> u64 {
        self.p.iter().sum()
    }

    pub fn contribution(&self, replica: usize) -> u64 {
        self.p[replica]
    }
}

impl Rdt for GCounter {
    fn clone_box(&self) -> Box<dyn Rdt> {
        Box::new(self.clone())
    }

    fn kind(&self) -> RdtKind {
        RdtKind::GCounter
    }

    fn category(&self, _opcode: u8) -> Category {
        Category::Reducible
    }

    fn sync_groups(&self) -> u8 {
        0
    }

    fn permissible(&self, op: &OpCall) -> bool {
        op.is_query() || op.opcode == OP_INCREMENT
    }

    fn apply(&mut self, op: &OpCall) -> bool {
        debug_assert_eq!(op.opcode, OP_INCREMENT);
        self.p[op.origin] += op.a;
        true
    }

    fn query(&self) -> QueryValue {
        QueryValue::Int(self.value() as i64)
    }

    fn state_digest(&self) -> u64 {
        self.p
            .iter()
            .enumerate()
            .fold(0, |acc, (i, &v)| acc ^ mix64(v.wrapping_add((i as u64) << 56)))
    }

    fn gen_update(&self, rng: &mut Rng) -> OpCall {
        OpCall::new(OP_INCREMENT, 1 + rng.gen_range(10), 0, 0.0)
    }
}

/// Positive-negative counter: two G-Counters (increments `p`, decrements
/// `m`). Both ops reducible.
#[derive(Clone, Debug, Default)]
pub struct PnCounter {
    p: [u64; MAX_REPLICAS],
    m: [u64; MAX_REPLICAS],
}

impl PnCounter {
    pub fn value(&self) -> i64 {
        self.p.iter().sum::<u64>() as i64 - self.m.iter().sum::<u64>() as i64
    }

    /// Replica-major contribution rows for the `pn_merge` kernel.
    pub fn contributions(&self) -> (&[u64; MAX_REPLICAS], &[u64; MAX_REPLICAS]) {
        (&self.p, &self.m)
    }
}

impl Rdt for PnCounter {
    fn clone_box(&self) -> Box<dyn Rdt> {
        Box::new(self.clone())
    }

    fn kind(&self) -> RdtKind {
        RdtKind::PnCounter
    }

    fn category(&self, _opcode: u8) -> Category {
        Category::Reducible
    }

    fn sync_groups(&self) -> u8 {
        0
    }

    fn permissible(&self, op: &OpCall) -> bool {
        op.is_query() || matches!(op.opcode, OP_INCREMENT | OP_DECREMENT)
    }

    fn apply(&mut self, op: &OpCall) -> bool {
        match op.opcode {
            OP_INCREMENT => self.p[op.origin] += op.a,
            OP_DECREMENT => self.m[op.origin] += op.a,
            _ => unreachable!("pn-counter opcode {}", op.opcode),
        }
        true
    }

    fn query(&self) -> QueryValue {
        QueryValue::Int(self.value())
    }

    fn state_digest(&self) -> u64 {
        let dp = self
            .p
            .iter()
            .enumerate()
            .fold(0u64, |acc, (i, &v)| acc ^ mix64(v.wrapping_add((i as u64) << 56)));
        let dm = self
            .m
            .iter()
            .enumerate()
            .fold(0u64, |acc, (i, &v)| acc ^ mix64(v.wrapping_add(((i as u64) << 56) | (1 << 48))));
        dp ^ dm.rotate_left(1)
    }

    fn gen_update(&self, rng: &mut Rng) -> OpCall {
        let opcode = if rng.gen_bool(0.5) { OP_INCREMENT } else { OP_DECREMENT };
        OpCall::new(opcode, 1 + rng.gen_range(10), 0, 0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn op(opcode: u8, a: u64, origin: usize) -> OpCall {
        let mut o = OpCall::new(opcode, a, 0, 0.0);
        o.origin = origin;
        o
    }

    #[test]
    fn g_counter_sums_across_origins() {
        let mut c = GCounter::default();
        c.apply(&op(OP_INCREMENT, 5, 0));
        c.apply(&op(OP_INCREMENT, 3, 2));
        assert_eq!(c.value(), 8);
        assert_eq!(c.contribution(2), 3);
    }

    #[test]
    fn pn_counter_value_and_query() {
        let mut c = PnCounter::default();
        c.apply(&op(OP_INCREMENT, 10, 0));
        c.apply(&op(OP_DECREMENT, 4, 1));
        assert_eq!(c.value(), 6);
        assert_eq!(c.query(), QueryValue::Int(6));
    }

    #[test]
    fn pn_counter_ops_commute() {
        let ops = [op(OP_INCREMENT, 3, 0), op(OP_DECREMENT, 2, 1), op(OP_INCREMENT, 7, 2)];
        let mut a = PnCounter::default();
        let mut b = PnCounter::default();
        for o in &ops {
            a.apply(o);
        }
        for o in ops.iter().rev() {
            b.apply(o);
        }
        assert_eq!(a.state_digest(), b.state_digest());
        assert_eq!(a.value(), b.value());
    }

    #[test]
    fn digests_distinguish_p_from_m() {
        let mut a = PnCounter::default();
        let mut b = PnCounter::default();
        a.apply(&op(OP_INCREMENT, 5, 0));
        b.apply(&op(OP_DECREMENT, 5, 0));
        assert_ne!(a.state_digest(), b.state_digest());
    }

    #[test]
    fn gen_update_is_permissible() {
        let c = PnCounter::default();
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let o = c.gen_update(&mut rng);
            assert!(c.permissible(&o));
            assert_eq!(c.category(o.opcode), Category::Reducible);
        }
    }
}
