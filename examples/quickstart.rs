//! Quickstart: a 4-replica SafarDB cluster serving a PN-Counter CRDT over
//! the simulated network-attached-FPGA fabric, plus the same workload on
//! the Hamband CPU/RDMA baseline for contrast.
//!
//! Run: `cargo run --release --example quickstart`

use safardb::config::{SimConfig, WorkloadKind};
use safardb::engine::cluster;
use safardb::rdt::RdtKind;

fn main() {
    println!("SafarDB quickstart: PN-Counter, 4 replicas, 20% updates\n");
    for (name, mut cfg) in [
        ("SafarDB (FPGA)", SimConfig::safardb(WorkloadKind::Micro(RdtKind::PnCounter))),
        ("Hamband (CPU) ", SimConfig::hamband(WorkloadKind::Micro(RdtKind::PnCounter))),
    ] {
        cfg.update_pct = 20;
        cfg.total_ops = 100_000;
        let rep = cluster::run(cfg);
        assert!(rep.converged(), "replicas must converge");
        println!(
            "{name}: response {:>7.3} us | throughput {:>7.3} OPs/us | power {:>5.1} W | converged {}",
            rep.response_us(),
            rep.throughput(),
            rep.power.total_w(),
            rep.converged(),
        );
    }
    println!("\nBoth systems replicate the same RDT library; only the fabric");
    println!("and execution cost models differ (see DESIGN.md).");
}
