"""Layer-2: JAX compute graphs exported for the Rust coordinator.

Each entry composes Layer-1 Pallas kernels into the merge/apply graph that
the Rust replica engine invokes through PJRT (rust/src/runtime). Shapes are
fixed at export time (AOT); the Rust dispatcher pads bursts to these shapes.

Export shape constants mirror the paper's testbed scale: N=8 replicas
(Alveo cluster size), K=1024 FPGA-resident keys per shard tile, B=256 op
burst, W=512 bitmap words (16,384 set elements).
"""

import jax
import jax.numpy as jnp

from .kernels import (
    account_permissibility,
    batch_apply,
    lww_merge,
    pn_merge,
    set_or,
)

N_REPLICAS = 8
K_KEYS = 1024
B_BURST = 256
W_WORDS = 512


def pn_counter_merge(p, m):
    """PN-Counter fold: f32[N,K], f32[N,K] -> (f32[K],)."""
    return (pn_merge(p, m),)


def lww_register_merge(vals, ts):
    """LWW fold: f32[N,K], i32[N,K] -> (f32[K], i32[K])."""
    v, t = lww_merge(vals, ts)
    return (v, t)


def gset_merge(bitmaps):
    """G-Set fold: i32[N,W] -> (i32[W],)."""
    return (set_or(bitmaps),)


def two_p_set_merge(adds, removes):
    """2P-Set fold: present = OR(adds) & ~OR(removes). i32[N,W] x2 -> (i32[W],)."""
    a = set_or(adds)
    r = set_or(removes)
    return (a & ~r,)


def account_guard(b0, deltas):
    """Account batch permissibility: f32[1], f32[B] -> (i32[B], f32[1])."""
    accept, bal = account_permissibility(b0, deltas)
    return (accept, bal)


def kv_burst_apply(state, keys, deltas):
    """KV burst scatter-add: f32[K], i32[B], f32[B] -> (f32[K],)."""
    return (batch_apply(state, keys, deltas),)


def smallbank_burst(state, keys, deltas, b0, guard_deltas):
    """Fused SmallBank step: guard one hot account's batch, then apply the
    KV burst. Exercises kernel composition in a single HLO module so XLA can
    fuse the surrounding element-wise work."""
    accept, bal = account_permissibility(b0, guard_deltas)
    masked = deltas * accept.astype(deltas.dtype)
    new_state = batch_apply(state, keys, masked)
    return (new_state, accept, bal)


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


# name -> (fn, input ShapeDtypeStructs). The AOT exporter and the manifest
# generator both iterate this table; rust/src/runtime parses the manifest.
EXPORTS = {
    "pn_counter_merge": (
        pn_counter_merge,
        (_spec((N_REPLICAS, K_KEYS), jnp.float32), _spec((N_REPLICAS, K_KEYS), jnp.float32)),
    ),
    "lww_register_merge": (
        lww_register_merge,
        (_spec((N_REPLICAS, K_KEYS), jnp.float32), _spec((N_REPLICAS, K_KEYS), jnp.int32)),
    ),
    "gset_merge": (
        gset_merge,
        (_spec((N_REPLICAS, W_WORDS), jnp.int32),),
    ),
    "two_p_set_merge": (
        two_p_set_merge,
        (_spec((N_REPLICAS, W_WORDS), jnp.int32), _spec((N_REPLICAS, W_WORDS), jnp.int32)),
    ),
    "account_guard": (
        account_guard,
        (_spec((1,), jnp.float32), _spec((B_BURST,), jnp.float32)),
    ),
    "kv_burst_apply": (
        kv_burst_apply,
        (_spec((K_KEYS,), jnp.float32), _spec((B_BURST,), jnp.int32), _spec((B_BURST,), jnp.float32)),
    ),
    "smallbank_burst": (
        smallbank_burst,
        (
            _spec((K_KEYS,), jnp.float32),
            _spec((B_BURST,), jnp.int32),
            _spec((B_BURST,), jnp.float32),
            _spec((1,), jnp.float32),
            _spec((B_BURST,), jnp.float32),
        ),
    ),
}
