//! Simplified Raft for the Waverunner baseline [5] (Fig 12).
//!
//! Waverunner accelerates the Raft replication fast path on an FPGA
//! SmartNIC while the application runs in host software; only the leader
//! serves client requests — followers reject and the client re-sends
//! (§5.2 "SafarDB vs Waverunner"). We model the stable-leader fast path:
//! AppendEntries fan-out, majority-ack commit, apply, respond. Leader
//! election on failure is the smallest-live-ID shortcut (documented
//! simplification — Fig 12 runs fault-free).

use std::collections::VecDeque;

use crate::rdt::OpCall;
use crate::sim::NodeId;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RaftStep {
    Wait,
    /// Entry at `index` is committed: apply + respond to the client.
    Commit { index: u64, op: OpCall },
}

/// Leader-side replication pipeline. One in-flight entry at a time
/// (Waverunner's packet-serial fast path), queueing behind it.
#[derive(Debug)]
pub struct RaftLeader {
    pub term: u64,
    n: usize,
    next_index: u64,
    in_flight: Option<(u64, OpCall, u32)>, // (index, op, acks)
    queue: VecDeque<(u64, OpCall)>,
    pub committed: u64,
}

impl RaftLeader {
    pub fn new(n: usize) -> Self {
        RaftLeader { term: 1, n, next_index: 0, in_flight: None, queue: VecDeque::new(), committed: 0 }
    }

    fn majority_acks(&self) -> u32 {
        (self.n / 2) as u32 // leader's own log write is the +1 vote
    }

    /// Client op arrives at the leader. The entry's log index is assigned
    /// immediately (so callers can key pending requests on it); the
    /// AppendEntries fan-out is returned only if the pipeline was empty.
    pub fn submit(&mut self, op: OpCall) -> (u64, Option<(u64, u64, OpCall)>) {
        let index = self.next_index;
        self.next_index += 1;
        if self.in_flight.is_some() {
            self.queue.push_back((index, op));
            return (index, None);
        }
        self.in_flight = Some((index, op, 0));
        (index, Some((self.term, index, op)))
    }

    /// Follower ack for `index`.
    pub fn on_ack(&mut self, term: u64, index: u64) -> RaftStep {
        if term != self.term {
            return RaftStep::Wait;
        }
        let majority = self.majority_acks();
        match &mut self.in_flight {
            Some((idx, op, acks)) if *idx == index => {
                *acks += 1;
                if *acks >= majority {
                    let (i, o) = (*idx, *op);
                    self.in_flight = None;
                    self.committed += 1;
                    RaftStep::Commit { index: i, op: o }
                } else {
                    RaftStep::Wait
                }
            }
            _ => RaftStep::Wait,
        }
    }

    /// After a commit, start the next queued entry if any.
    pub fn pump(&mut self) -> Option<(u64, u64, OpCall)> {
        if self.in_flight.is_some() {
            return None;
        }
        let (index, op) = self.queue.pop_front()?;
        self.in_flight = Some((index, op, 0));
        Some((self.term, index, op))
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }
}

/// Follower-side log acceptance.
#[derive(Debug, Default)]
pub struct RaftFollower {
    pub term: u64,
    entries: Vec<OpCall>,
    pub applied: u64,
}

impl RaftFollower {
    pub fn new() -> Self {
        Self::default()
    }

    /// AppendEntries from the leader; returns whether to ack.
    pub fn on_append(&mut self, term: u64, index: u64, op: OpCall) -> bool {
        if term < self.term {
            return false; // stale leader
        }
        self.term = term;
        let idx = index as usize;
        if idx > self.entries.len() {
            return false; // gap: reject (leader would back up; fast path has none)
        }
        if idx == self.entries.len() {
            self.entries.push(op);
        } else {
            self.entries[idx] = op;
        }
        true
    }

    /// Apply contiguous entries (followers apply on the leader's heels).
    pub fn drain_apply(&mut self) -> Vec<OpCall> {
        let out: Vec<OpCall> = self.entries[self.applied as usize..].to_vec();
        self.applied = self.entries.len() as u64;
        out
    }

    /// Waverunner followers reject client requests (redirect to leader).
    pub fn handles_clients(&self) -> bool {
        false
    }
}

/// Which replica leads (fault-free runs: node 0).
pub fn initial_leader() -> NodeId {
    0
}

#[cfg(test)]
mod tests {
    use super::*;

    fn op(n: u64) -> OpCall {
        OpCall::new(0, n, 0, 0.0)
    }

    #[test]
    fn three_node_commit_needs_one_follower_ack() {
        let mut l = RaftLeader::new(3);
        let (idx, fanout) = l.submit(op(1));
        let (term, fidx, _) = fanout.unwrap();
        assert_eq!((term, fidx, idx), (1, 0, 0));
        let s = l.on_ack(1, 0);
        assert_eq!(s, RaftStep::Commit { index: 0, op: op(1) });
    }

    #[test]
    fn pipeline_serializes_entries() {
        let mut l = RaftLeader::new(3);
        l.submit(op(1)).1.unwrap();
        let (idx2, fanout2) = l.submit(op(2));
        assert_eq!(idx2, 1, "index assigned immediately");
        assert!(fanout2.is_none(), "queued behind in-flight");
        l.on_ack(1, 0);
        let (_, idx, o) = l.pump().unwrap();
        assert_eq!(idx, 1);
        assert_eq!(o.a, 2);
    }

    #[test]
    fn stale_term_acks_ignored() {
        let mut l = RaftLeader::new(3);
        l.submit(op(1)).1.unwrap();
        assert_eq!(l.on_ack(0, 0), RaftStep::Wait);
        assert_eq!(l.on_ack(1, 5), RaftStep::Wait, "wrong index");
    }

    #[test]
    fn follower_appends_in_order_and_applies() {
        let mut f = RaftFollower::new();
        assert!(f.on_append(1, 0, op(1)));
        assert!(f.on_append(1, 1, op(2)));
        assert!(!f.on_append(1, 5, op(9)), "gap rejected");
        let applied = f.drain_apply();
        assert_eq!(applied.len(), 2);
        assert!(!f.handles_clients());
    }

    #[test]
    fn follower_rejects_stale_term() {
        let mut f = RaftFollower::new();
        f.on_append(3, 0, op(1));
        assert!(!f.on_append(2, 1, op(2)));
    }
}
