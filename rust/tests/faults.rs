//! Integration: crash faults, leader election, permission switch, and
//! recovery with log replay (§3 fault model, §4.4 leader switch plane).

use safardb::config::{FaultSchedule, SimConfig, SystemKind, WorkloadKind};
use safardb::engine::cluster;
use safardb::prop_assert;
use safardb::rdt::RdtKind;
use safardb::util::prop;

fn account(system: SystemKind, n: usize, fault: FaultSchedule) -> SimConfig {
    let mut cfg = match system {
        SystemKind::SafarDb => SimConfig::safardb(WorkloadKind::Micro(RdtKind::Account)),
        _ => SimConfig::hamband(WorkloadKind::Micro(RdtKind::Account)),
    };
    cfg.n_replicas = n;
    cfg.update_pct = 20;
    cfg.total_ops = 16_000;
    cfg.fault = fault;
    cfg
}

#[test]
fn leader_crash_elects_smallest_live_id() {
    let rep = cluster::run(account(
        SystemKind::SafarDb,
        5,
        FaultSchedule::crash_leader_at(40),
    ));
    assert!(rep.crashed[0], "initial leader 0 crashed");
    assert_eq!(rep.leader, 1, "smallest live ID becomes leader");
    assert!(rep.metrics.elections >= 1);
    assert!(rep.converged() && rep.invariants_ok);
    // Permission switches were recorded with FPGA-speed latencies (Fig 13).
    assert!(rep.metrics.perm_switch.count() >= 1);
    assert!(rep.metrics.perm_switch.max() <= 24, "FPGA switch is 17/24 ns");
}

#[test]
fn hamband_leader_crash_pays_rnic_switch_cost() {
    let rep = cluster::run(account(
        SystemKind::Hamband,
        4,
        FaultSchedule::crash_leader_at(40),
    ));
    assert!(rep.converged() && rep.invariants_ok);
    assert!(
        rep.metrics.perm_switch.p50() > 10_000,
        "traditional RNIC switch is 100s of us, got {} ns",
        rep.metrics.perm_switch.p50()
    );
}

#[test]
fn follower_crash_keeps_serving() {
    let rep = cluster::run(account(SystemKind::SafarDb, 4, FaultSchedule::crash_at(3, 30)));
    assert!(rep.crashed[3]);
    assert_eq!(rep.leader, 0, "leader unchanged");
    assert!(rep.metrics.elections == 0);
    assert!(rep.converged() && rep.invariants_ok);
    // Redistributed quota: total completed is still the full target.
    assert!(rep.metrics.total_completed() >= 15_990);
}

#[test]
fn crash_quota_redistribution_conserves_every_op() {
    // The dead node's un-issued quota splits across 3 survivors; 3 rarely
    // divides it evenly, so the remainder must be handed out round-robin
    // rather than truncated — a silent truncation would strand ops and
    // show up here as offered < total_ops. The books must balance
    // exactly: every op in the budget was either completed or killed
    // in flight by the crash, and the closed loop never sheds.
    let rep = cluster::run(account(SystemKind::SafarDb, 4, FaultSchedule::crash_at(1, 50)));
    assert!(rep.crashed[1]);
    assert!(rep.converged() && rep.invariants_ok);
    let m = &rep.metrics;
    assert_eq!(m.offered, 16_000, "redistribution lost quota (remainder truncated?)");
    assert_eq!(m.shed, 0, "closed loop cannot shed");
    assert_eq!(
        m.offered,
        m.total_completed() + m.crash_killed,
        "op conservation broke: completed={} crash_killed={}",
        m.total_completed(),
        m.crash_killed
    );
}

#[test]
fn crashed_follower_recovers_and_catches_up_via_log_replay() {
    let rep = cluster::run(account(
        SystemKind::SafarDb,
        4,
        FaultSchedule::crash_then_recover(2, 30, 60),
    ));
    assert!(!rep.crashed[2], "node 2 is back");
    // The recovered node must converge with everyone else: the leader
    // replayed committed entries on heartbeat resume (§3).
    assert!(rep.converged(), "recovered node caught up: {:?}", rep.digests);
    assert!(rep.invariants_ok);
}

#[test]
fn crdt_replica_crash_no_election_needed() {
    let mut cfg = SimConfig::safardb(WorkloadKind::Micro(RdtKind::TwoPSet));
    cfg.n_replicas = 4;
    cfg.update_pct = 25;
    cfg.total_ops = 12_000;
    cfg.fault = FaultSchedule::crash_at(1, 50);
    let rep = cluster::run(cfg);
    assert!(rep.converged() && rep.invariants_ok);
    assert_eq!(rep.metrics.elections, 0, "CRDTs have no leader to lose");
}

#[test]
fn prop_random_crash_points_never_break_safety() {
    prop::check("crash-safety", 0xdead, 14, |rng| {
        let n = 3 + rng.gen_range(5) as usize;
        let node = rng.gen_range(n as u64) as usize;
        let pct = 10 + rng.gen_range(80) as u8;
        let leader_crash = rng.gen_bool(0.4);
        let fault = if leader_crash {
            FaultSchedule::crash_leader_at(pct)
        } else {
            FaultSchedule::crash_at(node, pct)
        };
        let label = fault.label();
        let mut cfg = account(SystemKind::SafarDb, n, fault);
        cfg.total_ops = 8_000;
        cfg.seed = rng.next_u64();
        let rep = cluster::run(cfg);
        prop_assert!(rep.converged(), "diverged under {label}: {:?}", rep.digests);
        prop_assert!(rep.invariants_ok, "integrity broke under {label}");
        Ok(())
    });
}
