//! Fig 17: summarization (batch size 5) on SmallBank across hybrid FPGA
//! shares — batching remote updates improves RT/throughput at the cost of
//! staleness (paper: 4.9× RT / 5× tput at 40 % FPGA, 50 % writes).

use crate::config::{HybridConfig, SimConfig, WorkloadKind};
use crate::expt::common::{cell_ops, f3, run_cells_tagged};
use crate::util::table::Table;

const FPGA_PCTS: &[u8] = &[20, 40, 60, 80];

pub fn run(quick: bool) -> Vec<Table> {
    let mut t = Table::new(
        "Fig 17 — summarization (size 5) on SmallBank, 50% writes",
        &["summarize", "fpga_ops%", "rt_us", "tput_ops_us", "staleness_us"],
    );
    let mut jobs = Vec::new();
    for &size in &[1u32, 5] {
        for &pct in FPGA_PCTS {
            if quick && (pct == 20 || pct == 60) {
                continue;
            }
            let mut cfg = SimConfig::safardb(WorkloadKind::SmallBank);
            cfg.n_replicas = 4;
            cfg.update_pct = 50;
            cfg.summarize_threshold = size;
            let mut h = HybridConfig::smallbank_default();
            h.fpga_ops_pct = pct;
            cfg.hybrid = Some(h);
            jobs.push(((size, pct), (cfg, cell_ops(quick))));
        }
    }
    for ((size, pct), cell, rep) in run_cells_tagged(jobs) {
        t.row(vec![
            size.to_string(),
            pct.to_string(),
            f3(cell.rt_us),
            f3(cell.tput),
            format!("{:.3}", rep.metrics.staleness.mean() / 1000.0),
        ]);
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batching_trades_staleness_for_performance() {
        let t = &run(true)[0];
        let get = |size: &str, pct: &str, col: usize| -> f64 {
            t.rows()
                .iter()
                .find(|r| r[0] == size && r[1] == pct)
                .unwrap()[col]
                .parse()
                .unwrap()
        };
        let rt_gain = get("1", "40", 2) / get("5", "40", 2);
        let tput_gain = get("5", "40", 3) / get("1", "40", 3);
        assert!(rt_gain > 1.2, "rt gain {rt_gain} (paper 4.9x)");
        assert!(tput_gain > 1.2, "tput gain {tput_gain} (paper 5x)");
        assert!(
            get("5", "40", 4) > get("1", "40", 4),
            "staleness must increase with batching"
        );
    }
}
