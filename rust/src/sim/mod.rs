//! Discrete-event simulation core: a virtual nanosecond clock and a
//! deterministic event queue.
//!
//! Everything time-shaped in SafarDB's reproduction flows through here —
//! verb deliveries, ACKs, background pollers, heartbeat scans, crash
//! injections, and closed-loop client arrivals. Determinism: events are
//! totally ordered by `(time, seq)` where `seq` is the global push order,
//! so equal-time events fire in FIFO order and runs are bit-reproducible
//! from the config seed.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::net::verbs::Verb;

/// Virtual time in nanoseconds.
pub type Time = u64;

/// Replica index (0-based).
pub type NodeId = usize;

/// Background timers a replica can arm.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TimerKind {
    /// §4.1 config (2): poll HBM to refresh the on-fabric copy of the
    /// contribution array.
    PollReducible,
    /// §4.2 config (1): poll the per-origin FIFO queues.
    PollIrreducible,
    /// §4.3 config (1): poll the replication log of one sync group.
    PollLog(u8),
    /// Summarization flush deadline (§5.4 Summarization).
    SummarizeFlush,
    /// Per-path batching: drain the relaxed plane's fan-out coalescer so a
    /// partially filled batch never stalls propagation.
    BatchFlush,
    /// Leader-switch plane: heartbeat scanner tick (§4.4).
    HeartbeatScan,
    /// Retry driving the SMR pipeline (leader waiting for quorum timeout).
    SmrTick(u8),
    /// Chaos-mode watchdog on a forwarded conflicting op: if the leader's
    /// reply was lost on a faulty link, re-forward (at-least-once).
    ForwardCheck { request_id: u64 },
    /// Generic continuation: replica finished a locally-serialized work
    /// item and should pick up the next queued one.
    WorkDone,
}

/// Fabric-level fault actions (chaos schedules). These ride the event
/// queue like everything else — so multi-fault scenarios replay
/// deterministically from the config seed — but are consumed by the
/// *cluster's* network actor when popped; the event's `dest` is unused.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NetFault {
    /// Cut the a <-> b link in both directions (NACK-on-partition).
    Partition { a: NodeId, b: NodeId },
    /// Repair every cut link (triggers leader anti-entropy replay).
    Heal,
    /// Silently lose the next `count` verbs on the directed src -> dst link.
    DropNext { src: NodeId, dst: NodeId, count: u32 },
    /// Scale the directed src -> dst one-way latency by `factor_pct`/100.
    DelaySpike { src: NodeId, dst: NodeId, factor_pct: u32 },
    /// End of a delay spike window (armed by the spike's `until_pct`).
    DelayRestore { src: NodeId, dst: NodeId },
}

/// Event payloads.
#[derive(Clone, Debug)]
pub enum EventKind {
    /// A closed-loop client slot at this replica wants to issue its next op.
    ClientArrive { client: usize },
    /// A verb arrives at this node's NIC (payload lands per its dst_mem).
    VerbDeliver { src: NodeId, verb: Verb },
    /// Completion (CQE/ACK) for a verb this node issued earlier.
    AckDeliver { token: u64 },
    /// Negative completion: QP closed at target, target crashed, link
    /// partitioned, or the verb was dropped by fault injection.
    NackDeliver { token: u64 },
    /// A background timer fired.
    Timer(TimerKind),
    /// Fault injection: node crash / recovery (delivered to the node).
    Crash,
    Recover,
    /// Fault injection: link-level action (handled by the cluster).
    Fault(NetFault),
}

#[derive(Clone, Debug)]
pub struct Event {
    pub time: Time,
    pub seq: u64,
    pub dest: NodeId,
    pub kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.time, self.seq).cmp(&(other.time, other.seq))
    }
}

/// Deterministic min-queue of events.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Reverse<Event>>,
    seq: u64,
    now: Time,
    pushed: u64,
    popped: u64,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn now(&self) -> Time {
        self.now
    }

    pub fn push(&mut self, time: Time, dest: NodeId, kind: EventKind) {
        debug_assert!(time >= self.now, "scheduling into the past: {} < {}", time, self.now);
        let seq = self.seq;
        self.seq += 1;
        self.pushed += 1;
        self.heap.push(Reverse(Event { time, seq, dest, kind }));
    }

    pub fn pop(&mut self) -> Option<Event> {
        let ev = self.heap.pop().map(|Reverse(e)| e)?;
        debug_assert!(ev.time >= self.now);
        self.now = ev.time;
        self.popped += 1;
        Some(ev)
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// (pushed, popped) — engine throughput accounting for §Perf.
    pub fn counters(&self) -> (u64, u64) {
        (self.pushed, self.popped)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(q: &mut EventQueue, t: Time) {
        q.push(t, 0, EventKind::Timer(TimerKind::WorkDone));
    }

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        ev(&mut q, 30);
        ev(&mut q, 10);
        ev(&mut q, 20);
        let times: Vec<Time> = std::iter::from_fn(|| q.pop()).map(|e| e.time).collect();
        assert_eq!(times, vec![10, 20, 30]);
    }

    #[test]
    fn equal_times_fifo_by_push_order() {
        let mut q = EventQueue::new();
        q.push(5, 1, EventKind::Timer(TimerKind::WorkDone));
        q.push(5, 2, EventKind::Timer(TimerKind::WorkDone));
        q.push(5, 3, EventKind::Timer(TimerKind::WorkDone));
        let dests: Vec<NodeId> = std::iter::from_fn(|| q.pop()).map(|e| e.dest).collect();
        assert_eq!(dests, vec![1, 2, 3]);
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        ev(&mut q, 10);
        ev(&mut q, 10);
        ev(&mut q, 40);
        let mut last = 0;
        while let Some(e) = q.pop() {
            assert!(e.time >= last);
            last = e.time;
            assert_eq!(q.now(), e.time);
        }
    }

    #[test]
    #[should_panic(expected = "scheduling into the past")]
    #[cfg(debug_assertions)]
    fn rejects_past_scheduling() {
        let mut q = EventQueue::new();
        ev(&mut q, 10);
        q.pop();
        ev(&mut q, 5);
    }
}
