"""Build-time compile package: L1 Pallas kernels + L2 JAX model + AOT export.

Never imported at runtime — the Rust binary is self-contained once
`make artifacts` has produced artifacts/*.hlo.txt.
"""
