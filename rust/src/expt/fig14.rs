//! Fig 14: crash-fault experiments, 4 nodes, 15/20/25 % updates —
//! 2P-Set replica crash (a/b in our layout → panels e/f of the paper),
//! Account follower crash (a/b), Account leader crash (c/d); each vs the
//! fault-free run, for SafarDB and Hamband.
//!
//! Expected shape: replica crash lowers RT slightly (one fewer peer) and
//! lowers throughput (less parallelism); follower crash barely touches
//! SafarDB while Hamband's RT rises ~1.4× (foreground follower-list
//! maintenance); leader crash costs SafarDB ~25 % RT / ~15 % tput vs
//! Hamband ~40 %/40 % (permission-switch gap, Fig 13).

use crate::config::{FaultSchedule, SimConfig, WorkloadKind};
use crate::expt::common::{cell_ops, f3, run_cells_tagged, UPDATE_SWEEP};
use crate::rdt::RdtKind;
use crate::util::table::Table;

fn base(system: &str, rdt: RdtKind) -> SimConfig {
    let mut cfg = match system {
        "SafarDB" => SimConfig::safardb(WorkloadKind::Micro(rdt)),
        _ => SimConfig::hamband(WorkloadKind::Micro(rdt)),
    };
    cfg.n_replicas = 4;
    cfg
}

pub fn run(quick: bool) -> Vec<Table> {
    let scenarios: &[(&str, RdtKind, FaultSchedule)] = &[
        ("2P-Set/none", RdtKind::TwoPSet, FaultSchedule::none()),
        ("2P-Set/replica-crash", RdtKind::TwoPSet, FaultSchedule::crash_at(2, 50)),
        ("Account/none", RdtKind::Account, FaultSchedule::none()),
        ("Account/follower-crash", RdtKind::Account, FaultSchedule::crash_at(3, 50)),
        ("Account/leader-crash", RdtKind::Account, FaultSchedule::crash_leader_at(50)),
    ];
    let mut t = Table::new(
        "Fig 14 — crash faults (4 nodes)",
        &["scenario", "system", "upd%", "rt_us", "tput_ops_us", "elections"],
    );
    let mut jobs = Vec::new();
    for (name, rdt, fault) in scenarios {
        for system in ["SafarDB", "Hamband"] {
            for &u in UPDATE_SWEEP {
                if quick && u != 15 {
                    continue;
                }
                let mut cfg = base(system, *rdt);
                cfg.update_pct = u;
                cfg.fault = fault.clone();
                jobs.push(((*name, system, u), (cfg, cell_ops(quick))));
            }
        }
    }
    for ((name, system, u), cell, rep) in run_cells_tagged(jobs) {
        t.row(vec![
            name.to_string(),
            system.into(),
            u.to_string(),
            f3(cell.rt_us),
            f3(cell.tput),
            rep.metrics.elections.to_string(),
        ]);
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cell(t: &Table, scen: &str, sys: &str) -> (f64, f64) {
        let r = t
            .rows()
            .iter()
            .find(|r| r[0] == scen && r[1] == sys)
            .unwrap();
        (r[3].parse().unwrap(), r[4].parse().unwrap())
    }

    #[test]
    fn fault_shapes_hold() {
        let t = &run(true)[0];

        // Replica crash on a CRDT: throughput drops (less parallelism),
        // SafarDB's response time does not degrade (one fewer peer).
        let (s_rt_none, tp_none) = cell(t, "2P-Set/none", "SafarDB");
        let (s_rt_crash, tp_crash) = cell(t, "2P-Set/replica-crash", "SafarDB");
        assert!(tp_crash < tp_none, "parallelism loss: {tp_crash} vs {tp_none}");
        assert!(s_rt_crash < s_rt_none * 1.1, "CRDT RT flat-or-better after crash");

        // Follower crash: SafarDB keeps serving, with RT essentially flat
        // ("no visible impact", §5.3) and only a small throughput dip.
        let (a_rt_none, a_tp_none) = cell(t, "Account/none", "SafarDB");
        let (a_rt_f, a_tp_f) = cell(t, "Account/follower-crash", "SafarDB");
        assert!(a_rt_f < a_rt_none * 1.25, "SafarDB follower-crash RT delta");
        assert!(a_tp_f > a_tp_none * 0.75, "SafarDB follower-crash tput dip small");

        // Leader crash: elections occur in both systems; SafarDB's
        // permission switch is ns-scale vs Hamband's 100s of µs — the Q5
        // recovery-cost claim this figure supports.
        for sys in ["SafarDB", "Hamband"] {
            let lead = t
                .rows()
                .iter()
                .find(|r| r[0] == "Account/leader-crash" && r[1] == sys)
                .unwrap();
            assert!(lead[5].parse::<u64>().unwrap() >= 1, "{sys}: election must occur");
        }
        // Both systems keep the majority of their throughput (crash model
        // redistributes load; exact deltas in EXPERIMENTS.md).
        let (_, h_tp_none) = cell(t, "Account/none", "Hamband");
        let (_, h_tp_l) = cell(t, "Account/leader-crash", "Hamband");
        let (_, s_tp_l) = cell(t, "Account/leader-crash", "SafarDB");
        assert!(s_tp_l > a_tp_none * 0.6, "SafarDB survives leader crash");
        assert!(h_tp_l > h_tp_none * 0.5, "Hamband survives leader crash");
    }
}
