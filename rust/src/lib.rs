//! # SafarDB (simulated reproduction)
//!
//! A three-layer Rust + JAX + Pallas reproduction of *"SafarDB:
//! FPGA-Accelerated Distributed Transactions via Replicated Data Types"*.
//!
//! Layer 3 (this crate) is the coordinator: a deterministic discrete-event
//! cluster simulation in which real CRDT/WRDT state is replicated over a
//! calibrated RDMA model, with Mu SMR for conflicting transactions, plus
//! the Hamband and Waverunner baselines, the paper's complete experiment
//! harness, and a PJRT runtime executing the AOT-compiled Pallas batch
//! kernels on the data plane. See DESIGN.md for the system inventory.

pub mod config;
pub mod engine;
pub mod expt;
pub mod mem;
pub mod metrics;
pub mod net;
pub mod power;
pub mod rdt;
pub mod runtime;
pub mod sim;
pub mod smr;
pub mod util;
pub mod workload;
