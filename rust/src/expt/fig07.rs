//! Fig 7: irreducible-transaction implementations (§4.2) on LWW-Register
//! and Courseware — RDMA Write (+queue polling) vs RDMA RPC.
//!
//! Expected shape: near-parity for the LWW register (polling hides the
//! queue reads — all replicas are peers); a small RPC edge on Courseware
//! that narrows with node count.

use crate::config::{PropagationMode, SimConfig, WorkloadKind};
use crate::expt::common::{cell_ops, f3, nodes, run_cells_tagged, UPDATE_SWEEP};
use crate::rdt::RdtKind;
use crate::util::table::Table;

const CONFIGS: &[(&str, PropagationMode)] =
    &[("write", PropagationMode::WriteNoBuffer), ("rpc", PropagationMode::Rpc)];

pub fn run(quick: bool) -> Vec<Table> {
    let mut tables = Vec::new();
    for rdt in [RdtKind::LwwRegister, RdtKind::Courseware] {
        let mut t = Table::new(
            &format!("Fig 7 — irreducible configs on {}", rdt.name()),
            &["config", "nodes", "upd%", "rt_us", "tput_ops_us"],
        );
        let mut jobs = Vec::new();
        for &(name, mode) in CONFIGS {
            for &n in nodes(quick) {
                for &u in UPDATE_SWEEP {
                    let mut cfg = SimConfig::safardb(WorkloadKind::Micro(rdt));
                    cfg.prop_irreducible = mode;
                    // Buffered reducible + write-mode conflicting: isolate
                    // the irreducible axis (as the paper's Fig 7 does).
                    cfg.prop_reducible = PropagationMode::WriteBuffered;
                    cfg.prop_conflicting = PropagationMode::WriteNoBuffer;
                    cfg.n_replicas = n;
                    cfg.update_pct = u;
                    jobs.push(((name, n, u), (cfg, cell_ops(quick))));
                }
            }
        }
        for ((name, n, u), cell, _) in run_cells_tagged(jobs) {
            t.row(vec![
                name.into(),
                n.to_string(),
                u.to_string(),
                f3(cell.rt_us),
                f3(cell.tput),
            ]);
        }
        tables.push(t);
    }
    tables
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expt::common::geomean_ratio;

    #[test]
    fn lww_register_near_parity_courseware_small_rpc_edge() {
        let tabs = run(true);
        let series = |t: &crate::util::table::Table, cfg: &str| -> Vec<f64> {
            t.rows().iter().filter(|r| r[0] == cfg).map(|r| r[3].parse().unwrap()).collect()
        };
        // LWW: polling hides everything — ratio close to 1.
        let lww_ratio = geomean_ratio(&series(&tabs[0], "write"), &series(&tabs[0], "rpc"));
        assert!((0.8..1.6).contains(&lww_ratio), "lww write/rpc = {lww_ratio}");
        // Courseware: rpc should not lose.
        let cw_ratio = geomean_ratio(&series(&tabs[1], "write"), &series(&tabs[1], "rpc"));
        assert!(cw_ratio >= 0.95, "courseware write/rpc = {cw_ratio}");
    }
}
