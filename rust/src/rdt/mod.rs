//! Replicated Data Type library.
//!
//! One implementation serves both systems under test: SafarDB's
//! FPGA-resident engine and the Hamband CPU baseline execute exactly this
//! code; only the *cost models* differ (DESIGN.md §5 "One RDT library, two
//! systems").
//!
//! * `crdt::*` — the six CRDTs of Table A.1 (operation-based).
//! * `wrdt::*` — the five WRDTs of Table B.1, with integrity invariants,
//!   permissibility checks, and synchronization groups.
//!
//! Every type implements [`Rdt`]: category routing (reducible / irreducible
//! / conflicting, §2.1), permissibility, op application, a state digest for
//! convergence checks, and an invariant check for integrity tests.

pub mod crdt;
pub mod op;
pub mod wrdt;

pub use op::{Category, ObjectId, OpCall, QueryValue};

use crate::util::rng::Rng;

/// Which concrete RDT a workload instantiates (paper benchmark names).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum RdtKind {
    // CRDTs (Table A.1)
    GCounter,
    PnCounter,
    LwwRegister,
    GSet,
    PnSet,
    TwoPSet,
    // WRDTs (Table B.1)
    Account,
    Courseware,
    Project,
    Movie,
    Auction,
}

impl RdtKind {
    pub fn name(&self) -> &'static str {
        match self {
            RdtKind::GCounter => "G-Counter",
            RdtKind::PnCounter => "PN-Counter",
            RdtKind::LwwRegister => "LWW-Register",
            RdtKind::GSet => "G-Set",
            RdtKind::PnSet => "PN-Set",
            RdtKind::TwoPSet => "2P-Set",
            RdtKind::Account => "Account",
            RdtKind::Courseware => "Courseware",
            RdtKind::Project => "Project",
            RdtKind::Movie => "Movie",
            RdtKind::Auction => "Auction",
        }
    }

    pub fn is_wrdt(&self) -> bool {
        matches!(
            self,
            RdtKind::Account
                | RdtKind::Courseware
                | RdtKind::Project
                | RdtKind::Movie
                | RdtKind::Auction
        )
    }

    /// The paper's five CRDT micro-benchmarks (Fig 9; G-Counter is a
    /// building block, not a benchmark — appendix A.1 footnote).
    pub fn crdt_benchmarks() -> &'static [RdtKind] {
        &[
            RdtKind::PnCounter,
            RdtKind::LwwRegister,
            RdtKind::GSet,
            RdtKind::PnSet,
            RdtKind::TwoPSet,
        ]
    }

    /// The paper's five WRDT micro-benchmarks (Fig 10).
    pub fn wrdt_benchmarks() -> &'static [RdtKind] {
        &[
            RdtKind::Account,
            RdtKind::Courseware,
            RdtKind::Project,
            RdtKind::Movie,
            RdtKind::Auction,
        ]
    }

    pub fn instantiate(&self) -> Box<dyn Rdt> {
        match self {
            RdtKind::GCounter => Box::new(crdt::counter::GCounter::default()),
            RdtKind::PnCounter => Box::new(crdt::counter::PnCounter::default()),
            RdtKind::LwwRegister => Box::new(crdt::lww::LwwRegister::default()),
            RdtKind::GSet => Box::new(crdt::sets::GSet::default()),
            RdtKind::PnSet => Box::new(crdt::sets::PnSet::default()),
            RdtKind::TwoPSet => Box::new(crdt::sets::TwoPSet::default()),
            RdtKind::Account => Box::new(wrdt::account::Account::default()),
            RdtKind::Courseware => Box::new(wrdt::courseware::Courseware::default()),
            RdtKind::Project => Box::new(wrdt::project::Project::default()),
            RdtKind::Movie => Box::new(wrdt::movie::Movie::default()),
            RdtKind::Auction => Box::new(wrdt::auction::Auction::default()),
        }
    }
}

/// Object-level interface shared by all replicated data types (§2.1).
pub trait Rdt: Send {
    fn kind(&self) -> RdtKind;

    /// Transaction category for coordination routing (§2.1). `QUERY_OP` is
    /// never routed.
    fn category(&self, opcode: u8) -> Category;

    /// Synchronization group of a conflicting opcode (Table B.1 SG column).
    fn sync_group(&self, opcode: u8) -> u8 {
        debug_assert!(matches!(self.category(opcode), Category::Conflicting));
        0
    }

    /// Number of synchronization groups (== SMR instances / replication
    /// logs this object needs; Auction has 3, Movie 2, others 1 or 0).
    fn sync_groups(&self) -> u8;

    /// Local precondition validation (§2.1 "permissibility check").
    fn permissible(&self, op: &OpCall) -> bool;

    /// Execute a (permissible) transaction against local state. Returns
    /// false if the op was a no-op under this state (still convergent).
    fn apply(&mut self, op: &OpCall) -> bool;

    /// Apply a *leader-committed* conflicting transaction unconditionally.
    /// A follower's local state may be missing concurrent relaxed updates
    /// (the paper's dependence discussion, §2.1), so leader-accepted ops
    /// must take effect regardless of the local precondition; transient
    /// dips resolve once in-flight relaxed updates land, and the leader's
    /// conservatism guarantees the quiescent invariant. Defaults to
    /// `apply` for types whose apply is already unconditional.
    fn apply_forced(&mut self, op: &OpCall) -> bool {
        self.apply(op)
    }

    /// Read-only query() transaction over local state.
    fn query(&self) -> QueryValue;

    /// Whether this object exposes a query() transaction at all (Movie does
    /// not — §5.2).
    fn has_query(&self) -> bool {
        true
    }

    /// Order-insensitive digest of the full state; equal digests across
    /// replicas at quiescence == convergence.
    fn state_digest(&self) -> u64;

    /// Integrity invariant (Table B.1). CRDTs: trivially true.
    fn invariant_ok(&self) -> bool {
        true
    }

    /// Generate a random update transaction that is locally sensible for
    /// workload driving (may still be impermissible — that is part of the
    /// workload, the engine counts rejects).
    fn gen_update(&self, rng: &mut Rng) -> OpCall;

    /// Human-readable state dump for divergence diagnosis (tests only).
    fn debug_dump(&self) -> String {
        String::new()
    }

    /// Deep-copy for recovery snapshot transfer (§3: a returned replica
    /// catches up on relaxed state via snapshot + committed-log replay).
    fn clone_box(&self) -> Box<dyn Rdt>;
}

/// Order-insensitive 64-bit mix for state digests: XOR of mixed element
/// hashes is set-equality-stable regardless of iteration order.
pub fn mix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Digest helper for f64 state (canonical bit pattern; -0.0 folded to 0.0).
pub fn mix_f64(x: f64) -> u64 {
    let x = if x == 0.0 { 0.0 } else { x };
    mix64(x.to_bits())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_kinds_instantiate_and_report_kind() {
        let kinds = [
            RdtKind::GCounter,
            RdtKind::PnCounter,
            RdtKind::LwwRegister,
            RdtKind::GSet,
            RdtKind::PnSet,
            RdtKind::TwoPSet,
            RdtKind::Account,
            RdtKind::Courseware,
            RdtKind::Project,
            RdtKind::Movie,
            RdtKind::Auction,
        ];
        for k in kinds {
            let o = k.instantiate();
            assert_eq!(o.kind(), k);
            assert!(o.invariant_ok(), "{} starts valid", k.name());
        }
    }

    #[test]
    fn benchmark_lists_match_paper() {
        assert_eq!(RdtKind::crdt_benchmarks().len(), 5);
        assert_eq!(RdtKind::wrdt_benchmarks().len(), 5);
        assert!(RdtKind::wrdt_benchmarks().iter().all(|k| k.is_wrdt()));
        assert!(!RdtKind::crdt_benchmarks().iter().any(|k| k.is_wrdt()));
    }

    #[test]
    fn sync_group_counts_match_table_b1() {
        assert_eq!(RdtKind::Account.instantiate().sync_groups(), 1);
        assert_eq!(RdtKind::Courseware.instantiate().sync_groups(), 1);
        assert_eq!(RdtKind::Project.instantiate().sync_groups(), 1);
        assert_eq!(RdtKind::Movie.instantiate().sync_groups(), 2);
        assert_eq!(RdtKind::Auction.instantiate().sync_groups(), 3);
        assert_eq!(RdtKind::PnCounter.instantiate().sync_groups(), 0);
    }

    #[test]
    fn movie_has_no_query_transaction() {
        assert!(!RdtKind::Movie.instantiate().has_query());
        assert!(RdtKind::Account.instantiate().has_query());
    }

    #[test]
    fn mix64_is_injective_enough() {
        let mut seen = std::collections::HashSet::new();
        for i in 0..10_000u64 {
            assert!(seen.insert(mix64(i)));
        }
    }
}
