//! Project WRDT (Table B.1): business project management.
//!
//! State: employees E, projects P, assignments A.
//! * addEmployee(e) where e ∉ E — irreducible conflict-free.
//! * addProject(p) where p ∉ P, deleteProject(p) where p ∈ P,
//!   assign(e, p) where e ∈ E ∧ p ∈ P ∧ (e,p) ∉ A — conflicting, one group.
//!
//! Structurally the sibling of Courseware (the paper benchmarks both; their
//! performance differs through op-mix and state size, not mechanism).

use std::collections::HashSet;

use crate::rdt::{mix64, Category, OpCall, QueryValue, Rdt, RdtKind};
use crate::util::rng::Rng;

pub const OP_ADD_EMPLOYEE: u8 = 0;
pub const OP_ADD_PROJECT: u8 = 1;
pub const OP_DELETE_PROJECT: u8 = 2;
pub const OP_ASSIGN: u8 = 3;

const ID_UNIVERSE: u64 = 512;

#[derive(Clone, Debug, Default)]
pub struct Project {
    employees: HashSet<u64>,
    projects: HashSet<u64>,
    assignments: HashSet<(u64, u64)>,
}

impl Rdt for Project {
    fn clone_box(&self) -> Box<dyn Rdt> {
        Box::new(self.clone())
    }

    fn kind(&self) -> RdtKind {
        RdtKind::Project
    }

    fn category(&self, opcode: u8) -> Category {
        match opcode {
            OP_ADD_EMPLOYEE => Category::Irreducible,
            OP_ADD_PROJECT | OP_DELETE_PROJECT | OP_ASSIGN => Category::Conflicting,
            _ => Category::Reducible,
        }
    }

    fn sync_group(&self, _opcode: u8) -> u8 {
        0
    }

    fn sync_groups(&self) -> u8 {
        1
    }

    fn permissible(&self, op: &OpCall) -> bool {
        match op.opcode {
            OP_ADD_EMPLOYEE => !self.employees.contains(&op.a),
            OP_ADD_PROJECT => !self.projects.contains(&op.a),
            OP_DELETE_PROJECT => self.projects.contains(&op.a),
            OP_ASSIGN => {
                self.employees.contains(&op.a)
                    && self.projects.contains(&op.b)
                    && !self.assignments.contains(&(op.a, op.b))
            }
            _ => op.is_query(),
        }
    }

    fn apply(&mut self, op: &OpCall) -> bool {
        match op.opcode {
            OP_ADD_EMPLOYEE => self.employees.insert(op.a),
            OP_ADD_PROJECT => self.projects.insert(op.a),
            OP_DELETE_PROJECT => {
                if self.projects.remove(&op.a) {
                    self.assignments.retain(|&(_, p)| p != op.a);
                    true
                } else {
                    false
                }
            }
            OP_ASSIGN => {
                if self.employees.contains(&op.a) && self.projects.contains(&op.b) {
                    self.assignments.insert((op.a, op.b))
                } else {
                    false
                }
            }
            _ => unreachable!("project opcode {}", op.opcode),
        }
    }

    fn apply_forced(&mut self, op: &OpCall) -> bool {
        match op.opcode {
            OP_ASSIGN => self.assignments.insert((op.a, op.b)),
            OP_DELETE_PROJECT => {
                self.projects.remove(&op.a);
                self.assignments.retain(|&(_, p)| p != op.a);
                true
            }
            _ => self.apply(op),
        }
    }

    fn query(&self) -> QueryValue {
        QueryValue::Pair(self.projects.len() as i64, self.assignments.len() as i64)
    }

    fn state_digest(&self) -> u64 {
        let de = self.employees.iter().fold(0u64, |a, &e| a ^ mix64(e));
        let dp = self.projects.iter().fold(0u64, |a, &e| a ^ mix64(e | 1 << 61));
        let da = self
            .assignments
            .iter()
            .fold(0u64, |a, &(e, p)| a ^ mix64(e.wrapping_mul(0x2E7) ^ (p << 32)));
        de ^ dp.rotate_left(11) ^ da.rotate_left(29)
    }

    fn invariant_ok(&self) -> bool {
        self.assignments
            .iter()
            .all(|&(e, p)| self.employees.contains(&e) && self.projects.contains(&p))
    }

    fn gen_update(&self, rng: &mut Rng) -> OpCall {
        match rng.gen_range(4) {
            0 => OpCall::new(OP_ADD_EMPLOYEE, rng.gen_range(ID_UNIVERSE), 0, 0.0),
            1 => OpCall::new(OP_ADD_PROJECT, rng.gen_range(ID_UNIVERSE), 0, 0.0),
            2 => OpCall::new(OP_DELETE_PROJECT, rng.gen_range(ID_UNIVERSE), 0, 0.0),
            _ => OpCall::new(OP_ASSIGN, rng.gen_range(ID_UNIVERSE), rng.gen_range(ID_UNIVERSE), 0.0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn op2(opcode: u8, a: u64, b: u64) -> OpCall {
        OpCall::new(opcode, a, b, 0.0)
    }

    #[test]
    fn assign_needs_both() {
        let mut p = Project::default();
        assert!(!p.permissible(&op2(OP_ASSIGN, 1, 2)));
        p.apply(&op2(OP_ADD_EMPLOYEE, 1, 0));
        p.apply(&op2(OP_ADD_PROJECT, 2, 0));
        assert!(p.apply(&op2(OP_ASSIGN, 1, 2)));
        assert!(p.invariant_ok());
    }

    #[test]
    fn delete_project_cascades() {
        let mut p = Project::default();
        p.apply(&op2(OP_ADD_EMPLOYEE, 1, 0));
        p.apply(&op2(OP_ADD_PROJECT, 2, 0));
        p.apply(&op2(OP_ASSIGN, 1, 2));
        p.apply(&op2(OP_DELETE_PROJECT, 2, 0));
        assert!(p.invariant_ok());
        assert_eq!(p.query(), QueryValue::Pair(0, 0));
    }

    #[test]
    fn categories_match_table_b1() {
        let p = Project::default();
        assert_eq!(p.category(OP_ADD_EMPLOYEE), Category::Irreducible);
        assert_eq!(p.category(OP_ASSIGN), Category::Conflicting);
        assert_eq!(p.sync_groups(), 1);
    }
}
