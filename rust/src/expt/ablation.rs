//! Ablation study (DESIGN.md §5): which *mechanism* buys the headline gap?
//!
//! Starting from the Hamband baseline, each row enables one SafarDB
//! ingredient in isolation on the PN-Counter (relaxed) and Account
//! (conflicting) workloads:
//!
//!   +pipeline   — drop the CQE wait (StRoM-style verb pipelining)
//!   +near-net   — FPGA verb-issue/landing costs (no PCIe doorbell dance)
//!   +near-mem   — BRAM-resident state + wire-speed dispatch (FPGA exec)
//!   full SafarDB — all of the above + RPC verbs
//!
//! The decomposition attributes the Fig 9/10 ratios to their causes — the
//! paper's Design Principles #1 (near-network) and #2 (direct updates).

use crate::config::{SimConfig, SystemParams, WorkloadKind};
use crate::expt::common::{cell_ops, f3, run_cells_tagged};
use crate::mem::MemKind;
use crate::net::fabric::FabricParams;
use crate::rdt::RdtKind;
use crate::util::table::Table;

fn variants() -> Vec<(&'static str, SystemParams)> {
    let base = SystemParams::hamband();
    let mut pipeline = base;
    pipeline.fabric.wait_ack = false;

    let mut near_net = pipeline;
    near_net.fabric = FabricParams::fpga();
    near_net.fabric.supports_rpc = false;
    // Still a host-resident application:
    near_net.fabric.remote_landing_ns = 430;
    near_net.exec = base.exec;

    let mut near_mem = near_net;
    near_mem.fabric.remote_landing_ns = 0;
    near_mem.exec = SystemParams::safardb().exec;
    near_mem.exec.state_mem = MemKind::Bram;

    vec![
        ("hamband", base),
        ("+pipeline", pipeline),
        ("+near-net", near_net),
        ("+near-mem", near_mem),
    ]
}

pub fn run(quick: bool) -> Vec<Table> {
    let mut t = Table::new(
        "Ablation — which mechanism buys the gap? (4 nodes, 20% updates)",
        &["variant", "workload", "rt_us", "tput_ops_us"],
    );
    let mut jobs = Vec::new();
    for rdt in [RdtKind::PnCounter, RdtKind::Account] {
        for (name, params) in variants() {
            let mut cfg = SimConfig::hamband(WorkloadKind::Micro(rdt));
            cfg.update_pct = 20;
            cfg.params_override = Some(params);
            jobs.push(((name, rdt), (cfg, cell_ops(quick))));
        }
        // Full SafarDB (adds RPC verbs on top of near-mem).
        let mut cfg = SimConfig::safardb(WorkloadKind::Micro(rdt));
        cfg.update_pct = 20;
        jobs.push((("safardb(full)", rdt), (cfg, cell_ops(quick))));
    }
    for ((name, rdt), cell, _) in run_cells_tagged(jobs) {
        t.row(vec![name.into(), rdt.name().into(), f3(cell.rt_us), f3(cell.tput)]);
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn each_mechanism_contributes_monotonically_to_throughput() {
        let t = &run(true)[0];
        for rdt in ["PN-Counter", "Account"] {
            let tput = |v: &str| -> f64 {
                t.rows().iter().find(|r| r[0] == v && r[1] == rdt).unwrap()[3].parse().unwrap()
            };
            let (h, p, nm, full) =
                (tput("hamband"), tput("+pipeline"), tput("+near-mem"), tput("safardb(full)"));
            assert!(p > h, "{rdt}: pipelining helps ({p} vs {h})");
            assert!(nm > p * 0.8, "{rdt}: near-mem at least holds ({nm} vs {p})");
            assert!(full >= nm * 0.8, "{rdt}: full SafarDB competitive ({full} vs {nm})");
            assert!(full > h * 2.0, "{rdt}: cumulative gap is large");
        }
    }
}
