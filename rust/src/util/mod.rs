//! Dependency-free utilities: deterministic RNG, statistics, table
//! rendering, a tiny JSON writer, and an in-repo property-test harness.
//!
//! The offline crate set has no `rand`, `serde`, or `proptest`, so these are
//! implemented here (see DESIGN.md §5 "Property testing without proptest").

pub mod hasher;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
